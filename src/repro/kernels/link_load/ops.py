"""jit'd wrappers for sparse per-link load accumulation.

Two layouts of the same computation (see ``repro.chip.mesh_noc.
SparseIncidence``):

* ``link_loads_cols`` — prefix-column plan (``SparseIncidence.col_plan``):
  per-link loads accumulate as K unrolled 1-D gathers + prefix adds over
  count-sorted links, a segment reduction with NO scatter op and no
  padding (sum of column lengths = nnz).  Exact per-link sums (bitwise
  equal to the dense einsum on integer counts), batched over leading
  axes; the chip engine's default sparse path.
* ``link_loads_csr`` — source-major entries, gather + segment-sum
  (scatter-accumulate).  Same results; the oracle the other layouts are
  tested against lives in ref.py.
* ``link_loads_csc`` — link-major (sorted) entries, Pallas prefix-sum
  kernel + boundary differences.  The TPU-throughput variant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.link_load.link_load import (BLOCK_ROWS, LANES,
                                               flat_prefix_sum_pallas)
from repro.kernels.link_load.ref import link_loads_ref


def link_loads_cols(weights, cols, inv_perm, *, n_links: int):
    """weights: (..., P) per-source counts; (cols, inv_perm): a
    ``SparseIncidence.col_plan``.  Returns (..., n_links) link loads.

    Column k gathers the (k+1)-th source of the ``len(cols[k])`` heaviest
    links and adds onto the load prefix (count-sorted link order), so the
    unrolled loop touches exactly nnz entries; the final take restores
    link-id order.  Not jitted itself — the caller traces it inside the
    engine's scan (column lengths are static metadata)."""
    w = weights.astype(jnp.float32)
    acc = jnp.zeros(w.shape[:-1] + (n_links,), jnp.float32)
    for c in cols:
        n_k = c.shape[0]
        acc = acc.at[..., :n_k].add(jnp.take(w, c, axis=-1))
    return jnp.take(acc, inv_perm, axis=-1)


@functools.partial(jax.jit, static_argnames=("n_links",))
def link_loads_csr(weights, link_ids, src_of_entry, *, n_links: int):
    """weights (..., P) per-source counts -> (..., n_links) link loads."""
    return link_loads_ref(weights, link_ids, src_of_entry, n_links)


@functools.partial(jax.jit, static_argnames=("n_links", "interpret"))
def link_loads_csc(weights, src_sorted, link_ptr, *, n_links: int,
                   interpret=True):
    """weights: (P,) per-source counts; src_sorted/link_ptr: the
    ``SparseIncidence.csc`` layout.  Returns (n_links,) link loads."""
    w = jnp.take(weights.astype(jnp.float32), src_sorted)     # (nnz,)
    per = BLOCK_ROWS * LANES
    pad = per if w.shape[0] == 0 else (-w.shape[0]) % per
    if pad:
        w = jnp.pad(w, (0, pad))
    csum = flat_prefix_sum_pallas(w.reshape(-1, LANES),
                                  interpret=interpret).reshape(-1)
    s = jnp.concatenate([jnp.zeros(1, jnp.float32), csum])    # exclusive
    return s[link_ptr[1:]] - s[link_ptr[:-1]]
