"""Sparse link-load accumulation kernel (Pallas, TPU target).

The per-tick NoC accounting over a CSR incidence is a segment-sum: each
entry of a source's multicast tree adds that source's packet weight to one
link.  Scatter-add has no native TPU tile shape, so the kernel uses the
classic sorted-segment formulation: with entries sorted by link id (the
``SparseIncidence.csc`` layout), per-link sums are differences of a
running prefix sum at the link boundaries,

    loads[l] = S[link_ptr[l+1]] - S[link_ptr[l]],   S = exclusive prefix sum

and the prefix sum is one VPU pass: a sequential grid over (BLOCK_ROWS,
128) tiles, the inter-block carry living in a scratch register across grid
steps (same pattern as the MAC-GEMM accumulator).  The boundary gather is
plain jnp in ops.py.

Validated on CPU with interpret=True against ref.py.  Note the numeric
contract: the REF segment-sum is exact per link; the prefix-sum kernel is
exact while the RUNNING TOTAL of all entries stays below float32's 2**24
integer range — ops.link_loads_csr therefore defaults to the ref path and
the kernel is the TPU-throughput variant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BLOCK_ROWS = 8
LANES = 128


def _prefix_sum_kernel(w_ref, o_ref, carry_ref):
    """Inclusive prefix sum of a (R, 128) array in row-major flattened
    order; grid is sequential over row blocks, carry_ref spans blocks."""
    @pl.when(pl.program_id(0) == 0)
    def _init():
        carry_ref[0, 0] = 0.0

    carry = carry_ref[0, 0]
    w = w_ref[...]                                   # (BLOCK_ROWS, 128)
    row_tot = w.sum(axis=1)                          # (BLOCK_ROWS,)
    row_off = jnp.cumsum(row_tot) - row_tot          # exclusive over rows
    o_ref[...] = jnp.cumsum(w, axis=1) + row_off[:, None] + carry
    carry_ref[0, 0] = carry + row_tot.sum()


def flat_prefix_sum_pallas(w, *, interpret=True):
    """w: (R, 128) float32, R multiple of BLOCK_ROWS -> (R, 128) inclusive
    prefix sums of the row-major flattening."""
    R, C = w.shape
    assert C == LANES and R % BLOCK_ROWS == 0, (R, C)
    bs = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _prefix_sum_kernel,
        grid=(R // BLOCK_ROWS,),
        in_specs=[bs],
        out_specs=bs,
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
    )(w)
