from repro.kernels.link_load.ops import (link_loads_cols, link_loads_csc,
                                         link_loads_csr)
from repro.kernels.link_load.ref import link_loads_ref
