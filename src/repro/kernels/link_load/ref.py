"""Pure-jnp oracle of the sparse per-link load accumulation.

One tick of NoC accounting over a CSR multicast-tree incidence
(``repro.chip.mesh_noc.SparseIncidence``): every CSR entry (source p uses
link l) contributes source p's weight to link l's load,

    loads[l] = sum_{e : link_ids[e] == l}  weights[src_of_entry[e]]

— a gather followed by a segment-sum, O(nnz) instead of the dense
O(P * n_links) einsum.  On integer-valued weights (packet or flit counts
below 2**24) float32 accumulation is exact in any order, so this agrees
BITWISE with the dense einsum — the engine's sparse/dense auto-select
never changes results.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def link_loads_ref(weights, link_ids, src_of_entry, n_links: int):
    """weights: (..., P) per-source counts; link_ids/src_of_entry: (nnz,)
    CSR entry arrays.  Returns (..., n_links) per-link loads."""
    w = jnp.take(weights.astype(jnp.float32), src_of_entry, axis=-1)
    wm = jnp.moveaxis(w, -1, 0)                       # (nnz, ...)
    loads = jax.ops.segment_sum(wm, link_ids, num_segments=n_links)
    return jnp.moveaxis(loads, 0, -1)
