"""Fused LIF neuron-update kernel (Pallas, TPU target).

The PE's per-tick neuron loop (decay -> integrate -> threshold -> reset ->
refractory) fused into one VPU pass over a (256, 128) neuron tile; each
lane is one neuron, mirroring how the Arm core iterates neurons in SRAM
while the exp accelerator supplies the decay constant.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lif.ref import FRAC, fx_mul

BLOCK_ROWS = 256
LANES = 128


def _lif_kernel(v_ref, ref_ref, isyn_ref, vo_ref, refo_ref, sp_ref, *,
                alpha, v_th, v_reset, ref_ticks, v_min=None):
    v = v_ref[...].astype(jnp.int32)
    rc = ref_ref[...].astype(jnp.int32)
    isyn = isyn_ref[...].astype(jnp.int32)
    active = rc <= 0
    v1 = fx_mul(v, jnp.int32(alpha)) + isyn
    if v_min is not None:
        v1 = jnp.maximum(v1, jnp.int32(v_min))
    spike = active & (v1 >= v_th)
    vo_ref[...] = jnp.where(spike, v_reset, jnp.where(active, v1, v))
    refo_ref[...] = jnp.where(spike, ref_ticks, jnp.maximum(rc - 1, 0))
    sp_ref[...] = spike.astype(jnp.int32)


def lif_step_pallas(v, ref_ct, i_syn, *, alpha, v_th, v_reset, ref_ticks,
                    v_min=None, interpret=True):
    """All inputs (R, 128) int32; R multiple of BLOCK_ROWS."""
    R, C = v.shape
    assert C == LANES and R % BLOCK_ROWS == 0
    kernel = functools.partial(_lif_kernel, alpha=alpha, v_th=v_th,
                               v_reset=v_reset, ref_ticks=ref_ticks,
                               v_min=v_min)
    bs = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    sds = jax.ShapeDtypeStruct((R, C), jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(R // BLOCK_ROWS,),
        in_specs=[bs, bs, bs],
        out_specs=(bs, bs, bs),
        out_shape=(sds, sds, sds),
        interpret=interpret,
    )(v, ref_ct, i_syn)
