"""jit'd wrappers for the LIF kernel + float<->fixed parameter helpers."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lif.lif import BLOCK_ROWS, LANES, lif_step_pallas
from repro.kernels.explog.ops import fx_exp, to_fx


def lif_params_fx(*, tau_ms: float, v_th: float, v_reset: float,
                  ref_ticks: int, dt_ms: float = 1.0, use_kernel=True,
                  v_min: float | None = None):
    """Fixed-point LIF parameters; alpha from the exp accelerator kernel.

    ``v_min`` is the optional inhibitory-reversal floor (see lif_step_ref)."""
    arg = to_fx(np.float32(-dt_ms / tau_ms))
    alpha = int(fx_exp(arg[None])[0]) if use_kernel else int(
        round(np.exp(-dt_ms / tau_ms) * (1 << 15)))
    return dict(alpha=alpha, v_th=int(to_fx(v_th)), v_reset=int(to_fx(v_reset)),
                ref_ticks=int(ref_ticks),
                v_min=None if v_min is None else int(to_fx(v_min)))


def _pad2d(x):
    n = x.shape[0]
    per = BLOCK_ROWS * LANES
    pad = (-n) % per
    if pad:
        x = jnp.pad(x, (0, pad))
    return x.reshape(-1, LANES), n


@functools.partial(jax.jit,
                   static_argnames=("alpha", "v_th", "v_reset", "ref_ticks",
                                    "v_min", "interpret"))
def lif_step(v, ref_ct, i_syn, *, alpha, v_th, v_reset, ref_ticks,
             v_min=None, interpret=True):
    """v, ref_ct, i_syn: (N,) int32.  Returns (v', ref', spikes) each (N,)."""
    v2, n = _pad2d(v)
    r2, _ = _pad2d(ref_ct)
    i2, _ = _pad2d(i_syn)
    vo, ro, so = lif_step_pallas(v2, r2, i2, alpha=alpha, v_th=v_th,
                                 v_reset=v_reset, ref_ticks=ref_ticks,
                                 v_min=v_min, interpret=interpret)
    unpad = lambda x: x.reshape(-1)[:n]
    return unpad(vo), unpad(ro), unpad(so)
