"""Pure-jnp oracle of the fixed-point LIF neuron update.

SpiNNaker-style s16.15 arithmetic: exponential membrane decay (the decay
factor alpha = exp(-dt/tau) is produced by the exp accelerator), synaptic
current injection, threshold/reset, refractory hold.
"""
from __future__ import annotations

import jax.numpy as jnp

FRAC = 15


def fx_mul(a, b):
    """s16.15 multiply without int32 overflow: split a into hi/lo parts."""
    ah = a >> FRAC                      # arithmetic shift (floor)
    al = a & 0x7FFF
    return ah * b + ((al * b) >> FRAC)


def lif_step_ref(v, ref_ct, i_syn, *, alpha, v_th, v_reset, ref_ticks,
                 v_min=None):
    """One 1 ms tick.  All int32 s16.15 except ref_ct (int32 counts).

    ``v_min`` (optional, s16.15) is the inhibitory reversal floor: the
    membrane cannot hyperpolarize below it, bounding the effect of tonic
    inhibition (conductance-based synapses saturate at E_inh).

    Returns (v_new, ref_new, spikes int32).
    """
    v = v.astype(jnp.int32)
    active = ref_ct <= 0
    v1 = fx_mul(v, jnp.int32(alpha)) + i_syn.astype(jnp.int32)
    if v_min is not None:
        v1 = jnp.maximum(v1, jnp.int32(v_min))
    spike = active & (v1 >= v_th)
    v_new = jnp.where(spike, v_reset, jnp.where(active, v1, v))
    ref_new = jnp.where(spike, ref_ticks, jnp.maximum(ref_ct - 1, 0))
    return v_new, ref_new, spike.astype(jnp.int32)
