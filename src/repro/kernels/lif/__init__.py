from repro.kernels.lif.ops import lif_step, lif_params_fx
from repro.kernels.lif.ref import lif_step_ref, fx_mul
