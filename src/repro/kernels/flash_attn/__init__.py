from repro.kernels.flash_attn.ops import flash_attention_kernel
from repro.kernels.flash_attn.ref import flash_attention_ref
