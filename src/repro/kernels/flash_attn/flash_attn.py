"""Fused flash-attention kernel (Pallas, TPU target) — beyond-paper.

EXPERIMENTS.md section 4 identifies the remaining memory term of the prefill
cells as attention score-chain traffic at HLO fusion boundaries; the fix is
keeping the whole online-softmax inner loop in VMEM.  This kernel is that
fix for the TPU target: one `pallas_call` per (batch, head, q-block) whose
kv loop runs in the grid's innermost dimension with the (m, l, acc)
accumulators resident in VMEM scratch — scores never visit HBM.

It is the paper's output-stationary MAC-array discipline applied to
attention: accumulators stay put, operands stream.

Causal masking is applied per block; fully-masked future blocks are
ZEROED (their contribution) but still iterated — Pallas grids are dense.
On a real deployment `num_stages`/block sizes would be tuned per chip;
here blocks default to MXU-aligned 128s and correctness is validated in
interpret mode against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq, bk, nk, scale, causal):
    """Grid (B*H, nq, nk); kv index is innermost (sequential)."""
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (bq, D)
    k = k_ref[0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    if causal:
        p = jnp.where(kpos <= qpos, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == nk - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, bq=128, bk=128, causal=True,
                           interpret=True):
    """q, k, v: (BH, S, D) — batch*heads flattened.  Returns (BH, S, D)."""
    BH, S, D = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = 1.0 / np.sqrt(D)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk,
                               scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m
            pltpu.VMEM((bq,), jnp.float32),       # l
            pltpu.VMEM((bq, D), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
