"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True):
    """q, k, v: (BH, S, D) -> (BH, S, D)."""
    S = q.shape[1]
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
