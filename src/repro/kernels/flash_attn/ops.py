"""jit'd wrapper: (B, S, H, D) GQA-expanded attention through the fused
Pallas flash kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.flash_attn import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention_kernel(q, k, v, *, causal=True, bq=128, bk=128,
                           interpret=True):
    """q: (B, S, H, D); k, v: (B, S, H, D) (KV pre-expanded to H heads)."""
    B, S, H, D = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    bq = min(bq, S)
    bk = min(bk, S)
    out = flash_attention_pallas(fold(q), fold(k), fold(v),
                                 bq=bq, bk=bk, causal=causal,
                                 interpret=interpret)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
