"""jit'd wrapper: padding / blocking for the MAC conv kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mac_conv.mac_conv import mac_conv2d_pallas


@functools.partial(jax.jit,
                   static_argnames=("stride", "padding", "bh", "bcout",
                                    "interpret"))
def mac_conv2d(x, w, *, stride=(1, 1), padding="VALID", bh=8, bcout=128,
               interpret=True):
    """x: (B,H,W,Cin) int8/uint8; w: (KH,KW,Cin,Cout) -> (B,Ho,Wo,Cout) int32."""
    B, H, W, Cin = x.shape
    KH, KW, _, Cout = w.shape
    sh, sw = stride
    if padding == "SAME":
        Ho = -(-H // sh)
        Wo = -(-W // sw)
        ph = max((Ho - 1) * sh + KH - H, 0)
        pw = max((Wo - 1) * sw + KW - W, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                        (pw // 2, pw - pw // 2), (0, 0)))
    H, W = x.shape[1], x.shape[2]
    Ho = (H - KH) // sh + 1
    Wo = (W - KW) // sw + 1

    bh_eff = min(bh, Ho)
    pad_rows = (-Ho) % bh_eff
    if pad_rows:                              # pad input so Ho divides bh
        x = jnp.pad(x, ((0, 0), (0, pad_rows * sh), (0, 0), (0, 0)))
    bc_eff = min(bcout, max(128, 1)) if Cout >= 128 else Cout
    pad_c = (-Cout) % bc_eff
    if pad_c:
        w = jnp.pad(w, ((0, 0), (0, 0), (0, 0), (0, pad_c)))
    out = mac_conv2d_pallas(x, w, stride=stride, bh=bh_eff, bcout=bc_eff,
                            interpret=interpret)
    return out[:, :Ho, :Wo, :Cout]
