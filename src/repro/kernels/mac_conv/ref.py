"""Pure-jnp oracle for MAC conv2d: patch extraction + exact int32 matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def mac_conv2d_ref(x, w, *, stride=(1, 1), padding="VALID"):
    """x: (B,H,W,Cin) int8/uint8; w: (KH,KW,Cin,Cout) -> (B,Ho,Wo,Cout) int32."""
    B, H, W, Cin = x.shape
    KH, KW, _, Cout = w.shape
    sh, sw = stride
    if padding == "SAME":
        Ho = -(-H // sh)
        Wo = -(-W // sw)
        ph = max((Ho - 1) * sh + KH - H, 0)
        pw = max((Wo - 1) * sw + KW - W, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                        (pw // 2, pw - pw // 2), (0, 0)))
        H, W = x.shape[1], x.shape[2]
    Ho = (H - KH) // sh + 1
    Wo = (W - KW) // sw + 1
    xi = x.astype(jnp.int32)
    out = jnp.zeros((B, Ho, Wo, Cout), jnp.int32)
    for dh in range(KH):
        for dw in range(KW):
            patch = jax.lax.slice(
                xi, (0, dh, dw, 0),
                (B, dh + sh * (Ho - 1) + 1, dw + sw * (Wo - 1) + 1, Cin),
                (1, sh, sw, 1))
            out = out + jnp.einsum("bhwc,co->bhwo", patch,
                                   w[dh, dw].astype(jnp.int32))
    return out
