"""MAC-array 2D convolution kernel (Pallas, TPU target) — the paper's CONV
fetch mode.

SpiNNaker2's CONV mode changes only the *memory fetch pattern* feeding the
same 16x4 MAC array: a shift register reuses input-feature-map rows so the
SRAM fetch relaxes to 4 B / 4 clk.  The TPU analogue implemented here:

* the padded input tile lives in VMEM (the paper partitions layers to fit
  the 128 kB PE SRAM; we partition to fit VMEM),
* the (KH x KW) kernel loop re-slices that resident tile instead of
  re-fetching from HBM — the VMEM-resident reuse is the shift register,
* each tap contributes an MXU-shaped (BH*Wo, Cin) x (Cin, BCout) int8 dot
  into an output-stationary int32 accumulator.

Grid: (batch, out-row blocks, out-channel blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _conv_kernel(x_ref, w_ref, o_ref, acc_ref, *, bh, wo, sh, sw, kh, kw):
    """x_ref: (1, Hp, Wp, Cin) padded input (whole image resident in VMEM);
    w_ref: (kh, kw, Cin, BCout); o_ref: (1, bh, wo, BCout)."""
    i = pl.program_id(1)
    x = x_ref[0]                                        # (Hp, Wp, Cin)
    cin = x.shape[-1]
    acc = jnp.zeros_like(acc_ref)
    for dh in range(kh):
        row0 = i * bh * sh + dh
        rows = jax.lax.dynamic_slice(
            x, (row0, 0, 0), (sh * (bh - 1) + 1, x.shape[1], cin))
        rows = jax.lax.slice(rows, (0, 0, 0), rows.shape, (sh, 1, 1))  # (bh, Wp, Cin)
        for dw in range(kw):
            cols = jax.lax.slice(rows, (0, dw, 0),
                                 (bh, dw + sw * (wo - 1) + 1, cin),
                                 (1, sw, 1))            # (bh, wo, Cin)
            a = cols.reshape(bh * wo, cin).astype(jnp.int32)
            w = w_ref[dh, dw].astype(jnp.int32)         # (Cin, BCout)
            acc += jax.lax.dot_general(
                a, w, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32).reshape(acc.shape)
    acc_ref[...] = acc
    o_ref[0] = acc_ref[...].reshape(bh, wo, -1)


def mac_conv2d_pallas(x, w, *, stride=(1, 1), bh=8, bcout=128,
                      interpret=True):
    """x: (B, Hp, Wp, Cin) int8/uint8 PRE-PADDED; w: (KH, KW, Cin, Cout).

    Returns (B, Ho, Wo, Cout) int32 with Ho = (Hp-KH)//sh + 1.
    Ho must be a multiple of bh and Cout of bcout (ops wrapper pads).
    """
    B, Hp, Wp, Cin = x.shape
    KH, KW, _, Cout = w.shape
    sh, sw = stride
    Ho = (Hp - KH) // sh + 1
    Wo = (Wp - KW) // sw + 1
    assert Ho % bh == 0 and Cout % bcout == 0, (Ho, bh, Cout, bcout)
    grid = (B, Ho // bh, Cout // bcout)
    return pl.pallas_call(
        functools.partial(_conv_kernel, bh=bh, wo=Wo, sh=sh, sw=sw,
                          kh=KH, kw=KW),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Hp, Wp, Cin), lambda b, i, j: (b, 0, 0, 0)),
            pl.BlockSpec((KH, KW, Cin, bcout), lambda b, i, j: (0, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bh, Wo, bcout), lambda b, i, j: (b, i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, Cout), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bh * Wo, bcout), jnp.int32)],
        interpret=interpret,
    )(x, w)
