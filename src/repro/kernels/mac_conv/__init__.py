from repro.kernels.mac_conv.ops import mac_conv2d
from repro.kernels.mac_conv.ref import mac_conv2d_ref
