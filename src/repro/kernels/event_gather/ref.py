"""Pure-jnp oracle of the active-source segment-gather (event-mode NoC).

One tick of event-driven NoC accounting: instead of pushing the DENSE
(P,) per-source packet vector through the incidence (dense einsum or
column plan — ``repro.kernels.link_load``), the event engine hands over a
bounded compacted index buffer ``idx`` of the sources active this tick
(sentinel ``P`` marks unused lanes) and only their multicast-tree rows of
the CSR incidence are touched:

    loads[l] = sum_{k : idx[k] < P}  weights[idx[k]] * [l in tree(idx[k])]

Rows come in the padded layout ``SparseIncidence.padded_rows`` (link ids
right-padded with ``n_links``), so the gather is rectangular.  On
integer-valued weights float32 accumulation is exact in any order, and a
quiescent source contributes exact 0.0 — so as long as ``idx`` covers
every source with a nonzero weight, this agrees BITWISE with the dense
einsum over the full vector.
"""
from __future__ import annotations

import jax.numpy as jnp


def event_link_loads_ref(idx, weights, rows_padded, n_links: int):
    """idx: (cap,) active-source ids, sentinel P for unused lanes;
    weights: (P,) per-source counts; rows_padded: (P, L) padded link ids.
    Returns (n_links,) float32 per-link loads."""
    P_ = weights.shape[-1]
    safe = jnp.minimum(idx, P_ - 1)
    w = jnp.where(idx < P_, weights[safe].astype(jnp.float32), 0.0)  # (cap,)
    ids = rows_padded[safe]                                          # (cap, L)
    loads = jnp.zeros(n_links + 1, jnp.float32)
    loads = loads.at[ids].add(jnp.broadcast_to(w[:, None], ids.shape))
    return loads[:n_links]
