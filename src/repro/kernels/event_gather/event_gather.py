"""Active-source link accumulation kernel (Pallas, TPU target).

The event engine's NoC accounting is a segment reduction over the
gathered CSR rows of the active sources.  Scatter-add has no native TPU
tile shape (same constraint as ``repro.kernels.link_load``), so the
kernel uses the one-hot matmul formulation: with the gathered entries
flattened to ``(M, 1)`` link ids + per-entry weights, the grid walks the
link space in 128-lane blocks and each step materializes the (M, 128)
hit mask against its lane window,

    loads[l] = sum_m  w[m] * [ids[m] == l]

— a masked broadcast + lane reduction, all VPU-shaped.  M is
O(cap * max_tree_links): bounded by the event buffer, independent of P.

Validated on CPU with interpret=True against ref.py; exact on
integer-valued weights (every partial sum is an integer below 2**24).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _onehot_accum_kernel(ids_ref, w_ref, o_ref):
    base = pl.program_id(0) * LANES
    lane = base + jax.lax.broadcasted_iota(jnp.int32, (1, LANES), 1)
    ids = ids_ref[...]                                  # (M, 1) int32
    w = w_ref[...]                                      # (M, 1) float32
    hit = (ids == lane).astype(jnp.float32)             # (M, LANES)
    o_ref[...] = (w * hit).sum(axis=0, keepdims=True)   # (1, LANES)


def onehot_link_accum_pallas(ids, w, *, n_links: int, interpret=True):
    """ids: (M,) int32 link ids (>= n_links = discard); w: (M,) float32
    entry weights.  Returns (n_links,) float32 per-link sums."""
    m = ids.shape[0]
    blocks = -(-max(n_links, 1) // LANES)
    out = pl.pallas_call(
        _onehot_accum_kernel,
        grid=(blocks,),
        in_specs=[pl.BlockSpec((m, 1), lambda j: (0, 0)),
                  pl.BlockSpec((m, 1), lambda j: (0, 0))],
        out_specs=pl.BlockSpec((1, LANES), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, LANES), jnp.float32),
        interpret=interpret,
    )(ids.reshape(m, 1).astype(jnp.int32), w.reshape(m, 1))
    return out.reshape(-1)[:n_links]
