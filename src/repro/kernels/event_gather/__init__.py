from repro.kernels.event_gather.ops import (EVENT_GATHER_IMPLS,
                                            active_source_set,
                                            event_link_loads,
                                            event_link_loads_gather,
                                            event_link_loads_pallas)
from repro.kernels.event_gather.ref import event_link_loads_ref
