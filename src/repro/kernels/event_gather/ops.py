"""jit'd wrappers for the event-mode active-source NoC accumulation.

Layouts of the same computation (see ``repro.kernels.event_gather.ref``):

* ``event_link_loads`` with ``impl="gather"`` — gather the active
  sources' padded CSR rows, flatten, one ``segment_sum``.  O(cap * L)
  work, independent of P; the jnp reference path of the compacted-index
  formulation.
* ``impl="pallas"`` — same gather stage, accumulation through the
  one-hot lane kernel (``event_gather.onehot_link_accum_pallas``,
  interpret mode on CPU, compiled on a real TPU target).
* ``impl="auto"`` — resolved by the ENGINE (``repro.chip.mesh_noc.
  NocAccounting.event_plan``): on CPU it delegates to the dense-weight
  column plan, which is already O(nnz) with no scatter and measured
  fastest there; the compacted-index impls here are the TPU-shaped
  variants and the oracle-tested reference semantics.

All impls sum the same exact integer-valued terms per link (quiescent
lanes contribute exact 0.0), so they agree bitwise with each other and
with the dense einsum whenever ``idx`` covers every nonzero weight.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.event_gather.event_gather import onehot_link_accum_pallas
from repro.kernels.event_gather.ref import event_link_loads_ref

EVENT_GATHER_IMPLS = ("auto", "gather", "pallas")


def active_source_set(weights, cap: int):
    """Compact the nonzero lanes of ``weights`` (..., P) into a (cap,)
    index buffer (one sort — ascending ids first, sentinel P after).
    Returns (idx, n_active); ``n_active > cap`` flags overflow (callers
    fall back to the dense path to stay exact)."""
    P_ = weights.shape[-1]
    act = weights != 0
    dt = jnp.uint16 if P_ <= 0xFFFF else jnp.int32
    tags = jnp.where(act, jnp.arange(P_, dtype=dt),
                     jnp.asarray(P_, dt))
    idx = jax.lax.sort(tags)[..., :cap].astype(jnp.int32)
    return idx, act.sum(axis=-1).astype(jnp.int32)


def gather_entries(idx, weights, rows_padded):
    """Gather stage shared by both compacted impls: (cap,) active ids ->
    flattened (cap * L,) link ids + per-entry float32 weights (0.0 on
    unused lanes)."""
    P_ = weights.shape[-1]
    safe = jnp.minimum(idx, P_ - 1)
    w = jnp.where(idx < P_, weights[safe].astype(jnp.float32), 0.0)
    ids = rows_padded[safe]                              # (cap, L)
    w_entry = jnp.broadcast_to(w[:, None], ids.shape)
    return ids.reshape(-1), w_entry.reshape(-1)


@functools.partial(jax.jit, static_argnames=("n_links",))
def event_link_loads_gather(idx, weights, rows_padded, *, n_links: int):
    ids, w = gather_entries(idx, weights, rows_padded)
    # one extra segment swallows the padding sentinel (id == n_links)
    return jax.ops.segment_sum(w, ids, num_segments=n_links + 1)[:n_links]


@functools.partial(jax.jit, static_argnames=("n_links", "interpret"))
def event_link_loads_pallas(idx, weights, rows_padded, *, n_links: int,
                            interpret=True):
    ids, w = gather_entries(idx, weights, rows_padded)
    return onehot_link_accum_pallas(ids, w, n_links=n_links,
                                    interpret=interpret)


def event_link_loads(idx, weights, rows_padded, *, n_links: int,
                     impl: str = "gather"):
    """Per-link loads from a compacted active-source buffer; see module
    docstring for the impl menu ("auto" resolves to "gather" here — the
    engine-level auto lives on ``NocAccounting.event_plan``)."""
    if impl not in EVENT_GATHER_IMPLS:
        raise ValueError(f"unknown event_gather impl {impl!r}; "
                         f"expected one of {EVENT_GATHER_IMPLS}")
    if impl == "pallas":
        return event_link_loads_pallas(idx, weights, rows_padded,
                                       n_links=n_links)
    return event_link_loads_gather(idx, weights, rows_padded,
                                   n_links=n_links)
