from repro.kernels.mac_gemm.ops import mac_gemm, mac_gemm_dequant
from repro.kernels.mac_gemm.ref import mac_gemm_ref, mac_gemm_dequant_ref
