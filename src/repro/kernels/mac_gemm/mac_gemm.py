"""MAC-array GEMM kernel (Pallas, TPU target).

TPU adaptation of the SpiNNaker2 16x4 8-bit output-stationary MAC array
(paper Fig. 8, "MM mode").  The architectural insight carried over:

* output-stationary accumulation — the int32 accumulator tile lives in VMEM
  scratch across the whole K loop (the paper keeps accumulators in the MAC
  registers while streaming operands from SRAM),
* operand streaming — A tiles stream from HBM to VMEM like the paper's
  128 bit/clk SRAM port; B tiles stream like its NoC port,
* 8-bit multipliers with wide accumulation (int8 x int8 -> int32), giving
  the 2x int8 MXU throughput on TPU (394 TOPS vs 197 TFLOP/s bf16).

Scaling up: the paper's 4x16 array becomes a 128x128 MXU tile; blocks are
(BM, BK) x (BK, BN) with 128-multiples so every dot hits the systolic array
natively.  Validated on CPU with interpret=True against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 128, 128, 128


def _mac_gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    """Grid (M/BM, N/BN, K/BK); K is the innermost (sequential) dimension."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)          # (BM, BK) int8 -> int32
    b = b_ref[...].astype(jnp.int32)          # (BK, BN)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def mac_gemm_pallas(a: jax.Array, b: jax.Array, *, bm=DEFAULT_BM,
                    bn=DEFAULT_BN, bk=DEFAULT_BK, interpret=True) -> jax.Array:
    """a: (M, K) int8/uint8; b: (K, N) int8/uint8 -> (M, N) int32.

    Shapes must be multiples of the block sizes (ops.mac_gemm pads).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    return pl.pallas_call(
        functools.partial(_mac_gemm_kernel, nk=nk),
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(a, b)
