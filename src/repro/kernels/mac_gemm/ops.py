"""jit'd public wrappers around the MAC GEMM kernel (padding + dequant)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.mac_gemm.mac_gemm import (
    DEFAULT_BK, DEFAULT_BM, DEFAULT_BN, mac_gemm_pallas,
)


def _pad_to(x, m0, m1):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mac_gemm(a, b, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
             interpret=True):
    """int8/uint8 GEMM with int32 accumulation; pads to block multiples."""
    M, K = a.shape
    _, N = b.shape
    ap = _pad_to(a, bm, bk)
    bp = _pad_to(b, bk, bn)
    out = mac_gemm_pallas(ap, bp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def mac_gemm_dequant(a, b, a_scale, b_scale, *, bm=DEFAULT_BM, bn=DEFAULT_BN,
                     bk=DEFAULT_BK, interpret=True):
    """W8A8 path: int32 accumulate then per-row/col rescale to f32."""
    acc = mac_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return acc.astype(jnp.float32) * a_scale[:, None] * b_scale[None, :]
