"""Pure-jnp oracle for the MAC GEMM kernel."""
from __future__ import annotations

import jax.numpy as jnp


def mac_gemm_ref(a, b):
    """a: (M,K) int8/uint8; b: (K,N) int8/uint8 -> (M,N) int32 exact."""
    return jnp.matmul(a.astype(jnp.int32), b.astype(jnp.int32))


def mac_gemm_dequant_ref(a, b, a_scale, b_scale):
    """Dequantized W8A8 matmul: per-row a_scale (M,), per-col b_scale (N,)."""
    acc = mac_gemm_ref(a, b).astype(jnp.float32)
    return acc * a_scale[:, None] * b_scale[None, :]
