"""jit'd wrappers: arbitrary shapes + float-facing helpers for the SNN stack."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.explog.explog import (
    BLOCK_ROWS, LANES, fx_exp_pallas, fx_log_pallas,
)
from repro.kernels.explog.ref import FX_ONE


def _shape_to_blocks(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    per = BLOCK_ROWS * LANES
    pad = (-n) % per
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


@functools.partial(jax.jit, static_argnames=("interpret",))
def fx_exp(x, interpret=True):
    """x: int32 s16.15 any shape -> exp(x) int32 s16.15."""
    x2d, n = _shape_to_blocks(x)
    out = fx_exp_pallas(x2d, interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fx_log(x, interpret=True):
    """x: int32 s16.15 any shape, > 0 -> ln(x) int32 s16.15."""
    x2d, n = _shape_to_blocks(x)
    out = fx_log_pallas(x2d, interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


def to_fx(x_float):
    return jnp.round(jnp.asarray(x_float, jnp.float32) * FX_ONE).astype(jnp.int32)


def from_fx(x_fx):
    return x_fx.astype(jnp.float32) / FX_ONE


def fx_exp_float(x_float, interpret=True):
    return from_fx(fx_exp(to_fx(x_float), interpret=interpret))


def fx_log_float(x_float, interpret=True):
    return from_fx(fx_log(to_fx(x_float), interpret=interpret))
