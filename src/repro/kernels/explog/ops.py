"""jit'd wrappers: arbitrary shapes + float-facing helpers for the SNN stack.

Both wrappers take an ``impl`` knob, mirroring the ``link_load_impl``
convention of ``repro.chip.mesh_noc``: "pallas" selects the Pallas kernel
(interpret-mode on CPU hosts, compiled on a real TPU target), "ref" the
pure-jnp bit-exact oracle, and "auto" resolves to the measured-fastest
CPU path — the reference, since interpret-mode Pallas pays a large
per-call overhead.  The two implementations are BIT-IDENTICAL (enforced
by tests/test_kernels_explog.py), so the knob only moves wall time; the
engine's plasticity trace decay (``repro.learn``) selects "auto" so
learning ticks stay fast on interpret-mode hosts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.explog.explog import (
    BLOCK_ROWS, LANES, fx_exp_pallas, fx_log_pallas,
)
from repro.kernels.explog.ref import FX_ONE, fx_exp_ref, fx_log_ref

EXPLOG_IMPLS = ("auto", "ref", "pallas")


def resolve_explog_impl(impl: str) -> str:
    """"auto" -> the reference path (fastest on interpret-mode hosts)."""
    if impl not in EXPLOG_IMPLS:
        raise ValueError(f"unknown explog impl {impl!r}; expected one of "
                         f"{EXPLOG_IMPLS}")
    return "ref" if impl == "auto" else impl


def _shape_to_blocks(x):
    flat = x.reshape(-1)
    n = flat.shape[0]
    per = BLOCK_ROWS * LANES
    pad = (-n) % per
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def fx_exp(x, impl="auto", interpret=True):
    """x: int32 s16.15 any shape -> exp(x) int32 s16.15."""
    if resolve_explog_impl(impl) == "ref":
        return fx_exp_ref(jnp.asarray(x))
    x2d, n = _shape_to_blocks(x)
    out = fx_exp_pallas(x2d, interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def fx_log(x, impl="auto", interpret=True):
    """x: int32 s16.15 any shape, > 0 -> ln(x) int32 s16.15."""
    if resolve_explog_impl(impl) == "ref":
        return fx_log_ref(jnp.asarray(x))
    x2d, n = _shape_to_blocks(x)
    out = fx_log_pallas(x2d, interpret=interpret)
    return out.reshape(-1)[:n].reshape(x.shape)


def to_fx(x_float):
    return jnp.round(jnp.asarray(x_float, jnp.float32) * FX_ONE).astype(jnp.int32)


def from_fx(x_fx):
    return x_fx.astype(jnp.float32) / FX_ONE


def fx_exp_float(x_float, interpret=True):
    return from_fx(fx_exp(to_fx(x_float), interpret=interpret))


def fx_log_float(x_float, interpret=True):
    return from_fx(fx_log(to_fx(x_float), interpret=interpret))
