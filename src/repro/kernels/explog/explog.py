"""Fixed-point exp/log accelerator kernels (Pallas, TPU target).

TPU adaptation of the SpiNNaker2 elementary-function accelerator
([10] ISCAS'17, [11] ARITH'18): s16.15 fixed-point exp/ln via iterative
shift-add over the ln(1 + 2^-k) constant ladder.  In the PE this is a
serial multiplier-free datapath next to the Arm core; on TPU the same
ladder becomes 15 vectorized compare/select steps on the VPU over a
(block_rows, 128)-lane tile — each lane is one "accelerator instance".

Bit-exact against ref.py (same integer ops); scientific accuracy vs float
exp/log is asserted in tests (rel. error < 2^-12).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.explog.ref import FRAC, FX_ONE, LN2, LOG_TABLE, _MAX_EXP_ARG

BLOCK_ROWS = 256
LANES = 128


def _fx_exp_kernel(x_ref, o_ref):
    x = jnp.clip(x_ref[...].astype(jnp.int32), -_MAX_EXP_ARG, _MAX_EXP_ARG)
    n = jnp.floor_divide(x, LN2)
    r = x - n * LN2
    y = jnp.full_like(x, FX_ONE)
    for k in range(1, 16):
        lk = LOG_TABLE[k - 1]
        take = r >= lk
        r = jnp.where(take, r - lk, r)
        y = jnp.where(take, y + (y >> k), y)
    y = y + ((y * r) >> FRAC)
    n = jnp.clip(n, -31, 31)
    y = jnp.where(n >= 0,
                  jnp.where(n >= 16, jnp.int32(2**31 - 1),
                            y << jnp.minimum(n, 15)),
                  y >> jnp.minimum(-n, 31))
    o_ref[...] = y


def _fx_log_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.int32)
    bad = x <= 0
    xs = jnp.maximum(x, 1)
    n = jnp.zeros_like(xs)
    z = xs
    for shift in (15, 8, 4, 2, 1):
        cond = z >= (FX_ONE << shift)
        z = jnp.where(cond, z >> shift, z)
        n = jnp.where(cond, n + shift, n)
    for shift in (8, 4, 2, 1, 1):
        cond = z < (FX_ONE >> (shift - 1))
        z = jnp.where(cond, z << shift, z)
        n = jnp.where(cond, n - shift, n)
    acc = n * LN2
    w = jnp.full_like(xs, FX_ONE)
    for k in range(1, 16):
        lk = LOG_TABLE[k - 1]
        w_next = w + (w >> k)
        take = w_next <= z
        w = jnp.where(take, w_next, w)
        acc = jnp.where(take, acc + lk, acc)
    acc = acc + jnp.floor_divide((z - w) << FRAC, w)
    o_ref[...] = jnp.where(bad, jnp.int32(-(2**30)), acc)


def _elementwise_call(kernel, x2d, interpret=True):
    R, C = x2d.shape
    assert C == LANES and R % BLOCK_ROWS == 0
    return pl.pallas_call(
        kernel,
        grid=(R // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), jnp.int32),
        interpret=interpret,
    )(x2d)


def fx_exp_pallas(x, interpret=True):
    return _elementwise_call(_fx_exp_kernel, x, interpret)


def fx_log_pallas(x, interpret=True):
    return _elementwise_call(_fx_log_kernel, x, interpret)
