"""Bit-exact pure-jnp oracle of the fixed-point exp/log accelerator.

Reimplements the SpiNNaker2 elementary-function accelerator algorithm
([10] Partzsch et al. ISCAS'17, [11] Mikaitis et al. ARITH'18) in s16.15
fixed point: iterative shift-add decomposition over ln(1 + 2^-k) factors —
multiplier-free in hardware; here each iteration is a vectorized
compare/select, which maps onto the TPU VPU.

The Pallas kernel must match these references BIT-EXACTLY; scientific
accuracy vs. float exp/log is asserted separately in tests (rel err
< 2^-12 over the supported range).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FRAC = 15
FX_ONE = 1 << FRAC                      # 1.0 in s16.15
LN2 = int(round(np.log(2.0) * FX_ONE))  # 22713

# ln(1 + 2^-k) table, k = 1..15, s16.15
LOG_TABLE = tuple(int(round(np.log1p(2.0 ** -k) * FX_ONE)) for k in range(1, 16))

_MAX_EXP_ARG = (15 << FRAC)             # overflow guard for s16.15 result


def fx_exp_ref(x):
    """x: int32 s16.15 -> exp(x) int32 s16.15 (saturating)."""
    x = x.astype(jnp.int32)
    x = jnp.clip(x, -_MAX_EXP_ARG, _MAX_EXP_ARG)
    n = jnp.floor_divide(x, LN2)                       # integer part, base 2
    r = x - n * LN2                                    # r in [0, ln2)
    y = jnp.full_like(x, FX_ONE)
    for k in range(1, 16):
        lk = LOG_TABLE[k - 1]
        take = r >= lk
        r = jnp.where(take, r - lk, r)
        y = jnp.where(take, y + (y >> k), y)
    # first-order remainder: y *= (1 + r),  r < 2^-15
    y = y + ((y * r) >> FRAC)
    # apply 2^n with saturation
    n = jnp.clip(n, -31, 31)
    y = jnp.where(n >= 0,
                  jnp.where(n >= 16, jnp.int32(2**31 - 1), y << jnp.minimum(n, 15)),
                  y >> jnp.minimum(-n, 31))
    return y


def fx_log_ref(x):
    """x: int32 s16.15, x > 0 -> ln(x) int32 s16.15 (x <= 0 -> INT32_MIN/2)."""
    x = x.astype(jnp.int32)
    bad = x <= 0
    xs = jnp.maximum(x, 1)
    # normalize to z in [1, 2): find n = floor(log2(xs)) - FRAC
    n = jnp.zeros_like(xs)
    z = xs
    for shift in (15, 8, 4, 2, 1):                     # downward normalize
        cond = z >= (FX_ONE << shift)
        z = jnp.where(cond, z >> shift, z)
        n = jnp.where(cond, n + shift, n)
    for shift in (8, 4, 2, 1, 1):                      # upward normalize
        cond = z < (FX_ONE >> (shift - 1))
        z = jnp.where(cond, z << shift, z)
        n = jnp.where(cond, n - shift, n)
    acc = n * LN2
    w = jnp.full_like(xs, FX_ONE)
    for k in range(1, 16):
        lk = LOG_TABLE[k - 1]
        w_next = w + (w >> k)
        take = w_next <= z
        w = jnp.where(take, w_next, w)
        acc = jnp.where(take, acc + lk, acc)
    # first-order remainder: ln(z/w) ~ (z - w) / w,  w ~ z
    acc = acc + jnp.floor_divide((z - w) << FRAC, w)
    return jnp.where(bad, jnp.int32(-(2**30)), acc)
