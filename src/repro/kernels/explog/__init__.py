from repro.kernels.explog.ops import (EXPLOG_IMPLS, fx_exp, fx_exp_float,
                                      fx_log, fx_log_float,
                                      resolve_explog_impl)
from repro.kernels.explog.ref import fx_exp_ref, fx_log_ref, FX_ONE
