from repro.kernels.explog.ops import fx_exp, fx_log, fx_exp_float, fx_log_float
from repro.kernels.explog.ref import fx_exp_ref, fx_log_ref, FX_ONE
