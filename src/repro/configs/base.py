"""Architecture / shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload shape
is a ``ShapeSpec``.  The (arch x shape) grid drives the multi-pod dry-run and
the roofline table.  Reduced ("smoke") variants of each arch are derived
mechanically so CPU tests stay cheap while exercising the same code paths.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Iterator


# ---------------------------------------------------------------------------
# Architecture configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # "transformer" | "rwkv6" | "rglru"
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- layer pattern -----------------------------------------------------
    # Repeated over depth.  Entries: "attn" (global), "local" (windowed attn),
    # "rglru" (recurrent block).  len(pattern) is the scan-group size.
    layer_pattern: tuple = ("attn",)
    window_size: int = 0              # for "local" layers

    # --- attention details ---------------------------------------------------
    pos_emb: str = "rope"             # "rope" | "sinusoidal" | "none"
    rope_base: float = 10_000.0
    rope_base_global: float = 0.0     # 0 -> same as rope_base (gemma3: 1e6)
    rope_pct: float = 1.0             # partial rotary (glm4 / nemotron: 0.5)
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float = 0.0        # final-logit softcap (0 = off)

    # --- MLP -----------------------------------------------------------------
    mlp: str = "swiglu"               # "swiglu" | "geglu" | "relu2" | "gelu"

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # --- embeddings ----------------------------------------------------------
    embed_scale: bool = False         # multiply embeddings by sqrt(d_model)
    tie_embeddings: bool = False

    # --- modality frontend (stub per assignment) -----------------------------
    frontend: str = "none"            # "none" | "vq_image" | "encodec"
    num_codebooks: int = 1

    # --- recurrent families --------------------------------------------------
    conv_width: int = 4               # temporal conv width (rglru)
    lru_width: int = 0                # RG-LRU state width (0 -> d_model)
    rwkv_head_size: int = 64

    # --- norms ---------------------------------------------------------------
    norm: str = "rmsnorm"             # "rmsnorm" | "layernorm"
    norm_eps: float = 1e-6

    # --- runtime -------------------------------------------------------------
    dtype: str = "bfloat16"
    sub_quadratic: bool = False       # eligible for long_500k
    attn_impl: str = "baseline"       # "baseline" | "packed" (see layers.py)
    attn_part: str = "baseline"       # "baseline" | "expand": repeat KV to
                                      # full head count so attention shards
                                      # head-parallel when kv_heads < TP
    norm_bf16_mul: bool = False       # norms: f32 only inside the variance
                                      # reduction (fused); multiplies stay
                                      # bf16 -> no full-seq f32 tensors
    moe_scatter_out: bool = False     # psum_scatter MoE output over seq
                                      # (matches the SP residual; 16x less
                                      # all-reduce volume than full psum)
    train_gather_bf16: bool = False   # cast params bf16 BEFORE the FSDP
                                      # all-gather (identical numerics: the
                                      # baseline casts the same f32 values
                                      # after gathering; this halves gather
                                      # bytes on the ICI)
    source: str = ""                  # provenance tag from the assignment

    # -------------------------------------------------------------------
    @property
    def moe(self) -> bool:
        return self.num_experts > 0

    @property
    def pattern_len(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_groups(self) -> int:
        return self.num_layers // self.pattern_len

    @property
    def rem_layers(self) -> tuple:
        """Trailing layers that do not fill a whole pattern group."""
        rem = self.num_layers % self.pattern_len
        return self.layer_pattern[:rem]

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    # -------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS and sanity checks)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        n = 0
        n += v * d                                    # embedding
        if not self.tie_embeddings:
            n += v * d * self.num_codebooks           # lm head(s)
        n += d                                        # final norm
        for kind in self._all_layers():
            if kind in ("attn", "local"):
                n += self._attn_params()
                n += self._mlp_params()
                n += 2 * d                            # pre norms
                if self.norm == "layernorm":
                    n += 2 * d
            elif kind == "rglru":
                n += self._rglru_params()
                n += self._mlp_params()
                n += 2 * d
            elif kind == "rwkv":
                # time mixing: r,k,v,g,o projections + token-shift mixing
                # LoRAs (5x32), decay LoRA (64), mu/u/groupnorm vectors
                n += 5 * d * d + d * (1 + 5 + 2 * 5 * 32 + 1 + 2 * 64 + 1 + 2)
                # channel mixing: k (d->dff), v (dff->d), r (d->d), mixes
                n += d * dff + dff * d + d * d + 2 * d
                n += 4 * d                        # two layernorms
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        expert_p = self._expert_params()
        total = self.param_count()
        inactive = (self.num_experts - self.experts_per_token) * expert_p
        return total - inactive * self.num_layers

    def _all_layers(self):
        for g in range(self.num_groups):
            yield from self.layer_pattern
        yield from self.rem_layers

    def _attn_params(self) -> int:
        d = self.d_model
        p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        if self.qk_norm:
            p += 2 * self.head_dim
        return p

    def _expert_params(self) -> int:
        d, dff = self.d_model, self.d_ff
        if self.mlp in ("swiglu", "geglu"):
            return 3 * d * dff
        return 2 * d * dff

    def _mlp_params(self) -> int:
        d, dff = self.d_model, self.d_ff
        if self.moe:
            return self.num_experts * self._expert_params() + d * self.num_experts
        if self.mlp in ("swiglu", "geglu"):
            return 3 * d * dff
        return 2 * d * dff

    def _rglru_params(self) -> int:
        d = self.d_model
        w = self.lru_width or d
        # in/out proj (2 branches) + conv + rg-lru gates + out
        p = 2 * d * w            # x branch + gate branch
        p += self.conv_width * w  # temporal conv (depthwise)
        p += 2 * (w // _RGLRU_BLOCKS) * w  # input & recurrence gates (block-diag)
        p += w                   # lambda
        p += w * d               # out proj
        return p

    # -------------------------------------------------------------------
    def smoke(self) -> "ArchConfig":
        """Mechanically reduced config of the same family for CPU tests."""
        plen = self.pattern_len
        changes = dict(
            name=self.name + "-smoke",
            num_layers=max(plen, 2 if plen == 1 else plen),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=32,
            d_ff=160,
            vocab_size=256,
            window_size=min(self.window_size, 8) if self.window_size else 0,
            lru_width=128 if self.lru_width else 0,
            rwkv_head_size=32,
        )
        if self.moe:
            changes.update(num_experts=4, experts_per_token=min(self.experts_per_token, 2))
        return dataclasses.replace(self, **changes)


_RGLRU_BLOCKS = 1  # block-diagonal gate factor (1 = dense, matches small widths)


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeSpec) -> bool:
    """long_500k only for sub-quadratic archs (see DESIGN.md table)."""
    if shape.name == "long_500k":
        return arch.sub_quadratic
    return True


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    assert cfg.name not in _REGISTRY, f"duplicate arch {cfg.name}"
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_archs() -> dict:
    return dict(_REGISTRY)


def cells() -> Iterator[tuple]:
    """Yield every applicable (arch, shape) dry-run cell."""
    for arch in _REGISTRY.values():
        for shape in SHAPES.values():
            if shape_applicable(arch, shape):
                yield arch, shape
