"""gemma3-27b — dense transformer, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt; unverified]  62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144.  head_dim=128 (explicit, gemma3 style: q_dim != d_model).
Pattern: 5 sliding-window (1024) layers then 1 global layer; global layers use
rope base 1e6.
"""
from repro.configs.base import ArchConfig, register

GEMMA3_27B = register(ArchConfig(
    name="gemma3-27b",
    family="transformer",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    window_size=1024,
    mlp="geglu",
    qk_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    rope_base=10_000.0,
    rope_base_global=1_000_000.0,
    sub_quadratic=True,        # 5/6 of layers are sliding-window
    source="hf:google/gemma-3-1b-pt (family); 27b geometry per assignment",
))
