"""qwen1.5-4b — dense transformer with QKV bias (MHA: kv == heads).

[hf:Qwen/Qwen1.5-0.5B (family); hf]  40L d_model=2560 20H (GQA kv=20)
d_ff=6912 vocab=151936.
"""
from repro.configs.base import ArchConfig, register

QWEN15_4B = register(ArchConfig(
    name="qwen1.5-4b",
    family="transformer",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    layer_pattern=("attn",),
    mlp="swiglu",
    qkv_bias=True,
    rope_base=10_000.0,
    sub_quadratic=False,
    source="hf:Qwen/Qwen1.5-0.5B (family); 4b geometry per assignment",
))
