"""musicgen-large — decoder-only transformer over EnCodec audio tokens.

[arXiv:2306.05284; hf]  48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048.  Backbone only per assignment: the EnCodec frontend is a stub
that supplies precomputed frame embeddings (sum of 4 codebook embeddings,
delay-pattern interleaving abstracted away).  4 codebook output heads.
"""
from repro.configs.base import ArchConfig, register

MUSICGEN_LARGE = register(ArchConfig(
    name="musicgen-large",
    family="transformer",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    layer_pattern=("attn",),
    mlp="gelu",
    pos_emb="sinusoidal",
    norm="layernorm",
    frontend="encodec",
    num_codebooks=4,
    sub_quadratic=False,
    source="arXiv:2306.05284 / hf:facebook/musicgen-large",
))
