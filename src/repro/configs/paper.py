"""Constants measured/defined in the paper (Hoeppner et al. 2021).

Table I   — energy-model parameters of the SpiNNaker2 test chip PE.
Table II  — synfire chain network parameters.
Sec. VI-A — MAC array efficiency operating points.
Plus the TPU-v5e roofline constants used by the framework-level energy /
roofline model (DESIGN.md section 7).
"""
from __future__ import annotations

from dataclasses import dataclass


# ---------------------------------------------------------------------------
# Performance levels (test chip, Sec. VI-B)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PerfLevel:
    name: str
    vdd: float          # V
    freq_hz: float      # Hz
    p_baseline_w: float     # P_BL,i  [W]   (Table I)
    e_neuron_j: float       # e_neur,i [J]  (Table I)
    e_synapse_j: float      # e_syn,i  [J]  (Table I)


PL1 = PerfLevel("PL1", 0.5, 100e6, 22.38e-3, 1.51e-9, 0.20e-9)
PL2 = PerfLevel("PL2", 0.5, 200e6, 29.72e-3, 1.50e-9, 0.20e-9)
PL3 = PerfLevel("PL3", 0.6, 400e6, 66.44e-3, 1.89e-9, 0.26e-9)
PERF_LEVELS = (PL1, PL2, PL3)

# Implementation operating points (Sec. IV-B): MEP & high-performance level.
MEP_VDD, MEP_FREQ = 0.50, 200e6
HIGH_VDD, HIGH_FREQ = 0.60, 400e6

# ---------------------------------------------------------------------------
# Synfire chain (Table II + Sec. VI-B)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SynfireParams:
    n_exc: int = 200                 # excitatory neurons per PE
    n_inh: int = 50                  # inhibitory neurons per PE
    neurons_per_core: int = 250
    synapses_per_core: int = 20_000
    avg_fan_out: int = 80
    fan_in_exc: int = 60             # presynaptic exc connections per neuron
    fan_in_inh: int = 25             # presynaptic inh connections per exc neuron
    l_th1: int = 17                  # spike-count threshold PL1 -> PL2
    l_th2: int = 59                  # spike-count threshold PL2 -> PL3
    delay_inh_ms: float = 8.0        # inh -> exc synaptic delay
    delay_exc_ms: float = 10.0       # exc -> next layer delay
    t_sys_ms: float = 1.0            # simulation tick
    n_pes: int = 8                   # test chip: 2 QPEs = 8 PEs, ring


SYNFIRE = SynfireParams()

# Paper Table III reference results (mW) for validation
TABLE_III = {
    "only_pl3": {"baseline": 66.4, "neuron": 3.3, "synapse": 1.6, "total": 71.3},
    "dvfs": {"baseline": 24.3, "neuron": 2.6, "synapse": 1.3, "total": 28.2},
    "reduction": {"baseline": 0.634, "neuron": 0.212, "synapse": 0.187, "total": 0.604},
}

# ---------------------------------------------------------------------------
# PE / MAC array (Sec. III-C, VI-A)
# ---------------------------------------------------------------------------

MAC_ROWS, MAC_COLS = 4, 16           # 16x4 MAC array, 64 MACs/cycle
MAC_OPS_PER_CYCLE = 2 * MAC_ROWS * MAC_COLS   # 1 MAC = 2 ops
SRAM_BYTES = 128 * 1024              # 128 kB local SRAM per PE
SRAM_PORT_BYTES_PER_CLK = 16         # 128 bit / clk local SRAM port
NOC_PORT_BYTES_PER_CLK = 16          # 128 bit / clk NoC port

# Measured MAC efficiency (Fig. 15); the hardware data-transfer bug divides
# achieved TOPS/W by ~1.56.
MAC_TOPS_PER_W = {
    (0.50, 200e6): 1.47,
    (0.60, 400e6): 1.51,
    (0.50, 320e6): 1.75,
}
MAC_HW_BUG_FACTOR = 1.56

# CoreMark processor efficiency (Fig. 14), uW/MHz
COREMARK_UW_PER_MHZ = {(0.50, 200e6): 16.68, (0.60, 400e6): 20.16}

# NoC (Sec. III-A)
DNOC_FLIT_BITS = 192
CNOC_FLIT_BITS = 32
NOC_HOP_CYCLES = 5
NOC_FREQ_HZ = 400e6
NOC_PAYLOAD_BITS_MAX = 128

# Loihi comparison point (Sec. VI-C): 24 pJ / synaptic op
LOIHI_PJ_PER_SYNOP = 24.0

# NEF neuron-update dynamic energy (Sec. VI-C).  The Table I e_neur
# (1.5 nJ) was measured on the SNN benchmark whose per-neuron work includes
# the event-driven synapse-FIFO walk; the NEF neuron loop only integrates
# the MAC-array-precomputed current.  Calibrated against the paper's own
# reported operating point (~10 pJ per equivalent synop at 512 neurons).
NEF_E_NEURON_J = 0.5e-9

# ---------------------------------------------------------------------------
# Cycle model for the SNN engine (used to compute t_sp in Eq. (1)).
# Derived from Table I: the dynamic energy per neuron/synapse update and the
# baseline powers imply per-update service times on the order of hundreds of
# processor cycles, consistent with SpiNNaker-1 software loops [8,9].
# ---------------------------------------------------------------------------
CYCLES_PER_NEURON_UPDATE = 100
CYCLES_PER_SYN_EVENT = 32
CYCLES_TICK_OVERHEAD = 2_000         # wake-up, FIFO drain, bookkeeping

# ---------------------------------------------------------------------------
# TPU v5e-class roofline constants (framework target hardware)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ChipSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s
    peak_flops_int8: float = 394e12      # FLOP/s (2x bf16)
    hbm_bw: float = 819e9                # B/s
    hbm_bytes: int = 16 * 1024**3        # capacity
    ici_bw_per_link: float = 50e9        # B/s/link
    ici_links: int = 4                   # 2D torus: +-x, +-y
    vmem_bytes: int = 128 * 1024**2      # ~128 MB VMEM
    # Energy model (approximate public numbers for 5nm-class accelerators):
    idle_power_w: float = 80.0           # static + infra per chip
    peak_power_w: float = 250.0
    pj_per_flop_bf16: float = 0.55       # dynamic
    pj_per_hbm_byte: float = 120.0 / 64  # ~1.9 pJ/byte
    pj_per_ici_byte: float = 10.0


TPU_V5E = ChipSpec()
