"""glm4-9b — dense transformer, extreme GQA (kv=2), partial rotary.

[hf:THUDM/glm-4-9b; hf]  40L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=151552.  GLM4 uses 50% partial rotary embedding and QKV bias.
"""
from repro.configs.base import ArchConfig, register

GLM4_9B = register(ArchConfig(
    name="glm4-9b",
    family="transformer",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151552,
    layer_pattern=("attn",),
    mlp="swiglu",
    rope_pct=0.5,
    qkv_bias=True,
    rope_base=10_000.0,
    sub_quadratic=False,
    source="hf:THUDM/glm-4-9b",
))
