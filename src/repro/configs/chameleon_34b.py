"""chameleon-34b — early-fusion VLM over a unified text+VQ-image vocab.

[arXiv:2405.09818; unverified]  48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536.  Early fusion: images are VQ-quantized into tokens of the SAME
vocabulary, so the backbone is a plain decoder-only transformer; the VQ
tokenizer is the (stubbed) modality frontend.  Chameleon uses QK-norm for
training stability.
"""
from repro.configs.base import ArchConfig, register

CHAMELEON_34B = register(ArchConfig(
    name="chameleon-34b",
    family="transformer",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    layer_pattern=("attn",),
    mlp="swiglu",
    qk_norm=True,
    frontend="vq_image",
    rope_base=10_000.0,
    sub_quadratic=False,
    source="arXiv:2405.09818",
))
