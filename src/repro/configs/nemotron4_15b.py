"""nemotron-4-15b — dense transformer with squared-ReLU MLP.

[arXiv:2402.16819; unverified]  32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000.  Nemotron-4: squared-ReLU (no gating), partial rotary 50%,
LayerNorm.
"""
from repro.configs.base import ArchConfig, register

NEMOTRON4_15B = register(ArchConfig(
    name="nemotron-4-15b",
    family="transformer",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    layer_pattern=("attn",),
    mlp="relu2",
    rope_pct=0.5,
    norm="layernorm",
    rope_base=10_000.0,
    sub_quadratic=False,
    source="arXiv:2402.16819",
))
