"""rwkv6-1.6b ("Finch") — attention-free RNN with data-dependent decay.

[arXiv:2404.05892; unverified]  24L d_model=2048 d_ff=7168 vocab=65536.
Head size 64 -> 32 WKV heads.  O(1)-state decode; eligible for long_500k.
"""
from repro.configs.base import ArchConfig, register

RWKV6_1B6 = register(ArchConfig(
    name="rwkv6-1.6b",
    family="rwkv6",
    num_layers=24,
    d_model=2048,
    num_heads=32,              # d_model / rwkv_head_size
    num_kv_heads=32,
    head_dim=64,
    d_ff=7168,
    vocab_size=65536,
    layer_pattern=("rwkv",),
    mlp="rwkv_channel_mix",    # RWKV channel mixing (squared-relu variant)
    rwkv_head_size=64,
    pos_emb="none",
    norm="layernorm",
    sub_quadratic=True,
    source="arXiv:2404.05892",
))
