"""Config registry: importing this package registers all assigned archs."""
from repro.configs.base import (
    ArchConfig,
    ShapeSpec,
    SHAPES,
    all_archs,
    cells,
    get_arch,
    register,
    shape_applicable,
)

# Importing registers each architecture.
from repro.configs.phi35_moe import PHI35_MOE
from repro.configs.olmoe import OLMOE
from repro.configs.gemma3_27b import GEMMA3_27B
from repro.configs.glm4_9b import GLM4_9B
from repro.configs.nemotron4_15b import NEMOTRON4_15B
from repro.configs.qwen15_4b import QWEN15_4B
from repro.configs.chameleon_34b import CHAMELEON_34B
from repro.configs.rwkv6_1b6 import RWKV6_1B6
from repro.configs.musicgen_large import MUSICGEN_LARGE
from repro.configs.recurrentgemma_2b import RECURRENTGEMMA_2B

from repro.configs import paper

ASSIGNED = [
    "phi3.5-moe-42b-a6.6b",
    "olmoe-1b-7b",
    "gemma3-27b",
    "glm4-9b",
    "nemotron-4-15b",
    "qwen1.5-4b",
    "chameleon-34b",
    "rwkv6-1.6b",
    "musicgen-large",
    "recurrentgemma-2b",
]

__all__ = [
    "ArchConfig", "ShapeSpec", "SHAPES", "all_archs", "cells", "get_arch",
    "register", "shape_applicable", "paper", "ASSIGNED",
]
