"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE transformer.

[hf:microsoft/Phi-3.5-MoE-instruct; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2.
"""
from repro.configs.base import ArchConfig, register

PHI35_MOE = register(ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="transformer",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    layer_pattern=("attn",),
    mlp="swiglu",
    num_experts=16,
    experts_per_token=2,
    norm="layernorm",          # Phi-3.5-MoE uses LayerNorm
    rope_base=10_000.0,
    sub_quadratic=False,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
))
