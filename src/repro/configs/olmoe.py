"""olmoe-1b-7b — 64-expert top-8 MoE transformer.

[arXiv:2409.02060; hf]  16L d_model=2048 16H (GQA kv=16) d_ff=1024
vocab=50304, MoE 64e top-8.
"""
from repro.configs.base import ArchConfig, register

OLMOE = register(ArchConfig(
    name="olmoe-1b-7b",
    family="transformer",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    layer_pattern=("attn",),
    mlp="swiglu",
    num_experts=64,
    experts_per_token=8,
    qk_norm=True,              # OLMoE applies QK-norm
    rope_base=10_000.0,
    sub_quadratic=False,
    source="arXiv:2409.02060",
))
