"""recurrentgemma-2b — Griffin-style hybrid: RG-LRU + local attention (2:1).

[arXiv:2402.19427; hf]  26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680
vocab=256000.  Pattern: (recurrent, recurrent, local-attention) repeated;
sliding window 2048; RG-LRU width 2560, temporal conv width 4.
O(1)-state recurrent decode; eligible for long_500k.
"""
from repro.configs.base import ArchConfig, register

RECURRENTGEMMA_2B = register(ArchConfig(
    name="recurrentgemma-2b",
    family="rglru",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    mlp="geglu",
    embed_scale=True,
    tie_embeddings=True,
    conv_width=4,
    lru_width=2560,
    rope_base=10_000.0,
    sub_quadratic=True,
    source="arXiv:2402.19427",
))
