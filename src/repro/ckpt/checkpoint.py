"""Sharded checkpointing with atomic publish, async save and elastic restore.

Layout per step:
    <dir>/step_000123/
        manifest.json       tree structure, shapes, dtypes, step, meta
        <leafpath>.npy      one file per leaf (per-process shard set in a
                            multi-host deployment; this container is 1 proc)
    <dir>/LATEST            text file with the newest published step

Atomicity: a checkpoint is written into step_XXXX.tmp and os.replace'd into
place, then LATEST is swapped — a crash mid-save never corrupts the
previous checkpoint (power-fail-safe publish).

Elastic restore: leaves are loaded host-side (mmap) and device_put against
the *target* mesh's shardings — the saved and restored mesh shapes are
independent, which is what elastic re-scaling needs.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k2 in sorted(tree):
            out.update(_flatten(tree[k2], f"{prefix}.{k2}" if prefix else str(k2)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}[{i}]"))
    else:
        out[prefix] = tree
    return out


def _unflatten_into(struct, flat):
    """Rebuild values for a template tree `struct` from {path: leaf}."""
    def walk(node, prefix):
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}.{k}" if prefix else str(k))
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [walk(v, f"{prefix}[{i}]") for i, v in enumerate(node)]
        return flat[prefix]
    return walk(struct, "")


def _sanitize(path: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.\[\]-]", "_", path)


class CheckpointManager:
    def __init__(self, directory, *, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, meta: dict | None = None):
        """Snapshot to host memory synchronously, write to disk (async)."""
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, meta or {}), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta or {})

    def _write(self, step: int, host: dict, meta: dict):
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "meta": meta, "leaves": {}}
        for k, v in host.items():
            fn = _sanitize(k) + ".npy"
            np.save(tmp / fn, v)
            manifest["leaves"][k] = {
                "file": fn, "shape": list(v.shape), "dtype": str(v.dtype)}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        latest_tmp = self.dir / "LATEST.tmp"
        latest_tmp.write_text(str(step))
        os.replace(latest_tmp, self.dir / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore
    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if p.is_dir() and not p.name.endswith(".tmp")]

    def latest_step(self):
        f = self.dir / "LATEST"
        if f.exists():
            s = int(f.read_text().strip())
            if (self.dir / f"step_{s:08d}").exists():
                return s
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, template, step: int | None = None, *, shardings=None,
                mesh=None):
        """template: pytree with the target structure (values ignored).
        shardings: optional matching tree of NamedSharding for elastic
        placement on a (possibly different) mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {}
        for k, info in manifest["leaves"].items():
            arr = np.load(d / info["file"], mmap_mode="r")
            flat[k] = arr
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda v, s: jax.device_put(jnp.asarray(v), s), tree, shardings)
        else:
            tree = jax.tree.map(lambda v: jnp.asarray(v), tree)
        return tree, manifest
