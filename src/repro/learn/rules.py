"""Synaptic plasticity rules: trace-based STDP and error-driven PES.

The paper motivates the PE's exponential-function accelerator explicitly
as a speedup for synaptic plasticity (Sec. III-B, [10][11]); this module
is the matching rule library.  Each rule exists twice:

* a **fixed-point path** in s16.15, the on-PE arithmetic: eligibility
  traces decay through the ``repro.kernels.explog`` accelerator kernel
  (``fx_exp``, ``impl`` knob selecting the Pallas kernel or the bit-exact
  reference — see ``EXPLOG_IMPLS``), weights and traces stay int32, and
  every multiply uses the overflow-safe hi/lo split the LIF kernel uses;
* a **float reference oracle** (``*_ref``) the fixed-point path is tested
  against within s16.15 tolerance.

Rule semantics (both paths, identical op order):

``STDP`` — pair-based with pre/post eligibility traces.  Per tick the
traces decay by exp(-1/tau) and accumulate this tick's spikes; then every
post spike potentiates by ``a_plus * pre_trace`` and every pre spike
depresses by ``a_minus * post_trace``; weights clip to
[``w_min``, ``w_max``].  Weights are s16.15 (1.0 == ``FX_ONE``).

``PES`` — the NEF's error-driven decoder rule (Yan et al.,
arXiv:2009.08921 run it on this hardware for adaptive control):
``d <- d - lr/n * a * e`` with ``a`` the low-pass-filtered activity in Hz
(trace in s16.15, decayed through the same accelerator) and ``e`` the
arrived error vector.  Zero error is an exact fixed point.  Decoders stay
float32, as on the Arm core.

Energy: each weight update is a MAC-class op (priced through the MAC-array
TOPS/W like every other datapath op), each trace decay one accelerator
evaluation of ``EXP_ACC_CYCLES`` shift-add iterations — the constants the
engine's ``e_learn`` record is built from.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.configs import paper
from repro.kernels.explog.ops import fx_exp, resolve_explog_impl, to_fx
from repro.kernels.explog.ref import FX_ONE
from repro.kernels.lif.ref import fx_mul

FRAC = 15

# one exp-accelerator evaluation = one shift-add iteration per ln(1+2^-k)
# table entry ([10] Partzsch et al. ISCAS'17 — 16-entry table in s16.15)
EXP_ACC_CYCLES = 16


@dataclass(frozen=True)
class STDP:
    """Pair-based spike-timing-dependent plasticity on a SPIKE projection.

    Time constants are in ticks (1 tick = 1 ms system tick); weights and
    bounds are in the float domain (converted to s16.15 internally).
    ``impl`` selects the trace-decay exp kernel (``EXPLOG_IMPLS``)."""
    a_plus: float = 0.02
    a_minus: float = 0.022
    tau_plus_ticks: float = 20.0
    tau_minus_ticks: float = 20.0
    w_min: float = 0.0
    w_max: float = 1.0
    w_init: float = 0.5
    impl: str = "auto"

    def __post_init__(self):
        resolve_explog_impl(self.impl)
        if not self.w_min <= self.w_init <= self.w_max:
            raise ValueError(
                f"STDP w_init {self.w_init} outside bounds "
                f"[{self.w_min}, {self.w_max}]")


@dataclass(frozen=True)
class PES:
    """Prescribed Error Sensitivity: error-driven NEF decoder learning on
    a GRADED projection (the projection carries the decoded value; the
    decoders being learned live on the source PE)."""
    learning_rate: float = 1e-5
    tau_ticks: float = 20.0            # activity-trace filter constant
    w_init: float = 0.0
    impl: str = "auto"

    def __post_init__(self):
        resolve_explog_impl(self.impl)


PLASTICITY_RULES = (STDP, PES)


# ---------------------------------------------------------------------------
# Eligibility traces (s16.15 + float oracle)
# ---------------------------------------------------------------------------

def trace_decay_fx(tau_ticks: float, impl: str = "auto"):
    """Per-tick decay factor exp(-1/tau) in s16.15 — computed BY the
    exp accelerator kernel (evaluated inside the tick loop; XLA is free
    to hoist the constant, the PE is not)."""
    arg = to_fx(jnp.float32(-1.0 / tau_ticks))
    return fx_exp(arg[None], impl=impl)[0]


def trace_step_fx(tr, spikes, tau_ticks: float, impl: str = "auto"):
    """tr: int32 s16.15 trace -> decayed + FX_ONE per spike.

    ``fx_mul``'s hi/lo split keeps the decay multiply exact and
    overflow-free for any non-negative int32 trace."""
    d = trace_decay_fx(tau_ticks, impl=impl)
    return fx_mul(tr.astype(jnp.int32), d) \
        + spikes.astype(jnp.int32) * FX_ONE


def trace_step_ref(tr, spikes, tau_ticks: float):
    """Float oracle of ``trace_step_fx`` (same decay-then-add order)."""
    return tr * np.float32(np.exp(-1.0 / tau_ticks)) \
        + spikes.astype(jnp.float32)


def trace_to_hz(tr_fx, tau_ticks: float):
    """s16.15 trace -> filtered firing-rate estimate in Hz.

    A trace accumulating 1.0 per spike with decay alpha has steady state
    rate/(1 - alpha) in spikes/tick; scale by (1 - alpha) * 1000 to get
    Hz — the unit NEF decoders are solved against."""
    one_m_alpha = 1.0 - float(np.exp(-1.0 / tau_ticks))
    return tr_fx.astype(jnp.float32) * (one_m_alpha * 1000.0 / FX_ONE)


# ---------------------------------------------------------------------------
# STDP weight update (s16.15 + float oracle)
# ---------------------------------------------------------------------------

def stdp_step_fx(w, pre_tr, post_tr, pre_spk, post_spk, rule: STDP):
    """One tick of pair STDP in s16.15.

    w (n_pre, n_post) int32; traces int32; spikes 0/1.  Returns
    (w, pre_tr, post_tr) — traces already advanced by this tick."""
    pre_tr = trace_step_fx(pre_tr, pre_spk, rule.tau_plus_ticks, rule.impl)
    post_tr = trace_step_fx(post_tr, post_spk, rule.tau_minus_ticks,
                            rule.impl)
    ap = jnp.int32(round(rule.a_plus * FX_ONE))
    am = jnp.int32(round(rule.a_minus * FX_ONE))
    pre_i = pre_spk.astype(jnp.int32)
    post_i = post_spk.astype(jnp.int32)
    pot = fx_mul(pre_tr, ap)[:, None] * post_i[None, :]
    dep = pre_i[:, None] * fx_mul(post_tr, am)[None, :]
    w = jnp.clip(w + pot - dep,
                 jnp.int32(round(rule.w_min * FX_ONE)),
                 jnp.int32(round(rule.w_max * FX_ONE)))
    return w, pre_tr, post_tr


def stdp_step_ref(w, pre_tr, post_tr, pre_spk, post_spk, rule: STDP):
    """Float oracle of ``stdp_step_fx`` (identical op order)."""
    pre_tr = trace_step_ref(pre_tr, pre_spk, rule.tau_plus_ticks)
    post_tr = trace_step_ref(post_tr, post_spk, rule.tau_minus_ticks)
    pre_f = pre_spk.astype(jnp.float32)
    post_f = post_spk.astype(jnp.float32)
    pot = (rule.a_plus * pre_tr)[:, None] * post_f[None, :]
    dep = pre_f[:, None] * (rule.a_minus * post_tr)[None, :]
    w = jnp.clip(w + pot - dep, rule.w_min, rule.w_max)
    return w, pre_tr, post_tr


# ---------------------------------------------------------------------------
# PES decoder update (float — decoders live on the Arm core)
# ---------------------------------------------------------------------------

def pes_step(dec, act_hz, err, rule: PES, n_pre: int):
    """d <- d - lr/n * a e.  dec (n_pre, d); act_hz (n_pre,); err (d,).
    Zero error is an exact fixed point (lr * a * 0 == 0)."""
    return dec - (rule.learning_rate / n_pre) \
        * act_hz[:, None] * err[None, :].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Energy pricing constants
# ---------------------------------------------------------------------------

def exp_op_energy_j(n_ops, pl: paper.PerfLevel = paper.PERF_LEVELS[2]):
    """Energy of ``n_ops`` exp-accelerator evaluations: EXP_ACC_CYCLES
    shift-add iterations each, priced at the PL's per-cycle baseline
    energy (the accelerator shares the PE power domain)."""
    return n_ops * EXP_ACC_CYCLES * pl.p_baseline_w / pl.freq_hz
