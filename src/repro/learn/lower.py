"""Plasticity lowering: graph projections -> per-projection learn slots.

Shared by the single-chip compiler (``repro.chip.compile.compile``) and
the board compiler (``repro.board.route.compile_board``): both call
``lower_plasticity(graph, pe_slices)`` after placement and store the
resulting tuple on the program — so a plastic graph trains identically
on one chip and across a multi-chip board, and a ``plasticity=None``
graph lowers to ``learn_slots == ()`` (the engine then traces EXACTLY
the pre-plasticity tick body — bitwise-identity is a test invariant).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.chip.graph import GRADED, SPIKE, NetGraph
from repro.learn.rules import PES, STDP


@dataclass(frozen=True)
class LearnSlot:
    """One plastic projection, lowered.

    ``n_pre``/``n_post`` are the unit counts of the source/destination
    populations (STDP: synapse matrix shape; PES: decoder shape, with
    ``n_post`` the error dimensionality).  ``pe_ids`` are the logical
    PEs that execute — and are charged ``e_learn`` for — the update:
    the destination tiles for STDP (fan-in weights live at the synapse),
    the source tiles for PES (decoders live where decoding happens).
    """
    name: str
    kind: str                  # "stdp" | "pes"
    rule: object
    src: str
    dst: str
    n_pre: int
    n_post: int
    pe_ids: tuple


def lower_plasticity(graph: NetGraph, pe_slices: dict) -> tuple:
    """Collect the graph's plastic projections into ``LearnSlot``s,
    validating rule/payload pairing with errors that name the edge."""
    slots = []
    for pr in graph.projections:
        rule = getattr(pr, "plasticity", None)
        if rule is None:
            continue
        edge = f"{pr.src}->{pr.dst}"
        if isinstance(rule, STDP):
            if pr.payload != SPIKE:
                raise ValueError(
                    f"projection {edge}: STDP needs a SPIKE projection "
                    f"(pair STDP is defined on spike events), got "
                    f"{pr.payload!r}")
            kind, own = "stdp", pe_slices[pr.dst]
        elif isinstance(rule, PES):
            if pr.payload != GRADED:
                raise ValueError(
                    f"projection {edge}: PES needs a GRADED projection "
                    f"(it carries the decoded value), got {pr.payload!r}")
            kind, own = "pes", pe_slices[pr.src]
        else:
            raise ValueError(
                f"projection {edge}: unknown plasticity rule "
                f"{type(rule).__name__!r}; expected STDP or PES")
        slots.append(LearnSlot(
            name=edge, kind=kind, rule=rule, src=pr.src, dst=pr.dst,
            n_pre=graph.population(pr.src).n,
            n_post=graph.population(pr.dst).n,
            pe_ids=tuple(range(own.start, own.stop))))
    return tuple(slots)
