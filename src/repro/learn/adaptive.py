"""Closed-loop adaptive control with on-mesh PES learning, plus the
STDP pair demo — the workloads of the plasticity subsystem.

``adaptive_control_graph`` reproduces the control loop Yan et al.
(arXiv:2009.08921) ran on a SpiNNaker 2 prototype with the NEF: a spiking
ensemble encodes the reference signal r(t), its decoded output u drives a
first-order plant y' = (u - y)/tau, and the tracking error e = y - r
closes the loop back to the ensemble, where PES adapts the decoders
online.  On the mesh this is K independent channels of TWO populations
each — ``nef{k}`` (ensemble + decoders) and ``plant{k}`` (plant + error)
— joined by two GRADED projections per channel: the decoded control value
outbound (``plasticity=PES(...)`` — the learned decoders), the error
inbound.  Both values cross real mesh links as graded DNoC packets with a
1-tick transport delay each way, so the loop learns THROUGH the fabric it
will run on; decoders start at zero and the tracking error converges as
PES pulls u toward the plant-inverting control.

All nef populations are laid out before all plant populations (the
hybrid-farm layout), so on a multi-chip board most control loops cross
chip boundaries — the same graph compiles unchanged through
``compile_board`` and trains across the chip-to-chip tier.

``stdp_pair_graph`` is the minimal STDP workload: a Poisson source
population spiking into a LIF population over a plastic SPIKE projection.
Causally effective synapses (pre spikes that precede post spikes)
potentiate, the rest depress — weights live in the engine's learn carry
as s16.15 and move every tick.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.compile import ChipProgram, compile as compile_graph
from repro.chip.graph import GRADED, NetGraph, Population, Projection
from repro.core.nef import build_ensemble, encode_drive
from repro.kernels.explog.ref import FX_ONE
from repro.kernels.lif.ops import lif_params_fx
from repro.kernels.lif.ref import lif_step_ref
from repro.learn.engine import init_learn_state
from repro.learn.rules import PES, STDP


# -------------------------------------------------------------------------
# Adaptive control (PES): K closed loops over the mesh
# -------------------------------------------------------------------------

@dataclass
class AdaptiveControlSemantics:
    """Per-tick step of the K-channel adaptive-control loop.

    States batch the channel axis ((K, N) LIF arrays, one
    ``lif_step_ref`` for the whole farm).  Per channel and tick:

    * nef PE: LIF integrates the MAC-encoded reference drive; the spike
      vector decodes through the CURRENT decoders (read from the learn
      carry), the decoded value low-pass filters into the control u and
      leaves as one 32 b graded packet;
    * plant PE: consumes LAST tick's u, advances y += (u - y)/tau_p,
      emits the error e = y - r back as a graded packet;
    * the error arriving AT the nef PE (one more tick later) is what the
      engine's PES step consumes — reported per slot under
      ``learn/nef{k}->plant{k}/err`` next to the pre spikes.

    With ``plastic=False`` the projections carry no rule and the decode
    uses ``frozen_decoders`` — the frozen twin the learning benchmark
    measures tick-time overhead against.
    """
    ens: object                          # core.nef.Ensemble (shared build)
    drive_fx: jnp.ndarray                # (T, N) s16.15 encode of r(t)
    r_table: np.ndarray                  # (T,) reference signal
    n_channels: int
    plastic: bool = True
    tau_plant_ticks: float = 4.0
    bits_per_value: int = 32
    t_sys_s: float = 1e-3
    frozen_decoders: Optional[np.ndarray] = None   # (N,) used if frozen

    def slot_name(self, k: int) -> str:
        return f"nef{k}->plant{k}"

    def _pe_ids(self, program: ChipProgram):
        nef = np.array([program.pe_slices[f"nef{k}"].start
                        for k in range(self.n_channels)])
        pla = np.array([program.pe_slices[f"plant{k}"].start
                        for k in range(self.n_channels)])
        return nef, pla

    def init_state(self, program: ChipProgram):
        K, N = self.n_channels, self.ens.n_neurons
        st = {"v": jnp.zeros((K, N), jnp.int32),
              "ref": jnp.zeros((K, N), jnp.int32),
              "u_filt": jnp.zeros(K, jnp.float32),
              "u_buf": jnp.zeros(K, jnp.float32),     # nef -> plant wire
              "err_buf": jnp.zeros(K, jnp.float32),   # plant -> nef wire
              "y": jnp.zeros(K, jnp.float32)}
        if self.plastic:
            st["learn"] = init_learn_state(program)
        return st

    def make_tick(self, program: ChipProgram, *, dvfs, em, key):
        ens = self.ens
        K, N = self.n_channels, ens.n_neurons
        P = program.n_pes
        drive = self.drive_fx
        r = jnp.asarray(self.r_table, jnp.float32)
        T = drive.shape[0]
        # co-prime phase offsets decorrelate the channels
        offsets = jnp.asarray((np.arange(K) * 31) % T)
        alpha_syn = float(np.exp(-1.0 / ens.tau_syn_ticks))
        k_p = 1.0 / self.tau_plant_ticks
        nef_np, pla_np = self._pe_ids(program)
        nef_ids, pla_ids = jnp.asarray(nef_np), jnp.asarray(pla_np)
        n_neur = (jnp.zeros(P).at[nef_ids].set(float(N))
                  .at[pla_ids].set(1.0)).astype(jnp.int32)
        if not self.plastic:
            d_frozen = jnp.asarray(
                self.frozen_decoders if self.frozen_decoders is not None
                else np.zeros(N), jnp.float32)

        def tick(state, t):
            tt = (t + offsets) % T
            dfx = drive[tt]                                   # (K, N)
            v, ref, spk = lif_step_ref(state["v"], state["ref"], dfx,
                                       **ens.lif)
            spk_f = spk.astype(jnp.float32)                   # (K, N)
            n_spk = spk_f.sum(axis=1)                         # (K,)

            # decode with the CURRENT decoders (the learn carry is the
            # weight memory; the engine advances it after this tick)
            if self.plastic:
                d_all = jnp.stack([state["learn"][self.slot_name(k)]
                                   ["w"][:, 0] for k in range(K)])  # (K, N)
            else:
                d_all = jnp.broadcast_to(d_frozen, (K, N))
            contrib = (spk_f * d_all).sum(axis=1)             # (K,)
            u = alpha_syn * state["u_filt"] \
                + (1 - alpha_syn) * contrib * 1000.0

            # plant consumes LAST tick's control (1-tick transport)
            y = state["y"] + (state["u_buf"] - state["y"]) * k_p
            r_now = r[tt]                                     # (K,)
            e_now = y - r_now
            e_arr = state["err_buf"]     # error arriving at nef this tick

            zP = jnp.zeros(P)
            packets = zP.at[nef_ids].set(1.0).at[pla_ids].set(1.0)
            fifo = zP.at[nef_ids].set(float(N)).at[pla_ids].set(1.0)
            pl = dvfs.select_pl(fifo.astype(jnp.int32))
            snn_ev = zP.at[nef_ids].set(n_spk)      # event-based decode
            e_dvfs = em.tick_energy(pl, n_neur, snn_ev, dvfs=True)
            e_pl3 = em.tick_energy(jnp.full((P,), 2), n_neur, snn_ev,
                                   dvfs=False)

            rec = {
                "packets": packets,
                "pl": pl,
                "n_fifo": fifo,
                "syn_events": snn_ev,
                "n_spk": n_spk.sum(),
                "u": u,
                "y": y,
                "r": r_now,
                "track_err": jnp.abs(e_now),
                "dec_norm": jnp.abs(d_all).mean(),
                "e_dvfs_baseline": e_dvfs["baseline"],
                "e_dvfs_neuron": e_dvfs["neuron"],
                "e_dvfs_synapse": e_dvfs["synapse"],
                "e_pl3_baseline": e_pl3["baseline"],
                "e_pl3_neuron": e_pl3["neuron"],
                "e_pl3_synapse": e_pl3["synapse"],
            }
            if self.plastic:
                for k in range(K):
                    name = self.slot_name(k)
                    rec[f"learn/{name}/pre"] = spk_f[k]
                    rec[f"learn/{name}/err"] = e_arr[k][None]

            new_state = {"v": v, "ref": ref, "u_filt": u, "u_buf": u,
                         "err_buf": e_now, "y": y}
            if self.plastic:
                new_state["learn"] = state["learn"]   # engine advances it
            return new_state, rec

        return tick


def adaptive_control_graph(n_channels: int = 4, n_neurons: int = 100,
                           n_ticks: int = 1024, seed: int = 0,
                           learning_rate: float = 3e-6,
                           plastic: bool = True,
                           tau_plant_ticks: float = 4.0,
                           period: int = 2048, amp: float = 0.8) -> NetGraph:
    """K closed adaptive-control loops as one graph (2K populations).

    The reference r(t) is a slow sine (Yan et al.'s stimulus class); its
    MAC-encoded drive table is shared by all channels at co-prime phase
    offsets.  ``plastic=False`` builds the frozen twin (no rules, fixed
    decoders) for overhead baselines."""
    ens = build_ensemble(n_neurons, 1, seed=seed)
    t = np.arange(n_ticks)
    r = amp * np.sin(2 * np.pi * t / period)
    drive_fx = encode_drive(ens, r[:, None], use_mac=True)

    nef_sram = n_neurons * (3 * 4 + 2 * 4) + n_neurons * 4 * 2   # + dec/tr
    plant_sram = 64
    pops = ([Population(name=f"nef{k}", n=n_neurons, sram_bytes=nef_sram)
             for k in range(n_channels)]
            + [Population(name=f"plant{k}", n=1, sram_bytes=plant_sram)
               for k in range(n_channels)])
    rule = PES(learning_rate=learning_rate) if plastic else None
    projs = ([Projection(src=f"nef{k}", dst=f"plant{k}", payload=GRADED,
                         bits_per_packet=32, delay_ticks=1, plasticity=rule)
              for k in range(n_channels)]
             + [Projection(src=f"plant{k}", dst=f"nef{k}", payload=GRADED,
                           bits_per_packet=32, delay_ticks=1)
                for k in range(n_channels)])
    sem = AdaptiveControlSemantics(
        ens=ens, drive_fx=drive_fx, r_table=r, n_channels=n_channels,
        plastic=plastic, tau_plant_ticks=tau_plant_ticks)
    return NetGraph(populations=pops, projections=projs, semantics=sem,
                    name=f"adaptive_control{n_channels}"
                         + ("" if plastic else "_frozen"))


def convergence_tick(track_err: np.ndarray, threshold: float,
                     window: int) -> int:
    """First tick after which the windowed mean of the worst channel's
    |error| stays below ``threshold`` for good (-1: never converges)."""
    worst = np.asarray(track_err).max(axis=1)            # (T,)
    if len(worst) < window:
        return -1
    kern = np.ones(window) / window
    smooth = np.convolve(worst, kern, mode="valid")      # (T - w + 1,)
    bad = np.flatnonzero(smooth >= threshold)
    if smooth[-1] >= threshold:
        return -1
    if not bad.size:
        return 0                                          # converged at t=0
    return int(bad[-1]) + window                          # in raw ticks


def adaptive_control_workload(n_channels: int = 4, n_neurons: int = 100,
                              n_ticks: int = 2048, board=None,
                              err_threshold: float = 0.1,
                              err_window: int = 64, seed: int = 0,
                              refine: bool = True, **graph_kw) -> dict:
    """Build + compile + run the adaptive-control loop and report
    convergence and the learning-energy share.

    ``board=None`` compiles to a single chip; a ``BoardSpec`` routes the
    SAME graph through ``compile_board`` — the engine and the learning
    carry are identical, only the incidence (and the chip-to-chip tier)
    differ.  ``refine=False`` keeps the greedy graph-order partition
    (all nef populations fill the first chips), so control loops are
    FORCED across chip boundaries — the min-cut refinement would
    otherwise pack each loop's pair onto one chip and zero the cut."""
    graph = adaptive_control_graph(n_channels, n_neurons, n_ticks=n_ticks,
                                   seed=seed, **graph_kw)
    if board is not None:
        from repro.board import compile_board
        prog = compile_board(graph, board, refine=refine)
    else:
        prog = compile_graph(graph)
    sim = ChipSim(prog)
    recs = sim.run(n_ticks)
    track = np.asarray(recs["track_err"])                # (T, K)
    tab = chip_power_table(sim, recs)
    conv = convergence_tick(track, err_threshold, err_window)
    return {
        "sim": sim, "recs": recs, "table": tab, "program": prog,
        "convergence_tick": conv,
        "final_err": float(track[-err_window:].max(axis=1).mean()),
        "initial_err": float(track[:err_window].max(axis=1).mean()),
        "e_learn_j": tab.get("learn", {}).get("energy_j", 0.0),
        "learn_energy_frac": tab.get("learn", {}).get("energy_frac", 0.0),
        "dec_norm": float(np.asarray(recs["dec_norm"])[-1]),
    }


# -------------------------------------------------------------------------
# STDP pair demo: Poisson source -> LIF over a plastic spike projection
# -------------------------------------------------------------------------

@dataclass
class StdpPairSemantics:
    """Pre spikes stream over the mesh (1-tick delay) into a LIF
    population whose fan-in weights the engine's STDP step moves every
    tick.  The forward pass reads the CURRENT weights from the learn
    carry, so potentiation feeds back into excitability — the loop the
    exp-accelerator speedup argument is about."""
    pre_table: np.ndarray                # (T, n_pre) 0/1 spike trains
    n_post: int
    gain: float = 0.55
    lif: dict = field(default_factory=lambda: lif_params_fx(
        tau_ms=10.0, v_th=1.0, v_reset=0.0, ref_ticks=2))
    t_sys_s: float = 1e-3

    def init_state(self, program: ChipProgram):
        n_pre = self.pre_table.shape[1]
        return {"buf": jnp.zeros(n_pre, jnp.float32),
                "v": jnp.zeros(self.n_post, jnp.int32),
                "ref": jnp.zeros(self.n_post, jnp.int32),
                "learn": init_learn_state(program)}

    def make_tick(self, program: ChipProgram, *, dvfs, em, key):
        table = jnp.asarray(self.pre_table, jnp.float32)
        T, n_pre = table.shape
        n_post = self.n_post
        P = program.n_pes
        pre_pe = program.pe_slices["pre"].start
        post_pe = program.pe_slices["post"].start
        pre_mask = jnp.zeros(P).at[pre_pe].set(1.0)
        post_mask = jnp.zeros(P).at[post_pe].set(1.0)
        n_neur = (post_mask * n_post).astype(jnp.int32)
        gain = self.gain

        def tick(state, t):
            pre_spk = table[t % T]                       # emitted now
            arr = state["buf"]                           # arrived (1-tick)
            w = state["learn"]["pre->post"]["w"]         # (n_pre, n_post)
            w_f = w.astype(jnp.float32) / FX_ONE
            i_syn = jnp.round((arr @ w_f) * gain * FX_ONE).astype(jnp.int32)
            v, ref, post_spk = lif_step_ref(state["v"], state["ref"],
                                            i_syn, **self.lif)

            n_arr = arr.sum()
            fifo = post_mask * n_arr
            pl = dvfs.select_pl(fifo.astype(jnp.int32))
            syn_ev = post_mask * n_arr * n_post
            e_dvfs = em.tick_energy(pl, n_neur, syn_ev, dvfs=True)
            e_pl3 = em.tick_energy(jnp.full((P,), 2), n_neur, syn_ev,
                                   dvfs=False)
            rec = {
                "packets": pre_mask * pre_spk.sum(),
                "pl": pl,
                "n_fifo": fifo,
                "syn_events": syn_ev,
                "learn/pre->post/pre": arr,
                "learn/pre->post/post": post_spk.astype(jnp.float32),
                "post_spikes": post_spk.sum(),
                "w_mean": w_f.mean(),
                "e_dvfs_baseline": e_dvfs["baseline"],
                "e_dvfs_neuron": e_dvfs["neuron"],
                "e_dvfs_synapse": e_dvfs["synapse"],
                "e_pl3_baseline": e_pl3["baseline"],
                "e_pl3_neuron": e_pl3["neuron"],
                "e_pl3_synapse": e_pl3["synapse"],
            }
            new_state = {"buf": pre_spk, "v": v, "ref": ref,
                         "learn": state["learn"]}
            return new_state, rec

        return tick


def stdp_pair_graph(n_pre: int = 24, n_post: int = 8, n_ticks: int = 512,
                    rate: float = 0.08, seed: int = 0,
                    rule: STDP | None = None) -> NetGraph:
    """Poisson source -> LIF pair with a plastic STDP projection.  Pre
    rates ramp across the population (0.5x .. 1.5x ``rate``), so causally
    effective high-rate synapses separate from the rest."""
    rng = np.random.default_rng(seed)
    rates = rate * np.linspace(0.5, 1.5, n_pre)
    table = (rng.random((n_ticks, n_pre)) < rates[None, :]).astype(
        np.float32)
    rule = rule or STDP()
    pops = [Population(name="pre", n=n_pre, sram_bytes=n_pre * 8),
            Population(name="post", n=n_post,
                       sram_bytes=n_pre * n_post * 4 + n_post * 8)]
    projs = [Projection(src="pre", dst="post", delay_ticks=1,
                        plasticity=rule)]
    sem = StdpPairSemantics(pre_table=table, n_post=n_post)
    return NetGraph(populations=pops, projections=projs, semantics=sem,
                    name="stdp_pair")


def stdp_pair_workload(n_pre: int = 24, n_post: int = 8,
                       n_ticks: int = 512, seed: int = 0,
                       rule: STDP | None = None) -> dict:
    """Compile + run the STDP pair and report weight motion + bounds."""
    graph = stdp_pair_graph(n_pre, n_post, n_ticks=n_ticks, seed=seed,
                            rule=rule)
    prog = compile_graph(graph)
    sim = ChipSim(prog)
    recs = sim.run(n_ticks)
    w_mean = np.asarray(recs["w_mean"])
    tab = chip_power_table(sim, recs)
    return {
        "sim": sim, "recs": recs, "table": tab, "program": prog,
        "w_mean_first": float(w_mean[0]),
        "w_mean_last": float(w_mean[-1]),
        "post_spikes": float(np.asarray(recs["post_spikes"]).sum()),
        "e_learn_j": tab.get("learn", {}).get("energy_j", 0.0),
        "learn_energy_frac": tab.get("learn", {}).get("energy_frac", 0.0),
    }
