"""The engine side of on-mesh learning: carry init + per-tick update.

``ChipSim.run`` calls ``make_learn_step`` once per program; the returned
function runs INSIDE the per-tick scan, right after the semantics' tick,
and is the only place weights mutate.  The contract with a learnable
``TickSemantics`` is small:

* its ``init_state`` includes ``state["learn"] = init_learn_state(prog)``
  (it may overwrite individual weight arrays, e.g. pre-trained decoders);
* its tick reads weights from ``state["learn"][slot.name]["w"]`` for the
  forward pass and passes the ``"learn"`` subtree through UNCHANGED;
* its per-tick ``rec`` reports, per slot ``s``,

      learn/{s.name}/pre   (n_pre,)  pre-synaptic spikes this tick
      learn/{s.name}/post  (n_post,) post spikes        (STDP only)
      learn/{s.name}/err   (n_post,) arrived error      (PES only)

The engine then advances eligibility traces through the s16.15 exp
accelerator kernel, applies the rule (``repro.learn.rules``), and prices
the tick's learning work — MAC-class weight updates + exp-accelerator
trace decays — into a per-PE ``e_learn`` record charged to the slot's
owning tiles.  A program with no plastic projections never reaches this
module: ``ChipSim`` skips it entirely, keeping frozen graphs bitwise
identical to the pre-plasticity engine.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.chip.graph import mac_dynamic_energy_j
from repro.kernels.explog.ref import FX_ONE
from repro.learn.rules import (exp_op_energy_j, pes_step, stdp_step_fx,
                               trace_step_fx, trace_to_hz)


def init_learn_state(program) -> dict:
    """Fresh weight/trace arrays for every learn slot of ``program``.

    PES decoders are float32 (Arm-core arithmetic), STDP weights and all
    eligibility traces are int32 s16.15."""
    out = {}
    for s in program.learn_slots:
        if s.kind == "pes":
            out[s.name] = {
                "w": jnp.full((s.n_pre, s.n_post), s.rule.w_init,
                              jnp.float32),
                "tr": jnp.zeros((s.n_pre,), jnp.int32),
            }
        else:
            out[s.name] = {
                "w": jnp.full((s.n_pre, s.n_post),
                              int(round(s.rule.w_init * FX_ONE)),
                              jnp.int32),
                "pre_tr": jnp.zeros((s.n_pre,), jnp.int32),
                "post_tr": jnp.zeros((s.n_post,), jnp.int32),
            }
    return out


def _slot_signal(rec: dict, key: str, slot_name: str):
    try:
        return rec[key]
    except KeyError:
        raise KeyError(
            f"plastic projection {slot_name!r} needs the semantics to "
            f"report {key!r} in its per-tick rec (see repro.learn.engine "
            f"docstring)") from None


def group_slots(slots) -> list:
    """Batchable groups of learn slots: same kind, same (frozen, hashable)
    rule, same weight shape.  Slot order inside a group — and group order
    — follows program order, so record keys and energy accumulation stay
    deterministic."""
    groups: dict = {}
    for s in slots:
        groups.setdefault((s.kind, s.rule, s.n_pre, s.n_post),
                          []).append(s)
    return list(groups.values())


def make_learn_step(program):
    """Per-tick learning update for ``program`` (traced in the scan).

    Returns ``step(learn_state, rec) -> (learn_state, rec_updates)``;
    ``rec_updates`` carries ``e_learn`` — the (P,) per-PE learning
    energy of this tick — plus one ``learn/<slot>/dw`` scalar per slot
    (mean |weight delta|, in weight units), the live update-magnitude
    signal the telemetry probes and the Perfetto learn track consume.

    Same-shape slots sharing one rule are BATCHED: their weights/traces/
    signals stack on a leading group axis and one vmapped rule update
    advances the whole group — the trace cost per extra slot is a few
    stack/slice eqns instead of a full rule unroll (the s16.15 exp
    accelerator alone traces ~50 eqns), so programs with hundreds of
    plastic projections stay compilable.  Per-slot state layout, record
    keys and arithmetic are unchanged: stacking batches the identical
    elementwise ops, so each slot's weights advance bit-exactly as in
    the unrolled form."""
    P = program.n_pes
    groups = group_slots(program.learn_slots)
    # static scatter metadata per group: every slot's owning-PE ids and
    # tile counts concatenate into ONE consolidated e_learn scatter
    meta = []
    for g in groups:
        ids = np.concatenate([np.asarray(s.pe_ids, np.int64) for s in g])
        counts = np.array([len(s.pe_ids) for s in g])
        meta.append((jnp.asarray(ids), counts,
                     jnp.asarray(counts, jnp.float32)))

    def step(lstate, rec):
        new = dict(lstate)
        e = jnp.zeros(P, jnp.float32)
        updates = {}
        for g, (ids, counts, lens) in zip(groups, meta):
            s0 = g[0]
            pre = jnp.stack([_slot_signal(rec, f"learn/{s.name}/pre",
                                          s.name) for s in g])
            w_old = jnp.stack([lstate[s.name]["w"] for s in g])
            if s0.kind == "pes":
                err = jnp.stack([_slot_signal(rec, f"learn/{s.name}/err",
                                              s.name) for s in g])
                # trace decay + rate filter are elementwise — the stacked
                # call IS the batched update (one fx_exp per group)
                tr = trace_step_fx(
                    jnp.stack([lstate[s.name]["tr"] for s in g]), pre,
                    s0.rule.tau_ticks, s0.rule.impl)
                act_hz = trace_to_hz(tr, s0.rule.tau_ticks)
                w = jax.vmap(lambda wi, ai, ei: pes_step(
                    wi, ai, ei, s0.rule, s0.n_pre))(w_old, act_hz, err)
                for i, s in enumerate(g):
                    new[s.name] = {"w": w[i], "tr": tr[i]}
                # event-driven: a zero-error tick dispatches no updates
                active = jnp.any(err != 0, axis=-1).astype(jnp.float32)
                macs = active * float(s0.n_pre * s0.n_post)       # (G,)
                n_exp = float(s0.n_pre)
                dw = jnp.abs(w - w_old).mean(axis=(1, 2))
            else:
                post = jnp.stack([_slot_signal(rec, f"learn/{s.name}/post",
                                               s.name) for s in g])
                ptr0 = jnp.stack([lstate[s.name]["pre_tr"] for s in g])
                qtr0 = jnp.stack([lstate[s.name]["post_tr"] for s in g])
                w, ptr, qtr = jax.vmap(
                    lambda wi, pi, qi, pri, poi: stdp_step_fx(
                        wi, pi, qi, pri, poi, s0.rule))(
                    w_old, ptr0, qtr0, pre, post)
                for i, s in enumerate(g):
                    new[s.name] = {"w": w[i], "pre_tr": ptr[i],
                                   "post_tr": qtr[i]}
                macs = (pre.astype(jnp.float32).sum(axis=-1) * s0.n_post
                        + post.astype(jnp.float32).sum(axis=-1) * s0.n_pre)
                n_exp = float(s0.n_pre + s0.n_post)
                dw = (jnp.abs(w - w_old).astype(jnp.float32).mean(
                    axis=(1, 2)) / FX_ONE)
            for i, s in enumerate(g):
                updates[f"learn/{s.name}/dw"] = dw[i]
            e_slot = mac_dynamic_energy_j(macs) + exp_op_energy_j(n_exp)
            e = e.at[ids].add(jnp.repeat(
                e_slot / lens, counts,
                total_repeat_length=int(counts.sum())))
        updates["e_learn"] = e
        return new, updates

    return step
