"""The engine side of on-mesh learning: carry init + per-tick update.

``ChipSim.run`` calls ``make_learn_step`` once per program; the returned
function runs INSIDE the per-tick scan, right after the semantics' tick,
and is the only place weights mutate.  The contract with a learnable
``TickSemantics`` is small:

* its ``init_state`` includes ``state["learn"] = init_learn_state(prog)``
  (it may overwrite individual weight arrays, e.g. pre-trained decoders);
* its tick reads weights from ``state["learn"][slot.name]["w"]`` for the
  forward pass and passes the ``"learn"`` subtree through UNCHANGED;
* its per-tick ``rec`` reports, per slot ``s``,

      learn/{s.name}/pre   (n_pre,)  pre-synaptic spikes this tick
      learn/{s.name}/post  (n_post,) post spikes        (STDP only)
      learn/{s.name}/err   (n_post,) arrived error      (PES only)

The engine then advances eligibility traces through the s16.15 exp
accelerator kernel, applies the rule (``repro.learn.rules``), and prices
the tick's learning work — MAC-class weight updates + exp-accelerator
trace decays — into a per-PE ``e_learn`` record charged to the slot's
owning tiles.  A program with no plastic projections never reaches this
module: ``ChipSim`` skips it entirely, keeping frozen graphs bitwise
identical to the pre-plasticity engine.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.chip.graph import mac_dynamic_energy_j
from repro.kernels.explog.ref import FX_ONE
from repro.learn.rules import (exp_op_energy_j, pes_step, stdp_step_fx,
                               trace_step_fx, trace_to_hz)


def init_learn_state(program) -> dict:
    """Fresh weight/trace arrays for every learn slot of ``program``.

    PES decoders are float32 (Arm-core arithmetic), STDP weights and all
    eligibility traces are int32 s16.15."""
    out = {}
    for s in program.learn_slots:
        if s.kind == "pes":
            out[s.name] = {
                "w": jnp.full((s.n_pre, s.n_post), s.rule.w_init,
                              jnp.float32),
                "tr": jnp.zeros((s.n_pre,), jnp.int32),
            }
        else:
            out[s.name] = {
                "w": jnp.full((s.n_pre, s.n_post),
                              int(round(s.rule.w_init * FX_ONE)),
                              jnp.int32),
                "pre_tr": jnp.zeros((s.n_pre,), jnp.int32),
                "post_tr": jnp.zeros((s.n_post,), jnp.int32),
            }
    return out


def _slot_signal(rec: dict, key: str, slot_name: str):
    try:
        return rec[key]
    except KeyError:
        raise KeyError(
            f"plastic projection {slot_name!r} needs the semantics to "
            f"report {key!r} in its per-tick rec (see repro.learn.engine "
            f"docstring)") from None


def make_learn_step(program):
    """Per-tick learning update for ``program`` (traced in the scan).

    Returns ``step(learn_state, rec) -> (learn_state, rec_updates)``;
    ``rec_updates`` carries ``e_learn`` — the (P,) per-PE learning
    energy of this tick — plus one ``learn/<slot>/dw`` scalar per slot
    (mean |weight delta|, in weight units), the live update-magnitude
    signal the telemetry probes and the Perfetto learn track consume."""
    slots = program.learn_slots
    P = program.n_pes

    def step(lstate, rec):
        new = dict(lstate)
        e = jnp.zeros(P, jnp.float32)
        updates = {}
        for s in slots:
            st = lstate[s.name]
            pre = _slot_signal(rec, f"learn/{s.name}/pre", s.name)
            if s.kind == "pes":
                err = _slot_signal(rec, f"learn/{s.name}/err", s.name)
                tr = trace_step_fx(st["tr"], pre, s.rule.tau_ticks,
                                   s.rule.impl)
                act_hz = trace_to_hz(tr, s.rule.tau_ticks)
                w = pes_step(st["w"], act_hz, err, s.rule, s.n_pre)
                new[s.name] = {"w": w, "tr": tr}
                # event-driven: a zero-error tick dispatches no updates
                active = jnp.any(err != 0).astype(jnp.float32)
                macs = active * float(s.n_pre * s.n_post)
                n_exp = float(s.n_pre)
                dw = jnp.abs(w - st["w"]).mean()
            else:
                post = _slot_signal(rec, f"learn/{s.name}/post", s.name)
                w, ptr, qtr = stdp_step_fx(st["w"], st["pre_tr"],
                                           st["post_tr"], pre, post, s.rule)
                new[s.name] = {"w": w, "pre_tr": ptr, "post_tr": qtr}
                macs = (pre.astype(jnp.float32).sum() * s.n_post
                        + post.astype(jnp.float32).sum() * s.n_pre)
                n_exp = float(s.n_pre + s.n_post)
                dw = (jnp.abs(w - st["w"]).astype(jnp.float32).mean()
                      / FX_ONE)
            updates[f"learn/{s.name}/dw"] = dw
            e_slot = mac_dynamic_energy_j(macs) + exp_op_energy_j(n_exp)
            e = e.at[jnp.asarray(s.pe_ids)].add(e_slot / len(s.pe_ids))
        updates["e_learn"] = e
        return new, updates

    return step
