"""On-mesh synaptic plasticity (paper Sec. III-B; Yan et al. 2009.08921).

Projections of a ``NetGraph`` become trainable by attaching a rule:

    from repro.learn import PES, STDP
    Projection("nef0", "plant0", payload=GRADED, bits_per_packet=32,
               plasticity=PES(learning_rate=3e-5))

``compile``/``compile_board`` lower plastic projections into
``LearnSlot`` descriptors on the program; ``ChipSim`` extends its scan
carry with per-slot weight/trace state and applies the rule every tick
(``repro.learn.engine``), pricing the work into a per-PE ``e_learn``
record.  Rules live in ``repro.learn.rules`` (fixed-point path through
the exp-accelerator kernel + float oracle); the closed-loop
adaptive-control workload is in ``repro.learn.adaptive`` (imported as a
submodule to keep this package import-light).
"""
from repro.learn.engine import init_learn_state, make_learn_step
from repro.learn.lower import LearnSlot, lower_plasticity
from repro.learn.rules import (EXP_ACC_CYCLES, PES, PLASTICITY_RULES, STDP,
                               exp_op_energy_j, pes_step, stdp_step_fx,
                               stdp_step_ref, trace_step_fx, trace_step_ref,
                               trace_to_hz)

__all__ = ["STDP", "PES", "PLASTICITY_RULES", "LearnSlot",
           "lower_plasticity", "init_learn_state", "make_learn_step",
           "trace_step_fx", "trace_step_ref", "trace_to_hz",
           "stdp_step_fx", "stdp_step_ref", "pes_step",
           "exp_op_energy_j", "EXP_ACC_CYCLES"]
