"""SRAM-constrained mapping of workloads onto the PE mesh.

Two placers:

* ``place_ring``   — neuron populations of a synfire ring onto PEs in
  snake order over the QPE grid (ring neighbours stay mesh neighbours;
  only the wrap-around edge crosses the chip).
* ``place_layers`` — feedforward DNN layers split into 128 kB-SRAM tiles
  with ``pe.partition_layer_to_sram`` ("we divide the layers to fit into
  the 128 kByte SRAM per PE"), tiles assigned to consecutive PEs.

Both emit per-PE ``RoutingTable``s plus precomputed X/Y-multicast-tree
link-incidence tensors, so the per-tick NoC accounting in ``chip.ChipSim``
is a dense einsum rather than a per-source Python loop.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.chip.mesh_noc import MeshNoc, MeshSpec
from repro.configs import paper
from repro.core.pe import PESpec, partition_layer_to_sram
from repro.core.router import RoutingTable


def snake_order(mesh: MeshSpec) -> list[int]:
    """QPE indices in boustrophedon order: adjacent in the order =>
    adjacent on the mesh (except nothing — snake rows join at the ends)."""
    order = []
    for y in range(mesh.height):
        xs = range(mesh.width) if y % 2 == 0 else range(mesh.width - 1, -1, -1)
        order.extend(y * mesh.width + x for x in xs)
    return order


def snake_coords(mesh: MeshSpec, slots) -> np.ndarray:
    """(len(slots), 2) QPE coords of placement slots in snake order —
    the shared placement primitive of ``place_ring``/``place_layers`` and
    the graph compiler (``repro.chip.compile``)."""
    qpe_order = snake_order(mesh)
    return np.array([mesh.qpe_coord(qpe_order[s // mesh.pes_per_qpe])
                     for s in slots], np.int32).reshape(-1, 2)


def assign_slots(populations, pes_per_qpe: int) -> tuple:
    """Map population tiles to consecutive placement slots.

    Returns (slots_per_pop: dict name -> (start, stop), total_slots).
    ``align_qpe`` populations start on a QPE boundary and reserve whole
    QPEs, so inter-population traffic crosses real mesh links.  Shared by
    the single-chip compiler (``repro.chip.compile``) and the board
    partitioner/placer (``repro.board``), which runs it once per chip.
    """
    slots = {}
    cur = 0
    for pop in populations:
        if pop.align_qpe and cur % pes_per_qpe:
            cur += pes_per_qpe - cur % pes_per_qpe
        slots[pop.name] = (cur, cur + pop.n_tiles)
        cur += pop.n_tiles
        if pop.align_qpe and cur % pes_per_qpe:
            cur += pes_per_qpe - cur % pes_per_qpe
    return slots, cur


@dataclass
class Placement:
    """Where each logical PE of a workload lives, how its spikes route,
    and the precomputed link-incidence of each source's multicast tree."""
    mesh: MeshSpec
    noc: MeshNoc
    coords: np.ndarray                  # (P, 2) int: QPE coord of logical PE
    table: RoutingTable                 # (P, P) key -> destination masks
    inc: np.ndarray                     # (P, n_links) float32 incidence
    sram_bytes_per_pe: int = 0          # workload state per PE (fits check)

    @property
    def n_pes(self) -> int:
        return len(self.coords)

    @property
    def worst_tree_hops(self) -> int:
        c = np.asarray(self.coords, np.int64)
        dist = np.abs(c[:, None, :] - c[None, :, :]).sum(axis=-1)
        return int((dist * self.table.masks).max(initial=0))

    def fits(self, pe: PESpec = PESpec()) -> bool:
        return pe.fits_sram(self.sram_bytes_per_pe)


def _incidence_from_table(noc: MeshNoc, coords, table: RoutingTable):
    c = np.asarray(coords, np.int64)
    dst_lists = [c[np.flatnonzero(m)] for m in table.masks]
    return noc.sparse_incidence(c, dst_lists).dense()


def synfire_sram_bytes(sp: paper.SynfireParams = paper.SYNFIRE) -> int:
    """Per-PE synfire state: sparse synapse words (the hardware stores
    synapse lists, not the dense debug matrices), neuron state, FIFOs."""
    syn = sp.synapses_per_core * 4                      # word per synapse
    neuron = sp.neurons_per_core * 3 * 4                # v, ref, params
    fifo = (int(sp.delay_exc_ms) * sp.n_exc
            + int(sp.delay_inh_ms) * sp.n_inh) // 8 + 1024
    return syn + neuron + fifo


def place_ring(n_pes: int, mesh: MeshSpec | None = None,
               sp: paper.SynfireParams = paper.SYNFIRE,
               pe: PESpec = PESpec()) -> Placement:
    """Place an ``n_pes`` synfire ring on the mesh (auto-sized if None)."""
    mesh = mesh or MeshSpec.for_pes(n_pes)
    if n_pes > mesh.n_pes:
        raise ValueError(f"ring of {n_pes} PEs > mesh capacity {mesh.n_pes}")
    sram = synfire_sram_bytes(sp)
    if not pe.fits_sram(sram):
        raise ValueError(f"synfire core state {sram} B exceeds PE SRAM")

    coords = snake_coords(mesh, range(n_pes))
    table = RoutingTable.ring(n_pes)
    noc = MeshNoc(mesh)
    inc = _incidence_from_table(noc, coords, table)
    return Placement(mesh=mesh, noc=noc, coords=coords, table=table,
                     inc=inc, sram_bytes_per_pe=sram)


# -------------------------------------------------------------------------
# DNN layer placement
# -------------------------------------------------------------------------

@dataclass
class LayerPlacement:
    """One feedforward layer split into SRAM-sized tiles on a PE range."""
    name: str
    h: int; w: int; cin: int; cout: int; kh: int; kw: int
    rows_per_tile: int
    cout_per_tile: int
    n_tiles: int
    pes: list[int] = field(default_factory=list)     # logical PE ids
    cycles_per_tile: float = 0.0
    out_bytes: int = 0                               # activations to next layer


def place_layers(layers: list[dict], mesh: MeshSpec | None = None,
                 pe: PESpec = PESpec(), bytes_per: int = 1):
    """Split each layer into PE-sized tiles and assign tiles to consecutive
    PEs in snake order.  ``layers``: dicts with h,w,cin,cout,kh,kw[,name].

    Returns (placements, noc, inc, tile_coords):
      placements — per-layer ``LayerPlacement``
      inc        — (n_used_pes, n_links) incidence of each tile-PE's
                   multicast tree to ALL next-layer tile PEs (every output
                   tile feeds every next-layer input tile: full halo)
    """
    total_tiles = 0
    placements: list[LayerPlacement] = []
    for li, ly in enumerate(layers):
        rows, cout_t, n_tiles = partition_layer_to_sram(
            pe, ly["h"], ly["w"], ly["cin"], ly["cout"],
            ly["kh"], ly["kw"], bytes_per=bytes_per)
        lp = LayerPlacement(
            name=ly.get("name", f"layer{li}"),
            h=ly["h"], w=ly["w"], cin=ly["cin"], cout=ly["cout"],
            kh=ly["kh"], kw=ly["kw"],
            rows_per_tile=rows, cout_per_tile=cout_t, n_tiles=n_tiles,
            pes=list(range(total_tiles, total_tiles + n_tiles)),
            cycles_per_tile=pe.mac_conv_cycles(
                min(rows, ly["h"]), ly["w"], ly["cin"], cout_t,
                ly["kh"], ly["kw"]),
            out_bytes=ly["h"] * ly["w"] * ly["cout"] * bytes_per,
        )
        placements.append(lp)
        total_tiles += n_tiles

    mesh = mesh or MeshSpec.for_pes(total_tiles)
    if total_tiles > mesh.n_pes:
        raise ValueError(f"{total_tiles} tiles > mesh capacity {mesh.n_pes}")
    coords = snake_coords(mesh, range(total_tiles))

    # routing: every tile of layer i multicasts its activations to every
    # tile of layer i+1 (dense feedforward halo)
    masks = np.zeros((total_tiles, total_tiles), bool)
    for cur, nxt in zip(placements[:-1], placements[1:]):
        for p in cur.pes:
            masks[p, nxt.pes] = True
    table = RoutingTable(masks)
    noc = MeshNoc(mesh)
    inc = _incidence_from_table(noc, coords, table)
    return placements, noc, inc, coords
