"""Declarative workload graphs for the chip-level simulator.

The paper's claim is ONE PE architecture for three workload classes — SNN,
DNN and hybrid SNN/DNN.  This module is the matching programming model: a
workload is a ``NetGraph`` of ``Population`` nodes (neuron populations, DNN
layer tiles, NEF ensembles — anything with an SRAM footprint and per-tick
step semantics) joined by typed ``Projection`` edges that carry either
binary spike events (header-only DNoC packets) or graded payloads
(multi-flit packets, e.g. activations or NEF spike vectors).

``repro.chip.compile.compile(graph, mesh)`` lowers a graph to a
``ChipProgram`` (placement + routing + incidence tensors); the
workload-agnostic engine ``repro.chip.chip.ChipSim`` then runs any program
in one ``jax.lax.scan``.  The per-tick behaviour of a graph is supplied by
its ``TickSemantics`` — the contract is small:

    init_state(program)              -> state pytree
    make_tick(program, dvfs, em, key)-> tick(state, t) -> (state, rec)

where ``rec`` must contain, per logical PE,

    packets  (P,)  multicast packets emitted this tick (NoC sources)
    pl       (P,)  selected performance level (DVFS)
    e_dvfs_baseline/neuron/synapse, e_pl3_baseline/neuron/synapse (P,)
                   the Eq. (1) energy split under DVFS and only-PL3

and may contain ``payload_bits`` (P,) to override the program's static
per-packet payload size for graded traffic that varies tick to tick.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Protocol, runtime_checkable

import jax.numpy as jnp

from repro.configs import paper

SPIKE = "spike"      # binary events: header-only 64 b DNoC packet
GRADED = "graded"    # graded payload: header + ceil(bits/128) 192 b flits


@dataclass(frozen=True)
class Population:
    """One logical node of a workload graph.

    ``n`` is the unit count (neurons, activations, ...); ``n_tiles`` is how
    many PEs the node occupies after SRAM partitioning (the compiler places
    tiles on consecutive PEs); ``sram_bytes`` is the per-tile footprint the
    compiler validates against the 128 kB PE SRAM.  ``align_qpe`` forces the
    node onto a fresh QPE so inter-node traffic crosses real mesh links
    (used by the hybrid workload to keep the SNN and DNN paths on separate
    quads, as on the test chip).
    """
    name: str
    n: int
    sram_bytes: int
    n_tiles: int = 1
    align_qpe: bool = False
    meta: dict = field(default_factory=dict)


@dataclass(frozen=True)
class Projection:
    """Typed edge: every PE of ``src`` multicasts to every PE of ``dst``.

    ``payload`` selects the DNoC packet class: SPIKE packets are header-only
    (64 b effective); GRADED packets carry ``bits_per_packet`` payload bits,
    priced as ceil(bits / 128) flits of 192 bits per link traversal
    (paper Sec. III-A).  ``delay_ticks`` is the synaptic/transport delay the
    semantics honours between emission and arrival.

    ``plasticity`` makes the projection trainable on-mesh: attach a
    ``repro.learn.STDP`` (SPIKE projections) or ``repro.learn.PES``
    (GRADED projections) descriptor and the compiler lowers it into a
    ``LearnSlot`` on the program; the engine then updates the
    projection's weights tick by tick inside the scan and reports the
    per-PE learning energy as ``e_learn`` (see ``repro.learn``).  The
    default ``None`` keeps the projection frozen — and the compiled
    program bitwise identical to the pre-plasticity engine.
    """
    src: str
    dst: str
    payload: str = SPIKE
    bits_per_packet: int = 0
    delay_ticks: int = 1
    plasticity: object = None

    def __post_init__(self):
        if self.payload not in (SPIKE, GRADED):
            raise ValueError(f"unknown payload class {self.payload!r}")
        if self.payload == GRADED and self.bits_per_packet <= 0:
            raise ValueError(
                f"graded projection {self.src}->{self.dst} needs "
                f"bits_per_packet > 0")
        if self.payload == SPIKE and self.bits_per_packet:
            raise ValueError(
                f"spike projection {self.src}->{self.dst} must not carry "
                f"payload bits (got {self.bits_per_packet})")


@runtime_checkable
class TickSemantics(Protocol):
    """Per-tick behaviour of a compiled graph (see module docstring)."""

    def init_state(self, program): ...

    def make_tick(self, program, *, dvfs, em, key): ...


@dataclass
class NetGraph:
    """Ordered populations + typed projections + tick semantics."""
    populations: list
    projections: list
    semantics: Optional[TickSemantics] = None
    name: str = "net"

    def __post_init__(self):
        known, dup = set(), set()
        for p in self.populations:
            (dup if p.name in known else known).add(p.name)
        if dup:
            raise ValueError(f"duplicate population names: {sorted(dup)}")
        for pr in self.projections:
            for end in (pr.src, pr.dst):
                if end not in known:
                    raise ValueError(
                        f"projection {pr.src}->{pr.dst} references unknown "
                        f"population {end!r}; have {sorted(known)}")

    # -- derived ----------------------------------------------------------

    @property
    def n_tiles_total(self) -> int:
        return sum(p.n_tiles for p in self.populations)

    def population(self, name: str) -> Population:
        for p in self.populations:
            if p.name == name:
                return p
        raise KeyError(name)

    def out_projections(self, name: str) -> list:
        return [pr for pr in self.projections if pr.src == name]

    def in_projections(self, name: str) -> list:
        return [pr for pr in self.projections if pr.dst == name]


# ---------------------------------------------------------------------------
# Shared accounting helpers for semantics implementations
# ---------------------------------------------------------------------------

def busy_window_energy(pl, busy_cycles, *, pls=paper.PERF_LEVELS,
                       t_sys_s: float = 1e-3, dvfs: bool = True):
    """Eq. (1) baseline term for a datapath busy ``busy_cycles`` this tick.

    The generalization of ``PEEnergyModel.tick_energy``'s baseline to
    non-SNN workloads: busy time is the cycle count at the selected PL's
    clock, the idle remainder runs at PL1 (dvfs=True) or stays at the
    selected PL (dvfs=False, the "only PL3" comparison mode).
    """
    freqs = jnp.asarray([p.freq_hz for p in pls])
    p_bl = jnp.asarray([p.p_baseline_w for p in pls])
    t_sp = jnp.minimum(busy_cycles / freqs[pl], t_sys_s)
    if dvfs:
        return p_bl[pl] * t_sp + p_bl[0] * (t_sys_s - t_sp)
    return p_bl[pl] * t_sys_s


def mac_dynamic_energy_j(macs, *, tops_per_w: float | None = None):
    """Dynamic energy of ``macs`` MAC-array ops (2 ops each) this tick."""
    tops_per_w = tops_per_w or paper.MAC_TOPS_PER_W[(paper.MEP_VDD,
                                                     paper.MEP_FREQ)]
    return 2.0 * macs / (tops_per_w * 1e12)
