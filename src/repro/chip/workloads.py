"""Chip-scale scenario builders.

Three workload families from the paper, each mapped onto the PE mesh:

* ``synfire_workload``   — the Sec. VI-B benchmark generalized from the
  fixed 8-PE test-chip ring to any ring length (``ChipSim.synfire``).
* ``tiled_dnn_workload`` — feedforward conv layers split into 128 kB SRAM
  tiles across PEs (Sec. VI-D), inter-layer activations priced per NoC
  link traversal.  Static (analytic) latency/energy/link-load report.
* ``hybrid_workload``    — the Sec. II hybrid: a NEF ensemble (SNN path,
  Arm core) spikes into an event-triggered MAC MLP (DNN path, MAC array)
  on a different PE, spike payloads crossing the mesh.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.mapping import place_layers
from repro.chip.mesh_noc import MeshNoc, MeshSpec
from repro.configs import paper
from repro.core.hybrid import event_mac, event_mac_energy_j
from repro.core.nef import build_ensemble, run_channel, synop_metrics
from repro.core.pe import PESpec
from repro.core.quant import quantize_params_linear


def synfire_workload(n_pes: int = 8, mesh: MeshSpec | None = None,
                     n_ticks: int = 1200, seed: int = 0) -> dict:
    """Build, run and account a synfire ring of ``n_pes`` on the mesh."""
    sim = ChipSim.synfire(n_pes, mesh, seed=seed)
    recs = sim.run(n_ticks)
    return {"sim": sim, "recs": recs, "table": chip_power_table(sim, recs)}


# -------------------------------------------------------------------------
# Tiled DNN
# -------------------------------------------------------------------------

# A small VGG-ish feedforward stack (the paper's Sec. VI-D keyword-spotting
# class of networks): enough layers to spread over tens of PEs.
DEFAULT_DNN = [
    dict(name="conv1", h=32, w=32, cin=3, cout=32, kh=3, kw=3),
    dict(name="conv2", h=32, w=32, cin=32, cout=32, kh=3, kw=3),
    dict(name="conv3", h=16, w=16, cin=32, cout=64, kh=3, kw=3),
    dict(name="conv4", h=16, w=16, cin=64, cout=64, kh=3, kw=3),
]


def tiled_dnn_workload(layers=None, mesh: MeshSpec | None = None,
                       pe: PESpec = PESpec(),
                       freq_hz: float = paper.MEP_FREQ) -> dict:
    """Map a feedforward stack over the mesh and price one inference.

    Per layer: tiles run in parallel on their PEs (latency = slowest tile);
    the layer's output activations multicast to every next-layer tile, and
    every link traversal of every flit is charged via ``NocSpec``.
    """
    layers = layers or DEFAULT_DNN
    placements, noc, inc, coords = place_layers(layers, mesh, pe=pe)
    n_used = len(coords)

    # layers execute SEQUENTIALLY (feedforward): per-layer link loads are
    # computed separately and the chip-wide peak is the max over layers,
    # never the sum — two layers' trees sharing a link don't contend.
    per_layer = []
    compute_s = 0.0
    noc_bits = 0.0
    e_noc = 0.0
    loads = np.zeros(noc.n_links, np.float32)
    for lp, nxt in zip(placements, placements[1:] + [None]):
        t_layer = lp.cycles_per_tile / freq_hz
        compute_s += t_layer
        # activations to the next layer: one multicast burst per source
        # tile, links from the precomputed incidence rows
        bits = 0.0
        if nxt is not None:
            payload_bits = lp.out_bytes * 8 / max(lp.n_tiles, 1)
            packets = np.zeros(n_used, np.float32)
            packets[lp.pes] = 1.0
            l_layer = np.asarray(noc.link_loads(jnp.asarray(packets), inc))
            loads = np.maximum(loads, l_layer)
            nflits = -(-payload_bits // noc.spec.payload_bits)
            bits = float(l_layer.sum()) * nflits * noc.spec.flit_bits
            e_noc += float(noc.payload_energy_j(l_layer, payload_bits))
        noc_bits += bits
        per_layer.append({
            "name": lp.name, "n_tiles": lp.n_tiles,
            "rows_per_tile": lp.rows_per_tile,
            "cout_per_tile": lp.cout_per_tile,
            "cycles_per_tile": lp.cycles_per_tile,
            "layer_latency_s": t_layer,
            "noc_bits_out": bits,
        })

    noc_s = noc_bits / 8 / (noc.spec.freq_hz * 16)   # 128-bit/clk links
    e_mac = sum(
        2.0 * lp.cycles_per_tile * pe.macs_per_cycle * lp.n_tiles
        for lp in placements) / (paper.MAC_TOPS_PER_W[(0.50, 200e6)] * 1e12)
    return {
        "layers": per_layer,
        "n_pes_used": n_used,
        "mesh": (noc.mesh.width, noc.mesh.height),
        "latency_s": compute_s + noc_s,
        "compute_s": compute_s,
        "noc_s": noc_s,
        "energy_mac_j": e_mac,
        "energy_noc_j": e_noc,
        "link_loads": loads,
        "peak_link_load": float(noc.congestion(loads)) if loads.size else 0.0,
    }


# -------------------------------------------------------------------------
# Hybrid NEF + MLP
# -------------------------------------------------------------------------

def hybrid_workload(n_neurons: int = 256, hidden: int = 64,
                    n_ticks: int = 600, mesh: MeshSpec | None = None,
                    seed: int = 0) -> dict:
    """NEF ensemble on PE A, event-triggered MAC MLP on PE B (Sec. II).

    Each tick the ensemble's spike vector crosses the mesh as a payload
    multicast; ticks with no spikes dispatch NOTHING to the MAC array —
    energy follows activity on the NoC and in the datapath alike.
    """
    mesh = mesh or MeshSpec.for_pes(8)
    noc = MeshNoc(mesh)
    ens = build_ensemble(n_neurons, 1, seed=seed)

    # drive the channel with a slow sine (Fig. 20's stimulus class)
    t = np.arange(n_ticks)
    x = 0.8 * np.sin(2 * np.pi * t / 400)[:, None]
    out = run_channel(ens, x, use_mac=True)
    spikes = jnp.asarray(out["spikes"], jnp.float32)          # (T, N)
    active = spikes.sum(axis=1) > 0                           # (T,)

    # MLP on the far corner PE: event rows = per-tick spike vectors
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((n_neurons, hidden)) * 0.1,
                    jnp.float32)
    wq, ws = quantize_params_linear(w)
    h, n_disp = event_mac(spikes, active, wq, ws)

    # NoC: NEF PE at one corner, MLP PE at the other — worst-case X/Y path
    src = (0, 0)
    dst = (mesh.width - 1, mesh.height - 1)
    inc = noc.incidence_row(src, [dst])[None]                 # (1, L)
    # payload: the active-neuron bitmap + graded values, 16 b per spike;
    # one burst per active tick, flit/energy accounting via NocSpec
    payload_bits = spikes.sum(axis=1).astype(jnp.int32) * 16  # (T,)
    bursts = active.astype(jnp.float32)[:, None]              # (T, 1)
    pkt_loads = noc.link_loads(bursts, inc)                   # (T, L)
    e_noc = float(np.asarray(
        noc.payload_energy_j(pkt_loads, payload_bits).sum()))
    nflits = -(-payload_bits // noc.spec.payload_bits)
    loads = pkt_loads * nflits[:, None]                       # flits per link

    # energy: event-triggered MAC accumulates one weight row per spike
    # (2*hidden ops), vs. frame-based which multiplies the full N x hidden
    # matrix every tick — the ratio is exactly the mean firing rate
    total_spikes = float(np.asarray(out["spikes_per_tick"]).sum())
    e_mac = event_mac_energy_j(total_spikes, 1, hidden)
    e_frame = event_mac_energy_j(n_ticks, n_neurons, hidden)
    e_tick = (n_neurons * paper.NEF_E_NEURON_J
              + np.asarray(out["spikes_per_tick"]) * 1 * 0.2e-9)
    return {
        "xhat": out["xhat"],
        "x": x,
        "rmse": float(np.sqrt(np.mean(
            (out["xhat"][n_ticks // 4:, 0] - x[n_ticks // 4:, 0]) ** 2))),
        "n_dispatched": int(n_disp),
        "total_spikes": total_spikes,
        "duty_cycle": float(np.asarray(active).mean()),
        "energy_mac_j": e_mac,
        "energy_mac_frame_j": e_frame,
        "event_vs_frame": e_mac / e_frame,
        "energy_noc_j": e_noc,
        "link_loads": np.asarray(loads),
        "synops": synop_metrics(ens, np.asarray(out["spikes_per_tick"]),
                                e_tick),
        "hidden_out": np.asarray(h),
    }
