"""Chip-scale workloads as graph builders on the unified API.

Three workload families from the paper, each expressed as a ``NetGraph``
(populations + typed projections + tick semantics), compiled with
``repro.chip.compile.compile`` and executed tick-by-tick by the
workload-agnostic ``ChipSim`` engine:

* ``synfire_graph``  — the Sec. VI-B benchmark: ring of per-PE neuron
  populations, binary spike projections.  The 8-PE graph compiles to a
  program bit-identical to the single-chip ``simulate_synfire``.
* ``dnn_graph``      — feedforward conv layers split into 128 kB-SRAM tile
  populations (Sec. VI-D), graded activation-burst projections.  Frames
  stream through the pipeline tick by tick; tile FIFO occupancy drives
  DVFS, layer completions drive multicast NoC bursts.
* ``hybrid_graph``   — the Sec. II hybrid: a NEF ensemble (SNN path) on
  one QPE spiking into an event-triggered MAC MLP (DNN path) on another,
  the per-tick spike vector crossing the mesh as a graded payload packet.

The ``*_workload`` entry points keep their old signatures but now build /
compile / run through the graph pipeline — no analytic shortcuts.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.compile import ChipProgram, compile as compile_graph
from repro.chip.graph import (GRADED, NetGraph, Population, Projection,
                              busy_window_energy, mac_dynamic_energy_j)
from repro.chip.mapping import synfire_sram_bytes
from repro.chip.mesh_noc import MeshSpec
from repro.configs import paper
from repro.core.dvfs import DVFSController
from repro.core.hybrid import event_mac_energy_j, event_mac_tick
from repro.core.nef import build_ensemble, encode_drive, synop_metrics
from repro.core.pe import PESpec, partition_layer_to_sram
from repro.core.quant import quantize_params_linear
from repro.core.snn import (build_synfire, make_synfire_tick,
                            synfire_init_state)
from repro.kernels.lif.ref import lif_step_ref


# -------------------------------------------------------------------------
# Synfire ring (SNN)
# -------------------------------------------------------------------------

@dataclass
class SynfireSemantics:
    """Per-tick step of the synfire ring = the single-chip tick function
    (``make_synfire_tick``), unchanged — which is what makes the compiled
    8-PE program bit-identical to ``simulate_synfire``."""
    net: object                        # core.snn.SynfireNet

    def init_state(self, program: ChipProgram):
        return synfire_init_state(self.net)

    def make_tick(self, program: ChipProgram, *, dvfs, em, key):
        return make_synfire_tick(self.net, dvfs=dvfs, em=em, key=key)

    def make_event_tick(self, program: ChipProgram, *, dvfs, em, key):
        """The activity-compressed synfire tick (``ChipSim`` event mode):
        active sources compact into a bounded index buffer, synaptic
        gather + energy pricing touch only those lanes — bitwise-equal
        records to ``make_tick`` (overflow falls back to dense)."""
        return make_synfire_tick(self.net, dvfs=dvfs, em=em, key=key,
                                 event=True)

    def dvfs_controller(self):
        """The net's own FIFO thresholds (Table II l_th1/l_th2) — picked up
        by ``ChipSim`` when no controller is passed explicitly."""
        sp = self.net.params
        return DVFSController(sp.l_th1, sp.l_th2)


def synfire_graph(n_pes: int = 8, seed: int = 0,
                  sp: paper.SynfireParams = paper.SYNFIRE,
                  **build_kw) -> NetGraph:
    """Synfire ring of any length as a graph: one population per PE, spike
    projections around the ring (exc -> next PE's exc+inh; the same-PE
    inhibitory loop stays inside the population's tick)."""
    net = build_synfire(seed, n_pes=n_pes, sp=sp, **build_kw)
    sram = synfire_sram_bytes(net.params)
    pops = [Population(name=f"pe{i}", n=net.params.neurons_per_core,
                       sram_bytes=sram) for i in range(n_pes)]
    projs = [Projection(src=f"pe{i}", dst=f"pe{(i + 1) % n_pes}",
                        delay_ticks=int(net.params.delay_exc_ms))
             for i in range(n_pes)]
    return NetGraph(populations=pops, projections=projs,
                    semantics=SynfireSemantics(net), name=f"synfire{n_pes}")


def synfire_workload(n_pes: int = 8, mesh: MeshSpec | None = None,
                     n_ticks: int = 1200, seed: int = 0) -> dict:
    """Build, compile, run and account a synfire ring on the mesh."""
    graph = synfire_graph(n_pes, seed=seed)
    sim = ChipSim(compile_graph(graph, mesh))
    recs = sim.run(n_ticks)
    return {"sim": sim, "recs": recs, "table": chip_power_table(sim, recs)}


# -------------------------------------------------------------------------
# Tiled DNN (feedforward pipeline)
# -------------------------------------------------------------------------

# A small VGG-ish feedforward stack (the paper's Sec. VI-D keyword-spotting
# class of networks): enough layers to spread over tens of PEs.
DEFAULT_DNN = [
    dict(name="conv1", h=32, w=32, cin=3, cout=32, kh=3, kw=3),
    dict(name="conv2", h=32, w=32, cin=32, cout=32, kh=3, kw=3),
    dict(name="conv3", h=16, w=16, cin=32, cout=64, kh=3, kw=3),
    dict(name="conv4", h=16, w=16, cin=64, cout=64, kh=3, kw=3),
]


def dnn_graph(layers=None, pe: PESpec = PESpec(),
              bytes_per: int = 1) -> NetGraph:
    """Feedforward conv stack as a graph: one population per layer, tiled
    to the 128 kB SRAM; graded projections carry each tile's activation
    burst (its share of the layer's output) to every next-layer tile."""
    layers = layers or DEFAULT_DNN
    pops, projs = [], []
    for li, ly in enumerate(layers):
        rows, cout_t, n_tiles = partition_layer_to_sram(
            pe, ly["h"], ly["w"], ly["cin"], ly["cout"], ly["kh"], ly["kw"],
            bytes_per=bytes_per)
        in_b = (rows + ly["kh"] - 1) * ly["w"] * ly["cin"] * bytes_per
        w_b = ly["kh"] * ly["kw"] * ly["cin"] * cout_t * bytes_per
        out_b = rows * ly["w"] * cout_t * 4
        name = ly.get("name", f"layer{li}")
        out_bytes = ly["h"] * ly["w"] * ly["cout"] * bytes_per
        macs = ly["h"] * ly["w"] * ly["cout"] * ly["cin"] * ly["kh"] * ly["kw"]
        pops.append(Population(
            name=name, n=out_bytes, sram_bytes=in_b + w_b + out_b,
            n_tiles=n_tiles,
            meta=dict(
                ly, rows_per_tile=rows, cout_per_tile=cout_t,
                cycles_per_tile=pe.mac_conv_cycles(
                    min(rows, ly["h"]), ly["w"], ly["cin"], cout_t,
                    ly["kh"], ly["kw"]),
                macs_per_tile=macs / n_tiles,
                in_events=(ly["h"] * ly["w"] * ly["cin"] if li == 0
                           else pops[-1].n),
                out_bytes=out_bytes)))
        if li:
            prev = pops[-2]
            projs.append(Projection(
                src=prev.name, dst=name, payload=GRADED,
                bits_per_packet=-(-prev.meta["out_bytes"] * 8
                                  // prev.n_tiles)))
    g = NetGraph(populations=pops, projections=projs, name="tiled_dnn")
    g.semantics = DnnPipelineSemantics(graph=g)
    return g


@dataclass
class DnnPipelineSemantics:
    """Tick-by-tick streaming inference over the tiled layer pipeline.

    Frames are injected into the first layer every ``frame_interval``
    ticks.  A tile queues arriving frames in its FIFO (occupancy drives
    DVFS, exactly as spike counts do for the SNN), processes one frame for
    ``stage_ticks`` ticks at PL3, and on completion the layer multicasts
    one graded activation burst per tile to every next-layer tile (1-tick
    NoC transport delay).  Energy: Eq. (1) baseline from the busy window
    plus MAC-array dynamic energy per dispatched op — activity-driven on
    both the datapath and the NoC.
    """
    graph: NetGraph
    n_frames: int = 4
    frame_interval: int = 0            # 0 -> auto: slowest stage (pipeline rate)
    t_sys_s: float = 1e-3

    def static_tables(self, program: ChipProgram) -> dict:
        """Placement-derived per-PE tables (stage latencies, layer
        membership, event counts).  Memoized per program: ``make_tick``
        and the workload report share one computation."""
        cache = self.__dict__.setdefault("_tables", {})
        key = id(program)
        if key not in cache:
            cache[key] = self._build_tables(program)
        return cache[key]

    def _build_tables(self, program: ChipProgram):
        pops = self.graph.populations
        P = program.n_pes
        n_layers = len(pops)
        pl3_cycles = paper.PERF_LEVELS[2].freq_hz * self.t_sys_s
        stage_ticks = np.array(
            [max(1, int(np.ceil(p.meta["cycles_per_tile"] / pl3_cycles)))
             for p in pops], np.int32)
        member = np.zeros((n_layers, P), np.float32)
        stage_pe = np.zeros(P, np.int32)
        macs_tick = np.zeros(P, np.float32)
        cycles_tick = np.zeros(P, np.float32)
        in_events = np.zeros(P, np.int32)
        for li, p in enumerate(pops):
            sl = program.pe_slices[p.name]
            member[li, sl] = 1.0
            stage_pe[sl] = stage_ticks[li]
            macs_tick[sl] = p.meta["macs_per_tile"] / stage_ticks[li]
            cycles_tick[sl] = p.meta["cycles_per_tile"] / stage_ticks[li]
            in_events[sl] = p.meta["in_events"]
        tiles_per_layer = member.sum(axis=1)
        # emission: layer l done -> 1 frame arrives at every tile of l+1
        nxt = np.zeros((n_layers, P), np.float32)
        for li in range(n_layers - 1):
            nxt[li, program.pe_slices[pops[li + 1].name]] = 1.0
        emit_mask = (member[:-1].sum(axis=0) > 0).astype(np.float32) \
            if n_layers > 1 else np.zeros(P, np.float32)
        first_mask = member[0]
        interval = self.frame_interval or int(stage_ticks.max() + 1)
        return dict(member=member, tiles=tiles_per_layer, nxt=nxt,
                    stage_pe=stage_pe, macs_tick=macs_tick,
                    cycles_tick=cycles_tick, in_events=in_events,
                    emit_mask=emit_mask, first_mask=first_mask,
                    interval=interval, stage_ticks=stage_ticks)

    def init_state(self, program: ChipProgram):
        P = program.n_pes
        return {"fifo": jnp.zeros(P, jnp.int32),
                "remaining": jnp.zeros(P, jnp.int32),
                "buf": jnp.zeros(P, jnp.float32)}

    def make_tick(self, program: ChipProgram, *, dvfs, em, key):
        st = self.static_tables(program)
        member = jnp.asarray(st["member"])
        tiles = jnp.asarray(st["tiles"])
        nxt = jnp.asarray(st["nxt"])
        stage_pe = jnp.asarray(st["stage_pe"])
        macs_tick = jnp.asarray(st["macs_tick"])
        cycles_tick = jnp.asarray(st["cycles_tick"])
        in_events = jnp.asarray(st["in_events"])
        emit_mask = jnp.asarray(st["emit_mask"])
        first_mask = jnp.asarray(st["first_mask"])
        interval = st["interval"]
        n_frames = self.n_frames
        tops_pl3 = paper.MAC_TOPS_PER_W[(paper.HIGH_VDD, paper.HIGH_FREQ)]

        def tick(state, t):
            inject = ((t % interval) == 0) & (t < n_frames * interval)
            arr = state["buf"] + inject.astype(jnp.float32) * first_mask
            arr_i = arr.astype(jnp.int32)
            fifo = state["fifo"] + arr_i
            n_fifo = arr_i * in_events                 # events entering FIFO
            pl_arr = dvfs.select_pl(n_fifo)

            start = (state["remaining"] == 0) & (fifo > 0)
            fifo = fifo - start.astype(jnp.int32)
            remaining = state["remaining"] + start * stage_pe
            busy = remaining > 0
            pl = jnp.maximum(pl_arr, busy.astype(jnp.int32) * 2)
            remaining = remaining - busy.astype(jnp.int32)
            done = busy & (remaining == 0)

            done_f = done.astype(jnp.float32)
            layer_done = (member @ done_f >= tiles).astype(jnp.float32)
            packets = done_f * emit_mask               # activation bursts
            buf = layer_done @ nxt                     # arrives next tick

            macs = busy.astype(jnp.float32) * macs_tick
            cycles = busy.astype(jnp.float32) * cycles_tick
            e_mac = mac_dynamic_energy_j(macs)
            e_mac_pl3 = mac_dynamic_energy_j(macs, tops_per_w=tops_pl3)
            zeros = jnp.zeros_like(e_mac)
            rec = {
                "packets": packets,
                "pl": pl,
                "n_fifo": n_fifo,
                "syn_events": macs,
                "busy": busy,
                "layer_done": layer_done,
                "frame_out": layer_done[-1],
                "e_dvfs_baseline": busy_window_energy(
                    pl, cycles, t_sys_s=self.t_sys_s, dvfs=True),
                "e_dvfs_neuron": zeros,
                "e_dvfs_synapse": e_mac,
                "e_pl3_baseline": busy_window_energy(
                    jnp.full_like(pl, 2), cycles, t_sys_s=self.t_sys_s,
                    dvfs=False),
                "e_pl3_neuron": zeros,
                "e_pl3_synapse": e_mac_pl3,
            }
            new_state = {"fifo": fifo, "remaining": remaining, "buf": buf}
            return new_state, rec

        return tick


def tiled_dnn_workload(layers=None, mesh: MeshSpec | None = None,
                       pe: PESpec = PESpec(), n_frames: int = 4,
                       n_ticks: int | None = None) -> dict:
    """Map a feedforward stack over the mesh and STREAM frames through it.

    Unlike the old analytic table, the compiled program executes tick by
    tick on ``ChipSim``: tiles process when their FIFO holds a frame,
    completions multicast graded activation bursts over real mesh links,
    and the DVFS/NoC accounting falls out of the per-tick records.
    """
    layers = layers or DEFAULT_DNN
    graph = dnn_graph(layers, pe=pe)
    graph.semantics.n_frames = n_frames
    prog = compile_graph(graph, mesh, pe=pe)
    sim = ChipSim(prog)

    st = graph.semantics.static_tables(prog)
    pipeline_ticks = int(st["stage_ticks"].sum() + len(layers))
    if n_ticks is None:
        n_ticks = st["interval"] * n_frames + pipeline_ticks + 4
    recs = sim.run(n_ticks)

    frame_out = np.asarray(recs["frame_out"])
    out_ticks = np.flatnonzero(frame_out > 0)
    latency_s = (float(out_ticks[0] + 1) * graph.semantics.t_sys_s
                 if out_ticks.size else float("nan"))
    loads = np.asarray(recs["link_load"])              # (T, L)
    flits = np.asarray(recs["link_flits"])
    per_layer = []
    for pop, ticks in zip(graph.populations, st["stage_ticks"]):
        per_layer.append({
            "name": pop.name, "n_tiles": pop.n_tiles,
            "rows_per_tile": pop.meta["rows_per_tile"],
            "cout_per_tile": pop.meta["cout_per_tile"],
            "cycles_per_tile": pop.meta["cycles_per_tile"],
            "stage_ticks": int(ticks),
            "layer_latency_s": float(ticks) * graph.semantics.t_sys_s,
        })
    compute_s = sum(l["layer_latency_s"] for l in per_layer)
    tab = chip_power_table(sim, recs)
    return {
        "sim": sim, "recs": recs, "table": tab,
        "layers": per_layer,
        "n_pes_used": prog.n_pes,
        "mesh": (prog.mesh.width, prog.mesh.height),
        "n_frames_out": int(frame_out.sum()),
        "latency_s": latency_s,
        "compute_s": compute_s,
        "noc_s": prog.worst_tree_hops * prog.noc.spec.hop_cycles
                 / prog.noc.spec.freq_hz,
        "energy_mac_j": float(np.asarray(recs["e_dvfs_synapse"]).sum()),
        "energy_noc_j": float(np.asarray(recs["e_noc"]).sum()),
        "link_loads": loads,
        "peak_link_load": float(loads.max()) if loads.size else 0.0,
        "peak_link_flits": float(flits.max()) if flits.size else 0.0,
    }


# -------------------------------------------------------------------------
# Hybrid NEF + event-MAC MLP
# -------------------------------------------------------------------------

@dataclass
class HybridSemantics:
    """NEF ensemble (SNN path) on one QPE, event-triggered MAC MLP (DNN
    path) on another, executing tick by tick ON the mesh (Sec. II).

    Per tick: the ensemble's LIF neurons integrate the (MAC-encoded) drive;
    spiking neurons are decoded event-based into ``xhat``; the spike vector
    crosses the mesh as ONE graded-payload packet (16 b per spike) and is
    consumed by the MLP PE on the NEXT tick, where only arrived events
    dispatch weight rows to the MAC array.  Ticks with no spikes send
    nothing and multiply nothing — energy follows activity on the NoC and
    in the datapath alike.
    """
    ens: object                         # core.nef.Ensemble
    wq: jnp.ndarray                     # (N, hidden) int8
    w_scale: jnp.ndarray
    drive_fx: jnp.ndarray               # (T, N) int32 s16.15 encode drive
    bits_per_spike: int = 16
    t_sys_s: float = 1e-3

    def init_state(self, program: ChipProgram):
        N = self.ens.n_neurons
        return {"v": jnp.zeros(N, jnp.int32),
                "ref": jnp.zeros(N, jnp.int32),
                "xhat": jnp.zeros(self.ens.dims, jnp.float32),
                "spike_buf": jnp.zeros(N, jnp.float32)}

    def make_tick(self, program: ChipProgram, *, dvfs, em, key):
        ens = self.ens
        N, D = ens.n_neurons, ens.dims
        hidden = self.wq.shape[1]
        dec = jnp.asarray(ens.decoders, jnp.float32)
        w_eff = self.wq.astype(jnp.float32) * self.w_scale[None, :]
        alpha_syn = float(np.exp(-1.0 / ens.tau_syn_ticks))
        drive = self.drive_fx
        T = drive.shape[0]
        P = program.n_pes
        src = program.pe_slices["nef"].start
        dst = program.pe_slices["mlp"].start
        nef_mask = jnp.zeros(P).at[src].set(1.0)
        mlp_mask = jnp.zeros(P).at[dst].set(1.0)
        n_neur = (nef_mask * N).astype(jnp.int32)

        def tick(state, t):
            dfx = drive[t % T]
            v, ref, spk = lif_step_ref(state["v"], state["ref"], dfx,
                                       **ens.lif)
            spk_f = spk.astype(jnp.float32)
            n_spk = spk_f.sum().astype(jnp.int32)
            # event-based decode on the Arm core (only spikers contribute)
            contrib = spk_f @ dec
            # spikes/tick -> rate in Hz (decoders were solved against Hz
            # rates) — same discretization as core.nef.run_channel
            xhat = (alpha_syn * state["xhat"]
                    + (1 - alpha_syn) * contrib * 1000.0)

            # NoC: one graded packet iff the tick had spikes
            active = (n_spk > 0).astype(jnp.float32)
            packets = nef_mask * active
            bits_out = self.bits_per_spike * n_spk
            payload_bits = nef_mask * bits_out.astype(jnp.float32)

            # MLP PE consumes LAST tick's spike vector (1-tick transport)
            arr = state["spike_buf"]
            h, n_arr = event_mac_tick(arr, w_eff)
            mac_events = n_arr * hidden
            bits_in = self.bits_per_spike * n_arr

            # DVFS: inbound event counts pick the PL on both PEs
            fifo = (nef_mask * N + mlp_mask * n_arr.astype(jnp.float32))
            pl = dvfs.select_pl(fifo.astype(jnp.int32))
            # Arm-core synaptic events (decode adds) price via Eq. (1);
            # the MLP's MAC-array ops price via TOPS/W ONLY — charging
            # them e_synapse_j too would double-count the datapath
            snn_ev = nef_mask * n_spk.astype(jnp.float32) * D
            syn_ev = snn_ev + mlp_mask * mac_events.astype(jnp.float32)
            e_dvfs = em.tick_energy(pl, n_neur, snn_ev, dvfs=True)
            e_pl3 = em.tick_energy(jnp.full((P,), 2), n_neur, snn_ev,
                                   dvfs=False)
            e_mac = mac_dynamic_energy_j(mac_events.astype(jnp.float32))

            rec = {
                "packets": packets,
                "payload_bits": payload_bits,
                "graded_bits_out": nef_mask * bits_out.astype(jnp.float32),
                "graded_bits_in": mlp_mask * bits_in.astype(jnp.float32),
                "pl": pl,
                "n_fifo": fifo,
                "syn_events": syn_ev,
                "spikes": spk.astype(jnp.int8),
                "n_spk": n_spk,
                "n_dispatched": (n_arr > 0).astype(jnp.int32),
                "mac_events": mac_events,
                "xhat": xhat,
                "hidden_out": h,
                "e_dvfs_baseline": e_dvfs["baseline"],
                "e_dvfs_neuron": e_dvfs["neuron"],
                "e_dvfs_synapse": e_dvfs["synapse"] + mlp_mask * e_mac,
                "e_pl3_baseline": e_pl3["baseline"],
                "e_pl3_neuron": e_pl3["neuron"],
                "e_pl3_synapse": e_pl3["synapse"] + mlp_mask * e_mac,
            }
            new_state = {"v": v, "ref": ref, "xhat": xhat,
                         "spike_buf": spk_f}
            return new_state, rec

        return tick


def hybrid_graph(n_neurons: int = 256, hidden: int = 64,
                 n_ticks: int = 600, seed: int = 0) -> NetGraph:
    """NEF ensemble + event-MAC MLP as a two-population graph with a
    graded projection (16 b per spike event) between separate QPEs."""
    ens = build_ensemble(n_neurons, 1, seed=seed)

    # drive the channel with a slow sine (Fig. 20's stimulus class),
    # MAC-encoded by the SAME helper run_channel uses — the on-mesh hybrid
    # and the single-PE NEF path integrate identical per-tick drive
    t = np.arange(n_ticks)
    x = 0.8 * np.sin(2 * np.pi * t / 400)[:, None]
    drive_fx = encode_drive(ens, x, use_mac=True)

    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((n_neurons, hidden)) * 0.1,
                    jnp.float32)
    wq, ws = quantize_params_linear(w)

    nef_sram = n_neurons * (3 * 4 + 2 * 4) + n_neurons * 1 * 4 * 2
    mlp_sram = n_neurons * hidden + hidden * 4 + n_neurons // 8
    pops = [
        Population(name="nef", n=n_neurons, sram_bytes=nef_sram,
                   align_qpe=True, meta={"x": x}),
        Population(name="mlp", n=hidden, sram_bytes=mlp_sram,
                   align_qpe=True),
    ]
    projs = [Projection(src="nef", dst="mlp", payload=GRADED,
                        bits_per_packet=16 * n_neurons, delay_ticks=1)]
    sem = HybridSemantics(ens=ens, wq=wq, w_scale=ws, drive_fx=drive_fx)
    return NetGraph(populations=pops, projections=projs, semantics=sem,
                    name="hybrid_nef_mlp")


@dataclass
class HybridFarmSemantics:
    """K independent NEF -> event-MAC channels ticking in lockstep — the
    Sec. II hybrid at board scale (one channel = ``HybridSemantics``).

    All channels share one ensemble build (weights, LIF constants, drive
    table) but integrate phase-shifted copies of the drive, so spike
    times — and therefore NoC traffic — decorrelate across the mesh.
    States batch the channel axis: (K, N) arrays, one ``lif_step_ref``
    call for the whole farm.  Each NEF PE emits at most one graded
    spike-vector packet per tick (16 b per spike), consumed by its paired
    MLP PE on the next tick; energy follows activity on the NoC and in
    the datapath, exactly as in the single-channel semantics.
    """
    ens: object                         # core.nef.Ensemble (shared build)
    w_eff: jnp.ndarray                  # (N, hidden) f32 dequantized
    drive_fx: jnp.ndarray               # (T, N) int32 s16.15 encode drive
    n_pairs: int
    bits_per_spike: int = 16
    t_sys_s: float = 1e-3

    def _pe_ids(self, program: ChipProgram):
        nef = np.array([program.pe_slices[f"nef{k}"].start
                        for k in range(self.n_pairs)])
        mlp = np.array([program.pe_slices[f"mlp{k}"].start
                        for k in range(self.n_pairs)])
        return nef, mlp

    def init_state(self, program: ChipProgram):
        K, N = self.n_pairs, self.ens.n_neurons
        return {"v": jnp.zeros((K, N), jnp.int32),
                "ref": jnp.zeros((K, N), jnp.int32),
                "spike_buf": jnp.zeros((K, N), jnp.float32)}

    def make_tick(self, program: ChipProgram, *, dvfs, em, key):
        ens = self.ens
        K, N, D = self.n_pairs, ens.n_neurons, ens.dims
        hidden = self.w_eff.shape[1]
        P = program.n_pes
        drive = self.drive_fx
        T = drive.shape[0]
        # co-prime phase offsets decorrelate the channels' spike times
        offsets = jnp.asarray((np.arange(K) * 17) % T)
        nef_np, mlp_np = self._pe_ids(program)
        n_neur = jnp.zeros(P).at[jnp.asarray(nef_np)].set(
            float(N)).astype(jnp.int32)
        w_eff = self.w_eff
        # static placement permutation: every per-PE record row is (nef
        # values | mlp values | 0 elsewhere), so one gather through this
        # (P,) index table replaces a scatter per record key — scatters
        # with 2K dynamic indices were the farm tick's dominant cost at
        # 4096 PEs, a gather of the concatenated channel values is fused
        # elementwise.  Bitwise-identical: same values land on the same
        # PEs, everything else is exactly 0.
        perm_np = np.full(P, 2 * K, np.int64)
        perm_np[nef_np] = np.arange(K)
        perm_np[mlp_np] = K + np.arange(K)
        perm = jnp.asarray(perm_np)
        zk = jnp.zeros(K, jnp.float32)

        def place2(nef_vals, mlp_vals):
            """(K,) nef values + (K,) mlp values -> (P,) per-PE row."""
            return jnp.concatenate(
                [nef_vals, mlp_vals, jnp.zeros(1, jnp.float32)])[perm]

        def tick(state, t):
            dfx = drive[(t + offsets) % T]                    # (K, N)
            v, ref, spk = lif_step_ref(state["v"], state["ref"], dfx,
                                       **ens.lif)
            spk_f = spk.astype(jnp.float32)                   # (K, N)
            n_spk = spk_f.sum(axis=1)                         # (K,)
            active = (n_spk > 0).astype(jnp.float32)
            bits_out = self.bits_per_spike * n_spk

            # MLP PEs consume LAST tick's spike vectors (1-tick transport)
            arr = state["spike_buf"]                          # (K, N)
            h = arr @ w_eff                                   # (K, hidden)
            n_arr = arr.sum(axis=1)                           # (K,)
            mac_events = n_arr * hidden
            bits_in = self.bits_per_spike * n_arr

            packets = place2(active, zk)
            payload_bits = place2(bits_out, zk)
            fifo = place2(jnp.full(K, float(N)), n_arr)
            pl = dvfs.select_pl(fifo.astype(jnp.int32))
            snn_ev = place2(n_spk * D, zk)
            syn_ev = place2(n_spk * D, mac_events)
            e_dvfs = em.tick_energy(pl, n_neur, snn_ev, dvfs=True)
            e_pl3 = em.tick_energy(jnp.full((P,), 2), n_neur, snn_ev,
                                   dvfs=False)
            e_mac = place2(zk, mac_dynamic_energy_j(mac_events))

            rec = {
                "packets": packets,
                "payload_bits": payload_bits,
                "graded_bits_out": place2(bits_out, zk),
                "graded_bits_in": place2(zk, bits_in),
                "pl": pl,
                "n_fifo": fifo,
                "syn_events": syn_ev,
                "n_spk": n_spk.sum(),
                "hidden_out": h,
                "e_dvfs_baseline": e_dvfs["baseline"],
                "e_dvfs_neuron": e_dvfs["neuron"],
                "e_dvfs_synapse": e_dvfs["synapse"] + e_mac,
                "e_pl3_baseline": e_pl3["baseline"],
                "e_pl3_neuron": e_pl3["neuron"],
                "e_pl3_synapse": e_pl3["synapse"] + e_mac,
            }
            new_state = {"v": v, "ref": ref, "spike_buf": spk_f}
            return new_state, rec

        return tick


def hybrid_farm_graph(n_pairs: int, n_neurons: int = 32, hidden: int = 16,
                      n_ticks: int = 256, seed: int = 0) -> NetGraph:
    """``n_pairs`` independent NEF -> event-MAC channels as one graph
    (2 * n_pairs populations).  All NEF populations are laid out before
    all MLP populations, so channel k's projection crosses a long stretch
    of the snake — board-scale multicast traffic over real mesh links.
    """
    ens = build_ensemble(n_neurons, 1, seed=seed)
    t = np.arange(n_ticks)
    x = 0.8 * np.sin(2 * np.pi * t / 97)[:, None]
    drive_fx = encode_drive(ens, x, use_mac=True)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((n_neurons, hidden)) * 0.1,
                    jnp.float32)
    wq, ws = quantize_params_linear(w)
    w_eff = wq.astype(jnp.float32) * ws[None, :]

    nef_sram = n_neurons * (3 * 4 + 2 * 4)
    mlp_sram = n_neurons * hidden + hidden * 4 + n_neurons // 8
    pops = ([Population(name=f"nef{k}", n=n_neurons, sram_bytes=nef_sram)
             for k in range(n_pairs)]
            + [Population(name=f"mlp{k}", n=hidden, sram_bytes=mlp_sram)
               for k in range(n_pairs)])
    projs = [Projection(src=f"nef{k}", dst=f"mlp{k}", payload=GRADED,
                        bits_per_packet=16 * n_neurons, delay_ticks=1)
             for k in range(n_pairs)]
    sem = HybridFarmSemantics(ens=ens, w_eff=w_eff, drive_fx=drive_fx,
                              n_pairs=n_pairs)
    return NetGraph(populations=pops, projections=projs, semantics=sem,
                    name=f"hybrid_farm{n_pairs}")


# -------------------------------------------------------------------------
# Board-scale variants: the same three workload classes sized to fill a
# multi-chip board and compiled across chip boundaries
# -------------------------------------------------------------------------

def synfire_board_graph(board, fill: float = 1.0, seed: int = 0,
                        sp: paper.SynfireParams = paper.SYNFIRE,
                        **build_kw) -> NetGraph:
    """Synfire ring sized to ``fill`` of a board's PEs — one population
    per PE, so the ring snakes through every chip and the wrap-around
    edge crosses the whole chip grid."""
    return synfire_graph(n_pes=max(2, int(board.n_pes * fill)), seed=seed,
                         sp=sp, **build_kw)


def dnn_board_graph(board, layer: dict | None = None,
                    pe: PESpec = PESpec(), bytes_per: int = 1) -> NetGraph:
    """Feedforward conv pipeline sized to a board: the template ``layer``
    (default: the chip_scale 64x64x32->64 conv, ~13 tiles under the
    128 kB SRAM) repeats until the tiled stack fills the board's PEs, so
    consecutive layers land on neighboring chips and every inter-layer
    activation burst that crosses a boundary rides a chip-to-chip link."""
    layer = layer or dict(h=64, w=64, cin=32, cout=64, kh=3, kw=3)
    _, _, tiles = partition_layer_to_sram(
        pe, layer["h"], layer["w"], layer["cin"], layer["cout"],
        layer["kh"], layer["kw"], bytes_per=bytes_per)
    # populations are atomic on a chip, so size by whole layers per chip
    # (the partitioner cannot split a layer across a chip boundary)
    n_layers = max(2, (board.chip.n_pes // tiles) * board.n_chips)
    return dnn_graph([dict(layer, name=f"conv{i}") for i in range(n_layers)],
                     pe=pe, bytes_per=bytes_per)


def hybrid_farm_board_graph(board, n_neurons: int = 32, hidden: int = 16,
                            n_ticks: int = 256, seed: int = 0) -> NetGraph:
    """Hybrid NEF -> event-MAC farm sized to a board: one channel per PE
    pair.  All NEF populations precede all MLP populations, so after
    partitioning most channels span chips — worst-case (traffic-heavy)
    layout for the chip-to-chip tier, which is what makes it the board
    benchmark's headline workload."""
    return hybrid_farm_graph(n_pairs=max(1, board.n_pes // 2),
                             n_neurons=n_neurons, hidden=hidden,
                             n_ticks=n_ticks, seed=seed)


def board_workload(graph: NetGraph, board, n_ticks: int = 64,
                   refine: bool = True, **sim_kw) -> dict:
    """Partition + compile ``graph`` across ``board``, run it on the
    unchanged engine, and report the per-tier traffic split."""
    from repro.board import compile_board
    prog = compile_board(graph, board, refine=refine)
    sim = ChipSim(prog, **sim_kw)
    recs = sim.run(n_ticks)
    flits = np.asarray(recs["link_flits"])
    x_flits = float(np.asarray(recs["flits_xchip"]).sum()) \
        if "flits_xchip" in recs else 0.0
    tot = float(flits.sum())
    return {
        "sim": sim, "recs": recs, "table": chip_power_table(sim, recs),
        "program": prog,
        "n_chips_used": int((prog.part.chips_of_graph() > 0).sum()),
        "cut_flits": prog.part.cut_flits,
        "flits_total": tot,
        "flits_xchip": x_flits,
        "xchip_frac": x_flits / tot if tot else 0.0,
        "energy_noc_j": float(np.asarray(recs["e_noc"]).sum()),
        "energy_xchip_j": float(np.asarray(recs["e_noc_xchip"]).sum())
        if "e_noc_xchip" in recs else 0.0,
        "worst_path_latency_s": prog.worst_path_latency_s,
    }


def adaptive_control_workload(**kw) -> dict:
    """Closed-loop adaptive control with on-mesh PES learning (Yan et
    al., arXiv:2009.08921) — the plasticity subsystem's workload.  Lives
    in ``repro.learn.adaptive``; re-exported here (lazily — the learn
    package imports this module's neighbors) so the workload catalog has
    one front door."""
    from repro.learn.adaptive import adaptive_control_workload as f
    return f(**kw)


def stdp_pair_workload(**kw) -> dict:
    """Poisson -> LIF pair with an on-mesh STDP projection (see
    ``repro.learn.adaptive.stdp_pair_workload``)."""
    from repro.learn.adaptive import stdp_pair_workload as f
    return f(**kw)


def hybrid_workload(n_neurons: int = 256, hidden: int = 64,
                    n_ticks: int = 600, mesh: MeshSpec | None = None,
                    seed: int = 0) -> dict:
    """Compile and run the hybrid NEF -> event-MAC pipeline on the mesh."""
    graph = hybrid_graph(n_neurons, hidden, n_ticks=n_ticks, seed=seed)
    sim = ChipSim(compile_graph(graph, mesh))
    recs = sim.run(n_ticks)

    x = graph.populations[0].meta["x"]
    xhat = np.asarray(recs["xhat"])
    spikes_per_tick = np.asarray(recs["n_spk"], np.float64)
    total_spikes = float(spikes_per_tick.sum())
    active = spikes_per_tick > 0
    e_mac = event_mac_energy_j(total_spikes, 1, hidden)
    e_frame = event_mac_energy_j(n_ticks, n_neurons, hidden)
    e_tick = (n_neurons * paper.NEF_E_NEURON_J
              + spikes_per_tick * 1 * 0.2e-9)
    ens = graph.semantics.ens
    return {
        "sim": sim, "recs": recs, "table": chip_power_table(sim, recs),
        "xhat": xhat,
        "x": x,
        "rmse": float(np.sqrt(np.mean(
            (xhat[n_ticks // 4:, 0] - x[n_ticks // 4:, 0]) ** 2))),
        "n_dispatched": int(np.asarray(recs["n_dispatched"]).sum()),
        "total_spikes": total_spikes,
        "duty_cycle": float(active.mean()),
        "energy_mac_j": e_mac,
        "energy_mac_frame_j": e_frame,
        "event_vs_frame": e_mac / e_frame,
        "energy_noc_j": float(np.asarray(recs["e_noc"]).sum()),
        "link_loads": np.asarray(recs["link_flits"]),
        "graded_bits_out": np.asarray(recs["graded_bits_out"]).sum(axis=1),
        "graded_bits_in": np.asarray(recs["graded_bits_in"]).sum(axis=1),
        "synops": synop_metrics(ens, spikes_per_tick, e_tick),
        "hidden_out": np.asarray(recs["hidden_out"]),
    }
