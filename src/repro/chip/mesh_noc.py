"""Mesh NoC traffic model (paper Sec. III-A/B at chip scale).

The chip is a W x H mesh of QPEs (4 PEs each) joined by directed links.
Spike delivery is multicast: the router duplicates a packet at branch
points of its X/Y tree, so a tree's cost is its set of distinct links
(core/noc.py computes this per source with Python loops).  At chip scale
that loop is hoisted out of the hot path: each source PE's multicast tree
is precomputed ONCE as a 0/1 link-incidence row, and per-tick traffic
becomes a dense einsum

    link_load[l] = sum_p  packets[p] * incidence[p, l]

which vectorizes over ticks, sources, and links inside ``jax.lax.scan``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.configs import paper
from repro.core.noc import NocSpec, xy_route

SPIKE_PACKET_BITS = 64        # header-only DNoC spike packet (core/noc.py)


@dataclass(frozen=True)
class MeshSpec:
    """W x H QPE mesh; PEs number QPE-major (PE p lives in QPE p // 4)."""
    width: int
    height: int
    pes_per_qpe: int = 4

    @property
    def n_qpes(self) -> int:
        return self.width * self.height

    @property
    def n_pes(self) -> int:
        return self.n_qpes * self.pes_per_qpe

    def qpe_coord(self, q: int) -> tuple[int, int]:
        return (q % self.width, q // self.width)

    def pe_coord(self, p: int) -> tuple[int, int]:
        return self.qpe_coord(p // self.pes_per_qpe)

    @staticmethod
    def for_pes(n_pes: int, pes_per_qpe: int = 4) -> "MeshSpec":
        """Smallest near-square mesh holding ``n_pes`` PEs."""
        q = -(-n_pes // pes_per_qpe)
        w = int(np.ceil(np.sqrt(q)))
        h = -(-q // w)
        return MeshSpec(w, h, pes_per_qpe)


@dataclass
class MeshNoc:
    """Link enumeration + incidence construction + vectorized accounting."""
    mesh: MeshSpec
    spec: NocSpec = field(default_factory=NocSpec)

    def __post_init__(self):
        links = []
        for y in range(self.mesh.height):
            for x in range(self.mesh.width):
                if x + 1 < self.mesh.width:
                    links.append(((x, y), (x + 1, y)))
                    links.append(((x + 1, y), (x, y)))
                if y + 1 < self.mesh.height:
                    links.append(((x, y), (x, y + 1)))
                    links.append(((x, y + 1), (x, y)))
        self.links = links
        self.link_index = {lk: i for i, lk in enumerate(links)}

    @property
    def n_links(self) -> int:
        return len(self.links)

    # -- incidence construction (setup time, Python) ----------------------

    def tree_links(self, src: tuple, dsts) -> set:
        """Distinct links of the X/Y multicast tree src -> dsts (shared
        prefixes paid once — the router duplicates at branch points)."""
        out: set = set()
        for d in dsts:
            if d != src:
                out.update(xy_route(src, d))
        return out

    def incidence_row(self, src: tuple, dsts) -> np.ndarray:
        row = np.zeros(self.n_links, np.float32)
        for lk in self.tree_links(src, dsts):
            row[self.link_index[lk]] = 1.0
        return row

    def incidence(self, src_coords, dst_coord_lists) -> np.ndarray:
        """(n_sources, n_links) 0/1 multicast-tree incidence tensor."""
        return np.stack([self.incidence_row(s, d)
                         for s, d in zip(src_coords, dst_coord_lists)])

    def tree_hops(self, src: tuple, dsts) -> int:
        """Worst-case hop depth of the multicast tree (packet latency)."""
        return max((abs(src[0] - d[0]) + abs(src[1] - d[1]) for d in dsts),
                   default=0)

    # -- per-tick accounting (traced, dense) ------------------------------

    def link_loads(self, packets, inc) -> jnp.ndarray:
        """packets: (..., n_sources) packet counts emitted per source this
        tick; inc: (n_sources, n_links).  Returns (..., n_links) loads."""
        return jnp.einsum("...p,pl->...l", packets.astype(jnp.float32),
                          jnp.asarray(inc))

    def spike_energy_j(self, loads) -> jnp.ndarray:
        """Energy of header-only spike packets from total link traversals."""
        return (loads.sum(axis=-1) * SPIKE_PACKET_BITS
                * self.spec.pj_per_bit_hop * 1e-12)

    # -- typed packet classes (graded payloads over the DNoC) --------------

    def packet_flits(self, payload_bits) -> jnp.ndarray:
        """Flits per packet given per-source payload bits (0 = header-only
        spike packet = 1 flit; graded = ceil(bits / 128) flits)."""
        pb = jnp.asarray(payload_bits)
        return jnp.where(pb > 0, -(-pb // self.spec.payload_bits), 1)

    def packet_bits(self, payload_bits) -> jnp.ndarray:
        """Bits on the wire per link traversal of one packet: 64 b for a
        spike packet, ceil(bits/128) flits of 192 b for graded payloads."""
        pb = jnp.asarray(payload_bits)
        return jnp.where(pb > 0, self.packet_flits(pb) * self.spec.flit_bits,
                         SPIKE_PACKET_BITS)

    def flit_loads(self, packets, inc, payload_bits) -> jnp.ndarray:
        """Per-link flit traffic: each source's packets weighted by its
        packet's flit count before hitting the incidence tensor."""
        w = packets.astype(jnp.float32) * self.packet_flits(payload_bits)
        return jnp.einsum("...p,pl->...l", w, jnp.asarray(inc))

    def traffic_energy_j(self, packets, tree_links, payload_bits):
        """Energy of one tick's multicast traffic, packet-class aware.

        packets (..., P) packets emitted per source; tree_links (P,) link
        count of each source's multicast tree (= inc.sum(axis=1));
        payload_bits (..., P) or (P,).  Spike packets cost 64 b per link
        traversal, graded packets cost their flit footprint.
        """
        bits = (packets.astype(jnp.float32) * jnp.asarray(tree_links)
                * self.packet_bits(payload_bits))
        return bits.sum(axis=-1) * self.spec.pj_per_bit_hop * 1e-12

    def payload_energy_j(self, loads, payload_bits) -> jnp.ndarray:
        """Energy of payload packets: each traversal moves ceil(bits/128)
        DNoC flits of 192 bits."""
        nflits = -(-payload_bits // self.spec.payload_bits)
        return (loads.sum(axis=-1) * nflits * self.spec.flit_bits
                * self.spec.pj_per_bit_hop * 1e-12)

    def congestion(self, loads) -> jnp.ndarray:
        """Peak per-link load (packets / tick) — the SpiNNCer-style traffic
        bottleneck metric."""
        return loads.max(axis=-1)

    def link_capacity_packets(self, t_window_s: float,
                              packet_bits: int = SPIKE_PACKET_BITS) -> float:
        """Packets one link can carry in ``t_window_s`` at the NoC clock."""
        flits = -(-packet_bits // self.spec.payload_bits)
        cycles_per_packet = self.spec.hop_cycles * flits
        return t_window_s * self.spec.freq_hz / cycles_per_packet

    def hop_latency_s(self, n_hops) -> float:
        return n_hops * self.spec.hop_cycles / self.spec.freq_hz
