"""Mesh NoC traffic model (paper Sec. III-A/B at chip scale).

The chip is a W x H mesh of QPEs (4 PEs each) joined by directed links.
Spike delivery is multicast: the router duplicates a packet at branch
points of its X/Y tree, so a tree's cost is its set of distinct links
(core/noc.py computes this per source with Python loops).  At chip scale
both the setup and the hot path are vectorized:

* **setup** — each source's X/Y multicast tree is derived ARITHMETICALLY
  from its destination coordinate array (one eastward run + one westward
  run on the source row, one vertical run per destination column), so
  building the incidence never walks ``xy_route`` hop by hop.  Trees are
  stored sparse: a CSR ``SparseIncidence`` of (link_ids, source_ptr) —
  O(sum of tree sizes) memory instead of O(P * n_links).
* **per tick** — traffic is either the dense einsum

      link_load[l] = sum_p  packets[p] * incidence[p, l]

  over the densified incidence, or (preferred once trees are sparse
  relative to the mesh) a gather + segment-sum over the CSR entries
  (``repro.kernels.link_load``).  Both paths are exact on integer-valued
  packet counts, so they agree bitwise; ``ChipSim`` auto-selects from the
  incidence shape (mesh size, density, per-link fan-in).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core.noc import NocSpec, ORIENTATIONS, build_tree
from repro.kernels.event_gather.ops import (EVENT_GATHER_IMPLS,
                                            active_source_set,
                                            event_link_loads)
from repro.kernels.link_load.ops import link_loads_cols, link_loads_csc

SPIKE_PACKET_BITS = 64        # header-only DNoC spike packet (core/noc.py)

# selectable sparse accumulation kernels: the CPU column plan (bucketed
# gathers + prefix adds) or the Pallas sorted-segment prefix-sum kernel
# (interpret mode on CPU, compiled on a real TPU target); "auto" resolves
# to the column plan, the engine's measured-fastest CPU path
LINK_LOAD_IMPLS = ("auto", "column_plan", "pallas")

# incidence density above which the dense einsum beats the gather +
# segment-sum (small meshes / near-broadcast traffic); ChipSim.run uses it
# to auto-select the accounting path
DENSE_DENSITY = 0.25

# the column plan unrolls one gather+add per column (= max sources sharing
# one link), so fan-in-heavy graphs that pass the density test would still
# trace an O(P)-op tick body; above this column count auto-select falls
# back to the dense einsum
MAX_SPARSE_COLS = 128

# below this mesh size the dense einsum is a trivially small GEMV that
# beats the sparse plan's fixed op overhead (BENCH_pr3.json: the sparse
# path only breaks even around 8x8-QPE / 256-PE meshes), so auto-select
# keeps small chips dense
MIN_SPARSE_LINKS = 128


@dataclass(frozen=True)
class MeshSpec:
    """W x H QPE mesh; PEs number QPE-major (PE p lives in QPE p // 4)."""
    width: int
    height: int
    pes_per_qpe: int = 4

    @property
    def n_qpes(self) -> int:
        return self.width * self.height

    @property
    def n_pes(self) -> int:
        return self.n_qpes * self.pes_per_qpe

    def qpe_coord(self, q: int) -> tuple[int, int]:
        return (q % self.width, q // self.width)

    def pe_coord(self, p: int) -> tuple[int, int]:
        return self.qpe_coord(p // self.pes_per_qpe)

    @staticmethod
    def for_pes(n_pes: int, pes_per_qpe: int = 4) -> "MeshSpec":
        """Smallest near-square mesh holding ``n_pes`` PEs."""
        q = -(-n_pes // pes_per_qpe)
        w = int(np.ceil(np.sqrt(q)))
        h = -(-q // w)
        return MeshSpec(w, h, pes_per_qpe)


def _concat_ranges(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Concatenation of the integer ranges [starts[i], starts[i]+lens[i]),
    without a Python loop."""
    ends = np.cumsum(lens)
    total = int(ends[-1]) if lens.size else 0
    if total == 0:
        return np.empty(0, np.int64)
    return np.repeat(starts, lens) + np.arange(total) - np.repeat(
        ends - lens, lens)


@dataclass
class SparseIncidence:
    """CSR multicast-tree incidence: source p's tree is the distinct link
    ids ``link_ids[source_ptr[p]:source_ptr[p+1]]``.

    Equivalent to the dense 0/1 ``(P, n_links)`` tensor (``dense()``) but
    O(nnz) = O(sum of tree sizes) instead of O(P * n_links) — the per-tree
    link count is O(mesh diameter), not O(n_links), so board-scale meshes
    stay linear.  ``tree_hops[p]`` is the worst hop depth of source p's
    tree (packet latency), computed in the same construction pass.
    """
    link_ids: np.ndarray        # (nnz,) int32 — distinct within a source
    source_ptr: np.ndarray      # (P + 1,) int64 CSR row pointer
    n_links: int
    tree_hops: np.ndarray       # (P,) int32 worst-case hops per source

    @property
    def n_sources(self) -> int:
        return len(self.source_ptr) - 1

    @property
    def nnz(self) -> int:
        return len(self.link_ids)

    @property
    def density(self) -> float:
        cells = self.n_sources * self.n_links
        return self.nnz / cells if cells else 1.0

    @functools.cached_property
    def tree_links(self) -> np.ndarray:
        """(P,) link count of each source's multicast tree
        (== dense().sum(axis=1))."""
        return np.diff(self.source_ptr).astype(np.int64)

    @functools.cached_property
    def src_of_entry(self) -> np.ndarray:
        """(nnz,) source id of each CSR entry — the gather index of the
        per-tick segment-sum."""
        return np.repeat(np.arange(self.n_sources, dtype=np.int32),
                         self.tree_links)

    @staticmethod
    def from_rows(rows, n_links: int, tree_hops) -> "SparseIncidence":
        """Assemble the CSR form from per-source link-id arrays."""
        lens = np.array([r.size for r in rows], np.int64)
        ptr = np.zeros(len(rows) + 1, np.int64)
        np.cumsum(lens, out=ptr[1:])
        ids = (np.concatenate(rows).astype(np.int32) if rows
               else np.empty(0, np.int32))
        return SparseIncidence(link_ids=ids, source_ptr=ptr,
                               n_links=n_links,
                               tree_hops=np.asarray(tree_hops, np.int32))

    @functools.cached_property
    def max_fan_in(self) -> int:
        """Max sources sharing one link == column count of ``col_plan``
        (one vectorized bincount — no sort, no plan build)."""
        return int(np.bincount(self.link_ids, minlength=1).max())

    @functools.cached_property
    def csc(self) -> tuple[np.ndarray, np.ndarray]:
        """Link-major (CSC) view: (src_sorted, link_ptr) with entries
        sorted by link id — the layout of the Pallas prefix-sum kernel."""
        order = np.argsort(self.link_ids, kind="stable")
        counts = np.bincount(self.link_ids, minlength=self.n_links)
        link_ptr = np.zeros(self.n_links + 1, np.int64)
        np.cumsum(counts, out=link_ptr[1:])
        return self.src_of_entry[order], link_ptr

    @functools.cached_property
    def col_plan(self) -> tuple[tuple, np.ndarray]:
        """Prefix-column layout of the per-link segment reduction — the
        engine's per-tick plan.

        Links sorted by source count (heaviest first); column k holds the
        (k+1)-th source id of every link that HAS a (k+1)-th source, so
        the k-th take covers exactly the first ``len(cols[k])`` sorted
        links — per-link loads accumulate as K unrolled 1-D gathers +
        prefix adds (sum of lengths = nnz, no padding, no scatter op),
        then one final take restores link-id order via ``inv_perm``.
        Each link's sum has the same exact integer-valued terms as the
        dense einsum row, so the two agree bitwise.

        Returns (cols, inv_perm): cols a tuple of int32 index arrays of
        non-increasing length, inv_perm (n_links,) int32."""
        src_sorted, link_ptr = self.csc
        counts = np.diff(link_ptr)
        order = np.argsort(-counts, kind="stable")
        inv_perm = np.empty(self.n_links, np.int32)
        inv_perm[order] = np.arange(self.n_links, dtype=np.int32)
        sorted_counts = counts[order]
        cols = []
        for k in range(int(counts.max(initial=0))):
            n_k = int(np.count_nonzero(sorted_counts > k))
            cols.append(src_sorted[link_ptr[order[:n_k]] + k]
                        .astype(np.int32))
        return tuple(cols), inv_perm

    def device_col_plan(self) -> tuple[tuple, "jnp.ndarray"]:
        """``col_plan`` as device arrays, ready to close over in a tick
        loop (hoist ONCE per program, not per tick)."""
        cols, inv_perm = self.col_plan
        return tuple(jnp.asarray(c) for c in cols), jnp.asarray(inv_perm)

    @functools.cached_property
    def padded_rows(self) -> np.ndarray:
        """(P, max tree size) rectangular row layout: source p's link ids
        right-padded with the sentinel ``n_links`` — the gatherable form
        the event engine's compacted-index kernels index by active source
        (``repro.kernels.event_gather``)."""
        L = max(1, int(self.tree_links.max(initial=0)))
        out = np.full((self.n_sources, L), self.n_links, np.int32)
        if self.nnz:
            col = (np.arange(self.nnz)
                   - np.repeat(self.source_ptr[:-1], self.tree_links))
            out[self.src_of_entry, col] = self.link_ids
        return out

    def dense(self) -> np.ndarray:
        """Materialize the (P, n_links) 0/1 incidence tensor."""
        m = np.zeros((self.n_sources, self.n_links), np.float32)
        m[self.src_of_entry, self.link_ids] = 1.0
        return m


class NocAccounting:
    """Per-tick NoC accounting over a CSR/dense multicast incidence.

    Shared by the on-chip ``MeshNoc`` and the board-level
    ``repro.board.BoardNoc``: anything with a ``spec`` (``NocSpec``), an
    ``n_links`` link count and a ``link_load_impl`` knob prices traffic
    the same way, so single-chip and board programs run on one engine.
    All methods are traced inside the engine's scan; none hold state.
    """

    # -- sparse kernel selection ------------------------------------------

    def resolve_link_load_impl(self, impl: str | None = None) -> str:
        """Resolve the sparse accumulation kernel ("auto" -> the CPU
        column plan; "pallas" selects the sorted-segment prefix-sum
        kernel, interpret-mode on CPU)."""
        impl = impl or getattr(self, "link_load_impl", "auto")
        if impl not in LINK_LOAD_IMPLS:
            raise ValueError(f"unknown link_load_impl {impl!r}; "
                             f"expected one of {LINK_LOAD_IMPLS}")
        return "column_plan" if impl == "auto" else impl

    def device_plan(self, sinc: "SparseIncidence",
                    impl: str | None = None) -> tuple:
        """Device-resident per-tick plan for ``noc_loads``: a tagged
        layout matching the selected kernel.  Hoist ONCE per program,
        outside the tick closure."""
        impl = self.resolve_link_load_impl(impl)
        if impl == "column_plan":
            return ("column_plan", sinc.device_col_plan())
        src_sorted, link_ptr = sinc.csc
        return ("pallas", (jnp.asarray(src_sorted), jnp.asarray(link_ptr)))

    def noc_loads(self, packets, plan, payload_bits):
        """One tick's (link_loads, flit_loads) through the plan built by
        ``device_plan`` — the engine's sparse hot path, kernel-agnostic.
        Both kernels sum the same exact integer-valued terms per link, so
        every impl agrees bitwise with the dense einsum."""
        kind, data = plan
        if kind == "column_plan":
            cols, inv_perm = data
            return self.noc_loads_sparse(packets, cols, inv_perm,
                                         payload_bits)
        src_sorted, link_ptr = data
        pk = packets.astype(jnp.float32)
        w = pk * self.packet_flits(payload_bits)
        ll = link_loads_csc(pk, src_sorted, link_ptr, n_links=self.n_links)
        fl = link_loads_csc(w, src_sorted, link_ptr, n_links=self.n_links)
        return ll, fl

    # -- event-mode accounting (compacted active-source buffer) ------------

    def resolve_event_impl(self, impl: str | None = None) -> str:
        """Resolve the event-mode accumulation kernel.  "auto" delegates
        to the dense-weight column plan: it is already O(nnz), scatter-
        free, and the measured-fastest CPU path (BENCH_pr3: 16.8 us at
        4096 PEs) — the compacted-index kernels ("gather", "pallas";
        ``repro.kernels.event_gather``) are the TPU-shaped variants whose
        work is bounded by the event buffer instead of P."""
        impl = impl or getattr(self, "event_impl", "auto")
        if impl not in EVENT_GATHER_IMPLS:
            raise ValueError(f"unknown event_gather impl {impl!r}; "
                             f"expected one of {EVENT_GATHER_IMPLS}")
        return "column_plan" if impl == "auto" else impl

    def event_plan(self, sinc: "SparseIncidence",
                   impl: str | None = None) -> tuple:
        """Device-resident per-tick plan for ``event_noc_loads``.  Hoist
        ONCE per program, outside the tick closure."""
        impl = self.resolve_event_impl(impl)
        if impl == "column_plan":
            return ("column_plan", sinc.device_col_plan())
        return (impl, jnp.asarray(sinc.padded_rows))

    def event_noc_loads(self, packets, plan, payload_bits, idx=None):
        """Event-mode twin of ``noc_loads``: one tick's (link_loads,
        flit_loads).  ``idx`` is an optional pre-compacted active-source
        buffer (sentinel P on unused lanes) — it must cover every source
        with nonzero packets; when None the compaction runs here at full
        width, which is always exact.  Every impl sums the same exact
        integer-valued terms per link, so event and dense accounting
        agree bitwise."""
        kind, data = plan
        if kind == "column_plan":
            return self.noc_loads(packets, plan, payload_bits)
        if idx is None:
            idx, _ = active_source_set(packets, packets.shape[-1])
        w = packets.astype(jnp.float32) * self.packet_flits(payload_bits)
        ll = event_link_loads(idx, packets, data, n_links=self.n_links,
                              impl=kind)
        fl = event_link_loads(idx, w, data, n_links=self.n_links, impl=kind)
        return ll, fl

    def touched_link_counts(self, link_loads) -> dict:
        """Per-tier count of links carrying any traffic this tick — the
        activity telemetry both execution modes record identically
        (``repro.obs`` activity probes)."""
        hit = (link_loads > 0).astype(jnp.float32)
        return {tier: hit @ jnp.asarray(mask)
                for tier, mask in self.tier_masks().items()}

    # -- per-tick accounting (traced; dense or CSR) -----------------------

    def link_loads(self, packets, inc) -> jnp.ndarray:
        """packets: (..., n_sources) packet counts emitted per source this
        tick; inc: (n_sources, n_links).  Returns (..., n_links) loads."""
        return jnp.einsum("...p,pl->...l", packets.astype(jnp.float32),
                          jnp.asarray(inc))

    def link_loads_sparse(self, packets, buckets, inv_perm):
        """Sparse twin of ``link_loads``: bucketed column gathers +
        prefix adds — O(nnz) instead of the dense O(P * n_links), with no
        scatter in the hot path.

        ``buckets``/``inv_perm`` are ``SparseIncidence.col_plan`` (pass
        device index arrays, hoisted out of tick loops).  Bitwise-equal
        to the dense einsum on integer-valued counts."""
        return link_loads_cols(packets.astype(jnp.float32), buckets,
                               inv_perm, n_links=self.n_links)

    def spike_energy_j(self, loads) -> jnp.ndarray:
        """Energy of header-only spike packets from total link traversals."""
        return (loads.sum(axis=-1) * SPIKE_PACKET_BITS
                * self.spec.pj_per_bit_hop * 1e-12)

    # -- typed packet classes (graded payloads over the DNoC) --------------

    def packet_flits(self, payload_bits) -> jnp.ndarray:
        """Flits per packet given per-source payload bits (0 = header-only
        spike packet = 1 flit; graded = ceil(bits / 128) flits)."""
        pb = jnp.asarray(payload_bits)
        return jnp.where(pb > 0, -(-pb // self.spec.payload_bits), 1)

    def packet_bits(self, payload_bits) -> jnp.ndarray:
        """Bits on the wire per link traversal of one packet: 64 b for a
        spike packet, ceil(bits/128) flits of 192 b for graded payloads."""
        pb = jnp.asarray(payload_bits)
        return jnp.where(pb > 0, self.packet_flits(pb) * self.spec.flit_bits,
                         SPIKE_PACKET_BITS)

    def flit_loads(self, packets, inc, payload_bits) -> jnp.ndarray:
        """Per-link flit traffic: each source's packets weighted by its
        packet's flit count before hitting the incidence tensor."""
        w = packets.astype(jnp.float32) * self.packet_flits(payload_bits)
        return jnp.einsum("...p,pl->...l", w, jnp.asarray(inc))

    def flit_loads_sparse(self, packets, buckets, inv_perm, payload_bits):
        """Sparse twin of ``flit_loads`` (same column plan as
        ``link_loads_sparse``)."""
        w = packets.astype(jnp.float32) * self.packet_flits(payload_bits)
        return link_loads_cols(w, buckets, inv_perm, n_links=self.n_links)

    def noc_loads_sparse(self, packets, buckets, inv_perm, payload_bits):
        """One tick's (link_loads, flit_loads) through one fused column
        pass — the column-plan sparse hot path."""
        pk = packets.astype(jnp.float32)
        w = jnp.stack([pk, pk * self.packet_flits(payload_bits)])
        both = link_loads_cols(w, buckets, inv_perm, n_links=self.n_links)
        return both[0], both[1]

    def traffic_energy_j(self, packets, tree_links, payload_bits):
        """Energy of one tick's multicast traffic, packet-class aware.

        packets (..., P) packets emitted per source; tree_links (P,) link
        count of each source's multicast tree (``SparseIncidence.
        tree_links`` == inc.sum(axis=1)); payload_bits (..., P) or (P,).
        Spike packets cost 64 b per link traversal, graded packets cost
        their flit footprint.  Representation-independent: both the dense
        and the sparse engine path call this with the same inputs.
        """
        bits = (packets.astype(jnp.float32)
                * jnp.asarray(tree_links, jnp.float32)
                * self.packet_bits(payload_bits))
        return bits.sum(axis=-1) * self.spec.pj_per_bit_hop * 1e-12

    def congestion(self, loads) -> jnp.ndarray:
        """Peak per-link load (packets / tick) — the SpiNNCer-style traffic
        bottleneck metric."""
        return loads.max(axis=-1)

    def tier_masks(self) -> dict:
        """Named 0/1 masks over the link-id space, one per link tier —
        what the telemetry layer (``repro.obs``) uses to split per-link
        records into per-tier tracks.  A single-chip NoC has one tier;
        the board NoC adds the chip-to-chip SerDes tier."""
        return {"onchip": np.ones(self.n_links, np.float32)}

    def link_capacity_packets(self, t_window_s: float,
                              packet_bits: int = SPIKE_PACKET_BITS) -> float:
        """Packets one link can carry in ``t_window_s`` at the NoC clock."""
        flits = -(-packet_bits // self.spec.payload_bits)
        cycles_per_packet = self.spec.hop_cycles * flits
        return t_window_s * self.spec.freq_hz / cycles_per_packet

    def hop_latency_s(self, n_hops) -> float:
        return n_hops * self.spec.hop_cycles / self.spec.freq_hz


@dataclass
class MeshNoc(NocAccounting):
    """Link enumeration + incidence construction + vectorized accounting."""
    mesh: MeshSpec
    spec: NocSpec = field(default_factory=NocSpec)
    link_load_impl: str = "auto"       # sparse kernel: see LINK_LOAD_IMPLS

    def __post_init__(self):
        links = []
        for y in range(self.mesh.height):
            for x in range(self.mesh.width):
                if x + 1 < self.mesh.width:
                    links.append(((x, y), (x + 1, y)))
                    links.append(((x + 1, y), (x, y)))
                if y + 1 < self.mesh.height:
                    links.append(((x, y), (x, y + 1)))
                    links.append(((x, y + 1), (x, y)))
        self.links = links
        self.link_index = {lk: i for i, lk in enumerate(links)}
        # arithmetic link-id tables, keyed by the link's lower endpoint —
        # what lets tree construction index whole runs of links at once
        W, H = self.mesh.width, self.mesh.height
        self._id_e = np.full((W, H), -1, np.int32)   # (x,y) -> (x+1,y)
        self._id_w = np.full((W, H), -1, np.int32)   # (x+1,y) -> (x,y)
        self._id_n = np.full((W, H), -1, np.int32)   # (x,y) -> (x,y+1)
        self._id_s = np.full((W, H), -1, np.int32)   # (x,y+1) -> (x,y)
        for i, ((x0, y0), (x1, y1)) in enumerate(links):
            if x1 == x0 + 1:
                self._id_e[x0, y0] = i
            elif x1 == x0 - 1:
                self._id_w[x1, y1] = i
            elif y1 == y0 + 1:
                self._id_n[x0, y0] = i
            else:
                self._id_s[x0, y1] = i

    @property
    def n_links(self) -> int:
        return len(self.links)

    @property
    def n_onchip_links(self) -> int:
        """Every link of a single-chip mesh is on-chip — the shared
        tier-boundary accessor the benchmark link profiles use (the
        board NoC's first ``n_onchip_links`` ids are its on-chip tier)."""
        return len(self.links)

    # -- incidence construction (setup time, numpy) -----------------------

    def tree_links(self, src: tuple, dsts, orientation: str = "xy") -> set:
        """Distinct links of the dimension-ordered multicast tree
        src -> dsts (shared prefixes paid once — the router duplicates at
        branch points).

        Reference implementation: the shared ``repro.core.noc.build_tree``
        walk.  The vectorized ``tree_link_ids`` is validated against it
        in tests."""
        return set(build_tree(src, dsts, orientation))

    def tree_link_ids(self, src, dst_xy: np.ndarray,
                      orientation: str = "xy") -> np.ndarray:
        """Distinct link ids of the dimension-ordered multicast tree
        src -> dst coords, derived arithmetically from the destination
        coordinate array.

        Trunk-first routing makes the tree one trunk through the source
        (along the first-routed dimension, out to the farthest
        destination on either side) plus, per destination lane, one
        perpendicular run to the farthest destination — no
        per-destination route walk.  ``orientation`` picks the trunk
        dimension: "xy" (X first, the historical default) or "yx" — the
        latter is the same arithmetic over the transposed link-id
        tables, so both orientations share ONE implementation.
        """
        d = np.asarray(dst_xy, np.int64).reshape(-1, 2)
        if not d.size:
            return np.empty(0, np.int32)
        if orientation == "yx":
            # transposed space: u = y, v = x; +u links are north, +v east
            return self._oriented_tree_ids(
                (int(src[1]), int(src[0])), d[:, ::-1],
                self._id_n.T, self._id_s.T, self._id_e.T, self._id_w.T,
                self.mesh.height)
        if orientation != "xy":
            raise ValueError(f"unknown orientation {orientation!r}; "
                             f"expected one of {ORIENTATIONS}")
        return self._oriented_tree_ids(
            (int(src[0]), int(src[1])), d,
            self._id_e, self._id_w, self._id_n, self._id_s,
            self.mesh.width)

    def _oriented_tree_ids(self, src, d, id_pos, id_neg, id_up, id_dn,
                           width) -> np.ndarray:
        """Trunk + branch-run construction in an orientation-agnostic
        frame: (u, v) coordinates where u is the trunk dimension, with
        ``id_pos``/``id_neg`` the +u/-u link tables, ``id_up``/``id_dn``
        the +v/-v tables (transposed views for "yx") and ``width`` the
        u-extent of the mesh."""
        su, sv = src
        du, dv = d[:, 0], d[:, 1]
        parts = []
        umax, umin = int(du.max()), int(du.min())
        if umax > su:
            parts.append(id_pos[su:umax, sv])
        if umin < su:
            parts.append(id_neg[umin:su, sv])
        up = dv > sv
        if up.any():
            top = np.full(width, sv, np.int64)
            np.maximum.at(top, du[up], dv[up])
            cols = np.flatnonzero(top > sv)
            lens = top[cols] - sv
            vs = _concat_ranges(np.full(cols.size, sv, np.int64), lens)
            parts.append(id_up[np.repeat(cols, lens), vs])
        dn = dv < sv
        if dn.any():
            bot = np.full(width, sv, np.int64)
            np.minimum.at(bot, du[dn], dv[dn])
            cols = np.flatnonzero(bot < sv)
            lens = sv - bot[cols]
            vs = _concat_ranges(bot[cols], lens)
            parts.append(id_dn[np.repeat(cols, lens), vs])
        if not parts:
            return np.empty(0, np.int32)
        return np.concatenate(parts).astype(np.int32)

    def sparse_incidence(self, src_coords, dst_coord_lists,
                         orientations=None) -> SparseIncidence:
        """CSR incidence + per-source tree hop depths in one pass.

        ``dst_coord_lists[i]`` is source i's destination coordinate array
        (anything ``np.asarray`` can shape to (n, 2); duplicates and the
        source's own coordinate are harmless).  ``orientations`` is an
        optional per-source sequence of tree orientations ("xy"/"yx");
        None keeps every tree X-first — bit-identical to the
        pre-orientation compiler."""
        src = np.asarray(src_coords, np.int64).reshape(-1, 2)
        rows = []
        hops = np.zeros(len(src), np.int32)
        for i, (s, d) in enumerate(zip(src, dst_coord_lists)):
            d = np.asarray(d, np.int64).reshape(-1, 2)
            o = orientations[i] if orientations is not None else "xy"
            rows.append(self.tree_link_ids(s, d, orientation=o))
            if d.size:
                hops[i] = int(np.abs(d - s).sum(axis=1).max())
        return SparseIncidence.from_rows(rows, self.n_links, hops)

    def incidence_row(self, src: tuple, dsts) -> np.ndarray:
        row = np.zeros(self.n_links, np.float32)
        row[self.tree_link_ids(src, np.asarray(list(dsts),
                                               np.int64).reshape(-1, 2))] = 1.0
        return row

    def incidence(self, src_coords, dst_coord_lists) -> np.ndarray:
        """(n_sources, n_links) 0/1 multicast-tree incidence tensor."""
        return self.sparse_incidence(src_coords, dst_coord_lists).dense()

    def tree_hops(self, src: tuple, dsts) -> int:
        """Worst-case hop depth of the multicast tree (packet latency)."""
        return max((abs(src[0] - d[0]) + abs(src[1] - d[1]) for d in dsts),
                   default=0)
