"""Chip-level mesh simulator (paper Sec. III-A/B scaled out).

Unified workload API: declare any SNN / DNN / hybrid workload as a
``NetGraph`` (populations + typed spike/graded projections), compile it to
a ``ChipProgram`` (SRAM-constrained placement, routing tables, multicast
link-incidence tensors), and run it on the ONE workload-agnostic engine:

    graph = workloads.synfire_graph(8)          # or dnn_graph / hybrid_graph
    prog  = compile(graph)                      # placement + routing + NoC
    sim   = ChipSim(prog)
    recs  = sim.run(n_ticks=1200)               # one lax.scan, all PEs
    table = chip_power_table(sim, recs)         # Table III at chip scale

Modules:

* ``graph``     — ``NetGraph`` / ``Population`` / ``Projection`` and the
  ``TickSemantics`` contract (per-tick step, packets, Eq. (1) energies).
* ``compile``   — graph -> ``ChipProgram`` lowering with clear capacity /
  SRAM errors.
* ``mesh_noc``  — link enumeration, arithmetic X/Y multicast-tree
  construction into a CSR ``SparseIncidence``, and per-tick accounting
  (sparse segment reduction or dense einsum, bit-identical) for spike
  AND graded multi-flit packets.
* ``mapping``   — the shared snake-order placement primitive plus the
  legacy direct placers (``place_ring``/``place_layers``).
* ``chip``      — ``ChipSim``: runs any program in one ``lax.scan`` with
  per-PE activity-driven DVFS and chip-level power tables.
* ``workloads`` — graph builders: synfire ring of any length, tiled
  feedforward DNN pipeline, hybrid NEF + event-driven-MAC pipeline (and
  its board-scale ``hybrid_farm_graph`` of independent channels), plus
  ``*_board_graph`` variants sized to a multi-chip board.

One level up, ``repro.board`` compiles a ``NetGraph`` across a whole
grid of chips (``compile_board``) into a program this same ``ChipSim``
engine runs unchanged — see ``src/repro/board/``.
"""
from repro.chip.mesh_noc import MeshNoc, MeshSpec, SparseIncidence
from repro.chip.mapping import Placement, place_ring, place_layers
from repro.chip.graph import NetGraph, Population, Projection
from repro.chip.compile import ChipProgram, compile
from repro.chip.chip import ChipSim, chip_power_table

__all__ = ["MeshNoc", "MeshSpec", "SparseIncidence", "Placement",
           "place_ring", "place_layers", "NetGraph", "Population",
           "Projection", "ChipProgram", "compile", "ChipSim",
           "chip_power_table"]
