"""Chip-level mesh simulator (paper Sec. III-A/B scaled out).

Composes the per-PE models (core/) into a full W x H QPE mesh:

* ``mesh_noc``  — link enumeration, X/Y multicast-tree incidence tensors,
  vectorized per-tick link-load / latency / energy accounting.
* ``mapping``   — SRAM-constrained placement of neuron populations and DNN
  layer tiles onto PEs; emits routing tables + incidence tensors.
* ``chip``      — ``ChipSim``: all PEs vectorized in one ``lax.scan`` with
  per-PE activity-driven DVFS and chip-level power tables.
* ``workloads`` — scenario builders: synfire ring of any length, tiled
  feedforward DNN, hybrid NEF + event-driven-MAC pipeline.
"""
from repro.chip.mesh_noc import MeshNoc, MeshSpec
from repro.chip.mapping import Placement, place_ring, place_layers
from repro.chip.chip import ChipSim, chip_power_table

__all__ = ["MeshNoc", "MeshSpec", "Placement", "place_ring", "place_layers",
           "ChipSim", "chip_power_table"]
