"""Graph -> ChipProgram compiler.

``compile(graph, mesh)`` lowers a declarative ``NetGraph`` to everything the
workload-agnostic engine needs:

* **placement** — population tiles land on consecutive PEs in snake order
  over the QPE grid (generalizing ``mapping.place_ring``/``place_layers``:
  a ring of 1-tile populations reproduces ``place_ring`` exactly, a chain
  of multi-tile layer populations reproduces ``place_layers``), validated
  against both mesh capacity and the 128 kB per-PE SRAM *before* any
  routing work, with errors that name the offending population.
* **routing** — a dense ``RoutingTable`` built from the projections (every
  tile of ``src`` multicasts to every tile of ``dst``).
* **incidence** — each source PE's X/Y-multicast tree, derived
  arithmetically from its destination coordinate array (all tiles of a
  population share one destination set, computed once) and emitted as a
  CSR ``SparseIncidence`` — (link_ids, source_ptr) plus per-source
  ``tree_links``/``tree_hops`` in the same pass.  O(sum of tree sizes)
  work and memory; the dense ``(P, n_links)`` tensor is materialized
  lazily only if something asks for it.
* **packet classes** — per-source payload bits (0 = header-only spike
  packet; >0 = graded multi-flit packet) from the typed projections.
* **learning** — projections carrying a ``plasticity=`` rule lower into
  ``LearnSlot`` descriptors (``repro.learn.lower``); the engine turns
  them into per-slot weight/trace carry state updated every tick.

The resulting ``ChipProgram`` is a pure description: ``ChipSim`` executes
it, ``chip_power_table`` accounts it, and the graph's ``TickSemantics``
provides the per-tick step.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np

from repro.chip.graph import GRADED, NetGraph
from repro.chip.mapping import assign_slots, snake_coords
from repro.chip.mesh_noc import MeshNoc, MeshSpec, SparseIncidence
from repro.core.pe import PESpec
from repro.core.router import RoutingTable
from repro.learn.lower import lower_plasticity


@dataclass
class ChipProgram:
    """A compiled workload: placement + routing + packet classes + step."""
    graph: NetGraph
    mesh: MeshSpec
    noc: MeshNoc
    coords: np.ndarray          # (P, 2) int: QPE coord of each logical PE
    table: RoutingTable         # (P, P) source PE -> destination mask
    sinc: SparseIncidence       # CSR multicast incidence + tree hop depths
    payload_bits: np.ndarray    # (P,) int: payload bits per packet (0=spike)
    sram_bytes: np.ndarray      # (P,) int: per-PE workload state
    pe_slices: dict             # population name -> slice of logical PEs
    learn_slots: tuple = ()     # lowered plastic projections (repro.learn)

    @property
    def n_pes(self) -> int:
        return len(self.coords)

    @functools.cached_property
    def inc(self) -> np.ndarray:
        """Dense (P, n_links) 0/1 incidence — materialized lazily from the
        CSR form (the engine only densifies when the einsum path wins)."""
        return self.sinc.dense()

    @property
    def tree_links(self) -> np.ndarray:
        """(P,) multicast-tree link count per source (== inc.sum(axis=1))."""
        return self.sinc.tree_links

    @property
    def energy_tree_links(self) -> np.ndarray:
        """Per-source link counts the engine prices NoC energy with.  A
        single-chip program has one link tier, so this is ``tree_links``;
        a ``BoardProgram`` returns a (P, 2) [on-chip, chip-to-chip] split
        that its tiered ``BoardNoc.traffic_energy_j`` consumes."""
        return self.tree_links

    @functools.cached_property
    def worst_tree_hops(self) -> int:
        return int(self.sinc.tree_hops.max(initial=0))

    def pe_range(self, name: str) -> np.ndarray:
        """Logical PE ids of a population's tiles."""
        return np.arange(self.pe_slices[name].start,
                         self.pe_slices[name].stop)

    def fits(self, pe: PESpec = PESpec()) -> bool:
        return bool((self.sram_bytes <= pe.sram_bytes).all())

    # -- semantics passthrough (the engine only sees these two) -----------

    def init_state(self):
        return self.graph.semantics.init_state(self)

    def make_tick(self, *, dvfs, em, key):
        return self.graph.semantics.make_tick(self, dvfs=dvfs, em=em,
                                              key=key)

    def make_event_tick(self, *, dvfs, em, key):
        """The semantics' activity-compressed tick, or None when the
        workload has no compressed form (the engine then runs the dense
        tick and keeps only the event-mode NoC/activity accounting —
        still bitwise-identical records, just no tick-body speedup)."""
        make = getattr(self.graph.semantics, "make_event_tick", None)
        return make(self, dvfs=dvfs, em=em, key=key) if make else None


def check_tile_sram(graph: NetGraph, pe: PESpec) -> None:
    """SRAM constraint per population tile, with an error naming the
    population (shared by the single-chip and board compilers)."""
    for pop in graph.populations:
        if pop.sram_bytes > pe.sram_bytes:
            raise ValueError(
                f"population {pop.name!r}: per-tile state {pop.sram_bytes} B"
                f" exceeds the {pe.sram_bytes} B PE SRAM — split it into "
                f"more tiles")


def source_packet_classes(graph: NetGraph) -> dict:
    """Per-source-population payload bits (0 = spike packet).

    Packet class is per SOURCE (one multicast tree per source PE): a
    population mixing spike and graded out-edges — or two graded sizes —
    would be silently mispriced over the union tree, so reject it here.
    Shared by the single-chip and board compilers.
    """
    out_bits: dict = {}
    for pr in graph.projections:
        bits = pr.bits_per_packet if pr.payload == GRADED else 0
        prev = out_bits.setdefault(pr.src, bits)
        if prev != bits:
            raise ValueError(
                f"population {pr.src!r} mixes packet classes on its "
                f"out-projections ({prev} vs {bits} payload bits); split "
                f"it into one population per packet class")
    return out_bits


def compile(graph: NetGraph, mesh: MeshSpec | None = None,
            pe: PESpec = PESpec(),
            orientations: dict | None = None) -> ChipProgram:  # noqa: A001
    """Compile ``graph`` onto ``mesh`` (auto-sized when None).

    ``orientations`` optionally maps population name -> tree orientation
    ("xy"/"yx", see ``repro.core.noc.ORIENTATIONS``); unlisted
    populations — and the default None — keep the historical X-first
    trees, bit-identical to the pre-orientation compiler.  The
    profile-guided optimizer (``repro.routeopt``) is the intended
    caller; routing orientation never changes neuron-state records,
    only NoC link accounting.

    Raises ``ValueError`` up front — naming the population at fault — when
    a tile exceeds the PE SRAM or the graph exceeds the mesh capacity.
    """
    if graph.semantics is None:
        raise ValueError(f"graph {graph.name!r} has no tick semantics; "
                         "attach one before compiling")

    # SRAM constraint per population tile (before any placement work)
    check_tile_sram(graph, pe)

    pes_per_qpe = (mesh.pes_per_qpe if mesh is not None
                   else MeshSpec.for_pes(1).pes_per_qpe)
    slots, total_slots = assign_slots(graph.populations, pes_per_qpe)
    mesh = mesh or MeshSpec.for_pes(total_slots)

    # mesh capacity, with a clear error instead of a deep placement failure
    if total_slots > mesh.n_pes:
        need = MeshSpec.for_pes(total_slots, mesh.pes_per_qpe)
        raise ValueError(
            f"graph {graph.name!r} needs {total_slots} PE slots "
            f"({graph.n_tiles_total} tiles over "
            f"{len(graph.populations)} populations) but the "
            f"{mesh.width}x{mesh.height} QPE mesh holds {mesh.n_pes} PEs; "
            f"use at least a {need.width}x{need.height} mesh")

    # logical PE id per tile: compact the slot ranges (alignment gaps are
    # left unoccupied on the mesh but carry no logical PE)
    pe_slices = {}
    pe_slot = []                       # placement slot of each logical PE
    cur = 0
    for pop in graph.populations:
        a, b = slots[pop.name]
        pe_slices[pop.name] = slice(cur, cur + pop.n_tiles)
        pe_slot.extend(range(a, b))
        cur += pop.n_tiles
    n_pes = cur

    coords = snake_coords(mesh, pe_slot)

    out_bits = source_packet_classes(graph)

    # routing: every tile of src multicasts to every tile of dst
    masks = np.zeros((n_pes, n_pes), bool)
    payload_bits = np.zeros(n_pes, np.int64)
    for pr in graph.projections:
        masks[pe_slices[pr.src], pe_slices[pr.dst]] = True
        payload_bits[pe_slices[pr.src]] = out_bits[pr.src]
    table = RoutingTable(masks)

    # incidence: all tiles of a population multicast to the same
    # destination set, so the destination coordinate array is computed once
    # per population and each source tile's tree is derived arithmetically
    # from it (MeshNoc.tree_link_ids) — never from a per-destination walk
    # of the (P, P) masks
    noc = MeshNoc(mesh)
    dst_slices: dict = {p.name: [] for p in graph.populations}
    for pr in graph.projections:
        dst_slices[pr.src].append(pe_slices[pr.dst])
    empty = np.empty((0, 2), np.int64)
    dst_lists = []
    orients = []
    for pop in graph.populations:
        sls = dst_slices[pop.name]
        dst_xy = np.concatenate([coords[sl] for sl in sls]) if sls else empty
        dst_lists.extend([dst_xy] * pop.n_tiles)
        o = (orientations or {}).get(pop.name, "xy")
        orients.extend([o] * pop.n_tiles)
    sinc = noc.sparse_incidence(coords, dst_lists, orientations=orients)

    sram = np.zeros(n_pes, np.int64)
    for pop in graph.populations:
        sram[pe_slices[pop.name]] = pop.sram_bytes

    return ChipProgram(graph=graph, mesh=mesh, noc=noc, coords=coords,
                       table=table, sinc=sinc, payload_bits=payload_bits,
                       sram_bytes=sram, pe_slices=pe_slices,
                       learn_slots=lower_plasticity(graph, pe_slices))
