"""``ChipSim`` — a virtual SpiNNaker2 chip: W x H QPE mesh of PEs runs a
spiking workload in one ``jax.lax.scan`` over 1 ms ticks.

All PEs advance together as batched axes of the same arrays (the per-PE
models in core/snn.py are already (P, ...)-vectorized); what the chip
level adds per tick is the NoC: each PE's spike-packet count hits its
precomputed multicast-tree incidence row, one einsum yields per-link
loads, and the energy/congestion/latency accounting follows from
``NocSpec`` — no per-source Python in the hot path.

``chip_power_table`` generalizes ``synfire_power_table`` from one PE
average to the whole chip: per-PE table + chip totals + NoC power + the
SpiNNCer-style peak-link-load bottleneck check.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.chip.mapping import Placement, place_ring
from repro.chip.mesh_noc import MeshNoc, MeshSpec, SPIKE_PACKET_BITS
from repro.configs import paper
from repro.core.dvfs import DVFSController
from repro.core.energy import PEEnergyModel
from repro.core.snn import (SynfireNet, build_synfire, make_synfire_tick,
                            synfire_init_state, synfire_power_table)


@dataclass
class ChipSim:
    """A placed spiking workload on a full PE mesh."""
    net: SynfireNet
    placement: Placement
    dvfs: DVFSController = None
    em: PEEnergyModel = field(default_factory=PEEnergyModel)

    def __post_init__(self):
        if self.dvfs is None:
            sp = self.net.params
            self.dvfs = DVFSController(sp.l_th1, sp.l_th2)
        assert self.net.params.n_pes == self.placement.n_pes

    @property
    def noc(self) -> MeshNoc:
        return self.placement.noc

    @staticmethod
    def synfire(n_pes: int = 8, mesh: MeshSpec | None = None, seed: int = 0,
                **build_kw) -> "ChipSim":
        """Synfire ring of any length placed on a QPE mesh.  With the
        default 8 PEs this is exactly the paper's test-chip benchmark."""
        net = build_synfire(seed, n_pes=n_pes, **build_kw)
        return ChipSim(net=net, placement=place_ring(n_pes, mesh))

    def run(self, n_ticks: int, seed: int = 1) -> dict:
        """Per-tick records: everything ``simulate_synfire`` returns, plus

        link_load  (T, n_links) — spike packets per link per tick
        e_noc      (T,)         — NoC spike-traffic energy per tick [J]

        The neuron dynamics are the SAME tick function the single-chip
        path scans (make_synfire_tick), so an 8-PE ChipSim reproduces
        ``simulate_synfire`` rasters bit for bit.
        """
        tick = make_synfire_tick(self.net, dvfs=self.dvfs, em=self.em,
                                 key=jax.random.PRNGKey(seed))
        inc = jnp.asarray(self.placement.inc)
        noc = self.noc

        def chip_tick(state, t):
            state, rec = tick(state, t)
            # each spiking exc neuron emits one multicast packet; the tree
            # is fixed per source PE, so per-link load is a dense matmul
            packets = rec["spikes_exc"].astype(jnp.int32).sum(axis=1)  # (P,)
            loads = noc.link_loads(packets, inc)                       # (L,)
            rec["link_load"] = loads
            rec["e_noc"] = noc.spike_energy_j(loads)
            return state, rec

        _, recs = jax.lax.scan(chip_tick, synfire_init_state(self.net),
                               jnp.arange(n_ticks))
        return recs


def chip_power_table(sim: ChipSim, recs: dict,
                     t_sys_s: float = 1e-3) -> dict:
    """Chip-level generalization of ``synfire_power_table``.

    per_pe     — the paper's Table III numbers (averaged over all PEs)
    chip       — the same, summed over the mesh [mW]
    noc        — average NoC power [mW], peak link load [packets/tick],
                 link utilization vs. capacity, worst multicast hop depth
    """
    per_pe = synfire_power_table(recs, t_sys_s=t_sys_s)
    P = sim.placement.n_pes
    chip = {mode: {k: v * P for k, v in per_pe[mode].items()}
            for mode in ("dvfs", "pl3")}

    loads = np.asarray(recs["link_load"])                  # (T, L)
    e_noc = np.asarray(recs["e_noc"])
    peak = float(sim.noc.congestion(loads).max()) if loads.size else 0.0
    cap = sim.noc.link_capacity_packets(t_sys_s, SPIKE_PACKET_BITS)
    noc = {
        "power_mw": float(e_noc.mean() / t_sys_s * 1e3),
        "peak_link_load": peak,
        "mean_link_load": float(loads.mean()) if loads.size else 0.0,
        "link_capacity": cap,
        "peak_utilization": peak / cap,
        "worst_tree_hops": sim.placement.worst_tree_hops,
        "worst_hop_latency_s": sim.noc.hop_latency_s(
            sim.placement.worst_tree_hops),
        "n_links": sim.noc.n_links,
    }
    return {"per_pe": per_pe, "chip": chip, "noc": noc,
            "n_pes": P, "mesh": (sim.placement.mesh.width,
                                 sim.placement.mesh.height)}
