"""``ChipSim`` — the workload-agnostic chip engine.

A virtual SpiNNaker2 chip: W x H QPE mesh of PEs running any compiled
``ChipProgram`` (SNN, DNN or hybrid — see ``repro.chip.graph`` /
``repro.chip.compile``) in one ``jax.lax.scan`` over 1 ms ticks.

The program's ``TickSemantics`` advances all PEs as batched axes of the
same arrays and reports per-PE activity (packets emitted, performance
level, Eq. (1) energy split); what the engine adds per tick is the NoC:
each source's packet count hits its precomputed multicast-tree incidence
— either the dense einsum over the (P, n_links) tensor or, once trees are
sparse relative to the mesh (the board-scale regime), a gather +
segment-sum over the CSR entries (``repro.kernels.link_load``) — yielding
per-link loads in packets AND in DNoC flits, so graded-payload
(multi-flit) packets are priced correctly, plus the energy/congestion
accounting from ``NocSpec``.  The representation is auto-selected from
the incidence shape — mesh size, density, per-link fan-in
(``noc_mode="auto"``; force with "dense"/"sparse") — both paths agree
bitwise on integer packet counts, and the incidence arrays are hoisted
onto the device once, outside the per-tick closure.
No per-source Python in the hot path, no per-workload branches in the
engine.

``chip_power_table`` generalizes ``synfire_power_table`` from one PE
average to the whole chip: per-PE table + chip totals + NoC power + the
SpiNNCer-style peak-link-load bottleneck check.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.chip.compile import ChipProgram
from repro.chip.mesh_noc import (DENSE_DENSITY, MAX_SPARSE_COLS,
                                 MIN_SPARSE_LINKS, MeshNoc,
                                 SPIKE_PACKET_BITS)
from repro.core.dvfs import DVFSController
from repro.core.energy import PEEnergyModel


@dataclass
class ChipSim:
    """A compiled workload program on a full PE mesh (or, for a
    ``repro.board.BoardProgram``, a whole multi-chip board — the engine
    is identical; only the incidence and the NoC pricing differ).

    ``noc_mode`` selects the NoC accounting representation: "auto" picks
    sparse vs dense by incidence density, "sparse"/"dense" force it (the
    two agree bitwise — forcing is for benchmarks and golden tests).
    ``link_load_impl`` overrides the program NoC's sparse accumulation
    kernel (None defers to the NoC's own knob: "auto" -> the CPU column
    plan; "pallas" -> the prefix-sum kernel, interpret-mode on CPU).

    ``exec_mode`` selects the execution mode: "dense" runs the per-PE
    work of every tick at full width; "event" runs the workload's
    activity-compressed tick (when its semantics provides one —
    ``make_event_tick``) and the event-mode NoC accounting; "auto" picks
    event exactly when the NoC auto-select goes sparse (the same
    board-scale regime).  Event mode is bitwise-identical to dense on
    every record — rasters, probes, energies — by construction; the
    compressed tick falls back to the dense formulas inside the scan
    whenever a tick's activity overflows the event buffer.
    ``event_impl`` picks the event NoC kernel (``repro.kernels.
    event_gather``: "auto" delegates to the column plan on CPU;
    "gather"/"pallas" force the compacted-index variants).
    """
    program: ChipProgram
    dvfs: Optional[DVFSController] = None
    em: PEEnergyModel = field(default_factory=PEEnergyModel)
    noc_mode: str = "auto"
    link_load_impl: Optional[str] = None
    exec_mode: str = "auto"
    event_impl: Optional[str] = None

    def __post_init__(self):
        if self.dvfs is None:
            # workload semantics may carry their own FIFO thresholds (e.g.
            # a synfire net built with custom l_th1/l_th2); fall back to
            # the paper's Table II defaults
            sem = self.program.graph.semantics
            make = getattr(sem, "dvfs_controller", None)
            self.dvfs = make() if make else DVFSController()

    @property
    def noc(self) -> MeshNoc:
        return self.program.noc

    def use_sparse_noc(self, noc_mode: str | None = None) -> bool:
        """Resolve the accounting representation for this program.

        Auto requires a big-enough mesh (below ~256 PEs the dense einsum
        is a trivially small GEMV that wins on op overhead), a sparse
        incidence (density), AND a bounded per-link fan-in: the column
        plan unrolls one op per column, so an all-to-one graph — sparse
        by density — would still trace an O(P)-op tick body."""
        mode = noc_mode or self.noc_mode
        if mode not in ("auto", "sparse", "dense"):
            raise ValueError(f"unknown noc_mode {mode!r}")
        if mode == "auto":
            sinc = self.program.sinc
            return (sinc.n_links >= MIN_SPARSE_LINKS
                    and sinc.density <= DENSE_DENSITY
                    and sinc.max_fan_in <= MAX_SPARSE_COLS)
        return mode == "sparse"

    def use_event_mode(self, exec_mode: str | None = None) -> bool:
        """Resolve the execution mode for this program: "auto" picks the
        activity-compressed mode exactly when the NoC auto-select goes
        sparse — the same mesh-scale/density regime where per-tick dense
        work dominates and activity is sparse relative to it."""
        mode = exec_mode or self.exec_mode
        if mode not in ("auto", "event", "dense"):
            raise ValueError(f"unknown exec_mode {mode!r}")
        if mode == "auto":
            return self.use_sparse_noc("auto")
        return mode == "event"

    def make_stepper(self, seed: int = 1, noc_mode: str | None = None,
                     link_load_impl: str | None = None,
                     exec_mode: str | None = None):
        """The batched-carry entry point: ``(init_state, step)`` where
        ``step(state, t) -> (state, rec)`` is the engine's FULL per-tick
        body — semantics tick, on-mesh learning, NoC accounting (sparse
        or dense, tiered for boards) — exactly as ``run`` scans it.

        ``run`` itself is ``lax.scan(step, init, arange(n_ticks))``, so
        anything that composes ``step`` differently — the serving tier's
        ``jax.vmap`` over a fleet of independent instances
        (``repro.serve.fleet``), chunked stepping with checkpoint /
        restore of the carry between chunks, interleaved host I/O —
        computes bit-identical per-tick records to a plain ``run`` of
        the same program.  The carry returned by ``step`` is the full
        engine state (workload state incl. the ``learn`` subtree), which
        is what ``repro.ckpt`` snapshots for session save/restore.
        """
        prog = self.program
        event = self.use_event_mode(exec_mode)
        key = jax.random.PRNGKey(seed)
        tick = None
        if event:
            # the workload's activity-compressed tick; semantics without
            # one run their dense tick under event-mode NoC/activity
            # accounting (records stay bitwise-identical either way)
            tick = prog.make_event_tick(dvfs=self.dvfs, em=self.em, key=key)
        if tick is None:
            tick = prog.make_tick(dvfs=self.dvfs, em=self.em, key=key)
        noc = self.noc
        # on-mesh learning: programs with plastic projections extend the
        # scan carry with per-slot weight/trace state, updated right after
        # the semantics' tick and priced into a per-PE e_learn record.
        # Frozen programs (learn_slots == ()) skip this entirely — the
        # traced tick body is EXACTLY the pre-plasticity engine's.
        # (import here: repro.learn.engine reaches back into repro.chip
        # for the shared energy helpers)
        if getattr(prog, "learn_slots", ()):
            from repro.learn.engine import make_learn_step
            learn = make_learn_step(prog)
        else:
            learn = None
        init = prog.init_state()
        if learn is not None and (not isinstance(init, dict)
                                  or "learn" not in init):
            raise ValueError(
                f"graph {prog.graph.name!r} has plastic projections but "
                "its semantics' init_state does not carry a 'learn' "
                "subtree; include repro.learn.init_learn_state(program)")
        # incidence onto the device ONCE, outside the per-tick closure.
        # The kernel knob is validated even when the dense einsum wins
        # (a typo'd impl must error, not silently benchmark dense).
        impl = noc.resolve_link_load_impl(link_load_impl
                                          or self.link_load_impl)
        sparse = self.use_sparse_noc(noc_mode)
        if sparse and event:
            plan = noc.event_plan(prog.sinc, impl=self.event_impl)
        elif sparse:
            plan = noc.device_plan(prog.sinc, impl=impl)
        else:
            inc = jnp.asarray(prog.inc)
        # activity telemetry (identical keys + values in both exec modes):
        # per-link tier masks hoisted once, like the incidence.  Empty
        # tiers (a 1x1 board's zero-link xchip tier) are dropped so the
        # record keys — and the 1x1-board == single-chip bitwise
        # guarantee — don't depend on the NoC class.
        n_src = prog.sinc.n_sources
        tier_masks = {tier: jnp.asarray(m)
                      for tier, m in noc.tier_masks().items()
                      if np.asarray(m).any()}
        tree_links = jnp.asarray(prog.energy_tree_links, jnp.float32)
        static_pb = jnp.asarray(prog.payload_bits)
        # tiered (board) NoC: static per-link tier mask + per-source
        # chip-to-chip tree link counts, hoisted like the incidence.
        # A 1x1 board has no chip-to-chip tier — its records (and traced
        # ops) stay exactly the single-chip engine's, keeping the golden
        # anchor bitwise.
        tiered = getattr(noc, "n_xchip_links", 0) > 0
        if tiered:
            xmask = jnp.asarray(noc.xlink_mask, jnp.float32)
            tree_links_x = jnp.asarray(prog.tree_links_x, jnp.float32)

        def chip_tick(state, t):
            state, rec = tick(state, t)
            if learn is not None:
                lstate, lrec = learn(state["learn"], rec)
                state = {**state, "learn": lstate}
                rec.update(lrec)
            packets = rec["packets"].astype(jnp.float32)    # (P,)
            pb = rec.get("payload_bits", static_pb)
            if sparse and event:
                rec["link_load"], rec["link_flits"] = noc.event_noc_loads(
                    packets, plan, pb)
            elif sparse:
                rec["link_load"], rec["link_flits"] = noc.noc_loads(
                    packets, plan, pb)
            else:
                rec["link_load"] = noc.link_loads(packets, inc)
                rec["link_flits"] = noc.flit_loads(packets, inc, pb)
            rec["e_noc"] = noc.traffic_energy_j(packets, tree_links, pb)
            # activity telemetry — emitted by BOTH modes from the same
            # packet/load signals, so activity probes read identically
            active = (rec["packets"] > 0).sum(axis=-1).astype(jnp.int32)
            rec["active_sources"] = active
            rec["active_frac"] = active.astype(jnp.float32) / max(n_src, 1)
            hit = (rec["link_load"] > 0).astype(jnp.float32)
            rec["touched_links"] = hit.sum(axis=-1)
            for tier, m in tier_masks.items():
                rec[f"touched_links_{tier}"] = hit @ m
            if tiered:
                rec["load_xchip"] = (rec["link_load"] * xmask).sum(axis=-1)
                rec["flits_xchip"] = (rec["link_flits"] * xmask).sum(axis=-1)
                rec["e_noc_xchip"] = noc.xchip_energy_j(packets,
                                                        tree_links_x, pb)
            return state, rec

        return init, chip_tick

    def run(self, n_ticks: int, seed: int = 1, noc_mode: str | None = None,
            link_load_impl: str | None = None, exec_mode: str | None = None,
            probes=(), keep_records: bool = True) -> dict:
        """Per-tick records: everything the program's semantics reports
        (spike rasters / layer occupancy / decoded signals, PLs, Eq. (1)
        energies), plus the engine's NoC accounting:

        link_load  (T, n_links) — packets per link per tick
        link_flits (T, n_links) — DNoC flits per link per tick (graded
                                  multi-flit packets weigh more)
        e_noc      (T,)         — NoC traffic energy per tick [J]
        active_sources (T,)     — sources emitting >= 1 packet this tick
        active_frac (T,)        — active_sources / n_sources
        touched_links (T,) + touched_links_<tier> — links carrying any
                                  traffic this tick, total and per tier

        and, when the program has plastic projections (``learn_slots``),
        the learning tier: weights/traces advance in the scan carry each
        tick (``repro.learn.engine``) and

        e_learn    (T, P)       — per-PE learning energy per tick [J]
                                  (MAC-class weight updates + exp-
                                  accelerator trace decays)

        and, when the program's NoC is tiered (a board: on-chip links plus
        chip-to-chip links), the per-tier split:

        load_xchip / flits_xchip (T,) — packet/flit traversals of
                                  chip-to-chip links this tick
        e_noc_xchip (T,)        — chip-to-chip share of e_noc [J]

        ``noc_mode`` overrides the sim's representation choice per run;
        sparse and dense produce bit-identical records, as do the sparse
        kernels selected by ``link_load_impl``, as does the execution
        mode selected by ``exec_mode`` ("event" = activity-compressed
        tick + event NoC accounting; see the class docstring).  For the synfire program
        the neuron dynamics are the SAME tick function the single-chip
        path scans (``make_synfire_tick``), so an 8-PE ChipSim reproduces
        ``simulate_synfire`` rasters bit for bit.

        ``probes`` (``repro.obs.probes``: ProbeSpec instances or registry
        names) compiles strided/windowed telemetry accumulators into the
        scan carry, returned under ``recs["probes"]``.  The probe step
        runs AFTER the tick — it reads records, never state — so probed
        runs produce bit-identical per-tick records, and with the default
        ``probes=()`` the traced tick body (and carry) is EXACTLY the
        bare engine's.  ``keep_records=False`` (probed runs only) drops
        the full (T, ...) per-tick records and returns just the probe
        output — the memory-bounded mode for long board-scale runs.
        """
        prog = self.program
        init, chip_tick = self.make_stepper(seed=seed, noc_mode=noc_mode,
                                            link_load_impl=link_load_impl,
                                            exec_mode=exec_mode)

        if not probes:
            if not keep_records:
                raise ValueError("keep_records=False without probes would "
                                 "record nothing; pass probes=...")
            _, recs = jax.lax.scan(chip_tick, init, jnp.arange(n_ticks))
            return recs

        # telemetry: compile the probe accumulators into the scan carry
        # NEXT TO the workload state.  The probe step consumes the tick's
        # records and never feeds back into state, so probed runs stay
        # bit-identical to bare runs — only the carry grows.  (import
        # here: repro.obs reaches back into repro.chip for helpers)
        from repro.obs.probes import make_probe_step, resolve_probes
        specs = resolve_probes(prog, probes)
        rec_shapes = jax.eval_shape(
            chip_tick, init, jax.ShapeDtypeStruct((), jnp.int32))[1]
        obs0, probe_step, finalize = make_probe_step(specs, rec_shapes,
                                                     n_ticks)

        def probed_tick(carry, t):
            state, obs = carry
            state, rec = chip_tick(state, t)
            obs = probe_step(obs, rec, t)
            return (state, obs), (rec if keep_records else {})

        (_, obs), recs = jax.lax.scan(probed_tick, (init, obs0),
                                      jnp.arange(n_ticks))
        recs = dict(recs) if keep_records else {}
        recs["probes"] = finalize(obs)
        return recs


def chip_power_table(sim: ChipSim, recs: dict,
                     t_sys_s: float = 1e-3) -> dict:
    """Chip-level generalization of ``synfire_power_table``.

    per_pe     — the paper's Table III split (averaged over all PEs)
    chip       — the same, summed over the mesh [mW]
    noc        — average NoC power [mW], peak link load [packets/tick] and
                 [flits/tick], link utilization vs. capacity, worst
                 multicast hop depth
    """
    from repro.core.snn import synfire_power_table
    per_pe = synfire_power_table(recs, t_sys_s=t_sys_s)
    P = sim.program.n_pes
    chip = {mode: {k: v * P for k, v in per_pe[mode].items()}
            for mode in ("dvfs", "pl3")}

    loads = np.asarray(recs["link_load"])                  # (T, L)
    flits = np.asarray(recs.get("link_flits", loads))
    e_noc = np.asarray(recs["e_noc"])
    peak = float(sim.noc.congestion(loads).max()) if loads.size else 0.0
    peak_flits = float(sim.noc.congestion(flits).max()) if flits.size else 0.0
    cap = sim.noc.link_capacity_packets(t_sys_s, SPIKE_PACKET_BITS)
    # flit capacity: one flit per hop_cycles at the NoC clock
    cap_flits = t_sys_s * sim.noc.spec.freq_hz / sim.noc.spec.hop_cycles
    noc = {
        "power_mw": float(e_noc.mean() / t_sys_s * 1e3),
        "peak_link_load": peak,
        "mean_link_load": float(loads.mean()) if loads.size else 0.0,
        "peak_link_flits": peak_flits,
        "link_capacity": cap,                 # spike packets / tick
        "link_capacity_flits": cap_flits,     # basis of peak_utilization
        "peak_utilization": peak_flits / cap_flits,
        "worst_tree_hops": sim.program.worst_tree_hops,
        "worst_hop_latency_s": sim.noc.hop_latency_s(
            sim.program.worst_tree_hops),
        "n_links": sim.noc.n_links,
    }
    # tiered (board) NoC: split the accounting into on-chip vs
    # chip-to-chip shares — the headline number of the board benchmark
    if "flits_xchip" in recs:
        xmask = np.asarray(sim.noc.xlink_mask) > 0
        x_flits = float(np.asarray(recs["flits_xchip"]).sum())
        tot_flits = float(flits.sum())
        e_x = float(np.asarray(recs["e_noc_xchip"]).sum())
        e_tot = float(e_noc.sum())
        peak_x = (float(flits[:, xmask].max())
                  if xmask.any() and flits.size else 0.0)
        # the chip-to-chip tier has its own (slower) flit clock, so it
        # saturates long before its flit counts rival on-chip links
        xspec = sim.noc.xspec
        cap_x = t_sys_s * xspec.freq_hz / xspec.hop_cycles
        noc["xchip"] = {
            "n_links": int(xmask.sum()),
            "flits": x_flits,
            "flits_frac": x_flits / tot_flits if tot_flits else 0.0,
            "energy_frac": e_x / e_tot if e_tot else 0.0,
            "power_mw": float(np.asarray(recs["e_noc_xchip"]).mean()
                              / t_sys_s * 1e3),
            "peak_xlink_flits": peak_x,
            "link_capacity_flits": cap_x,
            "peak_utilization": peak_x / cap_x,
        }
        # tier-aware roll-ups: worst latency prices each tier at its own
        # hop cost (one real path's split — BoardProgram.path_hops), and
        # utilization is the worse of the two tiers' peaks vs their own
        # capacities (on-chip-only constants would understate the SerDes
        # tier by ~8x)
        peak_on = (float(flits[:, ~xmask].max())
                   if (~xmask).any() and flits.size else 0.0)
        noc["peak_utilization"] = max(peak_on / cap_flits, peak_x / cap_x)
        noc["worst_hop_latency_s"] = sim.program.worst_path_latency_s
    out = {"per_pe": per_pe, "chip": chip, "noc": noc,
           "n_pes": P, "mesh": (sim.program.mesh.width,
                                sim.program.mesh.height)}
    # on-mesh learning: e_learn share of the total chip energy (datapath
    # Eq. (1) terms + NoC traffic + learning) — the headline number of
    # the plasticity benchmark
    if "e_learn" in recs:
        e_l = np.asarray(recs["e_learn"])
        e_pe = sum(float(np.asarray(recs[k]).sum())
                   for k in ("e_dvfs_baseline", "e_dvfs_neuron",
                             "e_dvfs_synapse"))
        tot = e_pe + float(e_noc.sum()) + float(e_l.sum())
        out["learn"] = {
            "power_mw": float(e_l.sum(axis=-1).mean() / t_sys_s * 1e3),
            "energy_j": float(e_l.sum()),
            "energy_frac": float(e_l.sum()) / tot if tot else 0.0,
        }
    board = getattr(sim.program, "board", None)
    if board is not None:
        out["board"] = (board.chips_x, board.chips_y)
    return out
