"""``ChipSim`` — the workload-agnostic chip engine.

A virtual SpiNNaker2 chip: W x H QPE mesh of PEs running any compiled
``ChipProgram`` (SNN, DNN or hybrid — see ``repro.chip.graph`` /
``repro.chip.compile``) in one ``jax.lax.scan`` over 1 ms ticks.

The program's ``TickSemantics`` advances all PEs as batched axes of the
same arrays and reports per-PE activity (packets emitted, performance
level, Eq. (1) energy split); what the engine adds per tick is the NoC:
each source's packet count hits its precomputed multicast-tree incidence
— either the dense einsum over the (P, n_links) tensor or, once trees are
sparse relative to the mesh (the board-scale regime), a gather +
segment-sum over the CSR entries (``repro.kernels.link_load``) — yielding
per-link loads in packets AND in DNoC flits, so graded-payload
(multi-flit) packets are priced correctly, plus the energy/congestion
accounting from ``NocSpec``.  The representation is auto-selected from
the incidence shape — mesh size, density, per-link fan-in
(``noc_mode="auto"``; force with "dense"/"sparse") — both paths agree
bitwise on integer packet counts, and the incidence arrays are hoisted
onto the device once, outside the per-tick closure.
No per-source Python in the hot path, no per-workload branches in the
engine.

``chip_power_table`` generalizes ``synfire_power_table`` from one PE
average to the whole chip: per-PE table + chip totals + NoC power + the
SpiNNCer-style peak-link-load bottleneck check.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.chip.compile import ChipProgram
from repro.chip.mesh_noc import (DENSE_DENSITY, MAX_SPARSE_COLS,
                                 MIN_SPARSE_LINKS, MeshNoc, MeshSpec,
                                 SPIKE_PACKET_BITS)
from repro.core.dvfs import DVFSController
from repro.core.energy import PEEnergyModel


@dataclass
class ChipSim:
    """A compiled workload program on a full PE mesh.

    ``noc_mode`` selects the NoC accounting representation: "auto" picks
    sparse vs dense by incidence density, "sparse"/"dense" force it (the
    two agree bitwise — forcing is for benchmarks and golden tests).
    """
    program: ChipProgram
    dvfs: Optional[DVFSController] = None
    em: PEEnergyModel = field(default_factory=PEEnergyModel)
    noc_mode: str = "auto"

    def __post_init__(self):
        if self.dvfs is None:
            # workload semantics may carry their own FIFO thresholds (e.g.
            # a synfire net built with custom l_th1/l_th2); fall back to
            # the paper's Table II defaults
            sem = self.program.graph.semantics
            make = getattr(sem, "dvfs_controller", None)
            self.dvfs = make() if make else DVFSController()

    @property
    def noc(self) -> MeshNoc:
        return self.program.noc

    @staticmethod
    def synfire(n_pes: int = 8, mesh: MeshSpec | None = None, seed: int = 0,
                **build_kw) -> "ChipSim":
        """DEPRECATED shim: build + compile a synfire ring in one call.

        New code should go through the graph API
        (``workloads.synfire_graph`` -> ``compile`` -> ``ChipSim``); this
        constructor survives for the existing call sites and stays
        bit-identical to the paper's 8-PE test-chip benchmark.
        """
        from repro.chip.compile import compile as compile_graph
        from repro.chip.workloads import synfire_graph
        graph = synfire_graph(n_pes=n_pes, seed=seed, **build_kw)
        return ChipSim(program=compile_graph(graph, mesh))

    def use_sparse_noc(self, noc_mode: str | None = None) -> bool:
        """Resolve the accounting representation for this program.

        Auto requires a big-enough mesh (below ~256 PEs the dense einsum
        is a trivially small GEMV that wins on op overhead), a sparse
        incidence (density), AND a bounded per-link fan-in: the column
        plan unrolls one op per column, so an all-to-one graph — sparse
        by density — would still trace an O(P)-op tick body."""
        mode = noc_mode or self.noc_mode
        if mode not in ("auto", "sparse", "dense"):
            raise ValueError(f"unknown noc_mode {mode!r}")
        if mode == "auto":
            sinc = self.program.sinc
            return (sinc.n_links >= MIN_SPARSE_LINKS
                    and sinc.density <= DENSE_DENSITY
                    and sinc.max_fan_in <= MAX_SPARSE_COLS)
        return mode == "sparse"

    def run(self, n_ticks: int, seed: int = 1,
            noc_mode: str | None = None) -> dict:
        """Per-tick records: everything the program's semantics reports
        (spike rasters / layer occupancy / decoded signals, PLs, Eq. (1)
        energies), plus the engine's NoC accounting:

        link_load  (T, n_links) — packets per link per tick
        link_flits (T, n_links) — DNoC flits per link per tick (graded
                                  multi-flit packets weigh more)
        e_noc      (T,)         — NoC traffic energy per tick [J]

        ``noc_mode`` overrides the sim's representation choice per run;
        sparse and dense produce bit-identical records.  For the synfire
        program the neuron dynamics are the SAME tick function the
        single-chip path scans (``make_synfire_tick``), so an 8-PE ChipSim
        reproduces ``simulate_synfire`` rasters bit for bit.
        """
        prog = self.program
        tick = prog.make_tick(dvfs=self.dvfs, em=self.em,
                              key=jax.random.PRNGKey(seed))
        noc = self.noc
        # incidence onto the device ONCE, outside the per-tick closure
        sparse = self.use_sparse_noc(noc_mode)
        if sparse:
            cols, inv_perm = prog.sinc.device_col_plan()
        else:
            inc = jnp.asarray(prog.inc)
        tree_links = jnp.asarray(prog.tree_links, jnp.float32)  # (P,)
        static_pb = jnp.asarray(prog.payload_bits)

        def chip_tick(state, t):
            state, rec = tick(state, t)
            packets = rec["packets"].astype(jnp.float32)    # (P,)
            pb = rec.get("payload_bits", static_pb)
            if sparse:
                rec["link_load"], rec["link_flits"] = noc.noc_loads_sparse(
                    packets, cols, inv_perm, pb)
            else:
                rec["link_load"] = noc.link_loads(packets, inc)
                rec["link_flits"] = noc.flit_loads(packets, inc, pb)
            rec["e_noc"] = noc.traffic_energy_j(packets, tree_links, pb)
            return state, rec

        _, recs = jax.lax.scan(chip_tick, prog.init_state(),
                               jnp.arange(n_ticks))
        return recs


def chip_power_table(sim: ChipSim, recs: dict,
                     t_sys_s: float = 1e-3) -> dict:
    """Chip-level generalization of ``synfire_power_table``.

    per_pe     — the paper's Table III split (averaged over all PEs)
    chip       — the same, summed over the mesh [mW]
    noc        — average NoC power [mW], peak link load [packets/tick] and
                 [flits/tick], link utilization vs. capacity, worst
                 multicast hop depth
    """
    from repro.core.snn import synfire_power_table
    per_pe = synfire_power_table(recs, t_sys_s=t_sys_s)
    P = sim.program.n_pes
    chip = {mode: {k: v * P for k, v in per_pe[mode].items()}
            for mode in ("dvfs", "pl3")}

    loads = np.asarray(recs["link_load"])                  # (T, L)
    flits = np.asarray(recs.get("link_flits", loads))
    e_noc = np.asarray(recs["e_noc"])
    peak = float(sim.noc.congestion(loads).max()) if loads.size else 0.0
    peak_flits = float(sim.noc.congestion(flits).max()) if flits.size else 0.0
    cap = sim.noc.link_capacity_packets(t_sys_s, SPIKE_PACKET_BITS)
    # flit capacity: one flit per hop_cycles at the NoC clock
    cap_flits = t_sys_s * sim.noc.spec.freq_hz / sim.noc.spec.hop_cycles
    noc = {
        "power_mw": float(e_noc.mean() / t_sys_s * 1e3),
        "peak_link_load": peak,
        "mean_link_load": float(loads.mean()) if loads.size else 0.0,
        "peak_link_flits": peak_flits,
        "link_capacity": cap,                 # spike packets / tick
        "link_capacity_flits": cap_flits,     # basis of peak_utilization
        "peak_utilization": peak_flits / cap_flits,
        "worst_tree_hops": sim.program.worst_tree_hops,
        "worst_hop_latency_s": sim.noc.hop_latency_s(
            sim.program.worst_tree_hops),
        "n_links": sim.noc.n_links,
    }
    return {"per_pe": per_pe, "chip": chip, "noc": noc,
            "n_pes": P, "mesh": (sim.program.mesh.width,
                                 sim.program.mesh.height)}
