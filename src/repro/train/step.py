"""Train / serve step factories.

These are the functions the dry-run lowers against the production mesh and
the trainer executes on real hardware.  Microbatching (gradient
accumulation) runs as a ``lax.scan`` over microbatch slices with a single
optimizer application — collective traffic for the gradient all-reduce is
paid once per step regardless of the microbatch count (compute/comm overlap
is then XLA latency-hiding's job; see DESIGN.md section 5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.loopctl import scan_or_loop
from repro.optim import AdamWConfig, adamw_update, cosine_schedule


def make_train_step(cfg, *, opt=AdamWConfig(), microbatch: int = 1,
                    remat: str = "full", moe_dense: bool = False,
                    ce_chunk: int = 512, total_steps: int = 10_000,
                    warmup_steps: int = 100, mesh=None):
    """Returns train_step(params, opt_state, batch, step) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return T.train_loss(cfg, params, batch, moe_dense=moe_dense,
                            remat=remat, ce_chunk=ce_chunk, mesh=mesh)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def grads_of(params, batch):
        if microbatch <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return grads, metrics
        # split batch leading dim into microbatches and accumulate
        def slice_mb(x):
            B = x.shape[0]
            return x.reshape(microbatch, B // microbatch, *x.shape[1:])
        mbs = jax.tree.map(slice_mb, batch)

        def body(carry, mb):
            acc, msum = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            msum = jax.tree.map(jnp.add, msum, metrics)
            return (acc, msum), None

        zero_g = jax.tree.map(jnp.zeros_like, params)
        zero_m = {"loss": 0.0, "ce": 0.0, "lb_loss": 0.0, "z_loss": 0.0}
        zero_m = jax.tree.map(jnp.float32, zero_m)
        (grads, msum), _ = scan_or_loop(body, (zero_g, zero_m), mbs)
        grads = jax.tree.map(lambda g: g / microbatch, grads)
        metrics = jax.tree.map(lambda m: m / microbatch, msum)
        return grads, metrics

    def train_step(params, opt_state, batch, step):
        grads, metrics = grads_of(params, batch)
        lr = cosine_schedule(step, peak_lr=opt.lr, warmup_steps=warmup_steps,
                             total_steps=total_steps)
        params, opt_state, om = adamw_update(grads, opt_state, params, opt, lr=lr)
        metrics.update(om)
        metrics["lr"] = lr
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg, max_seq: int, *, moe_dense: bool = False,
                      mesh=None):
    def prefill_step(params, batch):
        return T.prefill(cfg, params, batch, max_seq, moe_dense=moe_dense,
                         mesh=mesh)
    return prefill_step


def make_decode_step(cfg, *, moe_dense: bool = False, mesh=None):
    def decode_step(params, caches, pos, batch):
        return T.decode_step(cfg, params, caches, pos, batch,
                             moe_dense=moe_dense, mesh=mesh)
    return decode_step
