"""Serving launcher: batched decode with queue-driven (DVFS-style) widths.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --smoke \
        --requests 12 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    import jax.numpy as jnp
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

    eng = ServeEngine(cfg, params, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                dtype=np.int32),
            max_new_tokens=args.max_new))
    t0 = time.time()
    stats = eng.run()
    dt = time.time() - t0
    print(f"served {args.requests} requests, {stats['tokens']} tokens in "
          f"{dt:.1f}s ({stats['tokens']/dt:.1f} tok/s)")
    print(f"rounds={stats['rounds']} batch widths={stats['batch_hist']} "
          f"(queue-DVFS levels: {eng.dvfs.batch_levels})")
    return stats


if __name__ == "__main__":
    main()
