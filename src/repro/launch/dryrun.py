import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # CPU-only artifact suppression: XLA:CPU converts bf16 dot operands to
    # f32 and LICM hoists whole-cache converts out of the layer scan, which
    # would falsely dominate the memory analysis (a TPU bf16 MXU dot has no
    # such convert).  Keeping the convert inside the loop makes
    # memory_analysis faithful to the TPU target.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
)

"""Multi-pod dry-run driver.

For every (architecture x input shape) cell, lower + compile the step
function against the production mesh (single-pod 16x16 and multi-pod
2x16x16), print memory/cost analysis, derive roofline terms and write a
JSON artifact under artifacts/dryrun/.

The two os.environ lines above MUST precede every other import (jax locks
the device count on first init), which is why this module sets XLA_FLAGS
before importing anything else.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single --out artifacts/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro import roofline as RL
from repro.dist.cells import make_cell
from repro.launch.mesh import make_production_mesh
from repro.models.loopctl import unrolled


def _variant_cost(cfg, shape, mesh, k: int) -> tuple:
    """Lower an UNROLLED k-group variant and return (flops, bytes, coll).

    cost_analysis() counts while-loop bodies once; unrolled variants with
    1 and 2 layer-groups give exact per-group deltas for linear
    extrapolation to the full depth (layer groups are homogeneous)."""
    vcfg = dataclasses.replace(
        cfg, num_layers=cfg.pattern_len * k + len(cfg.rem_layers))
    cell = make_cell(vcfg, shape, mesh)
    with mesh, unrolled():
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate_argnums
                           ).lower(*cell.args).compile()
    cost = compiled.cost_analysis()
    coll = RL.parse_collective_bytes(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll)


def extrapolated_costs(cfg, shape, mesh) -> tuple:
    """(flops, bytes, coll_dict) extrapolated to the full group count."""
    G = cfg.num_groups
    f1, b1, c1 = _variant_cost(cfg, shape, mesh, 1)
    f2, b2, c2 = _variant_cost(cfg, shape, mesh, 2)
    scale = lambda a2, a1: a2 + (G - 2) * (a2 - a1)
    coll = {k: scale(c2.get(k, 0), c1.get(k, 0)) for k in c2}
    coll["total"] = sum(v for k, v in coll.items()
                        if k not in ("count", "total"))
    return scale(f2, f1), scale(b2, b1), coll


def run_cell(arch_name: str, shape_name: str, multi_pod: bool, out_dir: Path,
             *, save_hlo: bool = False, roofline: bool = True) -> dict:
    cfg = configs.get_arch(arch_name)
    shape = configs.SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch_name}_{shape_name}_{mesh_name}".replace("/", "-")
    out_path = out_dir / f"{tag}.json"
    t0 = time.time()
    record = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name,
              "status": "error"}
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = make_cell(cfg, shape, mesh)
        with mesh:
            jitted = jax.jit(cell.fn,
                             in_shardings=cell.in_shardings,
                             out_shardings=cell.out_shardings,
                             donate_argnums=cell.donate_argnums)
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        mem_stats = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": (mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
        }
        chips = mesh.devices.size
        mflops = RL.model_flops(cfg, shape)
        # roofline terms from trip-count-exact unrolled extrapolation
        t1 = time.time()
        if roofline:
            flops_x, bytes_x, coll_x = extrapolated_costs(cfg, shape, mesh)
        else:   # multi-pod pass: compile/memory proof only (see DESIGN.md)
            flops_x = float(cost.get("flops", 0.0))
            bytes_x = float(cost.get("bytes accessed", 0.0))
            coll_x = RL.parse_collective_bytes(hlo)
        t_roofline = time.time() - t1
        roof = RL.analyze(arch_name, shape_name, mesh_name, chips, flops_x,
                          bytes_x, coll_x, mflops, mem_stats)
        record.update(dataclasses.asdict(roof))
        record.update({
            "status": "ok",
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "roofline_s": round(t_roofline, 1),
            "hlo_bytes": len(hlo),
            "flops_scan_raw": float(cost.get("flops", 0.0)),
            "collectives_scan_raw": RL.parse_collective_bytes(hlo)["total"],
        })
        if save_hlo:
            (out_dir / f"{tag}.hlo.txt").write_text(hlo)
        print(f"[OK] {tag}: flops/dev={roof.flops:.3e} "
              f"bytes/dev={roof.bytes_accessed:.3e} "
              f"coll/dev={roof.collective_bytes:.3e} "
              f"dom={roof.dominant} "
              f"peakmem={mem_stats['peak_estimate_bytes']/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
              flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"[FAIL] {tag}: {record['error'][:400]}", flush=True)
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(record, indent=1))
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-roofline", action="store_true",
                    help="compile/memory proof only (multi-pod pass)")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = configs.ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = list(configs.SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch_name in archs:
        cfg = configs.get_arch(arch_name)
        for shape_name in shapes:
            shape = configs.SHAPES[shape_name]
            if not configs.shape_applicable(cfg, shape):
                print(f"[SKIP] {arch_name} x {shape_name}: "
                      f"not sub-quadratic (see DESIGN.md)", flush=True)
                n_skip += 1
                continue
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                tag = f"{arch_name}_{shape_name}_{mesh_name}"
                if args.skip_existing and (out_dir / f"{tag}.json").exists():
                    prev = json.loads((out_dir / f"{tag}.json").read_text())
                    if prev.get("status") == "ok":
                        n_skip += 1
                        continue
                rec = run_cell(arch_name, shape_name, multi, out_dir,
                               save_hlo=args.save_hlo,
                               roofline=not args.no_roofline)
                if rec["status"] == "ok":
                    n_ok += 1
                else:
                    n_fail += 1
    print(f"dry-run done: ok={n_ok} fail={n_fail} skip={n_skip}", flush=True)


if __name__ == "__main__":
    main()
