"""Neuromorphic fleet launcher: vmapped chip/board instances serving a
Poisson session stream with queue-driven (DVFS-style) fleet widths.

    PYTHONPATH=src python -m repro.launch.fleet --scenario adaptive \
        --fleet 16 --sessions 24 --rate 4

Add ``--board 2x1`` to compile the served program across a chip grid,
and ``--ckpt-dir PATH`` to checkpoint evicted sessions to disk instead
of in-memory snapshots.
"""
from __future__ import annotations

import argparse
import time

from repro.core.dvfs import QueueDVFS
from repro.serve.fleet import FleetEngine, PoissonTraffic, SCENARIOS


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="adaptive",
                    choices=sorted(SCENARIOS))
    ap.add_argument("--fleet", type=int, default=16,
                    help="top batch level (ladder = fleet/4, fleet/2, fleet)")
    ap.add_argument("--sessions", type=int, default=24)
    ap.add_argument("--rate", type=float, default=4.0,
                    help="expected session arrivals per scheduling round")
    ap.add_argument("--round-ticks", type=int, default=64)
    ap.add_argument("--min-ticks", type=int, default=128)
    ap.add_argument("--max-ticks", type=int, default=384)
    ap.add_argument("--board", default=None,
                    help="compile across a chip grid, e.g. 2x1")
    ap.add_argument("--chip", default="2x2")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint evicted sessions here (else in-memory)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    sc = SCENARIOS[args.scenario]()
    board = None
    if args.board:
        from repro.board import BoardSpec
        board = BoardSpec.parse(args.board, chip=args.chip)
    lo, mid = max(1, args.fleet // 4), max(1, args.fleet // 2)
    eng = FleetEngine(
        sc, round_ticks=args.round_ticks, board=board,
        ckpt_dir=args.ckpt_dir, keep_outputs=False,
        dvfs=QueueDVFS(thresholds=(max(2, lo // 2), max(3, mid // 2)),
                       batch_levels=(lo, mid, args.fleet)))
    traffic = PoissonTraffic(rate=args.rate, n_sessions=args.sessions,
                             tick_range=(args.min_ticks, args.max_ticks),
                             seed=args.seed)
    t0 = time.time()
    stats = eng.serve(traffic)["stats"]
    dt = time.time() - t0
    where = f"board {args.board}" if args.board else "chip"
    print(f"served {stats['completed']} {args.scenario} sessions on {where} "
          f"in {dt:.1f}s ({stats['sessions_per_s']:.1f} sessions/s)")
    print(f"rounds={stats['rounds']} fleet widths={stats['width_hist']} "
          f"(queue-DVFS levels: {eng.dvfs.batch_levels})")
    print(f"request p50/p99 {stats['request_latency_s']['p50']:.2f}/"
          f"{stats['request_latency_s']['p99']:.2f}s, "
          f"{stats['joules_per_request'] * 1e3:.2f} mJ/request, "
          f"{stats['preemptions']} preemptions")
    return stats


if __name__ == "__main__":
    main()
