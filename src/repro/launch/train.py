"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \
        --steps 200 --batch 8 --seq 128

--smoke runs the mechanically reduced config on the host devices; without
it the full config is built (requires real accelerators for execution; use
launch/dryrun.py to validate compilation against the production mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import PipelineConfig, SyntheticTokenPipeline
from repro.ft.loop import FaultTolerantLoop, LoopConfig
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--moe-dense", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_arch(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    mesh = make_host_mesh()
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)
    pipe = SyntheticTokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        kind="frames" if cfg.frontend == "encodec" else "tokens",
        d_model=cfg.d_model, num_codebooks=cfg.num_codebooks))

    step_fn = jax.jit(make_train_step(
        cfg, opt=AdamWConfig(lr=args.lr), microbatch=args.microbatch,
        remat="full", moe_dense=args.moe_dense, ce_chunk=min(args.seq, 512),
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 10)),
        donate_argnums=(0, 1))

    ckpt = CheckpointManager(f"{args.ckpt_dir}/{cfg.name}")
    loop = FaultTolerantLoop(
        LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                   install_signal_handlers=True),
        ckpt, step_fn, pipe)

    t0 = time.time()
    state, log = loop.run(params, opt_state)
    for rec in log:
        if rec["step"] % args.log_every == 0 or rec["step"] == args.steps - 1:
            print(f"step {rec['step']:5d} loss {rec['loss']:.4f} "
                  f"({rec['dt']*1e3:.0f} ms)")
    dt = time.time() - t0
    if log:
        first = sum(r["loss"] for r in log[:10]) / max(len(log[:10]), 1)
        last = sum(r["loss"] for r in log[-10:]) / max(len(log[-10:]), 1)
        print(f"done in {dt:.1f}s; loss {first:.4f} -> {last:.4f}")
        return {"first": first, "last": last, "log": log}
    return {}


if __name__ == "__main__":
    main()
