"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run forces 512 host-platform devices while tests/benches must see 1.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(model_axis: int | None = None):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    m = model_axis or (2 if n % 2 == 0 and n > 1 else 1)
    d = n // m
    return jax.make_mesh(
        (d, m), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
