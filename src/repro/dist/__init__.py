from repro.dist import sharding  # noqa: F401
