"""Dry-run cells: one (architecture x shape) combination = one ``Cell``.

A cell packages everything ``jax.jit(...).lower(...).compile()`` needs to
prove a step function against a production mesh WITHOUT real weights:

    cell = make_cell(cfg, shape, mesh)
    jax.jit(cell.fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums).lower(*cell.args).compile()

``cell.args`` are abstract ``ShapeDtypeStruct`` trees, so lowering a 42B
config costs graph construction only.  Shardings come from the logical-axis
tables in ``repro.dist.sharding``: parameters (and their AdamW moments —
ZeRO) through ``PARAM_RULES``, batches over the data axes, KV caches via
``cache_spec``.  This mirrors the SpiNNaker2 mapping problem one level up:
``repro.chip.compile`` places population tiles on PEs, ``make_cell`` places
tensor dims on mesh axes.

Used by ``repro.launch.dryrun`` (the full grid), ``scripts/diag_cell.py``
and ``scripts/hillclimb.py`` (single-cell iteration).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import sharding as SH
from repro.models import transformer as T
from repro.train.step import (make_decode_step, make_prefill_step,
                              make_train_step)

# per-arch gradient-accumulation override (scripts/hillclimb.py pokes this)
TRAIN_MICROBATCH: dict[str, int] = {}


@dataclass(frozen=True)
class Cell:
    """A jit-ready step closure plus its abstract args and shardings."""
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple = ()


# ---------------------------------------------------------------------------
# Sharding resolution
# ---------------------------------------------------------------------------

def _param_shardings(cfg, mesh, rules=None):
    """Parameter tree -> NamedSharding tree via the logical-axis tables.

    Each PSpec leaf carries logical dim names (repro.models.layers); they
    resolve greedily through ``rules`` (default ``SH.PARAM_RULES``) with
    divisibility checks, so any mesh — including a (1, 1) elastic-restore
    mesh — yields a valid placement.
    """
    if rules is None:
        rules = SH.PARAM_RULES
    shapes = T.abstract_params(cfg)
    axes = T.param_logical_axes(cfg)
    flat_s, treedef = jax.tree.flatten(shapes)
    flat_a = treedef.flatten_up_to(axes)
    shards = [NamedSharding(mesh, SH.spec_for(s.shape, a, mesh, rules=rules))
              for s, a in zip(flat_s, flat_a)]
    return jax.tree.unflatten(treedef, shards)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def _dim_spec(shape, mesh, wants: dict) -> P:
    """PartitionSpec sharding dim i over wants[i] when divisible."""
    entries: list = [None] * len(shape)
    used: set = set()
    for d, ax in wants.items():
        if (ax in mesh.shape and ax not in used and shape[d] > 1
                and shape[d] % mesh.shape[ax] == 0):
            entries[d] = ax
            used.add(ax)
    return P(*entries)


def _cache_shardings(cfg, batch, max_seq, mesh, dtype=jnp.bfloat16):
    """Sharding tree parallel to ``transformer.cache_specs``."""
    def attn_like(kind, off):
        S = (min(max_seq, cfg.window_size)
             if kind == "local" and cfg.window_size else max_seq)
        shape = (cfg.num_groups,) * off + (batch, S, cfg.num_kv_heads,
                                           cfg.head_dim)
        ns = NamedSharding(mesh, SH.cache_spec(
            shape, mesh, batch_dim=off, seq_dim=off + 1, kv_dim=off + 2))
        return {"k": ns, "v": ns}

    def block(kind, off):
        if kind in ("attn", "local"):
            return attn_like(kind, off)
        if kind == "rglru":
            w = cfg.lru_width or cfg.d_model
            conv = (cfg.num_groups,) * off + (batch, cfg.conv_width - 1, w)
            state = (cfg.num_groups,) * off + (batch, w)
            return {
                "conv": NamedSharding(mesh, _dim_spec(
                    conv, mesh, {off: "data", off + 2: "model"})),
                "state": NamedSharding(mesh, _dim_spec(
                    state, mesh, {off: "data", off + 1: "model"})),
            }
        if kind == "rwkv":
            d = cfg.d_model
            H = d // cfg.rwkv_head_size
            shift = (cfg.num_groups,) * off + (batch, 1, d)
            state = (cfg.num_groups,) * off + (batch, H,
                                               cfg.rwkv_head_size,
                                               cfg.rwkv_head_size)
            shift_ns = NamedSharding(mesh, _dim_spec(
                shift, mesh, {off: "data", off + 2: "model"}))
            return {
                "tmix": {"shift": shift_ns,
                         "state": NamedSharding(mesh, _dim_spec(
                             state, mesh, {off: "data", off + 1: "model"}))},
                "cmix": {"shift": shift_ns},
            }
        raise ValueError(kind)

    return {
        "groups": [block(kind, 1) for kind in cfg.layer_pattern],
        "rem": [block(kind, 0) for kind in cfg.rem_layers],
    }


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _abstract_batch(cfg, shape, *, kind: str):
    """ShapeDtypeStruct batch + its data-parallel shardings."""
    B = shape.global_batch
    if kind == "train":
        S = shape.seq_len
        if cfg.frontend == "none":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
        else:
            # modality frontends are stubbed: the backbone sees frames +
            # per-codebook labels (repro.models.transformer.train_loss)
            batch = {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                               jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S, cfg.num_codebooks),
                                               jnp.int32),
            }
    else:
        S = shape.seq_len if kind == "prefill" else 1
        if cfg.frontend == "none":
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        else:
            batch = {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                    jnp.bfloat16)}
    return batch


def _batch_shardings(batch, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, SH.data_spec(s.shape, mesh)), batch)


def _opt_abstract(params_abs):
    return {"mu": params_abs, "nu": params_abs,
            "count": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# Cells
# ---------------------------------------------------------------------------

def _train_cell(cfg, shape, mesh) -> Cell:
    params_abs = T.abstract_params(cfg)
    pshard = _param_shardings(cfg, mesh)
    batch = _abstract_batch(cfg, shape, kind="train")
    fn = make_train_step(
        cfg, microbatch=TRAIN_MICROBATCH.get(cfg.name, 1), mesh=mesh)
    args = (params_abs, _opt_abstract(params_abs), batch,
            jax.ShapeDtypeStruct((), jnp.int32))
    oshard = {"mu": pshard, "nu": pshard, "count": _replicated(mesh)}
    metrics = _replicated(mesh)
    return Cell(
        fn=fn, args=args,
        in_shardings=(pshard, oshard, _batch_shardings(batch, mesh),
                      _replicated(mesh)),
        out_shardings=(pshard, oshard, metrics),
        donate_argnums=(0, 1),
    )


def _prefill_cell(cfg, shape, mesh) -> Cell:
    params_abs = T.abstract_params(cfg)
    pshard = _param_shardings(cfg, mesh)
    batch = _abstract_batch(cfg, shape, kind="prefill")
    max_seq = shape.seq_len
    fn = make_prefill_step(cfg, max_seq, mesh=mesh)
    cshard = _cache_shardings(cfg, shape.global_batch, max_seq, mesh)
    return Cell(
        fn=fn, args=(params_abs, batch),
        in_shardings=(pshard, _batch_shardings(batch, mesh)),
        out_shardings=(_replicated(mesh), cshard),
        donate_argnums=(),
    )


def _decode_cell(cfg, shape, mesh) -> Cell:
    params_abs = T.abstract_params(cfg)
    pshard = _param_shardings(cfg, mesh)
    batch = _abstract_batch(cfg, shape, kind="decode")
    max_seq = shape.seq_len
    caches_abs = T.cache_specs(cfg, shape.global_batch, max_seq)
    cshard = _cache_shardings(cfg, shape.global_batch, max_seq, mesh)
    fn = make_decode_step(cfg, mesh=mesh)
    return Cell(
        fn=fn,
        args=(params_abs, caches_abs, jax.ShapeDtypeStruct((), jnp.int32),
              batch),
        in_shardings=(pshard, cshard, _replicated(mesh),
                      _batch_shardings(batch, mesh)),
        out_shardings=(_replicated(mesh), cshard),
        donate_argnums=(1,),
    )


def make_cell(cfg, shape, mesh) -> Cell:
    """Build the (arch x shape) dry-run cell for ``mesh``."""
    kind = shape.kind
    if kind == "train":
        return _train_cell(cfg, shape, mesh)
    if kind == "prefill":
        return _prefill_cell(cfg, shape, mesh)
    if kind == "decode":
        return _decode_cell(cfg, shape, mesh)
    raise ValueError(f"unknown shape kind {kind!r}")
