"""GPipe-style pipeline parallelism over one mesh axis.

``pipeline_forward`` shards a stack of homogeneous stage params over the
``axis`` devices and streams microbatches through them: device *s* runs
stage *s*, passing activations to device *s+1* with a collective permute
each schedule step.  The fill/drain schedule runs ``n_micro + S - 1``
steps; invalid (bubble) slots compute but are masked out of the result.

Semantics are exactly sequential: ``for s: x = stage_fn(params[s], x)``
applied microbatch-wise — verified against that reference in
tests/test_pipeline.py on a forced 4-device host platform.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_forward(stage_fn, stage_params, x, mesh, *, axis: str = "pod"):
    """Run ``x`` (n_micro, batch, d) through ``stage_params`` (S, ...).

    Stage outputs must have the same shape as stage inputs (homogeneous
    trunk), which is what makes the stack a pipeline.  Returns the
    (n_micro, batch, d) outputs of the final stage, replicated.
    """
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]

    if n_stages == 1:
        def seq(xm):
            for s in range(stage_params.shape[0]):
                xm = stage_fn(stage_params[s], xm)
            return xm
        return jax.vmap(seq)(x)

    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def local(w_local, xs):
        s = jax.lax.axis_index(axis)
        w = w_local[0]                              # this device's stage
        out = jnp.zeros_like(xs)
        recv = jnp.zeros_like(xs[0])
        for t in range(n_micro + n_stages - 1):
            m = t - s                               # microbatch at stage s
            feed = xs[min(t, n_micro - 1)]          # stage 0 reads inputs
            inp = jnp.where(s == 0, feed, recv)
            y = stage_fn(w, inp)
            valid = (m >= 0) & (m < n_micro)
            mi = jnp.clip(m, 0, n_micro - 1)
            out = out.at[mi].set(jnp.where(valid, y, out[mi]))
            recv = jax.lax.ppermute(y, axis, perm)
        # only the last stage's outputs are the pipeline result
        keep = jnp.where(s == n_stages - 1, 1.0, 0.0).astype(out.dtype)
        return jax.lax.psum(out * keep, axis)

    return jax.shard_map(local, mesh=mesh, in_specs=(P(axis), P()),
                         out_specs=P(), check_vma=False)(stage_params, x)
