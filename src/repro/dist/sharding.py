"""Logical-axis sharding rules (t5x-style) for the framework layer.

Each tensor dimension carries a logical name; ``RULES`` lists the mesh axes
that dimension may shard over, in preference order.  ``spec_for`` resolves a
shape to a PartitionSpec greedily: a mesh axis is used at most once per spec
and only when it divides the dimension — otherwise the dim replicates.

This mirrors the SpiNNaker2 mapping problem one level up: populations ->
PEs there, tensor dims -> mesh axes here (see repro.chip.mapping).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# logical dim name -> mesh axes it may occupy, in preference order
RULES: dict[str, tuple[str, ...]] = {
    "batch": ("data",),
    "embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv": ("model",),
    "vocab": ("model", "data"),
    "seq": ("data", "model"),
    "expert": ("model", "data"),
}

# Parameter placement (train cells): FSDP over "data" on the embed dim,
# tensor-parallel over "model" on the contraction-free dim, layer-stack
# and small table dims replicated.  ``repro.dist.cells._param_shardings``
# resolves each PSpec's logical axes through this table.
PARAM_RULES: dict[str, tuple[str, ...]] = {
    "embed": ("data",),
    "vocab": ("model", "data"),
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("model", "data"),
    "layer": (),
    "vocab_tbl": (),
    "embed_tbl": ("model",),
}


def _axis_size(mesh, axes) -> int:
    """Product of the mesh extents of ``axes`` (str or iterable of str)."""
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for(shape, names, mesh, rules=None) -> P:
    """Resolve (shape, logical names) -> PartitionSpec over ``mesh``.

    Greedy, never reuses a mesh axis, and only shards a dim whose size is
    divisible by the axis extent.  ``rules`` defaults to the activation
    table ``RULES``; pass ``PARAM_RULES`` for parameter placement.
    """
    if rules is None:
        rules = RULES
    used: set[str] = set()
    entries = []
    for dim, name in zip(shape, names):
        pick = None
        for ax in rules.get(name, ()):
            if ax in used or ax not in mesh.shape:
                continue
            if dim % mesh.shape[ax] == 0:
                pick = ax
                used.add(ax)
                break
        entries.append(pick)
    return P(*entries)


def batch_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that carry the data-parallel (batch) dimension."""
    return tuple(a for a in ("pod", "data") if a in getattr(mesh, "shape", {}))


def data_spec(shape, mesh, batch_dim: int = 0) -> P:
    """Global-batch placement: shard the batch dim over the data axes (when
    divisible), replicate everything else."""
    entries: list = [None] * len(shape)
    ba = [a for a in batch_axes(mesh) if mesh.shape[a] > 1]
    if ba and shape[batch_dim] % _axis_size(mesh, ba) == 0:
        entries[batch_dim] = ba[0] if len(ba) == 1 else tuple(ba)
    return P(*entries)


def act_hint(x, mesh, names):
    """Activation sharding hint: resolve logical ``names`` (None = replicate)
    to a PartitionSpec over ``mesh`` and apply a with_sharding_constraint.

    Elements may be logical dim names (resolved through RULES) or literal
    mesh axis names.  A dim not divisible by its axis extent replicates —
    hints must never make a program unshardable.  No-op without a mesh.
    """
    if mesh is None:
        return x
    used: set[str] = set()
    entries = []
    for dim, name in zip(x.shape, names):
        pick = None
        if name is not None:
            cands = RULES.get(name, (name,) if name in mesh.shape else ())
            for ax in cands:
                if ax in used or ax not in mesh.shape:
                    continue
                if dim % mesh.shape[ax] == 0:
                    pick = ax
                    used.add(ax)
                    break
        entries.append(pick)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def cache_spec(shape, mesh, *, batch_dim, seq_dim, kv_dim) -> P:
    """KV-cache layout: batch -> data, kv-heads -> model, and the sequence
    dim greedily absorbs whatever axes remain (growing a tuple while the
    combined extent still divides the sequence length)."""
    entries: list = [None] * len(shape)
    used: set[str] = set()

    if "data" in mesh.shape and shape[batch_dim] % mesh.shape["data"] == 0 \
            and shape[batch_dim] > 1:
        entries[batch_dim] = "data"
        used.add("data")
    if "model" in mesh.shape and shape[kv_dim] % mesh.shape["model"] == 0 \
            and shape[kv_dim] > 1:
        entries[kv_dim] = "model"
        used.add("model")

    leftover = [a for a in mesh.shape if a not in used]
    taken: list[str] = []
    for ax in leftover:
        cand = taken + [ax]
        if shape[seq_dim] % _axis_size(mesh, cand) == 0:
            taken = cand
    if len(taken) == 1:
        entries[seq_dim] = taken[0]
    elif taken:
        entries[seq_dim] = tuple(taken)
    return P(*entries)
