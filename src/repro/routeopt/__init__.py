"""Profile-guided routing & placement: a closed-loop congestion
optimizer over the board compiler's free routing parameters.

The compiler's default routes are legal but blind: every multicast tree
is X-then-Y and every chip-to-chip hop crosses the one mid-edge border
port, so hot sources pile onto the same SerDes links — BENCH_pr4 showed
the chip-to-chip tier carrying 42.9% of flits but 90.4% of NoC energy.
This package closes the loop the telemetry PRs opened:

    simulate -> probe -> re-route / re-place -> re-compile -> re-simulate

``measure_profile`` turns one probed run into a ``TrafficProfile``
(per-link peak/mean flits split at the tier boundary, per-source packet
rates, per-tier touched-link counts — all in-scan ``ProbeSpec``
reductions, O(n_links) memory).  ``optimize_routes`` then iterates:
re-partition with measured rates, pick each population's tree
orientations (X/Y vs Y/X, on-chip and at chip granularity) and spread
its chip-to-chip exits across multiple border ports against the
predicted residual load, re-compile, re-measure, and stop when the
measured peak stops improving (or the iteration / wall-clock budget
runs out).

Routing never changes neuron dynamics: packets ride the routing-table
masks, incidence only prices links — so every candidate is bitwise
neuron-identical by construction (``invariants.check_delivery`` proves
the flit-conservation half; the test suite asserts the record half).
"""
# Lazy re-exports (PEP 562): ``repro.board.route`` imports
# ``repro.routeopt.config`` while ``repro.routeopt.optimize`` imports
# ``repro.board.route`` back — resolving attributes on first touch
# keeps the package importable from either side of that edge.
_EXPORTS = {
    "RouteConfig": "repro.routeopt.config",
    "TrafficProfile": "repro.routeopt.profile",
    "measure_profile": "repro.routeopt.profile",
    "RouteOptResult": "repro.routeopt.optimize",
    "optimize_routes": "repro.routeopt.optimize",
    "check_delivery": "repro.routeopt.invariants",
}
__all__ = list(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
