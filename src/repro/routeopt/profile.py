"""One probed simulation -> one ``TrafficProfile``.

The optimizer steers by measurement, and ALL of it rides the in-scan
probe API (``repro.obs``): per-link peak/mean flit loads
(``link_flits`` probes, O(n_links) memory however long the run),
per-source packet rates (``packets`` probes — the partition re-weights
and the load predictor both consume these), and per-tier touched-link
counts (PR 8's ``activity`` signals).  ``keep_records=False``
throughout: no (T, n_links) timeline ever materializes.

The load-bearing physics: packet emission depends only on neuron
dynamics, which routing cannot touch (packets ride the routing-table
masks; incidence only prices links) — so measured source rates are
ROUTING-INVARIANT, and mean link loads are exactly linear in them:

    mean_flits[link] = sum over sources whose tree crosses the link of
                       mean_packets[source] * flits_per_packet[source]

That identity is what lets ``optimize.predicted_loads`` score a
candidate routing exactly (for the mean profile) without simulating it.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.obs.probes import ProbeSpec, link_profile_probes


@dataclass
class TrafficProfile:
    """Measured per-link and per-source traffic of one run."""
    peak: np.ndarray          # (n_links,) peak flits in any tick
    mean: np.ndarray          # (n_links,) mean flits per tick
    src_mean: np.ndarray      # (P,) mean packets/tick per source PE
    src_peak: np.ndarray      # (P,) peak packets in any tick
    touched: dict             # tier -> mean touched links per tick
    n_onchip_links: int       # tier boundary in the link-id space
    n_ticks: int

    @property
    def n_xchip_links(self) -> int:
        return len(self.peak) - self.n_onchip_links

    @property
    def peak_xlink(self) -> float:
        """Peak flits on any chip-to-chip link — THE congestion gate."""
        return float(self.peak[self.n_onchip_links:].max(initial=0.0))

    @property
    def mean_xlink(self) -> float:
        x = self.mean[self.n_onchip_links:]
        return float(x.mean()) if x.size else 0.0

    @property
    def peak_onchip(self) -> float:
        return float(self.peak[:self.n_onchip_links].max(initial=0.0))

    @property
    def peak_overall(self) -> float:
        return float(self.peak.max(initial=0.0))

    def objective(self) -> float:
        """What the optimizer minimizes: the chip-to-chip peak when the
        board has that tier, the overall peak otherwise (1x1 boards)."""
        return self.peak_xlink if self.n_xchip_links else self.peak_overall

    def pop_rates(self, pe_slices: dict) -> dict:
        """Population -> measured packets/tick summed over its tiles —
        the drop-in replacement for the partitioner's static
        every-tile-fires estimate.  ``pe_slices`` ordering is partition-
        independent (graph order), so rates measured under one placement
        re-weight any other."""
        return {name: float(self.src_mean[sl].sum())
                for name, sl in pe_slices.items()}

    def summary(self) -> dict:
        """The trajectory row committed per iteration in BENCH_pr9."""
        out = {"peak_xlink_flits": round(self.peak_xlink, 2),
               "mean_xlink_flits": round(self.mean_xlink, 4),
               "peak_onchip_flits": round(self.peak_onchip, 2),
               "peak_flits": round(self.peak_overall, 2)}
        for tier, v in self.touched.items():
            out[f"touched_links_{tier}"] = round(v, 2)
        return out


def profile_probes(program) -> tuple:
    """The full measurement set: link peak/mean + per-source packet
    rates + per-tier touched-link counts (empty tiers emit none, same
    rule as the ``activity`` registry set)."""
    specs = list(link_profile_probes())
    specs += [ProbeSpec("src_packets_mean", "packets", "mean"),
              ProbeSpec("src_packets_peak", "packets", "peak")]
    for tier, m in program.noc.tier_masks().items():
        if np.asarray(m).any():
            specs.append(ProbeSpec(f"touched_{tier}",
                                   f"touched_links_{tier}", "mean"))
    return tuple(specs)


def measure_profile(sim, n_ticks: int, **run_kw) -> TrafficProfile:
    """Run ``sim`` for ``n_ticks`` with the profile probe set (records
    dropped, probes only) and fold the output into a
    ``TrafficProfile``."""
    program = sim.program
    recs = sim.run(n_ticks, probes=profile_probes(program),
                   keep_records=False, **run_kw)
    po = recs["probes"]
    noc = program.noc
    touched = {}
    for tier, m in noc.tier_masks().items():
        if np.asarray(m).any():
            touched[tier] = float(np.asarray(po[f"touched_{tier}"])[-1])
    return TrafficProfile(
        peak=np.asarray(po["link_flits_peak"])[-1],
        mean=np.asarray(po["link_flits_mean"])[-1],
        src_mean=np.asarray(po["src_packets_mean"])[-1],
        src_peak=np.asarray(po["src_packets_peak"])[-1],
        touched=touched,
        n_onchip_links=int(getattr(noc, "n_onchip_links", noc.n_links)),
        n_ticks=n_ticks)
