"""The closed loop: simulate -> probe -> re-route/re-place -> re-compile
-> re-simulate, until the measured peak stops improving.

Per iteration:

1. **re-place** — re-run the min-cut partitioner with MEASURED
   per-population packet rates (``TrafficProfile.pop_rates``) instead of
   the static every-tile-fires estimate, so hot populations migrate off
   congested cut edges;
2. **re-route** — a greedy sweep over populations in descending measured
   flow: each one tries all four (chip-tree x on-chip-tree) orientation
   combos with a least-loaded border-port assignment per chip-to-chip
   exit, scored EXACTLY against the predicted mean load of everyone
   else's current routes (mean link loads are linear in the measured —
   and routing-invariant — source rates, so the predictor is the
   measurement, not a model);
3. **re-compile + re-simulate** — ``compile_board(route=...)`` then
   ``measure_profile``, appending one trajectory row (peak/mean per
   tier, compile/measure wall-clock, cut weight).

The loop keeps the best program by MEASURED objective (peak
chip-to-chip flits; overall peak on a 1x1 board) and stops when the
relative improvement drops below ``eps``, or the iteration /
wall-clock budget runs out.  ``max_iters=0`` compiles the plain
baseline and returns it untouched — bit-for-bit today's compiler
output (the golden anchor the tests pin).

Source rates are routing-invariant, so the re-route step sees the same
inputs every iteration once the partition settles — in practice the
loop converges in 2-3 iterations: one big re-route win, one confirming
re-measure.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.board.partition import Partition, partition
from repro.board.route import (BoardProgram, chip_tree, compile_board,
                               place_partition, population_dst_pes,
                               stitch_population)
from repro.board.spec import BoardNoc, BoardSpec
from repro.chip.chip import ChipSim
from repro.chip.compile import source_packet_classes
from repro.chip.graph import NetGraph
from repro.core.noc import ORIENTATIONS
from repro.core.pe import PESpec
from repro.routeopt.config import RouteConfig
from repro.routeopt.profile import TrafficProfile, measure_profile


@dataclass
class RouteOptResult:
    """Best program found + the evidence trail."""
    program: BoardProgram
    route: RouteConfig
    part: Partition
    baseline: Optional[TrafficProfile]   # profile of the fixed-route compile
    profile: Optional[TrafficProfile]    # profile of the best program
    trajectory: list                     # one summary row per iteration
    iterations: int                      # optimization iterations run
    converged: bool

    @property
    def improvement(self) -> float:
        """Fractional reduction of the measured objective vs baseline
        (0.15 == 15% lower peak_xlink_flits)."""
        if self.baseline is None or self.profile is None:
            return 0.0
        b = self.baseline.objective()
        return (b - self.profile.objective()) / max(b, 1e-9)


def _pop_contribution(board, noc, name, src_chip, by_chip, tile_xy,
                      tile_rate, flits, route) -> np.ndarray:
    """Predicted mean flits this population puts on every link under
    ``route`` — its stitched rows weighted by measured per-tile rates
    (exact for the mean profile; see repro.routeopt.profile)."""
    rows, _, _, _ = stitch_population(board, noc, name, src_chip, by_chip,
                                      tile_xy, route)
    v = np.zeros(noc.n_links)
    for row, rate in zip(rows, tile_rate):
        v[row] += float(rate) * flits    # ids within a row are distinct
    return v


def _assign_ports(board, noc, name, src_chip, by_chip, o_chip,
                  resid) -> dict:
    """Least-loaded border port per (chip, exit dir) of the population's
    chip tree, against the residual predicted load.  One port per
    (pop, chip, dir): the router duplicates at branch points, so
    splitting one tree's exit across ports would duplicate traffic."""
    tree = chip_tree(board, src_chip, by_chip.keys(), orientation=o_chip)
    k = board.ports_per_edge
    return {(name, c, d): min(range(k),
                              key=lambda j: (resid[noc.xlink_id(c, d, j)], j))
            for c in sorted(tree) for d in tree[c][1]}


def _search_routes(graph: NetGraph, board: BoardSpec, part: Partition,
                   src_mean: np.ndarray, flits_of: dict) -> RouteConfig:
    """One greedy sweep: populations in descending measured flow, each
    picking the (chip orientation x tree orientation x port assignment)
    that minimizes (predicted peak chip-to-chip, peak overall, total)
    against everyone else's current routes."""
    noc = BoardNoc(board)
    pe_slices, coords_local, chip_of_pe, _ = place_partition(graph, board,
                                                             part)
    dst_pes = population_dst_pes(graph, pe_slices)
    nx0 = noc.n_onchip_links

    pops = []
    for pop in graph.populations:
        sl = pe_slices[pop.name]
        src_chip = int(chip_of_pe[sl.start])
        by_chip: dict = {}
        for p in dst_pes[pop.name]:
            by_chip.setdefault(int(chip_of_pe[p]), []).append(
                coords_local[p])
        tile_rate = np.asarray(src_mean[sl], float)
        flits = flits_of.get(pop.name, 1)
        pops.append((pop.name, src_chip, by_chip, coords_local[sl],
                     tile_rate, flits, float(tile_rate.sum()) * flits))

    default = RouteConfig()
    contribs = {}
    load = np.zeros(noc.n_links)
    for name, src_chip, by_chip, tile_xy, tile_rate, flits, _ in pops:
        contribs[name] = _pop_contribution(board, noc, name, src_chip,
                                           by_chip, tile_xy, tile_rate,
                                           flits, default)
        load += contribs[name]

    tree_orient: dict = {}
    chip_orient: dict = {}
    ports: dict = {}
    for name, src_chip, by_chip, tile_xy, tile_rate, flits, _ in sorted(
            pops, key=lambda t: -t[-1]):
        resid = load - contribs[name]
        best = None
        for o_chip in ORIENTATIONS:
            pport = _assign_ports(board, noc, name, src_chip, by_chip,
                                  o_chip, resid)
            for o_tree in ORIENTATIONS:
                cand = RouteConfig(tree_orient={name: o_tree},
                                   chip_orient={name: o_chip},
                                   ports=pport)
                contrib = _pop_contribution(board, noc, name, src_chip,
                                            by_chip, tile_xy, tile_rate,
                                            flits, cand)
                total = resid + contrib
                key = (float(total[nx0:].max(initial=0.0)),
                       float(total.max(initial=0.0)), float(total.sum()))
                if best is None or key < best[0]:
                    best = (key, o_chip, o_tree, pport, contrib)
        _, o_chip, o_tree, pport, contrib = best
        if o_tree != "xy":
            tree_orient[name] = o_tree
        if o_chip != "xy":
            chip_orient[name] = o_chip
        ports.update({k: j for k, j in pport.items() if j != 0})
        contribs[name] = contrib
        load = resid + contrib
    return RouteConfig(tree_orient=tree_orient, chip_orient=chip_orient,
                       ports=ports)


def optimize_routes(graph: NetGraph, board: Optional[BoardSpec] = None, *,
                    pe: PESpec = PESpec(), n_ticks: int = 64,
                    max_iters: int = 4, eps: float = 0.02,
                    budget_s: Optional[float] = None,
                    ports_per_edge: int = 2,
                    replace_partition: bool = True, refine: bool = True,
                    seed: int = 1,
                    sim_kw: Optional[dict] = None) -> RouteOptResult:
    """Run the closed loop (see module docstring) and return the best
    program with its trajectory.

    ``ports_per_edge`` is the border-port budget the optimized board is
    grown to (clamped to what the chip mesh can host); the BASELINE
    compile keeps the caller's board untouched, so the comparison is
    fixed-routes vs optimized on the same chip grid.  ``budget_s``
    bounds total wall-clock (compile + simulate); the loop never starts
    an iteration past it.  ``sim_kw`` forwards to ``ChipSim`` (e.g.
    ``exec_mode``); ``n_ticks``/``seed`` drive every measurement run
    identically so profiles are comparable."""
    t0 = time.perf_counter()
    sim_kw = dict(sim_kw or {})

    tc = time.perf_counter()
    base_prog = compile_board(graph, board, pe=pe, refine=refine)
    base_compile_s = time.perf_counter() - tc
    board = base_prog.board
    if max_iters <= 0:
        return RouteOptResult(program=base_prog, route=base_prog.route,
                              part=base_prog.part, baseline=None,
                              profile=None, trajectory=[], iterations=0,
                              converged=False)

    tm = time.perf_counter()
    baseline = measure_profile(ChipSim(base_prog, **sim_kw), n_ticks,
                               seed=seed)
    trajectory = [{"iter": 0, **baseline.summary(),
                   "compile_s": round(base_compile_s, 3),
                   "measure_s": round(time.perf_counter() - tm, 3),
                   "cut_flits": base_prog.part.cut_flits}]

    k = min(ports_per_edge, board.chip.width, board.chip.height)
    grown = (dataclasses.replace(board, ports_per_edge=k)
             if board.n_chips > 1 else board)
    out_bits = source_packet_classes(graph)
    flits_of = {name: (max(1, -(-bits // board.noc.payload_bits))
                       if bits > 0 else 1)
                for name, bits in out_bits.items()}

    best = (base_prog, baseline)
    prof = baseline
    prev_obj = baseline.objective()
    converged = False
    iterations = 0
    for it in range(1, max_iters + 1):
        if budget_s is not None and time.perf_counter() - t0 > budget_s:
            break
        iterations = it
        rates = (prof.pop_rates(base_prog.pe_slices)
                 if replace_partition else None)
        tc = time.perf_counter()
        part = partition(graph, grown, refine=refine, rates=rates)
        route = _search_routes(graph, grown, part, prof.src_mean, flits_of)
        prog = compile_board(graph, grown, pe=pe, part=part, route=route)
        compile_s = time.perf_counter() - tc
        tm = time.perf_counter()
        prof = measure_profile(ChipSim(prog, **sim_kw), n_ticks, seed=seed)
        trajectory.append({"iter": it, **prof.summary(),
                           "compile_s": round(compile_s, 3),
                           "measure_s": round(time.perf_counter() - tm, 3),
                           "cut_flits": part.cut_flits})
        if prof.objective() < best[1].objective():
            best = (prog, prof)
        obj = prof.objective()
        rel = (prev_obj - obj) / max(prev_obj, 1e-9)
        prev_obj = obj
        if rel < eps:                      # no (or negative) improvement
            converged = True
            break

    prog, prof = best
    return RouteOptResult(program=prog, route=prog.route, part=prog.part,
                          baseline=baseline, profile=prof,
                          trajectory=trajectory, iterations=iterations,
                          converged=converged)
