"""The board compiler's free routing parameters, as one value object.

``RouteConfig`` is what the profile-guided optimizer searches over and
what ``repro.board.route.compile_board(route=...)`` consumes.  Three
independent knobs, all defaulting to the historical fixed choices so an
empty config compiles bit-identically to the pre-routeopt compiler:

* ``tree_orient`` — per source population, the on-chip multicast tree
  orientation ("xy" X-then-Y / "yx" Y-then-X) used for the local tree
  on the source chip and the entry trees on every downstream chip;
* ``chip_orient`` — per source population, the orientation of the
  chip-GRANULARITY tree that decides which chips the multicast
  traverses;
* ``ports`` — per (population, chip, direction), which of the board's
  ``ports_per_edge`` parallel border ports that population's exit in
  that direction uses.  A population keeps ONE port per (chip, dir) —
  the router duplicates packets at branch points, so splitting one
  tree's exit across ports would duplicate traffic, not spread it.

This module deliberately imports nothing from ``repro.board`` so the
board stitcher can import it without a cycle.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.noc import ORIENTATIONS


@dataclass(frozen=True)
class RouteConfig:
    tree_orient: dict = field(default_factory=dict)  # pop -> "xy" | "yx"
    chip_orient: dict = field(default_factory=dict)  # pop -> "xy" | "yx"
    ports: dict = field(default_factory=dict)        # (pop, chip, dir) -> j

    def orient_tree(self, pop: str) -> str:
        return self.tree_orient.get(pop, "xy")

    def orient_chip(self, pop: str) -> str:
        return self.chip_orient.get(pop, "xy")

    def port_index(self, pop: str, chip: int, d: str) -> int:
        return self.ports.get((pop, chip, d), 0)

    def validate(self, board) -> "RouteConfig":
        """Raise ValueError on an orientation outside ``ORIENTATIONS``
        or a port index outside ``board.ports_per_edge``; returns self
        so callers can chain."""
        for m in (self.tree_orient, self.chip_orient):
            for pop, o in m.items():
                if o not in ORIENTATIONS:
                    raise ValueError(
                        f"population {pop!r}: orientation {o!r} not in "
                        f"{ORIENTATIONS}")
        k = board.ports_per_edge
        for (pop, chip, d), j in self.ports.items():
            if not 0 <= j < k:
                raise ValueError(
                    f"population {pop!r}, chip {chip}, dir {d!r}: port "
                    f"{j} out of range for ports_per_edge={k}")
        return self
