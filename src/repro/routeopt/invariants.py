"""The invariants any legal routing must preserve, as checkable facts.

Re-routing is only allowed to move flits, never to create, drop or
duplicate deliveries.  ``check_delivery`` walks every source's stitched
multicast tree link-by-link over the NoC's endpoint view and proves:

* the row is a TREE rooted at the source QPE: link ids distinct, every
  node's in-degree <= 1, every link's tail reachable from the root;
* every routing-table destination of the source is covered by the tree
  (so each destination receives each packet exactly once — in-degree
  <= 1 makes "at least once" also "exactly once").

It returns a routing-independent delivery signature — per source, the
destination node set and the flits each delivery carries.  Two programs
with equal signatures conserve flits per (source, destination-set)
EXACTLY: total link traversals may legitimately differ between
orientations (tree shapes differ), delivered flits may not.
"""
from __future__ import annotations

import numpy as np


def _endpoints(noc, link_id: int):
    """((chip, (x, y)), (chip, (x, y))) of a link, for board and
    single-chip NoCs alike (a chip is chip 0 of itself)."""
    if hasattr(noc, "link_endpoints"):
        a, b = noc.link_endpoints(link_id)
        return (a[0], tuple(int(v) for v in a[1])), \
               (b[0], tuple(int(v) for v in b[1]))
    a, b = noc.links[link_id]
    return (0, (int(a[0]), int(a[1]))), (0, (int(b[0]), int(b[1])))


def _node_of(program, p: int):
    """(chip, within-chip coord) of logical PE ``p``."""
    if getattr(program, "chip_of_pe", None) is not None:
        return (int(program.chip_of_pe[p]),
                tuple(int(v) for v in program.coords_local[p]))
    return (0, tuple(int(v) for v in program.coords[p]))


def check_delivery(program) -> list:
    """Verify every source's tree (see module docstring) and return the
    delivery signature: ``[(src_pe, (sorted dst nodes), flits), ...]``.
    Raises ``AssertionError`` naming the source PE on any violation."""
    sinc = program.sinc
    noc = program.noc
    masks = np.asarray(program.table.masks)
    flits = np.asarray(noc.packet_flits(program.payload_bits))
    sig = []
    for p in range(program.n_pes):
        row = sinc.link_ids[sinc.source_ptr[p]:sinc.source_ptr[p + 1]]
        assert len(set(row.tolist())) == len(row), \
            f"source PE {p}: duplicate link ids in its tree row"
        root = _node_of(program, p)
        out: dict = {}
        indeg: dict = {}
        for lid in row.tolist():
            a, b = _endpoints(noc, lid)
            out.setdefault(a, []).append(b)
            indeg[b] = indeg.get(b, 0) + 1
            assert indeg[b] <= 1, \
                f"source PE {p}: node {b} entered twice — not a tree"
        assert root not in indeg, \
            f"source PE {p}: a link re-enters the source node"
        reach = {root}
        frontier = [root]
        while frontier:
            nxt = []
            for n in frontier:
                for m in out.get(n, ()):
                    if m not in reach:
                        reach.add(m)
                        nxt.append(m)
            frontier = nxt
        for a in out:
            assert a in reach, \
                f"source PE {p}: link tail {a} unreachable from {root}"
        dsts = tuple(sorted(_node_of(program, int(q))
                            for q in np.flatnonzero(masks[p])
                            if int(q) != p))
        for d in dsts:
            assert d in reach, \
                f"source PE {p}: destination {d} not covered by its tree"
        sig.append((p, dsts, int(flits[p])))
    return sig
