"""AdamW in plain JAX (fp32 master weights + moments), pytree-native.

Moments inherit the parameter sharding (ZeRO: both params and optimizer
state live sharded over ("data","model")); nothing here is mesh-aware —
shardings flow in through jit in/out specs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, state, params, cfg: AdamWConfig, lr=None):
    """Returns (new_params, new_state, metrics)."""
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.asarray(1.0)
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        step = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        newp = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "count": count}, {"grad_norm": gnorm}
