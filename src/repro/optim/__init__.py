from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig
from repro.optim.schedule import cosine_schedule
