"""int8 gradient compression with error feedback.

The paper's "trade spike payload for spike frequency" idea (Sec. II)
applied to gradient traffic: gradients cross the ICI as int8 payloads +
one f32 scale per tensor (4x fewer collective bytes than f32, 2x fewer
than bf16), with the quantization residual fed back into the next step so
the compression is unbiased over time (error-feedback SGD).

``compressed_psum_mean`` is the drop-in for the gradient all-reduce: each
device quantizes its local shard, all-gathers the int8 payloads over the
batch axes inside a shard_map, and dequantizes + averages locally.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_tensor(g, bits: int = 8):
    qmax = 2.0 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(g / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_tensor(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(grads, ef_state):
    """Apply error feedback then quantize each leaf.

    Returns (q_tree, scale_tree, new_ef_state)."""
    if ef_state is None:
        ef_state = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e,
                             grads, ef_state)
    qs = jax.tree.map(quantize_tensor, corrected)
    q_tree = jax.tree.map(lambda t: t[0], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    s_tree = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(
        lambda c, q, s: c - dequantize_tensor(q, s), corrected, q_tree, s_tree)
    return q_tree, s_tree, new_ef


def compressed_psum_mean(leaf, scale, mesh, axes=("data",)):
    """All-reduce-mean one tensor's int8 payload over `axes`.

    Implementation: all-gather int8 + per-shard scales inside shard_map,
    dequantize, mean.  Link traffic = n/4 of the f32 all-gather."""
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return dequantize_tensor(leaf, scale)
    ax = axes if len(axes) > 1 else axes[0]

    def local(q, s):
        qg = jax.lax.all_gather(q, ax)          # (n, ...) int8
        sg = jax.lax.all_gather(s, ax)          # (n,) f32
        deq = qg.astype(jnp.float32) * sg.reshape(
            (-1,) + (1,) * (qg.ndim - 1))
        return jnp.mean(deq, axis=0)

    return jax.shard_map(local, mesh=mesh, in_specs=(P(), P()),
                         out_specs=P(), check_vma=False)(leaf, scale)
