from repro.data.pipeline import SyntheticTokenPipeline, PipelineConfig
