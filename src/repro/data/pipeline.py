"""Deterministic, seekable synthetic data pipeline.

Every batch is a pure function of (seed, step) — the property fault
tolerance needs: after restart-from-checkpoint at step k the pipeline
resumes at exactly batch k with no replay log.  Tokens follow per-sequence
affine recurrences over the vocab (x_{t+1} = a x_t + c mod V) mixed with
noise tokens, so models have real structure to learn and training loss
decreases measurably within a few hundred steps.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise_prob: float = 0.05
    n_styles: int = 8             # size of the fixed (a, c) recurrence pool
    kind: str = "tokens"          # "tokens" | "frames"
    d_model: int = 0              # frames mode
    num_codebooks: int = 1


class SyntheticTokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self._key = jax.random.PRNGKey(cfg.seed)

    def batch(self, step: int) -> dict:
        """Batch for `step` (deterministic, O(1) seek)."""
        cfg = self.cfg
        k = jax.random.fold_in(self._key, step)
        if cfg.kind == "frames":
            kf, kl = jax.random.split(k)
            frames = jax.random.normal(
                kf, (cfg.global_batch, cfg.seq_len, cfg.d_model), jnp.bfloat16)
            labels = jax.random.randint(
                kl, (cfg.global_batch, cfg.seq_len, cfg.num_codebooks),
                0, cfg.vocab_size, jnp.int32)
            return {"frames": frames, "labels": labels}
        ka, kc, k0, kn, km = jax.random.split(k, 5)
        B, S, V = cfg.global_batch, cfg.seq_len + 1, cfg.vocab_size
        # each sequence follows one of n_styles fixed affine recurrences, so
        # transitions are memorizable (loss decreases) yet step-deterministic
        kpool = jax.random.PRNGKey(cfg.seed + 7919)
        pool_a = 1 + 2 * jax.random.randint(
            jax.random.fold_in(kpool, 0), (cfg.n_styles,), 0, (V - 1) // 2)
        pool_c = jax.random.randint(
            jax.random.fold_in(kpool, 1), (cfg.n_styles,), 0, V)
        style = jax.random.randint(ka, (B,), 0, cfg.n_styles)
        a = pool_a[style][:, None]
        c = pool_c[style][:, None]
        x0 = jax.random.randint(k0, (B, 1), 0, V)
        t = jnp.arange(S)[None, :]
        # closed form of the affine recurrence would need modpow; iterate in
        # log space instead: x_t = a^t x_0 + c (a^t - 1)/(a - 1)  (mod V).
        # Cheap approach: cumulative product via scan-free powers is
        # overkill for synthetic data — use a simple cumulative loop.
        def step_fn(x, _):
            nx = (x * a[:, 0] + c[:, 0]) % V
            return nx, nx
        _, seq = jax.lax.scan(step_fn, x0[:, 0], None, length=S - 1)
        tokens = jnp.concatenate([x0, seq.T], axis=1)
        noise = jax.random.randint(kn, tokens.shape, 0, V)
        mask = jax.random.uniform(km, tokens.shape) < cfg.noise_prob
        tokens = jnp.where(mask, noise, tokens)
        return {"tokens": tokens.astype(jnp.int32)}

    def shard_for(self, batch: dict, mesh, shardings=None):
        """Place a global batch onto the mesh (data-parallel leading dim)."""
        from repro.dist.sharding import data_spec
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x: jax.device_put(
                x, NamedSharding(mesh, data_spec(x.shape, mesh, 0))), batch)
