"""Run provenance for BENCH artifacts + host-side phase timers.

Every benchmark JSON the repo emits carries a ``manifest`` block —
git sha, config hash, seed, jax/jaxlib versions, host — so a BENCH file
found in CI artifacts months later is self-describing, and
``repro.obs.report`` can say WHAT two runs being diffed actually were.

``PhaseTimers`` is the shared host-side stopwatch for the compile
pipeline's phases (build / partition / compile / first-tick-jit /
steady-tick): benchmarks wrap each phase in ``with tm.phase("build")``
and the per-phase seconds ride the JSON next to the rows.
"""
from __future__ import annotations

import contextlib
import hashlib
import json
import platform
import socket
import subprocess
import time
from datetime import datetime, timezone
from pathlib import Path
from typing import Optional


def _git(args: list, cwd: Optional[str] = None) -> Optional[str]:
    try:
        out = subprocess.run(["git", *args], cwd=cwd, capture_output=True,
                             text=True, timeout=5)
        return out.stdout.strip() if out.returncode == 0 else None
    except (OSError, subprocess.TimeoutExpired):
        return None


def config_hash(obj) -> str:
    """Stable short hash of any JSON-serializable config (dataclasses
    and numpy scalars/arrays coerced via ``str``)."""
    blob = json.dumps(obj, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def run_manifest(seed: Optional[int] = None, config=None,
                 extra: Optional[dict] = None) -> dict:
    """The provenance block attached to every BENCH json."""
    import jax
    try:
        import jaxlib
        jaxlib_version = jaxlib.__version__
    except Exception:                                     # pragma: no cover
        jaxlib_version = None
    root = str(Path(__file__).resolve().parents[3])
    sha = _git(["rev-parse", "HEAD"], cwd=root)
    dirty = _git(["status", "--porcelain"], cwd=root)
    man = {
        "git_sha": sha,
        "git_dirty": bool(dirty) if dirty is not None else None,
        "seed": seed,
        "config_hash": config_hash(config) if config is not None else None,
        "jax_version": jax.__version__,
        "jaxlib_version": jaxlib_version,
        "python": platform.python_version(),
        "platform": platform.platform(),
        "host": socket.gethostname(),
        "timestamp_utc": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
    }
    if extra:
        man.update(extra)
    return man


class PhaseTimers:
    """Named host-side stopwatches for the compile/run pipeline.

    >>> tm = PhaseTimers()
    >>> with tm.phase("build"): graph = build()        # doctest: +SKIP
    >>> tm["build"]                                    # doctest: +SKIP
    0.123

    ``record`` stores an externally-measured duration (e.g. the steady
    per-tick time from ``time_call``); ``asdict`` rounds for JSON.
    """

    def __init__(self):
        self.seconds: dict = {}

    @contextlib.contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.seconds[name] = (self.seconds.get(name, 0.0)
                                  + time.perf_counter() - t0)

    def record(self, name: str, seconds: float) -> None:
        self.seconds[name] = float(seconds)

    def __getitem__(self, name: str) -> float:
        return self.seconds[name]

    def get(self, name: str, default: float = 0.0) -> float:
        return self.seconds.get(name, default)

    def asdict(self, ndigits: int = 6) -> dict:
        return {k: round(v, ndigits) for k, v in self.seconds.items()}


def bench_payload(rows: list, *, link_profiles: Optional[dict] = None,
                  timers: Optional[dict] = None, seed: Optional[int] = None,
                  config=None, **extra) -> dict:
    """The standard BENCH json payload: rows + manifest (+ optional
    per-link profiles / phase timers / extra sections).

    Top-level ``jax_version``/``python``/``platform`` keys are kept for
    backward compatibility with pre-manifest BENCH consumers."""
    man = run_manifest(seed=seed, config=config)
    payload = {
        "rows": rows,
        "manifest": man,
        # legacy flat keys (BENCH_pr3/4/5.json readers)
        "jax_version": man["jax_version"],
        "python": man["python"],
        "platform": man["platform"],
    }
    if link_profiles is not None:
        payload["link_profiles"] = link_profiles
    if timers is not None:
        payload["phase_timers"] = (timers.asdict()
                                   if isinstance(timers, PhaseTimers)
                                   else timers)
    payload.update(extra)
    return payload


def write_bench_json(path, rows: list, **kw) -> Path:
    """Write ``bench_payload`` to ``path`` (parents created) and return
    the path — the one JSON writer all benchmarks share."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(bench_payload(rows, **kw), indent=1))
    print(f"# wrote {len(rows)} rows to {path}")
    return path
