"""Request-lifecycle spans for the serving tier.

Every user session served by the fleet gets a *span chain* — the ordered
structured events of its lifecycle:

    enqueue -> admit (slot, width) -> round* -> preempt -> enqueue ->
    resume -> round* -> complete

recorded host-side by ``FleetEngine``/``RequestQueue`` into one
``SpanLog`` per serve.  The log also samples per-round *fleet counters*
(queue depth, fleet width, active residents, batched tick time, round
energy) — the signals ``repro.obs.trace`` renders as Perfetto counter
tracks next to the per-slot request slices.

The chain is a checkable grammar, not just a log: ``validate_spans``
runs the per-session state machine (admit precedes ticks, resume only
after preempt/suspend, exactly one terminal event, nothing after
completion) and returns every violation — the serving health verdict
and the span-completeness tests both gate on it.  A session restored
from a checkpoint in a *fresh* engine opens its chain with an
``enqueue`` carrying ``ticks_done > 0``, which the validator treats as
the preempted state — so a single engine's log validates standalone,
and two engines' logs concatenated per session validate as one chain
across suspend-to-disk/restore.
"""
from __future__ import annotations

import gzip
import json
import time
from dataclasses import dataclass, field
from pathlib import Path

SPAN_KINDS = ("enqueue", "admit", "resume", "round", "preempt",
              "suspend", "complete", "slo")

# fleet-level events (SLO violations, ...) carry this sid
FLEET_SID = -1


@dataclass(frozen=True)
class SpanEvent:
    """One structured lifecycle event: ``kind`` from ``SPAN_KINDS``,
    the session it belongs to (``FLEET_SID`` for fleet-level events),
    wall time relative to the log's epoch, the scheduling round it
    happened in (-1 outside the round loop), and kind-specific args."""
    kind: str
    sid: int = FLEET_SID
    t_s: float = 0.0
    round: int = -1
    args: dict = field(default_factory=dict)

    def asdict(self) -> dict:
        return {"kind": self.kind, "sid": self.sid,
                "t_s": round(self.t_s, 6), "round": self.round,
                "args": self.args}


class SpanLog:
    """Append-only span recorder + per-round fleet counter samples."""

    def __init__(self, clock=time.perf_counter, meta: dict | None = None):
        self._clock = clock
        self.epoch = clock()
        self.events: list[SpanEvent] = []
        self.counters: list[dict] = []
        self.meta = dict(meta or {})

    def now(self) -> float:
        return self._clock() - self.epoch

    def emit(self, kind: str, sid: int = FLEET_SID, round_i: int = -1,
             **args) -> SpanEvent:
        if kind not in SPAN_KINDS:
            raise ValueError(f"unknown span kind {kind!r}; expected one "
                             f"of {SPAN_KINDS}")
        ev = SpanEvent(kind=kind, sid=int(sid), t_s=self.now(),
                       round=int(round_i), args=args)
        self.events.append(ev)
        return ev

    def sample(self, round_i: int, **vals) -> None:
        """Record one per-round fleet counter sample (queue depth, width,
        tick time, energy, ...) — the counter-track side of the trace."""
        self.counters.append({"round": int(round_i),
                              "t_s": round(self.now(), 6), **vals})

    def for_sid(self, sid: int) -> list[SpanEvent]:
        return [e for e in self.events if e.sid == sid]

    @property
    def sids(self) -> list[int]:
        return sorted({e.sid for e in self.events if e.sid != FLEET_SID})

    # ------------------------------------------------------- (de)serialize
    def payload(self) -> dict:
        return {"schema": "fleet-spans-v1", "meta": self.meta,
                "events": [e.asdict() for e in self.events],
                "counters": self.counters}

    def write(self, path, compress: bool = False) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        blob = json.dumps(self.payload())
        if compress or path.suffix == ".gz":
            if path.suffix != ".gz":
                path = path.with_suffix(path.suffix + ".gz")
            path.write_bytes(gzip.compress(blob.encode()))
        else:
            path.write_text(blob)
        return path


def load_spans(path) -> dict:
    """Read a span-log payload written by ``SpanLog.write`` (gzip
    transparent: ``.gz`` paths decompress)."""
    path = Path(path)
    raw = path.read_bytes()
    if path.suffix == ".gz" or raw[:2] == b"\x1f\x8b":
        raw = gzip.decompress(raw)
    return json.loads(raw.decode())


# ---------------------------------------------------------------------------
# The span-chain grammar
# ---------------------------------------------------------------------------

_NEW, _QUEUED, _RESIDENT, _PREEMPTED, _DONE = range(5)
_STATE_NAMES = {_NEW: "new", _QUEUED: "queued", _RESIDENT: "resident",
                _PREEMPTED: "preempted", _DONE: "done"}


def validate_spans(events, require_complete: bool = False) -> list:
    """Check every session's span chain against the lifecycle grammar.

    ``events`` is an iterable of ``SpanEvent`` or their ``asdict`` form
    (so loaded payloads validate too); events must be in emission order
    per session — concatenating the logs of two engines that served the
    same session (suspend-to-disk, restore) yields one valid chain.

    Rules, per session:

    * the chain opens with ``enqueue`` (a restore into a fresh engine
      opens with an ``enqueue`` whose args carry ``ticks_done > 0`` —
      treated as arriving already-preempted);
    * ``admit`` only from the queue, and only as the FIRST residency;
      ``resume`` only from the queue after a ``preempt``/``suspend``;
    * ``round`` events (ticks actually served) only while resident;
    * ``preempt``/``suspend`` only while resident, and re-queueing
      (``enqueue``) only after one of them;
    * exactly one terminal ``complete`` (while resident), then nothing.

    Returns a list of human-readable violations (empty = valid).  With
    ``require_complete`` every session must have reached ``complete`` —
    the full-drain invariant (a dropped session is a broken chain).
    """
    problems: list = []
    state: dict = {}
    seen_ticks: dict = {}

    def ev_fields(e):
        if isinstance(e, SpanEvent):
            return e.kind, e.sid, e.args
        return e["kind"], e["sid"], e.get("args", {})

    for i, e in enumerate(events):
        kind, sid, args = ev_fields(e)
        if sid == FLEET_SID:
            continue                       # fleet-level events are free-form
        st = state.get(sid, _NEW)
        bad = None
        if kind == "enqueue":
            if st == _NEW:
                # a restored session opens mid-lifecycle
                state[sid] = _QUEUED
                if float(args.get("ticks_done", 0)) > 0:
                    seen_ticks[sid] = True
            elif st == _PREEMPTED:
                state[sid] = _QUEUED
            else:
                bad = "enqueue while " + _STATE_NAMES[st]
        elif kind == "admit":
            if st == _QUEUED and not seen_ticks.get(sid):
                state[sid] = _RESIDENT
            elif seen_ticks.get(sid):
                bad = "admit after ticks were served (expected resume)"
            else:
                bad = "admit while " + _STATE_NAMES[st]
        elif kind == "resume":
            if st == _QUEUED and seen_ticks.get(sid):
                state[sid] = _RESIDENT
            elif not seen_ticks.get(sid):
                bad = "resume with no prior preempt/suspend"
            else:
                bad = "resume while " + _STATE_NAMES[st]
        elif kind == "round":
            if st != _RESIDENT:
                bad = "round while " + _STATE_NAMES[st]
            elif float(args.get("ticks", 1)) > 0:
                seen_ticks[sid] = True
        elif kind in ("preempt", "suspend"):
            if st == _RESIDENT:
                state[sid] = _PREEMPTED
            else:
                bad = f"{kind} while " + _STATE_NAMES[st]
        elif kind == "complete":
            if st == _RESIDENT:
                state[sid] = _DONE
            else:
                bad = "complete while " + _STATE_NAMES[st]
        else:
            bad = f"unknown kind {kind!r}"
        if bad:
            problems.append(f"event {i} sid {sid}: {bad}")

    if require_complete:
        for sid, st in sorted(state.items()):
            if st != _DONE:
                problems.append(f"sid {sid}: chain ended "
                                f"{_STATE_NAMES[st]}, never completed")
    return problems
