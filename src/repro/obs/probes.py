"""Declarative in-scan probes over the engine's per-tick records.

A ``ProbeSpec`` names a per-tick record signal (any key the program's
semantics or the engine itself reports — ``link_flits``, ``packets``,
``pl``, ``e_learn``, ``learn/<slot>/err``, ...) and how to record it:

* ``stride``  — emit one sample every ``stride`` ticks (``None`` = one
  sample for the whole run), so a 10k-tick board run can keep e.g. 100
  strided samples of a (n_links,) signal instead of the full (T, n_links)
  timeline;
* ``op``      — the windowed reduction folded tick-by-tick inside the
  scan carry: ``peak`` / ``mean`` / ``sum`` over each tumbling window,
  ``last`` (instantaneous sample at window ends), or ``ema`` (a
  continuous exponential moving average, sampled at window ends — the
  hardware-counter idiom for DVFS-style feedback).

``ChipSim.run(probes=...)`` compiles the accumulators into the scan
carry, next to the workload state: no host round-trip per tick, no
(T, ...) allocation, and with ``probes=()`` (the default) the traced
tick body is EXACTLY the bare engine's — golden tests pin that bitwise.

The probe buffers come back under ``recs["probes"][name]`` with shape
``(n_samples, *signal_shape)``; ``keep_records=False`` drops the full
per-tick records entirely and returns only the probe output (the
memory-bounded mode for long board runs).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

PROBE_OPS = ("peak", "mean", "sum", "ema", "last")


@dataclass(frozen=True)
class ProbeSpec:
    """One recorded signal: ``key`` into the per-tick rec, windowed
    ``op``, sampling ``stride`` in ticks (None = whole run), EMA decay
    ``alpha`` (only for ``op="ema"``)."""
    name: str
    key: str
    op: str = "last"
    stride: Optional[int] = None
    alpha: float = 0.1

    def __post_init__(self):
        if self.op not in PROBE_OPS:
            raise ValueError(f"probe {self.name!r}: unknown op {self.op!r};"
                             f" expected one of {PROBE_OPS}")
        if self.stride is not None and self.stride < 1:
            raise ValueError(f"probe {self.name!r}: stride must be >= 1, "
                             f"got {self.stride}")
        if not 0.0 < self.alpha <= 1.0:
            raise ValueError(f"probe {self.name!r}: ema alpha must be in "
                             f"(0, 1], got {self.alpha}")


# ---------------------------------------------------------------------------
# Registry: named probe sets over the signals every program guarantees
# ---------------------------------------------------------------------------

def _link_flit_probes(program, stride=None):
    """Per-link DNoC flit loads — the SpiNNCer-style congestion signal."""
    return (ProbeSpec("link_flits_peak", "link_flits", "peak", stride),
            ProbeSpec("link_flits_mean", "link_flits", "mean", stride))


def _pe_activity_probes(program, stride=None):
    """Per-PE NoC source activity (multicast packets emitted)."""
    return (ProbeSpec("pe_packets_sum", "packets", "sum", stride),)


def _dvfs_probes(program, stride=None):
    """Per-PE performance level — the DVFS trajectory (mean occupancy of
    the levels plus a continuously-averaged hardware-counter view)."""
    return (ProbeSpec("pe_pl_mean", "pl", "mean", stride),
            ProbeSpec("pe_pl_ema", "pl", "ema", stride, alpha=0.05))


def _energy_probes(program, stride=None):
    """Per-PE Eq. (1) energy under DVFS plus the NoC traffic energy."""
    return (ProbeSpec("pe_e_dvfs_baseline_sum", "e_dvfs_baseline", "sum",
                      stride),
            ProbeSpec("pe_e_dvfs_synapse_sum", "e_dvfs_synapse", "sum",
                      stride),
            ProbeSpec("e_noc_sum", "e_noc", "sum", stride))


def _activity_probes(program, stride=None):
    """Event-sparsity telemetry: active-PE count, active-source fraction
    and per-tier touched-link counts — the signals the event execution
    mode compresses on.  Both exec modes emit these records identically,
    so the probes read the same whichever mode ran."""
    out = [ProbeSpec("active_pe_mean", "active_sources", "mean", stride),
           ProbeSpec("active_frac_mean", "active_frac", "mean", stride),
           ProbeSpec("touched_links_mean", "touched_links", "mean", stride)]
    # per-tier keys mirror the engine: empty tiers (1x1 board) emit none
    for tier, m in program.noc.tier_masks().items():
        if np.asarray(m).any():
            out.append(ProbeSpec(f"touched_links_{tier}_mean",
                                 f"touched_links_{tier}", "mean", stride))
    return tuple(out)


def _learn_probes(program, stride=None):
    """Per-slot learn signals: per-PE learning energy + per-slot mean
    |dw| (the engine reports both for every plastic program)."""
    if not getattr(program, "learn_slots", ()):
        return ()
    out = [ProbeSpec("pe_e_learn_sum", "e_learn", "sum", stride)]
    out += [ProbeSpec(f"learn_dw_{s.name}", f"learn/{s.name}/dw", "mean",
                      stride) for s in program.learn_slots]
    return tuple(out)


PROBE_REGISTRY = {
    "link_flits": _link_flit_probes,
    "pe_packets": _pe_activity_probes,
    "activity": _activity_probes,
    "dvfs": _dvfs_probes,
    "energy": _energy_probes,
    "learn": _learn_probes,
}


def default_probes(program, stride: Optional[int] = None) -> tuple:
    """The standard low-overhead probe set: congestion, activity, DVFS,
    energy — plus the learn tier when the program is plastic.  This is
    the set the < 10% tick overhead budget is measured against."""
    specs: list = []
    for build in PROBE_REGISTRY.values():
        specs.extend(build(program, stride))
    return tuple(specs)


def resolve_probes(program, probes) -> tuple:
    """Normalize ``probes`` to a tuple of ``ProbeSpec``: accepts specs,
    registry names ("link_flits", "dvfs", ...) and iterables of either.
    Duplicate probe names are rejected (they would shadow one another in
    the output dict)."""
    specs: list = []
    for p in probes:
        if isinstance(p, ProbeSpec):
            specs.append(p)
        elif isinstance(p, str):
            try:
                specs.extend(PROBE_REGISTRY[p](program))
            except KeyError:
                raise ValueError(
                    f"unknown probe set {p!r}; registry has "
                    f"{sorted(PROBE_REGISTRY)}") from None
        else:
            raise TypeError(f"probe {p!r} is neither a ProbeSpec nor a "
                            "registry name")
    names = [s.name for s in specs]
    dup = {n for n in names if names.count(n) > 1}
    if dup:
        raise ValueError(f"duplicate probe names: {sorted(dup)}")
    return tuple(specs)


# ---------------------------------------------------------------------------
# Compilation into the scan carry
# ---------------------------------------------------------------------------

def n_probe_samples(n_ticks: int, stride: Optional[int]) -> int:
    """Samples a probe emits over ``n_ticks``: one per tumbling window,
    the final partial window included."""
    s = n_ticks if stride is None else min(stride, n_ticks)
    return -(-n_ticks // s) if n_ticks else 0


def make_probe_step(probes: tuple, rec_shapes: dict, n_ticks: int):
    """Compile ``probes`` against the per-tick record layout.

    ``rec_shapes`` maps rec keys to abstract shapes (``jax.eval_shape``
    of the engine's tick).  Returns ``(init, step, finalize)``:

    * ``init`` — the probe subtree added to the scan carry (per probe: a
      window accumulator, a tick-in-window count and the (n_samples, ...)
      output buffer);
    * ``step(obs, rec, t) -> obs`` — traced inside the scan: folds this
      tick's signal into the accumulator and, at window ends, writes the
      reduced sample into the buffer and resets the window;
    * ``finalize(obs) -> {name: (n_samples, ...)}`` — the recorded
      timelines off the final carry.

    Windows are tumbling: sample s covers ticks [s*stride, (s+1)*stride)
    (the last window may be shorter; ``mean`` divides by the true tick
    count).  ``ema`` never resets — it is one continuous average over
    the whole run, sampled at window ends.
    """
    for p in probes:
        if p.key not in rec_shapes:
            raise KeyError(
                f"probe {p.name!r} reads rec key {p.key!r} which this "
                f"program's tick does not report; available keys: "
                f"{sorted(rec_shapes)}")

    compiled = []
    init = {}
    for p in probes:
        shape = tuple(rec_shapes[p.key].shape)
        stride = n_ticks if p.stride is None else min(p.stride, n_ticks)
        n_samples = n_probe_samples(n_ticks, p.stride)
        init[p.name] = {
            "acc": jnp.zeros(shape, jnp.float32),
            "cnt": jnp.zeros((), jnp.float32),
            "buf": jnp.zeros((max(n_samples, 1),) + shape, jnp.float32),
        }
        compiled.append((p, stride, n_samples))

    def step(obs, rec, t):
        new = dict(obs)
        for p, stride, n_samples in compiled:
            st = obs[p.name]
            v = rec[p.key].astype(jnp.float32)
            cnt = st["cnt"] + 1.0
            first = st["cnt"] == 0.0          # first tick of this window
            if p.op == "peak":
                acc = jnp.where(first, v, jnp.maximum(st["acc"], v))
            elif p.op in ("mean", "sum"):
                acc = jnp.where(first, v, st["acc"] + v)
            elif p.op == "ema":
                # continuous over the whole run: seed with the first
                # tick's value, never reset at window ends
                acc = jnp.where(st["acc_seen"] == 0.0, v,
                                p.alpha * v + (1.0 - p.alpha) * st["acc"])
            else:                             # last
                acc = v
            emit = acc / cnt if p.op == "mean" else acc
            # window end: the stride boundary or the run's final tick
            # (partial tail window)
            is_emit = ((t + 1) % stride == 0) | (t == n_ticks - 1)
            slot = jnp.minimum(t // stride, n_samples - 1)
            cur = jax.lax.dynamic_index_in_dim(st["buf"], slot,
                                               keepdims=False)
            buf = jax.lax.dynamic_update_index_in_dim(
                st["buf"], jnp.where(is_emit, emit, cur), slot, 0)
            keep = p.op == "ema"
            nxt = {
                "acc": acc if keep else jnp.where(is_emit,
                                                  jnp.zeros_like(acc), acc),
                "cnt": jnp.where(is_emit, 0.0, cnt),
                "buf": buf,
            }
            if keep:
                nxt["acc_seen"] = jnp.ones((), jnp.float32)
                nxt["cnt"] = cnt  # unused for ema, kept for pytree shape
            new[p.name] = nxt
        return new

    # ema carries an extra "seen" flag (its accumulator survives window
    # resets, so "cnt == 0" cannot mark the run's first tick)
    for p, _, _ in compiled:
        if p.op == "ema":
            init[p.name]["acc_seen"] = jnp.zeros((), jnp.float32)

    def finalize(obs) -> dict:
        return {p.name: obs[p.name]["buf"] for p, _, _ in compiled}

    return init, step, finalize


def make_batched_probe_step(probes: tuple, rec_shapes: dict, n_ticks: int,
                            batch: int):
    """``make_probe_step`` over a leading fleet/batch axis.

    The serving tier runs ``batch`` independent instances of one program
    under ``jax.vmap``; each instance carries its OWN probe accumulators
    and its own local tick counter (sessions start at different times, so
    the per-instance ``t`` drives each instance's window boundaries
    independently).  Returns ``(init, step, finalize)`` exactly as the
    unbatched compiler, except every tree leaf gains a leading ``batch``
    axis and ``step(obs, rec, t)`` takes batched rec/t:

    * ``init`` — the unbatched probe subtree broadcast to ``(batch, ...)``;
    * ``step(obs, rec, t)`` — ``vmap`` of the unbatched step: ``rec``
      leaves are ``(batch, ...)``, ``t`` is ``(batch,)`` int32 of each
      instance's local tick;
    * ``finalize(obs) -> {name: (batch, n_samples, ...)}``.

    Per instance the arithmetic is the unbatched fold verbatim, so slicing
    instance ``i`` out of every buffer equals running that instance alone
    — the property the probe tests pin.
    """
    init, step, finalize = make_probe_step(probes, rec_shapes, n_ticks)
    binit = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (batch,) + x.shape), init)
    bstep = jax.vmap(step, in_axes=(0, 0, 0))
    # finalize only gathers buffers out of the carry — it maps over the
    # batched tree unchanged, yielding (batch, n_samples, ...) timelines
    return binit, bstep, finalize


# ---------------------------------------------------------------------------
# The link-profile probe set (shared by both scale benchmarks)
# ---------------------------------------------------------------------------

def link_profile_probes() -> tuple:
    """Whole-run per-link peak/mean flit loads — the exact signals the
    congestion-aware-routing roadmap item consumes."""
    return (ProbeSpec("link_flits_peak", "link_flits", "peak", stride=None),
            ProbeSpec("link_flits_mean", "link_flits", "mean", stride=None))


def link_profile(program, probe_out: dict) -> dict:
    """Format whole-run link probes as the benchmark profile schema
    (identical to the pre-probe ``--profile-links`` JSON): per-link peak
    and mean flits plus the on-chip/chip-to-chip tier boundary."""
    noc = program.noc
    peak = np.asarray(probe_out["link_flits_peak"])[-1]
    mean = np.asarray(probe_out["link_flits_mean"])[-1]
    return {
        "n_onchip_links": int(getattr(noc, "n_onchip_links", noc.n_links)),
        "peak": np.round(peak, 2).tolist(),
        "mean": np.round(mean, 4).tolist(),
    }


def record_link_profile(sim, n_ticks: int, **run_kw) -> dict:
    """Run ``sim`` with only the link-profile probes (full per-tick
    records dropped — O(n_links) memory however long the run) and return
    the benchmark profile dict."""
    recs = sim.run(n_ticks, probes=link_profile_probes(),
                   keep_records=False, **run_kw)
    return link_profile(sim.program, recs["probes"])
