"""Serving-tier metrics: counters, gauges, log2 histograms + device-side
accumulators.

Two tiers, matching where the numbers are born:

* **host-side** — scheduler/queue events (admissions, preemptions,
  widths, wall-clock latencies) land in a small ``MetricsRegistry`` of
  ``Counter`` / ``Gauge`` / fixed-bucket log2 ``Histogram`` objects; no
  dynamic allocation per observation, so observing is O(1) and the
  registry can be sampled every scheduling round;
* **device-side** — per-tick record signals (spikes, packets, synaptic
  events) accumulate INSIDE the jitted round scan, riding the carry the
  same way ``ProbeSpec`` accumulators do (``make_device_metrics`` is the
  batched analogue of ``make_probe_step`` with per-instance reductions):
  one (width,) float32 leaf per metric, folded per tick, read back once
  per scheduling round — no host round-trip per tick.

``MetricsRegistry.snapshot()`` flattens everything to one
``{name: float}`` dict — the SAME numbers the SLO monitor evaluates,
``write_bench_json`` rows carry, and ``repro.obs.report`` gates on.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


class Counter:
    """Monotonic accumulator (events, joules, ticks)."""

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += float(n)


class Gauge:
    """Last-value metric that also remembers its peak (queue depth,
    fleet width, sessions/s)."""

    def __init__(self):
        self.value = 0.0
        self.peak = 0.0
        self._seen = False

    def set(self, v: float) -> None:
        v = float(v)
        self.value = v
        self.peak = v if not self._seen else max(self.peak, v)
        self._seen = True


class Histogram:
    """Fixed-bucket log2 histogram — the jit-friendly shape (static
    bucket count, O(1) observe) for long-tailed serving quantities.

    Bucket i counts observations in ``[scale * 2**i, scale * 2**(i+1))``;
    values below ``scale`` land in bucket 0, values off the top in the
    last bucket.  Percentiles are upper-bound estimates off the bucket
    edges (exact total/sum/max are tracked alongside), so a p99 is never
    under-reported — the right bias for latency SLOs.
    """

    def __init__(self, scale: float = 1e-6, n_buckets: int = 40):
        if scale <= 0 or n_buckets < 1:
            raise ValueError(f"need scale > 0 and n_buckets >= 1, got "
                             f"scale={scale} n_buckets={n_buckets}")
        self.scale = float(scale)
        self.counts = np.zeros(n_buckets, np.int64)
        self.count = 0
        self.sum = 0.0
        self.max = 0.0

    def bucket_of(self, v: float) -> int:
        if v < self.scale:
            return 0
        return min(int(math.floor(math.log2(v / self.scale))),
                   len(self.counts) - 1)

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[self.bucket_of(v)] += 1
        self.count += 1
        self.sum += v
        self.max = max(self.max, v)

    def percentile(self, p: float) -> float:
        """Upper edge of the bucket holding the p-th percentile (0.0
        when empty); the exact ``max`` caps the top bucket."""
        if self.count == 0:
            return 0.0
        target = math.ceil(self.count * p / 100.0)
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target))
        return min(self.scale * 2.0 ** (i + 1), self.max)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Get-or-create registry of named metrics with one flat snapshot."""

    def __init__(self):
        self._metrics: dict = {}

    def _get(self, name: str, cls, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(*args, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, scale: float = 1e-6,
                  n_buckets: int = 40) -> Histogram:
        return self._get(name, Histogram, scale, n_buckets)

    def names(self) -> list:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        """Flatten to ``{name: float}``: counters/gauges by name (gauges
        add ``_peak``), histograms as ``_p50`` / ``_p99`` / ``_mean`` /
        ``_max`` / ``_count`` — the dict the SLO monitor, BENCH rows and
        the report gate all consume."""
        out: dict = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out[name] = m.value
            elif isinstance(m, Gauge):
                out[name] = m.value
                out[f"{name}_peak"] = m.peak
            else:
                out[f"{name}_p50"] = m.percentile(50)
                out[f"{name}_p99"] = m.percentile(99)
                out[f"{name}_mean"] = m.mean
                out[f"{name}_max"] = m.max
                out[f"{name}_count"] = float(m.count)
        return out


# ---------------------------------------------------------------------------
# Device-side accumulators (ride the fleet's round scan carry)
# ---------------------------------------------------------------------------

DEVICE_METRIC_OPS = ("sum", "peak")


@dataclass(frozen=True)
class DeviceMetricSpec:
    """One per-instance reduction of a per-tick rec signal, accumulated
    inside the jitted round scan: ``sum`` (event totals, energy) or
    ``peak`` (high-water marks) over the round's ticks."""
    name: str
    key: str
    op: str = "sum"

    def __post_init__(self):
        if self.op not in DEVICE_METRIC_OPS:
            raise ValueError(f"device metric {self.name!r}: unknown op "
                             f"{self.op!r}; expected {DEVICE_METRIC_OPS}")


# the standard fleet set — filtered against the program's actual rec keys
FLEET_DEVICE_METRICS = (
    DeviceMetricSpec("spikes", "n_spk", "sum"),
    DeviceMetricSpec("packets", "packets", "sum"),
    DeviceMetricSpec("syn_events", "syn_events", "sum"),
    DeviceMetricSpec("pl", "pl", "peak"),
)


def device_metrics_for(rec_shapes: dict,
                       specs=FLEET_DEVICE_METRICS) -> tuple:
    """The subset of ``specs`` whose rec key this program reports."""
    return tuple(s for s in specs if s.key in rec_shapes)


def make_device_metrics(specs: tuple, width: int):
    """Compile ``specs`` into a batched fold for the fleet's round scan.

    Returns ``(init, step)``: ``init`` is ``{name: (width,) f32 zeros}``
    added to the scan carry for the round, ``step(acc, rec)`` folds one
    batched tick's rec in (each leaf ``(width, ...)``; the non-batch
    axes are reduced per instance).  The engine reads the accumulators
    back once per scheduling round — slot i is instance i's total, so
    padded (idle) slots are separable from real sessions.
    """
    init = {s.name: jnp.zeros((width,), jnp.float32) for s in specs}

    def step(acc, rec):
        out = dict(acc)
        for s in specs:
            v = rec[s.key].astype(jnp.float32).reshape(width, -1)
            if s.op == "sum":
                out[s.name] = acc[s.name] + v.sum(axis=1)
            else:
                out[s.name] = jnp.maximum(acc[s.name], v.max(axis=1))
        return out

    return init, step
