"""In-scan telemetry: probes, Perfetto traces, run manifests, bench diffs.

The real SpiNNaker 2 PE drives DVFS from live activity counters — per-PE
performance monitoring is an architectural feature, not an afterthought
(Mayr et al., arXiv:1911.02385).  This package is the simulator's
equivalent, in four layers:

* ``probes``   — declarative ``ProbeSpec``s compiled INTO the engine's
  ``lax.scan`` carry: sampling strides + windowed reductions (peak /
  mean / EMA / last) so board-scale runs record without host round-trips
  or per-tick memory blow-up.  Zero probes trace bitwise-identically to
  the bare engine.
* ``trace``    — export of recorded timelines to Chrome/Perfetto
  trace-event JSON (per-PE compute/DVFS tracks, per-NoC-tier flit
  counters, learn updates), viewable at https://ui.perfetto.dev.
* ``manifest`` — a provenance manifest (git sha, config hash, seed,
  jax/jaxlib versions, host) + host-side phase timers attached to every
  BENCH json artifact.
* ``report``   — ``python -m repro.obs.report A.json B.json`` diffs two
  BENCH artifacts and exits nonzero past a regression threshold (the CI
  regression gate).
"""
from repro.obs.health import (SloMonitor, SloRule, default_fleet_slos,
                              parse_slo)
from repro.obs.manifest import (PhaseTimers, bench_payload, config_hash,
                                run_manifest, write_bench_json)
from repro.obs.metrics import (Counter, DeviceMetricSpec, Gauge, Histogram,
                               MetricsRegistry, device_metrics_for,
                               make_device_metrics)
from repro.obs.probes import (PROBE_REGISTRY, ProbeSpec, default_probes,
                              link_profile, link_profile_probes,
                              record_link_profile, resolve_probes)
from repro.obs.spans import (SpanEvent, SpanLog, load_spans,
                             validate_spans)

__all__ = [
    "Counter", "DeviceMetricSpec", "Gauge", "Histogram",
    "MetricsRegistry", "PROBE_REGISTRY", "PhaseTimers", "ProbeSpec",
    "SloMonitor", "SloRule", "SpanEvent", "SpanLog", "bench_payload",
    "config_hash", "default_fleet_slos", "default_probes",
    "device_metrics_for", "diff_benches", "fleet_trace_events",
    "link_profile", "link_profile_probes", "load_spans",
    "make_device_metrics", "parse_slo", "record_link_profile",
    "resolve_probes", "run_manifest", "trace_events", "validate_spans",
    "write_bench_json", "write_fleet_trace", "write_trace",
]

_LAZY = {"diff_benches": "repro.obs.report",
         "trace_events": "repro.obs.trace",
         "fleet_trace_events": "repro.obs.trace",
         "write_fleet_trace": "repro.obs.trace",
         "write_trace": "repro.obs.trace"}


def __getattr__(name):
    # report/trace are also ``python -m`` entry points; importing them
    # eagerly here would trip runpy's double-import warning, so their
    # re-exports resolve on first use instead
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
