"""BENCH regression gate: diff two benchmark JSON artifacts.

    python -m repro.obs.report BASELINE.json FRESH.json \
        [--threshold 0.2] [--metric NAME[:direction[:threshold]]]... \
        [--warn-only]

Rows are matched by name; for each shared row the chosen metric is
compared as a ratio fresh/baseline, and any ratio above
``1 + threshold`` is a regression.  Exit status: 0 clean, 1 regressions
found (suppressed by ``--warn-only``), 2 malformed input / no
comparable rows — so CI can gate on it directly.

``--metric`` repeats: each occurrence gates one metric, optionally with
an inline direction and threshold overriding the global flags —

    --metric us_per_call --metric sessions_per_s:higher \
        --metric compile_s:lower:0.5

gates wall time (lower is good, global threshold), throughput (higher
is good), and compile time (lower, ±50%) in ONE invocation; the exit
code is the worst across all of them (2 only if *no* metric found
comparable rows).  The metric defaults to ``us_per_call`` (the per-row
wall time every ``benchmarks.common.emit`` records — tick_us for the
scale sweeps); any numeric key of a row's parsed ``values`` dict
(``compile_s``, ``partition_s``, ...) works too.  Both files'
provenance manifests are echoed so the report says what was actually
compared.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Optional


def _metric(row: dict, metric: str) -> Optional[float]:
    v = row.get(metric, row.get("values", {}).get(metric))
    return float(v) if isinstance(v, (int, float)) else None


def diff_benches(base: dict, new: dict, metric: str = "us_per_call",
                 threshold: float = 0.2, direction: str = "lower") -> dict:
    """Compare two ``bench_payload`` dicts row by row.

    ``direction`` says which way the metric is good: "lower" (wall
    times, ``peak_xlink_flits`` — a regression is ratio > 1 +
    threshold, the historical behavior) or "higher" (throughput,
    ``improvement`` — a regression is ratio < 1 / (1 + threshold)).

    Returns {"rows": [...], "regressions": [...], "missing": [...]} where
    each row entry is (name, base_value, new_value, ratio) and
    regressions are the subset past the threshold in the bad direction.
    """
    if direction not in ("lower", "higher"):
        raise ValueError(f"direction must be 'lower' or 'higher', "
                         f"got {direction!r}")
    base_rows = {r["name"]: r for r in base.get("rows", [])}
    new_rows = {r["name"]: r for r in new.get("rows", [])}
    rows, regressions = [], []
    for name in base_rows:
        if name not in new_rows:
            continue
        b = _metric(base_rows[name], metric)
        n = _metric(new_rows[name], metric)
        if b is None or n is None or b <= 0:
            continue
        ratio = n / b
        entry = {"name": name, "base": b, "new": n, "ratio": ratio}
        rows.append(entry)
        bad = (ratio > 1.0 + threshold if direction == "lower"
               else ratio < 1.0 / (1.0 + threshold))
        if bad:
            regressions.append(entry)
    missing = sorted(set(base_rows) - set(new_rows))
    return {"rows": rows, "regressions": regressions, "missing": missing}


def _describe(label: str, path: Path, payload: dict) -> None:
    man = payload.get("manifest", {})
    sha = (man.get("git_sha") or "?")[:12]
    when = man.get("timestamp_utc", "?")
    host = man.get("host", "?")
    jaxv = man.get("jax_version", payload.get("jax_version", "?"))
    print(f"# {label}: {path}  sha={sha}  jax={jaxv}  host={host}  {when}")


def parse_metric_spec(spec: str, direction: str = "lower",
                      threshold: float = 0.2) -> tuple:
    """``"NAME[:direction[:threshold]]"`` -> (name, direction,
    threshold), inheriting the global flags for omitted parts."""
    parts = spec.split(":")
    if len(parts) > 3 or not parts[0]:
        raise ValueError(f"bad metric spec {spec!r}; expected "
                         f"NAME[:direction[:threshold]]")
    name = parts[0]
    if len(parts) >= 2:
        if parts[1] not in ("lower", "higher"):
            raise ValueError(f"bad direction in metric spec {spec!r}; "
                             f"expected 'lower' or 'higher'")
        direction = parts[1]
    if len(parts) == 3:
        threshold = float(parts[2])
    return name, direction, threshold


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("baseline", type=Path)
    ap.add_argument("fresh", type=Path)
    ap.add_argument("--metric", action="append", default=None,
                    metavar="NAME[:direction[:threshold]]",
                    help="metric to gate; repeatable — each occurrence "
                         "may carry its own direction/threshold "
                         "(default: us_per_call with the global flags)")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="relative regression threshold (0.2 = +20%%)")
    ap.add_argument("--direction", choices=("lower", "higher"),
                    default="lower",
                    help="which way the metric is good: 'lower' (times, "
                         "peak_xlink_flits) or 'higher' (throughput)")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (CI advisory mode)")
    args = ap.parse_args(argv)

    try:
        specs = [parse_metric_spec(s, args.direction, args.threshold)
                 for s in (args.metric or ["us_per_call"])]
    except ValueError as e:
        print(f"# {e}", file=sys.stderr)
        return 2

    payloads = []
    for path in (args.baseline, args.fresh):
        try:
            payloads.append(json.loads(path.read_text()))
        except (OSError, json.JSONDecodeError) as e:
            print(f"# cannot read {path}: {e}", file=sys.stderr)
            return 2
    base, new = payloads
    _describe("baseline", args.baseline, base)
    _describe("fresh   ", args.fresh, new)

    compared = regressed = 0
    for metric, direction, threshold in specs:
        d = diff_benches(base, new, metric=metric,
                         threshold=threshold, direction=direction)
        if not d["rows"]:
            print(f"# no comparable rows for metric {metric!r}",
                  file=sys.stderr)
            continue
        compared += len(d["rows"])

        print(f"name,{metric}_base,{metric}_new,ratio  [{direction} "
              f"is good, +/-{threshold * 100:.0f}%]")
        for r in sorted(d["rows"], key=lambda r: -r["ratio"]):
            flag = "  <-- REGRESSION" if r in d["regressions"] else ""
            print(f"{r['name']},{r['base']:.3f},{r['new']:.3f},"
                  f"{r['ratio']:.3f}{flag}")
        if d["missing"]:
            print(f"# rows only in baseline (not compared): {d['missing']}")

        if d["regressions"]:
            regressed += len(d["regressions"])
            worst = max(r["ratio"] for r in d["regressions"])
            print(f"# {metric}: {len(d['regressions'])}/{len(d['rows'])} "
                  f"rows regressed past {threshold * 100:.0f}% "
                  f"(worst {worst:.2f}x)")
        else:
            print(f"# {metric}: all {len(d['rows'])} rows within "
                  f"{threshold * 100:.0f}%")

    if compared == 0:
        print("# no metric had comparable rows", file=sys.stderr)
        return 2
    if regressed:
        print(f"# TOTAL: {regressed} regression(s) across "
              f"{len(specs)} gated metric(s)")
        return 0 if args.warn_only else 1
    print(f"# TOTAL: {len(specs)} metric(s) gated, no regressions")
    return 0


if __name__ == "__main__":                                # pragma: no cover
    raise SystemExit(main())
