"""SLO health gate for the serving tier.

Declarative rules over the metrics snapshot — the SpiNNaker 2 system
papers treat live load/latency/energy monitoring as first-class at
machine scale; this is the serving tier's version of that loop:

    rules = (SloRule("req_latency_s_p99", "<=", 2.5, "critical"),
             SloRule("sessions_per_s", ">=", 5.0),
             SloRule("mj_per_request", "<=", 50.0))
    mon = SloMonitor(rules, spans=span_log)
    mon.check(metrics.snapshot(), round_i=r)     # every scheduling round
    mon.verdict(dropped=0, span_errors=[])       # final health verdict

``check`` evaluates every rule whose metric is present in the snapshot,
emits one structured ``slo`` event into the span log per violation
(level ``warn`` or ``critical``), and remembers the worst value seen
per rule.  ``verdict`` folds the rule history with two hard serving
invariants — no dropped sessions, no broken span chains — into the
final status: ``ok`` / ``warn`` / ``critical``.  A critical verdict is
CI-fatal in the serving smoke; warn is advisory.

Rules parse from compact specs (``"metric<=3.5"``,
``"metric>=10:critical"``) so benchmarks and CI can pass them as flags.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

SLO_LEVELS = ("warn", "critical")
_SPEC_RE = re.compile(r"^\s*([\w./]+)\s*(<=|>=)\s*([-+0-9.eE]+)"
                      r"\s*(?::(\w+))?\s*$")

_RANK = {"ok": 0, "warn": 1, "critical": 2}


@dataclass(frozen=True)
class SloRule:
    """``metric op threshold`` at a severity ``level``: the metric (a
    key of the registry snapshot) must stay ``<=`` or ``>=`` the
    threshold; a violation emits a span event at ``level``."""
    metric: str
    op: str                    # "<=" | ">="
    threshold: float
    level: str = "warn"

    def __post_init__(self):
        if self.op not in ("<=", ">="):
            raise ValueError(f"SLO rule {self.metric!r}: op must be '<=' "
                             f"or '>=', got {self.op!r}")
        if self.level not in SLO_LEVELS:
            raise ValueError(f"SLO rule {self.metric!r}: level must be "
                             f"one of {SLO_LEVELS}, got {self.level!r}")

    def ok(self, value: float) -> bool:
        return (value <= self.threshold if self.op == "<="
                else value >= self.threshold)

    @property
    def name(self) -> str:
        return f"{self.metric}{self.op}{self.threshold:g}"


def parse_slo(spec: str) -> SloRule:
    """``"metric<=3.5"`` / ``"metric>=10:critical"`` -> ``SloRule``."""
    m = _SPEC_RE.match(spec)
    if not m:
        raise ValueError(f"cannot parse SLO spec {spec!r}; expected "
                         f"METRIC<=X[:LEVEL] or METRIC>=X[:LEVEL]")
    metric, op, thr, level = m.groups()
    return SloRule(metric, op, float(thr), level or "warn")


def default_fleet_slos(max_req_p99_s: float = 60.0,
                       min_sessions_per_s: float = 0.0,
                       max_preempt_rate: float = 2.0,
                       max_mj_per_request: float = 1000.0) -> tuple:
    """The standard fleet rule set (latency / throughput / preemption /
    energy), with deliberately loose defaults — tighten per deployment;
    the defaults exist so every serve carries the full rule *shape*."""
    return (SloRule("req_latency_s_p99", "<=", max_req_p99_s, "warn"),
            SloRule("sessions_per_s", ">=", min_sessions_per_s, "warn"),
            SloRule("preempt_rate", "<=", max_preempt_rate, "warn"),
            SloRule("mj_per_request", "<=", max_mj_per_request, "warn"))


class SloMonitor:
    """Evaluate a rule set against metric snapshots, round by round."""

    def __init__(self, rules=(), spans=None):
        self.rules = tuple(parse_slo(r) if isinstance(r, str) else r
                           for r in rules)
        self.spans = spans
        self.violations: list = []
        self._per_rule: dict = {r.name: {"rule": r, "violations": 0,
                                         "worst": None}
                                for r in self.rules}

    def check(self, snapshot: dict, round_i: int = -1) -> list:
        """Evaluate every rule whose metric the snapshot carries;
        returns (and records) this round's violations."""
        hits = []
        for r in self.rules:
            v = snapshot.get(r.metric)
            if v is None or r.ok(float(v)):
                continue
            hit = {"rule": r.name, "metric": r.metric, "value": float(v),
                   "threshold": r.threshold, "level": r.level,
                   "round": int(round_i)}
            hits.append(hit)
            self.violations.append(hit)
            pr = self._per_rule[r.name]
            pr["violations"] += 1
            worse = (max if r.op == "<=" else min)
            pr["worst"] = (float(v) if pr["worst"] is None
                           else worse(pr["worst"], float(v)))
            if self.spans is not None:
                self.spans.emit("slo", round_i=round_i, **hit)
        return hits

    def verdict(self, dropped: int = 0, span_errors=()) -> dict:
        """The final health verdict: the worst rule level violated,
        escalated to ``critical`` by either hard invariant (dropped
        sessions, broken span chains)."""
        status = "ok"
        for hit in self.violations:
            status = max(status, hit["level"], key=_RANK.get)
        span_errors = list(span_errors)
        if dropped > 0 or span_errors:
            status = "critical"
        return {
            "status": status,
            "violations": len(self.violations),
            "dropped_sessions": int(dropped),
            "span_errors": span_errors,
            "rules": [{"rule": name, "level": pr["rule"].level,
                       "violations": pr["violations"],
                       "worst": pr["worst"]}
                      for name, pr in self._per_rule.items()],
        }
