"""Chrome/Perfetto trace-event export of recorded chip & board runs —
and of served-fleet span logs.

``trace_events(program, recs)`` turns the engine's per-tick records into
the Trace Event JSON format (https://ui.perfetto.dev loads it directly):

* one process per chip (boards) or one for the whole chip, one thread
  per PE named after its population and mesh coordinate;
* per-PE "X" slices on active ticks (multicast packets emitted), so the
  compute/communication rhythm of the workload is visible at a glance;
* per-PE "pl" counter tracks, delta-encoded, so DVFS transitions render
  as step functions;
* a NoC process with per-tier flit counters (on-chip vs the SerDes
  chip-to-chip tier) and traffic energy;
* per-slot learn-update counters (mean |dw| per tick) when the program
  is plastic.

``fleet_trace_events(payload)`` renders a serving-tier span log
(``repro.obs.spans.SpanLog.payload()``): a fleet process with
queue-depth / width / batched-tick-time / per-round-energy counter
tracks, a slots process with one thread per fleet slot carrying
per-round request slices, and a requests process with each session's
full lifecycle (queued / resident phases, preempt markers).

Also a CLI — the CI artifact paths:

    python -m repro.obs.trace --board 2x2 --chip 4x2 --workload hybrid \
        --ticks 64 --out artifacts/board_2x2.perfetto-trace.json
    python -m repro.obs.trace --fleet artifacts/fleet_spans.json \
        --gzip --out artifacts/serve_fleet.perfetto-trace.json
"""
from __future__ import annotations

import gzip as _gzip
import json
from pathlib import Path

import numpy as np

US_PER_TICK = 1e3      # trace ts unit is microseconds; 1 tick = 1 ms


def _pop_of_pe(program) -> list:
    names = [""] * program.n_pes
    for name, sl in program.pe_slices.items():
        for p in range(sl.start, sl.stop):
            names[p] = name
    return names


def _counter(events: list, pid: int, name: str, series: np.ndarray,
             t_sys_s: float, unit: str = "", scale: float = 1.0) -> None:
    """Delta-encoded counter track: one event at t=0, then only on value
    change (Perfetto renders counters as step functions, so skipping
    unchanged ticks loses nothing and keeps traces small)."""
    label = f"{name} [{unit}]" if unit else name
    prev = None
    for t, v in enumerate(np.asarray(series)):
        v = float(v) * scale
        if prev is not None and v == prev:
            continue
        events.append({"ph": "C", "pid": pid, "tid": 0, "name": label,
                       "ts": t * t_sys_s * 1e6, "args": {name: v}})
        prev = v


def trace_events(program, recs: dict, t_sys_s: float = 1e-3,
                 pes=None) -> dict:
    """Build the trace-event payload from a program and its run records.

    ``pes`` optionally restricts the per-PE tracks to a subset of
    logical PE ids (the NoC/learn tiers always export); default is every
    PE — fine up to a few hundred PEs x a few hundred ticks.
    """
    pl = np.asarray(recs["pl"])                    # (T, P)
    packets = np.asarray(recs["packets"])          # (T, P)
    T, P = pl.shape
    tick_us = t_sys_s * 1e6
    pops = _pop_of_pe(program)
    chip_of_pe = getattr(program, "chip_of_pe", None)
    board = getattr(program, "board", None)
    coords = np.asarray(getattr(program, "coords_local", None)
                        if chip_of_pe is not None else program.coords)
    pe_ids = range(P) if pes is None else [int(p) for p in pes]

    events: list = []

    # -- NoC process: per-tier flit counters + traffic energy --------------
    NOC_PID = 0
    events.append({"ph": "M", "pid": NOC_PID, "name": "process_name",
                   "args": {"name": "NoC"}})
    link_flits = np.asarray(recs["link_flits"])              # (T, L)
    for tier, mask in program.noc.tier_masks().items():
        _counter(events, NOC_PID, f"flits/{tier}",
                 link_flits @ np.asarray(mask, link_flits.dtype), t_sys_s)
    if "e_noc_xchip" in recs:
        _counter(events, NOC_PID, "e_noc_xchip", recs["e_noc_xchip"],
                 t_sys_s, unit="pJ", scale=1e12)
    _counter(events, NOC_PID, "e_noc", recs["e_noc"], t_sys_s,
             unit="pJ", scale=1e12)

    # -- learn process: per-slot update magnitude --------------------------
    slots = getattr(program, "learn_slots", ())
    if slots and "e_learn" in recs:
        LEARN_PID = 1
        events.append({"ph": "M", "pid": LEARN_PID, "name": "process_name",
                       "args": {"name": "learn"}})
        _counter(events, LEARN_PID, "e_learn",
                 np.asarray(recs["e_learn"]).sum(axis=-1), t_sys_s,
                 unit="pJ", scale=1e12)
        for s in slots:
            key = f"learn/{s.name}/dw"
            if key in recs:
                _counter(events, LEARN_PID, f"dw {s.name}", recs[key],
                         t_sys_s)

    # -- per-chip processes, per-PE threads --------------------------------
    PE_PID0 = 2
    if board is not None and chip_of_pe is not None:
        chips = np.asarray(chip_of_pe)
        for c in sorted(set(int(v) for v in chips)):
            cx, cy = board.chip_coord(c)
            events.append({"ph": "M", "pid": PE_PID0 + c,
                           "name": "process_name",
                           "args": {"name": f"chip {c} ({cx},{cy})"}})
    else:
        chips = np.zeros(P, np.int64)
        events.append({"ph": "M", "pid": PE_PID0, "name": "process_name",
                       "args": {"name": "chip"}})

    for p in pe_ids:
        pid = PE_PID0 + int(chips[p])
        x, y = (int(coords[p][0]), int(coords[p][1]))
        events.append({"ph": "M", "pid": pid, "tid": p,
                       "name": "thread_name",
                       "args": {"name": f"PE {p} {pops[p]}@({x},{y})"}})
        # active-tick slices: the workload's firing/streaming rhythm
        for t in np.flatnonzero(packets[:, p] > 0):
            events.append({
                "ph": "X", "pid": pid, "tid": p, "cat": "compute",
                "name": f"{pops[p]} tick", "ts": float(t) * tick_us,
                "dur": tick_us,
                "args": {"packets": int(packets[t, p]),
                         "pl": int(pl[t, p])}})
        # DVFS trajectory: one delta-encoded counter track per PE
        _counter(events, pid, f"pl PE{p}", pl[:, p], t_sys_s)

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"n_pes": P, "n_ticks": T,
                          "tick_ms": t_sys_s * 1e3}}


def _counter_at(events: list, pid: int, name: str, samples,
                unit: str = "") -> None:
    """Delta-encoded counter track over irregular (ts_us, value) samples
    — the span-log counters are per-round (variable wall-clock spacing),
    unlike the tick-indexed series ``_counter`` handles."""
    label = f"{name} [{unit}]" if unit else name
    prev = None
    for ts, v in samples:
        v = float(v)
        if prev is not None and v == prev:
            continue
        events.append({"ph": "C", "pid": pid, "tid": 0, "name": label,
                       "ts": float(ts), "args": {name: v}})
        prev = v


def fleet_trace_events(payload: dict) -> dict:
    """Render a served-fleet span log (``SpanLog.payload()`` /
    ``load_spans``) as trace events.

    Three processes: *fleet* (queue-depth / width / active / batched
    tick-time / round-energy counter tracks + SLO-violation instants),
    *slots* (one thread per fleet slot, an "X" slice per resident round
    named by the session occupying it), and *requests* (one thread per
    session: its queued and resident phases as slices, preempt/complete
    as instant markers) — the request-lifecycle view of the serve.
    """
    events: list = []
    counters = payload.get("counters", [])
    spans = payload.get("events", [])

    FLEET_PID, SLOT_PID, REQ_PID = 0, 1, 2
    events.append({"ph": "M", "pid": FLEET_PID, "name": "process_name",
                   "args": {"name": "fleet"}})
    events.append({"ph": "M", "pid": SLOT_PID, "name": "process_name",
                   "args": {"name": "slots"}})
    events.append({"ph": "M", "pid": REQ_PID, "name": "process_name",
                   "args": {"name": "requests"}})

    # -- fleet counter tracks (per-round samples, wall-clock spaced) -------
    tracks = (("queue_depth", ""), ("width", ""), ("n_active", ""),
              ("tick_us", "us"), ("energy_j", "J"), ("completed", ""))
    for key, unit in tracks:
        samples = [(c["t_s"] * 1e6, c[key]) for c in counters if key in c]
        if samples:
            _counter_at(events, FLEET_PID, key, samples, unit=unit)

    # -- per-slot round slices + per-request lifecycle ---------------------
    slots_seen: set = set()
    queued_at: dict = {}           # sid -> enqueue t_s
    resident_at: dict = {}         # sid -> admit/resume t_s
    req_tids: dict = {}            # sid -> stable tid on the request proc

    def req_tid(sid):
        if sid not in req_tids:
            req_tids[sid] = len(req_tids)
            events.append({"ph": "M", "pid": REQ_PID,
                           "tid": req_tids[sid], "name": "thread_name",
                           "args": {"name": f"sid {sid}"}})
        return req_tids[sid]

    for e in spans:
        kind, sid = e["kind"], e["sid"]
        t_us = e["t_s"] * 1e6
        args = e.get("args", {})
        if kind == "slo":
            events.append({"ph": "i", "pid": FLEET_PID, "tid": 0,
                           "name": f"SLO {args.get('rule', '?')}",
                           "ts": t_us, "s": "p", "cat": "slo",
                           "args": args})
            continue
        if sid < 0:
            continue
        if kind == "enqueue":
            queued_at[sid] = e["t_s"]
            req_tid(sid)
        elif kind in ("admit", "resume"):
            t0 = queued_at.pop(sid, None)
            if t0 is not None and e["t_s"] > t0:
                events.append({"ph": "X", "pid": REQ_PID,
                               "tid": req_tid(sid), "cat": "queued",
                               "name": "queued", "ts": t0 * 1e6,
                               "dur": (e["t_s"] - t0) * 1e6})
            resident_at[sid] = e["t_s"]
        elif kind == "round":
            slot = int(args.get("slot", 0))
            if slot not in slots_seen:
                slots_seen.add(slot)
                events.append({"ph": "M", "pid": SLOT_PID, "tid": slot,
                               "name": "thread_name",
                               "args": {"name": f"slot {slot}"}})
            start = args.get("start_s", e["t_s"])
            dur = max(args.get("dur_s", 0.0), 1e-7)
            events.append({"ph": "X", "pid": SLOT_PID, "tid": slot,
                           "cat": "round", "name": f"sid {sid}",
                           "ts": start * 1e6, "dur": dur * 1e6,
                           "args": {"width": args.get("width"),
                                    "ticks": args.get("ticks")}})
        elif kind in ("preempt", "suspend", "complete"):
            t0 = resident_at.pop(sid, None)
            if t0 is not None and e["t_s"] > t0:
                events.append({"ph": "X", "pid": REQ_PID,
                               "tid": req_tid(sid), "cat": "resident",
                               "name": "resident", "ts": t0 * 1e6,
                               "dur": (e["t_s"] - t0) * 1e6})
            events.append({"ph": "i", "pid": REQ_PID, "tid": req_tid(sid),
                           "name": kind, "ts": t_us, "s": "t",
                           "cat": "lifecycle", "args": args})

    meta = dict(payload.get("meta", {}))
    meta["n_requests"] = len(req_tids)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": meta}


def _write_payload(path, payload: dict, compress: bool = False) -> Path:
    """Write a trace-event payload, gzipped when ``compress`` is set or
    the path already ends in ``.gz`` (Perfetto loads both)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    blob = json.dumps(payload)
    if compress or path.suffix == ".gz":
        if path.suffix != ".gz":
            path = path.with_suffix(path.suffix + ".gz")
        path.write_bytes(_gzip.compress(blob.encode()))
    else:
        path.write_text(blob)
    print(f"# wrote {len(payload['traceEvents'])} trace events to {path} "
          f"(load at https://ui.perfetto.dev)")
    return path


def write_trace(path, program, recs: dict, t_sys_s: float = 1e-3,
                pes=None, compress: bool = False) -> Path:
    """Export a run to ``path`` as Perfetto-loadable trace-event JSON."""
    payload = trace_events(program, recs, t_sys_s=t_sys_s, pes=pes)
    return _write_payload(path, payload, compress=compress)


def write_fleet_trace(path, span_payload: dict,
                      compress: bool = False) -> Path:
    """Export a served-fleet span log as Perfetto trace-event JSON."""
    return _write_payload(path, fleet_trace_events(span_payload),
                          compress=compress)


def main(argv=None) -> int:
    """Run a small board workload and export its Perfetto trace — or,
    with ``--fleet SPANLOG``, render a recorded serving span log."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--board", default="2x2",
                    help="chip grid, e.g. 2x2 (default)")
    ap.add_argument("--chip", default="4x2", help="per-chip QPE mesh")
    ap.add_argument("--workload", default="hybrid",
                    choices=("hybrid", "synfire", "dnn"))
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--fleet", default=None, metavar="SPANLOG",
                    help="render a serving span log (SpanLog.write "
                         "output, .json or .json.gz) instead of running "
                         "a board workload")
    ap.add_argument("--gzip", action="store_true",
                    help="gzip the output trace (.gz appended if absent)")
    ap.add_argument("--out", default="artifacts/board.perfetto-trace.json")
    args = ap.parse_args(argv)

    if args.fleet is not None:
        from repro.obs.spans import load_spans
        write_fleet_trace(args.out, load_spans(args.fleet),
                          compress=args.gzip)
        return 0

    from repro.board import BoardSpec, compile_board
    from repro.chip.chip import ChipSim
    from repro.chip.workloads import (dnn_board_graph,
                                      hybrid_farm_board_graph,
                                      synfire_board_graph)
    builders = {"hybrid": hybrid_farm_board_graph,
                "synfire": synfire_board_graph, "dnn": dnn_board_graph}
    board = BoardSpec.parse(args.board, chip=args.chip)
    prog = compile_board(builders[args.workload](board), board)
    import jax
    recs = jax.block_until_ready(ChipSim(prog).run(args.ticks,
                                                   seed=args.seed))
    write_trace(args.out, prog, recs, compress=args.gzip)
    return 0


if __name__ == "__main__":                                # pragma: no cover
    raise SystemExit(main())
