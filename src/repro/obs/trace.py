"""Chrome/Perfetto trace-event export of recorded chip & board runs.

``trace_events(program, recs)`` turns the engine's per-tick records into
the Trace Event JSON format (https://ui.perfetto.dev loads it directly):

* one process per chip (boards) or one for the whole chip, one thread
  per PE named after its population and mesh coordinate;
* per-PE "X" slices on active ticks (multicast packets emitted), so the
  compute/communication rhythm of the workload is visible at a glance;
* per-PE "pl" counter tracks, delta-encoded, so DVFS transitions render
  as step functions;
* a NoC process with per-tier flit counters (on-chip vs the SerDes
  chip-to-chip tier) and traffic energy;
* per-slot learn-update counters (mean |dw| per tick) when the program
  is plastic.

Also a CLI — the CI artifact path:

    python -m repro.obs.trace --board 2x2 --chip 4x2 --workload hybrid \
        --ticks 64 --out artifacts/board_2x2.perfetto-trace.json
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

US_PER_TICK = 1e3      # trace ts unit is microseconds; 1 tick = 1 ms


def _pop_of_pe(program) -> list:
    names = [""] * program.n_pes
    for name, sl in program.pe_slices.items():
        for p in range(sl.start, sl.stop):
            names[p] = name
    return names


def _counter(events: list, pid: int, name: str, series: np.ndarray,
             t_sys_s: float, unit: str = "", scale: float = 1.0) -> None:
    """Delta-encoded counter track: one event at t=0, then only on value
    change (Perfetto renders counters as step functions, so skipping
    unchanged ticks loses nothing and keeps traces small)."""
    label = f"{name} [{unit}]" if unit else name
    prev = None
    for t, v in enumerate(np.asarray(series)):
        v = float(v) * scale
        if prev is not None and v == prev:
            continue
        events.append({"ph": "C", "pid": pid, "tid": 0, "name": label,
                       "ts": t * t_sys_s * 1e6, "args": {name: v}})
        prev = v


def trace_events(program, recs: dict, t_sys_s: float = 1e-3,
                 pes=None) -> dict:
    """Build the trace-event payload from a program and its run records.

    ``pes`` optionally restricts the per-PE tracks to a subset of
    logical PE ids (the NoC/learn tiers always export); default is every
    PE — fine up to a few hundred PEs x a few hundred ticks.
    """
    pl = np.asarray(recs["pl"])                    # (T, P)
    packets = np.asarray(recs["packets"])          # (T, P)
    T, P = pl.shape
    tick_us = t_sys_s * 1e6
    pops = _pop_of_pe(program)
    chip_of_pe = getattr(program, "chip_of_pe", None)
    board = getattr(program, "board", None)
    coords = np.asarray(getattr(program, "coords_local", None)
                        if chip_of_pe is not None else program.coords)
    pe_ids = range(P) if pes is None else [int(p) for p in pes]

    events: list = []

    # -- NoC process: per-tier flit counters + traffic energy --------------
    NOC_PID = 0
    events.append({"ph": "M", "pid": NOC_PID, "name": "process_name",
                   "args": {"name": "NoC"}})
    link_flits = np.asarray(recs["link_flits"])              # (T, L)
    for tier, mask in program.noc.tier_masks().items():
        _counter(events, NOC_PID, f"flits/{tier}",
                 link_flits @ np.asarray(mask, link_flits.dtype), t_sys_s)
    if "e_noc_xchip" in recs:
        _counter(events, NOC_PID, "e_noc_xchip", recs["e_noc_xchip"],
                 t_sys_s, unit="pJ", scale=1e12)
    _counter(events, NOC_PID, "e_noc", recs["e_noc"], t_sys_s,
             unit="pJ", scale=1e12)

    # -- learn process: per-slot update magnitude --------------------------
    slots = getattr(program, "learn_slots", ())
    if slots and "e_learn" in recs:
        LEARN_PID = 1
        events.append({"ph": "M", "pid": LEARN_PID, "name": "process_name",
                       "args": {"name": "learn"}})
        _counter(events, LEARN_PID, "e_learn",
                 np.asarray(recs["e_learn"]).sum(axis=-1), t_sys_s,
                 unit="pJ", scale=1e12)
        for s in slots:
            key = f"learn/{s.name}/dw"
            if key in recs:
                _counter(events, LEARN_PID, f"dw {s.name}", recs[key],
                         t_sys_s)

    # -- per-chip processes, per-PE threads --------------------------------
    PE_PID0 = 2
    if board is not None and chip_of_pe is not None:
        chips = np.asarray(chip_of_pe)
        for c in sorted(set(int(v) for v in chips)):
            cx, cy = board.chip_coord(c)
            events.append({"ph": "M", "pid": PE_PID0 + c,
                           "name": "process_name",
                           "args": {"name": f"chip {c} ({cx},{cy})"}})
    else:
        chips = np.zeros(P, np.int64)
        events.append({"ph": "M", "pid": PE_PID0, "name": "process_name",
                       "args": {"name": "chip"}})

    for p in pe_ids:
        pid = PE_PID0 + int(chips[p])
        x, y = (int(coords[p][0]), int(coords[p][1]))
        events.append({"ph": "M", "pid": pid, "tid": p,
                       "name": "thread_name",
                       "args": {"name": f"PE {p} {pops[p]}@({x},{y})"}})
        # active-tick slices: the workload's firing/streaming rhythm
        for t in np.flatnonzero(packets[:, p] > 0):
            events.append({
                "ph": "X", "pid": pid, "tid": p, "cat": "compute",
                "name": f"{pops[p]} tick", "ts": float(t) * tick_us,
                "dur": tick_us,
                "args": {"packets": int(packets[t, p]),
                         "pl": int(pl[t, p])}})
        # DVFS trajectory: one delta-encoded counter track per PE
        _counter(events, pid, f"pl PE{p}", pl[:, p], t_sys_s)

    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"n_pes": P, "n_ticks": T,
                          "tick_ms": t_sys_s * 1e3}}


def write_trace(path, program, recs: dict, t_sys_s: float = 1e-3,
                pes=None) -> Path:
    """Export a run to ``path`` as Perfetto-loadable trace-event JSON."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    payload = trace_events(program, recs, t_sys_s=t_sys_s, pes=pes)
    path.write_text(json.dumps(payload))
    print(f"# wrote {len(payload['traceEvents'])} trace events to {path} "
          f"(load at https://ui.perfetto.dev)")
    return path


def main(argv=None) -> int:
    """Run a small board workload and export its Perfetto trace."""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--board", default="2x2",
                    help="chip grid, e.g. 2x2 (default)")
    ap.add_argument("--chip", default="4x2", help="per-chip QPE mesh")
    ap.add_argument("--workload", default="hybrid",
                    choices=("hybrid", "synfire", "dnn"))
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--out", default="artifacts/board.perfetto-trace.json")
    args = ap.parse_args(argv)

    from repro.board import BoardSpec, compile_board
    from repro.chip.chip import ChipSim
    from repro.chip.workloads import (dnn_board_graph,
                                      hybrid_farm_board_graph,
                                      synfire_board_graph)
    builders = {"hybrid": hybrid_farm_board_graph,
                "synfire": synfire_board_graph, "dnn": dnn_board_graph}
    board = BoardSpec.parse(args.board, chip=args.chip)
    prog = compile_board(builders[args.workload](board), board)
    import jax
    recs = jax.block_until_ready(ChipSim(prog).run(args.ticks,
                                                   seed=args.seed))
    write_trace(args.out, prog, recs)
    return 0


if __name__ == "__main__":                                # pragma: no cover
    raise SystemExit(main())
