"""Partition one ``NetGraph`` across the chips of a board.

Populations are atomic (a population's tiles always share a chip — the
on-chip snake placement keeps them contiguous); the partitioner decides
which chip each population lives on, under each chip's PE-slot capacity
(the same ``assign_slots`` arithmetic the single-chip compiler uses, so
``align_qpe`` padding is accounted exactly, not estimated):

1. **greedy fill** — populations in graph order onto chips in snake
   order over the chip grid.  Graph builders order populations along the
   pipeline (ring order, layer order, nef-before-mlp), so consecutive
   populations land on the same or adjacent chips and most projections
   never cross a chip boundary.
2. **min-cut refinement** — a Kernighan-Lin-flavored greedy pass: move
   single populations toward their neighbors when that lowers the
   flit-weighted cut (flits per packet x src tiles x dst tiles x
   chip-grid hop distance) and the target chip has slack.  Deterministic;
   a 1x1 board is untouched (the single-chip golden anchor).

The result is a ``Partition``; ``repro.board.route.compile_board`` turns
it into placement + hierarchical routing.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.board.spec import BoardSpec
from repro.chip.graph import NetGraph
from repro.chip.mapping import assign_slots, snake_order
from repro.chip.mesh_noc import MeshSpec


@dataclass
class Partition:
    """Population -> chip assignment plus per-chip occupancy."""
    board: BoardSpec
    chip_of: dict                    # population name -> chip index
    chip_pops: list                  # per chip: populations, graph order
    slots_used: list                 # per chip: slots incl. align padding
    cut_flits: float                 # flit-weighted cut after refinement

    def chips_of_graph(self) -> np.ndarray:
        """(n_chips,) population counts — occupancy diagnostic."""
        return np.array([len(p) for p in self.chip_pops])


def _proj_weights(graph: NetGraph, payload_bits: int,
                  rates: dict | None = None) -> list:
    """(src, dst, flit-weighted traffic proxy) per projection: packets
    per source tile weigh their flit footprint (the engine's
    ``packet_flits`` formula over the board's flit payload size), every
    src tile multicasts to every dst tile.

    ``rates`` optionally replaces the static every-tile-fires-every-tick
    estimate with MEASURED packets/tick summed over the source
    population's tiles (``repro.routeopt.profile`` supplies it from the
    in-scan probes); populations without a measurement keep the static
    ``s.n_tiles`` proxy."""
    out = []
    for pr in graph.projections:
        flits = max(1, -(-pr.bits_per_packet // payload_bits))
        s, d = graph.population(pr.src), graph.population(pr.dst)
        rate = (rates or {}).get(pr.src, float(s.n_tiles))
        out.append((pr.src, pr.dst, float(flits * rate * d.n_tiles)))
    return out


def _cut(weights, chip_of, board: BoardSpec) -> float:
    """Flit-weighted cut: traffic proxy x chip-grid hop distance."""
    total = 0.0
    for s, d, w in weights:
        (ax, ay), (bx, by) = (board.chip_coord(chip_of[s]),
                              board.chip_coord(chip_of[d]))
        total += w * (abs(ax - bx) + abs(ay - by))
    return total


def _fits(pops, extra, mesh: MeshSpec) -> bool:
    """Would ``pops + [extra]`` fit the chip?  Exact — runs the
    compiler's own slot assignment, so ``align_qpe`` padding is charged
    the same way placement will charge it.  NOTE: ``assign_slots``
    totals are ORDER-dependent when ``align_qpe`` populations mix with
    plain ones, so callers must pass ``pops + [extra]`` in the order
    placement will use (the greedy fill appends in graph order, so a
    plain append is exact there; refinement re-sorts first)."""
    return assign_slots(pops + [extra], mesh.pes_per_qpe)[1] <= mesh.n_pes


def partition(graph: NetGraph, board: BoardSpec, refine: bool = True,
              max_passes: int = 2, rates: dict | None = None) -> Partition:
    """Assign each population to a chip (see module docstring).

    ``rates`` re-weights the min-cut refinement with measured per-
    population packet rates instead of the static flit estimate (see
    ``_proj_weights``); the greedy fill is rate-independent, so
    ``rates=None`` and any measurement agree bit-for-bit when
    refinement is off.

    Raises ``ValueError`` with the offending population / capacity totals
    when the graph cannot fit the board.
    """
    mesh = board.chip
    for pop in graph.populations:
        if not _fits([], pop, mesh):
            raise ValueError(
                f"population {pop.name!r} needs {pop.n_tiles} PE slots "
                f"(align_qpe={pop.align_qpe}) but one "
                f"{mesh.width}x{mesh.height} QPE chip holds only "
                f"{mesh.n_pes} PEs; split it into more populations or "
                f"use a bigger chip mesh")

    # 1. greedy fill, chips in snake order over the chip grid
    fill_order = snake_order(MeshSpec(board.chips_x, board.chips_y,
                                      pes_per_qpe=1))
    chip_pops: list = [[] for _ in range(board.n_chips)]
    chip_of: dict = {}
    cursor = 0
    for pop in graph.populations:
        while cursor < len(fill_order) and \
                not _fits(chip_pops[fill_order[cursor]], pop, mesh):
            cursor += 1
        if cursor == len(fill_order):
            need = sum(p.n_tiles for p in graph.populations)
            raise ValueError(
                f"graph {graph.name!r} ({need} tiles over "
                f"{len(graph.populations)} populations) does not fit the "
                f"{board.chips_x}x{board.chips_y} board of "
                f"{mesh.width}x{mesh.height} chips "
                f"({board.n_pes} PEs); use a bigger board")
        c = fill_order[cursor]
        chip_pops[c].append(pop)
        chip_of[pop.name] = c

    # 2. min-cut refinement: move populations toward their neighbors.
    # Only a move's incident edges change the cut, so each candidate is
    # scored in O(degree), not O(n_projections).
    weights = _proj_weights(graph, board.noc.payload_bits, rates)
    if refine and board.n_chips > 1 and weights:
        order = {p.name: i for i, p in enumerate(graph.populations)}
        incident: dict = {p.name: [] for p in graph.populations}
        for s, d, w in weights:
            if s != d:                       # self-edges never cross chips
                incident[s].append((d, w))
                incident[d].append((s, w))

        def local_cost(name, chip):
            cx, cy = board.chip_coord(chip)
            cost = 0.0
            for other, w in incident[name]:
                ox, oy = board.chip_coord(chip_of[other])
                cost += w * (abs(cx - ox) + abs(cy - oy))
            return cost

        def fits_in_graph_order(c, pop):
            """Capacity check against the EXACT population order the
            placer will use on chip c (align_qpe padding is
            order-dependent, so appending would validate a different
            slot total than placement charges)."""
            pops = sorted(chip_pops[c] + [pop], key=lambda p: order[p.name])
            return assign_slots(pops, mesh.pes_per_qpe)[1] <= mesh.n_pes

        for _ in range(max_passes):
            moved = False
            for pop in graph.populations:
                cur = chip_of[pop.name]
                cands = sorted({chip_of[n] for n, _ in incident[pop.name]}
                               - {cur})
                if not cands:
                    continue
                base = local_cost(pop.name, cur)
                best, best_cost = None, base
                for c in cands:
                    if not fits_in_graph_order(c, pop):
                        continue
                    cost = local_cost(pop.name, c)
                    if cost < best_cost - 1e-9:
                        best, best_cost = c, cost
                if best is not None:
                    chip_pops[cur].remove(pop)
                    chip_pops[best].append(pop)
                    chip_pops[best].sort(key=lambda p: order[p.name])
                    chip_of[pop.name] = best
                    moved = True
            if not moved:
                break

    used = [assign_slots(pops, mesh.pes_per_qpe)[1] for pops in chip_pops]
    return Partition(board=board, chip_of=chip_of, chip_pops=chip_pops,
                     slots_used=used,
                     cut_flits=_cut(weights, chip_of, board))
