"""Board-level multi-chip simulator (Mayr et al., arXiv:1911.02385).

The second tier of the system: a ``BoardSpec`` grid of SpiNNaker2 chips
joined by chip-to-chip links, a min-cut-flavored partitioner that splits
one ``NetGraph`` across chip boundaries under per-chip capacity, and
hierarchical routing that stitches on-chip X/Y multicast trees to
chip-to-chip hops into ONE board-wide CSR ``SparseIncidence`` — so the
unchanged, workload-agnostic ``ChipSim`` engine runs a whole board:

    from repro.board import BoardSpec, compile_board
    from repro.chip import ChipSim, chip_power_table
    from repro.chip.workloads import hybrid_farm_board_graph

    board = BoardSpec.parse("4x12", chip="4x2")      # 48 chips, 1536 PEs
    graph = hybrid_farm_board_graph(board)
    sim   = ChipSim(compile_board(graph, board))
    recs  = sim.run(64)          # + load_xchip / flits_xchip / e_noc_xchip
    table = chip_power_table(sim, recs)              # incl. noc["xchip"]

A 1x1 board is bit-identical to the single-chip ``compile`` + ``ChipSim``
path (tests/test_board.py) — the board layer adds tiers, not drift.
"""
from repro.board.partition import Partition, partition
from repro.board.route import BoardProgram, chip_tree, compile_board
from repro.board.spec import BoardNoc, BoardSpec, xlink_spec

__all__ = ["BoardSpec", "BoardNoc", "xlink_spec", "Partition", "partition",
           "BoardProgram", "chip_tree", "compile_board"]
