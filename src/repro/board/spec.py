"""Board-level topology: a grid of SpiNNaker2 chips joined by
chip-to-chip links (Mayr et al., arXiv:1911.02385 Sec. 2).

A board is ``chips_x x chips_y`` identical chips; each chip is the W x H
QPE mesh of ``repro.chip.mesh_noc.MeshSpec``, and adjacent chips are
joined by dedicated chip-to-chip links attached at fixed border "port"
QPEs.  The two link tiers carry the same 192-bit DNoC flits but price
differently: the chip-to-chip SerDes bridge is slower per hop and costs
an order of magnitude more energy per bit than an on-chip NoC hop, so
the partitioner's job (``repro.board.partition``) is to keep traffic on
the cheap tier.

``BoardNoc`` owns the board-global link id space — every chip's on-chip
links (one shared ``MeshNoc`` enumeration, offset per chip) followed by
the chip-to-chip links — and inherits ALL per-tick accounting from
``NocAccounting``, so the board-wide CSR ``SparseIncidence`` built by
``repro.board.route`` runs on the unchanged ``ChipSim`` engine.  Only
``traffic_energy_j`` is overridden: it prices the two tiers separately
from a (P, 2) per-source [on-chip, chip-to-chip] tree-link split, and
degenerates bitwise to the single-chip formula when a board has no
chip-to-chip links (the 1x1 golden anchor).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.chip.mesh_noc import MeshNoc, MeshSpec, NocAccounting
from repro.core.noc import NocSpec
from repro.configs import paper

# directions over the chip grid (and out of a chip's border ports)
EAST, WEST, NORTH, SOUTH = "E", "W", "N", "S"
DIRS = (EAST, WEST, NORTH, SOUTH)
OPPOSITE = {EAST: WEST, WEST: EAST, NORTH: SOUTH, SOUTH: NORTH}
DIR_STEP = {EAST: (1, 0), WEST: (-1, 0), NORTH: (0, 1), SOUTH: (0, -1)}


def xlink_spec() -> NocSpec:
    """Chip-to-chip link tier: same 192 b flit format crossing the
    bridge, but a serialized inter-chip hop costs ~8x the cycles of an
    on-chip router hop and ~1 pJ/bit against 0.08 pJ/bit on-chip
    (22FDSOI-class planning constants; Mayr et al. report 6 full-duplex
    chip-to-chip links per chip at a fraction of the NoC bandwidth)."""
    return NocSpec(hop_cycles=paper.NOC_HOP_CYCLES * 8,
                   pj_per_bit_hop=1.0)


@dataclass(frozen=True)
class BoardSpec:
    """``chips_x x chips_y`` grid of identical chips.

    ``chip`` is the per-chip QPE mesh; ``noc``/``xlink`` are the on-chip
    and chip-to-chip link tiers.  Chips index row-major: chip c sits at
    grid coordinate (c % chips_x, c // chips_x).
    """
    chips_x: int
    chips_y: int
    chip: MeshSpec = field(default_factory=lambda: MeshSpec(2, 2))
    noc: NocSpec = field(default_factory=NocSpec)
    xlink: NocSpec = field(default_factory=xlink_spec)
    # parallel SerDes bridges per chip edge: 1 (the historical mid-edge
    # port) keeps every link id bit-identical to the pre-multi-port
    # boards; >= 2 lets the profile-guided optimizer (repro.routeopt)
    # spread chip-to-chip traffic across border ports
    ports_per_edge: int = 1

    def __post_init__(self):
        k = self.ports_per_edge
        lim = min(self.chip.width, self.chip.height)
        if not 1 <= k <= lim:
            raise ValueError(
                f"ports_per_edge={k} out of range for a "
                f"{self.chip.width}x{self.chip.height} chip mesh; each "
                f"edge can host 1..{lim} distinct border port QPEs")

    @property
    def n_chips(self) -> int:
        return self.chips_x * self.chips_y

    @property
    def n_pes(self) -> int:
        return self.n_chips * self.chip.n_pes

    def chip_coord(self, c: int) -> tuple[int, int]:
        return (c % self.chips_x, c // self.chips_x)

    def chip_index(self, cx: int, cy: int) -> int:
        return cy * self.chips_x + cx

    def port(self, d: str, j: int = 0) -> tuple[int, int]:
        """Within-chip QPE coordinate of border port ``j`` serving the
        chip-to-chip links in direction ``d`` (j=0 is the historical
        mid-edge port)."""
        return self.ports(d)[j]

    def ports(self, d: str) -> list:
        """All ``ports_per_edge`` border port QPE coordinates on edge
        ``d``, evenly spread along it.  Port j on edge ``d`` bridges to
        port j on the neighbor's ``OPPOSITE[d]`` edge (the spread
        formula depends only on the perpendicular extent, so paired
        ports face each other).  ``ports_per_edge == 1`` reproduces the
        historical mid-edge ``port(d)`` exactly."""
        W, H = self.chip.width, self.chip.height
        k = self.ports_per_edge
        if d in (EAST, WEST):
            x = W - 1 if d == EAST else 0
            return [(x, (j + 1) * H // (k + 1)) for j in range(k)]
        y = H - 1 if d == NORTH else 0
        return [((j + 1) * W // (k + 1), y) for j in range(k)]

    @staticmethod
    def parse(board: str, chip: str = "2x2") -> "BoardSpec":
        """'4x12' board of '4x2' chips -> BoardSpec (CLI convenience)."""
        bx, by = (int(v) for v in board.lower().split("x"))
        cw, ch = (int(v) for v in chip.lower().split("x"))
        return BoardSpec(bx, by, chip=MeshSpec(cw, ch))


@dataclass
class BoardNoc(NocAccounting):
    """Board-global link space + tiered per-tick accounting.

    Link ids: chip c's on-chip links occupy
    ``[c * links_per_chip, (c+1) * links_per_chip)`` — the SAME
    enumeration ``MeshNoc`` uses for a single chip, so a 1x1 board's ids
    are bit-identical to the single-chip compiler's — followed by the
    directed chip-to-chip links.  ``xlink_mask`` (1.0 on chip-to-chip
    links) is what the engine uses for the per-tier record split.
    """
    board: BoardSpec
    link_load_impl: str = "auto"       # sparse kernel: see LINK_LOAD_IMPLS

    def __post_init__(self):
        self.spec = self.board.noc
        self.xspec = self.board.xlink
        self.chip_noc = MeshNoc(self.board.chip, spec=self.board.noc)
        self.links_per_chip = self.chip_noc.n_links
        self.n_onchip_links = self.board.n_chips * self.links_per_chip
        # directed chip-to-chip links, enumerated like MeshNoc's mesh
        # links: (chip index, outgoing direction, port j) -> global xlink
        # ordinal.  ports_per_edge == 1 reproduces the single-port
        # enumeration id-for-id (the j loop collapses to the old order).
        self.xlink_index: dict = {}
        self.xlinks: list = []
        bx, by = self.board.chips_x, self.board.chips_y
        k = self.board.ports_per_edge
        for cy in range(by):
            for cx in range(bx):
                if cx + 1 < bx:
                    for j in range(k):
                        self._add_xlink((cx, cy), EAST, j)
                        self._add_xlink((cx + 1, cy), WEST, j)
                if cy + 1 < by:
                    for j in range(k):
                        self._add_xlink((cx, cy), NORTH, j)
                        self._add_xlink((cx, cy + 1), SOUTH, j)
        self.n_xchip_links = len(self.xlinks)
        mask = np.zeros(self.n_links, np.float32)
        mask[self.n_onchip_links:] = 1.0
        self.xlink_mask = mask

    def _add_xlink(self, chip_xy, d, j):
        c = self.board.chip_index(*chip_xy)
        self.xlink_index[(c, d, j)] = len(self.xlinks)
        self.xlinks.append((c, d, j))

    @property
    def n_links(self) -> int:
        return self.n_onchip_links + self.n_xchip_links

    def chip_link_base(self, c: int) -> int:
        """Global id of chip c's first on-chip link."""
        return c * self.links_per_chip

    def xlink_id(self, c: int, d: str, j: int = 0) -> int:
        """Global link id of chip c's outgoing chip-to-chip link in
        direction d through border port j."""
        return self.n_onchip_links + self.xlink_index[(c, d, j)]

    def link_endpoints(self, link_id: int):
        """((chip, (x, y)), (chip, (x, y))) endpoints of any global link
        — the reference view the route property tests walk."""
        if link_id < self.n_onchip_links:
            c, local = divmod(link_id, self.links_per_chip)
            a, b = self.chip_noc.links[local]
            return (c, a), (c, b)
        c, d, j = self.xlinks[link_id - self.n_onchip_links]
        cx, cy = self.board.chip_coord(c)
        dx, dy = DIR_STEP[d]
        nbr = self.board.chip_index(cx + dx, cy + dy)
        return ((c, self.board.port(d, j)),
                (nbr, self.board.port(OPPOSITE[d], j)))

    def tier_masks(self) -> dict:
        """Two-tier twin of ``NocAccounting.tier_masks``: the cheap
        on-chip tier and the SerDes chip-to-chip tier, as 0/1 masks over
        the board-global link-id space (``repro.obs`` splits per-link
        records into per-tier tracks with these)."""
        return {"onchip": 1.0 - self.xlink_mask, "xchip": self.xlink_mask}

    # -- tiered pricing ---------------------------------------------------

    def traffic_energy_j(self, packets, tree_links, payload_bits):
        """Two-tier twin of ``NocAccounting.traffic_energy_j``:
        ``tree_links`` is the (P, 2) per-source [on-chip, chip-to-chip]
        link-count split (``BoardProgram.energy_tree_links``), each tier
        priced at its own pJ/bit-hop.  A board with no chip-to-chip
        links (1x1) takes the literal single-chip expression — not the
        two-term sum with a zero cross term — because XLA constant-folds
        the scalar chains of the two shapes differently (ULP drift), and
        the 1x1 anchor is BITWISE."""
        tl = jnp.asarray(tree_links, jnp.float32)
        pk = packets.astype(jnp.float32)
        pbits = self.packet_bits(payload_bits)
        bits_on = pk * tl[..., 0] * pbits
        if self.n_xchip_links == 0:
            return bits_on.sum(axis=-1) * self.spec.pj_per_bit_hop * 1e-12
        on = bits_on.sum(axis=-1) * self.spec.pj_per_bit_hop
        xc = (pk * tl[..., 1] * pbits).sum(axis=-1) * self.xspec.pj_per_bit_hop
        return (on + xc) * 1e-12

    def xchip_energy_j(self, packets, tree_links_x, payload_bits):
        """Chip-to-chip share of ``traffic_energy_j`` (the engine's
        ``e_noc_xchip`` record)."""
        bits = (packets.astype(jnp.float32)
                * jnp.asarray(tree_links_x, jnp.float32)
                * self.packet_bits(payload_bits))
        return bits.sum(axis=-1) * self.xspec.pj_per_bit_hop * 1e-12

    def path_latency_s(self, on_hops, x_hops) -> float:
        """Latency of a path with ``on_hops`` on-chip and ``x_hops``
        chip-to-chip hops, each tier at its own clock."""
        return (on_hops * self.spec.hop_cycles / self.spec.freq_hz
                + x_hops * self.xspec.hop_cycles / self.xspec.freq_hz)
