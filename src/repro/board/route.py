"""Hierarchical board routing: one ``NetGraph`` -> ``BoardProgram``.

``compile_board(graph, board)`` is the board-level twin of
``repro.chip.compile.compile``: it partitions the graph across chips
(``repro.board.partition``), snake-places each chip's populations with
the SAME slot arithmetic the single-chip compiler uses
(``place_partition``), and stitches each source's multicast route
hierarchically (``stitch_population``):

* **on the source chip** — the dimension-ordered multicast tree from the
  source tile to its local destinations PLUS the border port QPEs of
  every outgoing chip-to-chip direction the packet needs;
* **across chips** — a dimension-ordered multicast tree at CHIP
  granularity (the shared ``repro.core.noc.build_tree``, one level up):
  each edge is one chip-to-chip link through an assigned border port;
* **on every other chip the tree touches** — a tree from the entry
  port QPE to that chip's local destinations and onward exit ports.

Every free routing choice — tree orientation (X/Y vs Y/X, on-chip and
at chip granularity) and which of the board's parallel border ports
each exit uses — rides in a ``repro.routeopt.RouteConfig``; the default
(None) keeps the historical X-first / mid-edge-port routes bit-for-bit.
The profile-guided optimizer (``repro.routeopt.optimize_routes``)
searches that space against measured link loads; neuron-state records
are invariant under ALL of it because packets ride the routing-table
masks — incidence only prices links.

All stitched link ids land in ONE board-wide CSR ``SparseIncidence``
over ``BoardNoc``'s global link space, so the unchanged ``ChipSim``
tick loop — dense einsum or sparse column-plan/Pallas kernels — runs
the whole board, with per-tier flit/energy accounting riding on the
``xlink_mask``/``tree_links_x`` split.

Golden anchor: a 1x1 board IS the single-chip path — same slot
assignment, same snake coords, same link enumeration, same CSR — so
``compile_board(g, BoardSpec(1, 1, chip=mesh))`` is bit-identical to
``compile(g, mesh)`` end to end (tests/test_board.py).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.board.partition import Partition, partition
from repro.board.spec import (BoardNoc, BoardSpec, DIR_STEP, OPPOSITE)
from repro.chip.compile import (ChipProgram, check_tile_sram,
                                source_packet_classes)
from repro.chip.graph import NetGraph
from repro.chip.mapping import assign_slots, snake_coords
from repro.chip.mesh_noc import MeshSpec, SparseIncidence
from repro.core.noc import build_tree, oriented_route
from repro.core.pe import PESpec
from repro.core.router import RoutingTable
from repro.learn.lower import lower_plasticity
from repro.routeopt.config import RouteConfig


def _dir_of(a: tuple, b: tuple) -> str:
    step = (b[0] - a[0], b[1] - a[1])
    for d, s in DIR_STEP.items():
        if s == step:
            return d
    raise ValueError(f"chips {a} and {b} are not adjacent")


def chip_tree(board: BoardSpec, src_chip: int, dst_chips,
              orientation: str = "xy") -> dict:
    """Dimension-ordered multicast tree over the chip grid (the shared
    ``build_tree``, run at chip granularity).

    Returns {chip index: (entry_dir | None, sorted exit dirs)} for every
    chip the tree touches (the union of the dimension-ordered chip-level
    routes is a tree: each non-source chip has exactly one entry side).
    """
    nodes: dict = {src_chip: [None, set()]}
    sc = board.chip_coord(src_chip)
    dst_xy = [board.chip_coord(c) for c in sorted(set(dst_chips))]
    for a, b in build_tree(sc, dst_xy, orientation):
        ca, cb = board.chip_index(*a), board.chip_index(*b)
        d = _dir_of(a, b)
        nodes[ca][1].add(d)
        if cb not in nodes:
            nodes[cb] = [OPPOSITE[d], set()]
    return {c: (entry, sorted(exits)) for c, (entry, exits)
            in nodes.items()}


def _manhattan(a, b) -> int:
    return abs(int(a[0]) - int(b[0])) + abs(int(a[1]) - int(b[1]))


def place_partition(graph: NetGraph, board: BoardSpec, part: Partition):
    """Snake-place a partitioned graph: populations land on their
    assigned chip in graph order, each chip placed with the single-chip
    compiler's own slot arithmetic.

    Returns ``(pe_slices, coords_local, chip_of_pe, coords)``: the
    population -> logical-PE slice map, per-PE within-chip QPE coords,
    per-PE chip index, and board-global QPE coords.  Pure function of
    (graph, board, part) — the optimizer re-uses it to score candidate
    routings without recompiling."""
    chip_mesh = board.chip
    pe_slices: dict = {}
    cur = 0
    for pop in graph.populations:
        pe_slices[pop.name] = slice(cur, cur + pop.n_tiles)
        cur += pop.n_tiles
    n_pes = cur

    coords_local = np.zeros((n_pes, 2), np.int32)
    chip_of_pe = np.zeros(n_pes, np.int32)
    for c, pops in enumerate(part.chip_pops):
        if not pops:
            continue
        slots, _ = assign_slots(pops, chip_mesh.pes_per_qpe)
        pe_slot = []
        for pop in pops:
            a, b = slots[pop.name]
            pe_slot.extend(range(a, b))
        local = snake_coords(chip_mesh, pe_slot)
        off = 0
        for pop in pops:
            sl = pe_slices[pop.name]
            coords_local[sl] = local[off:off + pop.n_tiles]
            chip_of_pe[sl] = c
            off += pop.n_tiles
    chip_xy = np.array([board.chip_coord(c) for c in chip_of_pe])
    coords = coords_local + chip_xy * np.array(
        [chip_mesh.width, chip_mesh.height])
    return pe_slices, coords_local, chip_of_pe, coords


def population_dst_pes(graph: NetGraph, pe_slices: dict) -> dict:
    """Per source population, the concatenated destination PE ids in
    projection order (a 1x1 board concatenates exactly like the
    single-chip compiler)."""
    dst_slices: dict = {p.name: [] for p in graph.populations}
    for pr in graph.projections:
        dst_slices[pr.src].append(pe_slices[pr.dst])
    return {name: (np.concatenate([np.arange(s.start, s.stop)
                                   for s in sls])
                   if sls else np.empty(0, np.int64))
            for name, sls in dst_slices.items()}


def stitch_population(board: BoardSpec, noc: BoardNoc, name: str,
                      src_chip: int, by_chip: dict, tile_xy: np.ndarray,
                      route: RouteConfig):
    """Stitch one population's hierarchical multicast under a
    ``RouteConfig``.

    ``by_chip`` maps destination chip -> list of within-chip dst
    coords; ``tile_xy`` is the (n_tiles, 2) within-chip coords of the
    population's source tiles (all on ``src_chip``).  Returns
    ``(rows, hops, path_hops, n_x)``: per-tile global link-id rows, the
    per-tile worst hop depth, the per-tile latency-critical
    [on-chip, chip-to-chip] hop split, and the chip-to-chip link count
    (shared by every tile — they share one tree beyond the source PE).
    This is the ONE place routing choices turn into link ids; the
    optimizer calls it directly to score candidates exactly."""
    o_tree = route.orient_tree(name)
    tree = chip_tree(board, src_chip, by_chip.keys(),
                     orientation=route.orient_chip(name))
    empty = np.empty((0, 2), np.int64)

    def eport(c, d):
        return route.port_index(name, c, d)

    # tile-independent part: entry trees + outgoing xlinks of every
    # non-source chip, plus the source chip's own outgoing xlinks
    ext_parts: list = []
    n_x = 0
    for c in sorted(tree):
        entry, exits = tree[c]
        xids = np.array([noc.xlink_id(c, d, eport(c, d)) for d in exits],
                        np.int32)
        n_x += len(exits)
        if c == src_chip:
            ext_parts.append(xids)
            continue
        # ``entry`` is the side the packet arrives on; the entry PORT is
        # picked by the upstream chip's exit assignment (port j bridges
        # to port j on the facing edge)
        cx, cy = board.chip_coord(c)
        sx, sy = DIR_STEP[entry]
        up = board.chip_index(cx + sx, cy + sy)
        j_in = eport(up, OPPOSITE[entry])
        targets = ([np.asarray(by_chip.get(c, empty), np.int64)
                    .reshape(-1, 2)]
                   + [np.asarray([board.port(d, eport(c, d))], np.int64)
                      for d in exits])
        t = np.concatenate(targets) if targets else empty
        ids = noc.chip_noc.tree_link_ids(board.port(entry, j_in), t,
                                         orientation=o_tree)
        ext_parts.append(ids + noc.chip_link_base(c))
        ext_parts.append(xids)
    ext = (np.concatenate(ext_parts).astype(np.int32) if ext_parts
           else np.empty(0, np.int32))

    # per-destination-chip path costs shared by every source tile:
    # (first exit direction + port, hops beyond the source chip)
    local_dst = np.asarray(by_chip.get(src_chip, empty),
                           np.int64).reshape(-1, 2)
    remote: list = []
    sc_xy = board.chip_coord(src_chip)
    for c in sorted(by_chip):
        if c == src_chip:
            continue
        path = oriented_route(sc_xy, board.chip_coord(c),
                              route.orient_chip(name))
        dirs = [_dir_of(a, b) for a, b in path]
        js = [eport(board.chip_index(*a), dirs[i])
              for i, (a, _) in enumerate(path)]
        h = len(path)                       # one hop per xlink
        for i in range(1, len(path)):       # intermediate chips
            h += _manhattan(board.port(OPPOSITE[dirs[i - 1]], js[i - 1]),
                            board.port(dirs[i], js[i]))
        entry = board.port(OPPOSITE[dirs[-1]], js[-1])
        h += max(_manhattan(entry, d) for d in by_chip[c])
        remote.append((dirs[0], js[0], h, len(path)))

    # per-tile rows: local tree to local dests + exit ports, then ext
    src_exits = tree[src_chip][1]
    src_targets = np.concatenate(
        [local_dst] + [np.asarray([board.port(d, eport(src_chip, d))],
                                  np.int64)
                       for d in src_exits]) if (
        len(local_dst) or src_exits) else empty
    base = noc.chip_link_base(src_chip)
    n = len(tile_xy)
    rows: list = []
    hops = np.zeros(n, np.int32)
    path_hops = np.zeros((n, 2), np.int32)
    for i in range(n):
        t_xy = tile_xy[i]
        local_ids = noc.chip_noc.tree_link_ids(t_xy, src_targets,
                                               orientation=o_tree)
        rows.append(np.concatenate([local_ids + base, ext])
                    if ext.size else local_ids + base)
        h_local = int(np.abs(local_dst - t_xy).sum(axis=1).max()) \
            if len(local_dst) else 0
        # candidate delivery paths as (on-chip, chip-to-chip) hop
        # pairs — ``h`` counts every hop beyond the source chip, x
        # of which are chip-to-chip, so on-chip = tile part + h - x
        cands = [(h_local, 0)] + [
            (_manhattan(t_xy, board.port(d0, j0)) + h - x, x)
            for d0, j0, h, x in remote]
        hops[i] = max(on + x for on, x in cands)    # worst hop DEPTH
        # latency-critical path: the pair maximizing tiered latency
        path_hops[i] = max(
            cands, key=lambda c: noc.path_latency_s(c[0], c[1]))
    return rows, hops, path_hops, n_x


@dataclass
class BoardProgram(ChipProgram):
    """A compiled board workload — a ``ChipProgram`` whose link space
    spans every chip plus the chip-to-chip tier.

    ``coords`` are board-global QPE coordinates (chip origin at
    (cx * W, cy * H)) for reporting; routing used ``coords_local`` +
    ``chip_of_pe``.  Runs on the unchanged ``ChipSim``.
    """
    board: Optional[BoardSpec] = None
    part: Optional[Partition] = None
    chip_of_pe: Optional[np.ndarray] = None      # (P,) chip index per PE
    coords_local: Optional[np.ndarray] = None    # (P, 2) within-chip QPE
    tree_links_x: Optional[np.ndarray] = None    # (P,) chip-to-chip links
    # (P, 2) [on-chip hops, chip-to-chip hops] of each source's
    # latency-critical delivery path — ONE real path's split, chosen with
    # each tier at its own hop cost (NOT independent maxima, which could
    # pair hops from two different destinations into a path that does
    # not exist)
    path_hops: Optional[np.ndarray] = None
    route: Optional[RouteConfig] = None          # routing choices used

    @property
    def energy_tree_links(self) -> np.ndarray:
        """(P, 2) [on-chip, chip-to-chip] per-source link split — what
        the tiered ``BoardNoc.traffic_energy_j`` prices."""
        return np.stack([self.sinc.tree_links - self.tree_links_x,
                         self.tree_links_x], axis=-1)

    @property
    def tree_hops_x(self) -> np.ndarray:
        """(P,) chip-to-chip hops of each source's latency-critical
        path."""
        return self.path_hops[:, 1]

    @functools.cached_property
    def worst_path_latency_s(self) -> float:
        """Worst multicast delivery latency with each tier at its own
        hop cost (the single-chip ``hop_latency_s`` generalized)."""
        if not len(self.path_hops):
            return 0.0
        lat = self.noc.path_latency_s(self.path_hops[:, 0].astype(float),
                                      self.path_hops[:, 1].astype(float))
        return float(np.max(lat))


def compile_board(graph: NetGraph, board: Optional[BoardSpec] = None,
                  pe: PESpec = PESpec(), part: Optional[Partition] = None,
                  refine: bool = True,
                  route: Optional[RouteConfig] = None) -> BoardProgram:
    """Compile ``graph`` onto a multi-chip ``board``.

    ``board=None`` auto-sizes a near-square grid of the default 2x2-QPE
    chips.  ``part`` lets callers reuse / inspect a partition; otherwise
    ``repro.board.partition.partition`` runs (with ``refine``).
    ``route`` carries the free routing choices (tree orientations +
    border-port assignment, see ``repro.routeopt.RouteConfig``);
    ``None`` keeps the historical fixed routes bit-for-bit.
    Raises ``ValueError`` up front for SRAM / capacity violations, naming
    the population at fault (same contract as the single-chip compiler).
    """
    if graph.semantics is None:
        raise ValueError(f"graph {graph.name!r} has no tick semantics; "
                         "attach one before compiling")
    check_tile_sram(graph, pe)

    if board is None and part is not None:
        board = part.board
    if part is not None and part.board != board:
        raise ValueError(
            f"partition was built for a {part.board.chips_x}x"
            f"{part.board.chips_y} board of {part.board.chip.width}x"
            f"{part.board.chip.height} chips, not this board — "
            f"re-partition or pass the matching BoardSpec")
    if board is None:
        chip = MeshSpec(2, 2)
        for pop in graph.populations:       # unsatisfiable regardless of grid
            if assign_slots([pop], chip.pes_per_qpe)[1] > chip.n_pes:
                raise ValueError(
                    f"population {pop.name!r} needs more PE slots than one "
                    f"{chip.width}x{chip.height} QPE chip holds; pass an "
                    f"explicit BoardSpec with a bigger chip mesh")
        total = assign_slots(graph.populations, chip.pes_per_qpe)[1]
        side = max(1, int(np.ceil(np.sqrt(-(-total // chip.n_pes)))))
        while part is None:                 # grow until fragmentation fits
            board = BoardSpec(side, side, chip=chip)
            try:
                part = partition(graph, board, refine=refine)
            except ValueError:
                side += 1
    part = part or partition(graph, board, refine=refine)
    route = (route or RouteConfig()).validate(board)
    noc = BoardNoc(board)
    chip_mesh = board.chip

    # -- placement: snake within each chip, logical PEs in graph order ----
    pe_slices, coords_local, chip_of_pe, coords = \
        place_partition(graph, board, part)
    n_pes = len(coords)

    # -- routing table + packet classes (same contract as compile()) ------
    out_bits = source_packet_classes(graph)
    masks = np.zeros((n_pes, n_pes), bool)
    payload_bits = np.zeros(n_pes, np.int64)
    for pr in graph.projections:
        masks[pe_slices[pr.src], pe_slices[pr.dst]] = True
        payload_bits[pe_slices[pr.src]] = out_bits[pr.src]
    table = RoutingTable(masks)

    # -- hierarchical incidence: per population, shared by its tiles ------
    rows: list = [None] * n_pes
    hops = np.zeros(n_pes, np.int32)
    tl_x = np.zeros(n_pes, np.int64)
    path_hops = np.zeros((n_pes, 2), np.int32)
    dst_pes = population_dst_pes(graph, pe_slices)

    for pop in graph.populations:
        sl = pe_slices[pop.name]
        src_chip = int(chip_of_pe[sl.start])
        by_chip: dict = {}
        for p in dst_pes[pop.name]:
            by_chip.setdefault(int(chip_of_pe[p]), []).append(
                coords_local[p])
        p_rows, p_hops, p_ph, n_x = stitch_population(
            board, noc, pop.name, src_chip, by_chip, coords_local[sl],
            route)
        rows[sl.start:sl.stop] = p_rows
        hops[sl] = p_hops
        path_hops[sl] = p_ph
        tl_x[sl] = n_x

    sinc = SparseIncidence.from_rows(rows, noc.n_links, hops)

    sram = np.zeros(n_pes, np.int64)
    for pop in graph.populations:
        sram[pe_slices[pop.name]] = pop.sram_bytes

    return BoardProgram(graph=graph, mesh=chip_mesh, noc=noc,
                        coords=coords.astype(np.int32), table=table,
                        sinc=sinc, payload_bits=payload_bits,
                        sram_bytes=sram, pe_slices=pe_slices,
                        learn_slots=lower_plasticity(graph, pe_slices),
                        board=board, part=part, chip_of_pe=chip_of_pe,
                        coords_local=coords_local, tree_links_x=tl_x,
                        path_hops=path_hops, route=route)
