"""Event-triggered MAC layer — the paper's hybrid SNN/DNN mechanism
(Sec. II: "the MAC array could be run not frame-based, but in an
event-triggered fashion ... graded weight x graded activity-related input").

A batch of graded spike events (values + active mask) hits an int8 weight
matrix; only active rows are dispatched to the MAC array.  Dispatch uses
the same sort-to-capacity scheme as the MoE router (models/moe.py) — both
are instances of SpiNNaker2 multicast: keys pick destinations, payloads are
graded values.

Energy: proportional to dispatched events (activity), not to the frame
size — the DVFS principle applied to the MAC datapath.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper
from repro.core.quant import quantize_per_axis
from repro.kernels.mac_gemm.ops import mac_gemm


def event_mac(values, active, wq, w_scale, *, capacity=None, interpret=True):
    """values: (T, K) float graded payloads; active: (T,) bool event mask;
    wq: (K, N) int8.  Returns (out (T, N) f32, n_dispatched).

    Inactive rows produce exact zeros and are never multiplied: active rows
    are compacted to a fixed-capacity buffer (sorted dispatch), multiplied,
    and scattered back.
    """
    T, K = values.shape
    C = capacity or T
    idx = jnp.nonzero(active, size=C, fill_value=T)[0]       # (C,)
    src = jnp.concatenate([values, jnp.zeros((1, K), values.dtype)], axis=0)
    dispatched = src[idx]                                    # (C, K)
    xq, x_scale = quantize_per_axis(dispatched, axis=1)
    acc = mac_gemm(xq, wq, interpret=interpret)
    yq = acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]
    out = jnp.zeros((T + 1, wq.shape[1]), jnp.float32).at[idx].set(yq)
    return out[:T], jnp.sum(active.astype(jnp.int32))


def event_mac_tick(spikes, w_eff):
    """One tick of the event-triggered MAC: accumulate one weight row per
    spiking input ("graded weight x activity-related input", Sec. II).

    spikes: (K,) 0/1 event vector arriving this tick; w_eff: (K, N) f32
    dequantized weights.  Returns (out (N,), n_events) — ticks with no
    events produce exact zeros and dispatch nothing, which is what the
    per-tick chip engine (repro.chip) prices: energy follows activity.
    """
    s = spikes.astype(jnp.float32)
    n_events = s.sum().astype(jnp.int32)
    return s @ w_eff, n_events


def event_mac_energy_j(n_events, k, n, *, tops_per_w=None):
    """Energy of event-triggered MAC ops from the paper's measured
    efficiency (Fig. 15: 1.47 TOPS/W at PL2, x1.56 hardware bug factor)."""
    tops_per_w = tops_per_w or paper.MAC_TOPS_PER_W[(0.50, 200e6)]
    ops = 2.0 * float(n_events) * k * n
    return ops / (tops_per_w * 1e12)


def frame_mac_energy_j(t, k, n, **kw):
    return event_mac_energy_j(t, k, n, **kw)
