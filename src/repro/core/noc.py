"""NoC model (paper Sec. III-A): 2D-mesh X/Y-first routing, QPE tiles,
DNoC/CNoC packet cost accounting.

Used for (a) spike-traffic energy/latency accounting in the SNN engine and
(b) cross-checking the dry-run's ICI collective model: a mesh collective is
priced as the sum of link traversals its packets make under X/Y routing —
the same arithmetic the SpiNNaker2 DNoC performs per 192-bit flit.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs import paper


@dataclass(frozen=True)
class NocSpec:
    flit_bits: int = paper.DNOC_FLIT_BITS
    hop_cycles: int = paper.NOC_HOP_CYCLES
    freq_hz: float = paper.NOC_FREQ_HZ
    payload_bits: int = paper.NOC_PAYLOAD_BITS_MAX
    pj_per_bit_hop: float = 0.08          # planning constant, 22FDSOI-class


def xy_route(src: tuple, dst: tuple):
    """X-first then Y. Returns list of hops ((x,y) -> (x,y))."""
    (x0, y0), (x1, y1) = src, dst
    path = []
    x, y = x0, y0
    while x != x1:
        nx = x + (1 if x1 > x else -1)
        path.append(((x, y), (nx, y)))
        x = nx
    while y != y1:
        ny = y + (1 if y1 > y else -1)
        path.append(((x, y), (x, ny)))
        y = ny
    return path


def hops(src: tuple, dst: tuple) -> int:
    return abs(src[0] - dst[0]) + abs(src[1] - dst[1])


# Dimension-ordered routing comes in two legal orientations: X-then-Y
# (the classic default) and its Y-then-X mirror.  Which one a source uses
# is a free routing parameter — both deliver every destination — and the
# profile-guided optimizer (repro.routeopt) picks per source whichever
# spreads measured congestion better.
ORIENTATIONS = ("xy", "yx")


def oriented_route(src: tuple, dst: tuple, orientation: str = "xy"):
    """``xy_route`` with the trunk dimension as a parameter: "xy" routes
    X first (the historical fixed choice), "yx" routes Y first.  Returns
    the same hop-pair list format."""
    if orientation == "xy":
        return xy_route(src, dst)
    if orientation != "yx":
        raise ValueError(f"unknown orientation {orientation!r}; "
                         f"expected one of {ORIENTATIONS}")
    swapped = xy_route((src[1], src[0]), (dst[1], dst[0]))
    return [((a[1], a[0]), (b[1], b[0])) for a, b in swapped]


def build_tree(src: tuple, dsts, orientation: str = "xy"):
    """Directed edge list of the dimension-ordered multicast tree
    ``src -> dsts`` — the ONE shared tree builder both the on-chip NoC
    (``MeshNoc.tree_link_ids`` validates its arithmetic form against it)
    and the board stitcher (``repro.board.route.chip_tree`` runs it at
    chip granularity) parameterize by orientation, instead of each
    hard-coding X-first.

    The union of dimension-ordered routes is a tree (the router
    duplicates at branch points, never rejoins): shared prefixes are
    deduplicated, edges keep first-seen order so every edge's tail is
    already reachable when it appears.
    """
    seen: set = set()
    edges = []
    s = (int(src[0]), int(src[1]))
    for d in dsts:
        d = (int(d[0]), int(d[1]))
        if d == s:
            continue
        for e in oriented_route(s, d, orientation):
            if e not in seen:
                seen.add(e)
                edges.append(e)
    return edges


def multicast_links(src: tuple, dsts) -> int:
    """Number of distinct links traversed by an X/Y multicast tree — the
    router duplicates packets at branch points (Sec. III-B), so shared
    prefixes are paid once."""
    links = set()
    for d in dsts:
        links.update(xy_route(src, d))
    return len(links)


@dataclass(frozen=True)
class NocModel:
    spec: NocSpec = NocSpec()

    def packet_latency_s(self, src, dst) -> float:
        return hops(src, dst) * self.spec.hop_cycles / self.spec.freq_hz

    def spike_energy_j(self, src, dsts) -> float:
        """One multicast spike packet (header-only, 64b effective)."""
        nlinks = multicast_links(src, dsts)
        return nlinks * 64 * self.spec.pj_per_bit_hop * 1e-12

    def payload_energy_j(self, src, dsts, payload_bits) -> float:
        nflits = -(-payload_bits // self.spec.payload_bits)
        nlinks = multicast_links(src, dsts)
        return nlinks * nflits * self.spec.flit_bits \
            * self.spec.pj_per_bit_hop * 1e-12

    def collective_link_bytes(self, kind: str, nbytes: int, n: int) -> float:
        """Per-device link bytes of a ring collective over n devices — used
        to cross-check the HLO collective parser against a first-principles
        NoC count."""
        if n <= 1:
            return 0.0
        if kind == "all-gather":
            return nbytes * (n - 1) / n
        if kind == "reduce-scatter":
            return nbytes * (n - 1) / n
        if kind == "all-reduce":
            return 2.0 * nbytes * (n - 1) / n
        if kind == "all-to-all":
            return nbytes * (n - 1) / n
        if kind == "collective-permute":
            return float(nbytes)
        raise ValueError(kind)
