from repro.core import (dvfs, energy, hybrid, nef, noc, packets, pe,
                        quant, router, snn)
