"""Energy models.

1. ``PEEnergyModel`` — Eq. (1) of the paper with the measured Table I
   parameters: per-tick PE energy as baseline power at the active PL during
   the busy window t_sp, baseline power at PL1 for the idle remainder, plus
   per-neuron-update and per-synaptic-event energies.

2. ``TPUEnergyModel`` — the same "energy follows activity" principle lifted
   to the framework level: a compiled step's energy is estimated from its
   roofline terms (FLOPs / HBM bytes / ICI bytes) plus idle power for the
   un-overlapped remainder.  This is what every dry-run cell reports.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.configs import paper


@dataclass(frozen=True)
class PEEnergyModel:
    pls: tuple = paper.PERF_LEVELS
    t_sys_s: float = 1e-3
    cycles_per_neuron: int = paper.CYCLES_PER_NEURON_UPDATE
    cycles_per_syn: int = paper.CYCLES_PER_SYN_EVENT
    cycles_overhead: int = paper.CYCLES_TICK_OVERHEAD

    def t_sp(self, pl_idx, n_neur, n_syn_events):
        """Busy time within a tick at PL pl_idx (vectorized, seconds)."""
        freqs = jnp.asarray([p.freq_hz for p in self.pls])
        cycles = (self.cycles_overhead
                  + self.cycles_per_neuron * n_neur
                  + self.cycles_per_syn * n_syn_events)
        t = cycles / freqs[pl_idx]
        return jnp.minimum(t, self.t_sys_s)

    def tick_energy(self, pl_idx, n_neur, n_syn_events, *, dvfs=True):
        """Eq. (1).  Returns dict of energy components [J] (vectorized).

        dvfs=False models "only PL3": the PE never returns to PL1 while
        idle, so baseline power is P_BL,3 for the whole tick.
        """
        p_bl = jnp.asarray([p.p_baseline_w for p in self.pls])
        e_neur = jnp.asarray([p.e_neuron_j for p in self.pls])
        e_syn = jnp.asarray([p.e_synapse_j for p in self.pls])
        tsp = self.t_sp(pl_idx, n_neur, n_syn_events)
        if dvfs:
            base = p_bl[pl_idx] * tsp + p_bl[0] * (self.t_sys_s - tsp)
        else:
            base = p_bl[pl_idx] * self.t_sys_s
        return {
            "baseline": base,
            "neuron": e_neur[pl_idx] * n_neur,
            "synapse": e_syn[pl_idx] * n_syn_events,
            "t_sp": tsp,
        }


@dataclass(frozen=True)
class TPUEnergyModel:
    chip: paper.ChipSpec = paper.TPU_V5E

    def step_energy(self, *, flops, hbm_bytes, ici_bytes, step_time_s,
                    n_chips=1):
        """Per-step energy estimate [J] from roofline terms.

        step_time_s: the max of the three roofline terms (or a measured
        time); idle power covers the un-overlapped remainder — the direct
        analogue of Eq. (1)'s P_BL * (t_sys - t_sp).
        """
        c = self.chip
        dyn = (flops * c.pj_per_flop_bf16
               + hbm_bytes * c.pj_per_hbm_byte
               + ici_bytes * c.pj_per_ici_byte) * 1e-12
        idle = c.idle_power_w * step_time_s
        return {
            "dynamic": dyn * n_chips if np.ndim(dyn) == 0 else dyn,
            "idle": idle * n_chips,
            "total": (dyn + idle) * n_chips,
        }

    def tokens_per_joule(self, tokens, energy_j):
        return tokens / max(energy_j, 1e-12)
