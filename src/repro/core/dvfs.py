"""Activity-driven DVFS controller (paper Sec. VI-B, Table II).

Each 1 ms tick, the PE inspects the number of spikes waiting in its inbound
FIFO and selects a performance level BEFORE processing:

    n < l_th1          -> PL1 (0.5 V, 100 MHz)
    l_th1 <= n < l_th2 -> PL2 (0.5 V, 200 MHz)
    n >= l_th2         -> PL3 (0.6 V, 400 MHz)

After the busy window the PE drops back to PL1 and sleeps until the next
timer tick (modeled in PEEnergyModel.tick_energy).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from repro.configs import paper


@dataclass(frozen=True)
class DVFSController:
    l_th1: int = paper.SYNFIRE.l_th1
    l_th2: int = paper.SYNFIRE.l_th2

    def select_pl(self, n_spikes):
        """n_spikes: int array -> PL index array (0-based: 0=PL1,1=PL2,2=PL3)."""
        n = jnp.asarray(n_spikes)
        return ((n >= self.l_th1).astype(jnp.int32)
                + (n >= self.l_th2).astype(jnp.int32))

    def freq_hz(self, pl_idx):
        freqs = jnp.asarray([p.freq_hz for p in paper.PERF_LEVELS])
        return freqs[pl_idx]


@dataclass(frozen=True)
class QueueDVFS:
    """Framework-level analogue for serving: request-queue depth selects the
    execution level (decode batch width), mirroring spike-FIFO -> PL.

    Levels are (max_batch, relative_throughput) tuples; thresholds are queue
    depths, directly analogous to l_th1/l_th2.
    """
    thresholds: tuple = (4, 16)
    batch_levels: tuple = (8, 32, 128)

    def select_level(self, queue_depth: int) -> int:
        lvl = 0
        for t in self.thresholds:
            if queue_depth >= t:
                lvl += 1
        return lvl

    def batch_size(self, queue_depth: int) -> int:
        return self.batch_levels[self.select_level(queue_depth)]
