"""int8 quantization for the MAC-array compute path (W8A8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mac_gemm.ops import mac_gemm


def quantize_per_axis(x, axis: int, bits: int = 8):
    """Symmetric per-slice quantization along `axis` (the contraction's
    counterpart axis keeps its own scale).  Returns (q int8, scale f32)."""
    qmax = 2 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis=axis).astype(jnp.float32)


def quantized_linear(x, wq, w_scale, *, interpret=True):
    """x: (M, K) float; wq: (K, N) int8 with per-col w_scale (N,).

    Activations are quantized per-row on the fly (the MAC array's graded
    "spike payload"), multiplied in int8 with int32 accumulation, then
    rescaled — the W8A8 serve path.
    """
    xq, x_scale = quantize_per_axis(x, axis=1)
    acc = mac_gemm(xq, wq, interpret=interpret)
    return acc.astype(jnp.float32) * x_scale[:, None] * w_scale[None, :]


def quantize_params_linear(w):
    """w: (K, N) float -> (int8, per-col scale)."""
    return quantize_per_axis(w, axis=0)
