"""Event-driven SNN engine + the synfire-chain benchmark (paper Sec. VI-B).

Faithful to the paper's processing model: each PE simulates its neurons
once per 1 ms timer tick; inbound spikes sit in a FIFO until the next tick;
the FIFO occupancy picks the performance level (core/dvfs.py) BEFORE
processing; after the busy window t_sp the PE returns to PL1 and sleeps.

Arithmetic is SpiNNaker-style s16.15 fixed point: the LIF update uses
exactly the kernel math (kernels/lif/ref.py — bit-identical to the Pallas
kernel), the membrane decay constant comes from the exp accelerator
(kernels/explog), and synaptic-event accumulation is an integer matmul —
the event-driven MAC-array mode of Sec. II.

The synfire chain (Fig. 16, Table II): 8 PEs in a ring; per PE one
excitatory population (200) and one inhibitory population (50); exc of PE i
projects to exc+inh of PE i+1 with 10 ms delay (fan-in 60); inh projects to
exc of the same PE with 8 ms delay (fan-in 25); normally distributed noise
current; a stimulus pulse packet kick-starts PE 0.

Spike delay lines are stored bit-packed (one uint32 word per 32 neurons,
``pack_spikes``/``unpack_spikes``): the d×P×n int32 ring buffers were the
dominant per-tick cost at 4096 PEs (XLA copies the whole multi-MB carry on
every ``.at[t % d].set``), and packing shrinks them 32×.  Packing is exact
for 0/1 spike values, so dense and event mode share the same buffers.

``make_synfire_tick(..., event=True)`` builds the activity-compressed tick
(ISSUE 8): the per-tick input set — PEs with spike arrivals, noise kicks
or stimulus — is compacted into a bounded index buffer by a two-level
tag sort (active 64-PE chunks first, then candidate lanes within them),
and the synaptic accumulation — the dominant dense cost, O(P*fan_in*N)
integer MACs — runs on the compacted lanes only, scattered back with ONE
bounded scatter.  Everything cheap-and-regular (LIF, DVFS energy pricing,
record assembly) stays dense: on XLA CPU a fused elementwise pass over
all P PEs costs far less than gather/scatter round trips.  Records are
bitwise identical to the dense tick (integer accumulation is
reassociation-exact; skipped PEs receive exactly the zero input the
dense einsum computes for them), and a ``lax.cond`` falls back to the
dense formulas whenever activity overflows the buffer.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper
from repro.core.dvfs import DVFSController
from repro.core.energy import PEEnergyModel
from repro.core.router import RoutingTable, ring_exchange
from repro.kernels.explog.ops import to_fx
from repro.kernels.lif.ops import lif_params_fx
from repro.kernels.lif.ref import lif_step_ref

FX_ONE = 1 << 15

# Default bound on the per-tick input buffer of the event tick: PEs with
# spike arrivals, noise kicks or stimulus this tick.  A synfire wave
# lights O(1) PEs per tick and shot noise adds kicks_per_tick more, so 64
# covers 4096-PE rings with a wide margin; overflow falls back to the
# dense formulas (still bitwise).
EVENT_SRC_CAP = 64

# Two-level compaction of the input set (see make_synfire_tick): PEs
# group into chunks of EVENT_CHUNK; up to EVENT_MAX_CHUNKS active chunks
# are selected by a cheap chunk-tag sort before the per-PE tag sort runs
# on candidate lanes only — O(P/64 + 1024) sorted elements instead of P.
EVENT_CHUNK = 64
EVENT_MAX_CHUNKS = 16


# ---------------------------------------------------------------- bit-packed
# spike words: exact for 0/1 spikes, 32x smaller delay-line carries

def spike_words(n: int) -> int:
    """Number of uint32 words that hold ``n`` spike bits."""
    return (n + 31) // 32


def pack_spikes(spk: jnp.ndarray, n: int) -> jnp.ndarray:
    """Pack 0/1 spikes ``(..., n)`` into uint32 words ``(..., words(n))``."""
    w = spike_words(n)
    pad = w * 32 - n
    if pad:
        spk = jnp.pad(spk, [(0, 0)] * (spk.ndim - 1) + [(0, pad)])
    bits = spk.reshape(spk.shape[:-1] + (w, 32)).astype(jnp.uint32)
    return (bits << jnp.arange(32, dtype=jnp.uint32)).sum(
        axis=-1, dtype=jnp.uint32)


def unpack_spikes(words: jnp.ndarray, n: int) -> jnp.ndarray:
    """Inverse of ``pack_spikes``: uint32 words -> 0/1 int32 ``(..., n)``."""
    bits = (words[..., None] >> jnp.arange(32, dtype=jnp.uint32)) & 1
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,))
    return flat[..., :n].astype(jnp.int32)


def popcount_words(words: jnp.ndarray) -> jnp.ndarray:
    """Spike count per row: popcount over the trailing word axis (int32)."""
    return jax.lax.population_count(words).sum(axis=-1).astype(jnp.int32)


# ------------------------------------------------------------------ shot noise
# Deterministic per-(seed, tick) background input spikes ("shot noise"): a
# fixed number of subthreshold current kicks lands on hash-picked neurons
# each tick — the standard Poisson-background stand-in in SpiNNaker-scale
# synfire studies, and (unlike dense Gaussian draws) O(kicks) not O(P*N),
# so quiescent PEs really are quiescent and the event tick has something
# to compress.  murmur3 finalizer = 2 mults + 3 xorshifts per kick.

def _fmix32(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _shot_seed32(key) -> jnp.ndarray:
    kd = jax.random.key_data(key).astype(jnp.uint32).ravel()
    return _fmix32(kd[-1] ^ _fmix32(kd[0]))


def shot_noise_lanes(seed32, t, n_kicks: int, n_lanes: int):
    """Flat lane index (< n_lanes) of each of this tick's ``n_kicks`` kicks."""
    c = jnp.asarray(t).astype(jnp.uint32) * jnp.uint32(n_kicks) \
        + jnp.arange(n_kicks, dtype=jnp.uint32)
    return (_fmix32(c ^ seed32) % jnp.uint32(n_lanes)).astype(jnp.int32)


@dataclass
class SynfireNet:
    params: paper.SynfireParams
    w_ff: jnp.ndarray        # (P, 200, 250) int32 s16.15: prev-exc -> [exc|inh]
    w_inh: jnp.ndarray       # (P, 50, 200) int32 s16.15 (negative)
    deg_ff: jnp.ndarray      # (P, 200) int32: out-degree of each prev-exc source
    deg_inh: jnp.ndarray     # (P, 50) int32
    lif: dict
    noise_sigma_fx: int
    stim_ticks: int
    stim_current_fx: int
    noise_model: str = "gauss"   # "gauss" (dense threefry) | "shot" (kicks)
    kicks_per_tick: int = 0
    kick_fx: int = 0


def build_synfire(seed: int = 0, *, w_exc: float = 0.075, w_inh: float = -0.30,
                  noise_sigma: float = 0.30, tau_ms: float = 10.0,
                  v_th: float = 1.0, ref_ticks: int = 2,
                  sp: paper.SynfireParams = paper.SYNFIRE,
                  n_pes: int | None = None,
                  v_min: float | None = -1.0,
                  noise_model: str = "gauss",
                  kicks_per_tick: int = 4,
                  kick: float = 0.5) -> SynfireNet:
    """Build the synfire ring.  ``n_pes`` generalizes the fixed 8-PE test
    chip ring to any length (repro.chip places long rings on a mesh).

    ``noise_model="shot"`` replaces the dense Gaussian background current
    with ``kicks_per_tick`` subthreshold current kicks (``kick`` in units
    of v_th) on hash-picked neurons — sparse background input for the
    event-driven engine's benchmark nets.  The 8-PE paper configuration
    keeps the Gaussian default, so its goldens are untouched.
    """
    if noise_model not in ("gauss", "shot"):
        raise ValueError(f"unknown noise_model {noise_model!r}")
    if sp.neurons_per_core != sp.n_exc + sp.n_inh:
        raise ValueError(
            f"neurons_per_core ({sp.neurons_per_core}) must equal "
            f"n_exc + n_inh ({sp.n_exc} + {sp.n_inh}): the membrane array "
            f"is split [:n_exc]/[n_exc:] per PE")
    if n_pes is not None and n_pes != sp.n_pes:
        sp = dataclasses.replace(sp, n_pes=n_pes)
    rng = np.random.default_rng(seed)
    P_, NE, NI = sp.n_pes, sp.n_exc, sp.n_inh
    N = sp.neurons_per_core
    w_ff = np.zeros((P_, NE, N), np.float32)
    w_inh_m = np.zeros((P_, NI, NE), np.float32)
    for p in range(P_):
        # each target neuron draws fan_in_exc sources from prev layer's exc
        for tgt in range(N):
            src = rng.choice(NE, sp.fan_in_exc, replace=False)
            w_ff[p, src, tgt] = w_exc
        for tgt in range(NE):
            src = rng.choice(NI, sp.fan_in_inh, replace=False)
            w_inh_m[p, src, tgt] = w_inh
    # v_min bounds hyperpolarization (inhibitory reversal): without it,
    # tonic background inhibition drives the membrane ~3 v_th below rest
    # and the synfire wave dies before completing one ring traversal.
    lif = lif_params_fx(tau_ms=tau_ms, v_th=v_th, v_reset=0.0,
                        ref_ticks=ref_ticks, v_min=v_min)
    return SynfireNet(
        params=sp,
        w_ff=jnp.asarray(np.round(w_ff * FX_ONE), jnp.int32),
        w_inh=jnp.asarray(np.round(w_inh_m * FX_ONE), jnp.int32),
        deg_ff=jnp.asarray((w_ff != 0).sum(axis=2), jnp.int32),
        deg_inh=jnp.asarray((w_inh_m != 0).sum(axis=2), jnp.int32),
        lif=lif,
        noise_sigma_fx=int(round(noise_sigma * FX_ONE)),
        stim_ticks=2,
        stim_current_fx=int(round(2.0 * FX_ONE)),
        noise_model=noise_model,
        kicks_per_tick=kicks_per_tick if noise_model == "shot" else 0,
        kick_fx=int(round(kick * FX_ONE)) if noise_model == "shot" else 0,
    )


def synfire_init_state(net: SynfireNet) -> dict:
    """Zeroed membrane/refractory state and bit-packed delay-line FIFOs."""
    sp = net.params
    P_, NE, NI = sp.n_pes, sp.n_exc, sp.n_inh
    N = sp.neurons_per_core
    return {
        "v": jnp.zeros((P_, N), jnp.int32),
        "ref": jnp.zeros((P_, N), jnp.int32),
        "exc_buf": jnp.zeros((int(sp.delay_exc_ms), P_, spike_words(NE)),
                             jnp.uint32),
        "inh_buf": jnp.zeros((int(sp.delay_inh_ms), P_, spike_words(NI)),
                             jnp.uint32),
    }


def make_synfire_tick(net: SynfireNet, *, dvfs: DVFSController,
                      em: PEEnergyModel, key, exchange=ring_exchange,
                      event: bool = False, src_cap: int | None = None):
    """Build the per-tick step ``tick(state, t) -> (state, rec)``.

    ``exchange`` delivers each PE's exc spikes to its ring successor; the
    chip-level simulator passes the same function but adds NoC link-load
    accounting on top of the returned record (repro.chip.chip.ChipSim).

    ``event=True`` builds the activity-compressed tick: this tick's input
    set (spike arrivals + noise kicks + stimulus targets) is compacted
    into ``src_cap`` index lanes by a two-level tag sort — active
    ``EVENT_CHUNK``-PE chunks first, then per-PE tags on the surviving
    candidate lanes — and the synaptic einsum gathers only the touched
    weight slabs, writing back through ONE bounded scatter.  Kick and
    stimulus currents land directly on their compacted lanes (every
    kicked PE is in the input set by construction).  The LIF update and
    the energy pricing stay dense: they are fused elementwise passes,
    cheaper than gather/scatter round trips on CPU.  Activity overflow
    falls back (``lax.cond``) to the dense formulas.  Records are
    bitwise identical to ``event=False`` by construction: integer
    accumulation is reassociation-exact, and a skipped PE's synaptic
    input is exactly the zero row the dense einsum computes for it.
    """
    sp = net.params
    P_, NE, NI = sp.n_pes, sp.n_exc, sp.n_inh
    N = sp.neurons_per_core
    d_exc = int(sp.delay_exc_ms)
    d_inh = int(sp.delay_inh_ms)
    cap = min(P_, src_cap if src_cap is not None else EVENT_SRC_CAP)
    shot = net.noise_model == "shot" and net.kicks_per_tick > 0
    seed32 = _shot_seed32(key) if shot else None

    def add_noise(i_syn, t):
        """Background input current — identical formula in both modes."""
        if shot:
            lanes = shot_noise_lanes(seed32, t, net.kicks_per_tick, P_ * N)
            return i_syn.at[lanes // N, lanes % N].add(jnp.int32(net.kick_fx))
        k = jax.random.fold_in(key, t)
        noise = jax.random.normal(k, (P_, N))
        return i_syn + jnp.round(noise * net.noise_sigma_fx).astype(jnp.int32)

    def add_stim(i_syn, t):
        stim = jnp.where(
            (t < net.stim_ticks),
            jnp.zeros((P_, N), jnp.int32).at[0, :NE].set(net.stim_current_fx),
            jnp.zeros((P_, N), jnp.int32))
        return i_syn + stim

    def finish(state, t, pl, n_fifo, syn_events, v, ref, spk, energy_rows,
               extra_state):
        """Shared tail: spike routing + record assembly."""
        spk_exc, spk_inh = spk[:, :NE], spk[:, NE:]

        # route spikes (multicast ring -> next PE FIFO; inh -> own FIFO)
        exc_out = exchange(spk_exc)                    # to PE i+1
        exc_buf = state["exc_buf"].at[t % d_exc].set(pack_spikes(exc_out, NE))
        inh_buf = state["inh_buf"].at[t % d_inh].set(pack_spikes(spk_inh, NI))

        new_state = {"v": v, "ref": ref, "exc_buf": exc_buf,
                     "inh_buf": inh_buf, **extra_state}
        rec = {
            "pl": pl, "n_fifo": n_fifo, "syn_events": syn_events,
            # one multicast DNoC packet per spiking exc neuron — the NoC
            # source counts the chip engine prices against the incidence
            # tensor (repro.chip.chip.ChipSim)
            "packets": spk_exc.astype(jnp.int32).sum(axis=1),
            "spikes_exc": spk_exc.astype(jnp.int8),
            "spikes_inh": spk_inh.astype(jnp.int8),
            "e_dvfs_baseline": energy_rows[0],
            "e_dvfs_neuron": energy_rows[1],
            "e_dvfs_synapse": energy_rows[2],
            "t_sp": energy_rows[3],
            "e_pl3_baseline": energy_rows[4],
            "e_pl3_neuron": energy_rows[5],
            "e_pl3_synapse": energy_rows[6],
        }
        return new_state, rec

    def energy_stack(pl, syn_events):
        """Both energy accountings as a (7, ...) row stack."""
        e_dvfs = em.tick_energy(pl, N, syn_events, dvfs=True)
        e_pl3 = em.tick_energy(jnp.full(pl.shape, 2), N, syn_events,
                               dvfs=False)
        return jnp.stack([
            e_dvfs["baseline"], e_dvfs["neuron"], e_dvfs["synapse"],
            e_dvfs["t_sp"],
            e_pl3["baseline"], e_pl3["neuron"], e_pl3["synapse"]])

    def dense_tick(state, t):
        # 1. drain FIFOs (spikes that arrive this tick)
        we = state["exc_buf"][t % d_exc]               # (P, WE) packed
        wi = state["inh_buf"][t % d_inh]               # (P, WI) packed
        arr_exc = unpack_spikes(we, NE)                # (P, NE) from prev PE
        arr_inh = unpack_spikes(wi, NI)                # (P, NI) same PE
        n_fifo = popcount_words(we) + popcount_words(wi)

        # 2. DVFS: FIFO occupancy picks the PL before processing
        pl = dvfs.select_pl(n_fifo)                    # (P,)

        # 3. synaptic accumulation (event-driven integer MAC)
        i_ff = jnp.einsum("pe,pen->pn", arr_exc, net.w_ff)
        i_in = jnp.einsum("pi,pie->pe", arr_inh, net.w_inh)
        i_syn = add_stim(add_noise(i_ff.at[:, :NE].add(i_in), t), t)

        # 4. LIF update (bit-identical to the Pallas kernel) + accounting
        v, ref, spk = lif_step_ref(state["v"], state["ref"], i_syn,
                                   **net.lif)
        syn_events = (jnp.einsum("pe,pe->p", arr_exc, net.deg_ff)
                      + jnp.einsum("pi,pi->p", arr_inh, net.deg_inh))
        return finish(state, t, pl, n_fifo, syn_events, v, ref, spk,
                      energy_stack(pl, syn_events), {})

    # two-level compaction geometry (event tick only)
    nc = -(-P_ // EVENT_CHUNK)                         # chunks of 64 PEs
    kc = min(EVENT_MAX_CHUNKS, nc)
    cap_eff = min(cap, kc * EVENT_CHUNK)
    pad = nc * EVENT_CHUNK - P_
    wide = P_ > 0xFFFF                                 # u16 tags else i32
    tag_t = jnp.int32 if wide else jnp.uint16

    def compact(src):
        """Indices of up to ``cap_eff`` set bits of ``src`` (ascending;
        sentinel P_ pads the tail), via two bounded sorts: active chunks
        first, then per-PE tags on the candidate lanes only."""
        m = src if pad == 0 else jnp.pad(src, (0, pad))
        m = m.reshape(nc, EVENT_CHUNK)
        c_any = m.any(axis=1)
        ctags = jnp.where(c_any, jnp.arange(nc, dtype=tag_t), tag_t(nc))
        cidx = jax.lax.sort(ctags)[:kc].astype(jnp.int32)
        csafe = jnp.minimum(cidx, nc - 1)
        sub = m[csafe] & (cidx < nc)[:, None]          # (kc, 64)
        pos = (csafe[:, None] * EVENT_CHUNK
               + jnp.arange(EVENT_CHUNK)[None, :]).astype(tag_t)
        stags = jnp.where(sub, pos, tag_t(P_))
        idx = jax.lax.sort(stags.ravel())[:cap_eff].astype(jnp.int32)
        return idx, c_any.sum()

    def event_tick(state, t):
        # 1. drain FIFOs — popcount on the packed words gives n_fifo and
        #    the arrival mask without unpacking
        we = state["exc_buf"][t % d_exc]
        wi = state["inh_buf"][t % d_inh]
        n_fifo = popcount_words(we) + popcount_words(wi)
        pl = dvfs.select_pl(n_fifo)
        arr_exc = unpack_spikes(we, NE)
        arr_inh = unpack_spikes(wi, NI)

        # syn_events: fused dense elementwise — integer-exact match of
        # the dense einsum, and cheaper than gathering deg tables
        syn_events = ((arr_exc * net.deg_ff).sum(axis=1)
                      + (arr_inh * net.deg_inh).sum(axis=1))

        # 2. the input set: every PE receiving anything this tick —
        #    spike arrivals, shot-noise kicks, the stimulus.  (A dense
        #    Gaussian background is NOT input-sparse; it is added
        #    densely after the cond, identically in both branches.)
        src = n_fifo > 0
        if shot:
            lanes = shot_noise_lanes(seed32, t, net.kicks_per_tick, P_ * N)
            src = src.at[lanes // N].set(True)
        if net.stim_ticks > 0:
            src = src.at[0].set(src[0] | (t < net.stim_ticks))
        n_src = src.sum()
        idx, n_chunks = compact(src)                   # (cap_eff,)
        safe = jnp.minimum(idx, P_ - 1)
        valid = idx < P_

        def compressed(ops):
            arr_e, arr_i = ops
            m = valid[:, None]
            ae = arr_e[safe] * m                       # (cap_eff, NE)
            ai = arr_i[safe] * m                       # (cap_eff, NI)
            # gather only the touched weight slabs
            i_k = jnp.einsum("ke,ken->kn", ae, net.w_ff[safe])
            i_k = i_k.at[:, :NE].add(
                jnp.einsum("ki,kie->ke", ai, net.w_inh[safe]))
            if shot:
                # every kicked PE is in the input set, so searchsorted
                # finds its exact lane in the sorted index buffer
                kpos = jnp.searchsorted(idx, lanes // N)
                i_k = i_k.at[jnp.minimum(kpos, cap_eff - 1),
                             lanes % N].add(jnp.int32(net.kick_fx))
            if net.stim_ticks > 0:
                # PE 0 is forced into the set while stimulated, so it
                # owns lane 0 of the sorted buffer exactly when present
                hit0 = (t < net.stim_ticks) & (idx[0] == 0)
                i_k = i_k.at[0, :NE].add(
                    jnp.where(hit0, jnp.int32(net.stim_current_fx),
                              jnp.int32(0)))
            # ONE bounded scatter back to the dense current (sentinel
            # lanes drop); skipped PEs keep the exact zero rows the
            # dense einsum would compute for them
            return jnp.zeros((P_, N), jnp.int32).at[idx].set(i_k,
                                                             mode="drop")

        def dense_path(ops):
            arr_e, arr_i = ops
            i_ff = jnp.einsum("pe,pen->pn", arr_e, net.w_ff)
            i_syn = i_ff.at[:, :NE].add(
                jnp.einsum("pi,pie->pe", arr_i, net.w_inh))
            if shot:
                i_syn = i_syn.at[lanes // N, lanes % N].add(
                    jnp.int32(net.kick_fx))
            if net.stim_ticks > 0:
                i_syn = i_syn.at[0, :NE].add(
                    jnp.where(t < net.stim_ticks,
                              jnp.int32(net.stim_current_fx),
                              jnp.int32(0)))
            return i_syn

        i_syn = jax.lax.cond((n_src <= cap_eff) & (n_chunks <= kc),
                             compressed, dense_path, (arr_exc, arr_inh))
        if not shot:
            k = jax.random.fold_in(key, t)
            noise = jax.random.normal(k, (P_, N))
            i_syn = i_syn + jnp.round(
                noise * net.noise_sigma_fx).astype(jnp.int32)

        # 3. dense LIF + dense energy pricing: fused elementwise passes
        #    over regular arrays — cheaper than compacting them on CPU
        v, ref, spk = lif_step_ref(state["v"], state["ref"], i_syn,
                                   **net.lif)
        return finish(state, t, pl, n_fifo, syn_events, v, ref, spk,
                      energy_stack(pl, syn_events), {})

    return event_tick if event else dense_tick


def simulate_synfire(net: SynfireNet, n_ticks: int, seed: int = 1,
                     event: bool = False):
    """Returns per-tick records (all (T, P) unless noted):

    pl, n_fifo, syn_events, spikes_exc (T,P,200), spikes_inh (T,P,50),
    plus both energy accountings (dvfs / only-PL3).  ``event=True`` runs
    the activity-compressed tick — records are bitwise identical.
    """
    sp = net.params
    dvfs = DVFSController(sp.l_th1, sp.l_th2)
    em = PEEnergyModel()
    tick = make_synfire_tick(net, dvfs=dvfs, em=em,
                             key=jax.random.PRNGKey(seed), event=event)
    init = synfire_init_state(net)
    _, recs = jax.lax.scan(tick, init, jnp.arange(n_ticks))
    return recs


def synfire_power_table(recs, t_sys_s: float = 1e-3) -> dict:
    """Average per-PE power [mW], DVFS vs only-PL3 — the paper's Table III."""
    def avg_mw(x):
        return float(jnp.mean(x) / t_sys_s * 1e3)

    out = {}
    for mode in ("dvfs", "pl3"):
        base = avg_mw(recs[f"e_{mode}_baseline"])
        neur = avg_mw(recs[f"e_{mode}_neuron"])
        syn = avg_mw(recs[f"e_{mode}_synapse"])
        out[mode] = {"baseline": base, "neuron": neur, "synapse": syn,
                     "total": base + neur + syn}
    out["reduction"] = {
        # a workload may not exercise a component (e.g. the DNN pipeline
        # has no neuron updates): no PL3 energy -> no reduction to report
        k: (1.0 - out["dvfs"][k] / out["pl3"][k]) if out["pl3"][k] else 0.0
        for k in ("baseline", "neuron", "synapse", "total")
    }
    return out
