"""Event-driven SNN engine + the synfire-chain benchmark (paper Sec. VI-B).

Faithful to the paper's processing model: each PE simulates its neurons
once per 1 ms timer tick; inbound spikes sit in a FIFO until the next tick;
the FIFO occupancy picks the performance level (core/dvfs.py) BEFORE
processing; after the busy window t_sp the PE returns to PL1 and sleeps.

Arithmetic is SpiNNaker-style s16.15 fixed point: the LIF update uses
exactly the kernel math (kernels/lif/ref.py — bit-identical to the Pallas
kernel), the membrane decay constant comes from the exp accelerator
(kernels/explog), and synaptic-event accumulation is an integer matmul —
the event-driven MAC-array mode of Sec. II.

The synfire chain (Fig. 16, Table II): 8 PEs in a ring; per PE one
excitatory population (200) and one inhibitory population (50); exc of PE i
projects to exc+inh of PE i+1 with 10 ms delay (fan-in 60); inh projects to
exc of the same PE with 8 ms delay (fan-in 25); normally distributed noise
current; a stimulus pulse packet kick-starts PE 0.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import paper
from repro.core.dvfs import DVFSController
from repro.core.energy import PEEnergyModel
from repro.core.router import RoutingTable, ring_exchange
from repro.kernels.explog.ops import to_fx
from repro.kernels.lif.ops import lif_params_fx
from repro.kernels.lif.ref import lif_step_ref

FX_ONE = 1 << 15


@dataclass
class SynfireNet:
    params: paper.SynfireParams
    w_ff: jnp.ndarray        # (P, 200, 250) int32 s16.15: prev-exc -> [exc|inh]
    w_inh: jnp.ndarray       # (P, 50, 200) int32 s16.15 (negative)
    deg_ff: jnp.ndarray      # (P, 200) int32: out-degree of each prev-exc source
    deg_inh: jnp.ndarray     # (P, 50) int32
    lif: dict
    noise_sigma_fx: int
    stim_ticks: int
    stim_current_fx: int


def build_synfire(seed: int = 0, *, w_exc: float = 0.075, w_inh: float = -0.30,
                  noise_sigma: float = 0.30, tau_ms: float = 10.0,
                  v_th: float = 1.0, ref_ticks: int = 2,
                  sp: paper.SynfireParams = paper.SYNFIRE,
                  n_pes: int | None = None,
                  v_min: float | None = -1.0) -> SynfireNet:
    """Build the synfire ring.  ``n_pes`` generalizes the fixed 8-PE test
    chip ring to any length (repro.chip places long rings on a mesh)."""
    if n_pes is not None and n_pes != sp.n_pes:
        sp = dataclasses.replace(sp, n_pes=n_pes)
    rng = np.random.default_rng(seed)
    P_, NE, NI = sp.n_pes, sp.n_exc, sp.n_inh
    N = sp.neurons_per_core
    w_ff = np.zeros((P_, NE, N), np.float32)
    w_inh_m = np.zeros((P_, NI, NE), np.float32)
    for p in range(P_):
        # each target neuron draws fan_in_exc sources from prev layer's exc
        for tgt in range(N):
            src = rng.choice(NE, sp.fan_in_exc, replace=False)
            w_ff[p, src, tgt] = w_exc
        for tgt in range(NE):
            src = rng.choice(NI, sp.fan_in_inh, replace=False)
            w_inh_m[p, src, tgt] = w_inh
    # v_min bounds hyperpolarization (inhibitory reversal): without it,
    # tonic background inhibition drives the membrane ~3 v_th below rest
    # and the synfire wave dies before completing one ring traversal.
    lif = lif_params_fx(tau_ms=tau_ms, v_th=v_th, v_reset=0.0,
                        ref_ticks=ref_ticks, v_min=v_min)
    return SynfireNet(
        params=sp,
        w_ff=jnp.asarray(np.round(w_ff * FX_ONE), jnp.int32),
        w_inh=jnp.asarray(np.round(w_inh_m * FX_ONE), jnp.int32),
        deg_ff=jnp.asarray((w_ff != 0).sum(axis=2), jnp.int32),
        deg_inh=jnp.asarray((w_inh_m != 0).sum(axis=2), jnp.int32),
        lif=lif,
        noise_sigma_fx=int(round(noise_sigma * FX_ONE)),
        stim_ticks=2,
        stim_current_fx=int(round(2.0 * FX_ONE)),
    )


def synfire_init_state(net: SynfireNet) -> dict:
    """Zeroed membrane/refractory state and delay-line FIFO buffers."""
    sp = net.params
    P_, NE, NI = sp.n_pes, sp.n_exc, sp.n_inh
    N = sp.neurons_per_core
    return {
        "v": jnp.zeros((P_, N), jnp.int32),
        "ref": jnp.zeros((P_, N), jnp.int32),
        "exc_buf": jnp.zeros((int(sp.delay_exc_ms), P_, NE), jnp.int32),
        "inh_buf": jnp.zeros((int(sp.delay_inh_ms), P_, NI), jnp.int32),
    }


def make_synfire_tick(net: SynfireNet, *, dvfs: DVFSController,
                      em: PEEnergyModel, key, exchange=ring_exchange):
    """Build the per-tick step ``tick(state, t) -> (state, rec)``.

    ``exchange`` delivers each PE's exc spikes to its ring successor; the
    chip-level simulator passes the same function but adds NoC link-load
    accounting on top of the returned record (repro.chip.chip.ChipSim).
    """
    sp = net.params
    P_, NE, NI = sp.n_pes, sp.n_exc, sp.n_inh
    N = sp.neurons_per_core
    d_exc = int(sp.delay_exc_ms)
    d_inh = int(sp.delay_inh_ms)

    def tick(state, t):
        k = jax.random.fold_in(key, t)
        # 1. drain FIFOs (spikes that arrive this tick)
        arr_exc = state["exc_buf"][t % d_exc]          # (P, NE) from prev PE
        arr_inh = state["inh_buf"][t % d_inh]          # (P, NI) same PE
        n_fifo = arr_exc.sum(axis=1) + arr_inh.sum(axis=1)

        # 2. DVFS: FIFO occupancy picks the PL before processing
        pl = dvfs.select_pl(n_fifo)                    # (P,)

        # 3. synaptic accumulation (event-driven integer MAC)
        i_ff = jnp.einsum("pe,pen->pn", arr_exc, net.w_ff)
        i_in = jnp.einsum("pi,pie->pe", arr_inh, net.w_inh)
        i_syn = i_ff.at[:, :NE].add(i_in)
        noise = jax.random.normal(k, (P_, N))
        i_syn = i_syn + jnp.round(noise * net.noise_sigma_fx).astype(jnp.int32)
        stim = jnp.where(
            (t < net.stim_ticks),
            jnp.zeros((P_, N), jnp.int32).at[0, :NE].set(net.stim_current_fx),
            jnp.zeros((P_, N), jnp.int32))
        i_syn = i_syn + stim

        # 4. LIF update (bit-identical to the Pallas kernel)
        v, ref, spk = lif_step_ref(state["v"], state["ref"], i_syn, **net.lif)
        spk_exc, spk_inh = spk[:, :NE], spk[:, NE:]

        # 5. route spikes (multicast ring -> next PE FIFO; inh -> own FIFO)
        exc_out = exchange(spk_exc)                    # to PE i+1
        exc_buf = state["exc_buf"].at[t % d_exc].set(exc_out)
        inh_buf = state["inh_buf"].at[t % d_inh].set(spk_inh)

        # 6. accounting
        syn_events = (jnp.einsum("pe,pe->p", arr_exc, net.deg_ff)
                      + jnp.einsum("pi,pi->p", arr_inh, net.deg_inh))
        e_dvfs = em.tick_energy(pl, N, syn_events, dvfs=True)
        e_pl3 = em.tick_energy(jnp.full((P_,), 2), N, syn_events, dvfs=False)

        new_state = {"v": v, "ref": ref, "exc_buf": exc_buf, "inh_buf": inh_buf}
        rec = {
            "pl": pl, "n_fifo": n_fifo, "syn_events": syn_events,
            # one multicast DNoC packet per spiking exc neuron — the NoC
            # source counts the chip engine prices against the incidence
            # tensor (repro.chip.chip.ChipSim)
            "packets": spk_exc.astype(jnp.int32).sum(axis=1),
            "spikes_exc": spk_exc.astype(jnp.int8),
            "spikes_inh": spk_inh.astype(jnp.int8),
            "e_dvfs_baseline": e_dvfs["baseline"],
            "e_dvfs_neuron": e_dvfs["neuron"],
            "e_dvfs_synapse": e_dvfs["synapse"],
            "t_sp": e_dvfs["t_sp"],
            "e_pl3_baseline": e_pl3["baseline"],
            "e_pl3_neuron": e_pl3["neuron"],
            "e_pl3_synapse": e_pl3["synapse"],
        }
        return new_state, rec

    return tick


def simulate_synfire(net: SynfireNet, n_ticks: int, seed: int = 1):
    """Returns per-tick records (all (T, P) unless noted):

    pl, n_fifo, syn_events, spikes_exc (T,P,200), spikes_inh (T,P,50),
    plus both energy accountings (dvfs / only-PL3).
    """
    sp = net.params
    dvfs = DVFSController(sp.l_th1, sp.l_th2)
    em = PEEnergyModel()
    tick = make_synfire_tick(net, dvfs=dvfs, em=em,
                             key=jax.random.PRNGKey(seed))
    _, recs = jax.lax.scan(tick, synfire_init_state(net), jnp.arange(n_ticks))
    return recs


def synfire_power_table(recs, t_sys_s: float = 1e-3) -> dict:
    """Average per-PE power [mW], DVFS vs only-PL3 — the paper's Table III."""
    def avg_mw(x):
        return float(jnp.mean(x) / t_sys_s * 1e3)

    out = {}
    for mode in ("dvfs", "pl3"):
        base = avg_mw(recs[f"e_{mode}_baseline"])
        neur = avg_mw(recs[f"e_{mode}_neuron"])
        syn = avg_mw(recs[f"e_{mode}_synapse"])
        out[mode] = {"baseline": base, "neuron": neur, "synapse": syn,
                     "total": base + neur + syn}
    out["reduction"] = {
        # a workload may not exercise a component (e.g. the DNN pipeline
        # has no neuron updates): no PL3 energy -> no reduction to report
        k: (1.0 - out["dvfs"][k] / out["pl3"][k]) if out["pl3"][k] else 0.0
        for k in ("baseline", "neuron", "synapse", "total")
    }
    return out
