"""SpiNNaker2 multicast packet router (paper Sec. III-B), JAX-native.

Routing is key-based: each spike carries a key (its source population id);
routing tables map keys to destination PEs.  Three realizations:

* ``delivery_matrix`` — dense (n_sources, n_pes) 0/1 matrix; delivery is a
  matmul (the event-driven MAC view of routing).  Used by the SNN engine.
* ``ring_exchange``   — the synfire topology (PE i -> PE i+1) as a
  jnp.roll on one device or a shard_map collective_permute over a "pe"
  mesh axis — the NoC hop becomes an ICI hop.
* ``multicast_exchange`` — general key->multi-PE delivery via shard_map
  psum of masked contributions (each source broadcasts on the mesh like a
  DNoC multicast flit; receivers mask by routing table).

The MoE dispatch in ``repro.models.moe.moe_apply_sharded`` is the
rate-based twin of this module (spikes-with-payload = routed tokens).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class RoutingTable:
    """keys[i] -> boolean destination mask over PEs."""
    masks: np.ndarray          # (n_keys, n_pes) bool

    @staticmethod
    def ring(n_pes: int) -> "RoutingTable":
        m = np.zeros((n_pes, n_pes), bool)
        for i in range(n_pes):
            m[i, (i + 1) % n_pes] = True
        return RoutingTable(m)

    @staticmethod
    def self_loop(n_pes: int) -> "RoutingTable":
        return RoutingTable(np.eye(n_pes, dtype=bool))

    def delivery_matrix(self) -> jnp.ndarray:
        return jnp.asarray(self.masks, jnp.int32)

    def fan_out(self) -> np.ndarray:
        return self.masks.sum(axis=1)


def ring_exchange(spikes, mesh=None, axis="pe"):
    """spikes: (n_pes, ...) -> delivered to PE i+1 (synfire ring).

    With a mesh containing `axis`, PEs are sharded and the roll lowers to a
    collective_permute over ICI; otherwise a local jnp.roll.
    """
    if mesh is None or axis not in getattr(mesh, "shape", {}):
        return jnp.roll(spikes, 1, axis=0)

    n = mesh.shape[axis]

    def local(s):
        perm = [(i, (i + 1) % n) for i in range(n)]
        return jax.lax.ppermute(s, axis, perm)

    return jax.shard_map(local, mesh=mesh, in_specs=P(axis),
                         out_specs=P(axis), check_vma=False)(spikes)


def multicast_exchange(spikes, table: RoutingTable, mesh=None, axis="pe"):
    """spikes: (n_pes, n_keys_per_pe) counts emitted by each PE.

    Returns (n_pes, n_src_total) arrival counts at each PE, where source j
    of PE i is delivered to every PE in the table mask for key (i, j).
    Dense formulation: arrivals[p] = sum_i spikes[i] * mask[i -> p].
    """
    n_pes, n_keys = spikes.shape
    dm = table.delivery_matrix()                    # (n_pes, n_pes) here

    if mesh is None or axis not in getattr(mesh, "shape", {}):
        # arrivals[p, i, k] = spikes[i, k] * dm[i, p]
        return jnp.einsum("ik,ip->pik", spikes, dm)

    def local(s_local, dm_full):
        # each PE broadcasts its spikes (DNoC multicast); receivers mask
        gathered = jax.lax.all_gather(s_local, axis, tiled=True)  # (n_pes, k)
        p = jax.lax.axis_index(axis)
        return (gathered * dm_full[:, p][:, None])[None]

    return jax.shard_map(local, mesh=mesh,
                         in_specs=(P(axis), P()),
                         out_specs=P(axis), check_vma=False)(spikes, dm)
