"""Processing-element abstraction (paper Sec. III-C, Fig. 7).

Captures the PE's resources and cycle model so benchmarks can translate
workloads into time/energy the way the test chip measurements do, and so
the DNN-layer benchmark can partition layers into 128 kB SRAM tiles
("we divide the layers to fit into the 128 kByte SRAM per PE").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.configs import paper


@dataclass(frozen=True)
class PESpec:
    sram_bytes: int = paper.SRAM_BYTES
    mac_rows: int = paper.MAC_ROWS
    mac_cols: int = paper.MAC_COLS
    sram_port_bytes_per_clk: int = paper.SRAM_PORT_BYTES_PER_CLK
    noc_port_bytes_per_clk: int = paper.NOC_PORT_BYTES_PER_CLK

    @property
    def macs_per_cycle(self) -> int:
        return self.mac_rows * self.mac_cols              # 64

    def mac_mm_cycles(self, m: int, k: int, n: int) -> float:
        """MM mode: output-stationary over 16-wide x 4-tall output tiles;
        only min(m, 4) rows are active for skinny matrices; operand fetch at
        128 bit/clk must keep up (Sec. III-C)."""
        active = self.mac_cols * min(m, self.mac_rows)
        compute = m * k * n / active
        # A-operand streaming from SRAM: k bytes per output row tile
        fetch = (m / self.mac_rows) * k * np.ceil(n / self.mac_cols) \
            / self.sram_port_bytes_per_clk
        return max(compute, fetch)

    def mac_conv_cycles(self, h, w, cin, cout, kh, kw, stride=1) -> float:
        """CONV mode: shift-register IFM reuse relaxes fetch to 4 B / 4 clk."""
        ho, wo = h // stride, w // stride
        compute = ho * wo * cout * cin * kh * kw / self.macs_per_cycle
        fetch = h * w * cin / self.sram_port_bytes_per_clk / 4.0
        return max(compute, fetch)

    def arm_mm_cycles(self, m, k, n) -> float:
        """CMSIS-NN-class Arm M4F int8 fully-connected: SMLAD dual-MAC with
        load/loop overhead -> ~1.7 cycles/MAC (Lai et al. 2018)."""
        return m * k * n * 1.7

    def arm_conv_cycles(self, h, w, cin, cout, kh, kw, stride=1) -> float:
        """Arm q7 convolution: im2col + GEMM -> ~5 cycles/MAC effective
        (CMSIS-NN reports ~0.05 GMAC/s at 216 MHz on M4/M7-class cores),
        calibrated inside the 116-610x band of Fig. 22."""
        ho, wo = h // stride, w // stride
        macs = ho * wo * cout * cin * kh * kw
        return macs * 5.0 + ho * wo * cin * kh * kw

    def fits_sram(self, *tensors_bytes) -> bool:
        return sum(tensors_bytes) <= self.sram_bytes


@dataclass(frozen=True)
class QPESpec:
    pes: int = 4
    noc_freq_hz: float = paper.NOC_FREQ_HZ


def partition_layer_to_sram(pe: PESpec, h, w, cin, cout, kh, kw,
                            bytes_per=1):
    """Split (h x w x cin) -> (cout) conv into PE-sized tiles: returns
    (rows_per_tile, cout_per_tile, n_tiles) such that input tile + weights +
    output tile fit the 128 kB SRAM."""
    for cout_t in (cout, 64, 32, 16, 8, 4):
        if cout_t > cout:
            continue
        for rows in range(h, 0, -1):
            in_b = (rows + kh - 1) * w * cin * bytes_per
            w_b = kh * kw * cin * cout_t * bytes_per
            out_b = rows * w * cout_t * 4
            if in_b + w_b + out_b <= pe.sram_bytes:
                n_tiles = -(-h // rows) * -(-cout // cout_t)
                return rows, cout_t, n_tiles
    return 1, min(4, cout), h * -(-cout // 4)
