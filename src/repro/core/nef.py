"""Neural Engineering Framework ensemble (paper Sec. VI-C, Fig. 19).

The paper's hybrid SNN/DNN showcase, implemented with the same split as the
test chip:

    encode  (vector -> input currents)  = matrix multiply  -> MAC array
    neuron update (spiking LIF)          = SNN path          -> Arm core
    decode  (spikes -> vector)           = event-based adds  -> Arm core

Encoding runs through the int8 MAC GEMM path (core/quant.py) exactly as the
test chip offloads it to the 16x4 array; decoding accumulates decoder rows
only for neurons that spiked ("for spiking neurons, the decoding process is
event based").  A first-order synaptic filter (exp accelerator constant)
smooths the decoded output.

Energy accounting implements BOTH of the paper's synaptic-event metrics:
  * equivalent synops (Braindrop-style): N*N per input spike-equivalent,
  * hardware ops: N*D MACs (encode) + M*D adds (decode), M = spikers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import quantize_per_axis
from repro.kernels.explog.ops import fx_exp, to_fx, from_fx
from repro.kernels.lif.ops import lif_params_fx
from repro.kernels.lif.ref import lif_step_ref
from repro.kernels.mac_gemm.ops import mac_gemm

FX_ONE = 1 << 15


@dataclass
class Ensemble:
    n_neurons: int
    dims: int
    encoders: np.ndarray       # (N, D) float
    gains: np.ndarray          # (N,)
    biases: np.ndarray         # (N,)
    decoders: np.ndarray       # (N, D) float
    lif: dict
    tau_syn_ticks: float = 20.0
    # int8 MAC-path operands
    enc_q: Optional[np.ndarray] = None   # (D, N) int8
    enc_scale: Optional[np.ndarray] = None


def _lif_rate(J, tau_ref=0.002, tau_rc=0.02):
    """Steady-state LIF rate curve used for decoder solving (float)."""
    J = np.maximum(J, 1.0 + 1e-6)
    return 1.0 / (tau_ref + tau_rc * np.log1p(1.0 / (J - 1.0)))


def build_ensemble(n_neurons=512, dims=1, seed=0, tau_ms=20.0,
                   ref_ticks=2) -> Ensemble:
    rng = np.random.default_rng(seed)
    enc = rng.standard_normal((n_neurons, dims))
    enc /= np.linalg.norm(enc, axis=1, keepdims=True)
    # intercepts/max-rates a la Nengo defaults
    intercepts = rng.uniform(-0.9, 0.9, n_neurons)
    max_rates = rng.uniform(200.0, 400.0, n_neurons)
    gains = (1.0 - 1.0 / (1.0 - np.exp((0.002 * max_rates - 1.0)
                                       / (0.02 * max_rates)))) \
        / (intercepts - 1.0)
    biases = 1.0 - gains * intercepts

    # decoder solve on sampled points (regularized least squares)
    xs = np.linspace(-1, 1, 256)[:, None] if dims == 1 else \
        rng.uniform(-1, 1, (512, dims))
    J = gains[None, :] * (xs @ enc.T) + biases[None, :]
    A = np.where(J > 1.0, _lif_rate(J), 0.0)             # (S, N)
    reg = 0.1 * A.max()
    G = A.T @ A + reg**2 * len(xs) * np.eye(n_neurons)
    dec = np.linalg.solve(G, A.T @ xs)                   # (N, D)

    lif = lif_params_fx(tau_ms=tau_ms, v_th=1.0, v_reset=0.0,
                        ref_ticks=ref_ticks)
    enc_w = (gains[:, None] * enc).T                     # (D, N)
    enc_q, enc_scale = quantize_per_axis(jnp.asarray(enc_w, jnp.float32), axis=0)
    return Ensemble(n_neurons, dims, enc, gains, biases, dec, lif,
                    enc_q=np.asarray(enc_q), enc_scale=np.asarray(enc_scale))


def encode_drive(ens: Ensemble, x_seq, *, use_mac=True) -> jnp.ndarray:
    """(T, D) inputs -> (T, N) s16.15 per-tick membrane drive.

    Encoding runs through the int8 MAC array (Fig. 19 left); the result is
    the exact discretization of dv/dt = (J - v)/tau_rc:  v' = a v + (1-a) J.
    Shared by ``run_channel`` and the chip-level hybrid workload
    (``repro.chip.workloads.hybrid_graph``) so both paths stay equivalent.
    """
    xq, x_scale = quantize_per_axis(jnp.asarray(x_seq, jnp.float32), axis=1)
    if use_mac:
        acc = mac_gemm(xq, jnp.asarray(ens.enc_q))       # (T, N) int32
        J = (acc.astype(jnp.float32) * x_scale[:, None]
             * jnp.asarray(ens.enc_scale)[None, :])
    else:
        J = jnp.asarray(x_seq, jnp.float32) @ jnp.asarray(
            ens.gains[:, None] * ens.encoders, jnp.float32).T
    J = J + jnp.asarray(ens.biases, jnp.float32)[None, :]
    alpha = ens.lif["alpha"] / FX_ONE
    return jnp.round(J * (1.0 - alpha) * FX_ONE).astype(jnp.int32)


def run_channel(ens: Ensemble, x_seq: np.ndarray, *, dt_ms=1.0,
                use_mac=True, seed=0):
    """Communication channel: decoded output follows the input vector.

    x_seq: (T, D) inputs in [-1, 1].  Returns dict with xhat (T, D), spike
    counts, and op counts for the energy metrics.  rate_scale converts the
    rate-based current J to per-tick drive (J * dt adds to the s16.15
    membrane).
    """
    T, D = x_seq.shape
    N = ens.n_neurons
    dec = jnp.asarray(ens.decoders, jnp.float32)
    alpha_syn = float(np.exp(-1.0 / ens.tau_syn_ticks))
    drive_fx = encode_drive(ens, x_seq, use_mac=use_mac)

    def tick(state, inp):
        v, ref, xhat = state
        dfx = inp
        v, ref, spk = lif_step_ref(v, ref, dfx, **ens.lif)
        # event-based decode: only spiking neurons contribute (Arm core)
        contrib = jnp.einsum("n,nd->d", spk.astype(jnp.float32), dec)
        # spikes/tick -> rate in Hz (decoders were solved against Hz rates)
        xhat = alpha_syn * xhat + (1 - alpha_syn) * contrib * (1000.0 / dt_ms)
        return (v, ref, xhat), (xhat, spk.sum(), spk)

    v0 = jnp.zeros((N,), jnp.int32)
    r0 = jnp.zeros((N,), jnp.int32)
    x0 = jnp.zeros((D,), jnp.float32)
    _, (xhat, n_spk, spikes) = jax.lax.scan(tick, (v0, r0, x0), drive_fx)
    return {"xhat": np.asarray(xhat), "spikes_per_tick": np.asarray(n_spk),
            "spikes": np.asarray(spikes)}


def synop_metrics(ens: Ensemble, spikes_per_tick: np.ndarray,
                  dyn_energy_per_tick_j: np.ndarray | float) -> dict:
    """The paper's two energy-per-synaptic-event metrics (Sec. VI-C)."""
    N, D = ens.n_neurons, ens.dims
    T = len(spikes_per_tick)
    e = np.broadcast_to(np.asarray(dyn_energy_per_tick_j, np.float64), (T,))
    # equivalent synops: if the NxN matrix were not factorized, each spike
    # causes N synaptic ops
    eq_synops = spikes_per_tick.astype(np.float64) * N
    # hardware ops: N*D MACs (encode) + M*D adds (decode)
    hw_ops = N * D + spikes_per_tick.astype(np.float64) * D
    return {
        "pj_per_eq_synop": float(e.sum() / max(eq_synops.sum(), 1) * 1e12),
        "pj_per_hw_synop": float(e.sum() / max(hw_ops.sum(), 1) * 1e12),
        "mean_rate_hz": float(spikes_per_tick.mean() / N / 1e-3),
    }
