"""SpiNNaker2 packet formats + TCAM multicast routing (paper Fig. 4-6,
Sec. III-A/B).

DNoC packet (Fig. 4, 192-bit flit): 15-bit NoC header | 17-bit packet
header | 32-bit address | 0..128-bit payload.  SpiNNaker packets (Fig. 6)
ride inside: multicast (routed by a 32-bit source key against TCAM
key/mask entries), core-to-core (routed by destination address), and
nearest-neighbour (routed by port) — the three traffic classes the router
arbitrates round-robin.

The TCAM table mirrors the hardware: each entry is (key, mask, dest-port
bit-set); a packet matches entry i iff (pkt.key & mask_i) == key_i; the
FIRST match wins (priority order), unmatched multicast packets take the
default route (drop or monitor, per config).  ``route_batch`` evaluates a
whole spike batch vectorized — the dense-matmul delivery used by the SNN
engine (core/router.py) is provably equivalent for 1-hot tables (tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from enum import IntEnum

import numpy as np


class PacketType(IntEnum):
    MULTICAST = 0
    CORE_TO_CORE = 1
    NEAREST_NEIGHBOUR = 2


_NOC_HDR_BITS = 15
_PKT_HDR_BITS = 17
_ADDR_BITS = 32
MAX_PAYLOAD_BITS = 128
FLIT_BITS = 192


@dataclass(frozen=True)
class Packet:
    ptype: PacketType
    key: int                   # 32-bit routing key / destination address
    payload: int = 0           # up to 128 bits
    payload_bits: int = 0      # 0 (header-only spike), 32 or 128
    emergency: bool = False    # header flag (Fig. 6 control byte)
    timestamp: int = 0         # 2-bit phase tag in hardware

    def __post_init__(self):
        assert 0 <= self.key < (1 << 32)
        assert self.payload_bits in (0, 32, 128)
        assert 0 <= self.payload < (1 << max(self.payload_bits, 1))


def pack(pkt: Packet) -> int:
    """Encode to a 192-bit flit integer (Fig. 4 layout)."""
    noc_hdr = (int(pkt.ptype) & 0x3) | ((pkt.payload_bits // 32) & 0x7) << 2
    pkt_hdr = (int(pkt.emergency) | (pkt.timestamp & 0x3) << 1)
    word = noc_hdr
    word |= pkt_hdr << _NOC_HDR_BITS
    word |= pkt.key << (_NOC_HDR_BITS + _PKT_HDR_BITS)
    word |= pkt.payload << (_NOC_HDR_BITS + _PKT_HDR_BITS + _ADDR_BITS)
    assert word < (1 << FLIT_BITS)
    return word


def unpack(word: int) -> Packet:
    noc_hdr = word & ((1 << _NOC_HDR_BITS) - 1)
    pkt_hdr = (word >> _NOC_HDR_BITS) & ((1 << _PKT_HDR_BITS) - 1)
    key = (word >> (_NOC_HDR_BITS + _PKT_HDR_BITS)) & 0xFFFFFFFF
    payload = word >> (_NOC_HDR_BITS + _PKT_HDR_BITS + _ADDR_BITS)
    pbits = ((noc_hdr >> 2) & 0x7) * 32
    return Packet(
        ptype=PacketType(noc_hdr & 0x3),
        key=key,
        payload=payload,
        payload_bits=pbits,
        emergency=bool(pkt_hdr & 1),
        timestamp=(pkt_hdr >> 1) & 0x3,
    )


@dataclass
class TcamTable:
    """Ternary CAM multicast table: first-match-wins key/mask entries."""
    keys: np.ndarray           # (E,) uint32
    masks: np.ndarray          # (E,) uint32
    dests: np.ndarray          # (E, n_ports) bool

    @staticmethod
    def empty(n_ports: int) -> "TcamTable":
        return TcamTable(np.zeros(0, np.uint32), np.zeros(0, np.uint32),
                         np.zeros((0, n_ports), bool))

    def add(self, key: int, mask: int, ports) -> "TcamTable":
        dests = np.zeros((1, self.dests.shape[1] or len(ports)), bool)
        if self.dests.shape[0] == 0 and self.dests.shape[1] == 0:
            dests = np.zeros((1, len(ports)), bool)
        dests[0, list(np.nonzero(ports)[0]) if isinstance(ports, np.ndarray)
              else list(ports)] = True
        return TcamTable(
            np.concatenate([self.keys, [np.uint32(key)]]),
            np.concatenate([self.masks, [np.uint32(mask)]]),
            np.concatenate([self.dests, dests]) if self.dests.size
            else dests)

    def route(self, key: int):
        """First matching entry's port set, or None (default route)."""
        m = (np.uint32(key) & self.masks) == self.keys
        idx = np.nonzero(m)[0]
        if len(idx) == 0:
            return None
        return self.dests[idx[0]]

    def route_batch(self, keys: np.ndarray) -> np.ndarray:
        """keys: (N,) -> (N, n_ports) bool; unmatched rows all-False."""
        m = (keys[:, None].astype(np.uint32) & self.masks[None, :]) \
            == self.keys[None, :]                     # (N, E)
        first = np.argmax(m, axis=1)
        any_hit = m.any(axis=1)
        out = self.dests[first]
        out[~any_hit] = False
        return out

    def self_test(self) -> bool:
        """TCAM BIST analogue (Sec. III-B): every entry reachable, masks
        well-formed (key bits outside the mask must be zero)."""
        if not np.all((self.keys & ~self.masks) == 0):
            return False
        for i in range(len(self.keys)):
            if self.route(int(self.keys[i])) is None:
                return False
        return True


def population_key(chip_x: int, chip_y: int, core: int, pop: int) -> int:
    """Conventional SpiNNaker key layout: x|y|core|population."""
    return (chip_x & 0xFF) << 24 | (chip_y & 0xFF) << 16 \
        | (core & 0xFF) << 8 | (pop & 0xFF)
