from repro.models import layers, moe, rglru, rwkv6, transformer
from repro.models.registry import input_specs, batch_specs, make_dummy_batch
