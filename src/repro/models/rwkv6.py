"""RWKV-6 "Finch" blocks (arXiv:2404.05892): attention-free time mixing with
data-dependent decay + channel mixing.

The WKV recurrence per head (state S in R^{hd_k x hd_v}):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = (S_{t-1} + diag(u * k_t) v_t ... ) read with r_t:
    y_t = r_t @ S_{t-1} + (r_t . (u * k_t)) v_t

``wkv_chunked`` evaluates it in chunks of C steps so the bulk of the FLOPs
are (C x C x hd) einsums (MXU-friendly) instead of a length-S scan.  All
decay factors are handled in log space with exponents <= 0, so the chunked
form is numerically stable for arbitrary data-dependent decays (the naive
factored GLA form overflows via exp(-cumsum)).  ``wkv_sequential`` is the
oracle used by tests and by single-token decode.

Hybrid-neuromorphic note (DESIGN.md section 2): the data-dependent decay w_t is
the LM-scale analogue of activity-dependent dynamics — state "energy" decays
unless events (tokens) refresh it, exactly the DVFS principle applied to
state instead of voltage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import EMBED, HEADS, LAYER, MLP, NONE, PSpec
from repro.models.loopctl import scan_or_loop

_LORA_MIX = 32      # token-shift mixing LoRA width
_LORA_DECAY = 64    # decay LoRA width


def time_mix_pspecs(cfg):
    d = cfg.d_model
    return {
        "mu_base": PSpec((d,), (EMBED,), "zeros"),
        "mu_wkvrg": PSpec((5, d), (NONE, EMBED), "zeros"),
        "w1_mix": PSpec((d, 5 * _LORA_MIX), (EMBED, NONE)),
        "w2_mix": PSpec((5, _LORA_MIX, d), (NONE, NONE, EMBED)),
        "w0": PSpec((d,), (EMBED,), "zeros"),
        "w1_decay": PSpec((d, _LORA_DECAY), (EMBED, NONE)),
        "w2_decay": PSpec((_LORA_DECAY, d), (NONE, EMBED), "zeros"),
        "u": PSpec((d,), (EMBED,), "zeros"),
        "wr": PSpec((d, d), (EMBED, HEADS)),
        "wk": PSpec((d, d), (EMBED, HEADS)),
        "wv": PSpec((d, d), (EMBED, HEADS)),
        "wg": PSpec((d, d), (EMBED, HEADS)),
        "wo": PSpec((d, d), (HEADS, EMBED), "out"),
        "ln_x_scale": PSpec((d,), (EMBED,), "zeros"),
        "ln_x_bias": PSpec((d,), (EMBED,), "zeros"),
    }


def _token_shift(x, prev):
    """prev: (B,1,d) state (zeros at seq start) -> x_{t-1} sequence."""
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix_vectors(p, x, sx):
    """Data-dependent token-shift mixing -> 5 mixed inputs (w,k,v,r,g)."""
    xx = x + sx * p["mu_base"].astype(x.dtype)
    lora = jnp.einsum("bsd,dl->bsl", xx, p["w1_mix"].astype(x.dtype))
    B, S, _ = x.shape
    lora = jnp.tanh(lora.reshape(B, S, 5, _LORA_MIX))
    mixes = jnp.einsum("bsfl,fld->bsfd", lora, p["w2_mix"].astype(x.dtype))
    mixes = mixes + p["mu_wkvrg"].astype(x.dtype)[None, None]
    # x_i = x + sx * mix_i for each of the five streams
    return x[:, :, None] + sx[:, :, None] * mixes            # (B,S,5,d)


def _decay(p, xw):
    """Log decay lw = -exp(w0 + lora(xw)) in fp32, <= 0."""
    lora = jnp.einsum("bsd,dl->bsl", xw.astype(jnp.float32),
                      p["w1_decay"].astype(jnp.float32))
    lora = jnp.einsum("bsl,ld->bsd", jnp.tanh(lora),
                      p["w2_decay"].astype(jnp.float32))
    return -jnp.exp(jnp.clip(p["w0"].astype(jnp.float32) + lora, -12.0, 3.0))


def wkv_sequential(r, k, v, lw, u, state0):
    """Oracle WKV.  r,k,v: (B,S,H,D); lw: (B,S,H,D) log-decay; u: (H,D).

    state0: (B,H,D,D) f32 (k-index first).  Returns (y (B,S,H,D) f32, state).
    """
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))

    def step(S, inp):
        rt, kt, vt, lwt = inp                               # (B,H,D)
        w = jnp.exp(lwt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S) \
            + jnp.einsum("bhk,bhk,bhv->bhv", rt, u[None] * kt, vt)
        S = S * w[..., None] + jnp.einsum("bhk,bhv->bhkv", kt, vt)
        return S, y

    xs = tuple(t.transpose(1, 0, 2, 3) for t in (rf, kf, vf, lw))
    state, ys = jax.lax.scan(step, state0, xs)
    return ys.transpose(1, 0, 2, 3), state


def wkv_chunked(r, k, v, lw, u, state0, chunk=32):
    """Chunked WKV, exact (log-space, exponents <= 0).  Shapes as above."""
    B, S, H, D = r.shape
    C = min(chunk, S)
    assert S % C == 0, (S, C)
    N = S // C
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    rs, ks, vs, lws = (t.reshape(B, N, C, H, D).transpose(1, 0, 2, 3, 4)
                       for t in (rf, kf, vf, lw))

    tri = jnp.tril(jnp.ones((C, C), jnp.bool_), -1)          # j < i

    import functools
    @functools.partial(jax.checkpoint, prevent_cse=False,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def one_chunk(S0, inp):
        rc, kc, vc, lwc = inp                                # (B,C,H,D)
        incl = jnp.cumsum(lwc, axis=1)                       # (B,C,H,D)
        excl = incl - lwc
        # inter-chunk: y_i += (r_i * exp(excl_i)) @ S0
        y = jnp.einsum("bchk,bhkv->bchv", rc * jnp.exp(excl), S0)
        # intra-chunk: pairwise decay P_ij = exp(excl_i - incl_j), j < i
        expo = excl[:, :, None] - incl[:, None, :, :, :]     # (B,C,C,H,D) <= 0
        P = jnp.exp(jnp.where(tri[None, :, :, None, None], expo, -jnp.inf))
        A = jnp.einsum("bihk,bjhk,bijhk->bijh", rc, kc, P)
        y = y + jnp.einsum("bijh,bjhv->bihv", A, vc)
        # bonus diagonal term
        y = y + jnp.einsum("bchk,bchk,bchv->bchv", rc, u[None, None] * kc, vc)
        # state update: S = exp(b_C) * S0 + sum_j exp(b_C - incl_j) k_j v_j^T
        total = incl[:, -1]                                  # (B,H,D)
        Snew = S0 * jnp.exp(total)[..., None] + jnp.einsum(
            "bchk,bchv->bhkv", kc * jnp.exp(total[:, None] - incl), vc)
        return Snew, y

    state, ys = scan_or_loop(one_chunk, state0, (rs, ks, vs, lws))
    return ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D), state


def group_norm(y, scale, bias, H, eps=1e-5):
    """Per-head layer norm over head_dim (GroupNorm with H groups)."""
    B, S, d = y.shape
    yh = y.reshape(B, S, H, d // H).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    yh = yh.reshape(B, S, d)
    return yh * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)


def time_mix_apply(cfg, p, x, cache=None, chunk=32, use_chunked=True,
                   mesh=None):
    """x: (B,S,d).  cache: None or {"shift": (B,1,d), "state": (B,H,D,D) f32}.

    Returns (out, new_cache).
    """
    B, S, d = x.shape
    H = d // cfg.rwkv_head_size
    D = cfg.rwkv_head_size
    prev = cache["shift"] if cache is not None else jnp.zeros((B, 1, d), x.dtype)
    state0 = (cache["state"] if cache is not None
              else jnp.zeros((B, H, D, D), jnp.float32))

    sx = _token_shift(x, prev) - x
    mixed = _mix_vectors(p, x, sx)                           # (B,S,5,d)
    xw, xk, xv, xr, xg = (mixed[:, :, i] for i in range(5))
    lw = _decay(p, xw).reshape(B, S, H, D)
    r = jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)).reshape(B, S, H, D)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"].astype(x.dtype)).reshape(B, S, H, D)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"].astype(x.dtype)).reshape(B, S, H, D)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"].astype(x.dtype)))
    if mesh is not None:
        from repro.dist.sharding import act_hint
        r = act_hint(r, mesh, ("batch", None, "model", None))
        k = act_hint(k, mesh, ("batch", None, "model", None))
        v = act_hint(v, mesh, ("batch", None, "model", None))
        lw = act_hint(lw, mesh, ("batch", None, "model", None))
    u = p["u"].astype(jnp.float32).reshape(H, D)

    if S == 1 or not use_chunked:
        y, state = wkv_sequential(r, k, v, lw, u, state0)
    else:
        y, state = wkv_chunked(r, k, v, lw, u, state0, chunk=chunk)

    y = group_norm(y.reshape(B, S, d), p["ln_x_scale"], p["ln_x_bias"], H)
    y = (y.astype(x.dtype) * g)
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(x.dtype))
    new_cache = {"shift": x[:, -1:], "state": state}
    return out, new_cache


def channel_mix_apply(cfg, p, x, cache=None):
    """RWKV channel mixing.  cache: {"shift": (B,1,d)}."""
    B, S, d = x.shape
    prev = cache["shift"] if cache is not None else jnp.zeros((B, 1, d), x.dtype)
    sx = _token_shift(x, prev) - x
    xk = x + sx * p["mix_k"].astype(x.dtype)
    xr = x + sx * p["mix_r"].astype(x.dtype)
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"].astype(x.dtype))))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["wv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"].astype(x.dtype)))
    return rr * vv, {"shift": x[:, -1:]}


def rwkv_cache_specs(cfg, batch, dtype=jnp.bfloat16):
    d = cfg.d_model
    H, D = d // cfg.rwkv_head_size, cfg.rwkv_head_size
    return {
        "tmix": {"shift": jax.ShapeDtypeStruct((batch, 1, d), dtype),
                 "state": jax.ShapeDtypeStruct((batch, H, D, D), jnp.float32)},
        "cmix": {"shift": jax.ShapeDtypeStruct((batch, 1, d), dtype)},
    }
