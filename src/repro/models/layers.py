"""Shared model building blocks.

Parameters are declared as ``PSpec`` trees (shape + logical axes + init
style); the same tree mechanically yields real initialized params, abstract
``ShapeDtypeStruct`` trees for the dry-run, and logical-axis trees for the
sharding rules in ``repro.dist.sharding``.

All matmuls run in bf16 with fp32 accumulation; norms / softmax / rope and
recurrence gates run in fp32.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.loopctl import map_or_loop, scan_or_loop

# ---------------------------------------------------------------------------
# Logical axis names (mapped to mesh axes by repro.dist.sharding rules)
# ---------------------------------------------------------------------------
EMBED = "embed"        # d_model           -> fsdp ("data")
VOCAB = "vocab"        # vocabulary        -> "model"
HEADS = "heads"        # flattened q_dim   -> "model"
KV = "kv"              # flattened kv_dim  -> "model"
MLP = "mlp"            # d_ff              -> "model"
EXPERT = "expert"      # MoE experts       -> "model"
LAYER = "layer"        # stacked scan axis -> unsharded
VOCAB_TBL = "vocab_tbl"  # embedding-table vocab dim (serve: unsharded)
EMBED_TBL = "embed_tbl"  # embedding-table d dim (serve: "model")
NONE = None


@dataclass(frozen=True)
class PSpec:
    shape: tuple
    axes: tuple
    init: str = "normal"      # "normal" | "out" | "zeros" | "ones" | "embed"
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _path_seed(path: str) -> int:
    return int(np.uint32(hash(path) & 0xFFFFFFFF))


def init_leaf(spec: PSpec, rng: jax.Array, path: str, depth_scale: float = 1.0):
    key = jax.random.fold_in(rng, _path_seed(path))
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape, jnp.float32)).astype(dt)
    scale = 0.02
    if spec.init == "out":
        scale = 0.02 * depth_scale
    return (scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(dt)


def tree_paths(tree, prefix=""):
    """Flatten a nested dict/list tree of PSpec into {path: spec}."""
    out = {}
    if isinstance(tree, PSpec):
        out[prefix] = tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            out.update(tree_paths(v, f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(tree_paths(v, f"{prefix}/{i}"))
    else:
        raise TypeError(type(tree))
    return out


def init_params(spec_tree, rng: jax.Array, depth_scale: float = 1.0):
    def walk(node, prefix):
        if isinstance(node, PSpec):
            return init_leaf(node, rng, prefix, depth_scale)
        if isinstance(node, dict):
            return {k: walk(v, f"{prefix}/{k}") for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return [walk(v, f"{prefix}/{i}") for i, v in enumerate(node)]
        raise TypeError(type(node))
    return walk(spec_tree, "")


def param_shapes(spec_tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        spec_tree, is_leaf=lambda x: isinstance(x, PSpec))


def param_axes(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree,
                        is_leaf=lambda x: isinstance(x, PSpec))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


# ---------------------------------------------------------------------------
# Norms (fp32 math)
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32)) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_pspecs(cfg, d=None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": PSpec((d,), (EMBED,), "zeros"),
                "bias": PSpec((d,), (EMBED,), "zeros")}
    return {"scale": PSpec((d,), (EMBED,), "zeros")}


def rmsnorm_bf16(x, scale, eps=1e-6):
    """Variance in f32 (fused into the reduce); multiplies in x.dtype —
    avoids materializing full-sequence f32 copies of the residual."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * r * (1.0 + scale.astype(jnp.float32)).astype(x.dtype)


def layernorm_bf16(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps).astype(x.dtype)
    y = (x - mu.astype(x.dtype)) * r
    return y * (1.0 + scale.astype(jnp.float32)).astype(x.dtype)         + bias.astype(x.dtype)


def apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        if cfg.norm_bf16_mul:
            return layernorm_bf16(x, p["scale"], p["bias"], cfg.norm_eps)
        return layernorm(x, p["scale"], p["bias"], cfg.norm_eps)
    if cfg.norm_bf16_mul:
        return rmsnorm_bf16(x, p["scale"], cfg.norm_eps)
    return rmsnorm(x, p["scale"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Positional embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, rope_pct: float, base: float):
    rot = int(head_dim * rope_pct)
    rot -= rot % 2
    inv = 1.0 / (base ** (np.arange(0, rot, 2, dtype=np.float64) / rot))
    return rot, jnp.asarray(inv, jnp.float32)


def apply_rope(x, pos, *, base=10_000.0, pct=1.0):
    """x: (..., S, H, D); pos: broadcastable to (..., S). Half-split layout."""
    D = x.shape[-1]
    rot, inv = rope_freqs(D, pct, base)
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    ang = pos[..., None].astype(jnp.float32) * inv          # (..., S, rot/2)
    sin = jnp.sin(ang)[..., None, :]                         # (..., S, 1, rot/2)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    y1 = x1f * cos - x2f * sin
    y2 = x2f * cos + x1f * sin
    out = jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)
    return jnp.concatenate([out, xp], axis=-1) if rot < D else out


def sinusoidal_emb(pos, d_model: int, dtype=jnp.float32):
    """pos: (...,) -> (..., d_model)."""
    half = d_model // 2
    freq = np.exp(-np.log(10_000.0) * np.arange(half) / half)
    ang = pos[..., None].astype(jnp.float32) * jnp.asarray(freq, jnp.float32)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

_NEG = -1e30


def _mask(qpos, kpos, window):
    """qpos: (Q,), kpos: (K,) -> bool (Q, K). Causal, optional sliding window."""
    m = kpos[None, :] <= qpos[:, None]
    if window:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def attention_dense(q, k, v, qpos, kpos, *, window=0, kv_len=None):
    """q: (B,Sq,KH,G,D)  k,v: (B,Sk,KH,D).  Reference / small-seq path."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    m = _mask(qpos, kpos, window)
    if kv_len is not None:                       # decode: valid cache prefix
        m &= ((kpos < kv_len) & (kpos >= 0))[None, :]
    s = jnp.where(m[None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o


def _bw_attn_fwd(q, k, v, qpos, kpos, window, cq, ck):
    """Blockwise online-softmax forward.  Returns (out f32, lse f32).

    q: (B,Sq,KH,G,D); k,v: (B,Sk,KH,D); qpos: (Sq,), kpos: (Sk,)
    """
    B, Sq, KH, G, D = q.shape
    Sk = k.shape[1]
    nq, nk = Sq // cq, Sk // ck
    scale = 1.0 / np.sqrt(D)

    qs = q.reshape(B, nq, cq, KH, G, D).transpose(1, 0, 2, 3, 4, 5)
    qp = qpos.reshape(nq, cq)
    ks = k.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    kp = kpos.reshape(nk, ck)

    def one_q(args):
        qc, qpc = args                                     # (B,cq,KH,G,D), (cq,)

        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, kpc = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qpc, kpc, window)[None, None, None]
            s = jnp.where(msk, s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(msk, p, 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, KH, G, cq), _NEG, jnp.float32)
        l0 = jnp.zeros((B, KH, G, cq), jnp.float32)
        a0 = jnp.zeros((B, KH, G, cq, D), jnp.float32)
        (m, l, acc), _ = scan_or_loop(kv_step, (m0, l0, a0), (ks, vs, kp))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))           # (B,KH,G,cq)
        return o.transpose(0, 3, 1, 2, 4), lse.transpose(0, 3, 1, 2)

    outs, lses = map_or_loop(one_q, (qs, qp))              # (nq,B,cq,...)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KH, G, D)
    lse = lses.transpose(1, 0, 2, 3, 4).reshape(B, Sq, KH, G)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_attention(cfg_static, q, k, v, qpos, kpos):
    """Flash attention with recompute-in-backward VJP (O(S) residuals)."""
    window, cq, ck = cfg_static
    out, _ = _bw_attn_fwd(q, k, v, qpos, kpos, window, cq, ck)
    return out.astype(q.dtype)


def _flash_fwd(cfg_static, q, k, v, qpos, kpos):
    window, cq, ck = cfg_static
    out, lse = _bw_attn_fwd(q, k, v, qpos, kpos, window, cq, ck)
    return out.astype(q.dtype), (q, k, v, qpos, kpos, out, lse)


def _flash_bwd(cfg_static, res, dout):
    window, cq, ck = cfg_static
    q, k, v, qpos, kpos, out, lse = res
    B, Sq, KH, G, D = q.shape
    Sk = k.shape[1]
    nq, nk = Sq // cq, Sk // ck
    scale = 1.0 / np.sqrt(D)

    do = dout.astype(jnp.float32)
    delta = jnp.sum(do * out, axis=-1)                     # (B,Sq,KH,G)

    qs = q.reshape(B, nq, cq, KH, G, D).transpose(1, 0, 2, 3, 4, 5)
    dos = do.reshape(B, nq, cq, KH, G, D).transpose(1, 0, 2, 3, 4, 5)
    dls = delta.reshape(B, nq, cq, KH, G).transpose(1, 0, 2, 3, 4)
    lss = lse.reshape(B, nq, cq, KH, G).transpose(1, 0, 2, 3, 4)
    qp = qpos.reshape(nq, cq)
    ks = k.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    kp = kpos.reshape(nk, ck)

    def q_step(carry, inp):
        dk_acc, dv_acc = carry                             # (nk,B,ck,KH,D) f32
        qc, doc, dlc, lsc, qpc = inp

        def kv_step(dq_acc, inp2):
            kc, vc, kpc = inp2
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qpc, kpc, window)[None, None, None]
            p = jnp.exp(s - lsc.transpose(0, 2, 3, 1)[..., None])
            p = jnp.where(msk, p, 0.0)                     # (B,KH,G,cq,ck)
            dv = jnp.einsum("bhgqk,bqhgd->bkhd", p, doc)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doc, vc)
            ds = p * (dp - dlc.transpose(0, 2, 3, 1)[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kc)
            dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qc)
            return dq_acc, (dk, dv)

        dq0 = jnp.zeros((B, cq, KH, G, D), jnp.float32)
        dq, (dks, dvs) = scan_or_loop(kv_step, dq0, (ks, vs, kp))
        return (dk_acc + dks, dv_acc + dvs), dq

    z = jnp.zeros((nk, B, ck, KH, D), jnp.float32)
    (dk_s, dv_s), dqs = scan_or_loop(q_step, (z, z),
                                     (qs, dos, dls, lss, qp))
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KH, G, D).astype(q.dtype)
    dk = dk_s.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KH, D).astype(k.dtype)
    dv = dv_s.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KH, D).astype(v.dtype)
    return dq, dk, dv, None, None


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention_blockwise(q, k, v, qpos, kpos, *, window=0,
                        chunk_q=1024, chunk_kv=1024, impl="baseline"):
    B, Sq, KH, G, D = q.shape
    Sk = k.shape[1]
    from repro.models.loopctl import unroll_mode
    if unroll_mode():
        # roofline-extrapolation lowers: total FLOPs/bytes are chunk-size
        # invariant (full masked sweep is S^2 either way; packed triangle
        # changes only by the O(S*cq) diagonal), so bigger chunks -> far
        # fewer unrolled bodies -> much faster cost-analysis compiles
        chunk_q = max(chunk_q, 4096)
        chunk_kv = max(chunk_kv, 4096)
    cq = min(chunk_q, Sq)
    ck = min(chunk_kv, Sk)
    assert Sq % cq == 0 and Sk % ck == 0, (Sq, cq, Sk, ck)
    if impl == "packed" and Sq == Sk:
        return _attention_packed(q, k, v, qpos, kpos, window=window,
                                 cq=cq, ck=ck)
    return _flash_attention((window, cq, ck), q, k, v, qpos, kpos)


# ---------------------------------------------------------------------------
# Packed attention (beyond-paper perf path, selected via cfg.attn_impl)
#
# The baseline flash sweep visits every (q-chunk, kv-chunk) pair and masks —
# 2x wasted FLOPs for causal, ~nk/2 x for sliding windows.  The packed path
# visits only chunk pairs that can contain unmasked entries:
#   * sliding window (window <= ck): exactly 2 kv chunks per q chunk,
#   * causal (+ wide window): the lower triangle intersected with the
#     window band — nq(nq+1)/2 pairs instead of nq*nk for pure causal.
# ---------------------------------------------------------------------------

def _attention_packed(q, k, v, qpos, kpos, *, window, cq, ck):
    B, Sq, KH, G, D = q.shape
    nq, nk = Sq // cq, Sq // ck
    scale = 1.0 / np.sqrt(D)
    qs = q.reshape(B, nq, cq, KH, G, D).transpose(1, 0, 2, 3, 4, 5)
    qp = qpos.reshape(nq, cq)
    ks = k.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, ck, KH, D).transpose(1, 0, 2, 3, 4)
    kp = kpos.reshape(nk, ck)

    def block(qc, qpc, kc, vc, kpc, m, l, acc):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kc,
                       preferred_element_type=jnp.float32) * scale
        msk = _mask(qpc, kpc, window)[None, None, None]
        s = jnp.where(msk, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # p in bf16: exp(s - m) is in (0, 1], safe at bf16 resolution; the
        # row-sum and pv-einsum still accumulate in f32.  Halves the
        # dominant HBM traffic of the attention inner loop.
        p = jnp.where(msk, jnp.exp(s - m_new[..., None]), 0.0)             .astype(vc.dtype)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1, dtype=jnp.float32)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc,
            preferred_element_type=jnp.float32)
        return m_new, l, acc

    if window and window <= ck and cq == ck:
        # each q chunk sees kv chunks {i-1, i} only
        def one_q(args):
            qc, qpc, i = args
            m = jnp.full((B, KH, G, cq), _NEG, jnp.float32)
            l = jnp.zeros((B, KH, G, cq), jnp.float32)
            acc = jnp.zeros((B, KH, G, cq, D), jnp.float32)
            for off in (1, 0):                     # chunk i-1, then i
                j = jnp.maximum(i - off, 0)
                kc = jax.lax.dynamic_index_in_dim(ks, j, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(vs, j, 0, keepdims=False)
                kpc = jax.lax.dynamic_index_in_dim(kp, j, 0, keepdims=False)
                # when i == 0 the "previous" chunk is a duplicate of chunk 0;
                # shifting its positions far negative makes the window mask
                # kill every entry
                kpc = jnp.where((i - off) < 0, kpc - Sq - window, kpc)
                m, l, acc = block(qc, qpc, kc, vc, kpc, m, l, acc)
            o = acc / jnp.maximum(l, 1e-30)[..., None]
            return o.transpose(0, 3, 1, 2, 4)

        outs = map_or_loop(one_q, (qs, qp, jnp.arange(nq)))
        return outs.transpose(1, 0, 2, 3, 4, 5).reshape(
            B, Sq, KH, G, D).astype(q.dtype)

    # causal (optionally window-banded): packed static pair list
    def _keep(i, j):
        if j * ck > (i + 1) * cq - 1:
            return False                           # entirely in the future
        if window and (j + 1) * ck - 1 <= i * cq - window:
            return False                           # entirely past the window
        return True

    pairs = np.array([(i, j) for i in range(nq) for j in range(nk)
                      if _keep(i, j)], np.int32)
    i_idx = jnp.asarray(pairs[:, 0])
    j_idx = jnp.asarray(pairs[:, 1])

    @functools.partial(jax.checkpoint, prevent_cse=False,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def pair_step(carry, ij):
        m, l, acc = carry                          # (nq,B,KH,G,cq[,D])
        i, j = ij
        qc = jax.lax.dynamic_index_in_dim(qs, i, 0, keepdims=False)
        qpc = jax.lax.dynamic_index_in_dim(qp, i, 0, keepdims=False)
        kc = jax.lax.dynamic_index_in_dim(ks, j, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vs, j, 0, keepdims=False)
        kpc = jax.lax.dynamic_index_in_dim(kp, j, 0, keepdims=False)
        mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 0, keepdims=False)
        mi, li, ai = block(qc, qpc, kc, vc, kpc, mi, li, ai)
        m = jax.lax.dynamic_update_index_in_dim(m, mi, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, li, i, 0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, ai, i, 0)
        return (m, l, acc), None

    m0 = jnp.full((nq, B, KH, G, cq), _NEG, jnp.float32)
    l0 = jnp.zeros((nq, B, KH, G, cq), jnp.float32)
    a0 = jnp.zeros((nq, B, KH, G, cq, D), jnp.float32)
    (m, l, acc), _ = scan_or_loop(pair_step, (m0, l0, a0), (i_idx, j_idx))
    o = acc / jnp.maximum(l, 1e-30)[..., None]     # (nq,B,KH,G,cq,D)
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, KH, G, D)
    return o.astype(q.dtype)


def attention(q, k, v, qpos, kpos, *, window=0, kv_len=None,
              chunk_q=1024, chunk_kv=1024, force_dense=False,
              impl="baseline"):
    """Dispatch: dense for small problems / decode, blockwise otherwise."""
    Sq, Sk = q.shape[1], k.shape[1]
    if force_dense or kv_len is not None or (Sq * Sk) <= 4 * 1024 * 1024:
        return attention_dense(q, k, v, qpos, kpos, window=window, kv_len=kv_len)
    return attention_blockwise(q, k, v, qpos, kpos, window=window,
                               chunk_q=chunk_q, chunk_kv=chunk_kv, impl=impl)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + qk-norm + GQA)
# ---------------------------------------------------------------------------

def attn_pspecs(cfg):
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": PSpec((d, qd), (EMBED, HEADS)),
        "wk": PSpec((d, kvd), (EMBED, KV)),
        "wv": PSpec((d, kvd), (EMBED, KV)),
        "wo": PSpec((qd, d), (HEADS, EMBED), "out"),
    }
    if cfg.qkv_bias:
        p["bq"] = PSpec((qd,), (HEADS,), "zeros")
        p["bk"] = PSpec((kvd,), (KV,), "zeros")
        p["bv"] = PSpec((kvd,), (KV,), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = PSpec((cfg.head_dim,), (NONE,), "zeros")
        p["k_norm"] = PSpec((cfg.head_dim,), (NONE,), "zeros")
    return p


def attn_apply(cfg, p, x, qpos, *, kind="attn", cache=None, kv_len=None,
               mesh=None):
    """x: (B,S,d).  cache: None (full-seq) or dict(k,v,(ring) pos) for decode.

    Returns (out, new_cache).
    """
    B, S, d = x.shape
    KH, H, D = cfg.num_kv_heads, cfg.num_heads, cfg.head_dim
    G = H // KH
    window = cfg.window_size if kind == "local" else 0
    base = cfg.rope_base
    if kind == "attn" and cfg.rope_base_global:
        base = cfg.rope_base_global

    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, D)
    k = k.reshape(B, S, KH, D)
    v = v.reshape(B, S, KH, D)
    att_KH, att_G = KH, G
    if mesh is not None and cache is None:
        from repro.dist.sharding import act_hint
        tp = mesh.shape.get("model", 1)
        if KH % tp == 0:
            # head-parallel attention
            q = act_hint(q, mesh, ("batch", None, "model", None))
            k = act_hint(k, mesh, ("batch", None, "model", None))
            v = act_hint(v, mesh, ("batch", None, "model", None))
        elif cfg.attn_part == "expand" and H % tp == 0:
            # GQA expansion: repeat KV to the full head count so every
            # einsum shards head-parallel (beyond-paper perf path; the
            # baseline context-parallel fallback replicates attention
            # compute across "model" when kv_heads < TP)
            k = jnp.repeat(k, G, axis=2)
            v = jnp.repeat(v, G, axis=2)
            att_KH, att_G = H, 1
            q = act_hint(q, mesh, ("batch", None, "model", None))
            k = act_hint(k, mesh, ("batch", None, "model", None))
            v = act_hint(v, mesh, ("batch", None, "model", None))
        else:
            # context-parallel attention: shard q rows, replicate kv
            q = act_hint(q, mesh, ("batch", "model", None, None))
            k = act_hint(k, mesh, ("batch", None, None, None))
            v = act_hint(v, mesh, ("batch", None, None, None))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_emb == "rope":
        q = apply_rope(q, qpos, base=base, pct=cfg.rope_pct)
        k = apply_rope(k, qpos, base=base, pct=cfg.rope_pct)
    q = q.reshape(B, S, att_KH, att_G, D)

    if cache is None:
        kpos = qpos
        o = attention(q, k, v, qpos, kpos, window=window, impl=cfg.attn_impl)
        new_cache = None
    else:
        # decode: insert k,v at cache position, attend over valid prefix
        ck, cv = cache["k"], cache["v"]                     # (B,Sc,KH,D)
        Sc = ck.shape[1]
        if window and Sc == window:                          # ring buffer
            slot = jnp.mod(kv_len, window)
        else:
            slot = kv_len
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
        if window and Sc == window:
            kpos = _ring_positions(kv_len, window)        # abs pos per slot
        else:
            kpos = jnp.arange(Sc)
        o = attention_dense(q, ck, cv, qpos, kpos, window=window,
                            kv_len=kv_len + 1)
        new_cache = {"k": ck, "v": cv}

    o = o.reshape(B, S, H * D)
    out = jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype))
    return out, new_cache


def _ring_positions(kv_len, window):
    """Absolute position stored in each ring slot after writing pos=kv_len."""
    slots = jnp.arange(window)
    cur_slot = jnp.mod(kv_len, window)
    # slot s holds position kv_len - ((cur_slot - s) mod window)
    return kv_len - jnp.mod(cur_slot - slots, window)


def init_attn_cache(cfg, batch, max_seq, kind, dtype=jnp.bfloat16):
    S = min(max_seq, cfg.window_size) if kind == "local" and cfg.window_size else max_seq
    shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_cache_specs(cfg, batch, max_seq, kind, dtype=jnp.bfloat16):
    S = min(max_seq, cfg.window_size) if kind == "local" and cfg.window_size else max_seq
    shape = (batch, S, cfg.num_kv_heads, cfg.head_dim)
    sds = jax.ShapeDtypeStruct(shape, dtype)
    return {"k": sds, "v": sds}


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_pspecs(cfg):
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {"wi": PSpec((d, f), (EMBED, MLP)),
                "wg": PSpec((d, f), (EMBED, MLP)),
                "wo": PSpec((f, d), (MLP, EMBED), "out")}
    if cfg.mlp == "rwkv_channel_mix":
        return {"wk": PSpec((d, f), (EMBED, MLP)),
                "wv": PSpec((f, d), (MLP, EMBED), "out"),
                "wr": PSpec((d, d), (EMBED, EMBED)),
                "mix_k": PSpec((d,), (EMBED,), "zeros"),
                "mix_r": PSpec((d,), (EMBED,), "zeros")}
    return {"wi": PSpec((d, f), (EMBED, MLP)),
            "wo": PSpec((f, d), (MLP, EMBED), "out")}


def mlp_apply(cfg, p, x, mesh=None):
    dt = x.dtype
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else partial(jax.nn.gelu, approximate=True)
        h = act(jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))) \
            * jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))))
    else:  # gelu
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt)),
                        approximate=True)
    if mesh is not None:
        from repro.dist.sharding import act_hint
        h = act_hint(h, mesh, ("batch", None, "model"))
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_pspecs(cfg):
    p = {"table": PSpec((cfg.vocab_size, cfg.d_model),
                        (VOCAB_TBL, EMBED_TBL), "embed")}
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            p["head"] = PSpec((cfg.num_codebooks, cfg.d_model, cfg.vocab_size),
                              (NONE, EMBED, VOCAB))
        else:
            p["head"] = PSpec((cfg.d_model, cfg.vocab_size), (EMBED, VOCAB))
    return p


def embed_lookup(cfg, p, tokens, dtype=jnp.bfloat16):
    x = jnp.take(p["table"], tokens, axis=0).astype(dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    return x


def head_matrix(cfg, p):
    """(d, V) or (C, d, V) head weights."""
    if cfg.tie_embeddings:
        return p["table"].T
    return p["head"]
