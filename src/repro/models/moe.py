"""Mixture-of-Experts block.

Dispatch is the rate-based twin of SpiNNaker2 multicast spike routing
(DESIGN.md section 2): a token's top-k expert assignment is a "spike with
payload" — the router key picks destinations, the activation vector is the
graded payload.  Two implementations:

* ``moe_apply_dense``  — oracle: every expert sees every token, masked
  combine.  O(T * E * ff) FLOPs; used for tests and tiny configs only.
* ``moe_apply``        — production sort-based capacity dispatch: tokens are
  scattered to (E, C, d) buffers (C = capacity), expert FFNs run as grouped
  einsums sharded expert-parallel on the "model" mesh axis, results are
  combined with router weights.  Overflowing tokens are dropped (standard
  Switch-style), underflow is padding.

Aux losses: load-balance (Switch) + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import EMBED, EXPERT, MLP, NONE, PSpec


def moe_pspecs(cfg):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {"router": PSpec((d, E), (EMBED, EXPERT))}
    if cfg.mlp in ("swiglu", "geglu"):
        p.update({
            "wi": PSpec((E, d, f), (EXPERT, EMBED, MLP)),
            "wg": PSpec((E, d, f), (EXPERT, EMBED, MLP)),
            "wo": PSpec((E, f, d), (EXPERT, MLP, EMBED), "out"),
        })
    else:
        p.update({
            "wi": PSpec((E, d, f), (EXPERT, EMBED, MLP)),
            "wo": PSpec((E, f, d), (EXPERT, MLP, EMBED), "out"),
        })
    return p


def _router(cfg, p, x):
    """x: (T, d) -> (probs (T,E) f32, logits f32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    return jax.nn.softmax(logits, axis=-1), logits


def _expert_ffn(cfg, p, xe):
    """xe: (E, C, d) -> (E, C, d); grouped einsum, expert axis sharded (EP)."""
    dt = xe.dtype
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))) \
            * jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt))))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(dt)))
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dt))


def aux_losses(probs, sel_onehot):
    """Switch load-balance loss + z-loss ingredients.

    probs: (T, E) f32; sel_onehot: (T, E) f32 (summed over k).
    """
    E = probs.shape[-1]
    density = jnp.mean(sel_onehot, axis=0)           # fraction routed
    density_proxy = jnp.mean(probs, axis=0)
    lb = E * jnp.sum(density * density_proxy)
    return lb


def moe_apply(cfg, p, x, *, capacity_factor=None):
    """Sort-based top-k dispatch with capacity.  x: (B, S, d)."""
    B, S, d = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.experts_per_token
    cf = capacity_factor or cfg.capacity_factor
    C = int(np.ceil(cf * T * K / E))
    C = max(C, 1)

    xt = x.reshape(T, d)
    probs, logits = _router(cfg, p, xt)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)    # renormalize

    # --- capacity assignment: position of each (token, k) within its expert
    flat_e = gate_idx.reshape(-1)                            # (T*K,)
    # rank of each assignment among same-expert assignments, in token order
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (T*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - 1                # inclusive -> 0-based
    my_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = my_pos < C                                        # drop overflow

    # --- scatter tokens into (E, C, d)
    dst = jnp.where(keep, flat_e * C + my_pos, E * C)        # overflow -> trash row
    buf = jnp.zeros((E * C + 1, d), x.dtype)
    src = jnp.repeat(xt, K, axis=0) if K > 1 else xt
    # token index for each flat assignment
    tok_idx = jnp.repeat(jnp.arange(T), K) if K > 1 else jnp.arange(T)
    buf = buf.at[dst].add(src.astype(x.dtype))
    xe = buf[: E * C].reshape(E, C, d)

    ye = _expert_ffn(cfg, p, xe)                             # (E, C, d)

    # --- combine back: gather each assignment's output, weight, sum over K
    yt = ye.reshape(E * C, d)
    yt = jnp.concatenate([yt, jnp.zeros((1, d), yt.dtype)], axis=0)
    gathered = yt[dst]                                       # (T*K, d)
    w = (gate_vals.reshape(-1) * keep.astype(jnp.float32)).astype(x.dtype)
    contrib = gathered * w[:, None]
    out = jnp.zeros((T, d), x.dtype).at[tok_idx].add(contrib)

    sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(axis=1)
    lb_loss = aux_losses(probs, sel)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return out.reshape(B, S, d), {"lb_loss": lb_loss, "z_loss": z_loss}


def moe_apply_sharded(cfg, p, x, mesh, *, capacity_factor=None):
    """Expert-parallel MoE via shard_map (production path).

    Tokens are sharded over the batch axes and *replicated* over "model";
    each model rank dispatches locally (no cross-shard cumsum) and runs only
    its E/TP local experts; a single psum over "model" combines expert
    outputs — the same collective shape as a dense TP FFN.  This mirrors the
    SpiNNaker2 multicast router: the routing decision (key -> destinations)
    is computed where the spike originates, and only payloads destined for a
    core traverse its link.
    """
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import batch_axes

    ba = batch_axes(mesh)
    bspec = ba if len(ba) > 1 else (ba[0] if ba else None)
    tp = mesh.shape["model"]
    E = cfg.num_experts
    assert E % tp == 0, (E, tp)
    e_loc = E // tp

    def local(px, x_loc):
        probs, logits = _router(cfg, px, x_loc.reshape(-1, x_loc.shape[-1]))
        B, S, d = x_loc.shape
        T = B * S
        K = cfg.experts_per_token
        cf = capacity_factor or cfg.capacity_factor
        C = max(int(np.ceil(cf * T * K / E)), 1)

        gate_vals, gate_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
        flat_e = gate_idx.reshape(-1)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - 1
        my_pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
        keep = my_pos < C

        # local expert range for this model rank
        ridx = jax.lax.axis_index("model")
        lo = ridx * e_loc
        mine2 = (gate_idx >= lo) & (gate_idx < lo + e_loc) \
            & keep.reshape(T, K)                            # (T, K)
        my_pos2 = my_pos.reshape(T, K)
        xt = x_loc.reshape(T, d)

        # scatter one top-k slot at a time: K scatters of (T, d), no (T*K, d)
        buf = jnp.zeros((e_loc * C + 1, d), x_loc.dtype)
        for kk in range(K):
            dst_k = jnp.where(mine2[:, kk],
                              (gate_idx[:, kk] - lo) * C + my_pos2[:, kk],
                              e_loc * C)
            buf = buf.at[dst_k].add(xt)
        xe = buf[: e_loc * C].reshape(e_loc, C, d)

        ye = _expert_ffn(cfg, px, xe)

        yt = jnp.concatenate(
            [ye.reshape(e_loc * C, d), jnp.zeros((1, d), ye.dtype)], axis=0)
        out = jnp.zeros((T, d), x_loc.dtype)
        for kk in range(K):
            dst_k = jnp.where(mine2[:, kk],
                              (gate_idx[:, kk] - lo) * C + my_pos2[:, kk],
                              e_loc * C)
            w = (gate_vals[:, kk] * mine2[:, kk].astype(jnp.float32)
                 ).astype(x_loc.dtype)
            out = out + yt[dst_k] * w[:, None]
        def _aux():
            sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum(axis=1)
            lb = aux_losses(probs, sel)
            zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
            if ba:
                lb = jax.lax.pmean(lb, ba if len(ba) > 1 else ba[0])
                zl = jax.lax.pmean(zl, ba if len(ba) > 1 else ba[0])
            return lb, zl

        if cfg.moe_scatter_out and S % tp == 0:
            # reduce-scatter along seq: combine partial expert outputs into
            # the sequence-parallel residual layout directly
            out = out.reshape(B, S, d)
            out = jax.lax.psum_scatter(out, "model", scatter_dimension=1,
                                       tiled=True)
            return out, *_aux()
        out = jax.lax.psum(out, "model")
        return out.reshape(B, S, d), *_aux()

    pspecs = {
        "router": P(),
        "wi": P("model", None, None),
        "wo": P("model", None, None),
    }
    if "wg" in p:
        pspecs["wg"] = P("model", None, None)
    scatter = cfg.moe_scatter_out and x.shape[1] % tp == 0
    out_spec = P(bspec, "model", None) if scatter else P(bspec, None, None)
    fn = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(pspecs, P(bspec, None, None)),
        out_specs=(out_spec, P(), P()),
        check_vma=False,
    )
    out, lb, zl = fn({k: p[k] for k in pspecs}, x)
    return out, {"lb_loss": lb, "z_loss": zl}


def moe_apply_dense(cfg, p, x):
    """Oracle: run every expert on every token, weighted combine (no drops)."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    probs, logits = _router(cfg, p, xt)
    gate_vals, gate_idx = jax.lax.top_k(probs, cfg.experts_per_token)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    # full (T, E) combine weights
    w = jnp.sum(jax.nn.one_hot(gate_idx, cfg.num_experts) * gate_vals[..., None],
                axis=1)                                      # (T, E)
    ye = _expert_ffn(cfg, p, jnp.broadcast_to(xt[None], (cfg.num_experts, T, d)))
    out = jnp.einsum("etd,te->td", ye.astype(jnp.float32), w).astype(x.dtype)
    sel = jax.nn.one_hot(gate_idx, cfg.num_experts, dtype=jnp.float32).sum(axis=1)
    lb_loss = aux_losses(probs, sel)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return out.reshape(B, S, d), {"lb_loss": lb_loss, "z_loss": z_loss}
