"""Trace-time loop-mode switch.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE, regardless of trip
count, so scan-based lowerings under-report FLOPs/bytes/collectives.  For
roofline accounting the dry-run lowers small unrolled variants (1 and 2
layer-groups) under ``unrolled()`` — every lax.scan/map in the model
becomes a Python loop — and linearly extrapolates to the full depth, which
is exact for homogeneous stacks (see repro/launch/dryrun.py).

The production path always uses scans (small HLO, fast compiles); tests
assert both paths agree numerically.
"""
from __future__ import annotations

from contextlib import contextmanager

_UNROLL = False


def unroll_mode() -> bool:
    return _UNROLL


@contextmanager
def unrolled():
    global _UNROLL
    prev = _UNROLL
    _UNROLL = True
    try:
        yield
    finally:
        _UNROLL = prev


def scan_or_loop(body, carry, xs, *, length=None):
    """lax.scan drop-in honoring the unroll switch.

    body(carry, x) -> (carry, y).  Returns (carry, ys) with ys stacked (or
    None if every y is None).
    """
    import jax
    import jax.numpy as jnp

    if not _UNROLL:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def map_or_loop(fn, xs):
    """lax.map drop-in honoring the unroll switch."""
    import jax
    import jax.numpy as jnp

    if not _UNROLL:
        return jax.lax.map(fn, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    outs = [fn(jax.tree.map(lambda a: a[i], xs)) for i in range(n)]
    return jax.tree.map(lambda *a: jnp.stack(a), *outs)
