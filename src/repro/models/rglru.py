"""Griffin-style recurrent block with RG-LRU (RecurrentGemma, arXiv:2402.19427).

Block: x -> [W_x -> causal depthwise conv(4) -> RG-LRU] * gelu(W_gate x) -> W_o

RG-LRU (fp32):
    i_t = sigmoid(W_i u_t + b_i)
    a_t = exp(-c * softplus(Lambda) * sigmoid(W_a u_t + b_a)),  c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill uses ``jax.lax.associative_scan`` over the sequence (the
recurrence is linear in h); decode is a single fused step with O(1) state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import EMBED, NONE, PSpec
from repro.models.loopctl import scan_or_loop

LRU = "lru"          # recurrent width axis -> "model"
_C = 8.0             # RG-LRU decay sharpness constant


def rglru_pspecs(cfg):
    d = cfg.d_model
    w = cfg.lru_width or d
    cw = cfg.conv_width
    return {
        "wx": PSpec((d, w), (EMBED, LRU)),
        "wgate": PSpec((d, w), (EMBED, LRU)),
        "conv_w": PSpec((cw, w), (NONE, LRU)),
        "conv_b": PSpec((w,), (LRU,), "zeros"),
        "wi": PSpec((w, w), (NONE, LRU)),
        "bi": PSpec((w,), (LRU,), "zeros"),
        "wa": PSpec((w, w), (NONE, LRU)),
        "ba": PSpec((w,), (LRU,), "zeros"),
        "lam": PSpec((w,), (LRU,), "ones"),
        "wo": PSpec((w, d), (LRU, EMBED), "out"),
    }


def _causal_conv(p, u, conv_cache):
    """Depthwise causal conv, width cw.  u: (B,S,w); cache: (B,cw-1,w)."""
    cw = p["conv_w"].shape[0]
    full = jnp.concatenate([conv_cache.astype(u.dtype), u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(cw):
        # tap i uses x_{t-(cw-1-i)}
        out = out + full[:, i: i + u.shape[1]] * p["conv_w"][i].astype(u.dtype)
    out = out + p["conv_b"].astype(u.dtype)
    new_cache = full[:, -(cw - 1):] if cw > 1 else conv_cache
    return out, new_cache


def _gates(p, uf):
    """uf: (B,C,w) f32 -> (a, b) recurrence coefficients."""
    gate_i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf, p["wi"].astype(jnp.float32))
                            + p["bi"].astype(jnp.float32))
    gate_a = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", uf, p["wa"].astype(jnp.float32))
                            + p["ba"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * gate_a  # <= 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed from log_a for precision near a ~ 1
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta * gate_i * uf


def rg_lru(p, u, h0, chunk=1024):
    """u: (B,S,w); h0: (B,w) f32.  Returns (y (B,S,w) f32, h_final).

    Chunked: outer lax.scan carries the state across chunks; within a chunk
    the linear recurrence runs as an associative_scan.  The chunk body is
    rematerialized so backward keeps O(chunk) residuals.
    """
    uf = u.astype(jnp.float32)
    if u.shape[1] == 1:                                     # decode fast path
        a, b = _gates(p, uf)
        h = a[:, 0] * h0 + b[:, 0]
        return h[:, None], h

    from repro.models.loopctl import unroll_mode
    if unroll_mode():
        chunk = max(chunk, 8192)          # fewer unrolled bodies, same flops
    B, S, w = uf.shape
    C = min(chunk, S)
    while S % C:
        C -= 1
    N = S // C
    us = uf.reshape(B, N, C, w).transpose(1, 0, 2, 3)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    @functools.partial(jax.checkpoint, prevent_cse=False,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(h, uc):
        a, b = _gates(p, uc)
        b = b.at[:, 0].add(a[:, 0] * h)
        _, h_seq = jax.lax.associative_scan(combine, (a, b), axis=1)
        return h_seq[:, -1], h_seq

    h_final, ys = scan_or_loop(body, h0, us)
    return ys.transpose(1, 0, 2, 3).reshape(B, S, w), h_final


def rglru_block_apply(cfg, p, x, cache=None):
    """x: (B,S,d).  cache: {"conv": (B,cw-1,w), "state": (B,w) f32}."""
    B, S, d = x.shape
    w = cfg.lru_width or d
    cw = cfg.conv_width
    conv_cache = (cache["conv"] if cache is not None
                  else jnp.zeros((B, cw - 1, w), x.dtype))
    h0 = cache["state"] if cache is not None else jnp.zeros((B, w), jnp.float32)

    u = jnp.einsum("bsd,dw->bsw", x, p["wx"].astype(x.dtype))
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["wgate"].astype(x.dtype)),
                       approximate=True)
    u, new_conv = _causal_conv(p, u, conv_cache)
    y, h_final = rg_lru(p, u, h0)
    y = y.astype(x.dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", y, p["wo"].astype(x.dtype))
    return out, {"conv": new_conv, "state": h_final}


def rglru_cache_specs(cfg, batch, dtype=jnp.bfloat16):
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w), dtype),
        "state": jax.ShapeDtypeStruct((batch, w), jnp.float32),
    }
