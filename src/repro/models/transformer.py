"""Unified decoder-only LM driver for every assigned architecture.

A model is a repeated ``layer_pattern`` (e.g. gemma3: 5x local + 1x global
attention; recurrentgemma: rglru, rglru, local; rwkv6: rwkv).  The repeated
groups are stacked and driven by ``jax.lax.scan`` so the lowered HLO stays
O(pattern) instead of O(depth) — essential for fast multi-pod compiles of
27-42B configs.  Trailing layers that do not fill a group run unscanned.

Three entry points lower for the dry-run grid:
    train_loss   — full-sequence teacher forcing, chunked vocab-sharded CE
    prefill      — full-sequence, returns last-position logits + KV/state cache
    decode_step  — single token with cache (decode_32k / long_500k cells)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import rwkv6 as RWKV
from repro.models.layers import PSpec
from repro.models.loopctl import scan_or_loop


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def block_pspecs(cfg, kind: str):
    if kind in ("attn", "local"):
        mlp = MOE.moe_pspecs(cfg) if cfg.moe else L.mlp_pspecs(cfg)
        return {"norm1": L.norm_pspecs(cfg), "attn": L.attn_pspecs(cfg),
                "norm2": L.norm_pspecs(cfg), "mlp": mlp}
    if kind == "rglru":
        return {"norm1": L.norm_pspecs(cfg), "rec": RG.rglru_pspecs(cfg),
                "norm2": L.norm_pspecs(cfg), "mlp": L.mlp_pspecs(cfg)}
    if kind == "rwkv":
        return {"norm1": L.norm_pspecs(cfg), "tmix": RWKV.time_mix_pspecs(cfg),
                "norm2": L.norm_pspecs(cfg), "cmix": L.mlp_pspecs(cfg)}
    raise ValueError(kind)


def _stack_pspecs(tree, n):
    return jax.tree.map(
        lambda s: PSpec((n,) + s.shape, (L.LAYER,) + s.axes, s.init, s.dtype),
        tree, is_leaf=lambda x: isinstance(x, PSpec))


def model_pspecs(cfg):
    p: dict = {"embed": L.embed_pspecs(cfg),
               "final_norm": L.norm_pspecs(cfg)}
    if cfg.family == "rwkv6":
        p["ln0"] = L.norm_pspecs(cfg)
    p["blocks"] = [
        _stack_pspecs(block_pspecs(cfg, kind), cfg.num_groups)
        for kind in cfg.layer_pattern
    ]
    p["rem_blocks"] = [block_pspecs(cfg, kind) for kind in cfg.rem_layers]
    return p


def init_params(cfg, rng):
    depth_scale = 1.0 / np.sqrt(2.0 * max(cfg.num_layers, 1))
    return L.init_params(model_pspecs(cfg), rng, depth_scale)


def abstract_params(cfg):
    return L.param_shapes(model_pspecs(cfg))


def param_logical_axes(cfg):
    return L.param_axes(model_pspecs(cfg))


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _block_cache_specs(cfg, kind, batch, max_seq, dtype=jnp.bfloat16):
    if kind in ("attn", "local"):
        return L.attn_cache_specs(cfg, batch, max_seq, kind, dtype)
    if kind == "rglru":
        return RG.rglru_cache_specs(cfg, batch, dtype)
    if kind == "rwkv":
        return RWKV.rwkv_cache_specs(cfg, batch, dtype)
    raise ValueError(kind)


def _stack_specs(tree, n):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)


def cache_specs(cfg, batch, max_seq, dtype=jnp.bfloat16):
    return {
        "groups": [
            _stack_specs(_block_cache_specs(cfg, kind, batch, max_seq, dtype),
                         cfg.num_groups)
            for kind in cfg.layer_pattern
        ],
        "rem": [_block_cache_specs(cfg, kind, batch, max_seq, dtype)
                for kind in cfg.rem_layers],
    }


def init_cache(cfg, batch, max_seq, dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, batch, max_seq, dtype))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def block_apply(cfg, kind, p, x, qpos, *, cache=None, kv_len=None,
                build_cache_len=None, moe_dense=False, mesh=None):
    """Returns (x, new_cache, aux_losses)."""
    from repro.dist.sharding import act_hint
    def gather_seq(h):
        # Megatron-SP boundary: blocks compute with full sequence + TP
        # weights; the residual carry stays sequence-sharded.
        return act_hint(h, mesh, ("batch", None, None))

    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32)}
    if kind in ("attn", "local"):
        h = gather_seq(L.apply_norm(cfg, p["norm1"], x))
        a, new_cache = attn_with_cache(cfg, p["attn"], h, qpos, kind=kind,
                                       cache=cache, kv_len=kv_len,
                                       build_cache_len=build_cache_len,
                                       mesh=mesh)
        x = x + a
        h = gather_seq(L.apply_norm(cfg, p["norm2"], x))
        if cfg.moe:
            if moe_dense:
                m, aux = MOE.moe_apply_dense(cfg, p["mlp"], h)
            elif mesh is not None and "model" in mesh.shape:
                m, aux = MOE.moe_apply_sharded(cfg, p["mlp"], h, mesh)
            else:
                m, aux = MOE.moe_apply(cfg, p["mlp"], h)
        else:
            m = L.mlp_apply(cfg, p["mlp"], h, mesh=mesh)
        x = x + m
        return x, new_cache, aux
    if kind == "rglru":
        h = gather_seq(L.apply_norm(cfg, p["norm1"], x))
        r, new_cache = RG.rglru_block_apply(cfg, p["rec"], h, cache=cache)
        x = x + r
        h = gather_seq(L.apply_norm(cfg, p["norm2"], x))
        x = x + L.mlp_apply(cfg, p["mlp"], h, mesh=mesh)
        return x, new_cache, aux
    if kind == "rwkv":
        h = gather_seq(L.apply_norm(cfg, p["norm1"], x))
        t, tcache = RWKV.time_mix_apply(
            cfg, p["tmix"], h, cache=cache["tmix"] if cache else None,
            mesh=mesh)
        x = x + t
        h = gather_seq(L.apply_norm(cfg, p["norm2"], x))
        c, ccache = RWKV.channel_mix_apply(
            cfg, p["cmix"], h, cache=cache["cmix"] if cache else None)
        x = x + c
        return x, {"tmix": tcache, "cmix": ccache}, aux
    raise ValueError(kind)


def attn_with_cache(cfg, p, x, qpos, *, kind, cache, kv_len, build_cache_len,
                    mesh=None):
    """attn_apply + optional cache construction for prefill."""
    if build_cache_len is None:
        return L.attn_apply(cfg, p, x, qpos, kind=kind, cache=cache,
                            kv_len=kv_len, mesh=mesh)
    # prefill: run full-seq attention, then materialize the cache buffers
    out, _ = L.attn_apply(cfg, p, x, qpos, kind=kind, cache=None, kv_len=None,
                          mesh=mesh)
    B, S, _ = x.shape
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = L.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_emb == "rope":
        base = cfg.rope_base
        if kind == "attn" and cfg.rope_base_global:
            base = cfg.rope_base_global
        k = L.apply_rope(k, qpos, base=base, pct=cfg.rope_pct)
    window = cfg.window_size if kind == "local" else 0
    Sc = min(build_cache_len, window) if window else build_cache_len
    new_cache = _materialize_cache(k, v, S, Sc, window)
    return out, new_cache


def _materialize_cache(k, v, S, Sc, window):
    B, _, KH, D = k.shape
    ck = jnp.zeros((B, Sc, KH, D), k.dtype)
    cv = jnp.zeros((B, Sc, KH, D), v.dtype)
    if window and S >= window and Sc == window:
        idx = np.arange(S - window, S)
        slots = np.mod(idx, window)
        ck = ck.at[:, slots].set(k[:, S - window:])
        cv = cv.at[:, slots].set(v[:, S - window:])
    else:
        n = min(S, Sc)
        ck = ck.at[:, :n].set(k[:, :n])
        cv = cv.at[:, :n].set(v[:, :n])
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def embed_input(cfg, params, batch, qpos, dtype=jnp.bfloat16):
    if "frames" in batch:                       # stubbed modality frontend
        x = batch["frames"].astype(dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), dtype)
    else:
        x = L.embed_lookup(cfg, params["embed"], batch["tokens"], dtype)
    if cfg.pos_emb == "sinusoidal":
        x = x + L.sinusoidal_emb(qpos, cfg.d_model, dtype)[None]
    if cfg.family == "rwkv6":
        x = L.apply_norm(cfg, params["ln0"], x)
    return x


def _sum_aux(auxs):
    return jax.tree.map(lambda a: jnp.sum(a), auxs)


def forward_hidden(cfg, params, x, qpos, *, caches=None, kv_len=None,
                   build_cache_len=None, moe_dense=False, remat="none",
                   mesh=None):
    """Run all layers.  Returns (hidden, new_caches, aux)."""
    pattern = cfg.layer_pattern
    mode_decode = caches is not None
    mode_prefill = build_cache_len is not None

    def _res_hint(h):
        if mesh is None:
            return h
        from repro.dist.sharding import act_hint
        if h.shape[1] > 1:      # full-seq: sequence-parallel residual
            return act_hint(h, mesh, ("batch", "model", None))
        return act_hint(h, mesh, ("batch", None, None))

    def group_body(x, xs):
        x = _res_hint(x)
        if mode_decode:
            bparams, bcaches = xs
        else:
            bparams, bcaches = xs, [None] * len(pattern)
        new_caches, auxs = [], []
        for i, kind in enumerate(pattern):
            x, nc, aux = block_apply(cfg, kind, bparams[i], x, qpos,
                                     cache=bcaches[i], kv_len=kv_len,
                                     build_cache_len=build_cache_len,
                                     moe_dense=moe_dense, mesh=mesh)
            new_caches.append(nc)
            auxs.append(aux)
        aux = jax.tree.map(lambda *a: sum(a), *auxs)
        x = _res_hint(x)
        if mode_decode or mode_prefill:
            return x, (new_caches, aux)
        return x, aux

    body = group_body
    if remat == "full":
        body = jax.checkpoint(group_body, prevent_cse=False,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body = jax.checkpoint(group_body, prevent_cse=False,
                              policy=jax.checkpoint_policies.checkpoint_dots)

    xs = (params["blocks"], caches["groups"]) if mode_decode else params["blocks"]
    x, ys = scan_or_loop(body, x, xs)
    if mode_decode or mode_prefill:
        group_caches, auxs = ys
    else:
        group_caches, auxs = None, ys
    aux = _sum_aux(auxs)

    # remainder layers (unscanned)
    rem_caches = []
    for i, kind in enumerate(cfg.rem_layers):
        c_in = caches["rem"][i] if mode_decode else None
        x, nc, a = block_apply(cfg, kind, params["rem_blocks"][i], x, qpos,
                               cache=c_in, kv_len=kv_len,
                               build_cache_len=build_cache_len,
                               moe_dense=moe_dense, mesh=mesh)
        rem_caches.append(nc)
        aux = jax.tree.map(lambda s, v: s + v, aux, a)

    x = L.apply_norm(cfg, params["final_norm"], x)
    new_caches = None
    if mode_decode or mode_prefill:
        new_caches = {"groups": group_caches, "rem": rem_caches}
    return x, new_caches, aux


def logits_fn(cfg, params, hidden):
    head = L.head_matrix(cfg, params["embed"])
    if cfg.num_codebooks > 1:
        return jnp.einsum("bsd,kdv->bskv", hidden, head.astype(hidden.dtype))
    return jnp.einsum("bsd,dv->bsv", hidden, head.astype(hidden.dtype))


# ---------------------------------------------------------------------------
# Losses (chunked, vocab-sharded friendly)
# ---------------------------------------------------------------------------

def _ce_chunk(cfg, head, h, labels, mesh=None):
    """h: (B,C,d); labels: (B,C) or (B,C,K).  Returns summed CE (f32)."""
    from repro.dist.sharding import act_hint
    if cfg.num_codebooks > 1:
        logits = jnp.einsum("bcd,kdv->bckv", h, head.astype(h.dtype))
        logits = act_hint(logits, mesh, ("batch", None, None, "model"))
    else:
        logits = jnp.einsum("bcd,dv->bcv", h, head.astype(h.dtype))
        logits = act_hint(logits, mesh, ("batch", None, "model"))
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=jnp.float32)
    ll = jnp.sum(logits * onehot, axis=-1)
    return jnp.sum(lse - ll), jnp.sum(jnp.square(lse))


def chunked_ce(cfg, params, hidden, labels, ce_chunk=512, mesh=None):
    """Scan over sequence chunks so full (B,S,V) logits never materialize."""
    from repro.models.loopctl import unroll_mode
    if unroll_mode():
        ce_chunk = max(ce_chunk, 2048)    # fewer unrolled bodies, same flops
    B, S, d = hidden.shape
    C = min(ce_chunk, S)
    while S % C:
        C -= 1
    n = S // C
    head = L.head_matrix(cfg, params["embed"])
    hs = hidden.reshape(B, n, C, d).transpose(1, 0, 2, 3)
    ls = (labels.reshape(B, n, C, -1).transpose(1, 0, 2, 3).squeeze(-1)
          if labels.ndim == 2 else
          labels.reshape(B, n, C, labels.shape[-1]).transpose(1, 0, 2, 3))

    @functools.partial(jax.checkpoint, prevent_cse=False,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def step(carry, inp):
        tot, zsq = carry
        h, lab = inp
        ce, z = _ce_chunk(cfg, head, h, lab, mesh=mesh)
        return (tot + ce, zsq + z), None

    (tot, zsq), _ = scan_or_loop(step, (jnp.zeros((), jnp.float32),) * 2,
                                 (hs, ls))
    denom = float(B * S * (cfg.num_codebooks if labels.ndim == 3 else 1))
    return tot / denom, zsq / denom


def train_loss(cfg, params, batch, *, moe_dense=False, remat="full",
               ce_chunk=512, lb_coef=0.01, z_coef=1e-4, mesh=None):
    """batch: {"tokens": (B,S+1)} or {"frames": (B,S,d), "labels": (B,S,K)}."""
    if "frames" in batch:
        inputs = {"frames": batch["frames"]}
        labels = batch["labels"]
        S = batch["frames"].shape[1]
    else:
        inputs = {"tokens": batch["tokens"][:, :-1]}
        labels = batch["tokens"][:, 1:]
        S = labels.shape[1]
    if cfg.train_gather_bf16:
        # pre-cast sharded params so FSDP gathers move bf16, not f32
        params = dict(params, blocks=L.cast_tree(params["blocks"],
                                                 jnp.bfloat16),
                      rem_blocks=L.cast_tree(params["rem_blocks"],
                                             jnp.bfloat16))
    qpos = jnp.arange(S)
    x = embed_input(cfg, params, inputs, qpos)
    from repro.dist.sharding import act_hint
    x = act_hint(x, mesh, ("batch", None, None))
    hidden, _, aux = forward_hidden(cfg, params, x, qpos,
                                    moe_dense=moe_dense, remat=remat,
                                    mesh=mesh)
    ce, z_ce = chunked_ce(cfg, params, hidden, labels, ce_chunk, mesh=mesh)
    loss = ce + lb_coef * aux["lb_loss"] + z_coef * (aux["z_loss"] + z_ce)
    metrics = {"loss": loss, "ce": ce, "lb_loss": aux["lb_loss"],
               "z_loss": aux["z_loss"] + z_ce}
    return loss, metrics


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def prefill(cfg, params, batch, max_seq, *, moe_dense=False, mesh=None):
    """Full-sequence forward building the cache.  Returns (last_logits, cache)."""
    if "frames" in batch:
        S = batch["frames"].shape[1]
    else:
        S = batch["tokens"].shape[1]
    qpos = jnp.arange(S)
    x = embed_input(cfg, params, batch, qpos)
    hidden, caches, _ = forward_hidden(cfg, params, x, qpos,
                                       build_cache_len=max_seq,
                                       moe_dense=moe_dense, mesh=mesh)
    logits = logits_fn(cfg, params, hidden[:, -1:])
    return logits, caches


def decode_step(cfg, params, caches, pos, batch, *, moe_dense=False,
                mesh=None):
    """One token.  pos: scalar int32 (0-based position of the new token).

    batch: {"tokens": (B,1)} or {"frames": (B,1,d)}.
    Returns (logits (B,1,[K,]V), new_caches).
    """
    qpos = pos[None] if jnp.ndim(pos) == 0 else pos
    x = embed_input(cfg, params, batch, qpos)
    hidden, caches, _ = forward_hidden(cfg, params, x, qpos, caches=caches,
                                       kv_len=pos, moe_dense=moe_dense,
                                       mesh=mesh)
    return logits_fn(cfg, params, hidden), caches
