"""Input specifications per (arch x shape) cell.

``input_specs`` returns abstract ``ShapeDtypeStruct`` stand-ins for every
input of the step function the cell lowers (shannon/kernels pattern:
weak-type-correct, shardable, no device allocation).  ``make_dummy_batch``
materializes small concrete batches for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as T


def batch_specs(cfg: ArchConfig, shape: ShapeSpec):
    """Abstract batch for one cell (tokens or stubbed frontend frames)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.frontend == "encodec":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                    "labels": jax.ShapeDtypeStruct((B, S, cfg.num_codebooks), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S + 1), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.frontend == "encodec":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    # decode: one new token with a cache of length S
    if cfg.frontend == "encodec":
        return {"frames": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def input_specs(cfg: ArchConfig, shape: ShapeSpec, *, param_dtype=None):
    """Full argument specs for the step fn this cell lowers.

    train  -> (params_f32, opt_state, batch, step)
    prefill-> (params_bf16, batch)
    decode -> (params_bf16, caches, pos, batch)
    """
    batch = batch_specs(cfg, shape)
    if shape.kind == "train":
        params = T.abstract_params(cfg)
        opt = {"mu": params, "nu": params,
               "count": jax.ShapeDtypeStruct((), jnp.int32)}
        step = jax.ShapeDtypeStruct((), jnp.int32)
        return {"params": params, "opt_state": opt, "batch": batch, "step": step}
    pdt = param_dtype or jnp.bfloat16
    params = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, pdt if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype),
        T.abstract_params(cfg))
    if shape.kind == "prefill":
        return {"params": params, "batch": batch}
    caches = T.cache_specs(cfg, shape.global_batch, shape.seq_len)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return {"params": params, "caches": caches, "pos": pos, "batch": batch}


def make_dummy_batch(cfg: ArchConfig, shape_kind: str, batch: int, seq: int,
                     rng: np.random.Generator | None = None):
    """Concrete random batch for smoke tests (small sizes only)."""
    rng = rng or np.random.default_rng(0)
    V = cfg.vocab_size
    if shape_kind == "train":
        if cfg.frontend == "encodec":
            return {
                "frames": jnp.asarray(
                    rng.standard_normal((batch, seq, cfg.d_model)), jnp.bfloat16),
                "labels": jnp.asarray(
                    rng.integers(0, V, (batch, seq, cfg.num_codebooks)), jnp.int32),
            }
        return {"tokens": jnp.asarray(
            rng.integers(0, V, (batch, seq + 1)), jnp.int32)}
    if shape_kind == "prefill":
        if cfg.frontend == "encodec":
            return {"frames": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)), jnp.bfloat16)}
        return {"tokens": jnp.asarray(rng.integers(0, V, (batch, seq)), jnp.int32)}
    if cfg.frontend == "encodec":
        return {"frames": jnp.asarray(
            rng.standard_normal((batch, 1, cfg.d_model)), jnp.bfloat16)}
    return {"tokens": jnp.asarray(rng.integers(0, V, (batch, 1)), jnp.int32)}
