"""Fault-tolerant training loop.

Production behaviors, CPU-demonstrable and unit-tested:

* periodic async checkpoint (atomic publish; ckpt/checkpoint.py),
* resume-from-latest with deterministic data seek (data/pipeline.py batches
  are pure functions of step, so no replay log is needed),
* preemption handling — SIGTERM/SIGINT triggers checkpoint-then-exit at the
  next step boundary (the "grace window" pattern of managed TPU pods),
* bounded step retry: a transient step failure (e.g. a preempted donated
  buffer, a flaky host) restores the last checkpoint and replays,
* straggler mitigation hook: per-step wall time is tracked with an EMA; a
  step exceeding ``straggler_factor`` x EMA invokes ``on_straggler`` (in a
  real deployment: re-shard around the slow host / flag for eviction;
  here: recorded + surfaced in metrics so tests can assert the detection).

Elastic restarts are covered by CheckpointManager.restore(shardings=...)
against whatever mesh the restarted job has.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager


@dataclass
class LoopConfig:
    total_steps: int
    ckpt_every: int = 50
    max_retries: int = 3
    straggler_factor: float = 3.0
    ema_beta: float = 0.9
    install_signal_handlers: bool = False


class FaultTolerantLoop:
    def __init__(self, cfg: LoopConfig, ckpt: CheckpointManager,
                 train_step, pipeline, *, on_straggler=None):
        self.cfg = cfg
        self.ckpt = ckpt
        self.train_step = train_step
        self.pipeline = pipeline
        self.on_straggler = on_straggler or (lambda step, dt, ema: None)
        self.preempted = False
        self.metrics_log: list = []
        self.straggler_steps: list = []
        if cfg.install_signal_handlers:
            for sig in (signal.SIGTERM, signal.SIGINT):
                signal.signal(sig, self._handle_preempt)

    def _handle_preempt(self, signum, frame):
        self.preempted = True

    def run(self, params, opt_state, *, start_step: int | None = None,
            fail_injector=None):
        """fail_injector(step) -> bool, test hook that makes a step raise."""
        state = {"params": params, "opt": opt_state}
        step = start_step or 0
        restored, manifest = self.ckpt.restore(state) if start_step is None \
            else (None, None)
        if restored is not None:
            state = restored
            step = manifest["step"] + 1
        ema = None
        first_step = True          # step 0 includes compile; exclude from EMA
        retries = 0
        while step < self.cfg.total_steps:
            if self.preempted:
                self._checkpoint(step - 1, state, reason="preempt")
                break
            batch = self.pipeline.batch(step)
            t0 = time.monotonic()
            try:
                if fail_injector is not None and fail_injector(step):
                    raise RuntimeError(f"injected failure at step {step}")
                p, o, metrics = self.train_step(
                    state["params"], state["opt"], batch, step)
                jax.block_until_ready(metrics["loss"])
                state = {"params": p, "opt": o}
                retries = 0
            except Exception:
                retries += 1
                if retries > self.cfg.max_retries:
                    raise
                restored, manifest = self.ckpt.restore(state)
                if restored is not None:
                    state = restored
                    step = manifest["step"] + 1
                else:
                    step = 0
                continue
            dt = time.monotonic() - t0
            if first_step:
                first_step = False          # compile step: not a baseline
            else:
                if ema is not None and dt > self.cfg.straggler_factor * ema:
                    self.straggler_steps.append(step)
                    self.on_straggler(step, dt, ema)
                ema = dt if ema is None else \
                    self.cfg.ema_beta * ema + (1 - self.cfg.ema_beta) * dt
            self.metrics_log.append(
                {"step": step, "loss": float(metrics["loss"]), "dt": dt})
            if (step + 1) % self.cfg.ckpt_every == 0:
                self._checkpoint(step, state)
            step += 1
        self.ckpt.wait()
        return state, self.metrics_log

    def _checkpoint(self, step, state, reason="periodic"):
        self.ckpt.save(step, state, meta={"reason": reason})
