from repro.ft.loop import FaultTolerantLoop, LoopConfig
