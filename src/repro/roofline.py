"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / ICI_link_bw

``cost_analysis()`` of a GSPMD-partitioned executable reports *per-device*
flops/bytes (the module is the per-device program).  Collective bytes are
not in cost_analysis: we parse the compiled HLO text and sum operand sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (+ their async -start forms), so the term is also
per-device.  With the assignment's aggregate form
``total_bytes / (chips x link_bw)`` this is identical because total =
per_device x chips.
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field

from repro.configs.paper import TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

# dtype[1,2,3]{layout}  (layout optional; scalars: dtype[])
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(",
)


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes per collective kind from (partitioned) HLO text."""
    out: dict = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        kind = m.group(2)
        # all shapes on the line: first group = result tuple, rest = operands
        result_part = m.group(1)
        n_result = len(_SHAPE_RE.findall(result_part))
        shapes = _SHAPE_RE.findall(line)
        operands = shapes[n_result:] if len(shapes) > n_result else shapes
        nbytes = sum(shape_bytes(dt, dims) for dt, dims in operands)
        out[kind] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops: float                 # per device
    bytes_accessed: float        # per device
    collective_bytes: float      # per device
    collectives: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float           # useful model flops per device per step
    useful_ratio: float
    memory_per_device: dict
    note: str = ""

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=1)


def analyze(arch_name, shape_name, mesh_name, chips, flops, byts, coll,
            model_flops_global, mem_stats, chip=TPU_V5E, note="") -> Roofline:
    """flops/byts: per-device totals; coll: dict from parse_collective_bytes
    (already trip-count-corrected by the caller's unrolled extrapolation)."""
    compute_s = flops / chip.peak_flops_bf16
    memory_s = byts / chip.hbm_bw
    collective_s = coll["total"] / chip.ici_bw_per_link
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mflops_dev = model_flops_global / chips
    return Roofline(
        arch=arch_name, shape=shape_name, mesh=mesh_name, chips=chips,
        flops=flops, bytes_accessed=byts,
        collective_bytes=float(coll["total"]),
        collectives={k: v for k, v in coll.items() if v},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=mflops_dev,
        useful_ratio=(mflops_dev / flops) if flops else 0.0,
        memory_per_device=mem_stats, note=note,
    )


def model_flops(cfg, shape) -> float:
    """Useful-model-FLOPs for the cell (global, per step).

    train: 6 * N_active * tokens  (fwd+bwd)
    prefill: 2 * N_active * tokens (+ attention KV term)
    decode: 2 * N_active * batch  (+ attention score term over the cache)
    """
    n = cfg.active_param_count()
    attn_flops_token = _attn_flops_per_token(cfg, shape)
    if shape.kind == "train":
        return (6.0 * n + 3.0 * attn_flops_token) * shape.tokens
    if shape.kind == "prefill":
        return (2.0 * n + attn_flops_token) * shape.tokens
    # decode: one token per sequence
    return (2.0 * n + _decode_attn_flops(cfg, shape)) * shape.global_batch


def _attn_flops_per_token(cfg, shape) -> float:
    """Forward attention-score+value FLOPs per token (avg over causal)."""
    total = 0.0
    S = shape.seq_len
    for kind in cfg._all_layers():
        if kind in ("attn", "local"):
            ctx = min(S, cfg.window_size) if (kind == "local" and cfg.window_size) \
                else S / 2.0
            total += 2.0 * 2.0 * cfg.num_heads * cfg.head_dim * ctx
        elif kind == "rwkv":
            total += 2.0 * 2.0 * cfg.d_model * cfg.rwkv_head_size
        elif kind == "rglru":
            total += 8.0 * (cfg.lru_width or cfg.d_model)
    return total


def _decode_attn_flops(cfg, shape) -> float:
    total = 0.0
    S = shape.seq_len
    for kind in cfg._all_layers():
        if kind in ("attn", "local"):
            ctx = min(S, cfg.window_size) if (kind == "local" and cfg.window_size) else S
            total += 2.0 * 2.0 * cfg.num_heads * cfg.head_dim * ctx
        elif kind == "rwkv":
            total += 2.0 * 2.0 * cfg.d_model * cfg.rwkv_head_size
        elif kind == "rglru":
            total += 8.0 * (cfg.lru_width or cfg.d_model)
    return total
