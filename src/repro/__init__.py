"""Package root.

Holds small compatibility shims so the codebase (written against newer jax
APIs) runs on the pinned jax of this environment:

* ``jax.sharding.AxisType`` + the ``axis_types=`` kwarg of ``jax.make_mesh``
  (added after 0.4.37) — shimmed to a no-op enum / ignored kwarg.
* ``jax.shard_map`` with ``check_vma=`` — aliased to
  ``jax.experimental.shard_map.shard_map`` (``check_rep=``) when missing.

The shims install at ``import repro`` so test subprocesses that only import
a submodule get them too.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


def _install_jax_compat() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _orig_make_mesh = jax.make_mesh

        @functools.wraps(_orig_make_mesh)
        def make_mesh(*args, axis_types=None, **kw):
            return _orig_make_mesh(*args, **kw)

        jax.make_mesh = make_mesh

    # Compiled.cost_analysis() returns a single dict on newer jax but a
    # one-element list of dicts on the pinned version; normalize to dict
    # (repro.launch.dryrun / the dry-run tests index it directly).
    try:
        from jax import stages as _stages
        _orig_ca = _stages.Compiled.cost_analysis

        def _cost_analysis(self):
            out = _orig_ca(self)
            if isinstance(out, (list, tuple)):
                return out[0] if out else {}
            return out

        if getattr(_orig_ca, "__name__", "") != "_cost_analysis":
            _stages.Compiled.cost_analysis = _cost_analysis
    except Exception:
        pass

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
            if check_vma is not None:
                kw.setdefault("check_rep", check_vma)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map


_install_jax_compat()
