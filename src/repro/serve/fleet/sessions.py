"""Session bookkeeping for the fleet engine.

A *session* is one user attached to one vmapped instance slot: their
input stream, how many ticks of it have been served, the accumulated
per-tick outputs and energy, and — when the session is not resident —
where its checkpoint lives.  The ``SessionTable`` keeps the resident
sessions in a compact slot prefix (slot i of the batched scan carry is
session ``table.slots[i]``), so the fleet always runs the smallest batch
width covering the active set: completing or evicting a mid-table
session moves the LAST resident session into the hole (one gather/
scatter on the carry — instances are slot-relocatable because ``vmap``
is elementwise).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Session:
    """One user session's lifecycle record."""
    sid: int
    stream: object                       # .segment(t0, n) -> stim window
    total_ticks: int
    ticks_done: int = 0
    arrival_s: float = 0.0               # submit wall-clock
    admitted_s: Optional[float] = None   # first admission
    done_s: Optional[float] = None       # completion wall-clock
    energy_j: float = 0.0                # simulated joules served so far
    ticks_run: int = 0                   # includes post-completion padding
    preemptions: int = 0
    outputs: dict = field(default_factory=dict)   # key -> [per-round np]
    response: Optional[dict] = None
    snapshot: Optional[object] = None    # in-memory ckpt (no ckpt_dir)
    ckpt_step: int = -1                  # last on-disk checkpoint step

    @property
    def remaining(self) -> int:
        return max(0, self.total_ticks - self.ticks_done)

    @property
    def done(self) -> bool:
        return self.ticks_done >= self.total_ticks

    def latency_s(self) -> Optional[float]:
        return None if self.done_s is None else self.done_s - self.arrival_s


class SessionTable:
    """The resident set: sessions packed into slots [0, n_active)."""

    def __init__(self, capacity: int):
        self.capacity = capacity
        self.slots: list[Session] = []

    def __len__(self) -> int:
        return len(self.slots)

    @property
    def n_active(self) -> int:
        return len(self.slots)

    def admit(self, session: Session) -> int:
        """Seat ``session`` in the next free slot; returns the slot."""
        if len(self.slots) >= self.capacity:
            raise RuntimeError(f"session table full ({self.capacity})")
        self.slots.append(session)
        return len(self.slots) - 1

    def evict(self, slot: int):
        """Remove the session at ``slot``, compacting by moving the last
        resident session into the hole.  Returns ``(evicted, moved_from)``
        where ``moved_from`` is the old slot of the relocated session
        (``None`` when the tail slot itself was evicted) — the caller
        mirrors the move on the batched carry."""
        last = len(self.slots) - 1
        evicted = self.slots[slot]
        if slot == last:
            self.slots.pop()
            return evicted, None
        self.slots[slot] = self.slots.pop()
        return evicted, last

    def evict_tail(self):
        """Remove and return the last resident session (no compaction
        needed — the preemption path narrows from the tail)."""
        return self.slots.pop()
