from repro.serve.fleet.engine import FleetEngine
from repro.serve.fleet.scenarios import (SCENARIOS, ServedScenario,
                                         SineStream, adaptive_scenario,
                                         blank_stim, kws_scenario,
                                         served_adaptive_graph,
                                         served_kws_graph)
from repro.serve.fleet.sessions import Session, SessionTable
from repro.serve.fleet.traffic import PoissonTraffic, SessionSpec
