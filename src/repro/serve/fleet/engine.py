"""The fleet engine: vmapped board instances under user traffic.

One compiled ``ChipProgram`` (or board program — the engine never looks
inside), N resident user sessions, one ``jax.vmap`` over the engine's
per-tick step: the batched scan carry holds every session's full state
(membrane/learn/stimulus), and a scheduling round advances all resident
sessions ``round_ticks`` ticks in a single jitted scan of the batched
body.  Between rounds the host does admission control:

* arrivals from the load generator land in the shared ``RequestQueue``
  (``repro.serve.queue`` — the same class the LM ``ServeEngine`` drains);
* the queue's offered load (waiting + resident) runs through
  ``QueueDVFS`` — the paper's spike-FIFO -> performance-level loop — to
  pick the target fleet width.  Bursts widen the batch (jit retraces
  once per width, then it's cached); a draining queue narrows it,
  preempting tail sessions: their carry slice is checkpointed through
  ``repro.ckpt`` and they re-queue at the head, resuming bit-identically
  later (possibly in a different slot, or a different engine process);
* admitted sessions stream their input in per round (``state["stim"]``
  is swapped with each session's next stimulus window — host -> device
  streaming through the carry) and their per-tick outputs stream back
  out of the scan.

A fleet of width 1 is the plain engine: the batched body at w=1 runs
the exact ``ChipSim.run`` tick, which the tier-1 suite pins bitwise.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.chip.chip import ChipSim
from repro.chip.compile import compile as compile_graph
from repro.ckpt.checkpoint import CheckpointManager
from repro.core.dvfs import QueueDVFS
from repro.obs.health import SloMonitor, default_fleet_slos
from repro.obs.metrics import (MetricsRegistry, device_metrics_for,
                               make_device_metrics)
from repro.obs.probes import make_batched_probe_step, resolve_probes
from repro.obs.spans import SpanLog, validate_spans
from repro.serve.fleet.scenarios import ServedScenario, blank_stim
from repro.serve.fleet.sessions import Session, SessionTable
from repro.serve.queue import RequestQueue, percentiles

# the engine's simulated-energy tiers, summed per instance per tick
# (DVFS datapath + NoC traffic + learning engine when plastic)
ENERGY_KEYS = ("e_dvfs_baseline", "e_dvfs_neuron", "e_dvfs_synapse",
               "e_noc", "e_learn")


def _tree_map(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


@dataclass
class FleetObs:
    """The serving tier's observability bundle: one span log (request
    lifecycles + per-round fleet counters), one metrics registry
    (host-side scheduler/queue numbers + device-side scan accumulators),
    and one SLO monitor evaluated per scheduling round.  ``FleetEngine``
    accepts ``obs=FleetObs()`` (or ``obs=True`` for this default
    configuration); with ``obs=None`` — the default — NO observability
    code runs and the serve results are bitwise identical to the
    pre-observability engine."""
    spans: SpanLog = field(default_factory=SpanLog)
    metrics: MetricsRegistry = field(default_factory=MetricsRegistry)
    slos: tuple = field(default_factory=default_fleet_slos)
    device_metrics: tuple = None          # None = standard fleet set
    monitor: SloMonitor = None

    def __post_init__(self):
        if self.monitor is None:
            self.monitor = SloMonitor(self.slos, spans=self.spans)


class FleetEngine:
    """Serve a ``ServedScenario`` with a width-elastic vmapped fleet."""

    def __init__(self, scenario: ServedScenario, *, round_ticks: int = 64,
                 dvfs: Optional[QueueDVFS] = None,
                 capacity: Optional[int] = None, probes=(),
                 probe_ticks: int = 1024, board=None, refine: bool = True,
                 ckpt_dir=None, seed: int = 1, keep_outputs: bool = True,
                 max_rounds: int = 100_000, exec_mode: str = "auto",
                 obs: "FleetObs | bool | None" = None):
        self.scenario = scenario
        self.Tc = int(round_ticks)
        self.dvfs = dvfs or QueueDVFS()
        self.ckpt_dir = None if ckpt_dir is None else Path(ckpt_dir)
        self.keep_outputs = keep_outputs
        self.max_rounds = max_rounds
        self.obs = FleetObs() if obs is True else (obs or None)

        graph = scenario.graph(self.Tc)
        if board is not None:
            from repro.board import compile_board
            self.program = compile_board(graph, board, refine=refine)
        else:
            self.program = compile_graph(graph)
        # exec_mode reaches the vmapped stepper unchanged ("auto" | "dense"
        # | "event"): per-tick records are bitwise-identical either way, so
        # serving results don't depend on the mode.  Note the compressed
        # tick's overflow fallback is a lax.cond, and under vmap XLA
        # evaluates BOTH branches — a vmapped event fleet is correct but
        # only saves the work the compressed branch itself skips; the
        # single-instance speedup story lives in ChipSim.run.
        self.sim = ChipSim(self.program, exec_mode=exec_mode)
        self._template, self._tick = self.sim.make_stepper(seed=seed)

        self.capacity = int(capacity or max(self.dvfs.batch_levels))
        self.levels = sorted({min(int(l), self.capacity)
                              for l in self.dvfs.batch_levels})

        self._rec_sd = jax.eval_shape(
            self._tick, self._template,
            jax.ShapeDtypeStruct((), jnp.int32))[1]
        self.energy_keys = tuple(k for k in ENERGY_KEYS
                                 if k in self._rec_sd)
        self.output_keys = tuple(scenario.output_keys)
        missing = [k for k in self.output_keys if k not in self._rec_sd]
        if missing:
            raise KeyError(f"scenario output keys {missing} not in this "
                           f"program's rec; have {sorted(self._rec_sd)}")

        self.probe_specs = resolve_probes(self.program, probes)
        self.probe_ticks = int(probe_ticks)
        if self.probe_specs:
            binit1, _, fin = make_batched_probe_step(
                self.probe_specs, self._rec_sd, self.probe_ticks, 1)
            self._obs_template = _tree_map(lambda x: x[0], binit1)
            self._obs_fin = fin
        else:
            self._obs_template, self._obs_fin = {}, None

        self._blank = blank_stim(scenario.ens, self.Tc)
        self._rounds: dict = {}
        # device-side metric accumulators ride the round scan only when
        # observability is on; the spec set is filtered against this
        # program's actual rec keys once, here
        if self.obs is not None:
            self._dev_specs = (
                device_metrics_for(self._rec_sd)
                if self.obs.device_metrics is None
                else device_metrics_for(self._rec_sd,
                                        self.obs.device_metrics))
            self.obs.spans.meta.setdefault("scenario", scenario.name)
            self.obs.spans.meta.setdefault("round_ticks", self.Tc)
            self.obs.spans.meta.setdefault(
                "levels", [int(l) for l in self.levels])
        else:
            self._dev_specs = ()
        self.queue = RequestQueue(
            spans=None if self.obs is None else self.obs.spans)
        self.table = SessionTable(self.capacity)
        self._carry = None              # {"st": batched, "obs": batched}

    # ------------------------------------------------------------ rounds
    def _round_fn(self, w: int):
        """The jitted scheduling round at width ``w`` (cached per width):
        scan ``Tc`` ticks of the vmapped engine step, stream out the
        scenario's output signals, each instance's per-tick joules and —
        when observability is on — the round's device-metric totals."""
        fn = self._rounds.get(w)
        if fn is not None:
            return fn
        Tc, out_keys, e_keys = self.Tc, self.output_keys, self.energy_keys
        vtick = jax.vmap(self._tick, in_axes=(0, 0))
        if self.probe_specs:
            _, pstep, _ = make_batched_probe_step(
                self.probe_specs, self._rec_sd, self.probe_ticks, w)
        else:
            pstep = None
        if self._dev_specs:
            dinit, dstep = make_device_metrics(self._dev_specs, w)
        else:
            dinit, dstep = {}, None

        def run_round(carry, t0s):
            def body(c, i):
                ts = t0s + i                       # per-instance local tick
                st, rec = vtick(c["st"], ts)
                obs = pstep(c["obs"], rec, ts) if pstep else c["obs"]
                met = dstep(c["met"], rec) if dstep else c["met"]
                out = {k: rec[k] for k in out_keys}
                e = jnp.zeros(t0s.shape[0])
                for k in e_keys:
                    v = rec[k]
                    e = e + v.sum(axis=tuple(range(1, v.ndim)))
                return {"st": st, "obs": obs, "met": met}, (out, e)
            # the device-metric accumulators reset every round: they ride
            # the scan-internal carry, never the persistent fleet carry,
            # so observability on/off cannot change widths or snapshots
            cc = {"st": carry["st"], "obs": carry["obs"], "met": dinit}
            cc, (outs, es) = jax.lax.scan(body, cc, jnp.arange(Tc))
            return ({"st": cc["st"], "obs": cc["obs"]}, outs, es,
                    cc["met"])

        fn = jax.jit(run_round)
        self._rounds[w] = fn
        return fn

    def width_for(self, n_active: int) -> int:
        """Smallest batch level covering ``n_active`` residents."""
        for l in self.levels:
            if l >= n_active:
                return l
        return self.levels[-1]

    # ----------------------------------------------- batched carry admin
    def _fresh_carry(self, w: int) -> dict:
        bc = lambda tmpl: _tree_map(
            lambda x: jnp.broadcast_to(x, (w,) + x.shape), tmpl)
        return {"st": bc(self._template), "obs": bc(self._obs_template)}

    def _ensure_width(self, w: int) -> None:
        if self._carry is None:
            self._carry = self._fresh_carry(w)
            return
        cur = jax.tree_util.tree_leaves(self._carry["st"])[0].shape[0]
        if cur == w:
            return

        def fix(x, tmpl):
            if x.shape[0] >= w:
                return x[:w]
            pad = jnp.broadcast_to(tmpl, (w - x.shape[0],) + tmpl.shape)
            return jnp.concatenate([x, pad], axis=0)
        self._carry = {
            "st": _tree_map(fix, self._carry["st"], self._template),
            "obs": _tree_map(fix, self._carry["obs"], self._obs_template),
        }

    def _gather(self, slot: int) -> dict:
        """Session snapshot: slot ``slot`` of every carry leaf, on host."""
        return _tree_map(lambda x: np.asarray(x[slot]), self._carry)

    def _scatter(self, slot: int, snap: dict) -> None:
        self._carry = _tree_map(
            lambda b, s: b.at[slot].set(jnp.asarray(s)), self._carry, snap)

    def _move_slot(self, dst: int, src: int) -> None:
        self._carry = _tree_map(lambda x: x.at[dst].set(x[src]),
                                self._carry)

    # ------------------------------------------------ checkpoint/restore
    def _ckpt_mgr(self, sid: int) -> CheckpointManager:
        return CheckpointManager(self.ckpt_dir / f"s{sid:06d}", keep=1,
                                 async_save=False)

    def _store(self, sess: Session, snap: dict) -> None:
        if self.ckpt_dir is None:
            sess.snapshot = snap
        else:
            self._ckpt_mgr(sess.sid).save(
                sess.ticks_done, snap,
                meta={"sid": sess.sid, "ticks_done": sess.ticks_done,
                      "scenario": self.scenario.name})
            sess.ckpt_step = sess.ticks_done

    def _load(self, sess: Session) -> dict:
        template = {"st": self._template, "obs": self._obs_template}
        if self.ckpt_dir is not None and sess.ticks_done > 0:
            tree, manifest = self._ckpt_mgr(sess.sid).restore(template)
            if tree is not None:
                sess.ticks_done = int(manifest["meta"].get(
                    "ticks_done", sess.ticks_done))
                return tree
        if sess.snapshot is not None:
            return sess.snapshot
        return template                   # fresh session

    def suspend(self) -> list:
        """Checkpoint and evict every resident session (graceful engine
        shutdown / drain).  Returns the suspended sessions; with a
        ``ckpt_dir`` a different engine process can pick each one up via
        ``restore_session`` and continue bit-identically."""
        out = []
        while self.table.n_active:
            sess = self.table.evict_tail()
            self._store(sess, self._gather(self.table.n_active))
            if self.obs is not None:
                self.obs.spans.emit(
                    "suspend", sess.sid, ticks_done=sess.ticks_done,
                    ckpt="disk" if self.ckpt_dir is not None else "memory")
            out.append(sess)
        return out

    def restore_session(self, spec_or_sid, stream=None,
                        total_ticks: int = 0) -> Session:
        """Re-open a checkpointed session in THIS engine (possibly a
        different process than the one that evicted it): reads the
        session's latest checkpoint meta and queues it for admission."""
        sid = getattr(spec_or_sid, "sid", spec_or_sid)
        mgr = self._ckpt_mgr(sid)
        step = mgr.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint for session {sid}")
        sess = Session(sid=sid,
                       stream=stream or self.scenario.stream(sid),
                       total_ticks=total_ticks)
        sess.ticks_done = step
        sess.ckpt_step = step
        return sess

    # -------------------------------------------------------- the server
    def _admit_specs(self, specs, t_base: float) -> None:
        for spec in specs:
            self.queue.submit(Session(
                sid=spec.sid, stream=self.scenario.stream(spec.seed),
                total_ticks=spec.total_ticks,
                arrival_s=time.perf_counter() - t_base))

    def serve(self, traffic, *, sessions=None) -> dict:
        """Drive the fleet until ``traffic`` is exhausted and every
        session has completed.  ``sessions`` optionally seeds the queue
        with pre-built ``Session`` objects (e.g. checkpointed resumes)
        ahead of generated arrivals."""
        t0 = time.perf_counter()
        obs = self.obs
        for s in (sessions or []):
            s.arrival_s = time.perf_counter() - t0
            self.queue.submit(s)
        completed: list = []
        width_hist: dict = {}
        tick_lat_s: list = []
        rounds = 0

        while rounds < self.max_rounds:
            rounds += 1
            if traffic is not None:
                self._admit_specs(traffic.poll(), t0)
            exhausted = traffic is None or traffic.exhausted

            target = min(self.capacity, self.dvfs.batch_size(
                self.queue.peek_depth_with(self.table.n_active)))
            # narrow: preempt tail sessions (checkpoint + requeue front)
            while self.table.n_active > target:
                sess = self.table.evict_tail()
                self._store(sess, self._gather(self.table.n_active))
                sess.preemptions += 1
                if obs is not None:
                    obs.spans.emit(
                        "preempt", sess.sid, round_i=rounds - 1,
                        slot=self.table.n_active, target=target,
                        ticks_done=sess.ticks_done,
                        ckpt="disk" if self.ckpt_dir is not None
                        else "memory")
                    obs.metrics.counter("preempted").inc()
                self.queue.submit(sess, front=True)
            # widen: admit from the queue into compact slots
            while self.table.n_active < target and self.queue:
                sess = self.queue.take(1)[0]
                self._ensure_width(self.width_for(self.table.n_active + 1))
                slot = self.table.admit(sess)
                if sess.admitted_s is None:
                    sess.admitted_s = time.perf_counter() - t0
                self._scatter(slot, self._load(sess))
                sess.snapshot = None
                if obs is not None:
                    # a session with served ticks is resuming (it was
                    # preempted here, or restored from another engine's
                    # checkpoint); a fresh session is admitted
                    kind = "resume" if sess.ticks_done > 0 else "admit"
                    obs.spans.emit(kind, sess.sid, round_i=rounds - 1,
                                   slot=slot, width=target,
                                   ticks_done=sess.ticks_done)
                    obs.metrics.counter(
                        "resumed" if kind == "resume" else "admitted").inc()

            n_active = self.table.n_active
            if n_active == 0:
                if exhausted and not self.queue:
                    break
                continue
            w = self.width_for(n_active)
            self._ensure_width(w)
            width_hist[w] = width_hist.get(w, 0) + 1

            # stream this round's stimulus windows into the carry
            segs = [s.stream.segment(s.ticks_done, self.Tc)
                    for s in self.table.slots]
            segs += [self._blank] * (w - n_active)
            stim_b = {k: jnp.asarray(np.stack([g[k] for g in segs]))
                      for k in segs[0]}
            st = dict(self._carry["st"])
            st["stim"] = stim_b
            self._carry["st"] = st
            t0s = jnp.asarray([s.ticks_done for s in self.table.slots]
                              + [0] * (w - n_active), jnp.int32)

            wall0 = time.perf_counter()
            self._carry, outs, es, met = self._round_fn(w)(self._carry,
                                                           t0s)
            es = jax.block_until_ready(es)
            round_s = time.perf_counter() - wall0
            tick_lat_s.append(round_s / self.Tc)

            es_np = np.asarray(es)                       # (Tc, w)
            outs_np = {k: np.asarray(v) for k, v in outs.items()}
            done_slots = []
            for slot, sess in enumerate(self.table.slots):
                use = min(sess.remaining, self.Tc)
                if obs is not None:
                    obs.spans.emit("round", sess.sid, round_i=rounds - 1,
                                   slot=slot, width=w,
                                   t0_ticks=sess.ticks_done, ticks=use,
                                   start_s=wall0 - t0, dur_s=round_s)
                sess.ticks_run += self.Tc
                sess.energy_j += float(es_np[:, slot].sum())
                if self.keep_outputs:
                    for k in self.output_keys:
                        sess.outputs.setdefault(k, []).append(
                            outs_np[k][:use, slot])
                sess.ticks_done += use
                if sess.done:
                    done_slots.append(slot)
            for slot in sorted(done_slots, reverse=True):
                sess = self.table.slots[slot]
                sess.done_s = time.perf_counter() - t0
                if self.keep_outputs:
                    cat = {k: np.concatenate(v)
                           for k, v in sess.outputs.items()}
                    sess.outputs = cat
                    if self._obs_fin is not None:
                        obs_slot = _tree_map(lambda x: x[slot],
                                             self._carry["obs"])
                        sess.outputs["probes"] = {
                            k: np.asarray(v) for k, v in
                            self._obs_fin(obs_slot).items()}
                    if self.scenario.response is not None:
                        sess.response = self.scenario.response(cat)
                _, moved_from = self.table.evict(slot)
                if moved_from is not None:
                    self._move_slot(slot, moved_from)
                completed.append(sess)
                if obs is not None:
                    obs.spans.emit(
                        "complete", sess.sid, round_i=rounds - 1,
                        ticks_done=sess.ticks_done,
                        energy_j=round(sess.energy_j, 9),
                        latency_s=round(sess.latency_s(), 6))
            if obs is not None:
                self._observe_round(obs, rounds - 1, w, n_active, round_s,
                                    es_np, met, completed, t0, wall0)

        wall = time.perf_counter() - t0
        lat = [s.latency_s() for s in completed]
        ticks_served = sum(s.ticks_done for s in completed)
        stats = {
            "completed": len(completed),
            "rounds": rounds,
            "wall_s": wall,
            "sessions_per_s": len(completed) / wall if wall > 0 else 0.0,
            "ticks_served": ticks_served,
            "ticks_run": sum(s.ticks_run for s in completed),
            "ticks_per_s": ticks_served / wall if wall > 0 else 0.0,
            "request_latency_s": percentiles(lat),
            "tick_latency_s": percentiles(tick_lat_s),
            "joules_per_request": (float(np.mean([s.energy_j
                                                  for s in completed]))
                                   if completed else 0.0),
            "preemptions": sum(s.preemptions for s in completed),
            "width_hist": {str(k): v for k, v in sorted(width_hist.items())},
            "queue": self.queue.stats(),
        }
        result = {"sessions": completed, "stats": stats}
        if obs is not None:
            dropped = len(self.queue) + self.table.n_active
            errors = validate_spans(obs.spans.events)
            stats["health"] = obs.monitor.verdict(dropped=dropped,
                                                  span_errors=errors)
            result["obs"] = {"spans": obs.spans,
                             "metrics": obs.metrics.snapshot(),
                             "health": stats["health"]}
        return result

    # ------------------------------------------------- per-round telemetry
    def _observe_round(self, obs, round_i: int, w: int, n_active: int,
                       round_s: float, es_np, met, completed, t0,
                       wall0) -> None:
        """Fold one scheduling round into the observability bundle:
        fleet counter sample, host/device metrics, SLO check.  Pure
        bookkeeping — nothing here feeds back into scheduling."""
        m = obs.metrics
        tick_us = round_s / self.Tc * 1e6
        round_e = float(es_np[:, :n_active].sum())
        m.counter("rounds").inc()
        m.counter("ticks_run").inc(n_active * self.Tc)
        m.counter("energy_j").inc(round_e)
        m.gauge("width").set(w)
        m.gauge("n_active").set(n_active)
        m.gauge("queue_depth").set(len(self.queue))
        m.histogram("tick_us", scale=1.0).observe(tick_us)
        for s in self._dev_specs:
            vals = np.asarray(met[s.name])[:n_active]
            if s.op == "sum":
                m.counter(f"dev/{s.name}").inc(float(vals.sum()))
            elif vals.size:
                # snapshot suffixes gauges with _peak itself
                m.gauge(f"dev/{s.name}").set(float(vals.max()))
        # completion-derived quantities (latency / energy / throughput)
        elapsed = time.perf_counter() - t0
        n_done = len(completed)
        m.gauge("sessions_per_s").set(n_done / elapsed if elapsed else 0.0)
        admitted = m.counter("admitted").value
        m.gauge("preempt_rate").set(
            m.counter("preempted").value / max(1.0, admitted))
        if n_done:
            m.gauge("mj_per_request").set(
                float(np.mean([s.energy_j for s in completed])) * 1e3)
        lat_hist = m.histogram("req_latency_s", scale=1e-3)
        done_this_round = [s for s in completed
                           if s.done_s is not None
                           and s.done_s >= wall0 - t0]
        for sess in done_this_round:
            lat_hist.observe(sess.latency_s())
        obs.spans.sample(round_i, width=w, n_active=n_active,
                         queue_depth=len(self.queue),
                         tick_us=round(tick_us, 3),
                         round_s=round(round_s, 6),
                         energy_j=round(round_e, 9),
                         completed=len(completed))
        obs.monitor.check(m.snapshot(), round_i=round_i)
