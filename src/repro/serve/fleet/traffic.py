"""Synthetic user traffic for the serving benchmarks.

``PoissonTraffic`` is the load generator: each scheduling round it draws
``Poisson(rate)`` new session arrivals (deterministic in ``seed``) until
``n_sessions`` have been offered.  Every arrival is a ``SessionSpec`` —
a session seed (which parameterizes the user's input stream) and a
session length in ticks — that the fleet engine turns into a queued
``Session``.  Burstiness is what exercises the QueueDVFS width loop: a
Poisson stream at rate r keeps mean offered load at r sessions/round but
regularly spikes past the admission thresholds, forcing the fleet to
widen, then narrow (preempting + checkpointing sessions) as the burst
drains.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class SessionSpec:
    sid: int
    seed: int
    total_ticks: int


@dataclass
class PoissonTraffic:
    """Poisson session arrivals, ``rate`` expected per poll (= per
    scheduling round), stopping after ``n_sessions`` total.  Session
    lengths are uniform over ``tick_range`` (inclusive ends, quantized
    to ``tick_quantum``)."""
    rate: float = 2.0
    n_sessions: int = 64
    tick_range: tuple = (128, 384)
    tick_quantum: int = 1
    seed: int = 0
    _rng: np.random.Generator = field(init=False, repr=False)
    _emitted: int = field(init=False, default=0)

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    @property
    def exhausted(self) -> bool:
        return self._emitted >= self.n_sessions

    def poll(self) -> list:
        """This round's arrivals (possibly empty)."""
        if self.exhausted:
            return []
        k = min(int(self._rng.poisson(self.rate)),
                self.n_sessions - self._emitted)
        out = []
        lo, hi = self.tick_range
        for _ in range(k):
            sid = self._emitted
            ticks = int(self._rng.integers(lo, hi + 1))
            q = max(1, self.tick_quantum)
            ticks = max(q, (ticks // q) * q)
            out.append(SessionSpec(sid=sid, seed=self.seed * 100003 + sid,
                                   total_ticks=ticks))
            self._emitted += 1
        return out

    def drain(self) -> list:
        """All remaining arrivals at once (closed-loop benchmarking)."""
        specs = []
        while not self.exhausted:
            specs.extend(self.poll())
        return specs
