"""Served workloads: stimulus-streaming semantics for the fleet engine.

The two scenarios Yan et al. (arXiv:2009.08921) frame as one-user-per-
instance services, rebuilt as *served* graphs:

* **adaptive control** — each user session is a closed PES-learning
  control loop: the session streams its reference signal r(t) in, the
  instance tracks it through the mesh (NEF ensemble -> decoded control ->
  plant -> error back over a graded projection) and streams the plant
  state / tracking error out.  Decoders adapt on-mesh per session — two
  users' instances end up with different weights.
* **keyword spotting (KWS)** — each session streams an audio-like
  waveform (one of ``n_keywords`` synthetic keyword templates) into a
  hybrid NEF -> event-MAC channel farm; the instance streams the MAC
  layer's hidden activations out, and the response summarises them into
  a per-request score vector.

The serving twist over ``repro.learn.adaptive`` / ``repro.chip.workloads``
is WHERE the stimulus lives: instead of a drive table baked into the tick
closure at build time, a served semantics carries the stimulus in the
scan state (``state["stim"]``) — a per-session window of the input
stream (the raw signal plus its int8-MAC s16.15 encoding).  The tick
indexes it with ``t mod window``; the fleet engine replaces the window
between scheduling rounds (host -> device streaming) and a checkpoint of
the carry snapshots the in-flight input with the neuron/learn state.
A plain ``ChipSim.run`` of the same program needs no engine change at
all: ``init_state`` preloads the default stimulus, so a fleet of one is
bit-identical to the unbatched engine — the golden anchor of the tier.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from repro.chip.compile import ChipProgram
from repro.chip.graph import GRADED, NetGraph, Population, Projection
from repro.core.nef import build_ensemble, encode_drive
from repro.kernels.lif.ref import lif_step_ref
from repro.learn.engine import init_learn_state
from repro.learn.rules import PES


def _as_stim(r: np.ndarray, ens) -> dict:
    """A stimulus window: the raw signal + its s16.15 MAC-encoded drive.

    ``encode_drive`` quantizes per time step (per-row int8 scales), so a
    window encoded in segments is bit-identical to the same window
    encoded whole — streamed and preloaded stimuli agree exactly."""
    drive = np.asarray(encode_drive(ens, np.asarray(r, np.float32)[:, None],
                                    use_mac=True))
    return {"r": np.asarray(r, np.float32), "drive": drive}


def blank_stim(ens, n_ticks: int) -> dict:
    """The idle-slot stimulus: silence (and its encoding)."""
    return _as_stim(np.zeros(n_ticks, np.float32), ens)


# -------------------------------------------------------------------------
# Session input streams
# -------------------------------------------------------------------------

@dataclass
class SineStream:
    """One user's input stream: an amp/period/phase sine drawn from the
    session seed (the Yan-et-al. stimulus class, one parameterization per
    user).  ``segment(t0, n)`` returns ticks [t0, t0+n) of the stream as
    a stimulus window — deterministic in (seed, t0, n), so a preempted
    session regenerates exactly the input it would have seen."""
    ens: object
    seed: int
    keyword: Optional[int] = None         # KWS: index into the period table
    periods: tuple = (64.0, 96.0, 144.0, 216.0)
    # control references are SLOW sines (the Yan-et-al. stimulus class —
    # trackable through the loop's 2-tick transport delay); keyword
    # waveforms are fast enough to separate spike patterns per class
    period_range: tuple = (512.0, 2048.0)

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        if self.keyword is None:
            self.amp = float(rng.uniform(0.3, 0.9))
            self.period = float(rng.uniform(*self.period_range))
        else:                              # keyword template + user timbre
            self.amp = float(rng.uniform(0.6, 0.9))
            self.period = float(self.periods[self.keyword
                                             % len(self.periods)])
        self.phase = float(rng.uniform(0.0, self.period))

    def signal(self, t0: int, n: int) -> np.ndarray:
        t = np.arange(t0, t0 + n, dtype=np.float64)
        return (self.amp * np.sin(2 * np.pi * (t + self.phase)
                                  / self.period)).astype(np.float32)

    def segment(self, t0: int, n: int) -> dict:
        return _as_stim(self.signal(t0, n), self.ens)


# -------------------------------------------------------------------------
# Served adaptive control (PES learning per session)
# -------------------------------------------------------------------------

@dataclass
class ServedAdaptiveSemantics:
    """The adaptive-control loop of ``repro.learn.adaptive`` with the
    reference streamed through ``state["stim"]`` instead of baked in.

    All K channels track the session's ONE reference (K redundant
    controllers per user); everything else — decode through the learn
    carry, 1-tick graded transport each way, PES error signals — is the
    AdaptiveControlSemantics tick verbatim."""
    ens: object
    n_channels: int
    default_stim: dict                    # {"r": (L,), "drive": (L, N)}
    plastic: bool = True
    tau_plant_ticks: float = 4.0
    t_sys_s: float = 1e-3
    frozen_decoders: Optional[np.ndarray] = None

    def slot_name(self, k: int) -> str:
        return f"nef{k}->plant{k}"

    def _pe_ids(self, program: ChipProgram):
        nef = np.array([program.pe_slices[f"nef{k}"].start
                        for k in range(self.n_channels)])
        pla = np.array([program.pe_slices[f"plant{k}"].start
                        for k in range(self.n_channels)])
        return nef, pla

    def init_state(self, program: ChipProgram):
        K, N = self.n_channels, self.ens.n_neurons
        st = {"v": jnp.zeros((K, N), jnp.int32),
              "ref": jnp.zeros((K, N), jnp.int32),
              "u_filt": jnp.zeros(K, jnp.float32),
              "u_buf": jnp.zeros(K, jnp.float32),
              "err_buf": jnp.zeros(K, jnp.float32),
              "y": jnp.zeros(K, jnp.float32),
              "stim": {"r": jnp.asarray(self.default_stim["r"]),
                       "drive": jnp.asarray(self.default_stim["drive"])}}
        if self.plastic:
            st["learn"] = init_learn_state(program)
        return st

    def make_tick(self, program: ChipProgram, *, dvfs, em, key):
        ens = self.ens
        K, N = self.n_channels, ens.n_neurons
        P = program.n_pes
        alpha_syn = float(np.exp(-1.0 / ens.tau_syn_ticks))
        k_p = 1.0 / self.tau_plant_ticks
        nef_np, pla_np = self._pe_ids(program)
        nef_ids, pla_ids = jnp.asarray(nef_np), jnp.asarray(pla_np)
        n_neur = (jnp.zeros(P).at[nef_ids].set(float(N))
                  .at[pla_ids].set(1.0)).astype(jnp.int32)
        if not self.plastic:
            d_frozen = jnp.asarray(
                self.frozen_decoders if self.frozen_decoders is not None
                else np.zeros(N), jnp.float32)

        def tick(state, t):
            stim = state["stim"]
            L = stim["r"].shape[0]        # stimulus window (static shape)
            i = t % L
            dfx = jnp.broadcast_to(stim["drive"][i][None], (K, N))
            v, ref, spk = lif_step_ref(state["v"], state["ref"], dfx,
                                       **ens.lif)
            spk_f = spk.astype(jnp.float32)                   # (K, N)
            n_spk = spk_f.sum(axis=1)                         # (K,)

            if self.plastic:
                d_all = jnp.stack([state["learn"][self.slot_name(k)]
                                   ["w"][:, 0] for k in range(K)])  # (K, N)
            else:
                d_all = jnp.broadcast_to(d_frozen, (K, N))
            contrib = (spk_f * d_all).sum(axis=1)             # (K,)
            u = alpha_syn * state["u_filt"] \
                + (1 - alpha_syn) * contrib * 1000.0

            # plant consumes LAST tick's control (1-tick transport)
            y = state["y"] + (state["u_buf"] - state["y"]) * k_p
            r_now = jnp.broadcast_to(stim["r"][i], (K,))
            e_now = y - r_now
            e_arr = state["err_buf"]     # error arriving at nef this tick

            zP = jnp.zeros(P)
            packets = zP.at[nef_ids].set(1.0).at[pla_ids].set(1.0)
            fifo = zP.at[nef_ids].set(float(N)).at[pla_ids].set(1.0)
            pl = dvfs.select_pl(fifo.astype(jnp.int32))
            snn_ev = zP.at[nef_ids].set(n_spk)
            e_dvfs = em.tick_energy(pl, n_neur, snn_ev, dvfs=True)
            e_pl3 = em.tick_energy(jnp.full((P,), 2), n_neur, snn_ev,
                                   dvfs=False)

            rec = {
                "packets": packets,
                "pl": pl,
                "n_fifo": fifo,
                "syn_events": snn_ev,
                "n_spk": n_spk.sum(),
                "u": u,
                "y": y,
                "r": r_now,
                "track_err": jnp.abs(e_now),
                "dec_norm": jnp.abs(d_all).mean(),
                "e_dvfs_baseline": e_dvfs["baseline"],
                "e_dvfs_neuron": e_dvfs["neuron"],
                "e_dvfs_synapse": e_dvfs["synapse"],
                "e_pl3_baseline": e_pl3["baseline"],
                "e_pl3_neuron": e_pl3["neuron"],
                "e_pl3_synapse": e_pl3["synapse"],
            }
            if self.plastic:
                for k in range(K):
                    name = self.slot_name(k)
                    rec[f"learn/{name}/pre"] = spk_f[k]
                    rec[f"learn/{name}/err"] = e_arr[k][None]

            new_state = {"v": v, "ref": ref, "u_filt": u, "u_buf": u,
                         "err_buf": e_now, "y": y, "stim": stim}
            if self.plastic:
                new_state["learn"] = state["learn"]   # engine advances it
            return new_state, rec

        return tick


def served_adaptive_graph(n_channels: int = 1, n_neurons: int = 64,
                          stim: dict | None = None, stim_len: int = 32,
                          seed: int = 0, learning_rate: float = 3e-6,
                          plastic: bool = True) -> NetGraph:
    """The adaptive-control service graph: same populations/projections
    as ``adaptive_control_graph``, stimulus-streaming semantics.  The
    default stimulus (``stim`` or ``stim_len`` ticks of silence) sizes
    the window every streamed segment must match."""
    ens = build_ensemble(n_neurons, 1, seed=seed)
    stim = stim if stim is not None else blank_stim(ens, stim_len)

    nef_sram = n_neurons * (3 * 4 + 2 * 4) + n_neurons * 4 * 2
    plant_sram = 64
    pops = ([Population(name=f"nef{k}", n=n_neurons, sram_bytes=nef_sram)
             for k in range(n_channels)]
            + [Population(name=f"plant{k}", n=1, sram_bytes=plant_sram)
               for k in range(n_channels)])
    rule = PES(learning_rate=learning_rate) if plastic else None
    projs = ([Projection(src=f"nef{k}", dst=f"plant{k}", payload=GRADED,
                         bits_per_packet=32, delay_ticks=1, plasticity=rule)
              for k in range(n_channels)]
             + [Projection(src=f"plant{k}", dst=f"nef{k}", payload=GRADED,
                           bits_per_packet=32, delay_ticks=1)
                for k in range(n_channels)])
    sem = ServedAdaptiveSemantics(ens=ens, n_channels=n_channels,
                                  default_stim=stim, plastic=plastic)
    return NetGraph(populations=pops, projections=projs, semantics=sem,
                    name=f"served_adaptive{n_channels}"
                         + ("" if plastic else "_frozen"))


# -------------------------------------------------------------------------
# Served keyword spotting (hybrid NEF -> event-MAC farm)
# -------------------------------------------------------------------------

@dataclass
class ServedKwsSemantics:
    """``HybridFarmSemantics`` with the drive streamed per session: all
    K channels of the instance integrate the session's ONE waveform, the
    MAC layer's hidden activations are the streamed response."""
    ens: object
    w_eff: jnp.ndarray                    # (N, hidden) f32 dequantized
    n_pairs: int
    default_stim: dict                    # {"r": (L,), "drive": (L, N)}
    bits_per_spike: int = 16
    t_sys_s: float = 1e-3

    def _pe_ids(self, program: ChipProgram):
        nef = np.array([program.pe_slices[f"nef{k}"].start
                        for k in range(self.n_pairs)])
        mlp = np.array([program.pe_slices[f"mlp{k}"].start
                        for k in range(self.n_pairs)])
        return nef, mlp

    def init_state(self, program: ChipProgram):
        K, N = self.n_pairs, self.ens.n_neurons
        return {"v": jnp.zeros((K, N), jnp.int32),
                "ref": jnp.zeros((K, N), jnp.int32),
                "spike_buf": jnp.zeros((K, N), jnp.float32),
                "stim": {"r": jnp.asarray(self.default_stim["r"]),
                         "drive": jnp.asarray(self.default_stim["drive"])}}

    def make_tick(self, program: ChipProgram, *, dvfs, em, key):
        from repro.chip.graph import mac_dynamic_energy_j
        ens = self.ens
        K, N, D = self.n_pairs, ens.n_neurons, ens.dims
        P = program.n_pes
        nef_np, mlp_np = self._pe_ids(program)
        nef_ids, mlp_ids = jnp.asarray(nef_np), jnp.asarray(mlp_np)
        n_neur = jnp.zeros(P).at[nef_ids].set(float(N)).astype(jnp.int32)
        w_eff = self.w_eff
        hidden = w_eff.shape[1]

        def tick(state, t):
            stim = state["stim"]
            L = stim["r"].shape[0]
            dfx = jnp.broadcast_to(stim["drive"][t % L][None], (K, N))
            v, ref, spk = lif_step_ref(state["v"], state["ref"], dfx,
                                       **ens.lif)
            spk_f = spk.astype(jnp.float32)                   # (K, N)
            n_spk = spk_f.sum(axis=1)                         # (K,)
            active = (n_spk > 0).astype(jnp.float32)
            bits_out = self.bits_per_spike * n_spk

            arr = state["spike_buf"]                          # (K, N)
            h = arr @ w_eff                                   # (K, hidden)
            n_arr = arr.sum(axis=1)
            mac_events = n_arr * hidden
            bits_in = self.bits_per_spike * n_arr

            zP = jnp.zeros(P)
            packets = zP.at[nef_ids].set(active)
            payload_bits = zP.at[nef_ids].set(bits_out)
            fifo = zP.at[nef_ids].set(float(N)).at[mlp_ids].set(n_arr)
            pl = dvfs.select_pl(fifo.astype(jnp.int32))
            snn_ev = zP.at[nef_ids].set(n_spk * D)
            syn_ev = snn_ev.at[mlp_ids].add(mac_events)
            e_dvfs = em.tick_energy(pl, n_neur, snn_ev, dvfs=True)
            e_pl3 = em.tick_energy(jnp.full((P,), 2), n_neur, snn_ev,
                                   dvfs=False)
            e_mac = zP.at[mlp_ids].set(mac_dynamic_energy_j(mac_events))

            rec = {
                "packets": packets,
                "payload_bits": payload_bits,
                "graded_bits_out": zP.at[nef_ids].set(bits_out),
                "graded_bits_in": zP.at[mlp_ids].set(bits_in),
                "pl": pl,
                "n_fifo": fifo,
                "syn_events": syn_ev,
                "n_spk": n_spk.sum(),
                "hidden_out": h,
                "e_dvfs_baseline": e_dvfs["baseline"],
                "e_dvfs_neuron": e_dvfs["neuron"],
                "e_dvfs_synapse": e_dvfs["synapse"] + e_mac,
                "e_pl3_baseline": e_pl3["baseline"],
                "e_pl3_neuron": e_pl3["neuron"],
                "e_pl3_synapse": e_pl3["synapse"] + e_mac,
            }
            new_state = {"v": v, "ref": ref, "spike_buf": spk_f,
                         "stim": stim}
            return new_state, rec

        return tick


def served_kws_graph(n_pairs: int = 1, n_neurons: int = 64,
                     hidden: int = 16, stim: dict | None = None,
                     stim_len: int = 32, seed: int = 0) -> NetGraph:
    """The KWS service graph: ``hybrid_farm_graph`` populations with
    stimulus-streaming semantics (one user waveform into all channels)."""
    from repro.core.quant import quantize_per_axis
    ens = build_ensemble(n_neurons, 1, seed=seed)
    stim = stim if stim is not None else blank_stim(ens, stim_len)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((n_neurons, hidden)) * 0.1,
                    jnp.float32)
    wq, ws = quantize_per_axis(w, axis=0)
    w_eff = wq.astype(jnp.float32) * ws[None, :]

    nef_sram = n_neurons * (3 * 4 + 2 * 4)
    mlp_sram = n_neurons * hidden + hidden * 4 + n_neurons // 8
    pops = ([Population(name=f"nef{k}", n=n_neurons, sram_bytes=nef_sram)
             for k in range(n_pairs)]
            + [Population(name=f"mlp{k}", n=hidden, sram_bytes=mlp_sram)
               for k in range(n_pairs)])
    projs = [Projection(src=f"nef{k}", dst=f"mlp{k}", payload=GRADED,
                        bits_per_packet=16 * n_neurons, delay_ticks=1)
             for k in range(n_pairs)]
    sem = ServedKwsSemantics(ens=ens, w_eff=w_eff, n_pairs=n_pairs,
                             default_stim=stim)
    return NetGraph(populations=pops, projections=projs, semantics=sem,
                    name=f"served_kws{n_pairs}")


# -------------------------------------------------------------------------
# The scenario catalog the fleet engine serves from
# -------------------------------------------------------------------------

@dataclass
class ServedScenario:
    """Everything the fleet engine needs to serve one workload class:
    how to build the program for a given stimulus window, how to open a
    session's input stream, which per-tick rec keys stream back to the
    user, and how to summarise a finished session into a response."""
    name: str
    ens: object
    build_graph: Callable                 # (stim) -> NetGraph
    make_stream: Callable                 # (seed) -> SineStream
    output_keys: tuple
    response: Callable = None             # ({key: (T, ...) np}) -> dict

    def graph(self, stim_len: int, stim: dict | None = None) -> NetGraph:
        return self.build_graph(stim if stim is not None
                                else blank_stim(self.ens, stim_len))

    def stream(self, seed: int):
        return self.make_stream(seed)


def adaptive_scenario(n_channels: int = 1, n_neurons: int = 64,
                      seed: int = 0, learning_rate: float = 3e-6,
                      plastic: bool = True) -> ServedScenario:
    """Adaptive-control-as-a-service: per-session PES learning."""
    ens = build_ensemble(n_neurons, 1, seed=seed)

    def build(stim):
        return served_adaptive_graph(n_channels, n_neurons, stim=stim,
                                     seed=seed, learning_rate=learning_rate,
                                     plastic=plastic)

    def response(outs: dict) -> dict:
        err = np.asarray(outs["track_err"])         # (T, K)
        tail = max(1, len(err) // 4)
        return {"final_err": float(err[-tail:].max(axis=1).mean()),
                "initial_err": float(err[:tail].max(axis=1).mean())}

    return ServedScenario(
        name=f"adaptive{n_channels}ch", ens=ens, build_graph=build,
        make_stream=lambda seed: SineStream(ens, seed),
        output_keys=("u", "y", "r", "track_err"), response=response)


def kws_scenario(n_pairs: int = 1, n_neurons: int = 64, hidden: int = 16,
                 n_keywords: int = 4, seed: int = 0) -> ServedScenario:
    """Keyword spotting on the hybrid farm: each session streams one of
    ``n_keywords`` waveform templates; the response is the time-mean
    hidden-activation profile (the per-request score vector)."""
    ens = build_ensemble(n_neurons, 1, seed=seed)

    def build(stim):
        return served_kws_graph(n_pairs, n_neurons, hidden, stim=stim,
                                seed=seed)

    def make_stream(session_seed: int):
        kw = int(np.random.default_rng(session_seed).integers(n_keywords))
        return SineStream(ens, session_seed, keyword=kw)

    def response(outs: dict) -> dict:
        h = np.asarray(outs["hidden_out"])          # (T, K, hidden)
        scores = np.abs(h).mean(axis=(0, 1))        # (hidden,)
        return {"scores": scores.round(5).tolist(),
                "top_unit": int(scores.argmax()),
                "spikes": float(np.asarray(outs["n_spk"]).sum())}

    return ServedScenario(
        name=f"kws{n_pairs}ch", ens=ens, build_graph=build,
        make_stream=make_stream, output_keys=("hidden_out", "n_spk"),
        response=response)


SCENARIOS = {"adaptive": adaptive_scenario, "kws": kws_scenario}
