"""Shared request queue + admission accounting for the serving tier.

Both serving engines sit on this one module: the seed LM ``ServeEngine``
(queue of decode ``Request`` objects, drained in DVFS-selected batch
widths) and the neuromorphic ``FleetEngine`` (queue of pending user
sessions admitted into vmapped board instances).  The queue is the
activity signal of the paper's spike-FIFO -> performance-level loop
applied to serving: its depth feeds ``repro.core.dvfs.QueueDVFS``, which
selects how wide the machine runs this round.

``RequestQueue`` is FIFO with one twist the fleet needs: ``submit(...,
front=True)`` re-queues a preempted (checkpointed) session at the head,
so sessions evicted when the fleet narrows resume before new arrivals
are admitted.  Every item's queue wait is recorded at ``take`` time, so
admission latency lands in the serving stats for free.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Optional

import numpy as np


class RequestQueue:
    """FIFO admission queue shared by the LM and fleet serving engines.

    ``spans`` optionally attaches a ``repro.obs.spans.SpanLog``: every
    ``submit`` then opens (or re-opens, for preempted sessions) the
    item's request-lifecycle span with an ``enqueue`` event — the queue
    is where a request's observable life begins, so the hook lives here
    rather than in each engine."""

    def __init__(self, clock=time.perf_counter, spans=None):
        self._q: deque = deque()          # (item, enqueue_time)
        self._clock = clock
        self.spans = spans
        self.submitted = 0
        self.taken = 0
        self.wait_s: list = []            # queue wait of every taken item

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    @property
    def depth(self) -> int:
        return len(self._q)

    def submit(self, item, *, front: bool = False) -> None:
        """Enqueue ``item``; ``front=True`` puts it at the head (used for
        preempted sessions so they resume before fresh arrivals)."""
        entry = (item, self._clock())
        if front:
            self._q.appendleft(entry)
        else:
            self._q.append(entry)
        self.submitted += 1
        if self.spans is not None:
            sid = getattr(item, "sid", None)
            if sid is not None:
                self.spans.emit(
                    "enqueue", sid, front=front, depth=len(self._q),
                    ticks_done=int(getattr(item, "ticks_done", 0)))

    def extend(self, items) -> None:
        for it in items:
            self.submit(it)

    def take(self, n: int) -> list:
        """Dequeue up to ``n`` items in order, recording each one's queue
        wait (seconds between submit and take)."""
        now = self._clock()
        out = []
        while self._q and len(out) < n:
            item, t0 = self._q.popleft()
            self.wait_s.append(now - t0)
            out.append(item)
        self.taken += len(out)
        return out

    def peek_depth_with(self, in_flight: int = 0) -> int:
        """The admission-control activity signal: waiting + in-flight.

        Feeding only the waiting depth to ``QueueDVFS`` would collapse
        the width the moment the queue drains even with a full fleet in
        flight; offered load is both terms."""
        return len(self._q) + in_flight

    def stats(self) -> dict:
        w = np.asarray(self.wait_s, np.float64)
        return {
            "submitted": self.submitted,
            "taken": self.taken,
            "waiting": len(self._q),
            "wait_p50_s": float(np.percentile(w, 50)) if w.size else 0.0,
            "wait_p99_s": float(np.percentile(w, 99)) if w.size else 0.0,
        }


def percentiles(samples, ps=(50, 99)) -> dict:
    """{p50: ..., p99: ...} of ``samples`` (0.0s when empty) — the one
    latency summary both serving engines report.

    Edge cases are defined, not accidental: an empty input (or one that
    is all ``None`` — e.g. latencies of sessions that never completed)
    yields 0.0 for every percentile, and a single sample is its own
    p50 AND p99 (``np.percentile`` of one point), so downstream
    ``p99 >= p50`` comparisons hold for any sample count."""
    a = np.asarray([s for s in samples if s is not None], np.float64)
    return {f"p{p}": (float(np.percentile(a, p)) if a.size else 0.0)
            for p in ps}


def select_width(dvfs, queue: RequestQueue, in_flight: int,
                 capacity: Optional[int] = None) -> int:
    """Activity-driven width: offered load (waiting + in-flight) through
    ``QueueDVFS.batch_size``, clamped to ``capacity``."""
    width = dvfs.batch_size(queue.peek_depth_with(in_flight))
    return min(width, capacity) if capacity is not None else width
