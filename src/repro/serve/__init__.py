from repro.serve.engine import ServeEngine, Request
from repro.serve.queue import RequestQueue, percentiles, select_width
