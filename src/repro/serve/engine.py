"""Batched serving engine with activity-driven scheduling.

The spike-FIFO -> performance-level loop of the paper (core/dvfs.py),
applied to inference: the request queue's depth selects the decode batch
width each scheduling round (``QueueDVFS``), so machine activity tracks
offered load — idle deployments run narrow/cheap, bursts widen the batch.

Continuous-batching-lite: one padded decode batch; finished sequences are
replaced from the queue between rounds.  Energy per token is estimated via
``TPUEnergyModel`` from the decode step's roofline terms.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dvfs import QueueDVFS
from repro.core.energy import TPUEnergyModel
from repro.models import transformer as T
from repro.serve.queue import RequestQueue, select_width


def sample_logits(logits, key, *, temperature: float = 0.0, top_k: int = 0):
    """logits: (B, V).  temperature<=0 -> greedy; top_k>0 restricts support."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jnp.sort(lg, axis=-1)[:, -top_k][:, None]
        lg = jnp.where(lg < kth, -1e30, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 32
    out_tokens: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, max_seq: int = 256,
                 dvfs: QueueDVFS | None = None, eos_id: int | None = None,
                 greedy: bool = True, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0, mesh=None):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.dvfs = dvfs or QueueDVFS(thresholds=(2, 6),
                                      batch_levels=(1, 4, 8))
        self.eos_id = eos_id
        self.greedy = greedy
        self.temperature = temperature
        self.top_k = top_k
        self._key = jax.random.PRNGKey(seed)
        self.mesh = mesh
        self.energy = TPUEnergyModel()
        # the shared serving-tier admission queue (repro.serve.queue) —
        # the same class the neuromorphic FleetEngine admits sessions from
        self.queue = RequestQueue()
        self.stats = {"tokens": 0, "rounds": 0, "batch_hist": []}

        self._prefill = jax.jit(
            lambda p, b: T.prefill(cfg, p, b, max_seq),
            static_argnames=())
        self._decode = jax.jit(
            lambda p, c, pos, b: T.decode_step(cfg, p, c, pos, b))

    def submit(self, req: Request):
        self.queue.submit(req)

    def _sample(self, logits):
        lg = logits[:, -1]
        if lg.ndim == 3:                      # multi-codebook: first head
            lg = lg[:, 0]
        if self.greedy or self.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return sample_logits(lg, sub, temperature=self.temperature,
                             top_k=self.top_k)

    def _run_batch(self, reqs: list[Request]):
        """Prefill a batch of same-length prompts, then decode to completion."""
        B = len(reqs)
        S = max(len(r.prompt) for r in reqs)
        prompts = np.full((B, S), 0, np.int32)
        for i, r in enumerate(reqs):
            prompts[i, S - len(r.prompt):] = r.prompt       # left-pad
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        tok = self._sample(logits)
        max_new = max(r.max_new_tokens for r in reqs)
        for i, r in enumerate(reqs):
            r.out_tokens.append(int(tok[i]))
        for step in range(1, max_new):
            pos = jnp.int32(S + step - 1)
            logits, caches = self._decode(self.params, caches, pos,
                                          {"tokens": tok[:, None]})
            tok = self._sample(logits)
            for i, r in enumerate(reqs):
                if len(r.out_tokens) < r.max_new_tokens and not r.done:
                    t = int(tok[i])
                    r.out_tokens.append(t)
                    if self.eos_id is not None and t == self.eos_id:
                        r.done = True
            self.stats["tokens"] += B
        for r in reqs:
            r.done = True

    def run(self):
        """Drain the queue with DVFS-selected batch widths."""
        while self.queue:
            width = select_width(self.dvfs, self.queue, in_flight=0)
            batch = self.queue.take(width)
            self.stats["rounds"] += 1
            self.stats["batch_hist"].append(len(batch))
            self._run_batch(batch)
        self.stats["queue"] = self.queue.stats()
        return self.stats
