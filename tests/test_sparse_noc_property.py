"""Hypothesis property suite for the sparse NoC path: for ARBITRARY
random ``NetGraph``s, sparse link/flit loads and traffic energy are
exactly the dense einsum's, and the arithmetic tree builder matches the
seed's per-destination route walk."""
import numpy as np
import pytest

from test_sparse_noc import (assert_incidence_matches_route_walk,
                             assert_sparse_equals_dense, random_graph)

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
def test_sparse_loads_bitwise_equal_dense(graph_seed, packet_seed):
    graph = random_graph(np.random.default_rng(graph_seed))
    assert_sparse_equals_dense(graph, packet_seed)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_sparse_incidence_matches_route_walk(graph_seed):
    assert_incidence_matches_route_walk(
        random_graph(np.random.default_rng(graph_seed)))
