"""Fused Pallas flash-attention kernel vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention_kernel, flash_attention_ref


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 64, 2, 16), (1, 128, 4, 32),
                                   (1, 256, 1, 8)])
def test_matches_ref(shape, causal, rng):
    B, S, H, D = shape
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    out = flash_attention_kernel(q, k, v, causal=causal, bq=32, bk=32)
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ref = flash_attention_ref(fold(q), fold(k), fold(v), causal=causal)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_bf16_io(rng):
    B, S, H, D = 1, 64, 2, 16
    mk = lambda: jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.bfloat16)
    q, k, v = mk(), mk(), mk()
    out = flash_attention_kernel(q, k, v, bq=32, bk=32)
    assert out.dtype == jnp.bfloat16
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ref = flash_attention_ref(fold(q).astype(jnp.float32),
                              fold(k).astype(jnp.float32),
                              fold(v).astype(jnp.float32))
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=0.03, rtol=0.03)


def test_block_shape_sweep(rng):
    B, S, H, D = 1, 128, 1, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    outs = [flash_attention_kernel(q, k, v, bq=bq, bk=bk)
            for bq, bk in ((16, 16), (32, 64), (128, 32), (128, 128))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-5, rtol=1e-4)
