"""Hypothesis property suite for the plasticity rules.

Invariants, over randomized spike trains / rule parameters:

* STDP weights never leave their declared [w_min, w_max] bounds;
* zero error is an EXACT PES fixed point (decoders bitwise unchanged);
* the s16.15 trace decay (exp-accelerator kernel + hi/lo fixed-point
  multiply) tracks the float oracle within s16.15-class tolerance;
* the fx STDP weight trajectory tracks the float oracle;
* the explog ``impl`` knob is representation-only: "ref" and "pallas"
  agree bitwise.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.kernels.explog.ops import fx_exp, to_fx
from repro.kernels.explog.ref import FX_ONE
from repro.learn import (PES, STDP, pes_step, stdp_step_fx, stdp_step_ref,
                         trace_step_fx, trace_step_ref, trace_to_hz)


@st.composite
def spike_trains(draw, max_t=24, max_n=12):
    T = draw(st.integers(2, max_t))
    n = draw(st.integers(1, max_n))
    bits = draw(st.lists(st.integers(0, 1), min_size=T * n,
                         max_size=T * n))
    return np.asarray(bits, np.float32).reshape(T, n)


@given(spikes=spike_trains(), tau=st.floats(2.0, 50.0))
def test_fx_trace_decay_matches_float_oracle(spikes, tau):
    T, n = spikes.shape
    tr_fx = jnp.zeros(n, jnp.int32)
    tr_f = jnp.zeros(n, jnp.float32)
    for t in range(T):
        s = jnp.asarray(spikes[t])
        tr_fx = trace_step_fx(tr_fx, s, tau)
        tr_f = trace_step_ref(tr_f, s, tau)
    got = np.asarray(tr_fx, np.float64) / FX_ONE
    want = np.asarray(tr_f, np.float64)
    # decay factor is accurate to ~2^-12 relative per step; across T
    # steps the drift stays bounded by the accumulated trace magnitude
    tol = 2e-3 * max(float(want.max()), 1.0) * T + 2 / FX_ONE
    assert np.abs(got - want).max() <= tol


@given(spikes=spike_trains(max_n=6),
       a_plus=st.floats(0.0, 0.1), a_minus=st.floats(0.0, 0.1),
       w_lo=st.floats(0.0, 0.4), w_span=st.floats(0.05, 0.6),
       seed=st.integers(0, 2**16))
def test_stdp_weights_stay_within_declared_bounds(spikes, a_plus, a_minus,
                                                  w_lo, w_span, seed):
    T, n_pre = spikes.shape
    n_post = 3
    rule = STDP(a_plus=a_plus, a_minus=a_minus, w_min=w_lo,
                w_max=w_lo + w_span, w_init=w_lo + w_span / 2)
    rng = np.random.default_rng(seed)
    post = (rng.random((T, n_post)) < 0.3).astype(np.float32)
    w = jnp.full((n_pre, n_post), int(round(rule.w_init * FX_ONE)),
                 jnp.int32)
    ptr = jnp.zeros(n_pre, jnp.int32)
    qtr = jnp.zeros(n_post, jnp.int32)
    for t in range(T):
        w, ptr, qtr = stdp_step_fx(w, ptr, qtr, jnp.asarray(spikes[t]),
                                   jnp.asarray(post[t]), rule)
    wf = np.asarray(w, np.float64) / FX_ONE
    assert wf.min() >= rule.w_min - 1 / FX_ONE
    assert wf.max() <= rule.w_max + 1 / FX_ONE


@given(spikes=spike_trains(max_t=16, max_n=5), seed=st.integers(0, 2**16))
def test_fx_stdp_tracks_float_oracle(spikes, seed):
    T, n_pre = spikes.shape
    n_post = 2
    rule = STDP()
    rng = np.random.default_rng(seed)
    post = (rng.random((T, n_post)) < 0.4).astype(np.float32)
    w_fx = jnp.full((n_pre, n_post), int(round(rule.w_init * FX_ONE)),
                    jnp.int32)
    ptr_fx = jnp.zeros(n_pre, jnp.int32)
    qtr_fx = jnp.zeros(n_post, jnp.int32)
    w_f = jnp.full((n_pre, n_post), np.float32(rule.w_init))
    ptr_f = jnp.zeros(n_pre, jnp.float32)
    qtr_f = jnp.zeros(n_post, jnp.float32)
    for t in range(T):
        pre_t, post_t = jnp.asarray(spikes[t]), jnp.asarray(post[t])
        w_fx, ptr_fx, qtr_fx = stdp_step_fx(w_fx, ptr_fx, qtr_fx,
                                            pre_t, post_t, rule)
        w_f, ptr_f, qtr_f = stdp_step_ref(w_f, ptr_f, qtr_f,
                                          pre_t, post_t, rule)
    got = np.asarray(w_fx, np.float64) / FX_ONE
    want = np.asarray(w_f, np.float64)
    assert np.abs(got - want).max() <= 5e-3 * T + 2 / FX_ONE


@given(n=st.integers(1, 64), d=st.integers(1, 4),
       lr=st.floats(1e-7, 1e-2), seed=st.integers(0, 2**16))
def test_pes_zero_error_is_exact_fixed_point(n, d, lr, seed):
    rng = np.random.default_rng(seed)
    dec = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    act = jnp.asarray(np.abs(rng.standard_normal(n)) * 200, jnp.float32)
    rule = PES(learning_rate=lr)
    out = pes_step(dec, act, jnp.zeros(d), rule, n)
    assert np.array_equal(np.asarray(out), np.asarray(dec))
    # ...and a nonzero error moves the decoders against its sign
    err = jnp.ones(d)
    out2 = np.asarray(pes_step(dec, act, err, rule, n))
    moved = np.asarray(dec) - out2
    assert (moved[np.asarray(act) > 0] > 0).all()


@given(xs=st.lists(st.floats(-10.0, 10.0), min_size=1, max_size=64))
def test_explog_impl_knob_is_bitwise(xs):
    x = to_fx(jnp.asarray(np.asarray(xs, np.float32)))
    assert np.array_equal(np.asarray(fx_exp(x, impl="ref")),
                          np.asarray(fx_exp(x, impl="pallas")))


def test_trace_to_hz_steady_state():
    """A constant-rate train's trace converges to rate/(1-alpha); the Hz
    conversion recovers the rate."""
    tau = 20.0
    tr = jnp.zeros(1, jnp.int32)
    for _ in range(400):
        tr = trace_step_fx(tr, jnp.ones(1), tau)
    hz = float(trace_to_hz(tr, tau)[0])
    assert hz == pytest.approx(1000.0, rel=0.02)   # 1 spike/tick = 1 kHz
