"""SpiNNaker packet format + TCAM routing (paper Fig. 4-6)."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.packets import (
    FLIT_BITS, Packet, PacketType, TcamTable, pack, population_key, unpack,
)
from repro.core.router import RoutingTable


@given(ptype=st.sampled_from(list(PacketType)),
       key=st.integers(0, 2**32 - 1),
       pbits=st.sampled_from([0, 32, 128]),
       em=st.booleans(), ts=st.integers(0, 3),
       payload_seed=st.integers(0, 2**32 - 1))
def test_pack_unpack_roundtrip(ptype, key, pbits, em, ts, payload_seed):
    payload = payload_seed % (1 << max(pbits, 1))
    p = Packet(ptype, key, payload, pbits, em, ts)
    w = pack(p)
    assert w < (1 << FLIT_BITS)
    assert unpack(w) == p


def test_header_only_spike_is_compact():
    """A multicast spike (no payload) fits the 64-bit header+key budget."""
    w = pack(Packet(PacketType.MULTICAST, population_key(3, 2, 1, 0)))
    assert w < (1 << 64)


def test_tcam_first_match_priority():
    t = TcamTable.empty(4)
    t = t.add(0x1000, 0xF000, [0])        # broad entry
    t = t.add(0x1200, 0xFF00, [1, 2])     # narrower, added later
    assert list(np.nonzero(t.route(0x1234))[0]) == [0]   # first match wins
    assert t.route(0x9999) is None


def test_tcam_batch_equals_scalar(rng):
    t = TcamTable.empty(3)
    t = t.add(0x0100, 0xFF00, [0])
    t = t.add(0x0200, 0xFF00, [1, 2])
    keys = rng.integers(0, 0x400, 200).astype(np.uint32)
    batch = t.route_batch(keys)
    for i, k in enumerate(keys):
        r = t.route(int(k))
        expect = np.zeros(3, bool) if r is None else r
        assert np.array_equal(batch[i], expect)


def test_tcam_bist():
    good = TcamTable.empty(2).add(0x0100, 0xFF00, [0])
    assert good.self_test()
    bad = TcamTable.empty(2).add(0x0123, 0xFF00, [0])   # key bits outside mask
    assert not bad.self_test()


def test_tcam_matches_dense_routing_table():
    """The SNN engine's dense delivery matrix is the 1-hot special case."""
    n = 6
    ring = RoutingTable.ring(n)
    t = TcamTable.empty(n)
    for src in range(n):
        t = t.add(src << 8, 0xFF00, [(src + 1) % n])
    keys = np.asarray([s << 8 for s in range(n)], np.uint32)
    batch = t.route_batch(keys)
    assert np.array_equal(batch, ring.masks)
