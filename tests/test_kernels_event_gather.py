"""The event_gather kernel package: active-source compaction + padded
CSR segment-gather link accounting, validated bitwise against both the
scatter-add reference oracle and the dense einsum, for every impl and
activity level (empty, sparse, full).  Also pins the engine-level round
trip: ``NocAccounting.event_plan`` / ``event_noc_loads`` reproduce the
auto-path ``noc_loads`` bits for the compacted impls, and the per-tier
touched-link counts sum exactly.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.chip.compile import compile as compile_graph
from repro.chip.workloads import hybrid_farm_graph, synfire_graph
from repro.kernels.event_gather import (EVENT_GATHER_IMPLS,
                                        active_source_set,
                                        event_link_loads,
                                        event_link_loads_ref)

IMPLS = [i for i in EVENT_GATHER_IMPLS if i != "auto"]


@pytest.fixture(scope="module", params=["synfire", "hybrid"])
def prog(request):
    if request.param == "synfire":
        return compile_graph(synfire_graph(16, seed=0))
    return compile_graph(hybrid_farm_graph(n_pairs=8))


def _packets(prog, frac, seed=0):
    rng = np.random.default_rng(seed)
    p = rng.integers(0, 5, prog.n_pes).astype(np.float32)
    keep = rng.random(prog.n_pes) < frac
    return jnp.asarray(np.where(keep, p, 0.0).astype(np.float32))


@pytest.mark.parametrize("impl", IMPLS)
@pytest.mark.parametrize("frac", [0.0, 0.3, 1.0])
def test_link_loads_match_ref_oracle_and_dense(prog, impl, frac):
    noc, sinc = prog.noc, prog.sinc
    packets = _packets(prog, frac)
    rows = jnp.asarray(sinc.padded_rows)
    idx, n_active = active_source_set(packets, cap=prog.n_pes)
    assert int(n_active) == int((np.asarray(packets) != 0).sum())

    got = np.asarray(event_link_loads(idx, packets, rows,
                                      n_links=sinc.n_links, impl=impl))
    want_ref = np.asarray(event_link_loads_ref(
        np.asarray(idx), np.asarray(packets), np.asarray(sinc.padded_rows),
        sinc.n_links))
    want_dense = np.asarray(noc.link_loads(packets, prog.inc))
    np.testing.assert_array_equal(got, want_ref)
    np.testing.assert_array_equal(got, want_dense)


def test_unknown_impl_rejected(prog):
    packets = _packets(prog, 0.3)
    idx, _ = active_source_set(packets, cap=prog.n_pes)
    with pytest.raises(ValueError, match="event_gather impl"):
        event_link_loads(idx, packets,
                         jnp.asarray(prog.sinc.padded_rows),
                         n_links=prog.sinc.n_links, impl="bogus")


def test_active_source_set_bounded_and_overflow_flagged(prog):
    packets = _packets(prog, 1.0)
    cap = 4
    idx, n_active = active_source_set(packets, cap=cap)
    assert idx.shape == (cap,)
    live = np.flatnonzero(np.asarray(packets))
    assert int(n_active) == live.size > cap        # overflow is reported
    np.testing.assert_array_equal(np.asarray(idx), live[:cap])


@pytest.mark.parametrize("impl", IMPLS)
def test_engine_event_plan_round_trip(prog, impl):
    """The engine-facing wrapper — event_plan + event_noc_loads — emits
    the same (link_load, flit_load) bits as the auto-selected per-tick
    accounting path, with or without a precompacted index buffer."""
    noc, sinc = prog.noc, prog.sinc
    pb = jnp.asarray(prog.payload_bits)
    packets = _packets(prog, 0.4)
    want_ll = np.asarray(noc.link_loads(packets, prog.inc))
    want_fl = np.asarray(noc.flit_loads(packets, prog.inc, pb))

    plan = noc.event_plan(sinc, impl=impl)
    ll, fl = noc.event_noc_loads(packets, plan, pb)
    np.testing.assert_array_equal(np.asarray(ll), want_ll)
    np.testing.assert_array_equal(np.asarray(fl), want_fl)

    idx, _ = active_source_set(packets, cap=prog.n_pes)
    ll2, fl2 = noc.event_noc_loads(packets, plan, pb, idx=idx)
    np.testing.assert_array_equal(np.asarray(ll2), want_ll)
    np.testing.assert_array_equal(np.asarray(fl2), want_fl)


def test_event_plan_auto_resolves_to_column_plan(prog):
    assert prog.noc.resolve_event_impl("auto") == "column_plan"
    plan = prog.noc.event_plan(prog.sinc, impl="auto")
    pb = jnp.asarray(prog.payload_bits)
    packets = _packets(prog, 0.4)
    ll, fl = prog.noc.event_noc_loads(packets, plan, pb)
    np.testing.assert_array_equal(
        np.asarray(ll), np.asarray(prog.noc.link_loads(packets, prog.inc)))
    np.testing.assert_array_equal(
        np.asarray(fl),
        np.asarray(prog.noc.flit_loads(packets, prog.inc, pb)))


def test_touched_link_counts_split_by_tier(prog):
    noc = prog.noc
    packets = _packets(prog, 0.4)
    ll = noc.link_loads(packets, prog.inc)
    counts = noc.touched_link_counts(ll)
    total = float((np.asarray(ll) > 0).sum())
    assert pytest.approx(total) == sum(float(v) for v in counts.values())


def test_padded_rows_cover_every_csr_entry(prog):
    """The padded row table is exactly the CSR incidence, right-padded
    with the n_links sentinel — so a full-coverage index buffer touches
    every nonzero link weight exactly once."""
    sinc = prog.sinc
    rows = np.asarray(sinc.padded_rows)
    for p in range(prog.n_pes):
        a, b = sinc.source_ptr[p], sinc.source_ptr[p + 1]
        want = np.asarray(sinc.link_ids[a:b])
        got = rows[p][rows[p] < sinc.n_links]
        np.testing.assert_array_equal(np.sort(got), np.sort(want))
        assert (rows[p][b - a:] == sinc.n_links).all()
