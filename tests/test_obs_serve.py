"""Serving-tier observability (repro.obs.{spans,metrics,health} threaded
through the fleet engine).

Anchors, strongest first:

* **obs off is bitwise free** — serving with ``obs=None`` (the default)
  and with full instrumentation produces bitwise-identical per-session
  outputs: spans/metrics are pure side recorders;
* **every admitted session yields a well-formed span chain** — the
  lifecycle grammar (admit precedes ticks, resume only after preempt,
  exactly one terminal event) validates on live serves, across
  preemption, and across suspend-to-disk/restore in a fresh engine —
  standalone AND concatenated;
* device-side metric accumulators folded inside the jitted round scan
  equal the numpy reductions of the same records;
* the SLO monitor turns rule violations + hard invariants (dropped
  sessions, broken chains) into the serve's health verdict;
* the Perfetto exporter renders a span log (slices + counter tracks),
  and the report CLI gates several metrics in one invocation.
"""
import gzip
import json

import numpy as np
import pytest

from repro.core.dvfs import QueueDVFS
from repro.obs.health import SloMonitor, SloRule, default_fleet_slos, parse_slo
from repro.obs.metrics import (Counter, DeviceMetricSpec, Gauge, Histogram,
                               MetricsRegistry, make_device_metrics)
from repro.obs.spans import (FLEET_SID, SpanLog, load_spans,
                             validate_spans)
from repro.serve.fleet import (FleetEngine, PoissonTraffic, Session,
                               adaptive_scenario)
from repro.serve.fleet.engine import FleetObs
from repro.serve.queue import RequestQueue

TC = 32


@pytest.fixture(scope="module")
def sc():
    return adaptive_scenario(n_neurons=32)


# ------------------------------------------------------------ span grammar

def _chain(*kinds_args):
    log = SpanLog()
    for kind, args in kinds_args:
        log.emit(kind, sid=0, **args)
    return log.events


def test_valid_chain_with_preempt_and_resume():
    ev = _chain(("enqueue", {}), ("admit", {"slot": 0}),
                ("round", {"ticks": TC}), ("preempt", {}),
                ("enqueue", {"front": True}), ("resume", {}),
                ("round", {"ticks": TC}), ("complete", {}))
    assert validate_spans(ev, require_complete=True) == []


@pytest.mark.parametrize("events,frag", [
    ([("admit", {})], "admit while new"),
    ([("enqueue", {}), ("round", {})], "round while queued"),
    ([("enqueue", {}), ("admit", {}), ("round", {"ticks": 4}),
      ("preempt", {}), ("enqueue", {}), ("admit", {})],
     "admit after ticks"),
    ([("enqueue", {}), ("resume", {})], "resume with no prior"),
    ([("enqueue", {}), ("admit", {}), ("complete", {}),
      ("complete", {})], "complete while done"),
    ([("enqueue", {}), ("admit", {}), ("complete", {}),
      ("round", {})], "round while done"),
    ([("enqueue", {}), ("admit", {}), ("enqueue", {})],
     "enqueue while resident"),
    ([("enqueue", {}), ("admit", {}), ("preempt", {}), ("preempt", {})],
     "preempt while preempted"),
])
def test_broken_chains_are_flagged(events, frag):
    problems = validate_spans(_chain(*events))
    assert problems and frag in problems[0]


def test_restored_session_opens_mid_lifecycle():
    """An enqueue carrying ticks_done > 0 (restore into a fresh engine)
    is the preempted state: resume is legal, admit is not."""
    ok = _chain(("enqueue", {"ticks_done": 64}), ("resume", {}),
                ("round", {"ticks": TC}), ("complete", {}))
    assert validate_spans(ok, require_complete=True) == []
    bad = _chain(("enqueue", {"ticks_done": 64}), ("admit", {}))
    assert "expected resume" in validate_spans(bad)[0]


def test_require_complete_flags_unfinished_chains():
    ev = _chain(("enqueue", {}), ("admit", {}))
    assert validate_spans(ev) == []
    problems = validate_spans(ev, require_complete=True)
    assert len(problems) == 1 and "never completed" in problems[0]


def test_fleet_level_events_are_free_form():
    log = SpanLog()
    log.emit("slo", rule="tick_us<=5", value=9.0)
    assert log.events[0].sid == FLEET_SID
    assert validate_spans(log.events, require_complete=True) == []


def test_span_log_roundtrip_gzip(tmp_path):
    log = SpanLog(meta={"scenario": "t"})
    log.emit("enqueue", 3, depth=1)
    log.sample(0, width=4, queue_depth=2)
    p = log.write(tmp_path / "spans.json", compress=True)
    assert p.suffix == ".gz"
    payload = load_spans(p)
    assert payload["schema"] == "fleet-spans-v1"
    assert payload["meta"]["scenario"] == "t"
    assert payload["events"][0]["kind"] == "enqueue"
    assert payload["counters"][0]["width"] == 4
    # plain write too, and the loaded dict form validates
    p2 = log.write(tmp_path / "spans_plain.json")
    assert validate_spans(load_spans(p2)["events"]) == []


def test_unknown_span_kind_rejected():
    with pytest.raises(ValueError, match="unknown span kind"):
        SpanLog().emit("frobnicate", 0)


def test_queue_emits_enqueue_spans(sc):
    log = SpanLog()
    q = RequestQueue(spans=log)
    q.submit("no-sid-item")                  # plain items stay silent
    s = Session(sid=5, stream=sc.stream(0), total_ticks=TC)
    s.ticks_done = 2 * TC
    q.submit(s, front=True)
    assert len(log.events) == 1
    ev = log.events[0]
    assert ev.kind == "enqueue" and ev.sid == 5
    assert ev.args["front"] is True and ev.args["ticks_done"] == 2 * TC


# ---------------------------------------------------------------- metrics

def test_counter_gauge_basics():
    c = Counter()
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    g = Gauge()
    g.set(4)
    g.set(2)
    assert g.value == 2 and g.peak == 4


def test_histogram_percentiles_log2_buckets():
    h = Histogram(scale=1e-6, n_buckets=40)
    assert h.percentile(99) == 0.0 and h.mean == 0.0      # empty
    h.observe(3e-6)
    assert h.percentile(50) == h.percentile(99)           # single sample
    for v in [1e-6, 2e-6, 4e-6, 1e-3, 2e-3]:
        h.observe(v)
    # the p99 upper-edge estimate never under-reports: >= exact max is
    # capped AT the exact max
    assert h.percentile(99) == h.max == 2e-3
    assert h.percentile(50) <= h.percentile(99)
    assert h.count == 6


def test_registry_snapshot_and_type_conflicts():
    m = MetricsRegistry()
    m.counter("a").inc(2)
    m.gauge("b").set(7)
    m.histogram("c").observe(1.0)
    snap = m.snapshot()
    assert snap["a"] == 2 and snap["b"] == 7 and snap["b_peak"] == 7
    assert {"c_p50", "c_p99", "c_mean", "c_max", "c_count"} <= set(snap)
    with pytest.raises(TypeError):
        m.gauge("a")


def test_device_metric_fold_matches_numpy():
    """The jit-side accumulators (sum / peak over a round's ticks) equal
    the numpy reductions of the same per-tick records."""
    import jax.numpy as jnp
    specs = (DeviceMetricSpec("spk", "n_spk", "sum"),
             DeviceMetricSpec("pl", "pl", "peak"))
    W, T, P = 3, 5, 4
    rng = np.random.default_rng(0)
    recs = {"n_spk": rng.integers(0, 9, (T, W, P)).astype(np.float32),
            "pl": rng.integers(0, 4, (T, W, P)).astype(np.float32)}
    met, step = make_device_metrics(specs, W)
    for t in range(T):
        met = step(met, {k: jnp.asarray(v[t]) for k, v in recs.items()})
    np.testing.assert_allclose(np.asarray(met["spk"]),
                               recs["n_spk"].sum(axis=(0, 2)))
    np.testing.assert_allclose(np.asarray(met["pl"]),
                               recs["pl"].max(axis=(0, 2)))


# ----------------------------------------------------------------- health

def test_parse_slo_specs():
    r = parse_slo("req_latency_s_p99<=2.5")
    assert (r.metric, r.op, r.threshold, r.level) == \
        ("req_latency_s_p99", "<=", 2.5, "warn")
    r = parse_slo("sessions_per_s>=10:critical")
    assert r.op == ">=" and r.level == "critical"
    for bad in ("nope", "m<5", "m<=x", "m<=1:fatal"):
        with pytest.raises(ValueError):
            parse_slo(bad)


def test_slo_monitor_checks_and_verdict():
    log = SpanLog()
    mon = SloMonitor(["tick_us<=5:critical", "sessions_per_s>=1",
                      SloRule("absent_metric", "<=", 0.0)], spans=log)
    hits = mon.check({"tick_us": 3.0, "sessions_per_s": 2.0}, round_i=0)
    assert hits == [] and mon.verdict()["status"] == "ok"
    hits = mon.check({"tick_us": 9.0, "sessions_per_s": 0.25}, round_i=1)
    assert len(hits) == 2
    assert [e.kind for e in log.events] == ["slo", "slo"]
    v = mon.verdict()
    assert v["status"] == "critical" and v["violations"] == 2
    worst = {r["rule"]: r["worst"] for r in v["rules"]}
    assert worst["tick_us<=5"] == 9.0 and worst["sessions_per_s>=1"] == 0.25


def test_verdict_hard_invariants_escalate():
    mon = SloMonitor(default_fleet_slos())
    assert mon.verdict()["status"] == "ok"
    assert mon.verdict(dropped=1)["status"] == "critical"
    assert mon.verdict(span_errors=["sid 3: broken"])["status"] == \
        "critical"


# ------------------------------------------------- fleet serves, observed

@pytest.fixture(scope="module")
def observed_serve(sc):
    """One instrumented serve with narrowing (so preempt/resume spans
    appear), shared by the assertions below."""
    eng = FleetEngine(sc, round_ticks=TC,
                      dvfs=QueueDVFS(thresholds=(3,), batch_levels=(1, 4)),
                      obs=True)
    totals = [2 * TC, 5 * TC, 5 * TC]
    sessions = [Session(sid=i, stream=sc.stream(40 + i), total_ticks=t)
                for i, t in enumerate(totals)]
    out = eng.serve(None, sessions=sessions)
    return eng, out


def test_observed_serve_health_and_chains(observed_serve):
    eng, out = observed_serve
    assert out["stats"]["completed"] == 3
    obs = out["obs"]
    assert obs["health"]["status"] in ("ok", "warn")
    assert obs["health"]["dropped_sessions"] == 0
    assert obs["health"]["span_errors"] == []
    # every admitted session has a complete well-formed chain
    assert validate_spans(obs["spans"].events, require_complete=True) == []
    assert sorted(obs["spans"].sids) == [0, 1, 2]


def test_observed_serve_records_preemption_spans(observed_serve):
    eng, out = observed_serve
    assert out["stats"]["preemptions"] >= 1
    kinds = [e.kind for e in out["obs"]["spans"].events]
    assert kinds.count("preempt") == out["stats"]["preemptions"]
    assert kinds.count("resume") >= 1 and kinds.count("complete") == 3
    pre = next(e for e in out["obs"]["spans"].events
               if e.kind == "preempt")
    assert {"slot", "target", "ticks_done"} <= set(pre.args)


def test_observed_serve_metrics_and_counters(observed_serve):
    eng, out = observed_serve
    snap = out["obs"]["metrics"]
    st = out["stats"]
    assert snap["ticks_run"] == st["ticks_run"]
    assert snap["admitted"] == 3                 # fresh admissions
    assert snap["resumed"] == snap["preempted"] == st["preemptions"]
    assert snap["dev/spikes"] > 0 and snap["dev/pl_peak"] >= 1
    assert snap["energy_j"] == pytest.approx(
        sum(s.energy_j for s in out["sessions"]), rel=1e-4)
    # one fleet counter sample per EXECUTED round, consecutively numbered
    rounds = [c["round"] for c in out["obs"]["spans"].counters]
    assert rounds == list(range(len(rounds))) and rounds
    assert snap["rounds"] == snap["tick_us_count"] == len(rounds)
    assert snap["rounds"] <= st["rounds"]        # final empty round breaks
    assert all({"width", "queue_depth", "tick_us", "energy_j"} <= set(c)
               for c in out["obs"]["spans"].counters)


def test_obs_off_is_bitwise_free(sc):
    """The acceptance anchor: default (obs=None) serving and fully
    instrumented serving produce bitwise-identical session outputs —
    the instrumentation never feeds back into the computation."""
    def run(obs):
        eng = FleetEngine(sc, round_ticks=TC,
                          dvfs=QueueDVFS(thresholds=(3,),
                                         batch_levels=(1, 4)),
                          obs=obs)
        sessions = [Session(sid=i, stream=sc.stream(60 + i),
                            total_ticks=t)
                    for i, t in enumerate([2 * TC, 4 * TC, 4 * TC])]
        return eng.serve(None, sessions=sessions)

    plain, instrumented = run(None), run(True)
    assert "obs" not in plain
    assert instrumented["obs"]["health"]["span_errors"] == []
    for a, b in zip(plain["sessions"], instrumented["sessions"]):
        for k in sc.output_keys:
            np.testing.assert_array_equal(a.outputs[k], b.outputs[k])


def test_span_chain_across_suspend_restore(sc, tmp_path):
    """Engine 1 serves rounds then suspends to disk; a FRESH engine
    restores and completes.  Each engine's span log validates standalone
    and the concatenation validates as one complete chain."""
    kw = dict(round_ticks=TC, capacity=1, ckpt_dir=tmp_path,
              dvfs=QueueDVFS(thresholds=(2,), batch_levels=(1, 1)))
    T, seed = 4 * TC, 17

    eng1 = FleetEngine(sc, max_rounds=2, obs=True, **kw)
    s1 = Session(sid=9, stream=sc.stream(seed), total_ticks=T)
    eng1.serve(None, sessions=[s1])
    eng1.suspend()
    log1 = eng1.obs.spans.events
    assert "suspend" in [e.kind for e in log1]
    assert validate_spans(log1) == []            # standalone: incomplete ok
    assert validate_spans(log1, require_complete=True) != []

    eng2 = FleetEngine(sc, obs=True, **kw)
    s2 = eng2.restore_session(9, stream=sc.stream(seed), total_ticks=T)
    out2 = eng2.serve(None, sessions=[s2])
    assert out2["sessions"][0].done
    log2 = eng2.obs.spans.events
    # the fresh engine's log opens with enqueue(ticks_done>0) -> resume
    assert validate_spans(log2, require_complete=True) == []
    sid9 = [e for e in log2 if e.sid == 9]
    assert sid9[0].kind == "enqueue" and sid9[0].args["ticks_done"] == 2 * TC
    assert "resume" in [e.kind for e in sid9]
    # concatenated across engines: one valid complete chain
    assert validate_spans(list(log1) + list(log2),
                          require_complete=True) == []
    assert out2["obs"]["health"]["status"] in ("ok", "warn")


def test_custom_slos_gate_the_serve(sc):
    """An impossible SLO produces warn events in the span log and a warn
    verdict; a dropped session (max_rounds hit) escalates to critical."""
    obs = FleetObs(slos=(SloRule("sessions_per_s", ">=", 1e9),))
    eng = FleetEngine(sc, round_ticks=TC,
                      dvfs=QueueDVFS(thresholds=(2,), batch_levels=(1, 1)),
                      capacity=1, obs=obs)
    out = eng.serve(None, sessions=[Session(sid=0, stream=sc.stream(1),
                                            total_ticks=TC)])
    assert out["obs"]["health"]["status"] == "warn"
    assert any(e.kind == "slo" for e in obs.spans.events)

    obs2 = FleetObs()
    eng2 = FleetEngine(sc, round_ticks=TC, max_rounds=1,
                       dvfs=QueueDVFS(thresholds=(2,),
                                      batch_levels=(1, 1)),
                       capacity=1, obs=obs2)
    out2 = eng2.serve(None, sessions=[
        Session(sid=i, stream=sc.stream(i), total_ticks=2 * TC)
        for i in range(2)])
    assert out2["stats"]["completed"] < 2
    assert out2["obs"]["health"]["status"] == "critical"
    assert out2["obs"]["health"]["dropped_sessions"] >= 1


# ----------------------------------------------------------- trace export

def test_fleet_trace_export_and_cli(observed_serve, tmp_path):
    from repro.obs.trace import fleet_trace_events, main as trace_main
    eng, out = observed_serve
    spans = out["obs"]["spans"]
    payload = fleet_trace_events(spans.payload())
    ev = payload["traceEvents"]
    phases = {e["ph"] for e in ev}
    assert {"M", "C", "X", "i"} <= phases
    # counter tracks present for the fleet signals
    counters = {e["name"].split(" [")[0] for e in ev if e["ph"] == "C"}
    assert {"queue_depth", "width", "tick_us", "energy_j"} <= counters
    # per-slot round slices named by the occupying session
    slices = [e for e in ev if e["ph"] == "X" and e["cat"] == "round"]
    assert slices and all(e["name"].startswith("sid ") for e in slices)
    # request lifecycle: resident slices + one terminal instant each
    completes = [e for e in ev
                 if e["ph"] == "i" and e["name"] == "complete"]
    assert len(completes) == 3
    assert any(e["ph"] == "X" and e.get("cat") == "resident" for e in ev)
    assert payload["otherData"]["n_requests"] == 3

    # CLI: span log (gz) in, gzipped Perfetto trace out
    slog = spans.write(tmp_path / "spans.json.gz")
    out_path = tmp_path / "fleet.perfetto-trace.json"
    assert trace_main(["--fleet", str(slog), "--gzip",
                       "--out", str(out_path)]) == 0
    gz = out_path.with_suffix(".json.gz")
    assert gz.exists()
    loaded = json.loads(gzip.decompress(gz.read_bytes()))
    assert len(loaded["traceEvents"]) == len(ev)


# ------------------------------------------------------ report multi-gate

def _payload(tmp_path, fname, rows):
    from repro.obs import bench_payload
    p = tmp_path / fname
    p.write_text(json.dumps(bench_payload(
        [{"name": n, "us_per_call": u, "derived": d,
          "values": v} for n, u, d, v in rows])))
    return str(p)


def test_report_multi_metric_single_invocation(tmp_path, capsys):
    from repro.obs.report import main as report_main
    base = _payload(tmp_path, "base.json", [
        ("serve", 100.0, "", {"sessions_per_s": 10.0, "compile_s": 5.0})])
    # tick time fine, throughput collapsed: only the :higher spec trips
    fresh = _payload(tmp_path, "fresh.json", [
        ("serve", 101.0, "", {"sessions_per_s": 4.0, "compile_s": 5.0})])
    rc = report_main([base, fresh, "--metric", "us_per_call",
                      "--metric", "sessions_per_s:higher"])
    assert rc == 1
    text = capsys.readouterr().out
    assert "us_per_call: all 1 rows" in text
    assert "sessions_per_s: 1/1 rows regressed" in text
    # warn-only downgrades, per-spec threshold loosens to clean
    assert report_main([base, fresh, "--metric", "us_per_call",
                        "--metric", "sessions_per_s:higher",
                        "--warn-only"]) == 0
    assert report_main([base, fresh,
                        "--metric", "sessions_per_s:higher:2.0"]) == 0


def test_report_multi_metric_missing_rows(tmp_path):
    from repro.obs.report import main as report_main
    base = _payload(tmp_path, "b.json", [("x", 1.0, "", {"m": 1.0})])
    fresh = _payload(tmp_path, "f.json", [("x", 1.0, "", {"m": 1.0})])
    # one gated metric absent everywhere -> the other still gates (rc 0);
    # ALL absent -> rc 2
    from repro.obs.report import parse_metric_spec
    assert report_main([base, fresh, "--metric", "m",
                        "--metric", "absent"]) == 0
    assert report_main([base, fresh, "--metric", "absent"]) == 2
    assert parse_metric_spec("m:higher:0.5") == ("m", "higher", 0.5)
    with pytest.raises(ValueError):
        parse_metric_spec("m:upward")
    with pytest.raises(ValueError):
        parse_metric_spec("m:higher:0.5:extra")
