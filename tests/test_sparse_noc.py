"""Sparse NoC path: CSR/col-plan accounting == dense einsum, exactly.

The engine auto-selects sparse vs dense by incidence density, so the two
representations must agree BITWISE — property-tested over random
``NetGraph``s, plus the golden 8-PE synfire program through the forced
sparse path.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.chip.chip import ChipSim
from repro.chip.compile import compile as compile_graph
from repro.chip.graph import GRADED, SPIKE, NetGraph, Population, Projection
from repro.chip.workloads import hybrid_farm_graph, synfire_graph
from repro.core.snn import build_synfire, simulate_synfire

def random_graph(rng) -> NetGraph:
    """Random placeable NetGraph: 1-5 populations, 1-4 tiles each, random
    spike/graded projections (one packet class per source population).
    Shared with the hypothesis suite (test_sparse_noc_property)."""
    n_pops = int(rng.integers(1, 6))
    pops = [Population(name=f"p{i}", n=8, sram_bytes=64,
                       n_tiles=int(rng.integers(1, 5)),
                       align_qpe=bool(rng.integers(2)))
            for i in range(n_pops)]
    projs = []
    for i in range(n_pops):
        dsts = [j for j in range(n_pops) if rng.integers(2)]
        if not dsts:
            continue
        graded = bool(rng.integers(2))
        bits = int(rng.integers(1, 4097)) if graded else 0
        projs.extend(Projection(src=f"p{i}", dst=f"p{j}",
                                payload=GRADED if graded else SPIKE,
                                bits_per_packet=bits)
                     for j in dsts)
    return NetGraph(pops, projs, semantics=object(), name="rand")


def assert_sparse_equals_dense(graph, seed=0):
    """Sparse column-plan loads + energy == dense einsum, bitwise."""
    prog = compile_graph(graph)
    noc = prog.noc
    sinc = prog.sinc
    rng = np.random.default_rng(seed)
    packets = jnp.asarray(
        rng.integers(0, 200, prog.n_pes).astype(np.float32))
    pb = jnp.asarray(prog.payload_bits)

    dense_ll = np.asarray(noc.link_loads(packets, prog.inc))
    dense_fl = np.asarray(noc.flit_loads(packets, prog.inc, pb))

    cols, inv = sinc.device_col_plan()
    sp_ll = np.asarray(noc.link_loads_sparse(packets, cols, inv))
    sp_fl = np.asarray(noc.flit_loads_sparse(packets, cols, inv, pb))
    np.testing.assert_array_equal(sp_ll, dense_ll)
    np.testing.assert_array_equal(sp_fl, dense_fl)
    both_ll, both_fl = noc.noc_loads_sparse(packets, cols, inv, pb)
    np.testing.assert_array_equal(np.asarray(both_ll), dense_ll)
    np.testing.assert_array_equal(np.asarray(both_fl), dense_fl)

    # energy is representation-independent: tree_links == inc.sum(axis=1)
    np.testing.assert_array_equal(sinc.tree_links, prog.inc.sum(axis=1))
    e_sp = noc.traffic_energy_j(packets, jnp.asarray(sinc.tree_links,
                                                     jnp.float32), pb)
    e_de = noc.traffic_energy_j(packets, prog.inc.sum(axis=1), pb)
    np.testing.assert_array_equal(np.asarray(e_sp), np.asarray(e_de))


def assert_incidence_matches_route_walk(graph):
    """The arithmetic tree builder == the per-destination xy_route walk
    (the seed's reference implementation) for every compiled source."""
    prog = compile_graph(graph)
    noc = prog.noc
    for i in range(prog.n_pes):
        dsts = [tuple(prog.coords[j])
                for j in np.flatnonzero(prog.table.masks[i])]
        ref = {noc.link_index[lk]
               for lk in noc.tree_links(tuple(prog.coords[i]), dsts)}
        a, b = prog.sinc.source_ptr[i], prog.sinc.source_ptr[i + 1]
        got = set(prog.sinc.link_ids[a:b].tolist())
        assert got == ref, i
        # hop depth from the same pass
        assert prog.sinc.tree_hops[i] == noc.tree_hops(
            tuple(prog.coords[i]), dsts)


def test_sparse_equals_dense_fixed_seeds():
    for seed in range(12):
        rng = np.random.default_rng(seed)
        graph = random_graph(rng)
        assert_sparse_equals_dense(graph, seed)
        assert_incidence_matches_route_walk(graph)


def test_engine_sparse_dense_records_identical():
    """Same program, both engine paths, every NoC record bit-identical
    (dynamic graded payloads included via the farm workload)."""
    for graph in (synfire_graph(12),
                  hybrid_farm_graph(n_pairs=6, n_neurons=16, hidden=8,
                                    n_ticks=64)):
        sim = ChipSim(compile_graph(graph))
        a = sim.run(60, noc_mode="sparse")
        b = sim.run(60, noc_mode="dense")
        for k in ("link_load", "link_flits", "e_noc", "packets"):
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k


def test_golden_synfire_bit_identical_through_sparse_path():
    """The 8-PE test-chip benchmark stays bit-identical to the seed
    single-chip simulation when forced through the sparse NoC path."""
    sim = ChipSim(compile_graph(synfire_graph(8, seed=0)))
    recs = sim.run(300, noc_mode="sparse")
    ref = simulate_synfire(build_synfire(0), 300)
    for k in ("spikes_exc", "spikes_inh", "pl", "n_fifo", "syn_events",
              "packets"):
        assert np.array_equal(np.asarray(recs[k]), np.asarray(ref[k])), k
    # and the sparse NoC accounting equals the dense accounting
    dense = sim.run(300, noc_mode="dense")
    for k in ("link_load", "link_flits", "e_noc"):
        assert np.array_equal(np.asarray(recs[k]), np.asarray(dense[k])), k


def test_auto_mode_picks_sparse_for_sparse_trees():
    # board scale (224 links, density ~0.009): sparse
    sim = ChipSim(compile_graph(
        hybrid_farm_graph(n_pairs=128, n_neurons=8, hidden=4, n_ticks=16)))
    assert sim.program.sinc.density < 0.25
    assert sim.use_sparse_noc() is True
    assert sim.use_sparse_noc("dense") is False
    # small chip (48 links): the dense GEMV is cheaper than the plan's
    # fixed op overhead, so auto stays dense
    small = ChipSim(compile_graph(synfire_graph(64)))
    assert small.program.sinc.n_links < 128
    assert small.use_sparse_noc() is False
    assert small.use_sparse_noc("sparse") is True
    with pytest.raises(ValueError, match="noc_mode"):
        sim.use_sparse_noc("bogus")


def test_auto_mode_falls_back_to_dense_for_heavy_fan_in():
    """An all-to-one graph is sparse by density but its sink-adjacent
    links are shared by ~P sources — the column plan would unroll O(P)
    ops per tick, so auto must pick the dense einsum (forced sparse stays
    available and bitwise-correct)."""
    n_srcs = 200
    pops = ([Population(name=f"s{i}", n=1, sram_bytes=16)
             for i in range(n_srcs)]
            + [Population(name="sink", n=1, sram_bytes=16)])
    projs = [Projection(src=f"s{i}", dst="sink") for i in range(n_srcs)]
    graph = NetGraph(pops, projs, semantics=object(), name="fan_in")
    prog = compile_graph(graph)
    sim = ChipSim(prog)
    assert prog.sinc.density < 0.25                 # passes the density gate
    assert prog.sinc.max_fan_in > 128               # but not the fan-in gate
    assert prog.sinc.max_fan_in == len(prog.sinc.col_plan[0])
    assert sim.use_sparse_noc() is False
    assert_sparse_equals_dense(graph)               # forced sparse still exact


def test_dense_inc_materializes_lazily():
    prog = compile_graph(synfire_graph(16))
    assert "inc" not in prog.__dict__            # not built yet
    inc = prog.inc
    assert inc.shape == (prog.n_pes, prog.noc.n_links)
    np.testing.assert_array_equal(inc, prog.sinc.dense())
    assert "inc" in prog.__dict__                # cached after first use


def test_hybrid_farm_runs_and_conserves_payload():
    """The board-scale hybrid farm honours the record contract: graded
    payload bits emitted == consumed one transport tick later."""
    g = hybrid_farm_graph(n_pairs=8, n_neurons=16, hidden=8, n_ticks=64)
    sim = ChipSim(compile_graph(g))
    recs = jax.block_until_ready(sim.run(60))
    out = np.asarray(recs["graded_bits_out"]).sum(axis=1)
    inn = np.asarray(recs["graded_bits_in"]).sum(axis=1)
    assert out.sum() > 0
    np.testing.assert_array_equal(out[:-1], inn[1:])
    assert inn[0] == 0
    # NEF populations precede MLP populations on the snake, so every
    # channel crosses >= 1 real mesh link
    assert sim.program.sinc.tree_links[:g.semantics.n_pairs].min() >= 1
    assert np.asarray(recs["e_noc"]).sum() > 0
