"""Unified workload API: graph -> compile -> ChipProgram -> ChipSim.

Golden acceptance: the compiled 8-PE synfire program reproduces the seed
``simulate_synfire`` bit for bit; the hybrid graph conserves graded-event
payload across the NoC; the compiler rejects oversized graphs with clear
errors instead of failing deep inside placement.
"""
import numpy as np
import pytest

from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.compile import compile as compile_graph
from repro.chip.graph import (GRADED, NetGraph, Population, Projection,
                              SPIKE)
from repro.chip.mapping import place_ring
from repro.chip.mesh_noc import MeshSpec
from repro.chip.workloads import (dnn_graph, hybrid_graph, hybrid_workload,
                                  synfire_graph)
from repro.core.snn import build_synfire, simulate_synfire


# -------------------------------------------------------------------------
# Golden: compiled synfire == seed single-chip simulation, bit for bit
# -------------------------------------------------------------------------

def test_compiled_synfire_program_bit_identical_to_seed():
    graph = synfire_graph(8, seed=0)
    prog = compile_graph(graph)
    sim = ChipSim(prog)
    recs = sim.run(300)
    ref = simulate_synfire(build_synfire(0), 300)
    for k in ("spikes_exc", "spikes_inh", "pl", "n_fifo", "syn_events",
              "packets"):
        assert np.array_equal(np.asarray(recs[k]), np.asarray(ref[k])), k


def test_compiled_synfire_placement_matches_place_ring():
    """The graph compiler generalizes place_ring: same mesh, same snake
    coords, same routing masks, same incidence tensor."""
    for n_pes in (8, 24):
        prog = compile_graph(synfire_graph(n_pes))
        pl = place_ring(n_pes)
        assert (prog.mesh.width, prog.mesh.height) == \
            (pl.mesh.width, pl.mesh.height)
        np.testing.assert_array_equal(prog.coords, pl.coords)
        np.testing.assert_array_equal(prog.table.masks, pl.table.masks)
        np.testing.assert_array_equal(prog.inc, pl.inc)
        # spike projections -> header-only packets everywhere
        assert (prog.payload_bits == 0).all()
        assert prog.fits()


def test_dvfs_thresholds_flow_from_graph_to_engine():
    """A net built with custom l_th1/l_th2 must drive the engine's DVFS
    controller through the plain graph -> compile -> ChipSim path (no
    hand-patching at call sites)."""
    import dataclasses
    from repro.configs import paper
    sp = dataclasses.replace(paper.SYNFIRE, l_th1=5, l_th2=10)
    sim = ChipSim(compile_graph(synfire_graph(8, sp=sp)))
    assert (sim.dvfs.l_th1, sim.dvfs.l_th2) == (5, 10)


def test_synfire_shim_removed():
    """The deprecated ``ChipSim.synfire`` shim (PR 2 kept it for one
    cycle) is gone — the graph API is the one entry point."""
    assert not hasattr(ChipSim, "synfire")


# -------------------------------------------------------------------------
# Graph validation + compile errors
# -------------------------------------------------------------------------

def test_graph_rejects_bad_projections():
    pops = [Population("a", 10, 100), Population("b", 10, 100)]
    with pytest.raises(ValueError, match="unknown population"):
        NetGraph(pops, [Projection("a", "zzz")])
    with pytest.raises(ValueError, match="bits_per_packet"):
        Projection("a", "b", payload=GRADED, bits_per_packet=0)
    with pytest.raises(ValueError, match="must not carry"):
        Projection("a", "b", payload=SPIKE, bits_per_packet=8)
    with pytest.raises(ValueError, match="duplicate"):
        NetGraph([Population("a", 1, 1), Population("a", 1, 1)], [])


def test_compile_rejects_oversized_graph_with_clear_error():
    with pytest.raises(ValueError, match="mesh holds 16 PEs"):
        compile_graph(synfire_graph(64), MeshSpec(2, 2))
    with pytest.raises(ValueError, match="exceeds the .* PE SRAM"):
        compile_graph(NetGraph(
            [Population("fat", 1, sram_bytes=10 * 1024 * 1024)], [],
            semantics=object()))


def test_compile_rejects_mixed_packet_classes_per_source():
    """One multicast tree per source PE means one packet class per source:
    mixing spike + graded (or two graded sizes) on a population's
    out-projections would silently misprice traffic, so compile refuses."""
    pops = [Population("s", 8, 64), Population("a", 8, 64),
            Population("b", 8, 64)]
    mixed = NetGraph(pops, [
        Projection("s", "a", payload=SPIKE),
        Projection("s", "b", payload=GRADED, bits_per_packet=1024),
    ], semantics=object())
    with pytest.raises(ValueError, match="mixes packet classes"):
        compile_graph(mixed)
    two_sizes = NetGraph(pops, [
        Projection("s", "a", payload=GRADED, bits_per_packet=64),
        Projection("s", "b", payload=GRADED, bits_per_packet=1024),
    ], semantics=object())
    with pytest.raises(ValueError, match="mixes packet classes"):
        compile_graph(two_sizes)
    # same class on every edge is fine
    ok = NetGraph(pops, [
        Projection("s", "a", payload=GRADED, bits_per_packet=64),
        Projection("s", "b", payload=GRADED, bits_per_packet=64),
    ], semantics=object())
    prog = compile_graph(ok)
    assert prog.payload_bits[prog.pe_range("s")[0]] == 64


def test_compile_requires_semantics():
    with pytest.raises(ValueError, match="no tick semantics"):
        compile_graph(NetGraph([Population("a", 1, 1)], []))


def test_align_qpe_separates_populations():
    prog = compile_graph(hybrid_graph(n_neurons=64, hidden=16, n_ticks=10))
    (src,), (dst,) = prog.pe_range("nef"), prog.pe_range("mlp")
    # distinct QPEs -> the projection crosses >= 1 real mesh link
    assert tuple(prog.coords[src]) != tuple(prog.coords[dst])
    assert prog.inc[src].sum() >= 1
    # graded payload class on the source PE
    assert prog.payload_bits[src] == 16 * 64
    assert prog.payload_bits[dst] == 0


# -------------------------------------------------------------------------
# Conservation: graded payload in == out across the NoC
# -------------------------------------------------------------------------

def test_hybrid_graded_payload_conserved():
    """Every graded payload bit the NEF PE emits arrives at the MLP PE one
    transport tick later — the NoC neither drops nor invents events."""
    h = hybrid_workload(n_neurons=128, hidden=32, n_ticks=300)
    out = h["graded_bits_out"]                  # (T,) bits emitted per tick
    inn = h["graded_bits_in"]                   # (T,) bits consumed per tick
    assert out.sum() > 0
    np.testing.assert_array_equal(out[:-1], inn[1:])
    # nothing arrives before anything was sent
    assert inn[0] == 0


def test_dnn_program_compiles_and_places_tiles():
    graph = dnn_graph()
    prog = compile_graph(graph)
    total_tiles = sum(p.n_tiles for p in graph.populations)
    assert prog.n_pes == total_tiles
    assert prog.fits()
    # graded projections: every non-final layer's PEs carry payload bits
    last = graph.populations[-1].name
    for pop in graph.populations:
        pes = prog.pe_range(pop.name)
        if pop.name == last:
            assert (prog.payload_bits[pes] == 0).all()
        else:
            assert (prog.payload_bits[pes] > 0).all()


def test_power_table_works_for_any_program():
    """chip_power_table is workload-agnostic: it only needs the standard
    per-tick record contract."""
    h = hybrid_workload(n_neurons=64, hidden=16, n_ticks=120)
    tab = chip_power_table(h["sim"], h["recs"])
    assert tab["n_pes"] == 2
    assert tab["per_pe"]["dvfs"]["total"] > 0
    assert tab["noc"]["power_mw"] > 0
