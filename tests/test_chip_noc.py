"""Mesh NoC: link enumeration, incidence tensors, vectorized accounting.

The incidence path must agree exactly with the per-source Python loops in
core/noc.py — same multicast trees, same link counts, same energy."""
import jax.numpy as jnp
import numpy as np

from repro.chip.mesh_noc import MeshNoc, MeshSpec, SPIKE_PACKET_BITS
from repro.core.noc import NocModel, multicast_links, xy_route


def test_link_enumeration_count():
    for w, h in ((1, 1), (2, 1), (4, 4), (3, 5)):
        noc = MeshNoc(MeshSpec(w, h))
        expect = 2 * ((w - 1) * h + w * (h - 1))
        assert noc.n_links == expect, (w, h)
        # no duplicate links
        assert len(noc.link_index) == noc.n_links


def test_incidence_row_matches_core_multicast_links():
    rng = np.random.default_rng(0)
    noc = MeshNoc(MeshSpec(5, 4))
    coords = [(x, y) for x in range(5) for y in range(4)]
    for _ in range(25):
        src = tuple(coords[rng.integers(len(coords))])
        dsts = [tuple(coords[i])
                for i in rng.choice(len(coords), 4, replace=False)]
        row = noc.incidence_row(src, dsts)
        dsts_remote = [d for d in dsts if d != src]
        assert int(row.sum()) == multicast_links(src, dsts_remote)


def test_link_loads_equals_python_loop():
    rng = np.random.default_rng(1)
    noc = MeshNoc(MeshSpec(4, 4))
    coords = [(x, y) for x in range(4) for y in range(4)]
    srcs = [coords[i] for i in range(8)]
    dst_lists = [[coords[j] for j in rng.choice(16, 3, replace=False)]
                 for _ in srcs]
    inc = noc.incidence(srcs, dst_lists)
    packets = rng.integers(0, 50, len(srcs))

    loads = np.asarray(noc.link_loads(jnp.asarray(packets), inc))
    # reference: walk every source's tree link by link
    ref = np.zeros(noc.n_links)
    for p, (s, ds) in zip(packets, zip(srcs, dst_lists)):
        for lk in noc.tree_links(s, ds):
            ref[noc.link_index[lk]] += p
    np.testing.assert_allclose(loads, ref)


def test_spike_energy_matches_core_noc_model():
    """Chip accounting == core NocModel.spike_energy_j for one source."""
    noc = MeshNoc(MeshSpec(4, 4))
    m = NocModel(noc.spec)
    src, dsts = (0, 0), [(3, 1), (3, 2), (1, 3)]
    inc = noc.incidence_row(src, dsts)[None]
    loads = noc.link_loads(jnp.asarray([1.0]), inc)
    got = float(noc.spike_energy_j(loads))
    np.testing.assert_allclose(got, m.spike_energy_j(src, dsts), rtol=1e-5)


def test_intra_qpe_delivery_uses_no_links():
    noc = MeshNoc(MeshSpec(2, 2))
    assert noc.incidence_row((1, 1), [(1, 1)]).sum() == 0


def test_tick_batched_loads_shape():
    noc = MeshNoc(MeshSpec(3, 3))
    inc = np.ones((5, noc.n_links), np.float32)
    packets = jnp.ones((7, 5))                    # (T, P)
    assert noc.link_loads(packets, inc).shape == (7, noc.n_links)


def test_graded_packet_flits_and_bits():
    """Typed packet classes: payload 0 = header-only spike (1 flit, 64 b);
    graded payloads price as ceil(bits/128) flits of 192 b."""
    noc = MeshNoc(MeshSpec(2, 2))
    pb = jnp.asarray([0, 1, 128, 129, 4096])
    np.testing.assert_array_equal(noc.packet_flits(pb), [1, 1, 1, 2, 32])
    np.testing.assert_array_equal(
        noc.packet_bits(pb), [64, 192, 192, 384, 32 * 192])


def test_graded_traffic_energy_matches_core_noc_model():
    """Packet-class-aware energy == core NocModel payload pricing."""
    noc = MeshNoc(MeshSpec(4, 4))
    m = NocModel(noc.spec)
    src, dsts = (0, 0), [(3, 1), (3, 2), (1, 3)]
    inc = noc.incidence_row(src, dsts)[None]
    tree_links = inc.sum(axis=1)
    for payload in (16, 500, 4096):
        got = float(noc.traffic_energy_j(
            jnp.asarray([1.0]), tree_links, jnp.asarray([payload])))
        np.testing.assert_allclose(
            got, m.payload_energy_j(src, dsts, payload), rtol=1e-5)
    # payload 0 degrades to the spike-packet price
    got = float(noc.traffic_energy_j(jnp.asarray([1.0]), tree_links,
                                     jnp.asarray([0])))
    np.testing.assert_allclose(got, m.spike_energy_j(src, dsts), rtol=1e-5)


def test_flit_loads_weigh_multiflit_packets():
    noc = MeshNoc(MeshSpec(3, 1))
    inc = noc.incidence([(0, 0), (2, 0)], [[(2, 0)], [(0, 0)]])
    packets = jnp.asarray([2.0, 1.0])
    pb = jnp.asarray([0, 300])              # spike vs 3-flit graded
    flits = np.asarray(noc.flit_loads(packets, inc, pb))
    loads = np.asarray(noc.link_loads(packets, inc))
    # graded source contributes 3x its packet count in flits
    np.testing.assert_allclose(flits.sum(), 2 * 2 + 1 * 3 * 2)
    np.testing.assert_allclose(loads.sum(), 2 * 2 + 1 * 2)


def test_capacity_and_latency_scales():
    noc = MeshNoc(MeshSpec(4, 4))
    # 64 b packet = 1 flit, 5 cycles/hop @ 400 MHz
    assert noc.link_capacity_packets(1e-3, SPIKE_PACKET_BITS) == \
        1e-3 * 400e6 / 5
    np.testing.assert_allclose(noc.hop_latency_s(3), 3 * 5 / 400e6)
    assert noc.tree_hops((0, 0), [(3, 1), (0, 2)]) == 4
