"""Data pipeline determinism/seekability + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.data.pipeline import PipelineConfig, SyntheticTokenPipeline
from repro.optim.compress import (
    compressed_psum_mean, dequantize_tensor, ef_compress, quantize_tensor,
)


def _pipe(seed=0):
    return SyntheticTokenPipeline(PipelineConfig(
        vocab_size=101, seq_len=32, global_batch=4, seed=seed))


def test_batches_deterministic_and_seekable():
    p1, p2 = _pipe(), _pipe()
    for s in (0, 7, 3, 7):          # out-of-order seek
        a, b = p1.batch(s), p2.batch(s)
        assert bool(jnp.all(a["tokens"] == b["tokens"]))
    assert not bool(jnp.all(p1.batch(1)["tokens"] == p1.batch(2)["tokens"]))


def test_tokens_have_learnable_structure():
    """Most transitions follow the affine recurrence (noise_prob ~5%)."""
    cfg = PipelineConfig(vocab_size=101, seq_len=64, global_batch=8,
                         noise_prob=0.05)
    b = SyntheticTokenPipeline(cfg).batch(0)["tokens"]
    x = np.asarray(b)
    consistent = 0
    total = 0
    for row in x:
        # recover (a, c) from the first clean transitions by brute force
        for a in range(1, 101, 2):
            c = (row[1] - a * row[0]) % 101
            pred = (a * row[:-1] + c) % 101
            frac = (pred == row[1:]).mean()
            if frac > 0.5:
                consistent += (pred == row[1:]).sum()
                total += len(pred)
                break
    assert total > 0 and consistent / total > 0.8


def test_frames_mode_shapes():
    cfg = PipelineConfig(vocab_size=17, seq_len=8, global_batch=2,
                         kind="frames", d_model=16, num_codebooks=4)
    b = SyntheticTokenPipeline(cfg).batch(0)
    assert b["frames"].shape == (2, 8, 16)
    assert b["labels"].shape == (2, 8, 4)


@given(seed=st.integers(0, 1000))
def test_quantize_roundtrip_bounded(seed):
    r = np.random.default_rng(seed)
    g = jnp.asarray(r.standard_normal((64,)) * r.uniform(0.01, 10), jnp.float32)
    q, s = quantize_tensor(g)
    err = jnp.abs(dequantize_tensor(q, s) - g)
    assert float(jnp.max(err)) <= float(s) * 0.5 + 1e-9


def test_error_feedback_unbiased_over_time():
    """Sum of EF-compressed gradients converges to the sum of true ones."""
    r = np.random.default_rng(0)
    true_sum = np.zeros(32, np.float32)
    sent_sum = np.zeros(32, np.float32)
    ef = None
    for t in range(200):
        g = {"w": jnp.asarray(r.standard_normal(32), jnp.float32)}
        q, s, ef = ef_compress(g, ef)
        sent = dequantize_tensor(q["w"], s["w"])
        true_sum += np.asarray(g["w"])
        sent_sum += np.asarray(sent)
    resid = np.abs(true_sum - sent_sum).max()
    # residual equals the current EF buffer -> O(one quantization step)
    assert resid < 0.05, resid


def test_compressed_psum_on_trivial_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    g = jnp.asarray(np.random.default_rng(1).standard_normal((16, 8)),
                    jnp.float32)
    q, s = quantize_tensor(g)
    out = compressed_psum_mean(q, s, mesh, axes=("data",))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(dequantize_tensor(q, s)), rtol=1e-6)


def test_compression_ratio():
    """int8 + one f32 scale: 4x fewer collective payload bytes than f32."""
    g = jnp.zeros((1024,), jnp.float32)
    q, s = quantize_tensor(g)
    assert (q.size * q.dtype.itemsize + 4) * 4 <= g.size * 4 + 16
