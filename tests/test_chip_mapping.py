"""Placement: SRAM constraints, snake order, routing tables, incidence."""
import numpy as np
import pytest

from repro.chip.mapping import (Placement, place_layers, place_ring,
                                snake_order, synfire_sram_bytes)
from repro.chip.mesh_noc import MeshSpec
from repro.core.pe import PESpec, partition_layer_to_sram


def test_mesh_autosize():
    assert MeshSpec.for_pes(8).n_pes >= 8
    assert MeshSpec.for_pes(8).n_qpes == 2
    m = MeshSpec.for_pes(64)
    assert (m.width, m.height) == (4, 4) and m.n_pes == 64


def test_snake_order_is_mesh_adjacent():
    mesh = MeshSpec(4, 3)
    order = snake_order(mesh)
    assert sorted(order) == list(range(12))
    for a, b in zip(order, order[1:]):
        (xa, ya), (xb, yb) = mesh.qpe_coord(a), mesh.qpe_coord(b)
        assert abs(xa - xb) + abs(ya - yb) == 1


def test_synfire_state_fits_sram():
    assert PESpec().fits_sram(synfire_sram_bytes())


def test_place_ring_8_matches_test_chip():
    pl = place_ring(8)
    assert pl.n_pes == 8
    assert (pl.mesh.width, pl.mesh.height) == (2, 1)
    # ring neighbours: intra-QPE hops are free, two links cross between QPEs
    assert pl.inc.sum() == 2
    assert pl.fits()
    assert pl.worst_tree_hops == 1


def test_place_ring_locality_on_large_mesh():
    pl = place_ring(64)
    # snake placement: every ring edge except those crossing QPE rows is a
    # 1-hop (or free intra-QPE) delivery; per-source trees are tiny
    per_src_links = pl.inc.sum(axis=1)
    assert per_src_links.max() <= pl.mesh.width + pl.mesh.height  # wrap edge
    assert np.median(per_src_links) <= 1.0


def test_place_ring_rejects_oversize():
    with pytest.raises(ValueError):
        place_ring(64, MeshSpec(2, 2))


def test_place_layers_tiles_fit_and_route():
    layers = [
        dict(name="c1", h=32, w=32, cin=3, cout=32, kh=3, kw=3),
        dict(name="c2", h=32, w=32, cin=32, cout=32, kh=3, kw=3),
    ]
    placements, noc, inc, coords = place_layers(layers)
    total = sum(lp.n_tiles for lp in placements)
    assert len(coords) == total == inc.shape[0]
    assert inc.shape[1] == noc.n_links
    pe = PESpec()
    for lp, ly in zip(placements, layers):
        # the chosen tiling must actually fit the 128 kB SRAM
        rows, cout_t, n = partition_layer_to_sram(
            pe, ly["h"], ly["w"], ly["cin"], ly["cout"], ly["kh"], ly["kw"])
        assert (rows, cout_t, n) == (lp.rows_per_tile, lp.cout_per_tile,
                                     lp.n_tiles)
        in_b = (rows + ly["kh"] - 1) * ly["w"] * ly["cin"]
        w_b = ly["kh"] * ly["kw"] * ly["cin"] * cout_t
        out_b = rows * ly["w"] * cout_t * 4
        assert pe.fits_sram(in_b, w_b, out_b)
    # layer 1 tiles multicast to every layer 2 tile; last layer sends nothing
    c1, c2 = placements
    for p in c1.pes:
        assert inc[p].sum() >= 0          # row exists
    # masks: c1 -> c2 only
    placements2, noc2, inc2, _ = place_layers(layers, MeshSpec(3, 3))
    assert noc2.mesh.n_pes == 36
