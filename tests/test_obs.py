"""Telemetry subsystem (repro.obs): probe neutrality goldens, probe
correctness, link-profile parity, Perfetto trace export, manifest
provenance, and the bench regression gate.

Acceptance anchors:

* probes-off runs are BITWISE identical to the pre-telemetry engine —
  the synfire golden still reproduces ``simulate_synfire`` through the
  default ``run()`` path, and a plastic 2x2-board run's records do not
  change whether probes are compiled into the carry or not;
* the whole-run link probes reproduce the pre-probe ``--profile-links``
  JSON schema exactly (peak/mean off the full-resolution records);
* a 2x2-board run exports trace-event JSON with per-PE and per-tier
  tracks that round-trips through ``json``;
* ``repro.obs.report`` exits nonzero on an injected >20% tick_us
  regression and 0 within threshold / with ``--warn-only``.
"""
import json

import numpy as np
import pytest

from repro.board import BoardSpec, compile_board
from repro.chip.chip import ChipSim
from repro.chip.compile import compile as compile_graph
from repro.chip.workloads import hybrid_farm_board_graph, synfire_graph
from repro.core.snn import build_synfire, simulate_synfire
from repro.learn.adaptive import adaptive_control_graph
from repro.obs import (ProbeSpec, bench_payload, default_probes,
                       link_profile, link_profile_probes,
                       record_link_profile, run_manifest, trace_events,
                       write_trace)
from repro.obs.report import diff_benches, main as report_main
from repro.obs.trace import main as trace_main


def _assert_same_records(a: dict, b: dict, keys=None):
    for k in (keys or a):
        if k == "probes":
            continue
        assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k


# -------------------------------------------------------------------------
# Probe neutrality: probes-off == pre-telemetry engine, bitwise
# -------------------------------------------------------------------------

def test_probes_off_golden_synfire_vs_seed_engine():
    """The default ``run()`` (zero probes) still traces the pre-PR tick
    body: the 8-PE synfire golden reproduces ``simulate_synfire`` bit
    for bit, and ``probes=()`` is the very same path."""
    sim = ChipSim(compile_graph(synfire_graph(8)))
    recs = sim.run(300)
    ref = simulate_synfire(build_synfire(0), 300)
    for k in ref:
        assert np.array_equal(np.asarray(recs[k]), np.asarray(ref[k])), k
    _assert_same_records(sim.run(300, probes=()), recs)
    assert "probes" not in recs


def test_probed_run_leaves_records_bitwise_identical():
    """Probes only read the tick's records — compiling them into the
    carry must not perturb a single bit of the per-tick records."""
    sim = ChipSim(compile_graph(synfire_graph(8)))
    bare = sim.run(300)
    probed = sim.run(300, probes=default_probes(sim.program))
    _assert_same_records(bare, probed, keys=bare)
    assert set(probed["probes"]) >= {"link_flits_peak", "pe_pl_mean",
                                     "pe_packets_sum", "e_noc_sum"}


# 2x2 board, 1x1-QPE chips: 4 channels don't fit on one chip, so the
# plastic control loops are forced across the SerDes tier
BOARD_KW = dict(n_channels=4, n_neurons=50, n_ticks=128, period=128)


@pytest.fixture(scope="module")
def plastic_board_sim():
    board = BoardSpec.parse("2x2", chip="1x1")
    g = adaptive_control_graph(**BOARD_KW)
    return ChipSim(compile_board(g, board, refine=False))


def test_probes_off_golden_board_plastic(plastic_board_sim):
    """A plastic 2x2-board run (cross-chip learning traffic) records
    identically with and without probes in the scan carry."""
    sim = plastic_board_sim
    bare = sim.run(128)
    assert float(np.asarray(bare["flits_xchip"]).sum()) > 0
    assert "e_learn" in bare
    probed = sim.run(128, probes=default_probes(sim.program))
    _assert_same_records(bare, probed, keys=bare)
    # the learn tier is probed too: per-slot |dw| plus per-PE e_learn
    assert "pe_e_learn_sum" in probed["probes"]
    assert any(k.startswith("learn_dw_") for k in probed["probes"])


# -------------------------------------------------------------------------
# Probe semantics: registry, validation, keep_records
# -------------------------------------------------------------------------

def test_probe_registry_and_validation():
    sim = ChipSim(compile_graph(synfire_graph(8)))
    # registry names expand to specs
    recs = sim.run(32, probes=("link_flits", "dvfs"))
    assert {"link_flits_peak", "link_flits_mean", "pe_pl_mean",
            "pe_pl_ema"} == set(recs["probes"])
    with pytest.raises(ValueError, match="unknown probe set"):
        sim.run(8, probes=("no_such_set",))
    with pytest.raises(KeyError, match="available keys"):
        sim.run(8, probes=(ProbeSpec("x", "no_such_rec_key", "peak"),))
    with pytest.raises(ValueError, match="duplicate probe names"):
        sim.run(8, probes=(ProbeSpec("x", "pl", "peak"),
                           ProbeSpec("x", "pl", "mean")))
    with pytest.raises(ValueError, match="unknown op"):
        ProbeSpec("x", "pl", "median")
    with pytest.raises(ValueError, match="keep_records"):
        sim.run(8, keep_records=False)


def test_keep_records_false_returns_only_probes():
    """The memory-bounded mode: strided probe buffers, no (T, ...)
    records — and the probe values match the full-resolution run."""
    sim = ChipSim(compile_graph(synfire_graph(8)))
    full = sim.run(300)
    slim = sim.run(300, probes=(ProbeSpec("pk", "link_flits", "peak"),),
                   keep_records=False)
    assert set(slim) == {"probes"}
    np.testing.assert_array_equal(
        np.asarray(slim["probes"]["pk"])[-1],
        np.asarray(full["link_flits"]).max(axis=0))


# -------------------------------------------------------------------------
# Link-profile parity: probe-based profiles == the pre-probe schema
# -------------------------------------------------------------------------

def test_link_profile_parity_chip_and_board(plastic_board_sim):
    """``record_link_profile`` must emit the exact JSON the benchmarks'
    hand-rolled ``--profile-links`` paths used to: per-link peak/mean
    flits off the full-resolution records, tier boundary included."""
    for sim, n_ticks in ((ChipSim(compile_graph(synfire_graph(16))), 64),
                         (plastic_board_sim, 128)):
        flits = np.asarray(sim.run(n_ticks)["link_flits"])
        legacy = {
            "n_onchip_links": int(sim.program.noc.n_onchip_links),
            "peak": np.round(flits.max(axis=0), 2).tolist(),
            "mean": np.round(flits.mean(axis=0), 4).tolist(),
        }
        assert record_link_profile(sim, n_ticks) == legacy


def test_link_profile_formats_probe_output():
    sim = ChipSim(compile_graph(synfire_graph(8)))
    recs = sim.run(64, probes=link_profile_probes(), keep_records=False)
    prof = link_profile(sim.program, recs["probes"])
    assert prof["n_onchip_links"] == sim.program.noc.n_links
    assert len(prof["peak"]) == len(prof["mean"]) == sim.program.noc.n_links


# -------------------------------------------------------------------------
# Perfetto trace export
# -------------------------------------------------------------------------

def test_trace_events_board(plastic_board_sim, tmp_path):
    sim = plastic_board_sim
    recs = sim.run(128)
    payload = trace_events(sim.program, recs)
    ev = payload["traceEvents"]
    # per-tier NoC counters (on-chip AND the SerDes tier)
    counters = {e["name"] for e in ev if e["ph"] == "C"}
    assert {"flits/onchip", "flits/xchip"} <= counters
    # learn tier: per-slot |dw| counters
    assert any(n.startswith("dw ") for n in counters)
    # per-PE threads grouped into per-chip processes
    procs = {e["args"]["name"] for e in ev
             if e.get("name") == "process_name"}
    assert sum(p.startswith("chip ") for p in procs) >= 2
    threads = [e for e in ev if e.get("name") == "thread_name"]
    assert len(threads) == sim.program.n_pes
    # per-PE DVFS counter tracks + active-tick slices
    assert any(n.startswith("pl PE") for n in counters)
    slices = [e for e in ev if e["ph"] == "X"]
    assert slices and all(
        {"pid", "tid", "ts", "dur", "name"} <= set(e) for e in slices)
    assert all(e["ts"] >= 0 and e["dur"] > 0 for e in slices)
    # round-trips through json and the file writer
    path = write_trace(tmp_path / "t.perfetto-trace.json", sim.program,
                       recs)
    assert json.loads(path.read_text())["traceEvents"]


def test_trace_events_single_chip():
    sim = ChipSim(compile_graph(synfire_graph(8)))
    payload = trace_events(sim.program, sim.run(64))
    counters = {e["name"] for e in payload["traceEvents"]
                if e["ph"] == "C"}
    assert "flits/onchip" in counters and "flits/xchip" not in counters


def test_trace_cli_writes_artifact(tmp_path):
    out = tmp_path / "board.perfetto-trace.json"
    assert trace_main(["--board", "2x2", "--chip", "4x2", "--ticks", "8",
                       "--out", str(out)]) == 0
    payload = json.loads(out.read_text())
    assert payload["traceEvents"]


# -------------------------------------------------------------------------
# Manifest + regression report
# -------------------------------------------------------------------------

def test_manifest_and_bench_payload():
    man = run_manifest(seed=7, config={"a": 1})
    assert man["seed"] == 7 and man["config_hash"]
    assert man["jax_version"] and man["python"] and man["host"]
    rows = [{"name": "r", "us_per_call": 1.0, "derived": "",
             "values": {}}]
    payload = bench_payload(rows, link_profiles={"r": {}},
                            timers={"r": {"build": 0.1}})
    assert payload["manifest"]["jax_version"] == payload["jax_version"]
    assert payload["phase_timers"] == {"r": {"build": 0.1}}
    # different configs hash differently, same config stably
    a = run_manifest(config={"x": 1})["config_hash"]
    assert a == run_manifest(config={"x": 1})["config_hash"]
    assert a != run_manifest(config={"x": 2})["config_hash"]


def _payload(tick_us: float, compile_s: float = 1.0) -> dict:
    return bench_payload([{
        "name": "scale_hybrid_1024pe", "us_per_call": tick_us,
        "derived": f"compile_s={compile_s}",
        "values": {"compile_s": compile_s},
    }])


def test_report_gate_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    base.write_text(json.dumps(_payload(100.0)))

    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps(_payload(110.0)))        # +10% — within 20%
    assert report_main([str(base), str(ok)]) == 0

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(_payload(125.0)))       # +25% — regression
    assert report_main([str(base), str(bad)]) == 1
    assert report_main([str(base), str(bad), "--warn-only"]) == 0
    assert report_main([str(base), str(bad), "--threshold", "0.5"]) == 0
    # alternate metric off the parsed derived values
    slow_compile = tmp_path / "slow.json"
    slow_compile.write_text(json.dumps(_payload(100.0, compile_s=3.0)))
    assert report_main([str(base), str(slow_compile),
                        "--metric", "compile_s"]) == 1
    # malformed / incomparable inputs
    assert report_main([str(tmp_path / "missing.json"), str(ok)]) == 2
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"rows": []}))
    assert report_main([str(base), str(empty)]) == 2


def test_diff_benches_matches_rows_by_name():
    base = _payload(100.0)
    new = _payload(130.0)
    new["rows"].append({"name": "only_new", "us_per_call": 1.0,
                        "derived": "", "values": {}})
    base["rows"].append({"name": "only_base", "us_per_call": 1.0,
                        "derived": "", "values": {}})
    d = diff_benches(base, new)
    assert [r["name"] for r in d["regressions"]] == ["scale_hybrid_1024pe"]
    assert d["missing"] == ["only_base"]
    assert d["regressions"][0]["ratio"] == pytest.approx(1.3)


# -------------------------------------------------------------------------
# Overhead guard: the default probe set stays cheap in traced-op terms
# -------------------------------------------------------------------------

def test_board_probe_run_matches_hybrid_board_golden():
    """The full board pipeline (hybrid farm) through a probed run: the
    per-tier probe sums agree with the full-resolution records."""
    board = BoardSpec.parse("2x2", chip="2x2")
    prog = compile_board(hybrid_farm_board_graph(board), board)
    sim = ChipSim(prog)
    recs = sim.run(32, probes=(
        ProbeSpec("xf", "flits_xchip", "sum"),
        ProbeSpec("en", "e_noc", "sum"),
    ))
    np.testing.assert_allclose(
        np.asarray(recs["probes"]["xf"])[-1],
        np.asarray(recs["flits_xchip"]).sum(), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(recs["probes"]["en"])[-1],
        np.asarray(recs["e_noc"]).sum(), rtol=1e-5)
