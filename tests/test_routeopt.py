"""Profile-guided routing (repro.routeopt): deterministic suite.

The invariants the subsystem lives by:

* **0-iteration golden** — ``optimize_routes(max_iters=0)`` IS today's
  compiler, bit for bit (CSR, coords, records);
* **routing invariance** — ANY orientation / border-port assignment
  yields bitwise-identical neuron-state records (packets ride the
  routing-table masks; incidence only prices links) and an identical
  delivery signature (flits conserved per (source, destination-set) —
  ``check_delivery`` also proves every stitched row is a tree);
* **multi-port spec** — ``ports_per_edge=1`` reproduces the historical
  mid-edge port and link enumeration exactly; grown boards keep ports
  distinct and facing;
* the optimizer never returns a program measured worse than baseline,
  and its trajectory rows carry the committed-BENCH schema.

This file is the hypothesis-less twin of test_routeopt_property.py.
"""
import numpy as np
import pytest

from repro.board import BoardSpec, compile_board, partition
from repro.board.spec import BoardNoc, DIRS, OPPOSITE
from repro.chip.chip import ChipSim
from repro.chip.compile import compile as compile_graph
from repro.chip.mesh_noc import MeshNoc, MeshSpec
from repro.chip.workloads import (hybrid_farm_board_graph, synfire_graph)
from repro.core.noc import ORIENTATIONS, build_tree, oriented_route, \
    xy_route
from repro.obs.report import diff_benches
from repro.routeopt import (RouteConfig, check_delivery, optimize_routes)

# per-tick record keys that legitimately depend on routing (NoC link
# accounting); every OTHER key is neuron/workload state and must be
# bitwise identical under any legal routing
NOC_KEYS = {"link_load", "link_flits", "e_noc", "e_noc_xchip",
            "load_xchip", "flits_xchip"}


def _is_noc_key(k: str) -> bool:
    return k in NOC_KEYS or k.startswith("touched_links")


def assert_neuron_identical(ra: dict, rb: dict):
    ka = {k for k in ra if not _is_noc_key(k) and k != "probes"}
    kb = {k for k in rb if not _is_noc_key(k) and k != "probes"}
    assert ka == kb
    for k in ka:
        assert np.array_equal(np.asarray(ra[k]), np.asarray(rb[k])), k


# -------------------------------------------------------------------------
# Shared tree builder + orientations
# -------------------------------------------------------------------------

def test_oriented_route_yx_is_y_first():
    path = oriented_route((0, 0), (2, 3), "yx")
    assert len(path) == 5                       # manhattan length
    assert path[0] == ((0, 0), (0, 1))          # first hop moves in Y
    assert path[-1] == ((1, 3), (2, 3))
    assert oriented_route((0, 0), (2, 3), "xy") == xy_route((0, 0), (2, 3))
    with pytest.raises(ValueError):
        oriented_route((0, 0), (1, 1), "zz")


@pytest.mark.parametrize("orientation", ORIENTATIONS)
def test_tree_link_ids_matches_shared_builder(orientation):
    noc = MeshNoc(MeshSpec(4, 3))
    link_of = {l: i for i, l in enumerate(noc.links)}
    rng = np.random.default_rng(7)
    for _ in range(25):
        src = (int(rng.integers(4)), int(rng.integers(3)))
        dsts = [(int(rng.integers(4)), int(rng.integers(3)))
                for _ in range(int(rng.integers(0, 7)))]
        ids = noc.tree_link_ids(src, np.array(dsts).reshape(-1, 2),
                                orientation=orientation)
        ref = {link_of[e] for e in build_tree(src, dsts, orientation)}
        assert set(ids.tolist()) == ref
        assert noc.tree_links(src, dsts, orientation) == \
            set(build_tree(src, dsts, orientation))


def test_build_tree_is_a_tree():
    edges = build_tree((1, 1), [(0, 0), (3, 2), (3, 0), (1, 3)], "yx")
    heads = [b for _, b in edges]
    assert len(set(edges)) == len(edges)
    assert len(set(heads)) == len(heads)        # in-degree <= 1
    assert (1, 1) not in heads                  # never re-enters the root


# -------------------------------------------------------------------------
# Multi-port BoardSpec / BoardNoc
# -------------------------------------------------------------------------

def test_single_port_board_reproduces_midedge_ports():
    b = BoardSpec(2, 2, chip=MeshSpec(4, 2))
    assert b.ports_per_edge == 1
    assert b.port("E") == (3, 1) and b.port("W") == (0, 1)
    assert b.port("N") == (2, 1) and b.port("S") == (2, 0)
    for d in DIRS:
        assert b.ports(d) == [b.port(d)]


def test_multi_port_spread_and_validation():
    b = BoardSpec(2, 2, chip=MeshSpec(4, 2), ports_per_edge=2)
    for d in DIRS:
        ps = b.ports(d)
        assert len(ps) == 2 and len(set(ps)) == 2
        # all on the correct border
        for x, y in ps:
            assert {"E": x == 3, "W": x == 0,
                    "N": y == 1, "S": y == 0}[d]
    with pytest.raises(ValueError):
        BoardSpec(2, 2, chip=MeshSpec(4, 2), ports_per_edge=3)  # > min(W,H)
    with pytest.raises(ValueError):
        BoardSpec(2, 2, chip=MeshSpec(4, 2), ports_per_edge=0)


def test_multi_port_noc_enumeration_and_endpoints():
    chip = MeshSpec(4, 2)
    n1 = BoardNoc(BoardSpec(2, 2, chip=chip))
    n2 = BoardNoc(BoardSpec(2, 2, chip=chip, ports_per_edge=2))
    assert n2.n_xchip_links == 2 * n1.n_xchip_links
    assert n2.n_onchip_links == n1.n_onchip_links
    # port-0 links exist under the same (c, d) keys in both
    for (c, d, j) in n1.xlinks:
        assert j == 0
        assert (c, d, 0) in n2.xlink_index and (c, d, 1) in n2.xlink_index
    # port j bridges to port j on the facing edge
    for lid in range(n2.n_onchip_links, n2.n_links):
        (c, a), (nbr, b) = n2.link_endpoints(lid)
        cc, dd, jj = n2.xlinks[lid - n2.n_onchip_links]
        assert c == cc and a == n2.board.port(dd, jj)
        assert b == n2.board.port(OPPOSITE[dd], jj)


# -------------------------------------------------------------------------
# RouteConfig validation
# -------------------------------------------------------------------------

def test_route_config_validate():
    b = BoardSpec(2, 2, chip=MeshSpec(4, 2), ports_per_edge=2)
    RouteConfig(tree_orient={"a": "yx"}, ports={("a", 0, "E"): 1}) \
        .validate(b)
    with pytest.raises(ValueError):
        RouteConfig(tree_orient={"a": "diag"}).validate(b)
    with pytest.raises(ValueError):
        RouteConfig(ports={("a", 0, "E"): 2}).validate(b)


# -------------------------------------------------------------------------
# 0-iteration golden: routeopt reproduces today's compile bit-for-bit
# -------------------------------------------------------------------------

def test_zero_iter_golden_bitwise():
    board = BoardSpec(2, 2, chip=MeshSpec(4, 2))
    g = hybrid_farm_board_graph(board)
    res = optimize_routes(g, board, max_iters=0)
    base = compile_board(hybrid_farm_board_graph(board), board)
    pa, pb = res.program, base
    np.testing.assert_array_equal(pa.coords, pb.coords)
    np.testing.assert_array_equal(pa.table.masks, pb.table.masks)
    np.testing.assert_array_equal(pa.sinc.link_ids, pb.sinc.link_ids)
    np.testing.assert_array_equal(pa.sinc.source_ptr, pb.sinc.source_ptr)
    np.testing.assert_array_equal(pa.sinc.tree_hops, pb.sinc.tree_hops)
    np.testing.assert_array_equal(pa.tree_links_x, pb.tree_links_x)
    np.testing.assert_array_equal(pa.path_hops, pb.path_hops)
    assert res.trajectory == [] and res.iterations == 0
    ra, rb = ChipSim(pa).run(16), ChipSim(pb).run(16)
    assert set(ra) == set(rb)
    for k in ra:
        assert np.array_equal(np.asarray(ra[k]), np.asarray(rb[k])), k


# -------------------------------------------------------------------------
# Routing invariance: deterministic parametrized twin of the property
# suite — any orientation / port assignment leaves neuron records
# bitwise identical and conserves delivered flits per (src, dst-set)
# -------------------------------------------------------------------------

def _variants(g, k2_board):
    pops = [p.name for p in g.populations]
    yield k2_board, RouteConfig()               # grown board, default route
    yield k2_board, RouteConfig(
        tree_orient={p: "yx" for p in pops},
        chip_orient={p: "yx" for p in pops})
    ports = {(p, c, d): (i + c) % k2_board.ports_per_edge
             for i, p in enumerate(pops)
             for c in range(k2_board.n_chips) for d in DIRS}
    yield k2_board, RouteConfig(
        tree_orient={p: ("yx" if i % 2 else "xy")
                     for i, p in enumerate(pops)},
        ports=ports)


@pytest.mark.parametrize("make", [
    lambda b: synfire_graph(n_pes=b.n_pes),
    hybrid_farm_board_graph,
])
def test_routing_invariance_deterministic(make):
    board = BoardSpec(2, 2, chip=MeshSpec(4, 2))
    base = compile_board(make(board), board)
    sig0 = check_delivery(base)
    r0 = ChipSim(base).run(12, seed=5)
    k2 = BoardSpec(2, 2, chip=MeshSpec(4, 2), ports_per_edge=2)
    for b, route in _variants(make(board), k2):
        prog = compile_board(make(b), b, route=route)
        # flit conservation: tree-walk proves each destination receives
        # each packet exactly once; equal signatures = equal deliveries
        assert check_delivery(prog) == sig0
        assert_neuron_identical(ChipSim(prog).run(12, seed=5), r0)
        # total flits per (source, dst-set) = packets x flits — flits
        # per packet is part of the signature, so conservation is exact


# -------------------------------------------------------------------------
# The optimizer itself
# -------------------------------------------------------------------------

@pytest.fixture(scope="module")
def opt_2x2():
    board = BoardSpec(2, 2, chip=MeshSpec(4, 2))
    g = hybrid_farm_board_graph(board)
    return optimize_routes(g, board, n_ticks=24, max_iters=3)


def test_optimizer_never_worse_and_trajectory_schema(opt_2x2):
    res = opt_2x2
    assert res.profile.objective() <= res.baseline.objective()
    assert res.improvement >= 0.0
    assert res.iterations >= 1
    assert res.trajectory[0]["iter"] == 0
    for row in res.trajectory:
        for key in ("peak_xlink_flits", "mean_xlink_flits",
                    "peak_onchip_flits", "compile_s", "measure_s",
                    "cut_flits"):
            assert key in row, key


def test_optimizer_program_is_legal(opt_2x2):
    res = opt_2x2
    board = BoardSpec(2, 2, chip=MeshSpec(4, 2))
    base = compile_board(hybrid_farm_board_graph(board), board)
    assert check_delivery(res.program) == check_delivery(base)
    assert_neuron_identical(ChipSim(res.program).run(12, seed=9),
                            ChipSim(base).run(12, seed=9))


def test_optimizer_budget_zero_skips_iterations():
    board = BoardSpec(2, 2, chip=MeshSpec(2, 2))
    g = synfire_graph(n_pes=board.n_pes)
    res = optimize_routes(g, board, n_ticks=8, max_iters=3, budget_s=0.0)
    assert res.iterations == 0 and not res.converged
    assert len(res.trajectory) == 1             # baseline row only


# -------------------------------------------------------------------------
# Partitioner re-weighting by measured rates
# -------------------------------------------------------------------------

def test_partition_rates_none_unchanged():
    board = BoardSpec(2, 2, chip=MeshSpec(4, 2))
    g = hybrid_farm_board_graph(board)
    pa = partition(g, board)
    pb = partition(g, board, rates=None)
    assert pa.chip_of == pb.chip_of and pa.cut_flits == pb.cut_flits


def test_partition_rates_reweight_moves_cut():
    board = BoardSpec(2, 2, chip=MeshSpec(4, 2))
    g = hybrid_farm_board_graph(board)
    # silencing every population but one changes the refinement's
    # weights; the cut metric must follow the given rates
    rates = {p.name: 0.001 for p in g.populations}
    hot = g.populations[0].name
    rates[hot] = 1000.0
    pa = partition(g, board, rates=rates)
    assert pa.cut_flits != partition(g, board).cut_flits


# -------------------------------------------------------------------------
# Report --direction (lower/higher regression gates)
# -------------------------------------------------------------------------

def _payload(**vals):
    return {"rows": [{"name": "r", "us_per_call": 1.0,
                      "values": dict(vals)}]}


def test_diff_benches_direction_lower():
    base = _payload(peak_xlink_flits=100.0)
    worse = _payload(peak_xlink_flits=150.0)
    better = _payload(peak_xlink_flits=60.0)
    d = diff_benches(base, worse, metric="peak_xlink_flits",
                     threshold=0.2, direction="lower")
    assert len(d["regressions"]) == 1
    d = diff_benches(base, better, metric="peak_xlink_flits",
                     threshold=0.2, direction="lower")
    assert d["regressions"] == []


def test_diff_benches_direction_higher():
    base = _payload(improvement=0.4)
    worse = _payload(improvement=0.1)
    better = _payload(improvement=0.5)
    d = diff_benches(base, worse, metric="improvement",
                     threshold=0.2, direction="higher")
    assert len(d["regressions"]) == 1
    d = diff_benches(base, better, metric="improvement",
                     threshold=0.2, direction="higher")
    assert d["regressions"] == []
    with pytest.raises(ValueError):
        diff_benches(base, worse, metric="improvement", direction="up")


# -------------------------------------------------------------------------
# Single-chip orientation knob (compile(orientations=...))
# -------------------------------------------------------------------------

def test_single_chip_orientation_neuron_invariant():
    g = synfire_graph(16)
    pa = compile_graph(g)
    pb = compile_graph(synfire_graph(16),
                       orientations={p.name: "yx" for p in g.populations})
    np.testing.assert_array_equal(pa.table.masks, pb.table.masks)
    assert check_delivery(pa) == check_delivery(pb)
    assert_neuron_identical(ChipSim(pa).run(30), ChipSim(pb).run(30))
