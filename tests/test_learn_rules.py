"""On-mesh plasticity: lowering, engine integration, convergence.

Acceptance anchors of the learn subsystem:

* plastic projections lower to ``LearnSlot``s identically through the
  single-chip and board compilers; rule/payload mismatches fail with
  errors naming the edge;
* a ``plasticity=None`` graph compiles to ``learn_slots == ()`` and runs
  BITWISE identical to the seed engine (synfire vs ``simulate_synfire``);
* the adaptive-control loop converges (tracking error below threshold)
  on a 1-chip mesh AND on a 2x2 board via the unchanged ``compile_board``
  path, with ``e_learn`` records present and charged to the owning PEs.
"""
import numpy as np
import pytest

from repro.board import BoardSpec, compile_board
from repro.chip.chip import ChipSim
from repro.chip.compile import compile as compile_graph
from repro.chip.graph import GRADED, NetGraph, Population, Projection
from repro.chip.workloads import synfire_graph
from repro.core.snn import build_synfire, simulate_synfire
from repro.learn import PES, STDP, lower_plasticity
from repro.learn.adaptive import (adaptive_control_graph,
                                  adaptive_control_workload,
                                  stdp_pair_graph, stdp_pair_workload)

# test-scale loop: one full reference period within the run; at this
# period the plant+filter phase lag sits clearly under the threshold
# (the same operating point BENCH_pr5.json records)
ADAPT_KW = dict(n_channels=2, n_neurons=100, n_ticks=2048, period=2048)


# -------------------------------------------------------------------------
# Lowering
# -------------------------------------------------------------------------

def test_plastic_projections_lower_to_slots():
    g = adaptive_control_graph(**ADAPT_KW)
    prog = compile_graph(g)
    assert len(prog.learn_slots) == 2
    s = prog.learn_slots[0]
    assert (s.kind, s.name) == ("pes", "nef0->plant0")
    assert (s.n_pre, s.n_post) == (100, 1)
    # PES decoders live on the SOURCE (nef) tile
    assert s.pe_ids == tuple(range(prog.pe_slices["nef0"].start,
                                   prog.pe_slices["nef0"].stop))

    gs = stdp_pair_graph(n_pre=8, n_post=4, n_ticks=32)
    ps = compile_graph(gs)
    (slot,) = ps.learn_slots
    assert (slot.kind, slot.n_pre, slot.n_post) == ("stdp", 8, 4)
    # STDP fan-in weights live on the DESTINATION (post) tile
    assert slot.pe_ids == tuple(range(ps.pe_slices["post"].start,
                                      ps.pe_slices["post"].stop))


def test_board_lowering_matches_single_chip():
    g = adaptive_control_graph(**ADAPT_KW)
    chip = compile_graph(g)
    board = compile_board(g, BoardSpec(1, 1, chip=chip.mesh))
    assert board.learn_slots == chip.learn_slots


def test_lowering_rejects_rule_payload_mismatch():
    pops = [Population("a", 8, 64), Population("b", 8, 64)]
    g1 = NetGraph(pops, [Projection("a", "b", payload=GRADED,
                                    bits_per_packet=32,
                                    plasticity=STDP())],
                  semantics=object())
    with pytest.raises(ValueError, match="STDP needs a SPIKE"):
        compile_graph(g1)
    g2 = NetGraph(pops, [Projection("a", "b", plasticity=PES())],
                  semantics=object())
    with pytest.raises(ValueError, match="PES needs a GRADED"):
        compile_graph(g2)
    g3 = NetGraph(pops, [Projection("a", "b", plasticity="nope")],
                  semantics=object())
    with pytest.raises(ValueError, match="unknown plasticity rule"):
        compile_graph(g3)


def test_lowering_ignores_frozen_projections():
    g = adaptive_control_graph(plastic=False, **ADAPT_KW)
    assert compile_graph(g).learn_slots == ()
    assert lower_plasticity(synfire_graph(8), {}) == ()


# -------------------------------------------------------------------------
# Frozen graphs stay bitwise identical to the seed engine
# -------------------------------------------------------------------------

def test_frozen_graph_bitwise_identical_to_seed_engine():
    """plasticity=None -> no learn step is traced: the compiled synfire
    still reproduces the seed ``simulate_synfire`` bit for bit, and the
    records carry no e_learn."""
    prog = compile_graph(synfire_graph(8, seed=0))
    assert prog.learn_slots == ()
    recs = ChipSim(prog).run(300)
    assert "e_learn" not in recs
    ref = simulate_synfire(build_synfire(0), 300)
    for k in ("spikes_exc", "spikes_inh", "pl", "n_fifo", "packets"):
        assert np.array_equal(np.asarray(recs[k]), np.asarray(ref[k])), k


def test_plastic_semantics_must_carry_learn_state():
    g = adaptive_control_graph(**ADAPT_KW)
    g.semantics.plastic = False            # builds state without "learn"
    prog = compile_graph(g)                # ...but projections are plastic
    with pytest.raises(ValueError, match="learn"):
        ChipSim(prog).run(4)


# -------------------------------------------------------------------------
# Closed-loop convergence: 1 chip AND 2x2 board, unchanged engine
# -------------------------------------------------------------------------

def _check_converged(rep):
    assert rep["convergence_tick"] >= 0, (
        f"loop never converged: final_err={rep['final_err']:.3f}")
    assert rep["final_err"] < 0.1
    assert rep["dec_norm"] > 0             # decoders actually moved
    recs = rep["recs"]
    assert "e_learn" in recs
    e_l = np.asarray(recs["e_learn"])      # (T, P)
    assert (e_l >= 0).all() and e_l.sum() > 0
    # e_learn is charged exactly to the decoder-owning (nef) PEs
    prog = rep["program"]
    owners = sorted({pe for s in prog.learn_slots for pe in s.pe_ids})
    charged = sorted(np.flatnonzero(e_l.sum(axis=0) > 0))
    assert charged == owners
    assert rep["learn_energy_frac"] > 0
    assert rep["table"]["learn"]["energy_j"] == pytest.approx(e_l.sum())


def test_adaptive_control_converges_on_chip():
    rep = adaptive_control_workload(err_window=64, **ADAPT_KW)
    _check_converged(rep)


def test_adaptive_control_converges_on_2x2_board():
    """The SAME graph through the unchanged compile_board path: loops
    split across chips (refine=False), every weight update driven by an
    error that crossed the SerDes tier."""
    board = BoardSpec.parse("2x2", chip="2x1")
    # 6 channels = 12 populations > one 8-PE chip, so the graph-order
    # fill spills nef/plant pairs across chips
    rep = adaptive_control_workload(board=board, refine=False,
                                    err_window=64,
                                    **dict(ADAPT_KW, n_channels=6))
    _check_converged(rep)
    assert float(np.asarray(rep["recs"]["flits_xchip"]).sum()) > 0


def test_adaptive_board_matches_chip_records():
    """Compiling the same plastic graph for one chip and a 1x1 board
    yields bit-identical learning trajectories (the board layer adds
    tiers, not drift — now including the learn carry)."""
    kw = dict(ADAPT_KW, n_ticks=256)
    g = adaptive_control_graph(**kw)
    prog_c = compile_graph(g)
    prog_b = compile_board(g, BoardSpec(1, 1, chip=prog_c.mesh))
    rc = ChipSim(prog_c).run(256)
    rb = ChipSim(prog_b).run(256)
    for k in ("track_err", "dec_norm", "e_learn", "u", "y"):
        assert np.array_equal(np.asarray(rc[k]), np.asarray(rb[k])), k


# -------------------------------------------------------------------------
# STDP pair on the mesh
# -------------------------------------------------------------------------

def test_stdp_pair_weights_move_and_stay_bounded():
    rule = STDP(w_min=0.1, w_max=0.9, w_init=0.5)
    rep = stdp_pair_workload(n_pre=16, n_post=4, n_ticks=256, rule=rule)
    assert rep["w_mean_last"] != rep["w_mean_first"]   # learning happened
    assert rep["post_spikes"] > 0                      # forward pass live
    recs = rep["recs"]
    w_mean = np.asarray(recs["w_mean"])
    assert (w_mean >= rule.w_min - 1e-6).all()
    assert (w_mean <= rule.w_max + 1e-6).all()
    assert rep["e_learn_j"] > 0
    # learning energy shows up in the power table roll-up
    assert rep["table"]["learn"]["energy_frac"] > 0
