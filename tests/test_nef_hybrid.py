"""NEF ensemble (paper Sec. VI-C) + event-triggered MAC (Sec. II)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hybrid import event_mac, event_mac_energy_j
from repro.core.nef import build_ensemble, run_channel, synop_metrics
from repro.core.quant import quantize_params_linear, quantized_linear


@pytest.fixture(scope="module")
def channel():
    ens = build_ensemble(256, 1, seed=0)
    t = np.arange(1200)
    x = 0.8 * np.sin(2 * np.pi * t / 400)[:, None]
    out = run_channel(ens, x, use_mac=True)
    return ens, x, out


def test_channel_follows_input(channel):
    """Fig. 20: decoded output resembles the input."""
    ens, x, out = channel
    rmse = np.sqrt(np.mean((out["xhat"][300:, 0] - x[300:, 0]) ** 2))
    assert rmse < 0.25, rmse


def test_mac_path_equals_float_path(channel):
    ens, x, _ = channel
    o1 = run_channel(ens, x[:300], use_mac=True)
    o2 = run_channel(ens, x[:300], use_mac=False)
    # int8 encode quantization must not change spike totals materially
    assert abs(o1["spikes_per_tick"].sum() - o2["spikes_per_tick"].sum()) \
        <= 0.05 * max(o2["spikes_per_tick"].sum(), 1)


def test_synop_metrics_in_paper_band(channel):
    """Paper: ~10 pJ/equivalent synop (vs Loihi 24), ~20 pJ/hw synop."""
    ens, x, out = channel
    # dynamic energy per tick: NEF neuron updates + MAC encode + decode adds
    from repro.configs import paper
    N, D = ens.n_neurons, ens.dims
    e_tick = (N * paper.NEF_E_NEURON_J
              + 2.0 * N * D / (1.47e12 / 1.56)
              + out["spikes_per_tick"] * D * 0.2e-9)
    m = synop_metrics(ens, out["spikes_per_tick"], e_tick)
    # paper band (~10 pJ at its operating point); this fixture runs a lower
    # firing rate, so allow up to 30 pJ — the benchmark's operating-point
    # sweep (benchmarks/nef_channel.py) lands at 9-20 pJ, beating Loihi.
    assert 3.0 < m["pj_per_eq_synop"] < 30.0
    assert m["mean_rate_hz"] > 20.0


def test_event_mac_exact_and_sparse(rng):
    T, K, N = 32, 16, 24
    w = jnp.asarray(rng.standard_normal((K, N)), jnp.float32)
    wq, ws = quantize_params_linear(w)
    vals = jnp.asarray(rng.standard_normal((T, K)), jnp.float32)
    active = jnp.asarray(rng.random(T) < 0.3)
    out, n = event_mac(vals, active, wq, ws)
    ref = np.asarray(vals @ w)
    act = np.asarray(active)
    assert bool(jnp.all(out[~act] == 0))
    scale = np.abs(ref[act]).max()
    assert np.abs(np.asarray(out)[act] - ref[act]).max() / scale < 0.02
    assert int(n) == int(act.sum())


def test_event_energy_scales_with_activity():
    e_sparse = event_mac_energy_j(10, 64, 64)
    e_frame = event_mac_energy_j(100, 64, 64)
    np.testing.assert_allclose(e_sparse / e_frame, 0.1)


def test_quantized_linear_error_bound(rng):
    x = jnp.asarray(rng.standard_normal((40, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    wq, ws = quantize_params_linear(w)
    out = quantized_linear(x, wq, ws)
    ref = np.asarray(x @ w)
    rel = np.abs(np.asarray(out) - ref).max() / np.abs(ref).max()
    assert rel < 0.02, rel
