"""The neuromorphic serving tier (repro.serve.fleet + repro.serve.queue).

Anchors, strongest first:

* **fleet of 1 == plain engine, bitwise** — a single-session fleet's
  streamed per-tick outputs equal ``ChipSim.run`` of the same program
  with the whole stimulus preloaded: the vmapped batched body at w=1 is
  the unbatched engine, and segment-wise stimulus encoding equals
  whole-table encoding (per-row quantization);
* **preemption/restore is bitwise invisible** — sessions evicted when
  the fleet narrows (QueueDVFS) and resumed later — in the same engine
  or, via ``repro.ckpt``, in a different one — produce outputs identical
  to an uninterrupted run;
* width follows the queue's offered load through the batch levels;
* both served scenarios (adaptive control, KWS hybrid farm) and the
  board-compiled program serve end-to-end under Poisson traffic.
"""
import numpy as np
import pytest

from repro.chip.chip import ChipSim
from repro.chip.compile import compile as compile_graph
from repro.core.dvfs import QueueDVFS
from repro.serve.fleet import (FleetEngine, PoissonTraffic, Session,
                               SessionTable, adaptive_scenario,
                               kws_scenario)
from repro.serve.queue import RequestQueue, percentiles, select_width

TC = 32


@pytest.fixture(scope="module")
def adaptive_sc():
    return adaptive_scenario(n_neurons=32)


def _solo(sc, seed, total_ticks):
    """Uninterrupted single-session reference run (width-1 fleet)."""
    eng = FleetEngine(sc, round_ticks=TC,
                      dvfs=QueueDVFS(thresholds=(2,), batch_levels=(1, 1)),
                      capacity=1)
    s = Session(sid=0, stream=sc.stream(seed), total_ticks=total_ticks)
    return eng.serve(None, sessions=[s])["sessions"][0]


# ---------------------------------------------------------------- queue

def test_request_queue_fifo_front_and_stats():
    q = RequestQueue()
    q.extend(["a", "b", "c"])
    q.submit("p", front=True)            # preempted session jumps the line
    assert len(q) == 4 and q.depth == 4
    assert q.take(2) == ["p", "a"]
    assert q.take(10) == ["b", "c"]
    assert not q
    st = q.stats()
    assert st["submitted"] == 4 and st["taken"] == 4 and st["waiting"] == 0
    assert st["wait_p99_s"] >= st["wait_p50_s"] >= 0.0


def test_select_width_tracks_offered_load():
    dvfs = QueueDVFS(thresholds=(4, 16), batch_levels=(8, 32, 128))
    q = RequestQueue()
    assert select_width(dvfs, q, in_flight=0) == 8
    q.extend(range(5))                   # waiting alone crosses threshold
    assert select_width(dvfs, q, in_flight=0) == 32
    q.take(5)
    # in-flight work keeps the width up after the queue drains
    assert select_width(dvfs, q, in_flight=20) == 128
    assert select_width(dvfs, q, in_flight=20, capacity=16) == 16


def test_percentiles_empty_and_ordered():
    assert percentiles([]) == {"p50": 0.0, "p99": 0.0}
    p = percentiles(range(100))
    assert p["p50"] < p["p99"]


def test_percentiles_single_sample_and_none():
    """A single sample is its own p50 AND p99, and None entries
    (sessions that never completed) are dropped, not propagated."""
    assert percentiles([7.5]) == {"p50": 7.5, "p99": 7.5}
    assert percentiles([None, 3.0, None]) == {"p50": 3.0, "p99": 3.0}
    assert percentiles([None, None]) == {"p50": 0.0, "p99": 0.0}
    p = percentiles([None, 1.0, 2.0], ps=(0, 50, 100))
    assert (p["p0"], p["p50"], p["p100"]) == (1.0, 1.5, 2.0)


def test_request_queue_stats_before_any_traffic():
    st = RequestQueue().stats()
    assert st == {"submitted": 0, "taken": 0, "waiting": 0,
                  "wait_p50_s": 0.0, "wait_p99_s": 0.0}


def test_select_width_at_exact_ladder_boundaries():
    """The thresholds are inclusive lower edges: offered load EXACTLY at
    a threshold selects the higher level, one below stays put — and the
    in-flight term holds the width up after the queue drains (the
    hysteresis that keeps a busy fleet from collapsing mid-burst)."""
    dvfs = QueueDVFS(thresholds=(4, 16), batch_levels=(8, 32, 128))
    q = RequestQueue()

    def width(waiting, in_flight, capacity=None):
        if len(q):
            q.take(len(q))
        q.extend(range(waiting))
        return select_width(dvfs, q, in_flight=in_flight,
                            capacity=capacity)

    assert width(3, 0) == 8                  # one below the first edge
    assert width(4, 0) == 32                 # exactly at it -> climb
    assert width(15, 0) == 32
    assert width(16, 0) == 128               # second edge, same rule
    # the same edges driven purely by in-flight sessions
    assert width(0, 3) == 8
    assert width(0, 4) == 32
    assert width(0, 16) == 128
    # split across both terms: 2 waiting + 2 resident touches the edge
    assert width(2, 2) == 32
    assert width(2, 1) == 8
    # capacity clamps the ladder, never raises it
    assert width(16, 0, capacity=12) == 12
    assert width(3, 0, capacity=12) == 8


def test_session_table_compaction():
    t = SessionTable(capacity=4)
    ss = [Session(sid=i, stream=None, total_ticks=1) for i in range(3)]
    assert [t.admit(s) for s in ss] == [0, 1, 2]
    evicted, moved_from = t.evict(0)     # tail (slot 2) fills the hole
    assert evicted.sid == 0 and moved_from == 2
    assert [s.sid for s in t.slots] == [2, 1]
    evicted, moved_from = t.evict(1)     # tail itself: no move
    assert evicted.sid == 1 and moved_from is None
    assert t.evict_tail().sid == 2 and t.n_active == 0


def test_poisson_traffic_deterministic_and_exhausts():
    a = PoissonTraffic(rate=2.0, n_sessions=9, seed=5)
    b = PoissonTraffic(rate=2.0, n_sessions=9, seed=5)
    got = []
    while not a.exhausted:
        got.extend(a.poll())
    assert len(got) == 9 and a.poll() == []
    assert [s.sid for s in got] == list(range(9))
    assert got == b.drain()              # same seed, same arrivals
    lo, hi = a.tick_range
    assert all(lo <= s.total_ticks <= hi for s in got)


# ------------------------------------------------------- bitwise anchors

def test_fleet_of_one_bitwise_matches_chipsim(adaptive_sc):
    """Acceptance anchor: w=1 fleet == plain ChipSim.run, bitwise."""
    sc = adaptive_sc
    T = 3 * TC
    sess = _solo(sc, seed=41, total_ticks=T)
    # plain engine: same program shape, whole stimulus preloaded
    stim = sc.stream(41).segment(0, T)
    recs = ChipSim(compile_graph(sc.graph(T, stim))).run(T)
    for k in sc.output_keys:
        np.testing.assert_array_equal(sess.outputs[k], np.asarray(recs[k]))


def test_preemption_and_resume_invisible(adaptive_sc):
    """Sessions preempted by fleet narrowing finish with outputs equal
    to their uninterrupted solo runs (learn state included — the
    adaptive scenario's decoders ride the checkpointed carry).

    Equality here is float-tolerance, not bitwise: narrowing by design
    changes the vmap width, and XLA reassociates batched reductions
    differently per width (~1e-7 relative).  Bitwise invariance at FIXED
    width is pinned by the fleet-of-one and suspend/restore tests."""
    sc = adaptive_sc
    totals = [2 * TC, 5 * TC, 5 * TC]
    tr = PoissonTraffic(rate=10.0, n_sessions=3, seed=2,
                        tick_range=(1, 1))       # lengths patched below
    specs = tr.drain()
    sessions = [Session(sid=sp.sid, stream=sc.stream(sp.seed),
                        total_ticks=totals[sp.sid]) for sp in specs]
    # levels (1, 4) with threshold 3: all three admitted wide; once the
    # short session completes, offered load 2 < 3 narrows the fleet to 1,
    # preempting a tail session mid-run
    eng = FleetEngine(sc, round_ticks=TC,
                      dvfs=QueueDVFS(thresholds=(3,), batch_levels=(1, 4)))
    out = eng.serve(None, sessions=sessions)
    assert out["stats"]["completed"] == 3
    assert out["stats"]["preemptions"] >= 1
    for sess in out["sessions"]:
        ref = _solo(sc, seed=specs[sess.sid].seed,
                    total_ticks=sess.total_ticks)
        for k in sc.output_keys:
            np.testing.assert_allclose(sess.outputs[k], ref.outputs[k],
                                       rtol=3e-6, atol=1e-7)


def test_suspend_restore_cross_engine_bitwise(adaptive_sc, tmp_path):
    """Engine 1 serves two rounds and suspends (checkpoint through
    repro.ckpt); a FRESH engine restores the session from disk and
    finishes it — the stitched outputs equal the uninterrupted run."""
    sc = adaptive_sc
    T, seed = 5 * TC, 99
    ref = _solo(sc, seed, T)

    kw = dict(round_ticks=TC, capacity=1, ckpt_dir=tmp_path,
              dvfs=QueueDVFS(thresholds=(2,), batch_levels=(1, 1)))
    eng1 = FleetEngine(sc, max_rounds=2, **kw)
    s1 = Session(sid=7, stream=sc.stream(seed), total_ticks=T)
    eng1.serve(None, sessions=[s1])
    assert s1.ticks_done == 2 * TC and not s1.done
    assert [s.sid for s in eng1.suspend()] == [7]
    part1 = {k: np.concatenate(v) for k, v in s1.outputs.items()}

    eng2 = FleetEngine(sc, **kw)
    s2 = eng2.restore_session(7, stream=sc.stream(seed), total_ticks=T)
    assert s2.ticks_done == 2 * TC
    done = eng2.serve(None, sessions=[s2])["sessions"][0]
    assert done.done
    for k in sc.output_keys:
        stitched = np.concatenate([part1[k], done.outputs[k]])
        np.testing.assert_array_equal(stitched, ref.outputs[k])


# ------------------------------------------------------------ scheduling

def test_width_follows_queue_depth(adaptive_sc):
    """A burst of arrivals widens the fleet to a higher batch level; the
    drain narrows it back down — both levels appear in the histogram."""
    sc = adaptive_sc
    eng = FleetEngine(sc, round_ticks=TC,
                      dvfs=QueueDVFS(thresholds=(3, 6),
                                     batch_levels=(2, 4, 8)))
    tr = PoissonTraffic(rate=8.0, n_sessions=8, seed=0,
                        tick_range=(2 * TC, 4 * TC))
    st = eng.serve(tr)["stats"]
    assert st["completed"] == 8
    widths = {int(k) for k in st["width_hist"]}
    assert max(widths) >= 4 and min(widths) <= 4
    assert set(st["queue"]) >= {"submitted", "taken", "wait_p50_s"}
    assert st["joules_per_request"] > 0.0
    assert st["request_latency_s"]["p99"] >= st["request_latency_s"]["p50"]


def test_fleet_stats_account_every_tick(adaptive_sc):
    sc = adaptive_sc
    eng = FleetEngine(sc, round_ticks=TC,
                      dvfs=QueueDVFS(thresholds=(2,), batch_levels=(1, 2)))
    tr = PoissonTraffic(rate=1.0, n_sessions=3, seed=4,
                        tick_range=(TC, 3 * TC))
    out = eng.serve(tr)
    st = out["stats"]
    assert st["ticks_served"] == sum(s.total_ticks
                                     for s in out["sessions"])
    # padded (post-completion) round ticks are accounted separately
    assert st["ticks_run"] >= st["ticks_served"]
    for s in out["sessions"]:
        assert s.response is not None and "final_err" in s.response
        assert s.energy_j > 0.0 and s.latency_s() > 0.0


# -------------------------------------------------- scenarios and boards

def test_kws_fleet_end_to_end():
    sc = kws_scenario(n_pairs=2, n_neurons=32, hidden=8, n_keywords=3)
    eng = FleetEngine(sc, round_ticks=TC,
                      dvfs=QueueDVFS(thresholds=(2, 5),
                                     batch_levels=(2, 4, 8)))
    tr = PoissonTraffic(rate=2.0, n_sessions=6, seed=3,
                        tick_range=(TC, 3 * TC))
    out = eng.serve(tr)
    assert out["stats"]["completed"] == 6
    for s in out["sessions"]:
        assert len(s.response["scores"]) == 8
        assert 0 <= s.response["top_unit"] < 8
        assert s.outputs["hidden_out"].shape == (s.total_ticks, 2, 8)


def test_board_fleet_smoke(adaptive_sc):
    """The engine never looks inside the program: a board-compiled
    adaptive graph (chip-crossing control loops) serves unchanged."""
    from repro.board import BoardSpec
    sc = adaptive_scenario(n_channels=2, n_neurons=24)
    eng = FleetEngine(sc, round_ticks=TC,
                      dvfs=QueueDVFS(thresholds=(2,), batch_levels=(1, 2)),
                      board=BoardSpec.parse("2x1", chip="2x2"),
                      refine=False)
    tr = PoissonTraffic(rate=1.0, n_sessions=2, seed=1,
                        tick_range=(TC, 2 * TC))
    out = eng.serve(tr)
    assert out["stats"]["completed"] == 2
    assert all(s.energy_j > 0 for s in out["sessions"])


def test_batched_probes_ride_the_fleet(adaptive_sc):
    """Per-instance probe accumulators travel with sessions through the
    batched carry and come back per-session at completion.  Sessions
    emit samples at the stride boundaries their own timeline crosses
    (a session shorter than ``probe_ticks`` leaves later windows empty),
    so fleet probes use strides <= the session length."""
    from repro.obs import ProbeSpec
    sc = adaptive_sc
    eng = FleetEngine(sc, round_ticks=TC,
                      dvfs=QueueDVFS(thresholds=(2,), batch_levels=(1, 2)),
                      probes=(ProbeSpec("pl_mean", "pl", "mean", stride=TC),
                              ProbeSpec("e_sum", "e_dvfs_baseline", "sum",
                                        stride=TC)),
                      probe_ticks=4 * TC)
    tr = PoissonTraffic(rate=2.0, n_sessions=3, seed=6,
                        tick_range=(2 * TC, 4 * TC))
    out = eng.serve(tr)
    assert out["stats"]["completed"] == 3
    for s in out["sessions"]:
        pr = s.outputs["probes"]
        n_win = s.ticks_run // TC               # windows this session ran
        assert pr["pl_mean"].shape[0] == 4      # probe_ticks // stride
        assert np.all(pr["pl_mean"][:n_win] >= 0.0)
        assert pr["e_sum"][:n_win].sum() > 0.0
        assert np.all(pr["e_sum"][n_win:] == 0.0)   # windows never reached


@pytest.mark.parametrize("op,stride", [("peak", 8), ("mean", 8), ("sum", 5),
                                       ("last", 8), ("ema", None)])
def test_batched_probe_step_equals_per_instance(op, stride):
    """Deterministic twin of the hypothesis property in
    test_obs_property.py (which skips when hypothesis is absent): the
    batched probe fold over B instances with distinct local tick
    counters equals B independent unbatched folds, bitwise."""
    import jax
    import jax.numpy as jnp
    from repro.obs import ProbeSpec
    from repro.obs.probes import (make_batched_probe_step, make_probe_step,
                                  n_probe_samples)

    batch, n_ticks, n_steps = 3, 24, 14
    offs = np.asarray([0, 5, 17], np.int32)
    rng = np.random.default_rng(9)
    sig = rng.uniform(0.0, 8.0, (batch, n_steps, 4)).astype(np.float32)
    specs = (ProbeSpec("p", "sig", op, stride=stride, alpha=0.25),)
    shapes = {"sig": jax.ShapeDtypeStruct((4,), jnp.float32)}

    init, step, fin = make_probe_step(specs, shapes, n_ticks)
    binit, bstep, bfin = make_batched_probe_step(specs, shapes, n_ticks,
                                                 batch)
    obs_b = binit
    for j in range(n_steps):
        obs_b = bstep(obs_b, {"sig": jnp.asarray(sig[:, j])},
                      jnp.asarray(offs + j))
    out_b = np.asarray(bfin(obs_b)["p"])
    assert out_b.shape == (batch, n_probe_samples(n_ticks, stride), 4)
    for i in range(batch):
        obs = init
        for j in range(n_steps):
            obs = step(obs, {"sig": jnp.asarray(sig[i, j])},
                       jnp.int32(int(offs[i]) + j))
        np.testing.assert_array_equal(out_b[i], np.asarray(fin(obs)["p"]))
