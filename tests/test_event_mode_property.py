"""Hypothesis property suite for the event-driven execution mode.

The contract ``ChipSim(exec_mode="event")`` makes is BITWISE equality
with the dense engine — not tolerance-equal: the compressed tick gathers
the active-source set and touches only live links, but every record,
probe and energy row it emits must carry exactly the bits the dense tick
would.  Over randomized synfire nets (ring length, layer sizes, fan-ins,
Gaussian vs shot background, seeds):

* event == dense on EVERY rec key (values AND dtypes), on a single chip
  and compiled across 1x1 / 2x2 boards;
* the telemetry probe sets (``activity`` included) read identically in
  both modes;
* edge ticks are covered: runs containing zero-activity ticks, and runs
  whose live set overflows the compressed index buffer — every PE driven
  by dense background noise on a mesh wider than ``EVENT_SRC_CAP``, and
  a shot net squeezed through a tiny ``src_cap`` so the event tick's
  ``lax.cond`` dense fallback executes — stay bitwise;
* the PR's goldens: the 8-PE paper synfire through ``ChipSim``, a
  plastic (PES) 2x2 board, and a served fleet segment.
"""
import dataclasses
import importlib.util

import numpy as np
import pytest

# the randomized properties need hypothesis (CI's [test] extra); the
# deterministic edge-tick + golden tests below run without it
HAS_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
if HAS_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

from repro.board import BoardSpec, compile_board
from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.compile import compile as compile_graph
from repro.chip.mesh_noc import MeshSpec
from repro.chip.workloads import synfire_board_graph, synfire_graph
from repro.configs import paper
from repro.core.snn import EVENT_SRC_CAP

SCALED = dict(neurons_per_core=20, synapses_per_core=400, l_th1=2, l_th2=7)


def random_sp(rng):
    n_exc = int(rng.integers(4, 13))
    n_inh = int(rng.integers(2, 5))
    return dataclasses.replace(
        paper.SYNFIRE, n_exc=n_exc, n_inh=n_inh,
        neurons_per_core=n_exc + n_inh, synapses_per_core=400,
        fan_in_exc=int(rng.integers(1, n_exc + 1)),
        fan_in_inh=int(rng.integers(1, n_inh + 1)), l_th1=2, l_th2=7)


def random_build_kw(rng):
    if rng.integers(2):
        # the event benchmark configuration: silent background, sparse
        # deterministic current kicks
        return dict(noise_model="shot", noise_sigma=0.0, w_exc=0.25,
                    kicks_per_tick=int(rng.integers(1, 7)), kick=0.5)
    return dict(noise_model="gauss",
                noise_sigma=float(rng.uniform(0.05, 0.5)))


def random_graph(seed, board=None):
    rng = np.random.default_rng(seed)
    sp = random_sp(rng)
    kw = random_build_kw(rng)
    seed2 = int(rng.integers(100))
    if board is not None:
        return synfire_board_graph(board, seed=seed2, sp=sp, **kw)
    return synfire_graph(int(rng.integers(6, 25)), seed=seed2, sp=sp, **kw)


def assert_bitwise(ra, rb, ctx=""):
    assert set(ra) == set(rb), ctx
    for k in sorted(ra):
        a, b = ra[k], rb[k]
        if isinstance(a, dict):
            assert_bitwise(a, b, ctx=f"{ctx}{k}/")
            continue
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype, f"{ctx}{k}: {a.dtype} != {b.dtype}"
        assert np.array_equal(a, b), f"{ctx}{k}"


def run_pair(prog, n_ticks, **kw):
    rd = ChipSim(prog, exec_mode="dense").run(n_ticks, **kw)
    re = ChipSim(prog, exec_mode="event").run(n_ticks, **kw)
    return rd, re


# ------------------------------------------- chip + board properties

if HAS_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_event_matches_dense_on_chip(seed):
        prog = compile_graph(random_graph(seed))
        rd, re = run_pair(prog, 48)
        assert_bitwise(rd, re)
        # the derived energy/power tables inherit the bit-equality
        assert chip_power_table(ChipSim(prog), rd) == \
            chip_power_table(ChipSim(prog), re)

    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_probes_read_identically_in_both_modes(seed):
        prog = compile_graph(random_graph(seed))
        rd, re = run_pair(prog, 32,
                          probes=("activity", "pe_packets", "dvfs"))
        assert_bitwise(rd["probes"], re["probes"])

    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([(1, 1), (2, 2)]))
    def test_event_matches_dense_on_board(seed, shape):
        board = BoardSpec(*shape, chip=MeshSpec(2, 1))
        prog = compile_board(random_graph(seed, board=board), board)
        rd, re = run_pair(prog, 32)
        assert_bitwise(rd, re)


# ----------------------------------------------------------- edge ticks

def _sparse_graph(n_pes=48):
    sp = dataclasses.replace(paper.SYNFIRE, n_exc=16, n_inh=4,
                             fan_in_exc=8, fan_in_inh=4, **SCALED)
    return synfire_graph(n_pes, sp=sp, w_exc=0.25, noise_sigma=0.0,
                         noise_model="shot")


def test_empty_activity_ticks_are_bitwise():
    prog = compile_graph(_sparse_graph())
    rd, re = run_pair(prog, 64)
    # the shot-noise net is quiet between wave fronts: the run must
    # actually contain zero-active ticks for this edge to be covered
    assert (np.asarray(rd["active_sources"]) == 0).any()
    assert_bitwise(rd, re)


def test_all_active_overflow_ticks_are_bitwise():
    # dense Gaussian background drives every PE every tick, so with more
    # PEs than the live buffer holds the event tick must run its dense
    # fallback on every tick — and stay bitwise through it
    n = EVENT_SRC_CAP + 8
    sp = dataclasses.replace(paper.SYNFIRE, n_exc=16, n_inh=4,
                             fan_in_exc=8, fan_in_inh=4, **SCALED)
    prog = compile_graph(synfire_graph(n, sp=sp, w_exc=0.25,
                                       noise_sigma=2.0))
    rd, re = run_pair(prog, 8)
    assert (np.asarray(rd["active_sources"]) > EVENT_SRC_CAP).any()
    assert_bitwise(rd, re)


def test_shot_overflow_cond_falls_back_bitwise():
    # dynamic overflow: a tiny src_cap forces the event tick's lax.cond
    # onto the dense branch once the kick decay tails outgrow it (by
    # tick ~5 with 3 kicks/tick), while the earliest ticks — stimulus
    # plus first kicks — still fit and run compressed.  Both branches of
    # the SAME traced tick must emit dense bits.
    import jax
    import jax.numpy as jnp
    from repro.core.dvfs import DVFSController
    from repro.core.energy import PEEnergyModel
    from repro.core.snn import (build_synfire, make_synfire_tick,
                                synfire_init_state)
    sp = dataclasses.replace(paper.SYNFIRE, n_pes=32, n_exc=16, n_inh=4,
                             fan_in_exc=8, fan_in_inh=4, **SCALED)
    net = build_synfire(sp=sp, w_exc=0.25, noise_sigma=0.0,
                        noise_model="shot", kicks_per_tick=3)
    dvfs = DVFSController(sp.l_th1, sp.l_th2)
    em = PEEnergyModel()
    key = jax.random.PRNGKey(1)

    def run(event, src_cap=None):
        tick = make_synfire_tick(net, dvfs=dvfs, em=em, key=key,
                                 event=event, src_cap=src_cap)
        init = synfire_init_state(net)
        _, recs = jax.lax.scan(tick, init, jnp.arange(48))
        return recs

    assert_bitwise(run(False), run(True, src_cap=4))


# -------------------------------------------------------------- goldens

def test_golden_8pe_synfire_event_matches_dense():
    """The paper's 8-PE test-chip configuration (Gaussian background),
    whose records anchor the Table III validation, is untouched by the
    event engine."""
    prog = compile_graph(synfire_graph(8, seed=0))
    rd, re = run_pair(prog, 200)
    assert_bitwise(rd, re)


def test_golden_plastic_2x2_board_event_matches_dense():
    """On-mesh PES learning across a 2x2 board: weight trajectories,
    learn records and e_learn are identical in event mode (the learn
    step runs outside the compressed section, on identical inputs)."""
    from repro.learn.adaptive import adaptive_control_graph
    board = BoardSpec(2, 2, chip=MeshSpec(2, 1))
    graph = adaptive_control_graph(n_channels=8, n_neurons=32, n_ticks=96)
    prog = compile_board(graph, board)
    rd, re = run_pair(prog, 96)
    assert_bitwise(rd, re)


def test_golden_fleet_segment_event_matches_dense():
    """A served fleet segment streams the same bits regardless of the
    engine mode the fleet's vmapped stepper compiles."""
    from repro.core.dvfs import QueueDVFS
    from repro.serve.fleet import FleetEngine, Session, adaptive_scenario
    sc = adaptive_scenario(n_neurons=32)
    outs = {}
    for mode in ("dense", "event"):
        eng = FleetEngine(sc, round_ticks=32,
                          dvfs=QueueDVFS(thresholds=(2,),
                                         batch_levels=(1, 1)),
                          capacity=1, exec_mode=mode)
        s = Session(sid=0, stream=sc.stream(7), total_ticks=64)
        outs[mode] = eng.serve(None, sessions=[s])["sessions"][0].outputs
    assert_bitwise(outs["dense"], outs["event"])
