"""Same-shape ``LearnSlot`` batching in ``repro.learn.engine``.

The per-slot Python unroll traced a full rule update per plastic
projection — the s16.15 exp-accelerator chain alone is ~50 eqns — and
stalled compilation past a few dozen slots.  The batched engine stacks
same-(kind, rule, shape) groups and advances each with ONE vmapped rule
step, so an extra slot costs only its stack/unstack bookkeeping.  Pinned
here:

* the ≥64-slot compile-time regression gate: the traced step's marginal
  eqn count per extra slot stays far below a rule unroll — this test
  FAILS if per-slot unrolling ever returns;
* grouping is by (kind, rule, shape) in program order;
* batching is semantics-free: a slot advanced inside a 6-slot group
  carries bit-identical weights/traces/dw to the same slot advanced as
  a group of one, and the consolidated ``e_learn`` scatter matches the
  per-slot sum.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.learn import PES, STDP
from repro.learn.engine import (group_slots, init_learn_state,
                                make_learn_step)
from repro.learn.lower import LearnSlot

N_PES = 8


class FakeProgram:
    def __init__(self, slots, n_pes=N_PES):
        self.learn_slots = slots
        self.n_pes = n_pes


def pes_slots(n, n_pre=16, n_post=2):
    rule = PES(learning_rate=1e-4)
    return [LearnSlot(name=f"s{i}", kind="pes", rule=rule, src=f"a{i}",
                      dst=f"b{i}", n_pre=n_pre, n_post=n_post,
                      pe_ids=(i % N_PES,)) for i in range(n)]


def stdp_slots(n, n_pre=12, n_post=4):
    rule = STDP()
    return [LearnSlot(name=f"t{i}", kind="stdp", rule=rule, src=f"a{i}",
                      dst=f"b{i}", n_pre=n_pre, n_post=n_post,
                      pe_ids=(i % N_PES,)) for i in range(n)]


def rec_for(slots, seed=0):
    rng = np.random.default_rng(seed)
    rec = {}
    for s in slots:
        rec[f"learn/{s.name}/pre"] = jnp.asarray(
            (rng.random(s.n_pre) < 0.3).astype(np.float32))
        if s.kind == "pes":
            rec[f"learn/{s.name}/err"] = jnp.asarray(
                rng.standard_normal(s.n_post).astype(np.float32))
        else:
            rec[f"learn/{s.name}/post"] = jnp.asarray(
                (rng.random(s.n_post) < 0.3).astype(np.float32))
    return rec


def traced_eqns(slots):
    prog = FakeProgram(slots)
    step = make_learn_step(prog)
    rec = rec_for(slots)
    jaxpr = jax.make_jaxpr(lambda st: step(st, rec))(init_learn_state(prog))
    return len(jaxpr.jaxpr.eqns)


# ------------------------------------------------ compile-time regression

@pytest.mark.parametrize("mk", [pes_slots, stdp_slots],
                         ids=["pes", "stdp"])
def test_64_slot_group_has_no_per_slot_rule_unroll(mk):
    """Marginal trace cost per extra same-shape slot must stay at
    stack/slice bookkeeping scale (~10-13 eqns measured).  A per-slot
    rule unroll costs >= ~50 eqns/slot (one fx_exp chain each), so the
    20-eqn bound trips long before the old behavior is back."""
    e8, e64 = traced_eqns(mk(8)), traced_eqns(mk(64))
    per_slot = (e64 - e8) / 56
    assert per_slot <= 20, (e8, e64, per_slot)


def test_grouping_by_kind_rule_and_shape_in_program_order():
    a = pes_slots(3)
    b = stdp_slots(2)
    c = pes_slots(2, n_pre=5)                    # different shape
    d = [LearnSlot(name="lr", kind="pes", rule=PES(learning_rate=9e-9),
                   src="x", dst="y", n_pre=16, n_post=2, pe_ids=(0,))]
    groups = group_slots(a + b + c + d)
    names = [[s.name for s in g] for g in groups]
    assert names == [[s.name for s in a], [s.name for s in b],
                     [s.name for s in c], ["lr"]]


# ----------------------------------------------------- bitwise semantics

@pytest.mark.parametrize("mk", [pes_slots, stdp_slots],
                         ids=["pes", "stdp"])
def test_grouped_update_bitwise_matches_singleton_groups(mk):
    slots = mk(6)
    rec = rec_for(slots, seed=3)
    prog = FakeProgram(slots)
    state = init_learn_state(prog)
    full_state, full_upd = make_learn_step(prog)(state, rec)

    e_sum = np.zeros(N_PES, np.float64)
    for s in slots:
        solo = FakeProgram([s])
        s_state, s_upd = make_learn_step(solo)(
            {s.name: state[s.name]}, rec)
        for k in s_state[s.name]:
            np.testing.assert_array_equal(
                np.asarray(full_state[s.name][k]),
                np.asarray(s_state[s.name][k]), err_msg=f"{s.name}/{k}")
        np.testing.assert_array_equal(
            np.asarray(full_upd[f"learn/{s.name}/dw"]),
            np.asarray(s_upd[f"learn/{s.name}/dw"]))
        e_sum += np.asarray(s_upd["e_learn"], np.float64)
    # one consolidated scatter vs per-slot scatters: same energy up to
    # float summation order
    np.testing.assert_allclose(np.asarray(full_upd["e_learn"]), e_sum,
                               rtol=1e-6, atol=0)
