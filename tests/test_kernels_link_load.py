"""link_load kernel triplet: segment-sum ref == dense einsum == column
plan == Pallas prefix-sum kernel, on random CSR incidences."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.chip.mesh_noc import MeshNoc, MeshSpec, SparseIncidence
from repro.kernels.link_load.link_load import (BLOCK_ROWS, LANES,
                                               flat_prefix_sum_pallas)
from repro.kernels.link_load.ops import (link_loads_cols, link_loads_csc,
                                         link_loads_csr)
from repro.kernels.link_load.ref import link_loads_ref

def _random_sinc(rng, n_sources, n_links, max_tree):
    """Random CSR incidence: per source, a sample of distinct link ids."""
    rows = [rng.choice(n_links, rng.integers(0, max_tree + 1),
                       replace=False).astype(np.int32)
            for _ in range(n_sources)]
    return SparseIncidence.from_rows(rows, n_links,
                                     np.zeros(n_sources, np.int32))


@pytest.mark.parametrize("seed,n_sources,n_links,max_tree", [
    (0, 1, 1, 1), (1, 8, 4, 2), (2, 40, 60, 12), (3, 17, 9, 9),
    (4, 33, 50, 1), (5, 5, 64, 30), (6, 64, 8, 8), (7, 25, 25, 0),
])
def test_all_layouts_equal_dense(seed, n_sources, n_links, max_tree):
    rng = np.random.default_rng(seed)
    max_tree = min(max_tree, n_links)
    sinc = _random_sinc(rng, n_sources, n_links, max_tree)
    w = jnp.asarray(rng.integers(0, 1000, n_sources).astype(np.float32))
    dense = np.asarray(w) @ sinc.dense()                 # oracle einsum

    ref = np.asarray(link_loads_ref(w, jnp.asarray(sinc.link_ids),
                                    jnp.asarray(sinc.src_of_entry),
                                    n_links))
    np.testing.assert_array_equal(ref, dense)

    csr = np.asarray(link_loads_csr(w, jnp.asarray(sinc.link_ids),
                                    jnp.asarray(sinc.src_of_entry),
                                    n_links=n_links))
    np.testing.assert_array_equal(csr, dense)

    cols, inv = sinc.device_col_plan()
    got = np.asarray(link_loads_cols(w, cols, inv, n_links=n_links))
    np.testing.assert_array_equal(got, dense)

    src_sorted, link_ptr = sinc.csc
    pal = np.asarray(link_loads_csc(w, jnp.asarray(src_sorted),
                                    jnp.asarray(link_ptr),
                                    n_links=n_links))
    np.testing.assert_array_equal(pal, dense)


def test_batched_layouts_match():
    rng = np.random.default_rng(0)
    sinc = _random_sinc(rng, 20, 30, 6)
    w = jnp.asarray(rng.integers(0, 50, (7, 20)).astype(np.float32))
    ref = np.asarray(link_loads_ref(w, jnp.asarray(sinc.link_ids),
                                    jnp.asarray(sinc.src_of_entry), 30))
    assert ref.shape == (7, 30)
    cols, inv = sinc.device_col_plan()
    got = np.asarray(link_loads_cols(w, cols, inv, n_links=30))
    np.testing.assert_array_equal(got, ref)


def test_prefix_sum_kernel_matches_cumsum():
    rng = np.random.default_rng(1)
    for rows in (BLOCK_ROWS, 3 * BLOCK_ROWS):
        x = rng.integers(0, 100, (rows, LANES)).astype(np.float32)
        got = np.asarray(flat_prefix_sum_pallas(jnp.asarray(x)))
        want = np.cumsum(x.reshape(-1)).reshape(rows, LANES)
        np.testing.assert_array_equal(got, want)


def test_empty_incidence():
    sinc = SparseIncidence(link_ids=np.empty(0, np.int32),
                           source_ptr=np.zeros(5, np.int64), n_links=8,
                           tree_hops=np.zeros(4, np.int32))
    w = jnp.ones(4)
    np_cols, np_inv = sinc.col_plan
    got = np.asarray(link_loads_cols(w, tuple(np_cols),
                                     jnp.asarray(np_inv), n_links=8))
    np.testing.assert_array_equal(got, np.zeros(8))
    src_sorted, link_ptr = sinc.csc
    pal = np.asarray(link_loads_csc(w, jnp.asarray(src_sorted),
                                    jnp.asarray(link_ptr), n_links=8))
    np.testing.assert_array_equal(pal, np.zeros(8))
