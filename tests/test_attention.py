"""Flash (blockwise, custom-VJP) attention vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.models.layers import attention_blockwise, attention_dense


def _mk(rng, B, S, KH, G, D):
    q = jnp.asarray(rng.standard_normal((B, S, KH, G, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("window", [0, 24, 7])
@pytest.mark.parametrize("chunks", [(16, 16), (32, 8), (8, 32)])
def test_forward_matches_dense(window, chunks, rng):
    B, S, KH, G, D = 2, 64, 2, 2, 16
    q, k, v = _mk(rng, B, S, KH, G, D)
    pos = jnp.arange(S)
    ref = attention_dense(q, k, v, pos, pos, window=window)
    out = attention_blockwise(q, k, v, pos, pos, window=window,
                              chunk_q=chunks[0], chunk_kv=chunks[1])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("window", [0, 24])
def test_grads_match_dense(window, rng):
    B, S, KH, G, D = 2, 64, 2, 2, 16
    q, k, v = _mk(rng, B, S, KH, G, D)
    pos = jnp.arange(S)

    def loss_ref(q, k, v):
        o = attention_dense(q, k, v, pos, pos, window=window)
        return jnp.sum(jnp.square(o.astype(jnp.float32)))

    def loss_blk(q, k, v):
        o = attention_blockwise(q, k, v, pos, pos, window=window,
                                chunk_q=16, chunk_kv=16)
        return jnp.sum(jnp.square(o.astype(jnp.float32)))

    g1 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_blk, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=1e-3)


@given(seed=st.integers(0, 5000), gqa=st.sampled_from([(1, 4), (2, 2), (4, 1)]),
       window=st.sampled_from([0, 10]))
def test_property_fwd(seed, gqa, window):
    r = np.random.default_rng(seed)
    KH, G = gqa
    B, S, D = 1, 32, 8
    q = jnp.asarray(r.standard_normal((B, S, KH, G, D)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S, KH, D)), jnp.float32)
    pos = jnp.arange(S)
    ref = attention_dense(q, k, v, pos, pos, window=window)
    out = attention_blockwise(q, k, v, pos, pos, window=window,
                              chunk_q=8, chunk_kv=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=1e-3)


def test_causality():
    """Output at position t must not depend on tokens > t."""
    r = np.random.default_rng(0)
    B, S, KH, G, D = 1, 32, 1, 2, 8
    q = jnp.asarray(r.standard_normal((B, S, KH, G, D)), jnp.float32)
    k = jnp.asarray(r.standard_normal((B, S, KH, D)), jnp.float32)
    v = jnp.asarray(r.standard_normal((B, S, KH, D)), jnp.float32)
    pos = jnp.arange(S)
    base = attention_blockwise(q, k, v, pos, pos, chunk_q=8, chunk_kv=8)
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(-99.0)
    pert = attention_blockwise(q, k2, v2, pos, pos, chunk_q=8, chunk_kv=8)
    np.testing.assert_allclose(np.asarray(base[:, :20]),
                               np.asarray(pert[:, :20]), atol=1e-6)
