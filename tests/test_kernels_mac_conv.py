"""MAC conv2d (CONV fetch mode) vs oracle."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.kernels.mac_conv import mac_conv2d, mac_conv2d_ref

CASES = [
    ((1, 8, 8, 16), (3, 3, 16, 32), (1, 1), "VALID"),
    ((2, 16, 16, 8), (3, 3, 8, 64), (1, 1), "SAME"),
    ((1, 28, 28, 1), (5, 5, 1, 6), (1, 1), "VALID"),       # LeNet C1
    ((1, 14, 14, 64), (1, 1, 64, 128), (1, 1), "VALID"),   # 1x1 bottleneck
    ((1, 16, 16, 16), (3, 3, 16, 32), (2, 2), "SAME"),     # strided
    ((1, 32, 32, 3), (3, 3, 3, 130), (1, 1), "SAME"),      # Cout padding
    ((1, 7, 9, 4), (2, 4, 4, 8), (1, 2), "VALID"),         # odd everything
]


@pytest.mark.parametrize("xs,ws,stride,pad", CASES)
def test_exact_vs_ref(xs, ws, stride, pad, rng):
    x = jnp.asarray(rng.integers(-128, 127, xs), np.int8)
    w = jnp.asarray(rng.integers(-128, 127, ws), np.int8)
    out = mac_conv2d(x, w, stride=stride, padding=pad)
    ref = mac_conv2d_ref(x, w, stride=stride, padding=pad)
    assert out.shape == ref.shape
    assert bool(jnp.all(out == ref))


@pytest.mark.parametrize("dtype", [np.int8, np.uint8])
def test_dtypes(dtype, rng):
    lo, hi = (-128, 127) if dtype == np.int8 else (0, 255)
    x = jnp.asarray(rng.integers(lo, hi, (1, 10, 10, 8)), dtype)
    w = jnp.asarray(rng.integers(lo, hi, (3, 3, 8, 16)), dtype)
    assert bool(jnp.all(mac_conv2d(x, w) == mac_conv2d_ref(x, w)))


@given(h=st.integers(4, 12), w=st.integers(4, 12), cin=st.integers(1, 8),
       cout=st.integers(1, 12), kh=st.integers(1, 3), kw=st.integers(1, 3),
       seed=st.integers(0, 1000))
def test_property_exact(h, w, cin, cout, kh, kw, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.integers(-128, 127, (1, h, w, cin)), np.int8)
    wt = jnp.asarray(r.integers(-128, 127, (kh, kw, cin, cout)), np.int8)
    out = mac_conv2d(x, wt, padding="SAME")
    ref = mac_conv2d_ref(x, wt, padding="SAME")
    assert bool(jnp.all(out == ref))
