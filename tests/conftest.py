import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings
    settings.register_profile(
        "repro", deadline=None, max_examples=25,
        suppress_health_check=[HealthCheck.too_slow,
                               HealthCheck.data_too_large])
    settings.load_profile("repro")
except ImportError:
    pass


@pytest.fixture
def rng():
    return np.random.default_rng(0)
