"""GPipe pipeline over a mesh axis: correctness vs sequential execution.

Multi-stage runs need >1 device, so the real test forces a 4-device host
platform in a subprocess (same pattern as the dry-run integration tests).
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = Path(__file__).resolve().parents[1]


def test_single_stage_identity():
    from repro.dist.pipeline import pipeline_forward
    mesh = jax.make_mesh((1,), ("pod",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    w = jnp.asarray(np.random.default_rng(0).standard_normal((1, 4, 4)),
                    jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((3, 2, 4)),
                    jnp.float32)
    out = pipeline_forward(lambda p, x: x @ p, w, x, mesh, axis="pod")
    ref = jnp.einsum("nbd,de->nbe", x, w[0])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.dist.pipeline import pipeline_forward

mesh = jax.make_mesh((4,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
W = jnp.asarray(rng.standard_normal((4, 8, 8)) * 0.5, jnp.float32)
x = jnp.asarray(rng.standard_normal((6, 3, 8)), jnp.float32)   # 6 microbatches

def stage(p, x):
    return jnp.tanh(x @ p)

out = pipeline_forward(stage, W, x, mesh, axis="pod")

ref = x
for s in range(4):
    ref = jnp.tanh(jnp.einsum("nbd,de->nbe", ref, W[s]))
err = float(jnp.max(jnp.abs(out - ref)))
print("ERR", err)
assert err < 1e-5, err
print("OK")
"""


@pytest.mark.slow
def test_four_stage_pipeline_subprocess():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
