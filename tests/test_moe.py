"""MoE dispatch: sort-based and shard_map EP variants vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import moe as MOE
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_arch("olmoe-1b-7b").smoke()   # 4 experts, top-2
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    p = jax.tree.map(lambda x: x[0], params["blocks"][0]["mlp"])
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 16, cfg.d_model)),
                    jnp.float32)
    return cfg, p, x


def test_sort_dispatch_matches_dense(setup):
    cfg, p, x = setup
    y1, a1 = MOE.moe_apply_dense(cfg, p, x)
    y2, a2 = MOE.moe_apply(cfg, p, x, capacity_factor=64.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    np.testing.assert_allclose(float(a1["lb_loss"]), float(a2["lb_loss"]),
                               rtol=1e-5)


def test_sharded_dispatch_matches_dense(setup):
    cfg, p, x = setup
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    y1, _ = MOE.moe_apply_dense(cfg, p, x)
    y2, _ = MOE.moe_apply_sharded(cfg, p, x, mesh, capacity_factor=64.0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


def test_capacity_drops_are_bounded(setup):
    cfg, p, x = setup
    y, _ = MOE.moe_apply(cfg, p, x, capacity_factor=1.0)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens pass through with zero expert contribution; the output
    # norm must stay below the no-drop output norm plus tolerance
    y_full, _ = MOE.moe_apply(cfg, p, x, capacity_factor=64.0)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) * 1.5


def test_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives lb_loss == 1 (Switch normalization)."""
    T_, E = 64, 4
    probs = jnp.full((T_, E), 1.0 / E)
    sel = jnp.zeros((T_, E)).at[jnp.arange(T_), jnp.arange(T_) % E].set(1.0)
    lb = MOE.aux_losses(probs, sel)
    np.testing.assert_allclose(float(lb), 1.0, rtol=1e-5)


def test_grads_flow_through_dispatch(setup):
    cfg, p, x = setup

    def loss(p):
        y, aux = MOE.moe_apply(cfg, p, x, capacity_factor=2.0)
        return jnp.sum(jnp.square(y)) + aux["lb_loss"]

    g = jax.grad(loss)(p)
    norms = [float(jnp.linalg.norm(v.astype(jnp.float32)))
             for v in jax.tree.leaves(g)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0
