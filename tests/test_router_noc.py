"""NoC / router model properties."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core.noc import NocModel, hops, multicast_links, xy_route
from repro.core.router import RoutingTable, multicast_exchange, ring_exchange

coord = st.tuples(st.integers(0, 7), st.integers(0, 7))


@given(src=coord, dst=coord)
def test_xy_route_length_is_manhattan(src, dst):
    assert len(xy_route(src, dst)) == hops(src, dst)


@given(src=coord, dsts=st.lists(coord, min_size=1, max_size=6, unique=True))
def test_multicast_tree_never_worse_than_unicast(src, dsts):
    tree = multicast_links(src, dsts)
    uni = sum(hops(src, d) for d in dsts)
    assert tree <= uni
    assert tree >= max(hops(src, d) for d in dsts)


def test_multicast_sharing_on_common_prefix():
    # two destinations in the same row share the X leg
    src, d1, d2 = (0, 0), (3, 1), (3, 2)
    assert multicast_links(src, [d1, d2]) < hops(src, d1) + hops(src, d2)


def test_packet_latency_matches_spec():
    m = NocModel()
    # 3 hops x 5 cycles @ 400 MHz
    np.testing.assert_allclose(m.packet_latency_s((0, 0), (2, 1)),
                               3 * 5 / 400e6)


def test_collective_link_bytes_formulas():
    m = NocModel()
    assert m.collective_link_bytes("all-reduce", 100, 4) == 150.0
    assert m.collective_link_bytes("all-gather", 100, 4) == 75.0
    assert m.collective_link_bytes("collective-permute", 100, 4) == 100.0


def test_ring_exchange_local():
    s = jnp.arange(12).reshape(4, 3)
    out = ring_exchange(s)
    assert bool(jnp.all(out[1] == s[0])) and bool(jnp.all(out[0] == s[3]))


def test_multicast_exchange_dense():
    spk = jnp.asarray(np.random.default_rng(0).integers(0, 2, (4, 5)),
                      jnp.int32)
    arr = multicast_exchange(spk, RoutingTable.ring(4))
    # PE 1 hears exactly PE 0's spikes; nothing else
    assert bool(jnp.all(arr[1, 0] == spk[0]))
    mask = jnp.ones(4, bool).at[0].set(False)
    assert bool(jnp.all(arr[1][mask] == 0))


def test_routing_table_fanout():
    t = RoutingTable.ring(8)
    assert np.all(t.fan_out() == 1)
