"""Hypothesis property suite for the board partitioner + hierarchical
router: for ARBITRARY random ``NetGraph``s on ARBITRARY board shapes,

* per-chip PE-slot capacity (the per-chip SRAM budget) is never exceeded
  and every tile's state fits the 128 kB PE SRAM,
* every projection is routed — each source's stitched link set walks to
  every destination PE across however many chips the partition spread
  them over, entering each chip on exactly ONE chip-to-chip link (so
  flits are conserved across chip boundaries: multicast duplicates at
  branch points, never rejoins),
* the board-wide sparse accounting is bitwise the dense einsum's, and
  the tier split sums exactly.
"""
import numpy as np
import pytest

from test_sparse_noc import random_graph

pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st

import jax.numpy as jnp

from repro.board import BoardSpec, compile_board, partition
from repro.chip.mapping import assign_slots
from repro.chip.mesh_noc import MeshSpec
from repro.core.pe import PESpec


def random_board(rng) -> BoardSpec:
    return BoardSpec(int(rng.integers(1, 4)), int(rng.integers(1, 3)),
                     chip=MeshSpec(int(rng.integers(1, 4)),
                                   int(rng.integers(1, 3))))


def compiled(graph_seed, board_seed):
    rng = np.random.default_rng(graph_seed)
    graph = random_graph(rng)
    board = random_board(np.random.default_rng(board_seed))
    try:
        return compile_board(graph, board), board
    except ValueError:
        assume(False)                    # graph does not fit this board


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
def test_partition_never_exceeds_chip_capacity(graph_seed, board_seed):
    prog, board = compiled(graph_seed, board_seed)
    part = prog.part
    pe = PESpec()
    for pops, used in zip(part.chip_pops, part.slots_used):
        assert used == assign_slots(pops, board.chip.pes_per_qpe)[1]
        assert used <= board.chip.n_pes
        for pop in pops:
            assert pop.sram_bytes <= pe.sram_bytes
    # every population assigned exactly once, tiles contiguous per chip
    names = [p.name for pops in part.chip_pops for p in pops]
    assert sorted(names) == sorted(p.name for p in prog.graph.populations)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
def test_every_projection_routed_and_boundary_conserving(graph_seed,
                                                         board_seed):
    prog, board = compiled(graph_seed, board_seed)
    noc = prog.noc
    for p in range(prog.n_pes):
        a, b = prog.sinc.source_ptr[p], prog.sinc.source_ptr[p + 1]
        ids = prog.sinc.link_ids[a:b]
        assert len(set(ids.tolist())) == len(ids)      # tree: links distinct
        links = [noc.link_endpoints(int(l)) for l in ids]
        # chip-boundary conservation: each non-source chip is entered on
        # exactly one chip-to-chip link — a packet's flits arrive once
        entries: dict = {}
        for l in ids:
            if l >= noc.n_onchip_links:
                (c0, _), (c1, _) = noc.link_endpoints(int(l))
                entries[c1] = entries.get(c1, 0) + 1
        assert all(v == 1 for v in entries.values()), entries
        assert int(prog.tree_links_x[p]) == len(entries)
        # connectivity: the stitched tree reaches every destination PE
        reach = {(int(prog.chip_of_pe[p]), tuple(prog.coords_local[p]))}
        grew = True
        while grew:
            grew = False
            for (c0, u), (c1, v) in links:
                if (c0, tuple(u)) in reach and (c1, tuple(v)) not in reach:
                    reach.add((c1, tuple(v)))
                    grew = True
        for q in np.flatnonzero(prog.table.masks[p]):
            assert (int(prog.chip_of_pe[q]),
                    tuple(prog.coords_local[q])) in reach, (p, q)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1),
       st.integers(0, 2**31 - 1))
def test_board_sparse_bitwise_equals_dense_and_tier_split(graph_seed,
                                                          board_seed,
                                                          packet_seed):
    prog, board = compiled(graph_seed, board_seed)
    noc = prog.noc
    rng = np.random.default_rng(packet_seed)
    packets = jnp.asarray(rng.integers(0, 200, prog.n_pes)
                          .astype(np.float32))
    pb = jnp.asarray(prog.payload_bits)
    dense_ll = np.asarray(noc.link_loads(packets, prog.inc))
    dense_fl = np.asarray(noc.flit_loads(packets, prog.inc, pb))
    for impl in ("column_plan", "pallas"):
        ll, fl = noc.noc_loads(packets, noc.device_plan(prog.sinc, impl),
                               pb)
        np.testing.assert_array_equal(np.asarray(ll), dense_ll, err_msg=impl)
        np.testing.assert_array_equal(np.asarray(fl), dense_fl, err_msg=impl)
    # tree_links bookkeeping: CSR row lengths == dense row sums, split
    # into tiers by the xlink mask
    np.testing.assert_array_equal(prog.sinc.tree_links,
                                  prog.inc.sum(axis=1))
    xmask = np.asarray(noc.xlink_mask)
    np.testing.assert_array_equal(prog.tree_links_x,
                                  (prog.inc * xmask).sum(axis=1))
    # tiered energy == hand-priced tiers (f64 reference)
    e = np.asarray(noc.traffic_energy_j(
        packets, jnp.asarray(prog.energy_tree_links, jnp.float32), pb),
        np.float64)
    pbits = np.asarray(noc.packet_bits(pb), np.float64)
    pk = np.asarray(packets, np.float64)
    on = (pk * (prog.sinc.tree_links - prog.tree_links_x) * pbits).sum()
    xc = (pk * prog.tree_links_x * pbits).sum()
    ref = (on * noc.spec.pj_per_bit_hop
           + xc * noc.xspec.pj_per_bit_hop) * 1e-12
    np.testing.assert_allclose(float(e), ref, rtol=1e-5)
