"""ChipSim acceptance: 8-PE ring reproduces the seed single-chip results
bit for bit; a 64-PE mesh runs the same workload with per-link load and
DVFS power reported."""
import numpy as np
import pytest

from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.compile import compile as compile_graph
from repro.chip.workloads import (hybrid_workload, synfire_graph,
                                  tiled_dnn_workload)
from repro.core.snn import build_synfire, simulate_synfire


@pytest.fixture(scope="module")
def chip8():
    sim = ChipSim(compile_graph(synfire_graph(8)))
    return sim, sim.run(1200)


@pytest.fixture(scope="module")
def chip64():
    sim = ChipSim(compile_graph(synfire_graph(64)))
    return sim, sim.run(700)


def test_chip8_reproduces_seed_rasters(chip8):
    sim, recs = chip8
    ref = simulate_synfire(build_synfire(0), 300)
    got = {k: np.asarray(v)[:300] for k, v in recs.items()}
    for k in ("spikes_exc", "spikes_inh", "pl", "n_fifo", "syn_events"):
        assert np.array_equal(got[k], np.asarray(ref[k])), k


def test_chip8_table_iii_within_tolerance(chip8):
    """Same acceptance band as the single-chip test (paper Table III)."""
    sim, recs = chip8
    tab = chip_power_table(sim, recs)
    per_pe = tab["per_pe"]
    assert abs(per_pe["pl3"]["baseline"] - 66.44) < 0.1
    assert abs(per_pe["dvfs"]["baseline"] - 24.3) < 3.0
    assert 0.52 <= per_pe["reduction"]["total"] <= 0.72
    # chip totals are per-PE x 8
    np.testing.assert_allclose(tab["chip"]["dvfs"]["total"],
                               per_pe["dvfs"]["total"] * 8)


def test_chip8_wave_and_link_loads(chip8):
    sim, recs = chip8
    spk = np.asarray(recs["spikes_exc"]).sum(axis=2)
    for p in range(8):
        waves = np.where(spk[:, p] > 100)[0]
        assert len(waves) >= 5, f"PE{p} wave died"
        assert np.all(np.abs(np.diff(waves[:5]) - 80) <= 2)
    loads = np.asarray(recs["link_load"])            # (T, 2)
    assert loads.shape[1] == sim.noc.n_links == 2
    # the wave crosses the inter-QPE links once per 80-tick period
    assert loads.max() > 100


def test_chip64_runs_and_reports(chip64):
    sim, recs = chip64
    assert sim.program.n_pes == 64
    assert (sim.program.mesh.width, sim.program.mesh.height) == (4, 4)
    spk = np.asarray(recs["spikes_exc"]).sum(axis=2)
    # wave traverses the whole ring: PE63 fires strongly at ~t=630
    w63 = np.where(spk[:, 63] > 100)[0]
    assert len(w63) >= 1 and abs(w63[0] - 630) <= 5
    # and returns to PE0 (period 640)
    w0 = np.where(spk[:, 0] > 100)[0]
    assert len(w0) >= 2 and abs(w0[1] - 640) <= 5

    tab = chip_power_table(sim, recs)
    assert tab["n_pes"] == 64
    # per-PE DVFS power stays in the single-chip band at 8x scale
    assert abs(tab["per_pe"]["dvfs"]["baseline"] - 24.3) < 3.0
    # link loads observed on the mesh, utilization far below capacity
    assert tab["noc"]["peak_link_load"] > 100
    assert 0 < tab["noc"]["peak_utilization"] < 0.1
    assert tab["noc"]["worst_tree_hops"] >= 2
    loads = np.asarray(recs["link_load"])
    assert loads.shape == (700, sim.noc.n_links)
    # only links on some ring edge ever carry traffic
    used = loads.sum(axis=0) > 0
    on_tree = np.asarray(sim.program.inc).sum(axis=0) > 0
    assert np.array_equal(used, used & on_tree)


def test_chip_dvfs_tracks_wave(chip64):
    """DVFS: the PE processing the wave runs at PL3 that tick, idles at
    PL1 otherwise — activity-driven power at chip scale."""
    sim, recs = chip64
    pl = np.asarray(recs["pl"])
    spk = np.asarray(recs["spikes_exc"]).sum(axis=2)
    t = 320                                            # wave at PE32
    assert spk[t, 32] > 100
    assert pl[t + 10, 33] == 2                         # FIFO full -> PL3
    frac_pl1 = (pl == 0).mean()
    assert frac_pl1 > 0.9


def test_tiled_dnn_workload_runs_on_mesh():
    """The DNN program executes tick-by-tick on ChipSim (no analytic
    shortcut): frames stream through the pipeline, graded activation
    bursts hit real links, DVFS power is reported per tick."""
    rep = tiled_dnn_workload()
    assert rep["n_pes_used"] >= 4
    assert rep["latency_s"] > 0 and rep["compute_s"] > 0
    assert rep["energy_mac_j"] > 0 and rep["energy_noc_j"] > 0
    assert rep["link_loads"].shape[0] > 0
    # per-layer latency sums to the compute total
    total = sum(l["layer_latency_s"] for l in rep["layers"])
    np.testing.assert_allclose(total, rep["compute_s"], rtol=1e-9)
    # tick-by-tick execution: every injected frame leaves the last layer
    assert rep["n_frames_out"] == 4
    # graded multi-flit packets weigh more than their packet count
    assert rep["peak_link_flits"] > rep["peak_link_load"]
    # DVFS power table is produced from the per-tick records
    assert rep["table"]["per_pe"]["dvfs"]["total"] > 0
    assert rep["table"]["per_pe"]["dvfs"]["total"] < \
        rep["table"]["per_pe"]["pl3"]["total"]
    # the pipeline is idle most ticks -> DVFS saves baseline power
    busy = np.asarray(rep["recs"]["busy"])
    assert 0 < busy.mean() < 0.5


def test_hybrid_workload_event_energy():
    h = hybrid_workload(n_ticks=400)
    assert h["rmse"] < 0.25                            # channel tracks input
    # event-triggered MAC energy ~ firing rate << frame-based
    assert h["event_vs_frame"] < 0.3
    assert h["energy_mac_j"] < h["energy_mac_frame_j"]
    assert h["energy_noc_j"] > 0
    assert h["synops"]["pj_per_eq_synop"] < 30.0       # beats Loihi's 24
    # tick-by-tick on the mesh: per-link graded traffic + DVFS PLs recorded
    assert h["link_loads"].shape[0] == 400
    assert np.asarray(h["recs"]["pl"]).shape == (400, h["sim"].program.n_pes)
