"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finiteness.  Exercises every assigned architecture family.
"""
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import registry as R
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_forward_and_train_step(arch, key):
    cfg = configs.get_arch(arch).smoke()
    params = T.init_params(cfg, key)
    batch = R.make_dummy_batch(cfg, "train", 2, 32)
    loss, metrics = T.train_loss(cfg, params, batch, moe_dense=True,
                                 remat="none", ce_chunk=16)
    assert jnp.isfinite(loss), arch
    assert metrics["ce"].shape == ()

    step = make_train_step(cfg, opt=AdamWConfig(lr=1e-3), moe_dense=True,
                           ce_chunk=16)
    opt = adamw_init(params)
    p2, o2, m2 = step(params, opt, batch, jnp.int32(0))
    assert jnp.isfinite(m2["loss"]) and jnp.isfinite(m2["grad_norm"])
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.any(a != b), params, p2))
    assert any(bool(x) for x in moved), arch


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_prefill_logits_shape(arch, key):
    cfg = configs.get_arch(arch).smoke()
    params = T.init_params(cfg, key)
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    batch = R.make_dummy_batch(cfg, "prefill", 2, 16)
    logits, caches = T.prefill(cfg, params, batch, 32, moe_dense=True)
    if cfg.num_codebooks > 1:
        assert logits.shape == (2, 1, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    assert caches is not None


@pytest.mark.parametrize("arch", ["phi3.5-moe-42b-a6.6b", "gemma3-27b",
                                  "rwkv6-1.6b", "recurrentgemma-2b"])
def test_param_count_matches_analytic(arch, key):
    """Analytic count tracks actual params (small bias/LoRA terms aside)."""
    cfg = configs.get_arch(arch).smoke()
    params = T.init_params(cfg, key)
    n = sum(x.size for x in jax.tree.leaves(params))
    assert abs(n - cfg.param_count()) / n < 0.03, (arch, n, cfg.param_count())


def test_full_configs_match_published_sizes():
    expect = {
        "phi3.5-moe-42b-a6.6b": (41.9e9, 6.6e9),
        "olmoe-1b-7b": (6.9e9, 1.3e9),
        "gemma3-27b": (27.0e9, 27.0e9),
        "glm4-9b": (9.4e9, 9.4e9),
        "nemotron-4-15b": (15.6e9, 15.6e9),
        "qwen1.5-4b": (4.0e9, 4.0e9),
        "chameleon-34b": (34.3e9, 34.3e9),
        "rwkv6-1.6b": (1.6e9, 1.6e9),
        "musicgen-large": (2.4e9, 2.4e9),
        "recurrentgemma-2b": (2.9e9, 2.9e9),
    }
    for name, (total, active) in expect.items():
        cfg = configs.get_arch(name)
        assert abs(cfg.param_count() - total) / total < 0.05, name
        assert abs(cfg.active_param_count() - active) / active < 0.07, name
