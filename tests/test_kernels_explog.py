"""Fixed-point exp/log accelerator kernels: bit-exactness, accuracy,
monotonicity, algebraic properties."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.kernels.explog import (
    FX_ONE, fx_exp, fx_exp_ref, fx_log, fx_log_ref,
)
from repro.kernels.explog.ops import from_fx, to_fx


def test_exp_bit_exact(rng):
    x = to_fx(rng.uniform(-12, 10.5, 8192))
    assert bool(jnp.all(fx_exp(x, impl="pallas") == fx_exp_ref(x)))


def test_log_bit_exact(rng):
    x = to_fx(rng.uniform(1e-3, 6e4, 8192))
    assert bool(jnp.all(fx_log(x, impl="pallas") == fx_log_ref(x)))


def test_impl_knob(rng):
    """"auto" resolves to the reference path, "pallas" to the kernel —
    bitwise identical either way; typos fail loudly."""
    x = to_fx(rng.uniform(-6, 6, 512))
    assert bool(jnp.all(fx_exp(x) == fx_exp(x, impl="pallas")))
    y = to_fx(rng.uniform(1e-2, 100, 512))
    assert bool(jnp.all(fx_log(y) == fx_log(y, impl="pallas")))
    with pytest.raises(ValueError, match="unknown explog impl"):
        fx_exp(x, impl="fastest")


def test_exp_accuracy(rng):
    xf = rng.uniform(-10, 10, 4096)
    out = from_fx(fx_exp(to_fx(xf)))
    e = np.exp(xf)
    assert np.all(np.abs(out - e) <= 2 / FX_ONE + e * 2.0**-11)


def test_log_accuracy(rng):
    xf = rng.uniform(1e-2, 6e4, 4096)
    out = from_fx(fx_log(to_fx(xf)))
    assert np.max(np.abs(out - np.log(np.round(xf * FX_ONE) / FX_ONE))) < 3e-4


def test_exp_monotone():
    xs = to_fx(np.linspace(-6, 6, 4001))
    ys = np.asarray(fx_exp(xs))
    assert np.all(np.diff(ys) >= 0)


def test_log_negative_flagged():
    x = jnp.asarray([-5, 0, 1, FX_ONE], jnp.int32)
    out = np.asarray(fx_log(x))
    assert out[0] < -(2**29) and out[1] < -(2**29)
    assert abs(out[3]) <= 1          # ln(1) = 0


@given(a=st.floats(-4, 4), b=st.floats(-4, 4))
def test_exp_add_property(a, b):
    """exp(a+b) ~ exp(a)exp(b) within fixed-point tolerance."""
    ea = float(from_fx(fx_exp(to_fx(np.float32(a))[None]))[0])
    eb = float(from_fx(fx_exp(to_fx(np.float32(b))[None]))[0])
    eab = float(from_fx(fx_exp(to_fx(np.float32(a + b))[None]))[0])
    ref = np.exp(a + b)
    assert abs(eab - ea * eb) <= 0.01 * max(ref, 1.0) + 4 / FX_ONE


@given(x=st.floats(0.01, 1000.0))
def test_log_exp_roundtrip(x):
    lx = fx_log(to_fx(np.float32(x))[None])
    back = float(from_fx(fx_exp(lx))[0])
    assert abs(back - x) <= 0.01 * x + 4 / FX_ONE
