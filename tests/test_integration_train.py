"""Integration: loss decreases on structured data; serve engine runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import PipelineConfig, SyntheticTokenPipeline
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.serve.engine import Request, ServeEngine
from repro.train.step import make_train_step


@pytest.mark.slow
def test_loss_decreases_tiny_lm():
    cfg = configs.get_arch("qwen1.5-4b").smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    pipe = SyntheticTokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=1))
    step = jax.jit(make_train_step(
        cfg, opt=AdamWConfig(lr=3e-3), ce_chunk=32, moe_dense=True,
        total_steps=120, warmup_steps=10), donate_argnums=(0, 1))
    losses = []
    for s in range(120):
        params, opt, m = step(params, opt, pipe.batch(s), jnp.int32(s))
        losses.append(float(m["loss"]))
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.15, (first, last)


def test_microbatching_matches_full_batch():
    """Gradient accumulation must give the same update as the full batch."""
    cfg = configs.get_arch("glm4-9b").smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    pipe = SyntheticTokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=8))
    batch = pipe.batch(0)
    s1 = make_train_step(cfg, microbatch=1, ce_chunk=16, remat="none")
    s2 = make_train_step(cfg, microbatch=4, ce_chunk=16, remat="none")
    p1, _, m1 = s1(params, opt, batch, jnp.int32(0))
    p2, _, m2 = s2(params, opt, batch, jnp.int32(0))
    # loss metric averages match; params match to accumulation tolerance
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(errs)) < 5e-4


def test_serve_engine_queue_dvfs():
    cfg = configs.get_arch("qwen1.5-4b").smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    eng = ServeEngine(cfg, params, max_seq=48)
    rng = np.random.default_rng(0)
    for i in range(7):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, 8,
                                               dtype=np.int32),
                           max_new_tokens=4))
    stats = eng.run()
    assert stats["tokens"] >= 7 * 3
    # queue depth 7 -> widest level (>= threshold 6) = 8 first
    assert stats["batch_hist"][0] == 7
