"""MAC GEMM kernel vs pure-jnp oracle: shape/dtype sweep + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.kernels.mac_gemm import (
    mac_gemm, mac_gemm_dequant, mac_gemm_dequant_ref, mac_gemm_ref,
)

SHAPES = [(128, 128, 128), (256, 384, 128), (100, 200, 60), (1, 128, 1),
          (257, 129, 300), (64, 512, 192)]


@pytest.mark.parametrize("dtype", [np.int8, np.uint8])
@pytest.mark.parametrize("m,k,n", SHAPES)
def test_exact_vs_ref(m, k, n, dtype, rng):
    lo, hi = (-128, 127) if dtype == np.int8 else (0, 255)
    a = jnp.asarray(rng.integers(lo, hi, (m, k)), dtype)
    b = jnp.asarray(rng.integers(lo, hi, (k, n)), dtype)
    assert bool(jnp.all(mac_gemm(a, b) == mac_gemm_ref(a, b)))


@given(m=st.integers(1, 64), k=st.integers(1, 64), n=st.integers(1, 64),
       seed=st.integers(0, 2**16))
def test_property_exact(m, k, n, seed):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.integers(-128, 127, (m, k)), np.int8)
    b = jnp.asarray(r.integers(-128, 127, (k, n)), np.int8)
    out = mac_gemm(a, b, bm=32, bn=32, bk=32)
    assert bool(jnp.all(out == mac_gemm_ref(a, b)))


def test_accumulator_no_overflow_path(rng):
    # worst case: K=2048 of extreme values stays exact in int32
    a = jnp.full((8, 2048), -128, jnp.int8)
    b = jnp.full((2048, 8), -128, jnp.int8)
    out = mac_gemm(a, b)
    assert int(out[0, 0]) == 128 * 128 * 2048


def test_dequant_matches_ref(rng):
    a = jnp.asarray(rng.integers(-128, 127, (33, 65)), np.int8)
    b = jnp.asarray(rng.integers(-128, 127, (65, 17)), np.int8)
    sa = jnp.asarray(rng.uniform(0.001, 0.1, 33), jnp.float32)
    sb = jnp.asarray(rng.uniform(0.001, 0.1, 17), jnp.float32)
    out = mac_gemm_dequant(a, b, sa, sb)
    ref = mac_gemm_dequant_ref(a, b, sa, sb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)
