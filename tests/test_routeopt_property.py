"""Hypothesis property suite for profile-guided routing: for ARBITRARY
random graphs, board shapes, orientations and border-port assignments,

* every compiled program's stitched rows are trees that cover every
  routing-table destination (``check_delivery`` — in-degree <= 1, so
  each destination receives each packet EXACTLY once);
* the delivery signature — per source, (destination node set, flits
  per packet) — is invariant under the routing config, i.e. flits are
  conserved per (source, destination-set) exactly;
* on a runnable workload, neuron-state records are bitwise identical
  under any routing config (packets ride the masks; incidence only
  prices links).

The deterministic twin for the hypothesis-less CI image lives in
tests/test_routeopt.py.
"""
import numpy as np
import pytest

from test_sparse_noc import random_graph

pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st

from repro.board import BoardSpec, compile_board
from repro.board.spec import DIRS
from repro.chip.chip import ChipSim
from repro.chip.mesh_noc import MeshSpec
from repro.chip.workloads import synfire_graph
from repro.core.noc import ORIENTATIONS
from repro.routeopt import RouteConfig, check_delivery

from test_routeopt import assert_neuron_identical


def random_route(rng, graph, board) -> RouteConfig:
    pops = [p.name for p in graph.populations]
    k = board.ports_per_edge
    return RouteConfig(
        tree_orient={p: ORIENTATIONS[rng.integers(2)] for p in pops},
        chip_orient={p: ORIENTATIONS[rng.integers(2)] for p in pops},
        ports={(p, c, d): int(rng.integers(k))
               for p in pops for c in range(board.n_chips) for d in DIRS})


def random_multiport_board(rng) -> BoardSpec:
    chip = MeshSpec(int(rng.integers(2, 5)), int(rng.integers(2, 4)))
    return BoardSpec(int(rng.integers(1, 4)), int(rng.integers(1, 3)),
                     chip=chip,
                     ports_per_edge=int(rng.integers(
                         1, min(chip.width, chip.height) + 1)))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2**31 - 1))
def test_delivery_signature_invariant_under_routing(graph_seed, cfg_seed):
    rng = np.random.default_rng(graph_seed)
    graph = random_graph(rng)
    board = random_multiport_board(np.random.default_rng(cfg_seed))
    try:
        base = compile_board(graph, board)
    except ValueError:
        assume(False)                    # graph does not fit this board
    route = random_route(np.random.default_rng(cfg_seed), graph, board)
    prog = compile_board(graph, board, route=route)
    assert check_delivery(prog) == check_delivery(base)
    # same multicast reach, possibly different link footprint
    np.testing.assert_array_equal(prog.table.masks, base.table.masks)
    np.testing.assert_array_equal(prog.payload_bits, base.payload_bits)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_neuron_records_bitwise_invariant(cfg_seed):
    board = BoardSpec(2, 2, chip=MeshSpec(2, 2), ports_per_edge=2)
    graph = synfire_graph(n_pes=board.n_pes)
    base = compile_board(synfire_graph(n_pes=board.n_pes), board)
    route = random_route(np.random.default_rng(cfg_seed), graph, board)
    prog = compile_board(graph, board, route=route)
    assert check_delivery(prog) == check_delivery(base)
    assert_neuron_identical(ChipSim(prog).run(10, seed=2),
                            ChipSim(base).run(10, seed=2))
