"""Checkpointing + fault-tolerance behaviors."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import PipelineConfig, SyntheticTokenPipeline
from repro.ft.loop import FaultTolerantLoop, LoopConfig
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def _tiny_setup(tmp_path, steps=30, ckpt_every=10):
    cfg = configs.get_arch("qwen1.5-4b").smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    pipe = SyntheticTokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
    step = jax.jit(make_train_step(cfg, opt=AdamWConfig(lr=1e-3),
                                   ce_chunk=16, moe_dense=True))
    ckpt = CheckpointManager(tmp_path / "ckpt", keep=2, async_save=False)
    loop = FaultTolerantLoop(
        LoopConfig(total_steps=steps, ckpt_every=ckpt_every), ckpt, step, pipe)
    return cfg, params, opt, pipe, step, ckpt, loop


def test_roundtrip_identity(tmp_path):
    cfg = configs.get_arch("rwkv6-1.6b").smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(7, {"params": params}, meta={"note": "x"})
    restored, manifest = m.restore({"params": params})
    assert manifest["step"] == 7
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        assert a.dtype == b.dtype and bool(jnp.all(a == b))


def test_atomic_publish_never_partial(tmp_path):
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(1, {"x": jnp.arange(5)})
    # a later tmp dir (simulated crash mid-save) must not be visible
    (tmp_path / "step_00000002.tmp").mkdir()
    assert m.latest_step() == 1
    t, _ = m.restore({"x": jnp.zeros(5, jnp.int32)})
    assert bool(jnp.all(t["x"] == jnp.arange(5)))


def test_gc_keeps_last_n(tmp_path):
    m = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        m.save(s, {"x": jnp.ones(2) * s})
    assert sorted(m.all_steps()) == [3, 4]


def test_resume_is_deterministic(tmp_path):
    """Train 30 straight vs train 30 with a restart at 20: identical params
    (checkpoint + seekable data => exact resume)."""
    cfg, params, opt, pipe, step, _, _ = _tiny_setup(tmp_path)

    def run(p, o, lo, hi):
        for s in range(lo, hi):
            p, o, _ = step(p, o, pipe.batch(s), jnp.int32(s))
        return p, o

    pA, oA = run(params, opt, 0, 30)

    pB, oB = run(params, opt, 0, 20)
    m = CheckpointManager(tmp_path / "c2", async_save=False)
    m.save(19, {"params": pB, "opt": oB})
    restored, man = m.restore({"params": pB, "opt": oB})
    pC, oC = run(restored["params"], restored["opt"], man["step"] + 1, 30)

    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pC)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retry_on_injected_failure(tmp_path):
    cfg, params, opt, pipe, step, ckpt, loop = _tiny_setup(
        tmp_path, steps=25, ckpt_every=5)
    fails = {12}

    def injector(s):
        if s in fails:
            fails.discard(s)
            return True
        return False

    state, log = loop.run(params, opt, fail_injector=injector)
    assert log[-1]["step"] == 24
    assert all(np.isfinite(r["loss"]) for r in log)


def test_elastic_restore_new_mesh(tmp_path):
    """Save unsharded, restore with explicit shardings on a (1,1) mesh —
    the elastic-rescale path (mesh shape independent of the saved one)."""
    pytest.importorskip("repro.dist.cells")
    cfg = configs.get_arch("glm4-9b").smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(3, {"params": params})

    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    from repro.dist.cells import _param_shardings
    from repro.dist import sharding as SH
    shards = _param_shardings(cfg, mesh, SH.PARAM_RULES)
    restored, _ = m.restore({"params": params},
                            shardings={"params": shards})
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        assert bool(jnp.all(a == b))


def test_straggler_detection(tmp_path):
    cfg, params, opt, pipe, step, ckpt, _ = _tiny_setup(tmp_path, steps=15)
    seen = []
    import time

    def slow_step(p, o, b, s):
        if int(s) == 10:
            time.sleep(0.5)
        return step(p, o, b, s)

    loop = FaultTolerantLoop(
        LoopConfig(total_steps=15, ckpt_every=100, straggler_factor=3.0),
        ckpt, slow_step, pipe,
        on_straggler=lambda s, dt, ema: seen.append(s))
    loop.run(params, opt)
    assert 10 in seen


def test_engine_scan_carry_roundtrip_bitwise(tmp_path):
    """The neuromorphic engine's scan carry (LIF + plant + LEARN state)
    saved mid-run, restored into a fresh tree, and continued must be
    bitwise identical to the uninterrupted run — the property the
    serving tier's session checkpoint/restore is built on."""
    from repro.chip.chip import ChipSim
    from repro.chip.compile import compile as compile_graph
    from repro.learn.adaptive import adaptive_control_graph

    g = adaptive_control_graph(n_channels=2, n_neurons=24, n_ticks=64)
    init, tick = ChipSim(compile_graph(g)).make_stepper()

    def run(st, t0, n):
        return jax.lax.scan(tick, st, t0 + jnp.arange(n))
    runj = jax.jit(run, static_argnums=2)

    ref_st, ref_recs = runj(init, 0, 32)

    st16, recs_a = runj(init, 0, 16)
    assert "learn" in st16                      # the plastic subtree rides
    m = CheckpointManager(tmp_path, async_save=False)
    m.save(16, st16, meta={"ticks_done": 16})
    restored, manifest = m.restore(st16)
    assert manifest["step"] == 16
    assert manifest["meta"]["ticks_done"] == 16
    st32, recs_b = runj(restored, 16, 16)

    for a, b in zip(jax.tree.leaves(ref_st), jax.tree.leaves(st32)):
        assert a.dtype == b.dtype and bool(jnp.all(a == b))
    for k in ("u", "track_err", "dec_norm", "n_spk"):
        full = np.concatenate([np.asarray(recs_a[k]), np.asarray(recs_b[k])])
        np.testing.assert_array_equal(full, np.asarray(ref_recs[k]))
