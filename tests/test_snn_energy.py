"""Synfire chain + DVFS energy model: reproduces the paper's Table III and
Fig. 17/18 behavior."""
import numpy as np
import pytest

from repro.configs import paper
from repro.core.dvfs import DVFSController
from repro.core.energy import PEEnergyModel
from repro.core.snn import build_synfire, simulate_synfire, synfire_power_table
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st


@pytest.fixture(scope="module")
def sim():
    net = build_synfire(0)
    recs = simulate_synfire(net, 1200)
    return net, recs


def test_wave_propagates_around_ring(sim):
    _, recs = sim
    spk = np.asarray(recs["spikes_exc"]).sum(axis=2)      # (T, P)
    for p in range(8):
        strong = np.where(spk[:, p] > 100)[0]
        assert len(strong) >= 5, f"PE{p} did not sustain the synfire wave"
        # wave period = 8 PEs x 10 ms delay = 80 ms
        gaps = np.diff(strong[:5])
        assert np.all(np.abs(gaps - 80) <= 2), (p, gaps)


def test_pl_mostly_1_with_bursts(sim):
    """Fig. 18: sparse activity -> PL1 dominates; waves trigger PL3."""
    _, recs = sim
    pl = np.asarray(recs["pl"])
    frac = np.bincount(pl.ravel(), minlength=3) / pl.size
    assert frac[0] > 0.9
    assert frac[2] > 0.005                                 # waves reach PL3


def test_table_iii_reductions(sim):
    """Paper: total -60.4 %, baseline -63.4 %, neuron -21.2 %, syn -18.7 %."""
    _, recs = sim
    tab = synfire_power_table(recs)
    assert 0.55 <= tab["reduction"]["baseline"] <= 0.72
    assert 0.15 <= tab["reduction"]["neuron"] <= 0.27
    assert 0.04 <= tab["reduction"]["synapse"] <= 0.25
    assert 0.52 <= tab["reduction"]["total"] <= 0.72
    # absolute anchors from Table I: only-PL3 baseline == P_BL,3
    assert abs(tab["pl3"]["baseline"] - 66.44) < 0.1
    assert abs(tab["dvfs"]["baseline"] - 24.3) < 3.0       # paper: 24.3 mW


def test_energy_model_matches_hand_calc():
    em = PEEnergyModel()
    out = em.tick_energy(np.int32(0), 250, 1000, dvfs=True)
    tsp = (em.cycles_overhead + 250 * em.cycles_per_neuron
           + 1000 * em.cycles_per_syn) / 100e6
    expect = paper.PL1.p_baseline_w * tsp \
        + paper.PL1.p_baseline_w * (1e-3 - tsp) \
        + 250 * paper.PL1.e_neuron_j + 1000 * paper.PL1.e_synapse_j
    np.testing.assert_allclose(
        float(out["baseline"] + out["neuron"] + out["synapse"]), expect,
        rtol=1e-6)


@given(n=st.integers(0, 500))
def test_dvfs_controller_thresholds(n):
    c = DVFSController()
    pl = int(c.select_pl(n))
    if n < paper.SYNFIRE.l_th1:
        assert pl == 0
    elif n < paper.SYNFIRE.l_th2:
        assert pl == 1
    else:
        assert pl == 2


@given(a=st.integers(0, 300), b=st.integers(0, 300))
def test_dvfs_monotone(a, b):
    c = DVFSController()
    if a <= b:
        assert int(c.select_pl(a)) <= int(c.select_pl(b))
