"""Recurrent-family numerics: chunked WKV6 vs sequential oracle; chunked
RG-LRU vs naive python recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.models.rglru import _gates, rg_lru
from repro.models.rwkv6 import wkv_chunked, wkv_sequential


def _wkv_inputs(seed, B=2, S=96, H=2, D=8, decay_lo=-6.0, decay_hi=2.0):
    r = np.random.default_rng(seed)
    mk = lambda: jnp.asarray(r.standard_normal((B, S, H, D)), jnp.float32)
    rr, k, v = mk(), mk(), mk()
    lw = jnp.asarray(-np.exp(r.uniform(decay_lo, decay_hi, (B, S, H, D))),
                     jnp.float32)
    u = jnp.asarray(r.standard_normal((H, D)), jnp.float32)
    s0 = jnp.asarray(r.standard_normal((B, H, D, D)), jnp.float32)
    return rr, k, v, lw, u, s0


@pytest.mark.parametrize("chunk", [16, 32, 48])
def test_wkv_chunked_equals_sequential(chunk):
    rr, k, v, lw, u, s0 = _wkv_inputs(0)
    y1, f1 = wkv_sequential(rr, k, v, lw, u, s0)
    y2, f2 = wkv_chunked(rr, k, v, lw, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=3e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               atol=3e-4, rtol=1e-4)


@given(seed=st.integers(0, 3000),
       decay=st.sampled_from([(-8.0, 3.0), (-2.0, 0.0), (-10.0, -5.0)]))
def test_wkv_property_extreme_decays(seed, decay):
    """Log-space chunking must stay exact for arbitrary data-dependent
    decays — the naive factored GLA form overflows here."""
    rr, k, v, lw, u, s0 = _wkv_inputs(seed, B=1, S=64, H=1, D=4,
                                      decay_lo=decay[0], decay_hi=decay[1])
    y1, f1 = wkv_sequential(rr, k, v, lw, u, s0)
    y2, f2 = wkv_chunked(rr, k, v, lw, u, s0, chunk=16)
    assert bool(jnp.all(jnp.isfinite(y2)))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=5e-4, rtol=5e-4)


def _rglru_params(seed, w):
    r = np.random.default_rng(seed)
    return {
        "wi": jnp.asarray(0.3 * r.standard_normal((w, w)), jnp.float32),
        "bi": jnp.asarray(0.1 * r.standard_normal(w), jnp.float32),
        "wa": jnp.asarray(0.3 * r.standard_normal((w, w)), jnp.float32),
        "ba": jnp.asarray(0.1 * r.standard_normal(w), jnp.float32),
        "lam": jnp.asarray(np.abs(r.standard_normal(w)) + 0.3, jnp.float32),
    }


@pytest.mark.parametrize("chunk", [8, 32, 1024])
def test_rglru_matches_naive(chunk):
    B, S, w = 2, 48, 8
    r = np.random.default_rng(0)
    p = _rglru_params(1, w)
    u = jnp.asarray(r.standard_normal((B, S, w)), jnp.float32)
    h0 = jnp.asarray(r.standard_normal((B, w)), jnp.float32)
    y, hf = rg_lru(p, u, h0, chunk=chunk)
    # naive python recurrence
    a, b = _gates(p, u)
    h = np.asarray(h0)
    ys = []
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        ys.append(h.copy())
    ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hf), ref[:, -1], atol=1e-5, rtol=1e-5)


def test_rglru_decode_continues_sequence():
    B, S, w = 1, 16, 8
    r = np.random.default_rng(3)
    p = _rglru_params(2, w)
    u = jnp.asarray(r.standard_normal((B, S, w)), jnp.float32)
    h0 = jnp.zeros((B, w), jnp.float32)
    y_full, _ = rg_lru(p, u, h0)
    _, h_mid = rg_lru(p, u[:, :10], h0)
    ys = []
    h = h_mid
    for t in range(10, S):
        yt, h = rg_lru(p, u[:, t:t + 1], h)
        ys.append(yt[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full[:, 10:]), atol=1e-5)


def test_rglru_decay_bounded():
    """a_t in (0, 1] for any input — state can never blow up."""
    p = _rglru_params(4, 6)
    u = jnp.asarray(np.random.default_rng(5).standard_normal((1, 100, 6)) * 50,
                    jnp.float32)
    a, _ = _gates(p, u)
    assert bool(jnp.all(a > 0)) and bool(jnp.all(a <= 1.0))
