"""End-to-end behaviour of the whole system: the paper's three benchmark
kinds (SNN / DNN / hybrid) run through the public API."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.nef import build_ensemble, run_channel
from repro.core.quant import quantize_params_linear, quantized_linear
from repro.core.snn import build_synfire, simulate_synfire, synfire_power_table


def test_snn_benchmark_end_to_end():
    """(1) conventional SNN with numerical accelerators + DVFS."""
    net = build_synfire(0)
    recs = simulate_synfire(net, 400)
    tab = synfire_power_table(recs)
    assert tab["dvfs"]["total"] < tab["pl3"]["total"]
    assert np.asarray(recs["spikes_exc"]).sum() > 1000


def test_dnn_benchmark_end_to_end(rng):
    """(2) standard DNN layer on the MAC array (int8 path)."""
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    wq, ws = quantize_params_linear(w)
    y = quantized_linear(x, wq, ws)
    rel = np.abs(np.asarray(y) - np.asarray(x @ w)).max() \
        / np.abs(np.asarray(x @ w)).max()
    assert rel < 0.02


def test_hybrid_benchmark_end_to_end():
    """(3) hybrid: MAC array in a spiking context (NEF, Fig. 19/20)."""
    ens = build_ensemble(128, 1, seed=1)
    t = np.arange(600)
    x = 0.6 * np.sin(2 * np.pi * t / 300)[:, None]
    out = run_channel(ens, x, use_mac=True)
    rmse = np.sqrt(np.mean((out["xhat"][200:, 0] - x[200:, 0]) ** 2))
    assert rmse < 0.3


def test_lm_framework_end_to_end():
    """The framework around the paper: one assigned arch trains a step."""
    from repro.models import registry as R
    from repro.models import transformer as T
    cfg = configs.get_arch("recurrentgemma-2b").smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = R.make_dummy_batch(cfg, "train", 2, 24)
    loss, _ = T.train_loss(cfg, params, batch, remat="none", ce_chunk=12)
    assert bool(jnp.isfinite(loss))
