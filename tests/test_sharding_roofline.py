"""Sharding rules + roofline HLO parsing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import roofline as RL
from repro.configs import SHAPES, get_arch
from repro.core.noc import NocModel
from repro.dist import sharding as SH


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_spec_for_basic(mesh):
    # divisible dims take their rule axes; mesh size 1 still yields specs
    spec = SH.spec_for((64, 32), ("embed", "mlp"), mesh)
    assert spec == P("data", "model")


def test_spec_for_nondivisible_replicates():
    m = jax.make_mesh((1,), ("model",),
                      axis_types=(jax.sharding.AxisType.Auto,))
    # fabricate a 16-way mesh via abstract shape checks instead: use the
    # divisibility helper directly
    assert SH._axis_size(m, ("model",)) == 1


def test_spec_never_reuses_axis(mesh):
    spec = SH.spec_for((8, 8, 8), ("mlp", "vocab", "heads"), mesh)
    used = [e for e in spec if e is not None]
    assert len(used) == len(set(used))


def test_cache_spec_falls_back_to_seq():
    """Pure sharding logic against a production-sized mesh shape (the
    functions only read mesh.shape, so a mock suffices on a 1-CPU host)."""
    import types
    m = types.SimpleNamespace(shape={"data": 16, "model": 16})
    # batch=1 cannot shard; kv=2 cannot shard over model=16
    # -> seq takes BOTH leftover axes (64 % 256 != 0 -> only data fits 64? no:
    #    greedy chooses data (64%16==0) then data+model (64%256!=0) stops)
    spec = SH.cache_spec((1, 64, 2, 4), m, batch_dim=0, seq_dim=1, kv_dim=2)
    assert spec[0] is None and spec[2] is None
    assert spec[1] == "data"
    # kv divisible -> kv on model, batch on data
    spec = SH.cache_spec((32, 4096, 16, 128), m, batch_dim=0, seq_dim=1,
                         kv_dim=2)
    assert spec[0] == "data" and spec[2] == "model"


HLO = """
ENTRY %main {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %ag = bf16[256,128]{1,0} all-gather(bf16[16,128]{1,0} %p0), dimensions={0}
  %ar = f32[64]{0} all-reduce(f32[64]{0} %x), to_apply=%sum
  %rs = f32[8,4]{1,0} reduce-scatter(f32[128,4]{1,0} %y), dimensions={0}
  %cp = u32[10]{0} collective-permute(u32[10]{0} %z)
  %aa = s8[32,32]{1,0} all-to-all(s8[32,32]{1,0} %w), dimensions={1}
  %ars = f32[64]{0} all-reduce-start(f32[64]{0} %x2), to_apply=%sum
  %dot = f32[4,4]{1,0} dot(f32[4,8] %a, f32[8,4] %b)
}
"""


def test_collective_parser_counts_operands():
    out = RL.parse_collective_bytes(HLO)
    assert out["all-gather"] == 16 * 128 * 2
    assert out["all-reduce"] == 64 * 4 * 2          # incl. -start form
    assert out["reduce-scatter"] == 128 * 4 * 4
    assert out["collective-permute"] == 10 * 4
    assert out["all-to-all"] == 32 * 32
    assert out["count"] == 6
    assert out["total"] == sum(
        out[k] for k in ("all-gather", "all-reduce", "reduce-scatter",
                         "collective-permute", "all-to-all"))


def test_shape_bytes_scalar():
    assert RL.shape_bytes("f32", "") == 4
    assert RL.shape_bytes("bf16", "2,3,4") == 48


def test_model_flops_scaling():
    cfg = get_arch("qwen1.5-4b")
    tr = RL.model_flops(cfg, SHAPES["train_4k"])
    pf = RL.model_flops(cfg, SHAPES["prefill_32k"])
    # both shapes run ~1M tokens: train is 3x fwd but prefill's 32k context
    # carries ~8x the attention flops -> ratio lands between 1.5 and 3
    assert 1.5 < tr / pf < 3.0
    # MoE uses active params
    moe = get_arch("phi3.5-moe-42b-a6.6b")
    dense_equiv = 6 * moe.param_count() * SHAPES["train_4k"].tokens
    got = RL.model_flops(moe, SHAPES["train_4k"])
    assert got < 0.35 * dense_equiv


def test_noc_collective_cross_check():
    """Ring all-reduce bytes from the NoC model ~ 2x payload (n-1)/n —
    the same arithmetic the HLO term should reflect per device."""
    m = NocModel()
    n, payload = 16, 1024
    assert m.collective_link_bytes("all-reduce", payload, n) == \
        2 * payload * 15 / 16
