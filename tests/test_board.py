"""Board-level multi-chip simulator: golden 1x1 anchor + hierarchical
routing + tiered accounting.

The load-bearing guarantee is the golden anchor: a 1x1-chip board runs
the SAME compile + engine path as today's single chip — identical CSR
incidence, identical per-tick records, bit for bit.  On real boards the
hierarchical router must cover every projection (checked by walking the
per-source link sets against ``BoardNoc.link_endpoints``) and the
per-tier accounting must split exactly.
"""
import numpy as np
import pytest

from repro.board import BoardSpec, compile_board, partition
from repro.board.route import chip_tree
from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.compile import compile as compile_graph
from repro.chip.graph import NetGraph, Population, Projection
from repro.chip.mesh_noc import MeshSpec
from repro.chip.workloads import (board_workload, dnn_board_graph,
                                  hybrid_farm_board_graph, hybrid_graph,
                                  synfire_board_graph, synfire_graph)


# -------------------------------------------------------------------------
# Golden anchor: 1x1 board == single chip, bit for bit
# -------------------------------------------------------------------------

@pytest.mark.parametrize("make", [
    lambda: synfire_graph(8, seed=0),
    lambda: hybrid_graph(n_neurons=64, hidden=16, n_ticks=60),
])
def test_board_1x1_bitwise_identical_to_single_chip(make):
    graph = make()
    pa = compile_graph(graph)
    pb = compile_board(make(), BoardSpec(1, 1, chip=pa.mesh))
    # compile artifacts identical: placement, routing, CSR incidence
    np.testing.assert_array_equal(pa.coords, pb.coords)
    np.testing.assert_array_equal(pa.table.masks, pb.table.masks)
    np.testing.assert_array_equal(pa.payload_bits, pb.payload_bits)
    np.testing.assert_array_equal(pa.sinc.link_ids, pb.sinc.link_ids)
    np.testing.assert_array_equal(pa.sinc.source_ptr, pb.sinc.source_ptr)
    np.testing.assert_array_equal(pa.sinc.tree_hops, pb.sinc.tree_hops)
    assert pa.sinc.n_links == pb.sinc.n_links
    assert pb.noc.n_xchip_links == 0
    assert (pb.tree_links_x == 0).all()
    # run records identical — same keys (no tier records on one chip),
    # same bits, through the engine's auto-selected NoC path
    ra, rb = ChipSim(pa).run(90), ChipSim(pb).run(90)
    assert set(ra) == set(rb)
    for k in ra:
        assert np.array_equal(np.asarray(ra[k]), np.asarray(rb[k])), k


# -------------------------------------------------------------------------
# Hierarchical route correctness: walk every source's stitched tree
# -------------------------------------------------------------------------

def _route_coverage(prog):
    """For each source PE, follow its link set from its own (chip, coord)
    node and assert it reaches EVERY destination PE of the routing table
    (a projection that lost a destination would fail here)."""
    noc = prog.noc
    for p in range(prog.n_pes):
        a, b = prog.sinc.source_ptr[p], prog.sinc.source_ptr[p + 1]
        links = [noc.link_endpoints(int(l)) for l in prog.sinc.link_ids[a:b]]
        assert len({tuple(map(tuple, (u, v))) for u, v in links}) == \
            len(links), f"source {p}: duplicate link in tree"
        reach = {(int(prog.chip_of_pe[p]), tuple(prog.coords_local[p]))}
        frontier = True
        while frontier:
            frontier = False
            for (c0, xy0), (c1, xy1) in links:
                if (c0, tuple(xy0)) in reach and (c1, tuple(xy1)) not in reach:
                    reach.add((c1, tuple(xy1)))
                    frontier = True
        for q in np.flatnonzero(prog.table.masks[p]):
            node = (int(prog.chip_of_pe[q]), tuple(prog.coords_local[q]))
            assert node in reach, f"source {p} never reaches PE {q}"


def test_every_projection_routed_across_chips():
    board = BoardSpec(3, 2, chip=MeshSpec(2, 2))
    graph = synfire_board_graph(board)          # ring spans every chip
    prog = compile_board(graph, board)
    assert prog.n_pes == board.n_pes
    assert (prog.part.chips_of_graph() > 0).all()
    assert prog.tree_links_x.sum() > 0          # the ring crosses chips
    _route_coverage(prog)


def test_chip_tree_is_a_tree():
    board = BoardSpec(4, 3)
    tree = chip_tree(board, src_chip=5, dst_chips=[0, 3, 7, 11])
    entries = [e for e, _ in tree.values() if e is not None]
    assert len(entries) == len(tree) - 1        # one entry per non-source
    # edges = nodes - 1 (tree, not a DAG with rejoins)
    n_edges = sum(len(x) for _, x in tree.values())
    assert n_edges == len(tree) - 1


# -------------------------------------------------------------------------
# Tiered accounting: the split is exact and consistent
# -------------------------------------------------------------------------

@pytest.fixture(scope="module")
def farm_2x2():
    board = BoardSpec(2, 2, chip=MeshSpec(2, 2))
    graph = hybrid_farm_board_graph(board, n_neurons=16, hidden=8,
                                    n_ticks=64)
    rep = board_workload(graph, board, n_ticks=60)
    return board, rep


def test_board_tier_split_is_exact(farm_2x2):
    board, rep = farm_2x2
    recs, prog = rep["recs"], rep["program"]
    flits = np.asarray(recs["link_flits"])
    loads = np.asarray(recs["link_load"])
    xmask = np.asarray(prog.noc.xlink_mask) > 0
    # per-tick tier records == masked per-link sums, flit conservation
    # across the chip-boundary tier (nothing dropped, nothing invented)
    np.testing.assert_array_equal(np.asarray(recs["flits_xchip"]),
                                  flits[:, xmask].sum(axis=1))
    np.testing.assert_array_equal(np.asarray(recs["load_xchip"]),
                                  loads[:, xmask].sum(axis=1))
    assert rep["flits_xchip"] > 0               # channels do cross chips
    assert 0 < rep["xchip_frac"] < 1
    # energy split: tiers sum to the total (tiered pricing, two pj rates)
    e = np.asarray(recs["e_noc"], np.float64)
    e_x = np.asarray(recs["e_noc_xchip"], np.float64)
    assert (e_x <= e + 1e-30).all()
    np.testing.assert_allclose(
        e, e_x + _onchip_energy_j(prog, recs), rtol=1e-6, atol=1e-24)


def _onchip_energy_j(prog, recs):
    """Reference on-chip share: per-source packets x on-chip tree links
    x packet bits x the on-chip pJ/bit-hop."""
    import jax.numpy as jnp
    pk = np.asarray(recs["packets"], np.float64)
    pb = np.asarray(recs.get("payload_bits",
                             np.broadcast_to(prog.payload_bits, pk.shape)))
    pbits = np.asarray(prog.noc.packet_bits(jnp.asarray(pb)), np.float64)
    tl_on = (prog.sinc.tree_links - prog.tree_links_x).astype(np.float64)
    bits = (pk * tl_on * pbits).sum(axis=-1)
    return bits * prog.noc.spec.pj_per_bit_hop * 1e-12


def test_power_table_reports_xchip_tier(farm_2x2):
    board, rep = farm_2x2
    tab = rep["table"]
    assert tab["board"] == (2, 2)
    x = tab["noc"]["xchip"]
    assert x["n_links"] == rep["program"].noc.n_xchip_links
    assert 0 < x["flits_frac"] < 1
    # chip-to-chip hops cost ~12x the energy per bit: crossing traffic
    # dominates NoC energy long before it dominates flit counts
    assert x["energy_frac"] > x["flits_frac"]


def test_board_sparse_dense_and_pallas_agree():
    board = BoardSpec(2, 2, chip=MeshSpec(2, 1))
    prog = compile_board(synfire_board_graph(board), board)
    sim = ChipSim(prog)
    a = sim.run(60, noc_mode="sparse")
    b = sim.run(60, noc_mode="dense")
    c = sim.run(60, noc_mode="sparse", link_load_impl="pallas")
    for k in ("link_load", "link_flits", "e_noc", "flits_xchip",
              "load_xchip", "e_noc_xchip"):
        assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), k
        assert np.array_equal(np.asarray(a[k]), np.asarray(c[k])), k


# -------------------------------------------------------------------------
# Partitioner basics (the hypothesis suite drives the random cases)
# -------------------------------------------------------------------------

def test_partition_respects_capacity_and_errors_clearly():
    board = BoardSpec(2, 1, chip=MeshSpec(1, 1))     # 2 chips x 4 PEs
    graph = synfire_graph(8)
    part = partition(graph, board)
    assert sorted(part.chip_of.values()) == [0] * 4 + [1] * 4
    assert all(u <= board.chip.n_pes for u in part.slots_used)
    with pytest.raises(ValueError, match="does not fit the"):
        partition(synfire_graph(9), board)
    fat = NetGraph([Population("fat", 1, 64, n_tiles=5)], [],
                   semantics=object())
    with pytest.raises(ValueError, match="one 1x1 QPE chip holds"):
        partition(fat, board)


def test_kernel_knob_validated_even_on_dense_path():
    """A typo'd link_load_impl must error up front, even when the dense
    einsum wins the auto-selection and the sparse plan is never built."""
    sim = ChipSim(compile_graph(synfire_graph(8)))
    assert sim.use_sparse_noc() is False
    with pytest.raises(ValueError, match="link_load_impl"):
        sim.run(4, link_load_impl="bogus")


def test_compile_board_rejects_mismatched_partition():
    graph = synfire_graph(8)
    part = partition(graph, BoardSpec(2, 1, chip=MeshSpec(1, 1)))
    with pytest.raises(ValueError, match="partition was built for"):
        compile_board(graph, BoardSpec(2, 2, chip=MeshSpec(2, 2)),
                      part=part)


def test_partition_refinement_reduces_cut():
    """A pair graph laid out nef0..nefK mlp0..mlpK greedily splits pairs
    across chips; refinement must pull each pair back together (or at
    least never make the cut worse)."""
    board = BoardSpec(2, 2, chip=MeshSpec(2, 2))
    graph = hybrid_farm_board_graph(board, n_neurons=16, hidden=8)
    rough = partition(graph, board, refine=False)
    fine = partition(graph, board, refine=True)
    assert fine.cut_flits <= rough.cut_flits
    assert all(u <= board.chip.n_pes for u in fine.slots_used)


def test_dnn_board_pipeline_runs_across_chips():
    board = BoardSpec(2, 2, chip=MeshSpec(4, 2))
    graph = dnn_board_graph(board)
    rep = board_workload(graph, board, n_ticks=120)
    assert rep["n_chips_used"] > 1
    assert rep["flits_xchip"] > 0
    assert np.asarray(rep["recs"]["frame_out"]).sum() > 0
