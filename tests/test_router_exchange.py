"""Router delivery: dense path vs. mesh/shard_map path agree, and
delivery conserves spike counts (satellite of the chip-mesh PR).

The shard_map paths need >1 device, so they run in a subprocess with a
forced 4-device host platform (same pattern as test_dryrun_integration)."""
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.router import RoutingTable, multicast_exchange, ring_exchange

ROOT = Path(__file__).resolve().parents[1]


def test_multicast_dense_conserves_spikes():
    rng = np.random.default_rng(0)
    spk = jnp.asarray(rng.integers(0, 3, (6, 5)), jnp.int32)
    for table in (RoutingTable.ring(6), RoutingTable.self_loop(6)):
        arr = multicast_exchange(spk, table)           # (P, P, K)
        sent = np.asarray(spk) * table.fan_out()[:, None]
        assert int(np.asarray(arr).sum()) == int(sent.sum())


def test_multicast_dense_respects_masks():
    rng = np.random.default_rng(1)
    masks = rng.random((4, 4)) < 0.5
    spk = jnp.asarray(rng.integers(0, 2, (4, 3)), jnp.int32)
    arr = np.asarray(multicast_exchange(spk, RoutingTable(masks)))
    for i in range(4):
        for p in range(4):
            expect = np.asarray(spk[i]) * int(masks[i, p])
            assert np.array_equal(arr[p, i], expect)


def test_ring_exchange_conserves_and_shifts():
    rng = np.random.default_rng(2)
    spk = jnp.asarray(rng.integers(0, 4, (5, 7)), jnp.int32)
    out = ring_exchange(spk)
    assert int(out.sum()) == int(spk.sum())
    assert np.array_equal(np.asarray(out), np.roll(np.asarray(spk), 1, 0))


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
import repro                                   # installs compat shims
from repro.core.router import RoutingTable, multicast_exchange, ring_exchange

mesh = jax.make_mesh((4,), ("pe",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
spk = jnp.asarray(rng.integers(0, 3, (4, 6)), jnp.int32)

# ring: jnp.roll path vs collective_permute path
dense = ring_exchange(spk)
sharded = ring_exchange(spk, mesh=mesh)
assert np.array_equal(np.asarray(dense), np.asarray(sharded)), "ring mismatch"

# multicast: dense einsum vs all_gather+mask path, plus conservation
for masks in (np.asarray(RoutingTable.ring(4).masks),
              rng.random((4, 4)) < 0.5):
    table = RoutingTable(np.asarray(masks))
    d = np.asarray(multicast_exchange(spk, table))
    s = np.asarray(multicast_exchange(spk, table, mesh=mesh))
    assert np.array_equal(d, s), "multicast mismatch"
    sent = np.asarray(spk) * table.fan_out()[:, None]
    assert int(d.sum()) == int(sent.sum()), "conservation"
print("OK")
"""


@pytest.mark.slow
def test_exchange_paths_agree_on_forced_mesh():
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
