"""Incremental decode must equal the full-sequence forward — exercises KV
caches, ring buffers (local attention), RWKV/RG-LRU recurrent state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer as T

ARCHS = ["qwen1.5-4b", "gemma3-27b", "glm4-9b", "rwkv6-1.6b",
         "recurrentgemma-2b", "olmoe-1b-7b", "musicgen-large"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = configs.get_arch(arch).smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    S, B, P = 24, 2, 8
    rng = np.random.default_rng(1)
    if cfg.frontend == "encodec":
        frames = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)),
                             jnp.bfloat16)
        full_in = {"frames": frames}
        pre_in = {"frames": frames[:, :P]}
        dec_in = lambda t: {"frames": frames[:, t:t + 1]}
    else:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
        full_in = {"tokens": toks}
        pre_in = {"tokens": toks[:, :P]}
        dec_in = lambda t: {"tokens": toks[:, t:t + 1]}

    qpos = jnp.arange(S)
    x = T.embed_input(cfg, params, full_in, qpos)
    hidden, _, _ = T.forward_hidden(cfg, params, x, qpos, moe_dense=True)
    full_logits = T.logits_fn(cfg, params, hidden)

    logits_p, caches = T.prefill(cfg, params, pre_in, S, moe_dense=True)
    outs = [logits_p[:, 0]]
    for t in range(P, S):
        lg, caches = T.decode_step(cfg, params, caches, jnp.int32(t),
                                   dec_in(t), moe_dense=True)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    ref = full_logits[:, P - 1:]
    err = jnp.max(jnp.abs(dec.astype(jnp.float32) - ref.astype(jnp.float32)))
    scale = jnp.max(jnp.abs(ref.astype(jnp.float32))) + 1e-6
    assert float(err / scale) < 0.02, (arch, float(err), float(scale))


def test_ring_buffer_wraps_correctly():
    """Local-attention ring cache must stay consistent past `window` steps."""
    cfg = configs.get_arch("gemma3-27b").smoke()   # window=8
    assert cfg.window_size == 8
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    params = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
    S, B = 32, 1                                    # 4x past the window
    toks = jnp.asarray(
        np.random.default_rng(2).integers(0, cfg.vocab_size, (B, S)),
        jnp.int32)
    qpos = jnp.arange(S)
    x = T.embed_input(cfg, params, {"tokens": toks}, qpos)
    hidden, _, _ = T.forward_hidden(cfg, params, x, qpos)
    full_logits = T.logits_fn(cfg, params, hidden)

    logits_p, caches = T.prefill(cfg, params, {"tokens": toks[:, :4]}, S)
    out = logits_p[:, 0]
    outs = [out]
    for t in range(4, S):
        lg, caches = T.decode_step(cfg, params, caches, jnp.int32(t),
                                   {"tokens": toks[:, t:t + 1]})
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    ref = full_logits[:, 3:]
    err = jnp.max(jnp.abs(dec.astype(jnp.float32) - ref.astype(jnp.float32)))
    scale = jnp.max(jnp.abs(ref.astype(jnp.float32))) + 1e-6
    assert float(err / scale) < 0.02
