"""Dry-run integration: lower+compile real cells against a forced multi-
device mesh in a subprocess (device count must be set before jax init)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

pytest.importorskip("repro.dist.cells")

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count={ndev} "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion")
import json, sys
import jax
from repro import configs
from repro.dist.cells import make_cell

mesh = jax.make_mesh({mesh_shape}, {mesh_axes},
                     axis_types=(jax.sharding.AxisType.Auto,) * {naxes})
cfg = configs.get_arch("{arch}")
shape = configs.SHAPES["{shape}"]
cell = make_cell(cfg, shape, mesh)
with mesh:
    compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                       out_shardings=cell.out_shardings,
                       donate_argnums=cell.donate_argnums
                       ).lower(*cell.args).compile()
ca = compiled.cost_analysis()
print(json.dumps({{"flops": ca.get("flops", 0.0),
                   "ok": True}}))
"""


def _run(arch, shape, ndev=8, mesh_shape=(2, 4), mesh_axes=("data", "model")):
    code = SCRIPT.format(ndev=ndev, arch=arch, shape=shape,
                         mesh_shape=mesh_shape, mesh_axes=mesh_axes,
                         naxes=len(mesh_shape))
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_dense_train_cell_compiles_8dev():
    res = _run("qwen1.5-4b", "train_4k")
    assert res["ok"] and res["flops"] > 0


@pytest.mark.slow
def test_moe_train_cell_compiles_8dev():
    res = _run("olmoe-1b-7b", "train_4k")
    assert res["ok"]


@pytest.mark.slow
def test_decode_cell_compiles_multipod_axes():
    res = _run("glm4-9b", "decode_32k", ndev=8, mesh_shape=(2, 2, 2),
               mesh_axes=("pod", "data", "model"))
    assert res["ok"]
