"""LIF kernel: bit-exact vs oracle + neuron behavior properties."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.kernels.explog.ops import to_fx
from repro.kernels.lif import fx_mul, lif_params_fx, lif_step, lif_step_ref

P = lif_params_fx(tau_ms=10.0, v_th=1.0, v_reset=0.0, ref_ticks=2)


def test_bit_exact(rng):
    N = 5000
    v = jnp.asarray(rng.integers(-(2**16), 2**16, N), jnp.int32)
    rc = jnp.asarray(rng.integers(0, 4, N), jnp.int32)
    i = jnp.asarray(rng.integers(-(2**13), 2**13, N), jnp.int32)
    out_k = lif_step(v, rc, i, **P)
    out_r = lif_step_ref(v, rc, i, **P)
    for a, b in zip(out_k, out_r):
        assert bool(jnp.all(a == b))


def test_decay_toward_zero():
    v = jnp.full((4,), to_fx(0.5), jnp.int32)
    rc = jnp.zeros((4,), jnp.int32)
    for _ in range(50):
        v, rc, _ = lif_step(v, rc, jnp.zeros_like(v), **P)
    assert np.all(np.abs(np.asarray(v)) < to_fx(0.01))


def test_spike_and_refractory():
    v = jnp.zeros((1,), jnp.int32)
    rc = jnp.zeros((1,), jnp.int32)
    big = jnp.full((1,), to_fx(2.0), jnp.int32)
    v, rc, s = lif_step(v, rc, big, **P)
    assert int(s[0]) == 1 and int(v[0]) == P["v_reset"]
    # refractory: immediate re-drive must not spike
    v, rc, s = lif_step(v, rc, big, **P)
    assert int(s[0]) == 0
    v, rc, s = lif_step(v, rc, big, **P)
    assert int(s[0]) == 0
    v, rc, s = lif_step(v, rc, big, **P)
    assert int(s[0]) == 1          # refractory (2 ticks) elapsed


@given(v=st.integers(-(2**17), 2**17), a=st.integers(0, 2**15))
def test_fx_mul_matches_float(v, a):
    got = int(fx_mul(jnp.int32(v), jnp.int32(a)))
    exact = v * a / 2**15
    assert abs(got - exact) <= 2.0


@given(seed=st.integers(0, 10_000))
def test_property_kernel_equals_ref(seed):
    r = np.random.default_rng(seed)
    n = int(r.integers(1, 300))
    v = jnp.asarray(r.integers(-(2**16), 2**16, n), jnp.int32)
    rc = jnp.asarray(r.integers(0, 3, n), jnp.int32)
    i = jnp.asarray(r.integers(-(2**14), 2**14, n), jnp.int32)
    for a, b in zip(lif_step(v, rc, i, **P), lif_step_ref(v, rc, i, **P)):
        assert bool(jnp.all(a == b))
