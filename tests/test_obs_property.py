"""Property tests for the in-scan probe reductions (repro.obs.probes).

Invariant: for ANY (stride, op, n_ticks), the strided/windowed probe
buffers computed inside the scan carry must equal the same reduction
applied to the full-resolution per-tick records after the fact —
tumbling windows of ``stride`` ticks, final partial window included,
``mean`` dividing by the true window length, ``ema`` one continuous
float32 average over the whole run sampled at window ends.

The probes run against the real engine (8-PE synfire chip program), so
the property also covers the engine plumbing: rec-shape discovery via
``eval_shape``, carry threading, and buffer slot indexing.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.chip.chip import ChipSim
from repro.chip.compile import compile as compile_graph
from repro.chip.workloads import synfire_graph
from repro.obs import ProbeSpec
from repro.obs.probes import n_probe_samples

MAX_TICKS = 48
_SIM = ChipSim(compile_graph(synfire_graph(8)))
# full-resolution reference records, one run per n_ticks (cached — the
# engine is deterministic, so slicing a longer run would NOT be valid:
# state carries across ticks but records are per-tick, so prefixes agree)
_FULL = {}


def _full(n_ticks: int) -> dict:
    if n_ticks not in _FULL:
        recs = _SIM.run(n_ticks)
        _FULL[n_ticks] = {k: np.asarray(v) for k, v in recs.items()}
    return _FULL[n_ticks]


def _windows(n_ticks: int, stride):
    s = n_ticks if stride is None else min(stride, n_ticks)
    return [(lo, min(lo + s, n_ticks)) for lo in range(0, n_ticks, s)]


def _reference(sig: np.ndarray, op: str, stride, alpha: float) -> np.ndarray:
    """The probe's contract, written the slow obvious way."""
    n_ticks = sig.shape[0]
    sig = sig.astype(np.float32)
    if op == "ema":
        ema = sig[0]
        series = [ema]
        for t in range(1, n_ticks):
            ema = np.float32(alpha) * sig[t] + np.float32(1 - alpha) * ema
            series.append(ema)
        return np.stack([series[hi - 1] for _, hi in
                         _windows(n_ticks, stride)])
    outs = []
    for lo, hi in _windows(n_ticks, stride):
        w = sig[lo:hi]
        if op == "peak":
            outs.append(w.max(axis=0))
        elif op == "mean":
            outs.append(w.sum(axis=0, dtype=np.float32) / (hi - lo))
        elif op == "sum":
            outs.append(w.sum(axis=0, dtype=np.float32))
        else:                                                  # last
            outs.append(w[-1])
    return np.stack(outs)


@st.composite
def probe_cases(draw):
    n_ticks = draw(st.integers(min_value=1, max_value=MAX_TICKS))
    stride = draw(st.one_of(
        st.none(), st.integers(min_value=1, max_value=MAX_TICKS + 8)))
    op = draw(st.sampled_from(("peak", "mean", "sum", "last", "ema")))
    key = draw(st.sampled_from(("link_flits", "packets", "pl", "e_noc")))
    alpha = draw(st.sampled_from((0.05, 0.25, 1.0)))
    return n_ticks, stride, op, key, alpha


@settings(max_examples=30, deadline=None)
@given(probe_cases())
def test_strided_probe_matches_full_resolution_reduction(case):
    n_ticks, stride, op, key, alpha = case
    spec = ProbeSpec("p", key, op, stride=stride, alpha=alpha)
    out = _SIM.run(n_ticks, probes=(spec,), keep_records=False)
    buf = np.asarray(out["probes"]["p"])
    ref = _reference(_full(n_ticks)[key], op, stride, alpha)
    assert buf.shape[0] == n_probe_samples(n_ticks, stride) == ref.shape[0]
    if op in ("peak", "last"):
        # pure selections of recorded float32 values — exact
        np.testing.assert_array_equal(buf, ref)
    else:
        # identical float32 fold order => tight tolerance
        np.testing.assert_allclose(buf, ref, rtol=1e-6, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=1, max_value=MAX_TICKS))
def test_whole_run_probe_equals_numpy_reduction(n_ticks):
    """stride=None is exactly one window covering the full run."""
    out = _SIM.run(n_ticks, probes=(
        ProbeSpec("pk", "link_flits", "peak"),
        ProbeSpec("sm", "packets", "sum"),
    ), keep_records=False)["probes"]
    full = _full(n_ticks)
    assert out["pk"].shape[0] == out["sm"].shape[0] == 1
    np.testing.assert_array_equal(np.asarray(out["pk"])[0],
                                  full["link_flits"].max(axis=0))
    np.testing.assert_allclose(
        np.asarray(out["sm"])[0],
        full["packets"].astype(np.float32).sum(axis=0), rtol=1e-6)


# ---------------------------------------------------------------------------
# Batched probes (the serving tier's per-instance accumulators)
# ---------------------------------------------------------------------------

@st.composite
def batched_probe_cases(draw):
    batch = draw(st.integers(min_value=1, max_value=4))
    n_ticks = draw(st.integers(min_value=2, max_value=24))
    n_steps = draw(st.integers(min_value=1, max_value=16))
    stride = draw(st.one_of(st.none(),
                            st.integers(min_value=1, max_value=28)))
    op = draw(st.sampled_from(("peak", "mean", "sum", "last", "ema")))
    alpha = draw(st.sampled_from((0.05, 0.25, 1.0)))
    offsets = draw(st.lists(st.integers(min_value=0, max_value=n_ticks - 1),
                            min_size=batch, max_size=batch))
    seed = draw(st.integers(min_value=0, max_value=2**16))
    return batch, n_ticks, n_steps, stride, op, alpha, offsets, seed


@settings(max_examples=30, deadline=None)
@given(batched_probe_cases())
def test_batched_probe_equals_per_instance_fold(case):
    """``make_batched_probe_step`` over B instances with DISTINCT local
    tick counters must equal B independent unbatched folds, bitwise —
    the invariant that lets fleet sessions carry probe state through
    slot moves, preemption, and width changes."""
    import jax
    import jax.numpy as jnp
    from repro.obs.probes import make_batched_probe_step, make_probe_step

    batch, n_ticks, n_steps, stride, op, alpha, offsets, seed = case
    rng = np.random.default_rng(seed)
    sig = rng.uniform(0.0, 8.0, (batch, n_steps, 3)).astype(np.float32)
    specs = (ProbeSpec("p", "sig", op, stride=stride, alpha=alpha),)
    shapes = {"sig": jax.ShapeDtypeStruct((3,), jnp.float32)}

    init, step, fin = make_probe_step(specs, shapes, n_ticks)
    binit, bstep, bfin = make_batched_probe_step(specs, shapes, n_ticks,
                                                 batch)
    offs = np.asarray(offsets, np.int32)
    obs_b = binit
    for j in range(n_steps):
        obs_b = bstep(obs_b, {"sig": jnp.asarray(sig[:, j])},
                      jnp.asarray(offs + j))
    out_b = np.asarray(bfin(obs_b)["p"])
    assert out_b.shape == (batch, n_probe_samples(n_ticks, stride), 3)

    for i in range(batch):
        obs = init
        for j in range(n_steps):
            obs = step(obs, {"sig": jnp.asarray(sig[i, j])},
                       jnp.int32(offs[i] + j))
        np.testing.assert_array_equal(out_b[i], np.asarray(fin(obs)["p"]))
