"""NEF communication channel (paper Sec. VI-C, Fig. 19/20): encode on the
MAC array, spike on fixed-point LIF, decode event-driven.

    PYTHONPATH=src python examples/nef_channel.py
"""
import numpy as np

from repro.core.nef import build_ensemble, run_channel

ens = build_ensemble(n_neurons=512, dims=1, seed=0)
t = np.arange(1200)
x = 0.8 * np.sin(2 * np.pi * t / 500)[:, None]
out = run_channel(ens, x, use_mac=True)

xhat = out["xhat"][:, 0]
rmse = float(np.sqrt(np.mean((xhat[300:] - x[300:, 0]) ** 2)))
rate = out["spikes_per_tick"].mean() / 512 * 1000

print("input vs decoded output (ASCII, 60 cols):")
for label, sig in (("x   ", x[:, 0]), ("xhat", xhat)):
    cols = sig[::20][:60]
    row = "".join("-+*#"[min(3, int((v + 1) * 2))] if abs(v) <= 1 else "!"
                  for v in cols)
    print(f"{label} |{row}|")
print(f"\nRMSE (steady state) = {rmse:.3f}; population rate = {rate:.0f} Hz")
print("encode ran through the int8 MAC-array kernel (Fig. 19 pipeline)")
