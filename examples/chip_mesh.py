"""Run a synfire ring across a full PE mesh and watch the NoC.

    PYTHONPATH=src python examples/chip_mesh.py [--pes 64] [--ticks 700]

Prints the mesh layout, a spike raster sampled over the ring, the busiest
links, and the chip-level power table (per-PE Table III numbers scaled to
the mesh plus NoC power/congestion).
"""
import argparse

import numpy as np

from repro.chip.chip import ChipSim, chip_power_table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pes", type=int, default=64)
    ap.add_argument("--ticks", type=int, default=700)
    args = ap.parse_args()

    sim = ChipSim.synfire(args.pes)
    m = sim.placement.mesh
    print(f"{args.pes}-PE ring on a {m.width}x{m.height} QPE mesh "
          f"({sim.noc.n_links} directed links)")

    recs = sim.run(args.ticks)
    spk = np.asarray(recs["spikes_exc"]).sum(axis=2)      # (T, P)

    show = list(range(0, args.pes, max(1, args.pes // 8)))
    bins = spk[: args.ticks - args.ticks % 8].reshape(-1, 8, args.pes)
    bins = bins.sum(axis=1)
    print("\nspike raster (rows = sampled PEs, cols = 8 ms bins)")
    for p in show:
        row = "".join("#" if b > 100 else ("." if b > 0 else " ")
                      for b in bins[:90, p])
        print(f"PE{p:3d} |{row}|")

    loads = np.asarray(recs["link_load"])                 # (T, L)
    busiest = np.argsort(loads.sum(axis=0))[::-1][:5]
    print("\nbusiest links (total packets over the run):")
    for li in busiest:
        (a, b) = sim.noc.links[li]
        print(f"  {a} -> {b}: {loads[:, li].sum():.0f} packets, "
              f"peak {loads[:, li].max():.0f}/tick")

    tab = chip_power_table(sim, recs)
    print(f"\nper-PE: DVFS {tab['per_pe']['dvfs']['total']:.1f} mW, "
          f"only-PL3 {tab['per_pe']['pl3']['total']:.1f} mW "
          f"(reduction {tab['per_pe']['reduction']['total']*100:.1f}%)")
    print(f"chip ({tab['n_pes']} PEs): DVFS "
          f"{tab['chip']['dvfs']['total']/1e3:.2f} W, only-PL3 "
          f"{tab['chip']['pl3']['total']/1e3:.2f} W")
    print(f"NoC: {tab['noc']['power_mw']*1e3:.2f} uW, peak link load "
          f"{tab['noc']['peak_link_load']:.0f} packets/tick "
          f"({tab['noc']['peak_utilization']*100:.2f}% of capacity), "
          f"worst multicast depth {tab['noc']['worst_tree_hops']} hops")


if __name__ == "__main__":
    main()
