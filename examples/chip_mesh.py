"""Run workload graphs across a full PE mesh and watch the NoC.

    PYTHONPATH=src python examples/chip_mesh.py [--pes 64] [--ticks 700]
        [--workload synfire|dnn|hybrid]

The unified API: build a ``NetGraph``, ``compile`` it to a ``ChipProgram``
(placement + routing + incidence), run it on the workload-agnostic
``ChipSim``.  Prints the mesh layout, a raster/occupancy view, the busiest
links, and the chip-level power table (per-PE Table III numbers scaled to
the mesh plus NoC power/congestion).
"""
import argparse

import numpy as np

from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.compile import compile as compile_graph
from repro.chip.workloads import (hybrid_workload, synfire_graph,
                                  tiled_dnn_workload)


def print_noc_and_power(sim, recs):
    loads = np.asarray(recs["link_load"])                 # (T, L)
    flits = np.asarray(recs["link_flits"])
    busiest = np.argsort(flits.sum(axis=0))[::-1][:5]
    print("\nbusiest links (total over the run):")
    for li in busiest:
        (a, b) = sim.noc.links[li]
        print(f"  {a} -> {b}: {loads[:, li].sum():.0f} packets / "
              f"{flits[:, li].sum():.0f} flits, "
              f"peak {flits[:, li].max():.0f} flits/tick")

    tab = chip_power_table(sim, recs)
    print(f"\nper-PE: DVFS {tab['per_pe']['dvfs']['total']:.1f} mW, "
          f"only-PL3 {tab['per_pe']['pl3']['total']:.1f} mW "
          f"(reduction {tab['per_pe']['reduction']['total']*100:.1f}%)")
    print(f"chip ({tab['n_pes']} PEs): DVFS "
          f"{tab['chip']['dvfs']['total']/1e3:.2f} W, only-PL3 "
          f"{tab['chip']['pl3']['total']/1e3:.2f} W")
    print(f"NoC: {tab['noc']['power_mw']*1e3:.2f} uW, peak link load "
          f"{tab['noc']['peak_link_flits']:.0f} flits/tick "
          f"({tab['noc']['peak_utilization']*100:.2f}% of capacity), "
          f"worst multicast depth {tab['noc']['worst_tree_hops']} hops")


def run_synfire(args):
    graph = synfire_graph(args.pes)
    prog = compile_graph(graph)
    sim = ChipSim(prog, exec_mode=args.exec_mode)
    m = prog.mesh
    print(f"{args.pes}-PE synfire ring on a {m.width}x{m.height} QPE mesh "
          f"({prog.noc.n_links} directed links), "
          f"exec_mode={args.exec_mode}")

    recs = sim.run(args.ticks)
    spk = np.asarray(recs["spikes_exc"]).sum(axis=2)      # (T, P)
    show = list(range(0, args.pes, max(1, args.pes // 8)))
    bins = spk[: args.ticks - args.ticks % 8].reshape(-1, 8, args.pes)
    bins = bins.sum(axis=1)
    print("\nspike raster (rows = sampled PEs, cols = 8 ms bins)")
    for p in show:
        row = "".join("#" if b > 100 else ("." if b > 0 else " ")
                      for b in bins[:90, p])
        print(f"PE{p:3d} |{row}|")
    print_noc_and_power(sim, recs)


def run_dnn(args):
    rep = tiled_dnn_workload()
    prog = rep["sim"].program
    print(f"tiled DNN: {rep['n_pes_used']} tile-PEs on a "
          f"{rep['mesh'][0]}x{rep['mesh'][1]} QPE mesh; "
          f"{rep['n_frames_out']} frames through the pipeline, "
          f"first-frame latency {rep['latency_s']*1e3:.1f} ms")
    busy = np.asarray(rep["recs"]["busy"])                # (T, P)
    print("\npipeline occupancy (rows = tile PEs, cols = ticks)")
    for p in range(prog.n_pes):
        row = "".join("#" if b else "." for b in busy[:70, p])
        print(f"PE{p:3d} |{row}|")
    print_noc_and_power(rep["sim"], rep["recs"])


def run_hybrid(args):
    h = hybrid_workload(n_ticks=max(args.ticks, 400))
    print(f"hybrid NEF->event-MAC: rmse {h['rmse']:.3f}, duty cycle "
          f"{h['duty_cycle']*100:.0f}%, event/frame MAC energy "
          f"{h['event_vs_frame']:.3f}")
    print(f"graded payload conservation: "
          f"{h['graded_bits_out'][:-1].sum():.0f} bits out == "
          f"{h['graded_bits_in'][1:].sum():.0f} bits in")
    print_noc_and_power(h["sim"], h["recs"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pes", type=int, default=64)
    ap.add_argument("--ticks", type=int, default=700)
    ap.add_argument("--workload", default="synfire",
                    choices=["synfire", "dnn", "hybrid"])
    ap.add_argument("--exec-mode", default="auto",
                    choices=["auto", "dense", "event"],
                    help="engine execution mode (synfire workload): the "
                    "event engine is bitwise-identical to dense")
    args = ap.parse_args()
    {"synfire": run_synfire, "dnn": run_dnn, "hybrid": run_hybrid}[
        args.workload](args)


if __name__ == "__main__":
    main()
