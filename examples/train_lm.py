"""End-to-end training driver (deliverable b): train a small LM on the
deterministic synthetic pipeline with the fault-tolerant loop.

    PYTHONPATH=src python examples/train_lm.py              # ~25M, CPU-sized
    PYTHONPATH=src python examples/train_lm.py --hundred-m  # ~100M config

The ~100M variant is the documented "train a ~100M model for a few hundred
steps" driver; the default is mechanically identical but CPU-sized so the
example finishes in minutes in this container.
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro import configs
from repro.ckpt.checkpoint import CheckpointManager
from repro.data.pipeline import PipelineConfig, SyntheticTokenPipeline
from repro.ft.loop import FaultTolerantLoop, LoopConfig
from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step


def small_lm(hundred_m: bool) -> configs.ArchConfig:
    base = configs.get_arch("qwen1.5-4b")
    if hundred_m:
        return dataclasses.replace(
            base, name="lm-100m", num_layers=12, d_model=768, num_heads=12,
            num_kv_heads=12, head_dim=64, d_ff=2048, vocab_size=32_000)
    return dataclasses.replace(
        base, name="lm-8m", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=4, head_dim=64, d_ff=768, vocab_size=2_048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = small_lm(args.hundred_m)
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    pipe = SyntheticTokenPipeline(PipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch))
    step = jax.jit(make_train_step(
        cfg, opt=AdamWConfig(lr=args.lr), ce_chunk=min(args.seq, 256),
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 10)),
        donate_argnums=(0, 1))
    ckpt = CheckpointManager(f"artifacts/ckpt/{cfg.name}")
    loop = FaultTolerantLoop(
        LoopConfig(total_steps=args.steps, ckpt_every=max(args.steps // 4, 25),
                   install_signal_handlers=True),
        ckpt, step, pipe)
    state, log = loop.run(params, opt)
    for rec in log[:: max(len(log) // 12, 1)]:
        print(f"step {rec['step']:5d} loss {rec['loss']:.4f}")
    first = sum(r["loss"] for r in log[:10]) / 10
    last = sum(r["loss"] for r in log[-10:]) / 10
    print(f"loss: {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'no progress'})")


if __name__ == "__main__":
    main()
