"""Batched serving with queue-driven (spike-FIFO-style) batch widths.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine

cfg = configs.get_arch("glm4-9b").smoke()
params = T.init_params(cfg, jax.random.PRNGKey(0))
params = jax.tree.map(
    lambda x: x.astype(jnp.bfloat16)
    if jnp.issubdtype(x.dtype, jnp.floating) else x, params)

eng = ServeEngine(cfg, params, max_seq=64)
rng = np.random.default_rng(0)
for i in range(11):
    eng.submit(Request(rid=i,
                       prompt=rng.integers(0, cfg.vocab_size, 12,
                                           dtype=np.int32),
                       max_new_tokens=8))
stats = eng.run()
print(f"served {stats['tokens']} tokens in {stats['rounds']} rounds")
print(f"queue-DVFS batch widths: {stats['batch_hist']} "
      f"(levels {eng.dvfs.batch_levels}, thresholds {eng.dvfs.thresholds})")
print("deep queue -> wide batch (PL3-like); drained queue -> narrow (PL1)")
