"""Neuromorphic serving: a vmapped fleet of chip instances under user
traffic, width-elastic via the paper's spike-FIFO -> performance-level
loop (QueueDVFS).

    PYTHONPATH=src python examples/serve_fleet.py

Each user session streams a reference signal into its OWN instance of
the adaptive-control program (NEF ensemble + PES decoders tracking a
plant over the mesh); the fleet advances all resident sessions together
in one batched scan, admits from the shared request queue as bursts
arrive, and narrows — checkpointing evicted sessions — as it drains.
"""
import numpy as np

from repro.core.dvfs import QueueDVFS
from repro.serve.fleet import FleetEngine, PoissonTraffic, adaptive_scenario

sc = adaptive_scenario(n_channels=1, n_neurons=64, learning_rate=1e-5)
eng = FleetEngine(sc, round_ticks=64,
                  dvfs=QueueDVFS(thresholds=(3, 8), batch_levels=(4, 8, 16)))
traffic = PoissonTraffic(rate=4.0, n_sessions=24, tick_range=(512, 1024),
                         seed=0)
out = eng.serve(traffic)
st = out["stats"]

print(f"served {st['completed']} sessions in {st['rounds']} rounds "
      f"({st['wall_s']:.1f}s wall, {st['sessions_per_s']:.1f} sessions/s)")
print(f"fleet widths used: {st['width_hist']} "
      f"(levels {eng.dvfs.batch_levels}, thresholds {eng.dvfs.thresholds})")
print(f"request latency p50/p99: {st['request_latency_s']['p50']:.2f}/"
      f"{st['request_latency_s']['p99']:.2f} s; "
      f"simulated {st['joules_per_request'] * 1e3:.2f} mJ/request; "
      f"{st['preemptions']} preemptions")

errs = np.array([[s.response["initial_err"], s.response["final_err"]]
                 for s in out["sessions"]])
print(f"per-session PES learning: mean |err| {errs[:, 0].mean():.3f} -> "
      f"{errs[:, 1].mean():.3f} over each session's stream")
print("burst -> wide fleet (PL3-like); drained queue -> narrow + checkpoint")
