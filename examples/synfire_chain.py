"""Synfire chain on 8 PEs with activity-driven DVFS (paper Sec. VI-B).

    PYTHONPATH=src python examples/synfire_chain.py [--ticks 400]

Prints an ASCII spike raster (exc populations), the PL timeline, and the
Table III power comparison.
"""
import argparse

import numpy as np

from repro.core.snn import build_synfire, simulate_synfire, synfire_power_table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=400)
    args = ap.parse_args()

    net = build_synfire(0)
    recs = simulate_synfire(net, args.ticks)
    spk = np.asarray(recs["spikes_exc"]).sum(axis=2)       # (T, P)
    pl = np.asarray(recs["pl"])                            # (T, P)

    print("spike raster (rows = PEs, cols = 4 ms bins; #: wave, .: sparse)")
    bins = spk[: args.ticks - args.ticks % 4].reshape(-1, 4, 8).sum(axis=1)
    for p in range(8):
        row = "".join("#" if b > 100 else ("." if b > 0 else " ")
                      for b in bins[:100, p])
        print(f"PE{p} |{row}|")

    print("\nPL timeline for PE0 (1=low power ... 3=peak):")
    print("".join(str(int(v) + 1) for v in pl[:100, 0]))

    tab = synfire_power_table(recs)
    print(f"\nonly-PL3: total {tab['pl3']['total']:.1f} mW   "
          f"DVFS: total {tab['dvfs']['total']:.1f} mW   "
          f"reduction {tab['reduction']['total']*100:.1f}% "
          f"(paper: 60.4%)")


if __name__ == "__main__":
    main()
