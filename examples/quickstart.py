"""Quickstart: the three compute styles of the hybrid PE in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. DNN  — int8 matrix multiply on the MAC-array kernel (MM mode)
2. SNN  — fixed-point LIF neurons with exp-accelerator decay + DVFS
3. hybrid — event-triggered MAC: graded spikes x int8 weights
"""
import jax.numpy as jnp
import numpy as np

from repro.core.dvfs import DVFSController
from repro.core.hybrid import event_mac, event_mac_energy_j
from repro.core.quant import quantize_params_linear, quantized_linear
from repro.kernels.explog.ops import fx_exp_float
from repro.kernels.lif.ops import lif_params_fx, lif_step

rng = np.random.default_rng(0)

# --- 1. DNN: W8A8 linear layer on the MAC array ---------------------------
x = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
wq, ws = quantize_params_linear(w)
y = quantized_linear(x, wq, ws)
err = float(jnp.max(jnp.abs(y - x @ w)) / jnp.max(jnp.abs(x @ w)))
print(f"[DNN]    int8 MAC linear: out {y.shape}, rel err vs f32 = {err:.4f}")

# --- 2. SNN: LIF tick with accelerator-generated decay + DVFS -------------
alpha = fx_exp_float(np.float32(-1.0 / 10.0))   # exp(-dt/tau) on the accel
p = lif_params_fx(tau_ms=10.0, v_th=1.0, v_reset=0.0, ref_ticks=2)
v = jnp.zeros(256, jnp.int32)
ref = jnp.zeros(256, jnp.int32)
drive = jnp.asarray(rng.integers(0, 1 << 14, 256), jnp.int32)
v, ref, spikes = lif_step(v, ref, drive, **p)
pl = int(DVFSController().select_pl(int(spikes.sum())))
print(f"[SNN]    {int(spikes.sum())} spikes this tick -> DVFS selects "
      f"PL{pl + 1} (alpha={float(alpha):.4f})")

# --- 3. hybrid: event-triggered MAC (spikes with graded payloads) ---------
vals = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
active = jnp.asarray(rng.random(32) < 0.25)       # 25% of rows carry events
out, n_ev = event_mac(vals, active, wq, ws)
e_ratio = event_mac_energy_j(int(n_ev), 64, 32) \
    / event_mac_energy_j(32, 64, 32)
print(f"[hybrid] event-MAC: {int(n_ev)}/32 rows dispatched, "
      f"energy = {e_ratio:.2f}x of frame-based")
