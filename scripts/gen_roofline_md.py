"""Generate the EXPERIMENTS.md roofline table from artifacts/dryrun."""
import glob
import json
import sys


def fmt(v):
    return f"{v:.2e}" if v < 0.01 or v > 1000 else f"{v:.3f}"


def main(pattern="artifacts/dryrun/*pod16x16.json"):
    rows = []
    for f in sorted(glob.glob(pattern)):
        r = json.load(open(f))
        if r.get("status") != "ok":
            rows.append((r["arch"], r["shape"], "FAIL", r.get("error", "")[:60]))
            continue
        dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        mfu_bound = r["model_flops"] / (dom_s * 197e12) if dom_s else 0
        rows.append((
            r["arch"], r["shape"],
            fmt(r["compute_s"]), fmt(r["memory_s"]), fmt(r["collective_s"]),
            r["dominant"], f"{r['useful_ratio']:.2f}",
            f"{mfu_bound*100:.1f}%",
            f"{r['memory_per_device']['peak_estimate_bytes']/2**30:.1f}",
        ))
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant "
           "| useful | roofline-MFU | peak GiB |")
    print(hdr)
    print("|" + "---|" * 9)
    for row in rows:
        print("| " + " | ".join(str(c) for c in row) + " |")


if __name__ == "__main__":
    main(*sys.argv[1:])
