import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
)

"""Perf-iteration harness: run one dry-run cell with config overrides and
print the roofline delta vs the baseline artifact.

    PYTHONPATH=src python scripts/hillclimb.py --arch gemma3-27b \
        --shape prefill_32k --set attn_impl=packed --tag packed_attn
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax

from repro import configs
from repro import roofline as RL
from repro.dist import cells as C
from repro.launch.dryrun import extrapolated_costs
from repro.launch.mesh import make_production_mesh


def parse_val(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", nargs="*", default=[],
                    help="cfg overrides key=value (dataclasses.replace)")
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--tag", default="variant")
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args()

    cfg = configs.get_arch(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    if args.microbatch is not None:
        C.TRAIN_MICROBATCH[cfg.name] = args.microbatch
    shape = configs.SHAPES[args.shape]
    mesh = make_production_mesh()
    cell = C.make_cell(cfg, shape, mesh)

    t0 = time.time()
    with mesh:
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate_argnums
                           ).lower(*cell.args).compile()
    mem = compiled.memory_analysis()
    mem_stats = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_estimate_bytes": (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
    }
    flops, byts, coll = extrapolated_costs(cfg, shape, mesh)
    roof = RL.analyze(args.arch, args.shape, "pod16x16", mesh.devices.size,
                      flops, byts, coll, RL.model_flops(cfg, shape),
                      mem_stats, note=args.tag)
    rec = dataclasses.asdict(roof)
    rec["overrides"] = overrides
    rec["wall_s"] = round(time.time() - t0, 1)

    base_path = Path("artifacts/dryrun") / \
        f"{args.arch}_{args.shape}_pod16x16.json"
    if base_path.exists():
        base = json.loads(base_path.read_text())
        if base.get("status") == "ok":
            for term in ("compute_s", "memory_s", "collective_s"):
                b, n = base[term], rec[term]
                rec[f"delta_{term}"] = f"{(n - b) / max(b, 1e-30) * 100:+.1f}%"
            rec["baseline"] = {k: base[k] for k in
                               ("compute_s", "memory_s", "collective_s",
                                "dominant", "useful_ratio")}
            rec["baseline"]["peak_GiB"] = \
                base["memory_per_device"]["peak_estimate_bytes"] / 2**30
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    out = out_dir / f"{args.arch}_{args.shape}_{args.tag}.json"
    out.write_text(json.dumps(rec, indent=1))
    print(json.dumps({k: rec[k] for k in
                      ("compute_s", "memory_s", "collective_s", "dominant",
                       "useful_ratio") if k in rec}, indent=1))
    for k in ("delta_compute_s", "delta_memory_s", "delta_collective_s"):
        if k in rec:
            print(f"{k}: {rec[k]}")
    print(f"peak_GiB: {mem_stats['peak_estimate_bytes']/2**30:.2f}")
    print(f"written: {out}")


if __name__ == "__main__":
    main()
