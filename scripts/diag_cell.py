import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # CPU-only artifact suppression: XLA:CPU converts bf16 dot operands to
    # f32 and LICM hoists whole-cache converts out of the layer scan, which
    # would falsely dominate the memory analysis (a TPU bf16 MXU dot has no
    # such convert).  Keeping the convert inside the loop makes
    # memory_analysis faithful to the TPU target.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
)

"""Diagnostic: compile one dry-run cell and dump the largest HLO buffers."""
import argparse
import collections
import re

import jax

from repro import configs
from repro.dist.cells import make_cell
from repro.launch.mesh import make_production_mesh

DT = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "pred": 1, "s8": 1, "u8": 1,
      "f16": 2, "s64": 8, "u64": 8}
PAT = re.compile(r"=\s+(f32|bf16|s32|u32|pred|s8|u8|f16|s64|u64)\[([0-9,]+)\]\S*\s+([\w-]+)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--min-gib", type=float, default=0.25)
    args = ap.parse_args()

    cfg = configs.get_arch(args.arch)
    shape = configs.SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi)
    cell = make_cell(cfg, shape, mesh)
    with mesh:
        compiled = jax.jit(
            cell.fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums).lower(*cell.args).compile()
    hlo = compiled.as_text()
    agg = collections.Counter()
    example = {}
    for line in hlo.splitlines():
        m = PAT.search(line)
        if not m:
            continue
        dt, dims, op = m.groups()
        n = 1
        for d in dims.split(","):
            n *= int(d)
        sz = n * DT[dt]
        if sz >= args.min_gib * 2**30:
            key = f"{dt}[{dims}]"
            agg[key] += 1
            example.setdefault(key, (op, line.strip()[:150]))

    def keysize(key):
        dtn, dims = key.split("[")
        n = 1
        for d in dims.rstrip("]").split(","):
            n *= int(d)
        return n * DT[dtn]

    for key in sorted(agg, key=keysize, reverse=True)[: args.top]:
        op, line = example[key]
        print(f"{keysize(key)/2**30:8.2f} GiB x{agg[key]:3d}  {key}  {op} | {line[:110]}")
    ma = compiled.memory_analysis()
    print(f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB arg={ma.argument_size_in_bytes/2**30:.2f} "
          f"out={ma.output_size_in_bytes/2**30:.2f} alias={ma.alias_size_in_bytes/2**30:.2f}")


if __name__ == "__main__":
    main()
