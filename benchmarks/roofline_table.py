"""Roofline table from the dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json (produced by repro.launch.dryrun) and prints
one row per (arch x shape x mesh): the three roofline terms, dominant
bottleneck, useful-flops ratio, and per-device peak memory.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def main(pattern: str = "artifacts/dryrun/*.json") -> None:
    files = sorted(glob.glob(pattern))
    if not files:
        emit("roofline_table", 0.0, "no_artifacts;run=python -m repro.launch.dryrun")
        return
    for f in files:
        r = json.load(open(f))
        tag = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r.get("status") != "ok":
            emit(tag, 0.0, f"status=FAIL;{r.get('error', '')[:100]}")
            continue
        peak = r["memory_per_device"]["peak_estimate_bytes"] / 2**30
        emit(tag, r.get("compile_s", 0.0) * 1e6,
             f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
             f"collective_s={r['collective_s']:.3e};dom={r['dominant']};"
             f"useful_ratio={r['useful_ratio']:.2f};peak_GiB={peak:.2f}")


if __name__ == "__main__":
    main()
