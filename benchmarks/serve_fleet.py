"""Serving-tier benchmark: vmapped board fleets under user traffic —
the numbers behind BENCH_pr7.json.

Per row, one ``FleetEngine`` serves a Poisson arrival stream of user
sessions end-to-end (admission queue -> QueueDVFS width -> vmapped tick
scan -> streamed outputs -> completion), and reports:

* **throughput** — sessions/sec and instance-ticks/sec at the wall;
* **latency** — p50/p99 request latency (submit -> completion, queue
  wait included) and p50/p99 per-tick wall latency of the batched scan;
* **energy** — simulated joules/request (Eq. (1) DVFS datapath + NoC
  traffic + learning engine, summed over each session's ticks);
* **elasticity** — the width histogram and preemption count the
  spike-FIFO -> performance-level scheduling produced under the burst
  pattern.

The headline rows run a >= 64-instance fleet on both served scenarios
(adaptive control with per-session PES learning, and the KWS hybrid
farm).  ``--fleet`` scales the whole grid down for CI smoke runs.
"""
from __future__ import annotations

import time

from benchmarks.common import RESULTS, emit
from repro.core.dvfs import QueueDVFS
from repro.serve.fleet import FleetEngine, PoissonTraffic, SCENARIOS


def _dvfs_for(fleet: int) -> QueueDVFS:
    """Batch levels at fleet/4, fleet/2, fleet; thresholds scale with
    the levels so bursts actually climb the ladder."""
    lo = max(1, fleet // 4)
    mid = max(1, fleet // 2)
    return QueueDVFS(thresholds=(max(2, lo // 2), max(3, mid // 2)),
                     batch_levels=(lo, mid, fleet))


def bench_fleet(scenario: str, fleet: int, n_sessions: int, rate: float,
                round_ticks: int, tick_range: tuple, seed: int = 0,
                board: str | None = None, chip: str = "2x2",
                obs: bool = False, span_log: str | None = None) -> dict:
    """One fleet-serve row.  With ``obs`` the engine runs fully
    instrumented (spans + metrics + SLO monitor): the row name gains an
    ``_obs`` suffix (so off/on pairs coexist in one artifact and the
    obs overhead is a row-ratio), the metrics snapshot is merged into
    the row's ``values``, the span log optionally lands at ``span_log``
    and a ``critical`` health verdict fails the benchmark."""
    if scenario == "adaptive":
        sc = SCENARIOS[scenario](n_channels=1, n_neurons=64)
    else:
        sc = SCENARIOS[scenario](n_pairs=1, n_neurons=64, hidden=16)
    bd = None
    if board is not None:
        from repro.board import BoardSpec
        bd = BoardSpec.parse(board, chip=chip)
    eng = FleetEngine(sc, round_ticks=round_ticks, dvfs=_dvfs_for(fleet),
                      board=bd, keep_outputs=False, obs=obs)
    tr = PoissonTraffic(rate=rate, n_sessions=n_sessions,
                        tick_range=tick_range, seed=seed)
    t0 = time.perf_counter()
    out = eng.serve(tr)
    wall_s = time.perf_counter() - t0
    st = out["stats"]
    if st["completed"] != n_sessions:
        raise RuntimeError(f"fleet served {st['completed']}/{n_sessions} "
                           "sessions — the stream must drain completely")

    where = f"board{board}" if board else "chip"
    name = f"serve_fleet_{scenario}_{where}_w{fleet}" + \
        ("_obs" if obs else "")
    tick_p50_us = st["tick_latency_s"]["p50"] * 1e6
    widths = ",".join(f"{k}:{v}" for k, v in st["width_hist"].items())
    emit(name, tick_p50_us,
         f"fleet={fleet};sessions={n_sessions};rate={rate};"
         f"round_ticks={round_ticks};pes={eng.program.n_pes};"
         f"sessions_per_s={st['sessions_per_s']:.3f};"
         f"ticks_per_s={st['ticks_per_s']:.0f};"
         f"req_p50_s={st['request_latency_s']['p50']:.4f};"
         f"req_p99_s={st['request_latency_s']['p99']:.4f};"
         f"tick_p99_us={st['tick_latency_s']['p99'] * 1e6:.1f};"
         f"joules_per_request={st['joules_per_request']:.6f};"
         f"preemptions={st['preemptions']};rounds={st['rounds']};"
         f"queue_wait_p99_s={st['queue']['wait_p99_s']:.4f};"
         f"widths={widths};wall_s={wall_s:.2f}")

    if obs:
        o = out["obs"]
        row = RESULTS[-1]
        # metrics snapshot joins the row's machine-readable values (the
        # derived-string keys win on collision — e.g. sessions_per_s is
        # the whole-serve figure there, the last-round gauge here)
        for k, v in o["metrics"].items():
            row["values"].setdefault(k, v)
        row["values"]["health"] = o["health"]["status"]
        if span_log:
            p = o["spans"].write(span_log)
            print(f"# span log ({len(o['spans'].events)} events) -> {p}")
        if o["health"]["status"] == "critical":
            raise RuntimeError(f"fleet health CRITICAL: {o['health']}")
    return st


def main(fleet: int = 64, sessions: int = 96, rate: float = 8.0,
         round_ticks: int = 64, min_ticks: int = 128, max_ticks: int = 384,
         board: str | None = None, budget_s: float | None = None,
         obs: str = "off", span_log: str | None = None) -> None:
    t0 = time.perf_counter()
    tick_range = (min_ticks, max_ticks)
    for with_obs in {"off": (False,), "on": (True,),
                     "both": (False, True)}[obs]:
        # the span-log artifact comes from the first instrumented run
        slog = span_log if with_obs else None
        bench_fleet("adaptive", fleet, sessions, rate, round_ticks,
                    tick_range, obs=with_obs, span_log=slog)
        bench_fleet("kws", fleet, sessions, rate, round_ticks, tick_range,
                    seed=1, obs=with_obs)
        if board:
            bench_fleet("adaptive", max(1, fleet // 8),
                        max(4, sessions // 8), rate, round_ticks,
                        tick_range, seed=2, board=board, obs=with_obs)
    wall = time.perf_counter() - t0
    if budget_s is not None and wall > budget_s:
        raise RuntimeError(f"serve_fleet benchmark took {wall:.1f}s "
                           f"> budget {budget_s:.1f}s")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fleet", type=int, default=64,
                    help="top batch level (>= 64 for the headline rows)")
    ap.add_argument("--sessions", type=int, default=96)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="expected session arrivals per scheduling round")
    ap.add_argument("--round-ticks", type=int, default=64)
    ap.add_argument("--min-ticks", type=int, default=128)
    ap.add_argument("--max-ticks", type=int, default=384)
    ap.add_argument("--board", default=None,
                    help="also run a board-compiled fleet row, e.g. 2x1")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if the whole run exceeds this many seconds")
    ap.add_argument("--obs", choices=("off", "on", "both"), default="off",
                    help="serve uninstrumented, instrumented (spans + "
                         "metrics + SLO gate, rows suffixed _obs), or "
                         "both back to back (overhead as a row pair)")
    ap.add_argument("--span-log", default=None, metavar="PATH",
                    help="write the first instrumented run's span log "
                         "here (.json / .json.gz)")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    main(fleet=args.fleet, sessions=args.sessions, rate=args.rate,
         round_ticks=args.round_ticks, min_ticks=args.min_ticks,
         max_ticks=args.max_ticks, board=args.board,
         budget_s=args.budget_s, obs=args.obs, span_log=args.span_log)

    if args.json:
        from repro.obs import write_bench_json
        write_bench_json(args.json, RESULTS, config=vars(args))
