"""Benchmark driver — one section per paper table/figure plus framework
benches.  Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only mac,synfire,...] \
        [--json artifacts/BENCH_latest.json]
"""
from __future__ import annotations

import argparse
import sys
import traceback

SECTIONS = [
    ("mac", "benchmarks.mac_efficiency", "Fig. 14/15 CoreMark + MAC TOPS/W"),
    ("synfire", "benchmarks.synfire", "Table III synfire DVFS power"),
    ("chip", "benchmarks.chip_scale", "chip-level mesh: power + link load"),
    ("nef", "benchmarks.nef_channel", "Fig. 20/21 NEF channel + pJ/synop"),
    ("dnn", "benchmarks.dnn_layers", "Fig. 22/23 DNN layer speedups"),
    ("lm", "benchmarks.lm_step", "framework LM step throughput"),
    ("roofline", "benchmarks.roofline_table", "dry-run roofline table"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of sections: "
                    + ",".join(k for k, _, _ in SECTIONS))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as machine-readable JSON")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failed = []
    for key, mod_name, desc in SECTIONS:
        if want and key not in want:
            continue
        print(f"# --- {key}: {desc}", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
        except Exception as e:  # noqa: BLE001 — keep the harness running
            failed.append(key)
            print(f"# {key} FAILED: {e}")
            traceback.print_exc()

    if args.json:
        from benchmarks.common import RESULTS
        from repro.obs import write_bench_json
        # the same manifest-stamped payload the scale benchmarks emit, so
        # every BENCH artifact is self-describing (git sha, versions,
        # host, timestamp)
        write_bench_json(args.json, RESULTS, failed_sections=failed,
                         config={"only": args.only})

    if failed:
        print(f"# sections failed: {failed}")
        sys.exit(1)
    print("# all sections complete")


if __name__ == "__main__":
    main()
