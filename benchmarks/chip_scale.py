"""Chip-scale sweep: compiled workload programs vs. mesh size.

SpiNNCer's result at network scale is that peak COMMUNICATION traffic,
not neuron compute, becomes the bottleneck — this sweep reports exactly
that, now for all three workload classes through the unified
graph -> compile -> ChipProgram pipeline:

* synfire rings 8 -> 64+ PEs: per-PE power stays flat (the DVFS point of
  the paper) while peak link load tracks the wave.
* the tiled-DNN pipeline: frames streamed tick-by-tick, graded activation
  bursts priced in DNoC flits, pipeline latency + MAC/NoC energy.
* the hybrid NEF -> event-MAC program: spike-vector payloads over the
  mesh, event-vs-frame energy, graded-payload conservation.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.compile import compile as compile_graph
from repro.chip.workloads import (hybrid_workload, synfire_graph,
                                  tiled_dnn_workload)


def main(sizes=(8, 16, 32, 64), ticks_per_pe: int = 12) -> None:
    for n_pes in sizes:
        sim = ChipSim(compile_graph(synfire_graph(n_pes)))
        n_ticks = max(300, ticks_per_pe * n_pes)   # >= one full ring period
        # wall time includes the scan trace (run() is cold each call);
        # block_until_ready so async dispatch doesn't fake the number
        t0 = time.perf_counter()
        recs = jax.block_until_ready(sim.run(n_ticks))
        us = (time.perf_counter() - t0) / n_ticks * 1e6
        tab = chip_power_table(sim, recs)
        m = tab["mesh"]
        emit(f"chip_synfire_{n_pes}pe", us,
             f"mesh={m[0]}x{m[1]};links={tab['noc']['n_links']};"
             f"perPE_dvfs_mW={tab['per_pe']['dvfs']['total']:.1f};"
             f"chip_dvfs_mW={tab['chip']['dvfs']['total']:.0f};"
             f"chip_pl3_mW={tab['chip']['pl3']['total']:.0f};"
             f"noc_uW={tab['noc']['power_mw']*1e3:.2f};"
             f"peak_link={tab['noc']['peak_link_load']:.0f};"
             f"peak_util={tab['noc']['peak_utilization']:.4f};"
             f"worst_hops={tab['noc']['worst_tree_hops']}")

    # tiled DNN: the compiled program streams frames tick-by-tick
    t0 = time.perf_counter()
    rep = jax.block_until_ready(tiled_dnn_workload())
    us = (time.perf_counter() - t0) * 1e6
    tab = rep["table"]
    emit("chip_tiled_dnn_program", us,
         f"pes={rep['n_pes_used']};mesh={rep['mesh'][0]}x{rep['mesh'][1]};"
         f"frames={rep['n_frames_out']};"
         f"latency_ms={rep['latency_s']*1e3:.1f};"
         f"compute_ms={rep['compute_s']*1e3:.1f};"
         f"mac_uJ={rep['energy_mac_j']*1e6:.2f};"
         f"noc_uJ={rep['energy_noc_j']*1e6:.3f};"
         f"peak_link_flits={rep['peak_link_flits']:.0f};"
         f"perPE_dvfs_mW={tab['per_pe']['dvfs']['total']:.1f}")

    # hybrid NEF -> event-MAC: graded spike-vector payloads over the mesh
    t0 = time.perf_counter()
    h = jax.block_until_ready(hybrid_workload(n_ticks=600))
    us = (time.perf_counter() - t0) * 1e6
    conserved = int(np.array_equal(h["graded_bits_out"][:-1],
                                   h["graded_bits_in"][1:]))
    emit("chip_hybrid_program", us,
         f"rmse={h['rmse']:.3f};event_vs_frame={h['event_vs_frame']:.4f};"
         f"spikes={h['total_spikes']:.0f};duty={h['duty_cycle']:.3f};"
         f"pj_per_eq_synop={h['synops']['pj_per_eq_synop']:.1f};"
         f"noc_nJ={h['energy_noc_j']*1e9:.2f};"
         f"payload_conserved={conserved}")


if __name__ == "__main__":
    main()
