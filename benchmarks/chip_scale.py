"""Chip-scale sweep: compiled workload programs vs. mesh size.

SpiNNCer's result at network scale is that peak COMMUNICATION traffic,
not neuron compute, becomes the bottleneck — this sweep reports exactly
that, now for all three workload classes through the unified
graph -> compile -> ChipProgram pipeline:

* synfire rings 8 -> 64+ PEs: per-PE power stays flat (the DVFS point of
  the paper) while peak link load tracks the wave.
* the tiled-DNN pipeline: frames streamed tick-by-tick, graded activation
  bursts priced in DNoC flits, pipeline latency + MAC/NoC energy.
* the hybrid NEF -> event-MAC program: spike-vector payloads over the
  mesh, event-vs-frame energy, graded-payload conservation.

The board-scale sweep (``--sweep 256,1024,4096``) takes the same three
classes to 1000+ PE meshes through the SPARSE NoC path, reporting graph
build, compile and per-tick engine time separately plus a sparse-vs-dense
microbench of the per-tick link/flit accounting — the numbers behind
BENCH_pr3.json.  ``--probe-overhead`` additionally times the engine with
the default telemetry probe set compiled into the scan (the < 10%
overhead budget of BENCH_pr6.json); ``--exec-mode event|both`` times the
activity-compressed event engine next to (or instead of) the dense rows
and ``--activity`` stamps each row with its mean active-source fraction —
the dense-vs-event pairs behind BENCH_pr8.json; ``--json`` writes a
manifest-stamped artifact.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.compile import compile as compile_graph
from repro.chip.workloads import (dnn_graph, hybrid_farm_graph,
                                  hybrid_workload, synfire_graph,
                                  tiled_dnn_workload)
from repro.configs import paper
from repro.core.pe import PESpec, partition_layer_to_sram
from repro.obs import PhaseTimers, default_probes, record_link_profile


def main(sizes=(8, 16, 32, 64), ticks_per_pe: int = 12) -> None:
    for n_pes in sizes:
        sim = ChipSim(compile_graph(synfire_graph(n_pes)))
        n_ticks = max(300, ticks_per_pe * n_pes)   # >= one full ring period
        # wall time includes the scan trace (run() is cold each call);
        # block_until_ready so async dispatch doesn't fake the number
        t0 = time.perf_counter()
        recs = jax.block_until_ready(sim.run(n_ticks))
        us = (time.perf_counter() - t0) / n_ticks * 1e6
        tab = chip_power_table(sim, recs)
        m = tab["mesh"]
        emit(f"chip_synfire_{n_pes}pe", us,
             f"mesh={m[0]}x{m[1]};links={tab['noc']['n_links']};"
             f"perPE_dvfs_mW={tab['per_pe']['dvfs']['total']:.1f};"
             f"chip_dvfs_mW={tab['chip']['dvfs']['total']:.0f};"
             f"chip_pl3_mW={tab['chip']['pl3']['total']:.0f};"
             f"noc_uW={tab['noc']['power_mw']*1e3:.2f};"
             f"peak_link={tab['noc']['peak_link_load']:.0f};"
             f"peak_util={tab['noc']['peak_utilization']:.4f};"
             f"worst_hops={tab['noc']['worst_tree_hops']}")

    # tiled DNN: the compiled program streams frames tick-by-tick
    t0 = time.perf_counter()
    rep = jax.block_until_ready(tiled_dnn_workload())
    us = (time.perf_counter() - t0) * 1e6
    tab = rep["table"]
    emit("chip_tiled_dnn_program", us,
         f"pes={rep['n_pes_used']};mesh={rep['mesh'][0]}x{rep['mesh'][1]};"
         f"frames={rep['n_frames_out']};"
         f"latency_ms={rep['latency_s']*1e3:.1f};"
         f"compute_ms={rep['compute_s']*1e3:.1f};"
         f"mac_uJ={rep['energy_mac_j']*1e6:.2f};"
         f"noc_uJ={rep['energy_noc_j']*1e6:.3f};"
         f"peak_link_flits={rep['peak_link_flits']:.0f};"
         f"perPE_dvfs_mW={tab['per_pe']['dvfs']['total']:.1f}")

    # hybrid NEF -> event-MAC: graded spike-vector payloads over the mesh
    t0 = time.perf_counter()
    h = jax.block_until_ready(hybrid_workload(n_ticks=600))
    us = (time.perf_counter() - t0) * 1e6
    conserved = int(np.array_equal(h["graded_bits_out"][:-1],
                                   h["graded_bits_in"][1:]))
    emit("chip_hybrid_program", us,
         f"rmse={h['rmse']:.3f};event_vs_frame={h['event_vs_frame']:.4f};"
         f"spikes={h['total_spikes']:.0f};duty={h['duty_cycle']:.3f};"
         f"pj_per_eq_synop={h['synops']['pj_per_eq_synop']:.1f};"
         f"noc_nJ={h['energy_noc_j']*1e9:.2f};"
         f"payload_conserved={conserved}")


# -------------------------------------------------------------------------
# Board-scale sweep (256 -> 1024 -> 4096 PEs) through the sparse NoC path
# -------------------------------------------------------------------------

# per-core neuron counts scaled down from Table II so a 4096-PE ring's
# weight tensors stay in laptop memory — the mesh/NoC work, which is what
# this sweep measures, is unchanged
SCALED_SYNFIRE = dataclasses.replace(
    paper.SYNFIRE, n_exc=16, n_inh=4, neurons_per_core=20,
    synapses_per_core=400, fan_in_exc=8, fan_in_inh=4, l_th1=2, l_th2=7)

# template conv layer that splits into ~13 tiles under the 128 kB SRAM
SCALE_DNN_LAYER = dict(h=64, w=64, cin=32, cout=64, kh=3, kw=3)


def dnn_layers_for_pes(n_pes: int, pe: PESpec = PESpec()) -> list:
    """Repeat the template layer until the tiled stack fills ~n_pes PEs."""
    _, _, tiles = partition_layer_to_sram(
        pe, **{k: SCALE_DNN_LAYER[k] for k in ("h", "w", "cin", "cout",
                                               "kh", "kw")})
    n_layers = max(2, -(-n_pes // tiles))
    return [dict(SCALE_DNN_LAYER, name=f"conv{i}") for i in range(n_layers)]


def build_scaled_graph(cls: str, n_pes: int):
    if cls == "synfire":
        # shot-noise drive (deterministic per (seed, tick)) with the
        # Gaussian sub-threshold jitter off: the wave still propagates
        # (~1.6 spikes/tick ring-wide) but the background is silent, so
        # the sweep exercises the activity sparsity the event engine
        # compresses.  Dense tick cost is activity-independent, so the
        # dense rows stay comparable to earlier BENCH artifacts.
        return synfire_graph(n_pes, sp=SCALED_SYNFIRE, w_exc=0.25,
                             noise_sigma=0.0, noise_model="shot")
    if cls == "dnn":
        return dnn_graph(dnn_layers_for_pes(n_pes))
    if cls == "hybrid":
        return hybrid_farm_graph(n_pairs=n_pes // 2, n_neurons=32, hidden=16)
    raise ValueError(cls)


def sweep(sizes=(256, 1024, 4096), n_ticks: int = 64,
          classes=("synfire", "dnn", "hybrid"),
          compile_budget_s: float | None = None,
          noc_batch: int = 64, profile_links: bool = False,
          probe_overhead: bool = False, exec_mode: str = "dense",
          activity: bool = False) -> dict:
    """Compile + run each workload class at each mesh size.

    Reported separately per (class, size):
      build_s    — graph construction (weights, drive tables; not ours)
      compile_s  — place + route + CSR incidence (the vectorized compiler)
      jit_s      — first runner call (scan trace + XLA compile, cold)
      tick_us    — engine wall time per tick, auto-selected NoC path
      noc_sparse_us / noc_dense_us — per-tick link+flit accounting alone
                   (jit'd, warmed, batched over ``noc_batch`` ticks), the
                   sparse gather+segment-sum vs the dense einsum
      probe_us / probe_overhead — (with ``probe_overhead=True``) per-tick
                   wall time with the default telemetry probe set in the
                   scan carry, and its relative cost vs the bare engine

    ``exec_mode`` selects the engine execution mode for the timed rows:
    ``"dense"`` (the always-on per-PE tick, baseline-comparable),
    ``"event"`` (activity-compressed ticks, rows suffixed ``_event``) or
    ``"both"`` — a dense/event row PAIR per (class, size), the event row
    carrying ``dense_tick_us`` + ``event_vs_dense`` speedup.  With
    ``activity=True`` each row also reports the run's mean
    ``active_frac`` (active sources / sources per tick).

    ``profile_links`` records per-link peak/mean flit profiles for each
    class's largest mesh through the whole-run link probes (parity with
    ``board_scale.py``), feeding the congestion-aware-routing roadmap
    item from single-chip runs too.  Returns ``{"link_profiles": ...,
    "phase_timers": ...}`` for the JSON artifact.
    """
    rng = np.random.default_rng(0)
    link_profiles: dict = {}
    phase_timers: dict = {}
    for cls in classes:
        for n_pes in sizes:
            tm = PhaseTimers()
            with tm.phase("build"):
                graph = build_scaled_graph(cls, n_pes)
            with tm.phase("compile"):
                prog = compile_graph(graph)
            if compile_budget_s is not None and \
                    tm["compile"] > compile_budget_s:
                raise RuntimeError(
                    f"{cls}@{n_pes}: compile took {tm['compile']:.2f}s "
                    f"> budget {compile_budget_s:.2f}s")

            # engine per-tick, auto-selected NoC path, compiled-once scan:
            # the first call pays the scan trace + XLA compile, the
            # steady-state median is the per-tick number
            modes = ("dense", "event") if exec_mode == "both" \
                else (exec_mode,)
            mode_us: dict = {}
            mode_frac: dict = {}
            sim = None
            for mode in modes:
                msim = ChipSim(prog, exec_mode=mode)
                sim = sim or msim
                runner = jax.jit(lambda s=msim: s.run(n_ticks))
                tag = "first_tick_jit" if mode == modes[0] \
                    else f"first_tick_jit_{mode}"
                with tm.phase(tag):
                    jax.block_until_ready(runner())
                mode_us[mode] = time_call(runner, warmup=0,
                                          iters=3) / n_ticks
                if activity:
                    frac = runner().get("active_frac")
                    if frac is not None:
                        mode_frac[mode] = float(np.asarray(frac).mean())
            tick_us = mode_us[modes[0]]
            tm.record("steady_tick", tick_us * 1e-6)

            probe_str = ""
            if probe_overhead:
                probes = default_probes(prog)
                prunner = jax.jit(lambda: sim.run(n_ticks, probes=probes))
                probe_us = time_call(prunner, warmup=1, iters=3) / n_ticks
                probe_str = (f";probe_us={probe_us:.1f};"
                             f"probe_overhead={probe_us / tick_us - 1:.4f}")

            if profile_links and n_pes == max(sizes):
                # whole-run per-link peak/mean through the probe layer —
                # O(n_links) memory regardless of n_ticks
                link_profiles[f"scale_{cls}_{prog.n_pes}pe"] = \
                    record_link_profile(sim, n_ticks)

            # NoC accounting alone, per tick inside a scan (how the engine
            # pays it): sparse column plan vs dense einsum
            noc = prog.noc
            P = prog.n_pes
            pk0 = jnp.asarray(rng.integers(0, 4, P).astype(np.float32))
            pb = jnp.asarray(prog.payload_bits)
            cols, inv = prog.sinc.device_col_plan()
            inc = jnp.asarray(prog.inc)

            def loads_scan(fn):
                def step(carry, t):
                    p = pk0 * (t % 3).astype(jnp.float32)
                    ll, fl = fn(p)
                    return carry + ll.sum() + fl.sum(), None
                return jax.lax.scan(step, jnp.float32(0),
                                    jnp.arange(noc_batch))[0]

            f_sp = jax.jit(lambda: loads_scan(
                lambda p: noc.noc_loads_sparse(p, cols, inv, pb)))
            f_de = jax.jit(lambda: loads_scan(
                lambda p: (noc.link_loads(p, inc),
                           noc.flit_loads(p, inc, pb))))
            # min over rounds: wall-clock noise is one-sided, the minimum
            # is the best estimator of the true per-tick cost
            sp_us = min(time_call(f_sp, iters=5) for _ in range(3)) \
                / noc_batch
            de_us = min(time_call(f_de, iters=5) for _ in range(3)) \
                / noc_batch

            base = f"scale_{cls}_{P}pe"
            phase_timers[base] = tm.asdict()
            shared = (
                f"mesh={prog.mesh.width}x{prog.mesh.height};"
                f"links={noc.n_links};nnz={prog.sinc.nnz};"
                f"density={prog.sinc.density:.4f};"
                f"build_s={tm['build']:.3f};compile_s={tm['compile']:.3f};"
                f"jit_s={tm['first_tick_jit']:.3f};"
                f"noc_sparse_us={sp_us:.2f};noc_dense_us={de_us:.2f};"
                f"noc_speedup={de_us / sp_us:.2f};"
                f"worst_hops={prog.worst_tree_hops}{probe_str}")
            for mode in modes:
                name = base if mode == "dense" else f"{base}_{mode}"
                extra = f";exec_mode={mode}"
                if mode in mode_frac:
                    extra += f";active_frac={mode_frac[mode]:.4f}"
                if mode == "event" and "dense" in mode_us:
                    extra += (f";dense_tick_us={mode_us['dense']:.1f};"
                              f"event_vs_dense="
                              f"{mode_us['dense'] / mode_us[mode]:.2f}")
                emit(name, mode_us[mode], shared + extra)
    return {"link_profiles": link_profiles, "phase_timers": phase_timers}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep", default=None, metavar="SIZES",
                    help="comma list of PE counts, e.g. 256,1024,4096 — "
                    "run the board-scale sweep instead of the CI smoke")
    ap.add_argument("--classes", default="synfire,dnn,hybrid")
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if any compile exceeds this many seconds")
    ap.add_argument("--profile-links", action="store_true",
                    help="record per-link peak/mean load profiles for "
                    "each class's largest mesh (parity with board_scale)")
    ap.add_argument("--probe-overhead", action="store_true",
                    help="also time the engine with the default telemetry "
                    "probe set (the BENCH_pr6 < 10%% overhead budget)")
    ap.add_argument("--exec-mode", default="dense",
                    choices=["dense", "event", "both"],
                    help="engine execution mode for the sweep rows; "
                    "'both' emits a dense/event row pair per (class, "
                    "size) with the event-vs-dense speedup")
    ap.add_argument("--activity", action="store_true",
                    help="record a run per mode and add its mean "
                    "active-source fraction to each sweep row")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as machine-readable JSON "
                    "(manifest-stamped)")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    extras: dict = {}
    if args.sweep:
        extras = sweep(sizes=tuple(int(s) for s in args.sweep.split(",")),
                       n_ticks=args.ticks,
                       classes=tuple(args.classes.split(",")),
                       compile_budget_s=args.budget_s,
                       profile_links=args.profile_links,
                       probe_overhead=args.probe_overhead,
                       exec_mode=args.exec_mode,
                       activity=args.activity)
    else:
        main()

    if args.json:
        from benchmarks.common import RESULTS
        from repro.obs import write_bench_json
        write_bench_json(args.json, RESULTS,
                         link_profiles=extras.get("link_profiles", {}),
                         timers=extras.get("phase_timers"),
                         config=vars(args))
