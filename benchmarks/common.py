"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup=1, iters=3, **kw):
    """Median wall time per call in microseconds (CPU, interpret-mode)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
