"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax

# every emit() also lands here so the driver can dump a machine-readable
# artifact (benchmarks/run.py --json)
RESULTS: list[dict] = []


def time_call(fn, *args, warmup=1, iters=3, **kw):
    """Median wall time per call in microseconds (CPU, interpret-mode)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def _parse_derived(derived: str) -> dict:
    """'a=1;b=x' -> {'a': 1.0, 'b': 'x'} (numbers parsed where possible)."""
    out = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v)
        except ValueError:
            out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
    RESULTS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived, "values": _parse_derived(derived)})
