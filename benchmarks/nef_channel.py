"""Fig. 20 + Fig. 21: NEF communication channel — decoded-output fidelity
and energy per (equivalent) synaptic event vs dimensions, against the
Loihi 24 pJ/synop reference point."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs import paper
from repro.core.nef import build_ensemble, run_channel, synop_metrics


def main(n_neurons: int = 512, ticks: int = 1200) -> None:
    # Fig. 21 plots energy/synop against population mean firing rate; we
    # sweep the drive amplitude to cover the rate axis (dims=1 column) and
    # sweep dims at fixed amplitude (the paper's dimensionality trend).
    for dims, amp in [(1, 0.4), (1, 0.8), (1, 1.4), (2, 0.8), (4, 0.8),
                      (8, 0.8), (16, 0.8)]:
        ens = build_ensemble(n_neurons, dims, seed=dims)
        t = np.arange(ticks)
        phases = np.linspace(0, np.pi, dims, endpoint=False)
        x = amp * np.sin(2 * np.pi * t[:, None] / 400 + phases[None, :]) \
            / np.sqrt(dims)
        t0 = time.perf_counter()
        out = run_channel(ens, x, use_mac=(dims == 1))
        us = (time.perf_counter() - t0) / ticks * 1e6
        rmse = float(np.sqrt(np.mean((out["xhat"][300:] - x[300:]) ** 2)))

        # dynamic energy per tick (the paper measures whole-core dynamic
        # power): N LIF updates on the Arm core (Table I e_neur), N*D MACs
        # on the array, D event-driven decode adds per spike
        mac_j_per_op = 1.0 / (paper.MAC_TOPS_PER_W[(0.50, 200e6)]
                              / paper.MAC_HW_BUG_FACTOR * 1e12)
        e_tick = (n_neurons * paper.NEF_E_NEURON_J
                  + 2.0 * n_neurons * dims * mac_j_per_op
                  + out["spikes_per_tick"] * dims * paper.PL2.e_synapse_j)
        m = synop_metrics(ens, out["spikes_per_tick"], e_tick)
        beats_loihi = m["pj_per_eq_synop"] < paper.LOIHI_PJ_PER_SYNOP
        emit(f"fig21_nef_D{dims}_amp{amp}", us,
             f"rmse={rmse:.3f};rate_hz={m['mean_rate_hz']:.1f};"
             f"pJ_eq_synop={m['pj_per_eq_synop']:.1f};"
             f"pJ_hw_synop={m['pj_per_hw_synop']:.1f};"
             f"loihi=24.0;beats_loihi={beats_loihi}")


if __name__ == "__main__":
    main()
