"""Fig. 14 + Fig. 15: PE CoreMark efficiency and MAC-array matrix-multiply
energy efficiency at the DVFS performance levels.

The kernel's correctness is executed (interpret mode); energy derives from
the cycle model (core/pe.py) + the paper's measured operating points.
Checks: modeled TOPS/W lands on the measured 1.47 / 1.51 (and 1.75 at the
0.5 V / 320 MHz point) within 10%, including the paper's 1.56x data-path
bug derating.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.configs import paper
from repro.core.pe import PESpec
from repro.kernels.mac_gemm import mac_gemm, mac_gemm_ref


def modeled_tops_per_w(vdd: float, freq_hz: float) -> float:
    """TOPS/W of the MAC array running MM from local SRAM.

    Two-parameter model P = P0 + c * f * (V/0.5)^2: a fixed overhead
    (leakage + clocking, amortized at higher f — this is why the measured
    efficiency RISES from 1.47 to 1.75 between 200 and 320 MHz) plus CV^2f
    switching.  Fitted on the (0.5 V, 200 MHz) and (0.5 V, 320 MHz)
    measurements; the (0.6 V, 400 MHz) point validates within 10%.
    """
    pe = PESpec()
    ops = lambda f: 2 * pe.macs_per_cycle * f
    p200 = ops(200e6) / (paper.MAC_TOPS_PER_W[(0.50, 200e6)] * 1e12)
    p320 = ops(320e6) / (paper.MAC_TOPS_PER_W[(0.50, 320e6)] * 1e12)
    c = (p320 - p200) / (320e6 - 200e6)
    p0 = p200 - c * 200e6
    p = p0 + c * freq_hz * (vdd / 0.50) ** 2
    return ops(freq_hz) / p / 1e12


def main() -> None:
    # Fig. 14 — CoreMark uW/MHz at the two PLs (anchored constants)
    for (v, f), uw in paper.COREMARK_UW_PER_MHZ.items():
        emit(f"fig14_coremark_{int(v*100)}V_{int(f/1e6)}MHz", 0.0,
             f"uW_per_MHz={uw}")

    # Fig. 15 — MAC MM efficiency: execute the kernel + model the energy
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, 255, (64, 128)), np.uint8)
    b = jnp.asarray(rng.integers(0, 255, (128, 64)), np.uint8)
    us = time_call(mac_gemm, a, b)
    assert bool(jnp.all(mac_gemm(a, b) == mac_gemm_ref(a, b)))

    for (v, f), measured in paper.MAC_TOPS_PER_W.items():
        got = modeled_tops_per_w(v, f)
        ok = abs(got - measured) / measured < 0.10
        emit(f"fig15_mac_mm_{int(v*100)}V_{int(f/1e6)}MHz", us,
             f"model_TOPS_W={got:.2f};paper={measured};within10pct={ok}")
    eff_bug = paper.MAC_TOPS_PER_W[(0.50, 200e6)] / paper.MAC_HW_BUG_FACTOR
    emit("fig15_mac_mm_with_hw_bug", us,
         f"effective_TOPS_W={eff_bug:.2f};derate=1.56x")


if __name__ == "__main__":
    main()
