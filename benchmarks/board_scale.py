"""Board-scale benchmark: one ``NetGraph`` compiled across multi-chip
SpiNNaker 2 boards (the numbers behind BENCH_pr4.json).

For each (workload class, board) pair this reports, separately:

  build_s      — graph construction (weights, drive tables; not ours)
  partition_s  — min-cut-flavored population -> chip assignment
  compile_s    — per-chip snake placement + hierarchical routing into
                 the board-wide CSR incidence (sub-quadratic in total
                 PEs: O(sum of stitched tree sizes))
  jit_s        — first runner call (scan trace + XLA compile, cold)
  tick_us      — engine wall time per tick through the auto-selected
                 sparse NoC path (one lax.scan for the whole board)
  xchip_*      — the traffic split: share of flits / NoC energy riding
                 the expensive chip-to-chip tier, peak chip-to-chip
                 link flits vs. capacity

The headline configuration is the 48-chip board (``--boards 4x12
--chip 4x2`` = 1536 PEs) running the hybrid NEF->event-MAC farm; the
default sweep walks 1x1 -> 2x2 -> 4x6 -> 4x12 so compile-time scaling
is visible in one artifact.  ``--profile-links`` additionally records
per-link peak/mean loads through the whole-run link probes
(``repro.obs``) — the real traffic profiles the congestion-aware-routing
roadmap item needs.  ``--json`` writes a manifest-stamped artifact.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, time_call
from repro.board import BoardSpec, compile_board, partition
from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.workloads import (dnn_board_graph, hybrid_farm_board_graph,
                                  synfire_board_graph)
from repro.obs import PhaseTimers, record_link_profile
from repro.routeopt import optimize_routes

# per-core neuron counts scaled down from Table II so a 1536-PE ring's
# weight tensors stay in laptop memory (same scaling as chip_scale.py)
from benchmarks.chip_scale import SCALED_SYNFIRE

BUILDERS = {
    "synfire": lambda b: synfire_board_graph(b, sp=SCALED_SYNFIRE),
    "dnn": dnn_board_graph,
    "hybrid": hybrid_farm_board_graph,
}


def bench_board(cls: str, board: BoardSpec, n_ticks: int = 64,
                compile_budget_s: float | None = None,
                profile_links: bool = False) -> dict:
    """One (class, board) row.  Returns ``{"name", "timers",
    "link_profile"}`` so the caller can assemble the JSON artifact
    without module-level globals."""
    tm = PhaseTimers()
    with tm.phase("build"):
        graph = BUILDERS[cls](board)
    with tm.phase("partition"):
        part = partition(graph, board)
    with tm.phase("compile"):
        prog = compile_board(graph, board, part=part)
    if compile_budget_s is not None and \
            tm["partition"] + tm["compile"] > compile_budget_s:
        raise RuntimeError(
            f"{cls}@{board.chips_x}x{board.chips_y}: partition+compile "
            f"took {tm['partition'] + tm['compile']:.2f}s > budget "
            f"{compile_budget_s:.2f}s")

    sim = ChipSim(prog)
    runner = jax.jit(lambda: sim.run(n_ticks))
    with tm.phase("first_tick_jit"):
        jax.block_until_ready(runner())
    tick_us = time_call(runner, warmup=0, iters=3) / n_ticks
    tm.record("steady_tick", tick_us * 1e-6)
    recs = jax.block_until_ready(sim.run(n_ticks))
    tab = chip_power_table(sim, recs)

    name = (f"board_{cls}_{board.chips_x}x{board.chips_y}chips_"
            f"{prog.n_pes}pe")
    x = tab["noc"].get("xchip", {})
    emit(name, tick_us,
         f"chips={board.n_chips};chip={board.chip.width}x"
         f"{board.chip.height};pes={prog.n_pes};links={prog.noc.n_links};"
         f"xlinks={prog.noc.n_xchip_links};nnz={prog.sinc.nnz};"
         f"density={prog.sinc.density:.5f};cut_flits={part.cut_flits:.0f};"
         f"build_s={tm['build']:.3f};partition_s={tm['partition']:.3f};"
         f"compile_s={tm['compile']:.3f};jit_s={tm['first_tick_jit']:.3f};"
         f"xchip_flit_frac={x.get('flits_frac', 0.0):.4f};"
         f"xchip_energy_frac={x.get('energy_frac', 0.0):.4f};"
         f"peak_xlink_flits={x.get('peak_xlink_flits', 0.0):.0f};"
         f"peak_link_flits={tab['noc']['peak_link_flits']:.0f};"
         f"noc_power_mw={tab['noc']['power_mw']:.4f};"
         f"worst_hops={prog.worst_tree_hops}")

    out = {"name": name, "timers": tm.asdict(), "link_profile": None}
    if profile_links:
        # the congestion-aware-routing seed: real per-link profiles off
        # the whole-run link probes, split at the tier boundary (ids >=
        # n_onchip_links are chip-to-chip)
        out["link_profile"] = record_link_profile(sim, n_ticks)
    return out


def bench_board_opt(cls: str, board: BoardSpec, n_ticks: int = 64,
                    opt_iters: int = 4,
                    compile_budget_s: float | None = None) -> dict:
    """The optimized twin of a ``bench_board`` row: run the
    profile-guided route/place loop (``repro.routeopt``) on the same
    (class, board) pair and emit a ``..._opt`` row carrying both sides
    — optimized peak/mean per tier next to the measured baseline — plus
    the per-iteration trajectory for the JSON artifact.  The
    optimizer's wall-clock budget is the same ``--budget-s`` the plain
    compile is held to (equal compile budget, the PR 9 gate)."""
    tm = PhaseTimers()
    with tm.phase("build"):
        graph = BUILDERS[cls](board)
    with tm.phase("optimize"):
        res = optimize_routes(graph, board, n_ticks=n_ticks,
                              max_iters=opt_iters,
                              budget_s=compile_budget_s)
    prog = res.program
    sim = ChipSim(prog)
    runner = jax.jit(lambda: sim.run(n_ticks))
    with tm.phase("first_tick_jit"):
        jax.block_until_ready(runner())
    tick_us = time_call(runner, warmup=0, iters=3) / n_ticks
    tm.record("steady_tick", tick_us * 1e-6)

    base, opt = res.baseline, res.profile
    name = (f"board_{cls}_{board.chips_x}x{board.chips_y}chips_"
            f"{prog.n_pes}pe_opt")
    emit(name, tick_us,
         f"chips={board.n_chips};pes={prog.n_pes};"
         f"ports={prog.board.ports_per_edge};"
         f"iters={res.iterations};converged={int(res.converged)};"
         f"optimize_s={tm['optimize']:.3f};"
         f"peak_xlink_flits={opt.peak_xlink:.0f};"
         f"base_peak_xlink_flits={base.peak_xlink:.0f};"
         f"mean_xlink_flits={opt.mean_xlink:.4f};"
         f"base_mean_xlink_flits={base.mean_xlink:.4f};"
         f"peak_onchip_flits={opt.peak_onchip:.0f};"
         f"base_peak_onchip_flits={base.peak_onchip:.0f};"
         f"improvement={res.improvement:.4f}")
    return {"name": name, "timers": tm.asdict(),
            "trajectory": res.trajectory}


def main(boards=("1x1", "2x2", "4x6", "4x12"), chip: str = "4x2",
         classes=("hybrid", "synfire", "dnn"), n_ticks: int = 64,
         compile_budget_s: float | None = None,
         profile_links: bool = False, route_opt: bool = False,
         opt_iters: int = 4) -> dict:
    link_profiles: dict = {}
    phase_timers: dict = {}
    route_opt_traj: dict = {}
    for cls in classes:
        for i, b in enumerate(boards):
            spec = BoardSpec.parse(b, chip=chip)
            row = bench_board(cls, spec, n_ticks=n_ticks,
                              compile_budget_s=compile_budget_s,
                              # profiles only for each class's largest board
                              profile_links=profile_links
                              and i == len(boards) - 1)
            phase_timers[row["name"]] = row["timers"]
            if row["link_profile"] is not None:
                link_profiles[row["name"]] = row["link_profile"]
            if route_opt and spec.n_chips > 1:
                orow = bench_board_opt(cls, spec, n_ticks=n_ticks,
                                       opt_iters=opt_iters,
                                       compile_budget_s=compile_budget_s)
                phase_timers[orow["name"]] = orow["timers"]
                route_opt_traj[orow["name"]] = orow["trajectory"]
    return {"link_profiles": link_profiles, "phase_timers": phase_timers,
            "route_opt": route_opt_traj}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--boards", default="1x1,2x2,4x6,4x12",
                    help="comma list of chip grids, e.g. 2x2,4x12")
    ap.add_argument("--chip", default="4x2",
                    help="per-chip QPE mesh, e.g. 4x2 (= 32 PEs)")
    ap.add_argument("--classes", default="hybrid,synfire,dnn")
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if any partition+compile exceeds this")
    ap.add_argument("--profile-links", action="store_true",
                    help="record per-link peak/mean load profiles")
    ap.add_argument("--route-opt", action="store_true",
                    help="pair each multi-chip row with a profile-guided "
                         "route/place-optimized twin (repro.routeopt)")
    ap.add_argument("--opt-iters", type=int, default=4,
                    help="max optimizer iterations per --route-opt row")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    extras = main(boards=tuple(args.boards.split(",")), chip=args.chip,
                  classes=tuple(args.classes.split(",")),
                  n_ticks=args.ticks, compile_budget_s=args.budget_s,
                  profile_links=args.profile_links,
                  route_opt=args.route_opt, opt_iters=args.opt_iters)

    if args.json:
        from benchmarks.common import RESULTS
        from repro.obs import write_bench_json
        write_bench_json(args.json, RESULTS,
                         link_profiles=extras["link_profiles"],
                         timers=extras["phase_timers"],
                         config=vars(args),
                         route_opt=extras["route_opt"])
