"""Board-scale benchmark: one ``NetGraph`` compiled across multi-chip
SpiNNaker 2 boards (the numbers behind BENCH_pr4.json).

For each (workload class, board) pair this reports, separately:

  build_s      — graph construction (weights, drive tables; not ours)
  partition_s  — min-cut-flavored population -> chip assignment
  compile_s    — per-chip snake placement + hierarchical routing into
                 the board-wide CSR incidence (sub-quadratic in total
                 PEs: O(sum of stitched tree sizes))
  tick_us      — engine wall time per tick through the auto-selected
                 sparse NoC path (one lax.scan for the whole board)
  xchip_*      — the traffic split: share of flits / NoC energy riding
                 the expensive chip-to-chip tier, peak chip-to-chip
                 link flits vs. capacity

The headline configuration is the 48-chip board (``--boards 4x12
--chip 4x2`` = 1536 PEs) running the hybrid NEF->event-MAC farm; the
default sweep walks 1x1 -> 2x2 -> 4x6 -> 4x12 so compile-time scaling
is visible in one artifact.  ``--profile-links`` additionally records
per-link peak/mean loads (cheap off the sparse records) — the real
traffic profiles the congestion-aware-routing roadmap item needs.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import RESULTS, emit, time_call
from repro.board import BoardSpec, compile_board, partition
from repro.chip.chip import ChipSim, chip_power_table
from repro.chip.workloads import (dnn_board_graph, hybrid_farm_board_graph,
                                  synfire_board_graph)

# per-core neuron counts scaled down from Table II so a 1536-PE ring's
# weight tensors stay in laptop memory (same scaling as chip_scale.py)
from benchmarks.chip_scale import SCALED_SYNFIRE

BUILDERS = {
    "synfire": lambda b: synfire_board_graph(b, sp=SCALED_SYNFIRE),
    "dnn": dnn_board_graph,
    "hybrid": hybrid_farm_board_graph,
}

# per-link profiles land here; --json writes them next to the rows
LINK_PROFILES: dict = {}


def bench_board(cls: str, board: BoardSpec, n_ticks: int = 64,
                compile_budget_s: float | None = None,
                profile_links: bool = False) -> None:
    t0 = time.perf_counter()
    graph = BUILDERS[cls](board)
    build_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    part = partition(graph, board)
    partition_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    prog = compile_board(graph, board, part=part)
    compile_s = time.perf_counter() - t0
    if compile_budget_s is not None and \
            partition_s + compile_s > compile_budget_s:
        raise RuntimeError(
            f"{cls}@{board.chips_x}x{board.chips_y}: partition+compile "
            f"took {partition_s + compile_s:.2f}s > budget "
            f"{compile_budget_s:.2f}s")

    sim = ChipSim(prog)
    runner = jax.jit(lambda: sim.run(n_ticks))
    tick_us = time_call(runner, warmup=1, iters=3) / n_ticks
    recs = jax.block_until_ready(sim.run(n_ticks))
    tab = chip_power_table(sim, recs)

    flits = np.asarray(recs["link_flits"])
    name = (f"board_{cls}_{board.chips_x}x{board.chips_y}chips_"
            f"{prog.n_pes}pe")
    x = tab["noc"].get("xchip", {})
    emit(name, tick_us,
         f"chips={board.n_chips};chip={board.chip.width}x"
         f"{board.chip.height};pes={prog.n_pes};links={prog.noc.n_links};"
         f"xlinks={prog.noc.n_xchip_links};nnz={prog.sinc.nnz};"
         f"density={prog.sinc.density:.5f};cut_flits={part.cut_flits:.0f};"
         f"build_s={build_s:.3f};partition_s={partition_s:.3f};"
         f"compile_s={compile_s:.3f};"
         f"xchip_flit_frac={x.get('flits_frac', 0.0):.4f};"
         f"xchip_energy_frac={x.get('energy_frac', 0.0):.4f};"
         f"peak_xlink_flits={x.get('peak_xlink_flits', 0.0):.0f};"
         f"peak_link_flits={tab['noc']['peak_link_flits']:.0f};"
         f"noc_power_mw={tab['noc']['power_mw']:.4f};"
         f"worst_hops={prog.worst_tree_hops}")

    if profile_links:
        # the congestion-aware-routing seed: real per-link profiles,
        # split at the tier boundary (ids >= n_onchip are chip-to-chip)
        LINK_PROFILES[name] = {
            "n_onchip_links": int(prog.noc.n_onchip_links),
            "peak": np.round(flits.max(axis=0), 2).tolist(),
            "mean": np.round(flits.mean(axis=0), 4).tolist(),
        }


def main(boards=("1x1", "2x2", "4x6", "4x12"), chip: str = "4x2",
         classes=("hybrid", "synfire", "dnn"), n_ticks: int = 64,
         compile_budget_s: float | None = None,
         profile_links: bool = False) -> None:
    for cls in classes:
        for i, b in enumerate(boards):
            spec = BoardSpec.parse(b, chip=chip)
            bench_board(cls, spec, n_ticks=n_ticks,
                        compile_budget_s=compile_budget_s,
                        # profiles only for each class's largest board
                        profile_links=profile_links
                        and i == len(boards) - 1)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--boards", default="1x1,2x2,4x6,4x12",
                    help="comma list of chip grids, e.g. 2x2,4x12")
    ap.add_argument("--chip", default="4x2",
                    help="per-chip QPE mesh, e.g. 4x2 (= 32 PEs)")
    ap.add_argument("--classes", default="hybrid,synfire,dnn")
    ap.add_argument("--ticks", type=int, default=64)
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if any partition+compile exceeds this")
    ap.add_argument("--profile-links", action="store_true",
                    help="record per-link peak/mean load profiles")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    main(boards=tuple(args.boards.split(",")), chip=args.chip,
         classes=tuple(args.classes.split(",")), n_ticks=args.ticks,
         compile_budget_s=args.budget_s, profile_links=args.profile_links)

    if args.json:
        import json
        import platform
        from pathlib import Path
        payload = {"rows": RESULTS, "link_profiles": LINK_PROFILES,
                   "jax_version": jax.__version__,
                   "python": platform.python_version(),
                   "platform": platform.platform()}
        path = Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(payload, indent=1))
        print(f"# wrote {len(RESULTS)} rows to {path}")
