"""Table III + Fig. 17/18: synfire chain power with and without DVFS."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.configs import paper
from repro.core.snn import build_synfire, simulate_synfire, synfire_power_table


def main(n_ticks: int = 1200) -> None:
    net = build_synfire(0)
    t0 = time.perf_counter()
    recs = simulate_synfire(net, n_ticks)
    us = (time.perf_counter() - t0) / n_ticks * 1e6
    tab = synfire_power_table(recs)

    pl = np.asarray(recs["pl"])
    hist = np.bincount(pl.ravel(), minlength=3) / pl.size
    emit("fig18_pl_histogram", us,
         f"PL1={hist[0]:.3f};PL2={hist[1]:.3f};PL3={hist[2]:.3f}")

    spk = np.asarray(recs["spikes_exc"]).sum(axis=2)
    waves = np.where(spk[:, 0] > 100)[0]
    period = float(np.diff(waves[:6]).mean()) if len(waves) > 1 else -1
    emit("fig17_wave_period_ms", us, f"period={period};expected=80")

    for mode in ("pl3", "dvfs"):
        t = tab[mode]
        emit(f"tableIII_{mode}_mW", us,
             f"baseline={t['baseline']:.1f};neuron={t['neuron']:.2f};"
             f"synapse={t['synapse']:.2f};total={t['total']:.1f}")
    r = tab["reduction"]
    ref = paper.TABLE_III["reduction"]
    emit("tableIII_reduction", us,
         f"total={r['total']:.3f}(paper={ref['total']});"
         f"baseline={r['baseline']:.3f}(paper={ref['baseline']});"
         f"neuron={r['neuron']:.3f}(paper={ref['neuron']});"
         f"synapse={r['synapse']:.3f}(paper={ref['synapse']})")


if __name__ == "__main__":
    main()
