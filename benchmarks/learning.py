"""On-mesh learning benchmark: the numbers behind BENCH_pr5.json.

Three questions, answered per row:

* **does it learn?** — the adaptive-control loop (NEF ensemble + PES
  decoders tracking a reference plant, after Yan et al. 2009.08921)
  reports its convergence tick (first tick after which the worst
  channel's windowed tracking error stays below the threshold) and the
  final error, on a single chip AND across a 2x2 board through the
  UNCHANGED ``compile_board`` path (``refine=False`` keeps the loops
  split across chips, so weight updates are driven by errors that rode
  the SerDes tier);
* **what does it cost per tick?** — engine wall time per tick of the
  plastic program vs its frozen twin (same graph, ``plasticity=None``,
  fixed decoders) — the tick_us overhead of carrying + updating
  weights in the scan;
* **what does it cost in energy?** — the ``e_learn`` share of total
  chip energy (MAC-class weight updates + exp-accelerator trace decays
  vs Eq. (1) datapath + NoC traffic).

The STDP pair row exercises the fixed-point trace path (s16.15 decay
through the exp accelerator kernel) with the same three readouts.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import RESULTS, emit, time_call
from repro.board import BoardSpec
from repro.chip.chip import ChipSim
from repro.chip.compile import compile as compile_graph
from repro.learn.adaptive import (adaptive_control_graph,
                                  adaptive_control_workload,
                                  stdp_pair_workload)


def _tick_us(prog, n_ticks: int) -> float:
    sim = ChipSim(prog)
    runner = jax.jit(lambda: sim.run(n_ticks))
    return time_call(runner, warmup=1, iters=3) / n_ticks


def bench_adaptive(n_channels: int, n_neurons: int, n_ticks: int,
                   board: BoardSpec | None = None,
                   err_threshold: float = 0.1) -> None:
    where = (f"board{board.chips_x}x{board.chips_y}" if board is not None
             else "chip")
    name = f"learn_adaptive_{where}_{n_channels}ch"
    t0 = time.perf_counter()
    rep = adaptive_control_workload(
        n_channels=n_channels, n_neurons=n_neurons, n_ticks=n_ticks,
        board=board, err_threshold=err_threshold, refine=False)
    wall_s = time.perf_counter() - t0

    # tick cost: plastic vs frozen twin (same graph, plasticity=None)
    tick_us = _tick_us(rep["program"], n_ticks=64)
    frozen = adaptive_control_graph(n_channels, n_neurons, n_ticks=n_ticks,
                                    plastic=False)
    if board is not None:
        from repro.board import compile_board
        fprog = compile_board(frozen, board, refine=False)
    else:
        fprog = compile_graph(frozen)
    frozen_us = _tick_us(fprog, n_ticks=64)

    recs = rep["recs"]
    xf = (float(np.asarray(recs["flits_xchip"]).sum())
          if "flits_xchip" in recs else 0.0)
    emit(name, tick_us,
         f"channels={n_channels};neurons={n_neurons};"
         f"pes={rep['program'].n_pes};ticks={n_ticks};"
         f"conv_tick={rep['convergence_tick']};"
         f"final_err={rep['final_err']:.4f};"
         f"initial_err={rep['initial_err']:.4f};"
         f"err_threshold={err_threshold};"
         f"frozen_tick_us={frozen_us:.1f};"
         f"learn_overhead={tick_us / frozen_us - 1.0:.3f};"
         f"e_learn_mj={rep['e_learn_j'] * 1e3:.4f};"
         f"learn_energy_frac={rep['learn_energy_frac']:.4f};"
         f"xchip_flits={xf:.0f};wall_s={wall_s:.2f}")
    if rep["convergence_tick"] < 0:
        raise RuntimeError(
            f"{name}: tracking error never settled below {err_threshold} "
            f"(final {rep['final_err']:.3f}) — the closed loop must "
            f"converge for the row to be meaningful")


def bench_stdp(n_pre: int = 24, n_post: int = 8, n_ticks: int = 512) -> None:
    t0 = time.perf_counter()
    rep = stdp_pair_workload(n_pre=n_pre, n_post=n_post, n_ticks=n_ticks)
    wall_s = time.perf_counter() - t0
    tick_us = _tick_us(rep["program"], n_ticks=64)
    emit("learn_stdp_pair", tick_us,
         f"n_pre={n_pre};n_post={n_post};ticks={n_ticks};"
         f"w_mean_first={rep['w_mean_first']:.4f};"
         f"w_mean_last={rep['w_mean_last']:.4f};"
         f"post_spikes={rep['post_spikes']:.0f};"
         f"e_learn_mj={rep['e_learn_j'] * 1e3:.5f};"
         f"learn_energy_frac={rep['learn_energy_frac']:.5f};"
         f"wall_s={wall_s:.2f}")


def main(n_channels: int = 6, n_neurons: int = 100, n_ticks: int = 2048,
         board: str = "2x2", chip: str = "2x1",
         budget_s: float | None = None) -> None:
    t0 = time.perf_counter()
    bench_adaptive(n_channels, n_neurons, n_ticks)
    bench_adaptive(n_channels, n_neurons, n_ticks,
                   board=BoardSpec.parse(board, chip=chip))
    bench_stdp(n_ticks=min(n_ticks, 512))
    wall = time.perf_counter() - t0
    if budget_s is not None and wall > budget_s:
        raise RuntimeError(f"learning benchmark took {wall:.1f}s "
                           f"> budget {budget_s:.1f}s")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--channels", type=int, default=6)
    ap.add_argument("--neurons", type=int, default=100)
    ap.add_argument("--ticks", type=int, default=2048)
    ap.add_argument("--board", default="2x2")
    ap.add_argument("--chip", default="2x1")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="fail if the whole run exceeds this many seconds")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    main(n_channels=args.channels, n_neurons=args.neurons,
         n_ticks=args.ticks, board=args.board, chip=args.chip,
         budget_s=args.budget_s)

    if args.json:
        from repro.obs import write_bench_json
        write_bench_json(args.json, RESULTS, config=vars(args))
