"""Framework benchmark: LM train/decode step throughput on the smoke
configs (CPU) — exercises the full step machinery end to end."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro import configs
from repro.models import registry as R
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.train.step import make_train_step

ARCHS = ["qwen1.5-4b", "olmoe-1b-7b", "rwkv6-1.6b", "recurrentgemma-2b"]


def main() -> None:
    for arch in ARCHS:
        cfg = configs.get_arch(arch).smoke()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        B, S = 4, 64
        batch = R.make_dummy_batch(cfg, "train", B, S)
        step = jax.jit(make_train_step(cfg, ce_chunk=32, moe_dense=True))
        us = time_call(step, params, opt, batch, jnp.int32(0), iters=3)
        emit(f"lm_train_step_{arch}", us,
             f"tokens_per_s={B * S / (us / 1e6):.0f};smoke_params="
             f"{cfg.param_count() / 1e6:.1f}M")

        bparams = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, params)
        _, caches = T.prefill(cfg, bparams,
                              R.make_dummy_batch(cfg, "prefill", B, 16), 32,
                              moe_dense=True)
        dec = jax.jit(lambda p, c, pos, b: T.decode_step(cfg, p, c, pos, b,
                                                         moe_dense=True))
        db = R.make_dummy_batch(cfg, "decode", B, 1)
        us = time_call(dec, bparams, caches, jnp.int32(16), db, iters=3)
        emit(f"lm_decode_step_{arch}", us,
             f"tokens_per_s={B / (us / 1e6):.0f}")


if __name__ == "__main__":
    main()
